//! Eviction policies: Lethe (the paper's contribution) and the four
//! baselines it is evaluated against (FullKV, H2O, StreamingLLM,
//! PyramidKV), all behind one trait so the engine, the accuracy harness
//! (Table 1) and the simulator (Tables 2–3) compare like for like.
//!
//! A policy instance is **per sequence** (it owns per-layer adaptive
//! state, e.g. Lethe's `L_evict` thresholds). After every decode step the
//! engine updates the cache's score accumulator with the policy's γ
//! (RASR Eq. 5) and calls [`EvictionPolicy::plan`] per layer; `Some(keep)`
//! triggers [`crate::kvcache::GroupCache::apply_retention`].

/// FullKV baseline (never evicts; the paper's OOM column).
pub mod fullkv;
/// H2O heavy-hitter baseline.
pub mod h2o;
/// Lethe — the paper's layer- and time-adaptive policy (Algorithm 1).
pub mod lethe;
/// PyramidKV fixed layerwise-budget baseline.
pub mod pyramid;
/// StreamingLLM sink+recency baseline.
pub mod streaming;

use crate::config::ServingConfig;

pub use fullkv::FullKv;
pub use h2o::H2o;
pub use lethe::LethePolicy;
pub use pyramid::PyramidKv;
pub use streaming::StreamingLlm;

/// What the policy sees for one (layer, sequence) after a decode step.
#[derive(Clone, Copy, Debug)]
pub struct LayerState<'a> {
    /// Accumulated attention scores per cache slot (γ pre-applied).
    pub scores: &'a [f32],
    /// Original absolute position of each cache slot (recency signal).
    pub pos: &'a [i32],
    /// Live slots (== scores.len() == pos.len()).
    pub len: usize,
    /// Decode steps completed for this sequence.
    pub step: usize,
    /// EMA Hoyer sparsity of this layer's recent attention (Eq. 1).
    pub sparsity: f64,
    /// Hard per-sequence capacity (largest compiled bucket).
    pub capacity: usize,
}

/// Table 4 capability row.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Capabilities {
    /// Protects recent tokens explicitly.
    pub recency_aware: bool,
    /// Uses accumulated attention mass in its retention decision.
    pub attention_aware: bool,
    /// Allocates budget per layer rather than one global budget.
    pub layerwise_budget: bool,
    /// Adapts budgets at runtime (vs fixed at configuration time).
    pub adaptive_budget: bool,
    /// Prunes repeatedly over a generation (vs once after prefill).
    pub multi_step_pruning: bool,
}

/// One eviction policy instance, owned by a single sequence (it may
/// carry per-layer adaptive state). See the module docs for the engine
/// contract.
pub trait EvictionPolicy: Send {
    /// Display name (matches [`PolicyKind::label`]).
    fn name(&self) -> &'static str;

    /// Score decay γ the engine applies when accumulating attention mass
    /// (Eq. 5). 1.0 = plain cumulative sum (H2O-style).
    fn gamma(&self) -> f32 {
        1.0
    }

    /// Retention decision for one layer. `None` = keep everything this
    /// step; `Some(keep)` = retain exactly these slot indices (any order,
    /// deduplicated downstream; relative order is preserved by the cache).
    fn plan(&mut self, layer: usize, st: &LayerState<'_>) -> Option<Vec<usize>>;

    /// Conservative pre-pass for the pipelined engine: may a
    /// [`EvictionPolicy::plan`] call for `layer` at live length `len`
    /// (hard capacity `capacity`) prune **or mutate any adaptive
    /// state**? `false` promises the upcoming `plan` is a pure no-op —
    /// returns `None` without touching per-layer thresholds — so the
    /// engine can pre-submit the next decode step against the current
    /// cache layout while the policy lane runs concurrently. Policies
    /// must err toward `true` (the default): a wrong `true` only costs
    /// a pipeline drain; a wrong `false` would let a stale upload
    /// image reach the device (the engine's layout fingerprint still
    /// catches it, at the price of a wasted execute).
    fn may_prune(&self, layer: usize, len: usize, capacity: usize) -> bool {
        let _ = (layer, len, capacity);
        true
    }

    /// The policy's Table 4 capability row.
    fn capabilities(&self) -> Capabilities;
}

/// Selector for the five implemented policies (CLI/config/requests).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    /// Never evicts (upper-bound accuracy, OOMs at capacity).
    FullKv,
    /// The paper's layer- and time-adaptive policy.
    Lethe,
    /// Heavy-hitter + recency split budget.
    H2o,
    /// Attention-sink prefix + recency window.
    StreamingLlm,
    /// Fixed pyramidal per-layer budgets.
    PyramidKv,
}

impl PolicyKind {
    /// Parse a CLI/config/request policy name (case-insensitive).
    pub fn parse(s: &str) -> anyhow::Result<PolicyKind> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "fullkv" | "full" => PolicyKind::FullKv,
            "lethe" => PolicyKind::Lethe,
            "h2o" => PolicyKind::H2o,
            "streamingllm" | "streaming" => PolicyKind::StreamingLlm,
            "pyramidkv" | "pyramid" => PolicyKind::PyramidKv,
            _ => anyhow::bail!(
                "unknown policy '{s}' \
                 (fullkv|lethe|h2o|streamingllm|pyramidkv)"
            ),
        })
    }

    /// Paper-style display label (table rows, server responses).
    pub fn label(&self) -> &'static str {
        match self {
            PolicyKind::FullKv => "FullKV",
            PolicyKind::Lethe => "Lethe(ours)",
            PolicyKind::H2o => "H2O",
            PolicyKind::StreamingLlm => "StreamingLLM",
            PolicyKind::PyramidKv => "PyramidKV",
        }
    }

    /// Every implemented policy, in the paper's table order.
    pub const ALL: [PolicyKind; 5] = [
        PolicyKind::FullKv,
        PolicyKind::H2o,
        PolicyKind::StreamingLlm,
        PolicyKind::PyramidKv,
        PolicyKind::Lethe,
    ];
}

/// Build a fresh per-sequence policy instance.
pub fn make_policy(
    kind: PolicyKind,
    cfg: &ServingConfig,
    n_layers: usize,
) -> Box<dyn EvictionPolicy> {
    match kind {
        PolicyKind::FullKv => Box::new(FullKv),
        PolicyKind::Lethe => Box::new(LethePolicy::new(cfg.lethe.clone(), n_layers)),
        PolicyKind::H2o => Box::new(H2o::new(cfg.baseline.clone())),
        PolicyKind::StreamingLlm => {
            Box::new(StreamingLlm::new(cfg.baseline.clone()))
        }
        PolicyKind::PyramidKv => {
            Box::new(PyramidKv::new(cfg.baseline.clone(), n_layers))
        }
    }
}

/// Indices of the `k` largest scores (stable under ties by lower index).
/// Shared by H2O / PyramidKV / Lethe.
pub fn top_k_indices(scores: &[f32], k: usize) -> Vec<usize> {
    let k = k.min(scores.len());
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    // Ties broken toward lower index for determinism.
    idx.sort_by(|&a, &b| {
        scores[b]
            .partial_cmp(&scores[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    idx.truncate(k);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_labels() {
        assert_eq!(PolicyKind::parse("Lethe").unwrap(), PolicyKind::Lethe);
        assert_eq!(PolicyKind::parse("h2o").unwrap(), PolicyKind::H2o);
        assert_eq!(
            PolicyKind::parse("streaming").unwrap(),
            PolicyKind::StreamingLlm
        );
        assert!(PolicyKind::parse("nope").is_err());
        assert_eq!(PolicyKind::Lethe.label(), "Lethe(ours)");
    }

    #[test]
    fn top_k_ordering_and_ties() {
        let s = [0.1f32, 0.9, 0.5, 0.9, 0.0];
        assert_eq!(top_k_indices(&s, 3), vec![1, 3, 2]);
        assert_eq!(top_k_indices(&s, 0), Vec::<usize>::new());
        assert_eq!(top_k_indices(&s, 10).len(), 5);
    }

    #[test]
    fn factory_builds_every_kind() {
        let cfg = ServingConfig::default();
        for kind in PolicyKind::ALL {
            let p = make_policy(kind, &cfg, 4);
            assert_eq!(p.name(), kind.label());
        }
    }

    #[test]
    fn table4_capability_matrix() {
        let cfg = ServingConfig::default();
        let lethe = make_policy(PolicyKind::Lethe, &cfg, 4);
        let caps = lethe.capabilities();
        assert!(caps.recency_aware && caps.attention_aware);
        assert!(caps.layerwise_budget && caps.adaptive_budget);
        assert!(caps.multi_step_pruning);
        let h2o = make_policy(PolicyKind::H2o, &cfg, 4);
        assert!(!h2o.capabilities().layerwise_budget);
        let s = make_policy(PolicyKind::StreamingLlm, &cfg, 4);
        assert!(!s.capabilities().attention_aware);
    }
}
