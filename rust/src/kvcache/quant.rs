//! Quantized KV storage — the paper's composition claim ("Lethe can be
//! layered on top of quantized caches for compounded memory savings",
//! Related Work §Quantization).
//!
//! Per-row symmetric int8: each cached (layer, slot, head) K/V row of D
//! floats is stored as i8[D] + one f32 scale (KIVI-style per-token
//! granularity, the variant that preserves outlier channels best at this
//! row shape). 4×(1 − 33/132) ≈ 3.9× memory reduction vs f32; the
//! accuracy cost is bounded by the quantization-error tests below and is
//! orthogonal to (multiplies with) Lethe's token-count reduction.
//!
//! [`QuantCache`] mirrors the [`super::GroupCache`] retention/packing API
//! so the engine could swap storage backends; the repo keeps f32 as the
//! serving default (CPU PJRT gains nothing from i8 uploads) and uses this
//! module to quantify the compounded-savings claim in `hotpath`/tests.

use anyhow::{ensure, Result};

/// KV storage format, for byte accounting (Table 2). Every `live_bytes`
/// style metric routes through [`kv_row_bytes`] so memory numbers stay
/// honest across storage backends.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvFormat {
    /// 4 bytes per element (the serving default).
    F32,
    /// Per-row symmetric int8: 1 byte per element + one f32 scale per
    /// (head, tensor) row.
    QuantI8,
}

/// Bytes to store one cached token row — K *and* V, all `kv_heads` heads
/// of `d_head` elements — in the given format.
pub fn kv_row_bytes(kv_heads: usize, d_head: usize, fmt: KvFormat) -> usize {
    let per_head = match fmt {
        KvFormat::F32 => d_head * 4,
        KvFormat::QuantI8 => d_head + 4,
    };
    kv_heads * per_head * 2
}

/// One quantized row: i8 mantissas + a power-independent f32 scale.
#[derive(Clone, Debug, Default)]
pub struct QuantRow {
    pub q: Vec<i8>,
    pub scale: f32,
}

/// Symmetric per-row int8 quantization.
pub fn quantize_row(x: &[f32]) -> QuantRow {
    let amax = x.iter().fold(0f32, |m, &v| m.max(v.abs()));
    if amax == 0.0 {
        return QuantRow { q: vec![0; x.len()], scale: 0.0 };
    }
    let scale = amax / 127.0;
    let inv = 1.0 / scale;
    QuantRow {
        q: x.iter().map(|&v| (v * inv).round().clamp(-127.0, 127.0) as i8)
            .collect(),
        scale,
    }
}

pub fn dequantize_row(r: &QuantRow, out: &mut [f32]) {
    debug_assert_eq!(out.len(), r.q.len());
    for (o, &q) in out.iter_mut().zip(&r.q) {
        *o = q as f32 * r.scale;
    }
}

/// Quantized group cache: same logical layout as GroupCache
/// ([L, B, Hkv, C] rows of D), i8 storage.
pub struct QuantCache {
    pub layers: usize,
    pub batch: usize,
    pub kv_heads: usize,
    pub capacity: usize,
    pub d_head: usize,
    /// [L*B*Hkv*C] rows; empty rows have scale 0/len 0.
    k: Vec<QuantRow>,
    v: Vec<QuantRow>,
    lens: Vec<usize>, // [L*B]
}

impl QuantCache {
    pub fn new(layers: usize, batch: usize, kv_heads: usize,
               capacity: usize, d_head: usize) -> Self {
        let rows = layers * batch * kv_heads * capacity;
        QuantCache {
            layers,
            batch,
            kv_heads,
            capacity,
            d_head,
            k: vec![QuantRow::default(); rows],
            v: vec![QuantRow::default(); rows],
            lens: vec![0; layers * batch],
        }
    }

    fn row_idx(&self, l: usize, b: usize, h: usize, c: usize) -> usize {
        ((l * self.batch + b) * self.kv_heads + h) * self.capacity + c
    }

    pub fn len(&self, l: usize, b: usize) -> usize {
        self.lens[l * self.batch + b]
    }

    pub fn is_empty(&self) -> bool {
        self.lens.iter().all(|&n| n == 0)
    }

    /// Append one token's K/V rows (layout [Hkv, D] each).
    pub fn insert(&mut self, l: usize, b: usize, k_row: &[f32],
                  v_row: &[f32]) -> Result<()> {
        let d = self.d_head;
        ensure!(k_row.len() == self.kv_heads * d, "bad row");
        let c = self.len(l, b);
        ensure!(c < self.capacity, "quant cache overflow");
        for h in 0..self.kv_heads {
            let i = self.row_idx(l, b, h, c);
            self.k[i] = quantize_row(&k_row[h * d..(h + 1) * d]);
            self.v[i] = quantize_row(&v_row[h * d..(h + 1) * d]);
        }
        self.lens[l * self.batch + b] = c + 1;
        Ok(())
    }

    /// Dequantize the live prefix of (l, b, h) into `out` ([len, D]).
    pub fn dequantize_into(&self, l: usize, b: usize, h: usize,
                           which_v: bool, out: &mut [f32]) {
        let d = self.d_head;
        let n = self.len(l, b);
        debug_assert!(out.len() >= n * d);
        for c in 0..n {
            let i = self.row_idx(l, b, h, c);
            let row = if which_v { &self.v[i] } else { &self.k[i] };
            dequantize_row(row, &mut out[c * d..(c + 1) * d]);
        }
    }

    /// Front-packing retention gather (same contract as
    /// GroupCache::apply_retention).
    pub fn apply_retention(&mut self, l: usize, b: usize, keep: &[usize])
        -> Result<usize>
    {
        let n = self.len(l, b);
        let mut ks: Vec<usize> = keep.to_vec();
        ks.sort_unstable();
        ks.dedup();
        ensure!(ks.iter().all(|&i| i < n), "retention index out of range");
        for h in 0..self.kv_heads {
            for (dst, &src) in ks.iter().enumerate() {
                if dst != src {
                    let di = self.row_idx(l, b, h, dst);
                    let si = self.row_idx(l, b, h, src);
                    self.k.swap(di, si);
                    self.v.swap(di, si);
                }
            }
        }
        self.lens[l * self.batch + b] = ks.len();
        Ok(ks.len())
    }

    /// Stored bytes for the live rows (i8 + scale), vs 4 bytes/elem f32.
    pub fn live_bytes(&self) -> usize {
        let row = kv_row_bytes(self.kv_heads, self.d_head, KvFormat::QuantI8);
        self.lens.iter().map(|&n| n * row).sum()
    }

    /// f32-equivalent live bytes (what GroupCache would hold).
    pub fn f32_equivalent_bytes(&self) -> usize {
        let row = kv_row_bytes(self.kv_heads, self.d_head, KvFormat::F32);
        self.lens.iter().map(|&n| n * row).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::proptest::{check, vec_f32};

    #[test]
    fn kv_row_bytes_by_format() {
        // 2 heads * 4 elems * 4 bytes * 2 tensors
        assert_eq!(kv_row_bytes(2, 4, KvFormat::F32), 64);
        // 2 heads * (4 elems + 4-byte scale) * 2 tensors
        assert_eq!(kv_row_bytes(2, 4, KvFormat::QuantI8), 32);
    }

    #[test]
    fn roundtrip_error_is_bounded() {
        let mut rng = Rng::new(9);
        let x = vec_f32(&mut rng, 64, -3.0, 3.0);
        let q = quantize_row(&x);
        let mut y = vec![0f32; 64];
        dequantize_row(&q, &mut y);
        let amax = x.iter().fold(0f32, |m, &v| m.max(v.abs()));
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() <= amax / 127.0 * 0.5 + 1e-6,
                    "{a} vs {b}");
        }
    }

    #[test]
    fn zero_row_is_exact() {
        let q = quantize_row(&[0.0; 8]);
        assert_eq!(q.scale, 0.0);
        let mut y = [1f32; 8];
        dequantize_row(&q, &mut y);
        assert_eq!(y, [0.0; 8]);
    }

    #[test]
    fn property_quantization_relative_error() {
        check("quant-rel-err", 60, |rng, size| {
            let d = 4 + size;
            let x = vec_f32(rng, d, -10.0, 10.0);
            let q = quantize_row(&x);
            let mut y = vec![0f32; d];
            dequantize_row(&q, &mut y);
            let num: f32 =
                x.iter().zip(&y).map(|(a, b)| (a - b) * (a - b)).sum();
            let den: f32 = x.iter().map(|a| a * a).sum::<f32>().max(1e-12);
            let rel = (num / den).sqrt();
            if rel > 0.02 {
                return Err(format!("relative L2 error {rel}"));
            }
            Ok(())
        });
    }

    #[test]
    fn cache_insert_retain_dequantize() {
        let mut c = QuantCache::new(2, 1, 2, 8, 4);
        let mut rng = Rng::new(4);
        let mut originals = Vec::new();
        for _ in 0..5 {
            let k = vec_f32(&mut rng, 8, -1.0, 1.0);
            let v = vec_f32(&mut rng, 8, -1.0, 1.0);
            c.insert(0, 0, &k, &v).unwrap();
            c.insert(1, 0, &k, &v).unwrap();
            originals.push(k);
        }
        assert_eq!(c.len(0, 0), 5);
        c.apply_retention(0, 0, &[0, 2, 4]).unwrap();
        assert_eq!(c.len(0, 0), 3);
        let mut out = vec![0f32; 3 * 4];
        c.dequantize_into(0, 0, 1, false, &mut out);
        // Row 1 after retention == original token 2, head 1, ±quant err.
        for (a, b) in originals[2][4..8].iter().zip(&out[4..8]) {
            assert!((a - b).abs() < 0.02, "{a} vs {b}");
        }
    }

    #[test]
    fn compounded_savings_vs_f32() {
        let mut c = QuantCache::new(4, 1, 2, 64, 32);
        let row = vec![0.5f32; 64];
        for _ in 0..50 {
            for l in 0..4 {
                c.insert(l, 0, &row, &row).unwrap();
            }
        }
        let ratio = c.f32_equivalent_bytes() as f64 / c.live_bytes() as f64;
        assert!(ratio > 3.4, "quant saving only {ratio:.2}x");
        // Composition: Lethe's ~91.6% token reduction × 3.5x quantization
        // ≈ 40x+ total — the paper's "compounded" claim, quantified.
        let compounded = ratio * (1.0 / (1.0 - 0.916));
        assert!(compounded > 40.0);
    }

    #[test]
    fn overflow_guard() {
        let mut c = QuantCache::new(1, 1, 1, 2, 4);
        let row = [0.1f32; 4];
        c.insert(0, 0, &row, &row).unwrap();
        c.insert(0, 0, &row, &row).unwrap();
        assert!(c.insert(0, 0, &row, &row).is_err());
    }
}
