//! `lethe` — the serving-system CLI (leader entrypoint).
//!
//! Subcommands:
//!   info      — print artifact/model/executable info
//!   generate  — one-shot generation for a prompt
//!   serve     — run the request server over a generated Poisson trace
//!   eval      — Table 1 accuracy harness for one policy
//!   trace     — policy-trace / simulator smoke (big-model numbers)

use std::path::Path;

use anyhow::{bail, Result};

use lethe::config::{MixedKvRule, ServingConfig};
use lethe::engine::Engine;
use lethe::eval;
use lethe::model::{ModelMeta, Tokenizer, DEEPSEEK_R1_DISTILL};
use lethe::policy::PolicyKind;
use lethe::runtime::Runtime;
use lethe::server::{GenerateRequest, Server};
use lethe::sim::{run_trace, Simulator, TraceConfig};
use lethe::util::argparse::ArgSpec;
use lethe::util::prng::Rng;
use lethe::workload;

fn spec() -> ArgSpec {
    ArgSpec::new(
        "lethe: layer- and time-adaptive KV cache pruning for \
         reasoning-intensive LLM serving (AAAI'26 reproduction)",
    )
    .positional("cmd", "info|generate|serve|eval|trace")
    .opt("artifacts", "artifacts", "artifacts directory")
    .opt("config", "", "optional JSON config file")
    .opt("policy", "lethe", "fullkv|lethe|h2o|streamingllm|pyramidkv")
    .opt("kv-format", "",
         "KV storage backend: f32|q8|q4 (default: config/f32)")
    .opt("kv-mixed", "",
         "sparsity-directed per-layer formats, e.g. \
          sparse=q4,dense=f32,threshold=0.5 (keys optional; omitted \
          keys use exactly those defaults)")
    .opt("prefill-chunk", "",
         "chunked-prefill grain: prompt tokens consumed per scheduler \
          tick (default: config/64)")
    .opt("kv-budget-mb", "",
         "group-wide live-KV budget in MB; over it the youngest \
          sequence is recompute-preempted instead of OOM-killed \
          (default: unlimited)")
    .opt("prompt", "", "prompt text (generate)")
    .opt("max-new", "64", "max new tokens")
    .opt("n", "16", "requests (serve) / tasks per subject (eval)")
    .opt("batch", "4", "decode batch size")
    .opt("rate", "4.0", "arrival rate req/s (serve)")
    .opt("seed", "0", "workload seed")
    .opt("fault-seed", "",
         "seed for deterministic fault injection (default: config)")
    .opt("fault-rate", "",
         "per-site fault probability in [0,1); 0 disables injection \
          (default: config/0)")
    .flag("no-pipeline",
          "disable pipelined decode (serial step: pack+execute+policy)")
    .flag("verbose", "debug logging")
}

fn load_cfg(args: &lethe::util::argparse::Args) -> Result<ServingConfig> {
    let mut cfg = if args.get("config").is_empty() {
        ServingConfig::default()
    } else {
        ServingConfig::load(Path::new(args.get("config")))?
    };
    cfg.artifacts_dir = args.get("artifacts").to_string();
    cfg.scheduler.max_batch = args.get_usize("batch")?.max(1);
    if !args.get("kv-format").is_empty() {
        cfg.kv.format = lethe::kvcache::KvFormat::parse(args.get("kv-format"))?;
    }
    if args.has("kv-mixed") {
        cfg.kv.mixed = Some(parse_kv_mixed(args.get("kv-mixed"))?);
    }
    if !args.get("prefill-chunk").is_empty() {
        cfg.scheduler.prefill_chunk = args.get_usize("prefill-chunk")?;
    }
    if !args.get("kv-budget-mb").is_empty() {
        let mb = args.get_f64("kv-budget-mb")?;
        anyhow::ensure!(mb >= 0.0, "--kv-budget-mb must be >= 0");
        cfg.scheduler.kv_budget_bytes = (mb * 1e6) as usize;
    }
    if args.has("no-pipeline") {
        cfg.engine.pipeline_decode = false;
    }
    if !args.get("fault-seed").is_empty() {
        cfg.faults.seed = args.get_usize("fault-seed")? as u64;
    }
    if !args.get("fault-rate").is_empty() {
        cfg.faults.rate = args.get_f64("fault-rate")?;
    }
    cfg.validate()?;
    Ok(cfg)
}

/// Parse the `--kv-mixed` rule: comma-separated `key=value` pairs with
/// keys `sparse`, `dense`, `threshold`; omitted keys keep the
/// [`MixedKvRule`] defaults (sparse=q4, dense=f32, threshold=0.5).
fn parse_kv_mixed(s: &str) -> Result<MixedKvRule> {
    let mut rule = MixedKvRule::default();
    for part in s.split(',').filter(|p| !p.trim().is_empty()) {
        let Some((k, v)) = part.split_once('=') else {
            bail!("--kv-mixed entry '{part}' is not key=value");
        };
        match k.trim() {
            "sparse" => rule.sparse = lethe::kvcache::KvFormat::parse(v.trim())?,
            "dense" => rule.dense = lethe::kvcache::KvFormat::parse(v.trim())?,
            "threshold" => {
                rule.threshold = v
                    .trim()
                    .parse()
                    .map_err(|e| anyhow::anyhow!(
                        "--kv-mixed threshold '{}': {e}", v.trim()))?;
            }
            other => bail!(
                "unknown --kv-mixed key '{other}' \
                 (sparse|dense|threshold)"
            ),
        }
    }
    Ok(rule)
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match spec().parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    if args.has("verbose") {
        lethe::util::logging::set_level(lethe::util::logging::Level::Debug);
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("info");
    match cmd {
        "info" => cmd_info(&args),
        "generate" => cmd_generate(&args),
        "serve" => cmd_serve(&args),
        "eval" => cmd_eval(&args),
        "trace" => cmd_trace(&args),
        other => {
            eprintln!("unknown command '{other}'\n{}", spec().usage("lethe"));
            std::process::exit(2);
        }
    }
}

fn cmd_info(args: &lethe::util::argparse::Args) -> Result<()> {
    let meta = ModelMeta::load(Path::new(args.get("artifacts")))?;
    let d = &meta.dims;
    println!("model: {} params ({})", d.param_count, d.weights_source);
    println!(
        "dims: L={} d={} Hq={} Hkv={} Dh={} ff={} V={}",
        d.n_layers, d.d_model, d.n_q_heads, d.n_kv_heads, d.d_head, d.d_ff,
        d.vocab_size
    );
    println!("kv bytes/token: {}", meta.kv_bytes_per_token());
    println!("profiles: {:?}", meta.cache_profiles);
    println!("decode capacities: {:?}", meta.decode_capacities);
    println!("prefill buckets: {:?}", meta.prefill_ts);
    println!("executables ({}):", meta.executables.len());
    for name in meta.executables.keys() {
        println!("  {name}");
    }
    Ok(())
}

fn cmd_generate(args: &lethe::util::argparse::Args) -> Result<()> {
    let cfg = load_cfg(args)?;
    let policy = PolicyKind::parse(args.get("policy"))?;
    let prompt = if args.get("prompt").is_empty() {
        // Demo: a 2-hop reasoning task.
        let mut rng = Rng::new(args.get_usize("seed")? as u64);
        let t = workload::make_task(&mut rng, 8, 2);
        println!("task    : {}", t.prompt);
        println!("expected: {}", t.answer);
        t.prompt
    } else {
        args.get("prompt").to_string()
    };
    let server = Server::start(cfg, policy)?;
    let resp = server.generate(GenerateRequest {
        prompt,
        max_new_tokens: args.get_usize("max-new")?,
        policy: None,
        deadline_ms: None,
        class: None,
    })?;
    println!("output  : {}", resp.text);
    println!(
        "finish={} prompt_toks={} gen_toks={} ttft={:.3}s total={:.3}s \
         prune_rounds={} kv={}",
        resp.finish, resp.prompt_tokens, resp.generated_tokens, resp.ttft_s,
        resp.total_s, resp.prune_rounds, resp.kv_format
    );
    Ok(())
}

fn cmd_serve(args: &lethe::util::argparse::Args) -> Result<()> {
    let cfg = load_cfg(args)?;
    let policy = PolicyKind::parse(args.get("policy"))?;
    let n = args.get_usize("n")?;
    let rate = args.get_f64("rate")?;
    let max_new = args.get_usize("max-new")?;
    let mut rng = Rng::new(args.get_usize("seed")? as u64);
    let trace = workload::poisson_trace(&mut rng, rate, n);
    let server = Server::start(cfg, policy)?;

    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for item in &trace {
        // Open-loop replay: sleep to the arrival time, then submit.
        let wait = item.arrival_s - t0.elapsed().as_secs_f64();
        if wait > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(wait));
        }
        handles.push((
            item.task.clone(),
            server.submit(GenerateRequest {
                prompt: item.task.prompt.clone(),
                max_new_tokens: max_new,
                policy: None,
                deadline_ms: None,
                class: None,
            })?,
        ));
    }
    let mut correct = 0usize;
    let mut chain = 0usize;
    let mut ttfts = Vec::new();
    let mut totals = Vec::new();
    for (task, rx) in handles {
        let resp = rx.recv()??;
        let (final_ok, _) = eval::judge(&task, &resp.text);
        correct += final_ok as usize;
        chain += eval::judge_chain(&task, &resp.text) as usize;
        ttfts.push(resp.ttft_s);
        totals.push(resp.total_s);
    }
    let wall = t0.elapsed().as_secs_f64();
    let ts = lethe::util::stats::Summary::of(&ttfts);
    let tt = lethe::util::stats::Summary::of(&totals);
    println!(
        "served {n} requests in {wall:.2}s (offered rate {rate:.2} req/s)"
    );
    println!(
        "accuracy: chain {:.3}  final {:.3}",
        chain as f64 / n as f64,
        correct as f64 / n as f64
    );
    println!(
        "TTFT   p50 {:.3}s p99 {:.3}s | E2E p50 {:.3}s p99 {:.3}s",
        ts.p50, ts.p99, tt.p50, tt.p99
    );
    Ok(())
}

fn cmd_eval(args: &lethe::util::argparse::Args) -> Result<()> {
    let cfg = load_cfg(args)?;
    let policy = PolicyKind::parse(args.get("policy"))?;
    let rt = Runtime::load(Path::new(&cfg.artifacts_dir))?;
    let tok = Tokenizer::from_meta(&rt.meta)?;
    let mut engine = Engine::new(rt, cfg)?;
    let report = eval::eval_policy(
        &mut engine,
        &tok,
        policy,
        args.get_usize("n")?,
        args.get_usize("batch")?,
        args.get_usize("max-new")?,
        args.get_usize("seed")? as u64,
    )?;
    println!("policy: {}", policy.label());
    println!(
        "{:<10} {:>6} {:>10} {:>10} {:>10} {:>10} {:>12}",
        "subject", "n", "final_acc", "chain_acc", "strict", "gen_toks",
        "prune_rounds"
    );
    for s in &report.subjects {
        println!(
            "{:<10} {:>6} {:>10.3} {:>10.3} {:>10.3} {:>10.1} {:>12.1}",
            s.subject, s.n, s.final_acc, s.chain_acc, s.strict_acc,
            s.mean_generated, s.prune_rounds
        );
    }
    println!(
        "overall: final {:.3}  chain {:.3}",
        report.overall_final_acc(),
        report.overall_chain_acc()
    );
    Ok(())
}

fn cmd_trace(args: &lethe::util::argparse::Args) -> Result<()> {
    let cfg = load_cfg(args).unwrap_or_default();
    println!(
        "{:<46} {:>14} {:>14} {:>12}",
        "model/policy", "mean retained", "final retained", "prune events"
    );
    for arch in &DEEPSEEK_R1_DISTILL {
        let mut sim = Simulator::new(arch);
        sim.calibrate(2048.0, 30.0);
        for kind in PolicyKind::ALL {
            let tc = TraceConfig {
                n_layers: arch.n_layers,
                gen_len: 2048,
                ..TraceConfig::default()
            };
            let tr = run_trace(kind, &cfg, &tc);
            println!(
                "{:<46} {:>14.0} {:>14.0} {:>12}",
                format!("{}/{}", arch.name, kind.label()),
                tr.mean_retained(),
                tr.final_retained(),
                tr.prune_events
            );
        }
    }
    Ok(())
}
