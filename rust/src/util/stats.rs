//! Summary statistics and the measurement core of the bench harness
//! (criterion substitute): warmup + timed iterations + robust summaries.

use std::time::{Duration, Instant};

/// Streaming mean/variance (Welford). Used by metrics counters.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }
    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Batch summary with percentiles (nearest-rank on a sorted copy).
#[derive(Clone, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty slice");
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut w = Welford::default();
        for &x in xs {
            w.push(x);
        }
        Summary {
            n: xs.len(),
            mean: w.mean(),
            std: w.std(),
            min: s[0],
            max: *s.last().unwrap(),
            p50: percentile_sorted(&s, 50.0),
            p90: percentile_sorted(&s, 90.0),
            p99: percentile_sorted(&s, 99.0),
        }
    }
}

/// Nearest-rank percentile over a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Fixed-boundary latency histogram (log-spaced buckets, microseconds).
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    bounds_us: Vec<f64>,
    counts: Vec<u64>,
    total: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        // 1us .. ~100s, quarter-decade spacing.
        let bounds: Vec<f64> =
            (0..33).map(|i| 10f64.powf(i as f64 / 4.0)).collect();
        let n = bounds.len();
        LatencyHistogram { bounds_us: bounds, counts: vec![0; n + 1], total: 0 }
    }
}

impl LatencyHistogram {
    pub fn record(&mut self, d: Duration) {
        let us = d.as_secs_f64() * 1e6;
        let idx = self
            .bounds_us
            .iter()
            .position(|&b| us <= b)
            .unwrap_or(self.bounds_us.len());
        self.counts[idx] += 1;
        self.total += 1;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// Approximate quantile from bucket upper bounds.
    pub fn quantile_us(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return if i < self.bounds_us.len() {
                    self.bounds_us[i]
                } else {
                    *self.bounds_us.last().unwrap()
                };
            }
        }
        *self.bounds_us.last().unwrap()
    }
}

/// Streaming quantile estimator — the P² algorithm (Jain & Chlamtac,
/// CACM 1985). Five markers track (min, p/2, p, (1+p)/2, max); each
/// observation nudges the middle markers toward their ideal positions
/// with a piecewise-parabolic height adjustment. O(1) memory per
/// tracked quantile, which is what lets [`crate::metrics`] keep
/// per-tenant-class latency percentiles alive across an unbounded soak
/// without retaining every sample. Exact (nearest-rank on the sorted
/// prefix) until five observations have arrived.
#[derive(Clone, Debug)]
pub struct P2Quantile {
    /// Target quantile in (0, 1), e.g. 0.95.
    p: f64,
    /// Marker heights.
    q: [f64; 5],
    /// Actual marker positions (1-based ranks).
    n: [f64; 5],
    /// Desired marker positions.
    np: [f64; 5],
    /// Desired-position increments per observation.
    dn: [f64; 5],
    count: u64,
}

impl P2Quantile {
    pub fn new(p: f64) -> P2Quantile {
        assert!(p > 0.0 && p < 1.0, "quantile must be in (0,1), got {p}");
        P2Quantile {
            p,
            q: [0.0; 5],
            n: [1.0, 2.0, 3.0, 4.0, 5.0],
            np: [1.0, 1.0 + 2.0 * p, 1.0 + 4.0 * p, 3.0 + 2.0 * p, 5.0],
            dn: [0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0],
            count: 0,
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Piecewise-parabolic (falling back to linear) height update for
    /// marker `i` moved by `d` (±1).
    fn adjust(&mut self, i: usize, d: f64) {
        let parabolic = self.q[i]
            + d / (self.n[i + 1] - self.n[i - 1])
                * ((self.n[i] - self.n[i - 1] + d)
                    * (self.q[i + 1] - self.q[i])
                    / (self.n[i + 1] - self.n[i])
                    + (self.n[i + 1] - self.n[i] - d)
                        * (self.q[i] - self.q[i - 1])
                        / (self.n[i] - self.n[i - 1]));
        self.q[i] = if self.q[i - 1] < parabolic && parabolic < self.q[i + 1]
        {
            parabolic
        } else {
            // Linear fallback keeps marker heights monotone.
            let j = if d > 0.0 { i + 1 } else { i - 1 };
            self.q[i] + d * (self.q[j] - self.q[i]) / (self.n[j] - self.n[i])
        };
        self.n[i] += d;
    }

    pub fn push(&mut self, x: f64) {
        self.count += 1;
        if self.count <= 5 {
            self.q[self.count as usize - 1] = x;
            if self.count == 5 {
                self.q.sort_by(|a, b| a.partial_cmp(b).unwrap());
            }
            return;
        }
        // Locate the cell and bump extreme markers.
        let k = if x < self.q[0] {
            self.q[0] = x;
            0
        } else if x < self.q[1] {
            0
        } else if x < self.q[2] {
            1
        } else if x < self.q[3] {
            2
        } else if x <= self.q[4] {
            3
        } else {
            self.q[4] = x;
            3
        };
        for i in (k + 1)..5 {
            self.n[i] += 1.0;
        }
        for i in 0..5 {
            self.np[i] += self.dn[i];
        }
        // Nudge interior markers toward their desired positions.
        for i in 1..4 {
            let d = self.np[i] - self.n[i];
            if (d >= 1.0 && self.n[i + 1] - self.n[i] > 1.0)
                || (d <= -1.0 && self.n[i - 1] - self.n[i] < -1.0)
            {
                self.adjust(i, d.signum());
            }
        }
    }

    /// Current estimate: exact nearest-rank while fewer than five
    /// observations have arrived, the middle marker afterwards.
    pub fn value(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        if self.count < 5 {
            let mut s: Vec<f64> = self.q[..self.count as usize].to_vec();
            s.sort_by(|a, b| a.partial_cmp(b).unwrap());
            return percentile_sorted(&s, self.p * 100.0);
        }
        self.q[2]
    }
}

/// Bounded streaming summary: Welford moments + min/max + a running
/// sum, plus [`P2Quantile`] markers at p50/p90/p99 — everything a
/// [`Summary`] reports, in O(1) memory. This is what lets
/// [`crate::metrics`] keep per-phase step timings alive across an
/// unbounded soak without retaining every sample (the former
/// `Vec<f64>`-per-step logs grew forever).
#[derive(Clone, Debug)]
pub struct StreamStat {
    w: Welford,
    sum: f64,
    min: f64,
    max: f64,
    p50: P2Quantile,
    p90: P2Quantile,
    p99: P2Quantile,
}

impl Default for StreamStat {
    fn default() -> Self {
        StreamStat {
            w: Welford::default(),
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            p50: P2Quantile::new(0.5),
            p90: P2Quantile::new(0.9),
            p99: P2Quantile::new(0.99),
        }
    }
}

impl StreamStat {
    pub fn push(&mut self, x: f64) {
        self.w.push(x);
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        self.p50.push(x);
        self.p90.push(x);
        self.p99.push(x);
    }

    pub fn count(&self) -> u64 {
        self.w.count()
    }

    /// Running total of every observation (exact, not estimated).
    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        self.w.mean()
    }

    /// Snapshot in the batch-summary shape. Zeroed when empty (the
    /// metrics layer reports `None` rather than a zero row; see
    /// [`crate::metrics::EngineMetrics::phase_summaries`]).
    pub fn summary(&self) -> Summary {
        if self.count() == 0 {
            return Summary {
                n: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                max: 0.0,
                p50: 0.0,
                p90: 0.0,
                p99: 0.0,
            };
        }
        Summary {
            n: self.count() as usize,
            mean: self.w.mean(),
            std: self.w.std(),
            min: self.min,
            max: self.max,
            p50: self.p50.value(),
            p90: self.p90.value(),
            p99: self.p99.value(),
        }
    }
}

/// Criterion-substitute measurement: `warmup` untimed runs, then time
/// `iters` runs of `f`, returning per-iteration seconds.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Summary::of(&samples)
}

/// Render a bench row the way the harness prints everything:
/// name, mean, p50, p99 (milliseconds).
pub fn bench_row(name: &str, s: &Summary) -> String {
    format!(
        "{:<40} mean {:>9.3} ms   p50 {:>9.3} ms   p99 {:>9.3} ms   (n={})",
        name,
        s.mean * 1e3,
        s.p50 * 1e3,
        s.p99 * 1e3,
        s.n
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 4.0).abs() < 1e-12);
        let direct_var =
            xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 4.0;
        assert!((w.variance() - direct_var).abs() < 1e-12);
    }

    #[test]
    fn summary_percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.p50, 50.0);
        assert_eq!(s.p99, 99.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
    }

    #[test]
    fn histogram_quantile_monotone() {
        let mut h = LatencyHistogram::default();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i));
        }
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.99));
        assert_eq!(h.count(), 1000);
    }

    #[test]
    fn p2_exact_below_five_samples() {
        let mut est = P2Quantile::new(0.5);
        for x in [3.0, 1.0, 2.0] {
            est.push(x);
        }
        assert_eq!(est.value(), 2.0);
        assert_eq!(est.count(), 3);
    }

    #[test]
    fn p2_tracks_exact_percentile_on_random_inputs() {
        // Property: on random samples the streaming estimate stays
        // close to the exact nearest-rank percentile of the full sort.
        crate::util::proptest::check("p2-vs-sort", 30, |rng, size| {
            let n = 200 + size % 800;
            let p = *rng.choose(&[0.5, 0.9, 0.95, 0.99]);
            let mut est = P2Quantile::new(p);
            let mut xs = Vec::with_capacity(n);
            for _ in 0..n {
                // Mix of scales so the parabolic update is exercised
                // away from the uniform easy case.
                let x = rng.f64() + if rng.bool(0.1) { 5.0 * rng.f64() } else { 0.0 };
                est.push(x);
                xs.push(x);
            }
            xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let exact = percentile_sorted(&xs, p * 100.0);
            let got = est.value();
            let span = xs[xs.len() - 1] - xs[0];
            if (got - exact).abs() > 0.12 * span.max(1e-12) {
                return Err(format!(
                    "p={p} n={n}: estimate {got} vs exact {exact} \
                     (span {span})"
                ));
            }
            if got < xs[0] || got > xs[xs.len() - 1] {
                return Err(format!("estimate {got} outside sample range"));
            }
            Ok(())
        });
    }

    #[test]
    fn stream_stat_matches_batch_summary_on_exact_prefix() {
        // Below five samples every P² marker is exact, so the streaming
        // summary must agree with the batch one bit-for-bit on the
        // deterministic fields and exactly on the percentiles.
        let xs = [0.5, 1.0, 0.5];
        let mut st = StreamStat::default();
        for &x in &xs {
            st.push(x);
        }
        let batch = Summary::of(&xs);
        let s = st.summary();
        assert_eq!(s.n, 3);
        assert!((st.sum() - 2.0).abs() < 1e-12);
        assert!((s.mean - batch.mean).abs() < 1e-12);
        assert!((s.std - batch.std).abs() < 1e-12);
        assert_eq!(s.min, batch.min);
        assert_eq!(s.max, batch.max);
        assert_eq!(s.p50, batch.p50);
        assert_eq!(s.p99, batch.p99);
        // Empty accumulator reports a zero row, never panics.
        let empty = StreamStat::default().summary();
        assert_eq!(empty.n, 0);
        assert_eq!(empty.min, 0.0);
    }

    #[test]
    fn stream_stat_tracks_batch_summary_on_random_inputs() {
        crate::util::proptest::check("streamstat-vs-sort", 20, |rng, size| {
            let n = 100 + size % 500;
            let mut st = StreamStat::default();
            let mut xs = Vec::with_capacity(n);
            for _ in 0..n {
                let x = rng.f64() * if rng.bool(0.2) { 10.0 } else { 1.0 };
                st.push(x);
                xs.push(x);
            }
            let batch = Summary::of(&xs);
            let s = st.summary();
            if (s.mean - batch.mean).abs() > 1e-9 {
                return Err(format!("mean {} vs {}", s.mean, batch.mean));
            }
            if s.min != batch.min || s.max != batch.max {
                return Err("min/max drifted".into());
            }
            if (st.sum() - xs.iter().sum::<f64>()).abs() > 1e-9 {
                return Err("sum drifted".into());
            }
            let span = (batch.max - batch.min).max(1e-12);
            for (got, exact) in
                [(s.p50, batch.p50), (s.p90, batch.p90), (s.p99, batch.p99)]
            {
                if (got - exact).abs() > 0.15 * span {
                    return Err(format!(
                        "percentile {got} vs exact {exact} (span {span})"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn bench_returns_reasonable_samples() {
        let s = bench(2, 10, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(s.n, 10);
        assert!(s.min >= 0.0 && s.mean < 1.0);
    }
}
