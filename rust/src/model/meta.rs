//! Parses `artifacts/model_meta.json` — the wire contract emitted by
//! `python/compile/aot.py`: model dims, tokenizer vocab, weight layout,
//! and the manifest of compiled HLO executables with their bucket shapes.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::{parse, Json};

#[derive(Clone, Debug)]
pub struct ModelDims {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_q_heads: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub param_count: usize,
    pub weights_source: String,
}

#[derive(Clone, Debug)]
pub struct WeightSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub bytes: usize,
}

#[derive(Clone, Debug)]
pub struct ExecutableSpec {
    pub name: String,
    pub file: String,
    /// (shape, dtype) per parameter, in lowered order (weights first).
    pub params: Vec<(Vec<usize>, String)>,
    pub outputs: Vec<String>,
}

#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub dir: PathBuf,
    pub dims: ModelDims,
    pub specials: Vec<String>,
    pub chars: String,
    pub weights: Vec<WeightSpec>,
    pub executables: BTreeMap<String, ExecutableSpec>,
    pub cache_profiles: BTreeMap<String, usize>,
    /// Per profile: compiled decode cache-capacity buckets (ascending).
    pub decode_capacities: BTreeMap<String, Vec<usize>>,
    pub decode_batches: BTreeMap<String, Vec<usize>>,
    pub prefill_ts: Vec<usize>,
}

fn usize_arr(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()?.iter().map(|x| x.as_usize()).collect()
}

fn usize_map(j: &Json) -> Result<BTreeMap<String, usize>> {
    j.as_obj()?
        .iter()
        .map(|(k, v)| Ok((k.clone(), v.as_usize()?)))
        .collect()
}

fn usize_arr_map(j: &Json) -> Result<BTreeMap<String, Vec<usize>>> {
    j.as_obj()?
        .iter()
        .map(|(k, v)| Ok((k.clone(), usize_arr(v)?)))
        .collect()
}

impl ModelMeta {
    pub fn load(artifacts_dir: &Path) -> Result<ModelMeta> {
        let path = artifacts_dir.join("model_meta.json");
        let src = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {path:?} — run `make artifacts` first"
            )
        })?;
        let j = parse(&src).context("parsing model_meta.json")?;

        let m = j.get("model")?;
        let dims = ModelDims {
            vocab_size: m.get("vocab_size")?.as_usize()?,
            d_model: m.get("d_model")?.as_usize()?,
            n_layers: m.get("n_layers")?.as_usize()?,
            n_q_heads: m.get("n_q_heads")?.as_usize()?,
            n_kv_heads: m.get("n_kv_heads")?.as_usize()?,
            d_head: m.get("d_head")?.as_usize()?,
            d_ff: m.get("d_ff")?.as_usize()?,
            param_count: m.get("param_count")?.as_usize()?,
            weights_source: m.get("weights_source")?.as_str()?.to_string(),
        };

        let tok = j.get("tokenizer")?;
        let specials = tok
            .get("specials")?
            .as_arr()?
            .iter()
            .map(|s| Ok(s.as_str()?.to_string()))
            .collect::<Result<Vec<_>>>()?;
        let chars = tok.get("chars")?.as_str()?.to_string();

        let weights = j
            .get("weights")?
            .as_arr()?
            .iter()
            .map(|w| {
                Ok(WeightSpec {
                    name: w.get("name")?.as_str()?.to_string(),
                    shape: usize_arr(w.get("shape")?)?,
                    offset: w.get("offset")?.as_usize()?,
                    bytes: w.get("bytes")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let executables = j
            .get("executables")?
            .as_arr()?
            .iter()
            .map(|e| {
                let spec = ExecutableSpec {
                    name: e.get("name")?.as_str()?.to_string(),
                    file: e.get("file")?.as_str()?.to_string(),
                    params: e
                        .get("params")?
                        .as_arr()?
                        .iter()
                        .map(|p| {
                            Ok((
                                usize_arr(p.get("shape")?)?,
                                p.get("dtype")?.as_str()?.to_string(),
                            ))
                        })
                        .collect::<Result<Vec<_>>>()?,
                    outputs: e
                        .get("outputs")?
                        .as_arr()?
                        .iter()
                        .map(|o| Ok(o.as_str()?.to_string()))
                        .collect::<Result<Vec<_>>>()?,
                };
                Ok((spec.name.clone(), spec))
            })
            .collect::<Result<BTreeMap<_, _>>>()?;

        Ok(ModelMeta {
            dir: artifacts_dir.to_path_buf(),
            dims,
            specials,
            chars,
            weights,
            executables,
            cache_profiles: usize_map(j.get("cache_profiles")?)?,
            decode_capacities: usize_arr_map(j.get("decode_capacities")?)?,
            decode_batches: usize_arr_map(j.get("decode_batches")?)?,
            prefill_ts: usize_arr(j.get("prefill_ts")?)?,
        })
    }

    /// Cache capacity C for a profile name.
    pub fn capacity(&self, profile: &str) -> Result<usize> {
        self.cache_profiles
            .get(profile)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unknown cache profile '{profile}'"))
    }

    /// KV bytes per cached token per sequence (all layers, K+V, f32).
    pub fn kv_bytes_per_token(&self) -> usize {
        self.dims.n_layers * 2 * self.dims.n_kv_heads * self.dims.d_head * 4
    }

    /// Token id of a named special (its position in the manifest's
    /// `tokenizer.specials` list), e.g. `special_id("<eos>")`.
    pub fn special_id(&self, name: &str) -> Option<i32> {
        self.specials.iter().position(|s| s == name).map(|i| i as i32)
    }

    /// EOS token id from the manifest (None when the vocabulary carries
    /// no `"<eos>"` special — callers decide their fallback).
    pub fn eos_id(&self) -> Option<i32> {
        self.special_id("<eos>")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Integration-style: parses the real artifact manifest if present
    /// (`make artifacts`), otherwise skipped.
    #[test]
    fn loads_real_manifest_when_present() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("model_meta.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let meta = ModelMeta::load(&dir).unwrap();
        assert!(meta.dims.n_layers >= 1);
        assert_eq!(
            meta.dims.vocab_size,
            meta.specials.len() + meta.chars.chars().count()
        );
        assert!(meta.kv_bytes_per_token() > 0);
        for spec in meta.executables.values() {
            assert!(dir.join(&spec.file).exists(), "missing {}", spec.file);
        }
    }
}
