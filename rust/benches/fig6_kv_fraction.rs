//! Figure 6: KV-cache memory as a fraction of total GPU memory versus
//! token length, DeepSeek-R1-Distill-Llama-8B vs -70B (batch 1, FullKV).
//! Under the baseline the KV share approaches ~50% of GPU memory at long
//! contexts; after Lethe the dominant consumer shifts back to weights.

use lethe::bench_support::{print_table, write_csv};
use lethe::config::ServingConfig;
use lethe::model::arch_by_name;
use lethe::policy::PolicyKind;
use lethe::sim::{run_trace, Simulator, TraceConfig};

fn main() -> anyhow::Result<()> {
    let mut cfg = ServingConfig::default();
    cfg.lethe.evict_threshold = 512;
    cfg.lethe.sink_len = 16;

    let mut rows = Vec::new();
    let mut csv = Vec::new();
    let lens: Vec<usize> =
        (0..=20).map(|i| 1000 + i * 1450).collect(); // 1k .. 30k

    for name in ["Llama-8B", "Llama-70B"] {
        let arch = arch_by_name(name).unwrap();
        let sim = Simulator::new(arch);
        let tc = TraceConfig {
            n_layers: arch.n_layers,
            prompt_len: 512,
            gen_len: 30_000,
            ..TraceConfig::default()
        };
        let lethe = run_trace(PolicyKind::Lethe, &cfg, &tc);
        for &t in &lens {
            let full = sim.kv_fraction(t as f64);
            let retained = lethe.retained[t.min(lethe.retained.len()) - 1];
            let kv_lethe = retained
                * arch.kv_bytes_per_token_per_gpu() as f64
                * lethe::sim::KV_FRAG;
            let lethe_frac = kv_lethe
                / (arch.weight_bytes_per_gpu() as f64 + kv_lethe);
            csv.push(format!(
                "{},{},{:.4},{:.4}",
                arch.name, t, full, lethe_frac
            ));
            if t % 5800 < 1450 {
                rows.push(vec![
                    name.to_string(),
                    format!("{t}"),
                    format!("{:.1}%", 100.0 * full),
                    format!("{:.1}%", 100.0 * lethe_frac),
                ]);
            }
        }
    }
    print_table(
        "Fig 6 — KV share of per-GPU memory vs context length",
        &["model", "tokens", "FullKV", "Lethe"],
        &rows,
    );
    write_csv(
        "fig6_kv_fraction.csv",
        "model,tokens,fullkv_fraction,lethe_fraction",
        &csv,
    )?;
    println!(
        "\nshape check: FullKV KV share grows toward ~50% (paper Fig. 6); \
         Lethe keeps it under a few percent — weights dominate again."
    );
    Ok(())
}
