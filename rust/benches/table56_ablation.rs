//! Tables 5 & 6: ablations of Lethe's two hyperparameters on the real
//! engine — recent_ratio ∈ {0.1, 0.2, 0.3, 0.4} (Table 5) and
//! sparse_ratio τ ∈ {20, 100, 400, 1000} (Table 6), against the FullKV
//! reference row. Metrics mirror the paper: accuracy on the Math500
//! proxy (hop3-16), wall latency, peak KV memory, decode throughput.
//!
//! Expected shape: accuracy plateaus above sparse_ratio≈400 while memory
//! keeps growing; recent_ratio≈0.3 is the sweet spot.

use lethe::bench_support::{gen_tasks, print_table, run_tasks, try_engine,
                           write_csv};
use lethe::config::ServingConfig;
use lethe::policy::PolicyKind;

fn env_usize(k: &str, default: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let n = env_usize("LETHE_BENCH_N", 30);
    let base = ServingConfig::default();
    let tasks = gen_tasks(0x5E55, n, 16, 3); // the Math500 proxy subject

    let mut rows = Vec::new();
    let mut csv = Vec::new();

    let mut run_one = |label: String,
                       cfg: ServingConfig,
                       kind: PolicyKind,
                       rows: &mut Vec<Vec<String>>,
                       csv: &mut Vec<String>|
     -> anyhow::Result<()> {
        let Some((mut engine, tok)) = try_engine(cfg) else {
            anyhow::bail!("no artifacts")
        };
        engine.cfg.lethe.evict_threshold = engine.cfg.lethe.evict_threshold.max(1);
        engine.metrics.reset();
        let st = run_tasks(&mut engine, &tok, kind, &tasks, 4, 64)?;
        rows.push(vec![
            label.clone(),
            format!("{:.1}", 100.0 * st.chain_acc),
            format!("{:.2}", st.wall_s),
            format!("{:.0}", st.peak_live_bytes as f64 / 1e3),
            format!("{:.0}", engine.metrics.decode_tput()),
            format!("{}", st.prune_events),
        ]);
        csv.push(format!(
            "{label},{:.4},{:.4},{:.3},{},{:.1},{}",
            st.chain_acc,
            st.final_acc,
            st.wall_s,
            st.peak_live_bytes,
            engine.metrics.decode_tput(),
            st.prune_events
        ));
        Ok(())
    };

    // FullKV reference row (shared by both tables).
    run_one("FullKV".into(), base.clone(), PolicyKind::FullKv, &mut rows,
            &mut csv)?;

    // Table 5: recent_ratio sweep.
    for rr in [0.1, 0.2, 0.3, 0.4] {
        let mut cfg = base.clone();
        cfg.lethe.recent_ratio = rr;
        cfg.lethe.evict_threshold = 48;
        run_one(format!("rr={rr}"), cfg, PolicyKind::Lethe, &mut rows,
                &mut csv)?;
    }
    print_table(
        &format!("Table 5 — recent_ratio ablation (hop3-16, n={n})"),
        &["config", "acc%", "lat_s", "peakKB", "tok/s", "prunes"],
        &rows,
    );
    write_csv(
        "table5_recent_ratio.csv",
        "config,chain_acc,final_acc,wall_s,peak_bytes,tok_s,prune_events",
        &csv,
    )?;

    // Table 6: sparse_ratio (τ) sweep.
    let mut rows6 = vec![rows[0].clone()]; // FullKV row again
    let mut csv6 = vec![csv[0].clone()];
    for tau in [20.0, 100.0, 400.0, 1000.0] {
        let mut cfg = base.clone();
        cfg.lethe.sparse_ratio = tau;
        cfg.lethe.evict_threshold = 48;
        run_one(format!("tau={tau}"), cfg, PolicyKind::Lethe, &mut rows6,
                &mut csv6)?;
    }
    print_table(
        &format!("Table 6 — sparse_ratio (tau) ablation (hop3-16, n={n})"),
        &["config", "acc%", "lat_s", "peakKB", "tok/s", "prunes"],
        &rows6,
    );
    write_csv(
        "table6_sparse_ratio.csv",
        "config,chain_acc,final_acc,wall_s,peak_bytes,tok_s,prune_events",
        &csv6,
    )?;
    Ok(())
}
