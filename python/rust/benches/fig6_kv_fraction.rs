fn main() {}
