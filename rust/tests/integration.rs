//! Integration tests over cache + policies + simulator that do NOT need
//! artifacts (pure-rust paths across module boundaries). The
//! artifact-dependent end-to-end path lives in `engine_e2e.rs`.

use lethe::attn::sparsity::hoyer_sparsity;
use lethe::config::ServingConfig;
use lethe::kvcache::{CacheDims, GroupCache};
use lethe::policy::{make_policy, LayerState, PolicyKind};
use lethe::sim::{run_trace, Simulator, TraceConfig};
use lethe::util::prng::Rng;
use lethe::util::proptest::check;
use lethe::workload::make_task;

fn dims(batch: usize, cap: usize) -> CacheDims {
    CacheDims { layers: 3, batch, kv_heads: 2, capacity: cap, d_head: 8 }
}

/// Drive a cache + policy pair the way the engine does, with synthetic
/// attention, and assert the cross-module invariants hold for every
/// policy kind.
#[test]
fn cache_and_policies_stay_consistent_under_decode_pressure() {
    let mut cfg = ServingConfig::default();
    cfg.baseline.budget = 24;
    cfg.lethe.evict_threshold = 16;
    cfg.lethe.segments = 4;
    cfg.lethe.sparse_ratio = 8.0;

    for kind in PolicyKind::ALL {
        let mut cache = GroupCache::new(dims(1, 512));
        let mut policy = make_policy(kind, &cfg, 3);
        let row: Vec<f32> = (0..16).map(|i| i as f32).collect();

        for t in 0..200i32 {
            for l in 0..3 {
                cache.insert(l, 0, &row, &row, t).unwrap();
                let n = cache.len(l, 0);
                // Synthetic peaked attention over live slots.
                let mut add = vec![0.001f32; n];
                add[n - 1] = 0.5;
                add[n / 2] = 0.3;
                cache.accumulate_scores(l, 0, policy.gamma(), &add);
                let st = LayerState {
                    scores: cache.scores(l, 0),
                    pos: cache.pos(l, 0),
                    len: n,
                    step: t as usize,
                    sparsity: hoyer_sparsity(&add),
                    capacity: 512,
                };
                let plan = policy.plan(l, &st);
                if let Some(keep) = plan {
                    cache.apply_retention(l, 0, &keep).unwrap();
                }
                // Invariants after every step:
                let len = cache.len(l, 0);
                assert!(len >= 1, "{kind:?} emptied the cache");
                assert!(len <= 512);
                assert_eq!(cache.pos(l, 0).len(), len);
                assert_eq!(cache.scores(l, 0).len(), len);
                // pos strictly increasing (relative order preserved).
                assert!(
                    cache.pos(l, 0).windows(2).all(|w| w[0] < w[1]),
                    "{kind:?} broke slot ordering at t={t}"
                );
                // Most recent token always survives.
                assert_eq!(*cache.pos(l, 0).last().unwrap(), t,
                           "{kind:?} evicted the current token");
            }
        }
        // Budgeted policies must actually have bounded the cache.
        if !matches!(kind, PolicyKind::FullKv) {
            for l in 0..3 {
                assert!(
                    cache.len(l, 0) < 200,
                    "{kind:?} layer {l} never pruned ({} slots)",
                    cache.len(l, 0)
                );
            }
        } else {
            assert_eq!(cache.len(0, 0), 200);
        }
    }
}

#[test]
fn lethe_budgets_follow_sparsity_across_layers() {
    // Feed layer 0 peaked attention (sparse) and layer 1 uniform
    // attention (dense); Lethe should end up retaining more on layer 1.
    let mut cfg = ServingConfig::default();
    cfg.lethe.evict_threshold = 24;
    cfg.lethe.sparse_ratio = 6.0;
    cfg.lethe.segments = 4;
    let mut cache = GroupCache::new(dims(1, 1024));
    let mut policy = make_policy(PolicyKind::Lethe, &cfg, 3);
    let row = [0f32; 16];

    for t in 0..300i32 {
        for l in 0..2 {
            cache.insert(l, 0, &row, &row, t).unwrap();
            let n = cache.len(l, 0);
            let add: Vec<f32> = if l == 0 {
                let mut a = vec![1e-4f32; n];
                a[0] = 1.0;
                a[n - 1] = 0.8;
                a
            } else {
                vec![1.0 / n as f32; n]
            };
            cache.accumulate_scores(l, 0, policy.gamma(), &add);
            let st = LayerState {
                scores: cache.scores(l, 0),
                pos: cache.pos(l, 0),
                len: n,
                step: t as usize,
                sparsity: hoyer_sparsity(&add),
                capacity: 1024,
            };
            let plan = policy.plan(l, &st);
            if let Some(keep) = plan {
                cache.apply_retention(l, 0, &keep).unwrap();
            }
        }
    }
    assert!(
        cache.len(1, 0) > cache.len(0, 0),
        "dense layer should retain more: sparse={} dense={}",
        cache.len(0, 0),
        cache.len(1, 0)
    );
}

#[test]
fn property_cache_retention_is_a_projection() {
    // Retaining, then retaining everything again, changes nothing.
    check("retention-projection", 40, |rng, size| {
        let n = 4 + size;
        let mut cache = GroupCache::new(dims(1, n + 8));
        let row = [0f32; 16];
        for t in 0..n {
            cache
                .insert(0, 0, &row, &row, t as i32)
                .map_err(|e| e.to_string())?;
        }
        let mut keep: Vec<usize> = (0..n).filter(|_| rng.bool(0.6)).collect();
        if keep.is_empty() {
            keep.push(n - 1);
        }
        let len1 =
            cache.apply_retention(0, 0, &keep).map_err(|e| e.to_string())?;
        let pos1 = cache.pos(0, 0).to_vec();
        let ident: Vec<usize> = (0..len1).collect();
        let len2 =
            cache.apply_retention(0, 0, &ident).map_err(|e| e.to_string())?;
        if len1 != len2 || cache.pos(0, 0) != &pos1[..] {
            return Err("retention not a projection".into());
        }
        Ok(())
    });
}

#[test]
fn simulator_preserves_paper_shape_end_to_end() {
    // The Table 2/3 shape: Lethe beats FullKV at batch >= 8 on memory and
    // throughput, and survives batch 32 where FullKV OOMs.
    let mut cfg = ServingConfig::default();
    cfg.baseline.budget = 768;
    cfg.lethe.evict_threshold = 512;
    cfg.lethe.sink_len = 16;
    let arch = lethe::model::arch_by_name("Llama-70B").unwrap();
    let mut sim = Simulator::new(arch);
    sim.calibrate(10_000.0, 8.3);
    let tc = TraceConfig {
        n_layers: arch.n_layers,
        prompt_len: 512,
        gen_len: 20_000,
        ..TraceConfig::default()
    };
    let lethe = run_trace(PolicyKind::Lethe, &cfg, &tc);
    let full_mean = 512.0 + 10_000.0;
    let full_final = 512.0 + 20_000.0;

    let f32_ = sim.point(32, full_mean, full_final);
    let l32 = sim.point(32, lethe.mean_retained(), lethe.final_retained());
    assert!(f32_.oom, "FullKV should OOM at batch 32 / 20k tokens");
    assert!(!l32.oom, "Lethe must survive batch 32");
    let f8 = sim.point(8, full_mean, full_final);
    let l8 = sim.point(8, lethe.mean_retained(), lethe.final_retained());
    assert!(
        l8.tok_per_s > 1.3 * f8.tok_per_s,
        "Lethe speedup at batch 8: {} vs {}",
        l8.tok_per_s,
        f8.tok_per_s
    );
    assert!(l8.gen_memory_mb < 0.3 * f8.gen_memory_mb);
}

#[test]
fn workload_tasks_are_encodable_and_judgeable() {
    let tok = lethe::model::Tokenizer::new(
        &["<pad>".into(), "<bos>".into(), "<eos>".into()],
        "abcdefghijklmnopqrstuvwxyz0123456789:;>?=. ",
    )
    .unwrap();
    let mut rng = Rng::new(11);
    for (name, pairs, hops) in lethe::workload::SUBJECTS {
        let t = make_task(&mut rng, pairs, hops);
        let ids = tok
            .encode_prompt(&t.prompt)
            .unwrap_or_else(|e| panic!("{name}: prompt not encodable: {e}"));
        assert!(ids.len() <= 192, "{name}: prompt too long ({})", ids.len());
        // Ground truth must judge itself correct.
        let (f, s) = lethe::eval::judge(&t, &t.answer);
        assert!(f && s, "{name}: self-judgement failed");
    }
}
