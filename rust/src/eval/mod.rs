//! Accuracy harness (Table 1): runs task suites through the full serving
//! path (prefill → batched decode → policy pruning) for each eviction
//! policy and scores the generations.
//!
//! Scoring mirrors the paper's task framing: a completion is correct when
//! its final value equals the task's ground truth (the model is free to
//! produce its CoT hop trace first, exactly like Math500 grading on the
//! final boxed answer). `strict` additionally requires the full CoT
//! trace to match — reported alongside as a diagnostic.

use anyhow::Result;

use crate::engine::{Engine, SeqState};
use crate::model::Tokenizer;
use crate::policy::{make_policy, PolicyKind};
use crate::util::prng::Rng;
use crate::workload::{make_task, Task, SUBJECTS};

#[derive(Clone, Debug)]
pub struct SubjectScore {
    pub subject: String,
    pub n: usize,
    pub final_acc: f64,
    pub strict_acc: f64,
    /// Hop-trace accuracy: every intermediate key of the CoT chain is
    /// correct (digits of the final value ignored). This is the
    /// retention-sensitive metric — losing the pair a later hop needs
    /// breaks the chain — and is robust to the tiny model's residual
    /// digit-copy error.
    pub chain_acc: f64,
    pub mean_generated: f64,
    pub prune_rounds: f64,
    pub peak_live_bytes: usize,
}

#[derive(Clone, Debug)]
pub struct EvalReport {
    pub policy: PolicyKind,
    pub subjects: Vec<SubjectScore>,
}

impl EvalReport {
    pub fn overall_final_acc(&self) -> f64 {
        self.overall(|s| s.final_acc)
    }

    pub fn overall_chain_acc(&self) -> f64 {
        self.overall(|s| s.chain_acc)
    }

    fn overall(&self, f: impl Fn(&SubjectScore) -> f64) -> f64 {
        let n: usize = self.subjects.iter().map(|s| s.n).sum();
        let hits: f64 =
            self.subjects.iter().map(|s| f(s) * s.n as f64).sum();
        if n == 0 {
            0.0
        } else {
            hits / n as f64
        }
    }
}

/// Extract the final 2-digit value from a generation like "cd>ef>42.".
pub fn extract_final(text: &str) -> Option<&str> {
    let trimmed = text.trim_end_matches('.');
    let tail = trimmed.rsplit('>').next()?;
    let tail = tail.trim();
    (tail.len() == 2 && tail.bytes().all(|b| b.is_ascii_digit()))
        .then_some(tail)
}

/// Judge one generation against its task.
pub fn judge(task: &Task, generated: &str) -> (bool, bool) {
    let strict = generated == task.answer;
    let final_ok = extract_final(generated)
        .map(|v| v == task.final_value)
        .unwrap_or(false);
    (final_ok, strict)
}

/// Hop-trace correctness: the '>'-separated key prefix of the generation
/// matches the expected chain, and the tail parses as a 2-digit value
/// (value itself not checked). For 1-hop (recall) tasks the chain is
/// empty, so this only checks well-formedness.
pub fn judge_chain(task: &Task, generated: &str) -> bool {
    let chain_of = |s: &str| -> Option<Vec<String>> {
        let t = s.trim_end_matches('.');
        let parts: Vec<&str> = t.split('>').collect();
        let (last, keys) = parts.split_last()?;
        (last.len() == 2 && last.bytes().all(|b| b.is_ascii_digit()))
            .then(|| keys.iter().map(|k| k.to_string()).collect())
    };
    match (chain_of(&task.answer), chain_of(generated)) {
        (Some(want), Some(got)) => want == got,
        _ => false,
    }
}

/// Evaluate one policy on one subject with `n` tasks, batching
/// `batch` sequences per group through the engine.
pub fn eval_subject(
    engine: &mut Engine,
    tok: &Tokenizer,
    policy: PolicyKind,
    subject: &str,
    n: usize,
    batch: usize,
    max_new: usize,
    seed: u64,
) -> Result<SubjectScore> {
    let &(_, pairs, hops) = SUBJECTS
        .iter()
        .find(|(s, _, _)| *s == subject)
        .ok_or_else(|| anyhow::anyhow!("unknown subject {subject}"))?;
    let mut rng = Rng::new(seed ^ 0xEE57);
    let n_layers = engine.dims().n_layers;
    let mut final_hits = 0usize;
    let mut strict_hits = 0usize;
    let mut chain_hits = 0usize;
    let mut gen_total = 0usize;
    let mut prune_total = 0usize;
    let mut peak_bytes = 0usize;

    let mut i = 0;
    while i < n {
        let b = batch.min(n - i);
        let mut group = engine.new_group(batch.max(b), policy);
        let mut tasks = Vec::with_capacity(b);
        for _ in 0..b {
            let task = make_task(&mut rng, pairs, hops);
            let prompt = tok.encode_prompt(&task.prompt)?;
            let slot = group.free_slot().unwrap();
            let seq = SeqState::new(
                (i + tasks.len()) as u64,
                make_policy(policy, &engine.cfg, n_layers),
                n_layers,
                max_new,
                tok.eos,
            );
            engine.prefill(&mut group, slot, seq, &prompt)?;
            tasks.push(task);
        }
        // Decode to completion, tracking peak live bytes.
        while group.active() > 0 {
            engine.step(&mut group)?;
            peak_bytes = peak_bytes.max(group.cache.live_bytes());
            group.reap();
        }
        // Score: done list order is reap order; match by id.
        for seq in &group.done {
            let task = &tasks[seq.id as usize - i];
            let text = tok.decode(&seq.generated);
            let (f, s) = judge(task, &text);
            final_hits += f as usize;
            strict_hits += s as usize;
            chain_hits += judge_chain(task, &text) as usize;
            gen_total += seq.generated.len();
            prune_total += seq.prune_log.len();
        }
        i += b;
    }

    Ok(SubjectScore {
        subject: subject.to_string(),
        n,
        final_acc: final_hits as f64 / n as f64,
        strict_acc: strict_hits as f64 / n as f64,
        chain_acc: chain_hits as f64 / n as f64,
        mean_generated: gen_total as f64 / n as f64,
        prune_rounds: prune_total as f64 / n as f64,
        peak_live_bytes: peak_bytes,
    })
}

/// Full Table 1 row set for one policy.
pub fn eval_policy(
    engine: &mut Engine,
    tok: &Tokenizer,
    policy: PolicyKind,
    n_per_subject: usize,
    batch: usize,
    max_new: usize,
    seed: u64,
) -> Result<EvalReport> {
    let mut subjects = Vec::new();
    for (name, _, _) in SUBJECTS {
        subjects.push(eval_subject(
            engine, tok, policy, name, n_per_subject, batch, max_new, seed,
        )?);
        crate::log_info!(
            "{}: {} final_acc={:.3}",
            policy.label(),
            name,
            subjects.last().unwrap().final_acc
        );
    }
    Ok(EvalReport { policy, subjects })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn extract_final_variants() {
        assert_eq!(extract_final("42."), Some("42"));
        assert_eq!(extract_final("cd>ef>42."), Some("42"));
        assert_eq!(extract_final("cd>ef>42"), Some("42"));
        assert_eq!(extract_final("cd>"), None);
        assert_eq!(extract_final(""), None);
        assert_eq!(extract_final("4."), None);
    }

    #[test]
    fn judge_strict_vs_final() {
        let mut rng = Rng::new(3);
        let t = make_task(&mut rng, 8, 2);
        assert_eq!(judge(&t, &t.answer), (true, true));
        // Wrong CoT but right final value: final-only credit.
        let sloppy = format!("zz>{}.", t.final_value);
        assert_eq!(judge(&t, &sloppy), (true, false));
        assert_eq!(judge(&t, "zz>00."), (false, false));
    }

    #[test]
    fn judge_chain_ignores_digits_but_not_hops() {
        let mut rng = Rng::new(4);
        let t = make_task(&mut rng, 8, 3); // answer "xx>yy>NN."
        assert!(judge_chain(&t, &t.answer));
        // Same chain, wrong digits: chain credit.
        let hops: Vec<&str> = t.answer.split('>').collect();
        let wrong_digits = format!("{}>{}>00.", hops[0], hops[1]);
        assert!(judge_chain(&t, &wrong_digits));
        // Broken chain: no credit, even with the right value.
        let wrong_hop = format!("{}>qq>{}.", hops[0], t.final_value);
        assert!(!judge_chain(&t, &wrong_hop));
        // Malformed tail: no credit.
        assert!(!judge_chain(&t, "ab>cd>"));
        // 1-hop tasks: chain empty, well-formedness only.
        let t1 = make_task(&mut rng, 8, 1);
        assert!(judge_chain(&t1, "42."));
        assert!(!judge_chain(&t1, "4."));
    }
}
