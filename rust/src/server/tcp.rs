//! TCP front-end: a newline-delimited JSON protocol over the in-process
//! [`super::Server`], so external clients can drive the engine:
//!
//!   -> {"prompt": "ab:12;cd:ab?cd>", "max_new_tokens": 32,
//!       "policy": "lethe", "class": "interactive"}
//!   <- {"ok": true, "text": "ab>12.", "finish": "Eos",
//!       "prompt_tokens": 18, "generated_tokens": 7,
//!       "ttft_s": 0.01, "tpot_s": 0.006, "total_s": 0.05,
//!       "prune_rounds": 0, "preemptions": 0, "kv_format": "f32"}
//!
//! `kv_format` reports the storage the request was served on: "f32",
//! "q8", "q4", or "mixed" when a per-layer format map
//! (`kv.layer_formats` / `kv.mixed`) was active; `preemptions` counts
//! how often the sequence was recompute-preempted under load. `tpot_s`
//! is seconds per output token after the first (0 for single-token
//! completions). The optional `class` labels the request's tenant
//! class for the per-class SLO tracks in `{"stats": true}` (omitted =
//! "default").
//!
//! A `{"stats": true}` line returns the serving-pressure snapshot
//! instead of a completion. Aggregate counters keep the original
//! single-scheduler shape; `groups` adds one health row per supervised
//! decode group and `model` the sharded model manifest:
//!
//!   -> {"stats": true}
//!   <- {"ok": true, "stats": {"queue_depth": 0, "active": 1,
//!       "prefilling": 0, "rejected": 0, "preemptions": 2,
//!       "resumes": 2, "kv_migrations": 4, "kv_format": "mixed",
//!       "draining": false,
//!       "groups": [{"id": 0, "health": "healthy", "live_bytes": 4096,
//!                   "queue_depth": 0, "seq_failures": 0, "rescues": 0,
//!                   "restarts": 0, ...}],
//!       "model": {"model_id": "lethe-4l-d64", "total_layers": 4,
//!                 "shards": [{"id": "embed", ...}]},
//!       "metrics": {...}}}
//!
//! One handler thread per connection (threadpool-bounded); requests on
//! one connection are pipelined through the engine like any other
//! client's. Malformed lines get {"ok": false, "error": ...} without
//! dropping the connection. Typed engine rejections additionally carry
//! `"retryable"` and (for overload) `"retry_after_ms"` so clients can
//! back off instead of guessing from the message text.
//!
//! Hardening: request lines are capped at [`MAX_LINE_BYTES`] (oversized
//! lines get a typed error and the rest of the line is discarded), and
//! idle connections are closed after [`IDLE_TIMEOUT_SECS`] without a
//! complete request line.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::error::EngineError;
use crate::fault::{FaultPlan, FaultSite};
use crate::policy::PolicyKind;
use crate::util::json::{parse, Json};
use crate::util::threadpool::ThreadPool;

use super::{GenerateRequest, GenerateResponse, Server};

/// Upper bound on one newline-delimited request line. Past it the line
/// is discarded and the client gets a typed, non-retryable error.
pub const MAX_LINE_BYTES: usize = 64 * 1024;

/// Connections with no complete request line for this long are closed.
pub const IDLE_TIMEOUT_SECS: u64 = 120;

pub struct TcpFrontend {
    pub addr: std::net::SocketAddr,
    listener: TcpListener,
    server: Arc<Server>,
    pool: ThreadPool,
    /// Seeded connection-drop plan (`faults.conn_drop_rate`); `None`
    /// when fault injection is off. Behind a mutex because `serve`
    /// takes `&self` while drawing mutates the plan's RNG.
    conn_faults: Mutex<Option<FaultPlan>>,
}

impl TcpFrontend {
    /// Bind to `addr` (use "127.0.0.1:0" for an ephemeral test port).
    pub fn bind(server: Arc<Server>, addr: &str, workers: usize)
        -> Result<TcpFrontend>
    {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding {addr}"))?;
        let conn_faults = Mutex::new(FaultPlan::from_config(&server.faults));
        Ok(TcpFrontend {
            addr: listener.local_addr()?,
            listener,
            server,
            pool: ThreadPool::new(workers.max(1)),
            conn_faults,
        })
    }

    /// Accept loop; returns after serving `max_conns` connections
    /// (None = forever). Each connection is handled on the pool.
    pub fn serve(&self, max_conns: Option<usize>) -> Result<()> {
        let mut served = 0usize;
        for stream in self.listener.incoming() {
            let stream = stream?;
            // Decide the injected drop on the accept path so the draw
            // order (and thus the whole plan) stays deterministic.
            let drop_after_first = self
                .conn_faults
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .as_mut()
                .is_some_and(|fp| fp.trip(FaultSite::ConnDrop));
            let server = Arc::clone(&self.server);
            self.pool.spawn(move || {
                if let Err(e) = handle_conn(stream, &server, drop_after_first) {
                    crate::log_debug!("connection ended: {e:#}");
                }
            });
            served += 1;
            if let Some(m) = max_conns {
                if served >= m {
                    break;
                }
            }
        }
        self.pool.wait_idle();
        Ok(())
    }
}

/// One complete read attempt from the connection.
enum LineRead {
    /// Peer closed the connection.
    Eof,
    /// A complete line (without the trailing newline).
    Line(String),
    /// Line exceeded [`MAX_LINE_BYTES`]; the remainder was discarded.
    Oversized,
}

fn read_line_capped<R: BufRead>(reader: &mut R) -> std::io::Result<LineRead> {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let chunk = reader.fill_buf()?;
        if chunk.is_empty() {
            return Ok(if buf.is_empty() {
                LineRead::Eof
            } else {
                LineRead::Line(String::from_utf8_lossy(&buf).into_owned())
            });
        }
        if let Some(pos) = chunk.iter().position(|&b| b == b'\n') {
            buf.extend_from_slice(&chunk[..pos]);
            reader.consume(pos + 1);
            return Ok(if buf.len() > MAX_LINE_BYTES {
                LineRead::Oversized
            } else {
                LineRead::Line(String::from_utf8_lossy(&buf).into_owned())
            });
        }
        let n = chunk.len();
        buf.extend_from_slice(chunk);
        reader.consume(n);
        if buf.len() > MAX_LINE_BYTES {
            drain_to_newline(reader)?;
            return Ok(LineRead::Oversized);
        }
    }
}

/// Discard input up to and including the next newline (or EOF), so an
/// oversized line doesn't poison the rest of the connection.
fn drain_to_newline<R: BufRead>(reader: &mut R) -> std::io::Result<()> {
    loop {
        let chunk = reader.fill_buf()?;
        if chunk.is_empty() {
            return Ok(());
        }
        if let Some(pos) = chunk.iter().position(|&b| b == b'\n') {
            reader.consume(pos + 1);
            return Ok(());
        }
        let n = chunk.len();
        reader.consume(n);
    }
}

fn handle_conn(
    stream: TcpStream,
    server: &Server,
    drop_after_first: bool,
) -> Result<()> {
    let peer = stream.peer_addr()?;
    crate::log_debug!("connection from {peer}");
    stream
        .set_read_timeout(Some(Duration::from_secs(IDLE_TIMEOUT_SECS)))
        .context("setting read timeout")?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    loop {
        let line = match read_line_capped(&mut reader) {
            Ok(LineRead::Eof) => break,
            Ok(LineRead::Line(l)) => l,
            Ok(LineRead::Oversized) => {
                let reply = Json::obj(vec![
                    ("ok", Json::from(false)),
                    (
                        "error",
                        Json::str(&format!(
                            "request line exceeds {MAX_LINE_BYTES} bytes"
                        )),
                    ),
                    ("retryable", Json::from(false)),
                ]);
                writeln!(writer, "{reply}")?;
                continue;
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                ) =>
            {
                crate::log_debug!("closing idle connection from {peer}");
                break;
            }
            Err(e) => return Err(e.into()),
        };
        if line.trim().is_empty() {
            continue;
        }
        let reply = match handle_line(&line, server) {
            Ok(resp) => resp,
            Err(e) => error_json(&e),
        };
        if drop_after_first {
            // Injected fault: the request was fully processed but the
            // client never hears back — exercises client-side timeout
            // handling and server-side cleanup of orphaned replies.
            crate::log_debug!("fault: dropping connection to {peer}");
            return Ok(());
        }
        writeln!(writer, "{reply}")?;
    }
    Ok(())
}

/// Error reply. When the cause is a typed [`EngineError`], annotate it
/// with `retryable` (and `retry_after_ms` for overload) so clients can
/// distinguish back-off-and-retry from give-up.
fn error_json(e: &anyhow::Error) -> Json {
    let mut fields = vec![
        ("ok", Json::from(false)),
        ("error", Json::str(&format!("{e:#}"))),
    ];
    if let Some(ee) = e.downcast_ref::<EngineError>() {
        fields.push(("retryable", Json::from(ee.is_retryable())));
        if let Some(ms) = ee.retry_after_ms() {
            fields.push(("retry_after_ms", Json::from(ms as usize)));
        }
    }
    Json::obj(fields)
}

fn handle_line(line: &str, server: &Server) -> Result<Json> {
    let j = parse(line).context("request is not valid JSON")?;
    // Telemetry query: {"stats": true} (today `Scheduler::rejected` and
    // friends are live counters, not write-only state).
    if let Some(v) = j.opt("stats") {
        if v.as_bool()? {
            return Ok(Json::obj(vec![
                ("ok", Json::from(true)),
                ("stats", server.stats()?),
            ]));
        }
    }
    let prompt = j.get("prompt")?.as_str()?.to_string();
    let max_new_tokens = j
        .opt("max_new_tokens")
        .map(|v| v.as_usize())
        .transpose()?
        .unwrap_or(64);
    let policy = j
        .opt("policy")
        .map(|v| PolicyKind::parse(v.as_str()?))
        .transpose()?;
    let deadline_ms = j
        .opt("deadline_ms")
        .map(|v| v.as_usize())
        .transpose()?
        .map(|v| v as u64);
    let class = j
        .opt("class")
        .map(|v| v.as_str().map(|s| s.to_string()))
        .transpose()?;
    let resp = server.generate(GenerateRequest {
        prompt,
        max_new_tokens,
        policy,
        deadline_ms,
        class,
    })?;
    Ok(response_json(&resp))
}

fn response_json(r: &GenerateResponse) -> Json {
    Json::obj(vec![
        ("ok", Json::from(true)),
        ("id", Json::from(r.id as usize)),
        ("text", Json::str(&r.text)),
        ("finish", Json::str(&r.finish)),
        ("prompt_tokens", Json::from(r.prompt_tokens)),
        ("generated_tokens", Json::from(r.generated_tokens)),
        ("ttft_s", Json::num(r.ttft_s)),
        ("tpot_s", Json::num(r.tpot_s)),
        ("total_s", Json::num(r.total_s)),
        ("prune_rounds", Json::from(r.prune_rounds)),
        ("preemptions", Json::from(r.preemptions as usize)),
        ("kv_format", Json::str(&r.kv_format)),
    ])
}

/// Minimal blocking client for tests/examples.
pub struct TcpClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl TcpClient {
    pub fn connect(addr: std::net::SocketAddr) -> Result<TcpClient> {
        let stream = TcpStream::connect(addr)?;
        Ok(TcpClient { writer: stream.try_clone()?, reader: BufReader::new(stream) })
    }

    pub fn request(&mut self, prompt: &str, max_new: usize,
                   policy: Option<&str>) -> Result<Json> {
        let mut obj = vec![
            ("prompt", Json::str(prompt)),
            ("max_new_tokens", Json::from(max_new)),
        ];
        if let Some(p) = policy {
            obj.push(("policy", Json::str(p)));
        }
        writeln!(self.writer, "{}", Json::obj(obj))?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        parse(&line)
    }

    /// Serving-pressure snapshot (`{"stats": true}` query).
    pub fn stats(&mut self) -> Result<Json> {
        writeln!(
            self.writer,
            "{}",
            Json::obj(vec![("stats", Json::from(true))])
        )?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        parse(&line)
    }
}
