//! Lethe: layer- and time-adaptive KV pruning (the paper's Algorithm 1 +
//! RASR + layerwise sparsity-aware budgets).
//!
//! Per decode step and layer, once the live length exceeds the layer's
//! adaptive eviction threshold `L_evict[l]` (scaled by the runtime
//! sparsity estimate — dense layers get more headroom), the RASR score
//! vector is sorted and cut into `D` segments; the first segment boundary
//! where attention has dropped by more than `sparse_ratio` (τ) is the
//! breakpoint — everything scoring below it, except attention sinks and
//! the recent window, is evicted.
//!
//! Inequality note: the paper's Eq. 4 / Algorithm 1 line 7 reads
//! `v_head / v_cut <= τ  =>  breakpoint`, but since the sorted values make
//! the ratio monotone *increasing* in the cut index, a literal reading
//! would make the first cut either always or never fire and would invert
//! the paper's own ablation (Table 6: *small* τ over-prunes, *large* τ
//! retains more and uses more memory). We therefore implement the
//! evidently intended test: the breakpoint is the first cut whose drop
//! *exceeds* τ (`v_head / v_cut >= τ`); when no cut exceeds τ the
//! distribution is still flat, no pruning happens, and the threshold
//! doubles — the "conservative delay" the paper describes.

use crate::config::LetheParams;

use super::{Capabilities, EvictionPolicy, LayerState};

pub struct LethePolicy {
    params: LetheParams,
    /// Per-layer adaptive eviction threshold (tokens).
    l_evict: Vec<usize>,
    /// Pruning rounds executed per layer (multi-round counter, exposed
    /// for tests/diagnostics).
    pub rounds: Vec<usize>,
}

impl LethePolicy {
    pub fn new(params: LetheParams, n_layers: usize) -> Self {
        let init = params.evict_threshold.max(1);
        LethePolicy {
            params,
            l_evict: vec![init; n_layers],
            rounds: vec![0; n_layers],
        }
    }

    pub fn threshold(&self, layer: usize) -> usize {
        self.l_evict[layer]
    }

    /// Effective threshold after the layerwise sparsity scaling: a dense
    /// layer (sparsity→0) gets up to 2x headroom, a maximally sparse
    /// layer exactly the base threshold (spatial budget allocation).
    fn effective_threshold(&self, layer: usize, sparsity: f64) -> usize {
        let scale = (2.0 - sparsity).clamp(1.0, 2.0);
        (self.l_evict[layer] as f64 * scale).ceil() as usize
    }

    /// Algorithm 1 over one layer's state; returns retained indices.
    /// `eff_threshold` is the sparsity-scaled trigger the caller used —
    /// the recent window is `recent_ratio` OF THAT BUDGET (not of the
    /// live length: a live-length-relative window makes `L_evict`'s
    /// ratchet unbounded, which contradicts the paper's reported memory
    /// plateau, e.g. 70B flat at ~800 MB past 6k tokens in Fig. 4).
    fn segmented_shrink(
        &mut self,
        layer: usize,
        st: &LayerState<'_>,
        eff_threshold: usize,
    ) -> Option<Vec<usize>> {
        let n = st.len;
        let d = self.params.segments;
        // Sort slot indices by score, descending (top_indices / top_values).
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| {
            st.scores[b]
                .partial_cmp(&st.scores[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let v_head = st.scores[order[0]].max(f32::MIN_POSITIVE);
        // Cut points at segment boundaries: floor(n*j/D), j = 1..D-1.
        let mut breakpoint: Option<usize> = None;
        for j in 1..d {
            let c = n * j / d;
            if c == 0 || c >= n {
                continue;
            }
            let v_cut = st.scores[order[c]];
            // Drop sharper than τ ⇒ everything past c is noise.
            if v_cut <= 0.0 || v_head / v_cut.max(f32::MIN_POSITIVE)
                >= self.params.sparse_ratio as f32
            {
                breakpoint = Some(c);
                break;
            }
        }

        let r = ((self.params.recent_ratio * eff_threshold as f64).ceil()
            as usize)
            .max(1)
            .min(n);
        match breakpoint {
            Some(c) => {
                // salient top-c ∪ sinks ∪ recent window.
                let mut keep: Vec<usize> = order[..c].to_vec();
                keep.extend(0..self.params.sink_len.min(n));
                keep.extend(n.saturating_sub(r)..n);
                // L_evict ← max(L_evict, breakpoint + r): don't re-trigger
                // until the cache has regrown past what we just kept.
                self.l_evict[layer] = self.l_evict[layer].max(c + r);
                self.rounds[layer] += 1;
                Some(keep)
            }
            None => {
                // Flat distribution — conservatively delay pruning.
                self.l_evict[layer] =
                    (self.l_evict[layer] * 2).min(st.capacity);
                None
            }
        }
    }
}

impl EvictionPolicy for LethePolicy {
    fn name(&self) -> &'static str {
        "Lethe(ours)"
    }

    fn gamma(&self) -> f32 {
        self.params.gamma as f32
    }

    fn plan(&mut self, layer: usize, st: &LayerState<'_>) -> Option<Vec<usize>> {
        if st.len == 0 {
            return None;
        }
        let eff = self.effective_threshold(layer, st.sparsity);
        // Memory-pressure backstop (paper §System Overview: "Lethe
        // monitors cache size and triggers pruning once a configurable
        // threshold is exceeded"): the conservative no-breakpoint delay
        // must not double L_evict past physical capacity. Within 1/8 of
        // capacity, force a shrink to the effective budget: top scorers
        // + sinks + recent window.
        let pressure = st.capacity - st.capacity / 8;
        if st.len >= pressure.max(1) {
            // Budget from the BASE threshold (not the ratcheted L_evict,
            // which the no-breakpoint doubling may have pushed to
            // capacity — the situation this backstop exists for).
            let scale = (2.0 - st.sparsity).clamp(1.0, 2.0);
            let base =
                (self.params.evict_threshold as f64 * scale).ceil() as usize;
            let n = st.len;
            let r = ((self.params.recent_ratio * base as f64).ceil()
                as usize)
                .max(1)
                .min(n);
            let salient = base.min(n);
            let mut keep = super::top_k_indices(st.scores, salient);
            keep.extend(0..self.params.sink_len.min(n));
            keep.extend(n - r..n);
            self.l_evict[layer] = base.max(1);
            self.rounds[layer] += 1;
            return Some(keep);
        }
        if st.len <= eff {
            return None;
        }
        self.segmented_shrink(layer, st, eff)
    }

    /// A `plan` call is a pure no-op only on the `len <= eff` early
    /// return: the memory-pressure backstop always prunes, and the
    /// segmented path mutates `l_evict` even when it returns `None`
    /// (the no-breakpoint doubling). `eff >= l_evict[layer]` (scale
    /// clamps at >= 1.0), so `len <= l_evict[layer]` guarantees the
    /// early return for any sparsity.
    fn may_prune(&self, layer: usize, len: usize, capacity: usize) -> bool {
        len >= (capacity - capacity / 8).max(1) || len > self.l_evict[layer]
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            recency_aware: true,
            attention_aware: true,
            layerwise_budget: true,
            adaptive_budget: true,
            multi_step_pruning: true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::proptest::check;

    fn params() -> LetheParams {
        LetheParams {
            sparse_ratio: 10.0,
            recent_ratio: 0.25,
            gamma: 0.9,
            segments: 4,
            sink_len: 2,
            evict_threshold: 16,
            ..LetheParams::default()
        }
    }

    fn state<'a>(scores: &'a [f32], pos: &'a [i32]) -> LayerState<'a> {
        LayerState {
            scores,
            pos,
            len: scores.len(),
            step: 100,
            sparsity: 1.0, // scale 1.0 => effective threshold == base
            capacity: 512,
        }
    }

    fn peaked_scores(n: usize) -> (Vec<f32>, Vec<i32>) {
        // A few heavy hitters, everything else tiny => sharp drop.
        let mut s = vec![1e-4f32; n];
        for i in 0..4 {
            s[i * 7 % n] = 1.0;
        }
        (s, (0..n as i32).collect())
    }

    #[test]
    fn below_threshold_never_prunes() {
        let mut p = LethePolicy::new(params(), 2);
        let (s, pos) = peaked_scores(16);
        assert!(p.plan(0, &state(&s, &pos)).is_none());
    }

    #[test]
    fn sharp_drop_triggers_breakpoint_and_keeps_structure() {
        let mut p = LethePolicy::new(params(), 2);
        let (s, pos) = peaked_scores(64);
        let keep = p.plan(0, &state(&s, &pos)).expect("should prune");
        let n = s.len();
        // Sinks and recent window retained (window = recent_ratio of the
        // effective threshold, which is 16 at sparsity 1.0 => r = 4).
        for sink in 0..2 {
            assert!(keep.contains(&sink), "sink {sink} evicted");
        }
        let r = (0.25f64 * 16.0).ceil() as usize;
        for recent in n - r..n {
            assert!(keep.contains(&recent), "recent {recent} evicted");
        }
        // Top scorer retained.
        let top = s
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert!(keep.contains(&top));
        // Actually pruned something.
        let mut uniq = keep.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert!(uniq.len() < n);
        assert_eq!(p.rounds[0], 1);
    }

    #[test]
    fn flat_distribution_delays_and_doubles_threshold() {
        let mut p = LethePolicy::new(params(), 2);
        let s = vec![0.5f32; 64];
        let pos: Vec<i32> = (0..64).collect();
        let before = p.threshold(0);
        assert!(p.plan(0, &state(&s, &pos)).is_none());
        assert_eq!(p.threshold(0), before * 2);
        // Threshold saturates at capacity.
        for _ in 0..20 {
            let _ = p.plan(0, &state(&s, &pos));
        }
        assert!(p.threshold(0) <= 512);
    }

    #[test]
    fn dense_layers_get_more_headroom() {
        let mut p = LethePolicy::new(params(), 2);
        let (s, pos) = peaked_scores(20);
        // len 20 > base threshold 16, but a dense layer (sparsity 0)
        // scales the threshold to 32 => no pruning.
        let mut st = state(&s, &pos);
        st.sparsity = 0.0;
        assert!(p.plan(0, &st).is_none());
        // Same length on a sparse layer prunes.
        let mut st2 = state(&s, &pos);
        st2.sparsity = 1.0;
        assert!(p.plan(1, &st2).is_some());
    }

    #[test]
    fn larger_tau_is_more_conservative() {
        // Table 6 semantics: raising sparse_ratio retains more tokens.
        let (s, pos) = {
            // Smoothly decaying scores.
            let n = 64;
            let s: Vec<f32> =
                (0..n).map(|i| 1.0 / (1.0 + i as f32)).collect();
            (s, (0..n as i32).collect::<Vec<i32>>())
        };
        let mut retained = Vec::new();
        for tau in [2.0, 8.0, 1000.0] {
            let mut prm = params();
            prm.sparse_ratio = tau;
            let mut p = LethePolicy::new(prm, 1);
            let plan = p.plan(0, &state(&s, &pos));
            let kept = plan
                .map(|mut k| {
                    k.sort_unstable();
                    k.dedup();
                    k.len()
                })
                .unwrap_or(s.len());
            retained.push(kept);
        }
        assert!(retained[0] <= retained[1] && retained[1] <= retained[2],
                "retention not monotone in tau: {retained:?}");
        // τ=1000 on this gentle decay: no breakpoint, keeps all.
        assert_eq!(retained[2], s.len());
    }

    #[test]
    fn memory_pressure_backstop_fires_even_on_flat_scores() {
        // Flat scores never produce a breakpoint, but near capacity the
        // backstop must shrink anyway (and reset the ratcheted
        // threshold), bounding memory as the paper's Fig. 4 plateau
        // requires.
        let mut p = LethePolicy::new(params(), 1);
        let n = 120;
        let s = vec![0.5f32; n];
        let pos: Vec<i32> = (0..n as i32).collect();
        let mut st = state(&s, &pos);
        st.capacity = 128; // pressure line at 112
        let keep = p.plan(0, &st).expect("backstop must fire");
        let mut k = keep;
        k.sort_unstable();
        k.dedup();
        assert!(k.len() < n, "backstop kept everything");
        assert!(k.len() <= 16 + 2 + 4 + 1, "kept {} > budget-ish", k.len());
        assert!(p.threshold(0) <= 32, "threshold not reset");
        // Far from capacity the same flat scores only delay.
        let mut p2 = LethePolicy::new(params(), 1);
        let mut st2 = state(&s, &pos);
        st2.capacity = 4096;
        assert!(p2.plan(0, &st2).is_none());
    }

    #[test]
    fn property_plan_indices_always_valid() {
        check("lethe-plan-valid", 60, |rng: &mut Rng, size| {
            let n = 8 + size * 4;
            let scores: Vec<f32> =
                (0..n).map(|_| rng.f32() * rng.f32()).collect();
            let pos: Vec<i32> = (0..n as i32).collect();
            let mut prm = params();
            prm.evict_threshold = 4;
            prm.sparse_ratio = 1.5 + rng.f64() * 20.0;
            prm.recent_ratio = 0.05 + rng.f64() * 0.4;
            let mut p = LethePolicy::new(prm.clone(), 1);
            let st = LayerState {
                scores: &scores,
                pos: &pos,
                len: n,
                step: 1,
                sparsity: rng.f64(),
                capacity: 4 * n,
            };
            if let Some(keep) = p.plan(0, &st) {
                if keep.iter().any(|&i| i >= n) {
                    return Err(format!("index out of range (n={n})"));
                }
                let mut k = keep.clone();
                k.sort_unstable();
                k.dedup();
                if k.is_empty() {
                    return Err("empty retention".into());
                }
                // The current (most recent) token always survives.
                if !keep.contains(&(n - 1)) {
                    return Err("current token evicted".into());
                }
            }
            Ok(())
        });
    }
}
