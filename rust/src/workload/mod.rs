//! Workload generation: the rust twin of `python/compile/tasks.py`
//! (same grammar, same subjects) plus serving-trace generation (Poisson
//! arrivals, length distributions) for the throughput/latency benches.
//!
//! The task generators here MUST stay semantically aligned with the
//! python training distribution — the integration test in
//! `rust/tests/engine_e2e.rs` runs rust-generated tasks through the
//! python-trained model to assert that alignment.

use crate::util::prng::Rng;

pub mod slo;
pub mod trace;

pub const KEY_LETTERS: &[u8] = b"abcdefghijklmnopqrstuvwxyz";

/// One reasoning task (see tasks.py for the grammar).
#[derive(Clone, Debug)]
pub struct Task {
    pub prompt: String,
    /// Full expected generation, e.g. "cd>ef>42." for a 3-hop chain.
    pub answer: String,
    /// The final 2-digit value.
    pub final_value: String,
    pub hops: usize,
    pub n_pairs: usize,
}

/// Table 1 "subjects": (name, n_pairs, hops). recall-N are the MMLU
/// proxies, hopK-N the Math500-style CoT proxies.
pub const SUBJECTS: [(&str, usize, usize); 8] = [
    ("recall-8", 8, 1),
    ("recall-16", 16, 1),
    ("recall-24", 24, 1),
    ("hop2-8", 8, 2),
    ("hop2-16", 16, 2),
    ("hop3-8", 8, 3),
    ("hop3-16", 16, 3),
    ("hop4-16", 16, 4),
];

/// Generate one task, mirroring tasks.make_task.
pub fn make_task(rng: &mut Rng, n_pairs: usize, hops: usize) -> Task {
    assert!(hops >= 1 && hops <= n_pairs);
    // Fresh distinct 2-letter keys.
    let mut keys: Vec<String> = Vec::with_capacity(n_pairs);
    while keys.len() < n_pairs {
        let k = format!(
            "{}{}",
            *rng.choose(KEY_LETTERS) as char,
            *rng.choose(KEY_LETTERS) as char
        );
        if !keys.contains(&k) {
            keys.push(k);
        }
    }
    let final_value = format!("{}", rng.range(10, 99));
    // Chain keys[0] -> keys[1] -> ... -> keys[hops-1] -> final value.
    let mut mapping: Vec<(String, String)> = Vec::with_capacity(n_pairs);
    for i in 0..hops - 1 {
        mapping.push((keys[i].clone(), keys[i + 1].clone()));
    }
    mapping.push((keys[hops - 1].clone(), final_value.clone()));
    for k in &keys[hops..] {
        mapping.push((k.clone(), format!("{}", rng.range(10, 99))));
    }
    // Shuffle presentation order.
    let mut order: Vec<usize> = (0..mapping.len()).collect();
    rng.shuffle(&mut order);
    let pairs: Vec<String> = order
        .iter()
        .map(|&i| format!("{}:{}", mapping[i].0, mapping[i].1))
        .collect();
    let prompt = format!("{}?{}>", pairs.join(";"), keys[0]);
    let mut answer = String::new();
    for k in keys.iter().take(hops).skip(1) {
        answer.push_str(k);
        answer.push('>');
    }
    answer.push_str(&final_value);
    answer.push('.');
    Task { prompt, answer, final_value, hops, n_pairs }
}

/// A timed serving trace entry.
#[derive(Clone, Debug)]
pub struct TraceItem {
    pub arrival_s: f64,
    pub task: Task,
}

/// Poisson-arrival CoT serving trace at `rate` requests/second, with the
/// task mix drawn uniformly from SUBJECTS — the workload behind the
/// batch-scaling tables.
pub fn poisson_trace(rng: &mut Rng, rate: f64, n: usize) -> Vec<TraceItem> {
    let mut t = 0.0;
    (0..n)
        .map(|_| {
            t += rng.exponential(rate);
            let &(_, pairs, hops) = rng.choose(&SUBJECTS);
            TraceItem { arrival_s: t, task: make_task(rng, pairs, hops) }
        })
        .collect()
}

/// Closed-loop batch workload: `n` tasks of one subject.
pub fn subject_batch(rng: &mut Rng, subject: &str, n: usize) -> Vec<Task> {
    let &(_, pairs, hops) = SUBJECTS
        .iter()
        .find(|(s, _, _)| *s == subject)
        .unwrap_or_else(|| panic!("unknown subject '{subject}'"));
    (0..n).map(|_| make_task(rng, pairs, hops)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn task_grammar_roundtrip() {
        let mut rng = Rng::new(42);
        let t = make_task(&mut rng, 8, 3);
        // prompt: 8 pairs ';'-joined, then ?key>
        assert_eq!(t.prompt.matches(';').count(), 7);
        assert!(t.prompt.contains('?') && t.prompt.ends_with('>'));
        // answer: 2 intermediate hops + value + '.'
        assert_eq!(t.answer.matches('>').count(), 2);
        assert!(t.answer.ends_with('.'));
        assert!(t.answer.contains(&t.final_value));
    }

    #[test]
    fn chain_is_resolvable() {
        // Follow the chain through the prompt text and confirm it reaches
        // final_value in exactly `hops` lookups.
        check("workload-chain", 40, |rng, size| {
            let n_pairs = 4 + size % 20;
            let hops = 1 + size % 4.min(n_pairs);
            let t = make_task(rng, n_pairs, hops);
            let body = &t.prompt[..t.prompt.find('?').unwrap()];
            let map: std::collections::HashMap<&str, &str> = body
                .split(';')
                .map(|p| {
                    let (k, v) = p.split_once(':').unwrap();
                    (k, v)
                })
                .collect();
            if map.len() != n_pairs {
                return Err(format!("{} pairs, want {n_pairs}", map.len()));
            }
            let q = &t.prompt[t.prompt.find('?').unwrap() + 1
                ..t.prompt.len() - 1];
            let mut cur = q;
            for _ in 0..hops {
                cur = map
                    .get(cur)
                    .ok_or_else(|| format!("broken chain at {cur}"))?;
            }
            if cur != t.final_value {
                return Err(format!("chain ends at {cur}, want {}",
                                   t.final_value));
            }
            Ok(())
        });
    }

    #[test]
    fn poisson_trace_is_ordered_and_rate_plausible() {
        let mut rng = Rng::new(7);
        let tr = poisson_trace(&mut rng, 10.0, 500);
        assert!(tr.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
        let span = tr.last().unwrap().arrival_s;
        // 500 arrivals at 10/s ≈ 50s ± noise.
        assert!((span - 50.0).abs() < 12.0, "span {span}");
    }

    #[test]
    fn subjects_cover_recall_and_multihop() {
        let mut rng = Rng::new(1);
        for (name, pairs, hops) in SUBJECTS {
            let t = make_task(&mut rng, pairs, hops);
            assert_eq!(t.n_pairs, pairs, "{name}");
            assert_eq!(t.hops, hops, "{name}");
        }
    }
}
