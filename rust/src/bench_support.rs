//! Shared plumbing for the bench harness (criterion substitute): engine
//! bring-up, result-file output, and the closed-loop generation driver
//! used by the table benches. Each bench binary prints the paper-style
//! rows AND writes a CSV under `bench_results/`.

use std::io::Write;
use std::path::Path;

use anyhow::Result;

use crate::config::{KvConfig, MixedKvRule, ServingConfig};
use crate::engine::{Engine, FinishReason, SeqState};
use crate::kvcache::KvFormat;
use crate::model::Tokenizer;
use crate::policy::{make_policy, PolicyKind};
use crate::runtime::Runtime;
use crate::scheduler::{Completion, Request, Scheduler};
use crate::util::json::Json;
use crate::util::prng::Rng;
use crate::workload::Task;

pub const RESULTS_DIR: &str = "bench_results";

/// Engine + tokenizer, or None when artifacts are not built (benches
/// print a skip notice instead of failing).
pub fn try_engine(cfg: ServingConfig) -> Option<(Engine, Tokenizer)> {
    let dir = Path::new(&cfg.artifacts_dir);
    if !dir.join("model_meta.json").exists() {
        eprintln!(
            "[skip] artifacts not found in {dir:?} — run `make artifacts`"
        );
        return None;
    }
    let rt = match Runtime::load(dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("[skip] runtime failed to load: {e:#}");
            return None;
        }
    };
    let tok = Tokenizer::from_meta(&rt.meta).ok()?;
    let engine = Engine::new(rt, cfg).ok()?;
    Some((engine, tok))
}

/// The four KV storage configurations the storage-sensitive benches run
/// (Tables 2(b)/3(b)): uniform f32 / q8 / q4 plus the sparsity-directed
/// mixed rule (q4 on high-sparsity layers over an f32 default, at the
/// default threshold), labelled for table rows and CSV columns.
pub fn kv_configs() -> Vec<(&'static str, KvConfig)> {
    vec![
        ("f32", KvConfig { format: KvFormat::F32, ..KvConfig::default() }),
        ("q8", KvConfig { format: KvFormat::QuantI8, ..KvConfig::default() }),
        ("q4", KvConfig { format: KvFormat::QuantI4, ..KvConfig::default() }),
        (
            "mixed",
            KvConfig {
                mixed: Some(MixedKvRule::default()),
                ..KvConfig::default()
            },
        ),
    ]
}

pub fn write_csv(name: &str, header: &str, rows: &[String]) -> Result<()> {
    std::fs::create_dir_all(RESULTS_DIR)?;
    let path = format!("{RESULTS_DIR}/{name}");
    let mut f = std::fs::File::create(&path)?;
    writeln!(f, "{header}")?;
    for r in rows {
        writeln!(f, "{r}")?;
    }
    eprintln!("[csv] wrote {path}");
    Ok(())
}

/// Closed-loop batch generation of a fixed task set under one policy.
/// Returns (wall seconds, generated tokens, peak live KV bytes,
/// final-answer accuracy, OOM count).
pub struct RunStats {
    pub wall_s: f64,
    pub gen_tokens: usize,
    /// Peak live KV bytes as actually stored by the backend.
    pub peak_live_bytes: usize,
    /// The same peak priced at f32 (Table 2's "f32-equivalent" column;
    /// equals `peak_live_bytes` on the dense backend).
    pub peak_f32_equiv_bytes: usize,
    pub final_acc: f64,
    /// Hop-trace accuracy (see [`crate::eval::judge_chain`]).
    pub chain_acc: f64,
    pub ooms: u64,
    pub prune_events: u64,
    /// Host bytes the delta-packer actually copied over the run.
    pub pack_bytes_copied: u64,
    /// (layer, slot) pairs served by the delta path (append/skip).
    pub delta_pack_hits: u64,
}

pub fn run_tasks(
    engine: &mut Engine,
    tok: &Tokenizer,
    policy: PolicyKind,
    tasks: &[Task],
    batch: usize,
    max_new: usize,
) -> Result<RunStats> {
    let n_layers = engine.dims().n_layers;
    let ooms0 = engine.metrics.ooms;
    let prunes0 = engine.metrics.prune_events;
    let pack0 = engine.metrics.pack_bytes_copied;
    let hits0 = engine.metrics.delta_pack_hits;
    let t0 = std::time::Instant::now();
    let mut peak = 0usize;
    let mut peak_f32 = 0usize;
    let mut gen_tokens = 0usize;
    let mut hits = 0usize;
    let mut chain_hits = 0usize;

    let mut i = 0;
    while i < tasks.len() {
        let b = batch.min(tasks.len() - i);
        let mut group = engine.new_group(batch.max(b), policy);
        for (j, task) in tasks[i..i + b].iter().enumerate() {
            let prompt = tok.encode_prompt(&task.prompt)?;
            let seq = SeqState::new(
                (i + j) as u64,
                make_policy(policy, &engine.cfg, n_layers),
                n_layers,
                max_new,
                tok.eos,
            );
            let slot = group.free_slot().unwrap();
            engine.prefill(&mut group, slot, seq, &prompt)?;
        }
        while group.active() > 0 {
            engine.step(&mut group)?;
            peak = peak.max(group.cache.live_bytes());
            peak_f32 = peak_f32.max(group.cache.f32_equivalent_bytes());
            group.reap();
        }
        for seq in &group.done {
            let task = &tasks[seq.id as usize];
            let text = tok.decode(&seq.generated);
            let (ok, _) = crate::eval::judge(task, &text);
            hits += ok as usize;
            chain_hits += crate::eval::judge_chain(task, &text) as usize;
            gen_tokens += seq.generated.len();
        }
        i += b;
    }
    Ok(RunStats {
        wall_s: t0.elapsed().as_secs_f64(),
        gen_tokens,
        peak_live_bytes: peak,
        peak_f32_equiv_bytes: peak_f32,
        final_acc: hits as f64 / tasks.len() as f64,
        chain_acc: chain_hits as f64 / tasks.len() as f64,
        ooms: engine.metrics.ooms - ooms0,
        prune_events: engine.metrics.prune_events - prunes0,
        pack_bytes_copied: engine.metrics.pack_bytes_copied - pack0,
        delta_pack_hits: engine.metrics.delta_pack_hits - hits0,
    })
}

/// Per-decode-group lane accounting for the churn/soak drivers. The
/// single-scheduler [`run_churn`] fills exactly one lane; multi-group
/// runs read the supervisor's per-group stats rows through
/// [`sum_group_rows`]. Either way the soak asserts the same invariant:
/// per-group counts sum to the run's aggregate.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GroupLaneStats {
    /// Decode-group id the lane belongs to.
    pub group: usize,
    /// Completions the lane delivered.
    pub completions: u64,
    /// Preemptions charged to the lane.
    pub preemptions: u64,
    /// Resumes charged to the lane.
    pub resumes: u64,
    /// `FinishReason::Oom` completions in the lane.
    pub oom_finishes: u64,
}

/// Lifecycle telemetry from a sustained-load churn run ([`run_churn`]).
pub struct ChurnStats {
    pub wall_s: f64,
    /// Completions with `FinishReason::Oom` (must be zero whenever
    /// every sequence fits the compiled capacity alone).
    pub oom_finishes: usize,
    /// Recompute-preemptions over the run.
    pub preemptions: u64,
    /// Preempted sequences resumed (prompt + generated re-prefilled).
    pub resumes: u64,
    /// Layer formats migrated in place on the live group.
    pub kv_migrations: u64,
    /// Migrations that happened while the core was serving load (live
    /// rows in the group, a prefill in flight, or work queued).
    pub busy_migrations: u64,
    /// Ticks where a prefill chunk and at least one decoded token
    /// landed together — chunked prefill interleaving with decode.
    pub interleaved_ticks: usize,
    /// Largest waiting-queue depth observed (over-subscription proof).
    pub peak_queue_depth: usize,
    /// Per-group breakdown; one lane per decode group that served the
    /// run. Their sums must equal the aggregate fields above (the soak
    /// asserts it).
    pub lanes: Vec<GroupLaneStats>,
}

/// Sustained-load churn driver over the real [`Scheduler`] (the serving
/// path with chunked prefill, recompute-preemption and live format
/// migration — not the bench-group closed loop). All `tasks` are
/// submitted up front, over-subscribing the group; returns lifecycle
/// telemetry plus every completion.
pub fn run_churn(
    engine: &mut Engine,
    tok: &Tokenizer,
    policy: PolicyKind,
    tasks: &[Task],
    max_new: usize,
) -> Result<(ChurnStats, Vec<Completion>)> {
    let mut sched = Scheduler::new(engine, policy);
    for (i, task) in tasks.iter().enumerate() {
        sched.submit(Request {
            id: i as u64,
            prompt: tok.encode_prompt(&task.prompt)?,
            max_new_tokens: max_new,
            policy,
            submitted_at: std::time::Instant::now(),
            deadline_ms: None,
            class: String::new(),
        })?;
    }
    let t0 = std::time::Instant::now();
    let mut stats = ChurnStats {
        wall_s: 0.0,
        oom_finishes: 0,
        preemptions: 0,
        resumes: 0,
        kv_migrations: 0,
        busy_migrations: 0,
        interleaved_ticks: 0,
        peak_queue_depth: 0,
        lanes: Vec::new(),
    };
    let mut completions = Vec::new();
    while !sched.idle() {
        let busy = !sched.group.cache.is_empty()
            || sched.prefilling() > 0
            || sched.waiting() > 0;
        stats.peak_queue_depth = stats.peak_queue_depth.max(sched.waiting());
        let r = sched.tick(engine)?;
        if r.prefill_chunks > 0 && r.decoded_tokens > 0 {
            stats.interleaved_ticks += 1;
        }
        if r.migrated > 0 && busy {
            stats.busy_migrations += r.migrated as u64;
        }
        completions.extend(r.completed);
    }
    stats.wall_s = t0.elapsed().as_secs_f64();
    stats.oom_finishes = completions
        .iter()
        .filter(|c| c.finish == FinishReason::Oom)
        .count();
    stats.preemptions = sched.preemptions;
    stats.resumes = sched.resumes;
    stats.kv_migrations = sched.migrations;
    stats.lanes = vec![GroupLaneStats {
        group: 0,
        completions: completions.len() as u64,
        preemptions: stats.preemptions,
        resumes: stats.resumes,
        oom_finishes: stats.oom_finishes as u64,
    }];
    Ok((stats, completions))
}

/// Open-loop trace replay over the real [`Scheduler`]: each
/// [`TraceRequest`](crate::workload::trace::TraceRequest) is submitted
/// at its arrival instant (wall clock, anchored at the first tick) with
/// its tenant class and deadline attached, and every terminal outcome
/// folds into a [`RequestOutcome`](crate::workload::slo::RequestOutcome)
/// for [`crate::workload::slo::summarize`].
///
/// `time_scale` compresses the trace clock (0.1 replays a 25 s trace in
/// ~2.5 s); deadlines scale by the same factor so SLO semantics are
/// preserved under compression. Requests the admission queue rejects
/// are recorded as aborted outcomes rather than failing the replay —
/// under open-loop load, rejection IS a service outcome.
///
/// The artifact-gated soak path and the `real_*` rows of
/// `BENCH_soak.json` run through here; the CI-gated numbers come from
/// the deterministic virtual-time twin in [`crate::sim::replay`].
pub fn replay_trace(
    engine: &mut Engine,
    tok: &Tokenizer,
    policy: PolicyKind,
    trace: &[crate::workload::trace::TraceRequest],
    time_scale: f64,
) -> Result<(Vec<crate::workload::slo::RequestOutcome>, f64)> {
    use crate::workload::slo::RequestOutcome;
    let scale_deadline = |d: Option<u64>| {
        d.map(|ms| ((ms as f64 * time_scale).round() as u64).max(1))
    };
    let mut sched = Scheduler::new(engine, policy);
    let t0 = std::time::Instant::now();
    let mut next = 0usize;
    let mut completions: Vec<Completion> = Vec::new();
    while next < trace.len() || !sched.idle() {
        let now = t0.elapsed().as_secs_f64();
        while next < trace.len()
            && trace[next].arrival_s * time_scale <= now
        {
            let r = &trace[next];
            next += 1;
            let req = Request {
                id: r.id,
                prompt: tok.encode_prompt(&r.task.prompt)?,
                max_new_tokens: r.max_new_tokens,
                policy,
                submitted_at: std::time::Instant::now(),
                deadline_ms: scale_deadline(r.deadline_ms),
                class: r.class.clone(),
            };
            // A typed admission rejection (queue full) is a service
            // outcome, not a replay failure: the request simply never
            // completes and folds in as aborted below.
            let _ = sched.submit(req);
        }
        if sched.idle() {
            if next >= trace.len() {
                break;
            }
            // Idle gap before the next arrival: yield instead of
            // spinning the tick loop on an empty core.
            std::thread::sleep(std::time::Duration::from_micros(200));
            continue;
        }
        completions.extend(sched.tick(engine)?.completed);
    }
    let makespan_s = t0.elapsed().as_secs_f64();
    let by_id: std::collections::HashMap<u64, &Completion> =
        completions.iter().map(|c| (c.id, c)).collect();
    let outcomes = trace
        .iter()
        .map(|r| match by_id.get(&r.id) {
            Some(c) => RequestOutcome {
                class: r.class.clone(),
                ttft_s: c.ttft,
                tpot_s: c.tpot,
                e2e_s: c.total,
                generated: c.generated.len(),
                ok: matches!(
                    c.finish,
                    FinishReason::Eos | FinishReason::Length
                ),
                deadline_ms: scale_deadline(r.deadline_ms),
                preemptions: c.preemptions as u64,
                // Swap/rescue attribution is aggregate-only on the
                // single-scheduler path; the sim twin carries them
                // per request.
                swaps: 0,
                rescues: 0,
            },
            // Rejected at admission (or lost): an aborted outcome with
            // zero service.
            None => RequestOutcome {
                class: r.class.clone(),
                ttft_s: 0.0,
                tpot_s: 0.0,
                e2e_s: 0.0,
                generated: 0,
                ok: false,
                deadline_ms: scale_deadline(r.deadline_ms),
                preemptions: 0,
                swaps: 0,
                rescues: 0,
            },
        })
        .collect();
    Ok((outcomes, makespan_s))
}

/// Sums of the per-group rows in a supervisor `{"stats": true}`
/// document. The multi-group soak asserts these equal the aggregate
/// counters reported by the same document — the supervision
/// bookkeeping must balance across groups, restarts included.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GroupRowSums {
    pub queue_depth: usize,
    pub active: usize,
    pub prefilling: usize,
    pub live_bytes: usize,
    pub completions: u64,
    pub preemptions: u64,
    pub resumes: u64,
    pub seq_failures: u64,
    pub rescues: u64,
    pub restarts: u64,
}

/// Fold the `groups` array of a stats document into [`GroupRowSums`].
pub fn sum_group_rows(stats: &Json) -> Result<GroupRowSums> {
    let mut out = GroupRowSums::default();
    for row in stats.get("groups")?.as_arr()? {
        out.queue_depth += row.get("queue_depth")?.as_usize()?;
        out.active += row.get("active")?.as_usize()?;
        out.prefilling += row.get("prefilling")?.as_usize()?;
        out.live_bytes += row.get("live_bytes")?.as_usize()?;
        out.completions += row.get("completions")?.as_usize()? as u64;
        out.preemptions += row.get("preemptions")?.as_usize()? as u64;
        out.resumes += row.get("resumes")?.as_usize()? as u64;
        out.seq_failures += row.get("seq_failures")?.as_usize()? as u64;
        out.rescues += row.get("rescues")?.as_usize()? as u64;
        out.restarts += row.get("restarts")?.as_usize()? as u64;
    }
    Ok(out)
}

/// Write the hotpath microbench rows to `bench_results/hotpath.csv`
/// (name + per-iteration seconds), so the q8/f32 storage-backend rows
/// land next to each other in the experiment logs.
pub fn hotpath_csv(rows: &[(String, crate::util::stats::Summary)]) -> Result<()> {
    let lines: Vec<String> = rows
        .iter()
        .map(|(name, s)| {
            format!("{name},{:.9},{:.9},{:.9},{:.9}", s.mean, s.p50, s.min,
                    s.max)
        })
        .collect();
    write_csv("hotpath.csv", "name,mean_s,p50_s,min_s,max_s", &lines)
}

/// One row of a machine-readable `BENCH_*.json` result file — the
/// schema the CI bench-smoke job validates and gates on.
pub struct BenchJsonRow {
    /// What was measured (e.g. `"delta_pack_step"`, `"decode_tput"`).
    pub name: String,
    /// KV storage label ("f32" | "q8" | "q4" | "mixed").
    pub kv_format: String,
    /// Measured throughput in tokens per second.
    pub tokens_per_s: f64,
    /// Wire bytes the upload path moved per steady-state decode step.
    pub upload_bytes_per_step: usize,
    /// Row-specific extra fields spliced verbatim into the JSON object
    /// (the soak rows carry per-class SLO fields here — see
    /// [`crate::workload::slo::ClassSlo::to_fields`]). Keys must not
    /// collide with the four fixed fields above.
    pub extra: Vec<(String, Json)>,
}

/// Write `bench_results/BENCH_{bench}.json`:
/// `{bench, timestamp, rows: [{name, kv_format, tokens_per_s,
/// upload_bytes_per_step}]}`. The timestamp comes from the environment
/// (`LETHE_BENCH_TS`, else `SOURCE_DATE_EPOCH`, else empty) so repeated
/// CI runs on identical code produce byte-identical artifacts.
pub fn write_bench_json(bench: &str, rows: &[BenchJsonRow]) -> Result<()> {
    let ts = std::env::var("LETHE_BENCH_TS")
        .or_else(|_| std::env::var("SOURCE_DATE_EPOCH"))
        .unwrap_or_default();
    let arr: Vec<Json> = rows
        .iter()
        .map(|r| {
            let mut fields = vec![
                ("name", Json::str(&r.name)),
                ("kv_format", Json::str(&r.kv_format)),
                ("tokens_per_s", Json::num(r.tokens_per_s)),
                (
                    "upload_bytes_per_step",
                    Json::from(r.upload_bytes_per_step),
                ),
            ];
            for (k, v) in &r.extra {
                fields.push((k.as_str(), v.clone()));
            }
            Json::obj(fields)
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::str(bench)),
        ("timestamp", Json::str(&ts)),
        ("rows", Json::Arr(arr)),
    ]);
    std::fs::create_dir_all(RESULTS_DIR)?;
    let path = format!("{RESULTS_DIR}/BENCH_{bench}.json");
    std::fs::write(&path, doc.to_string())?;
    eprintln!("[json] wrote {path}");
    Ok(())
}

/// Tasks for a (pairs, hops) workload.
pub fn gen_tasks(seed: u64, n: usize, pairs: usize, hops: usize) -> Vec<Task> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| crate::workload::make_task(&mut rng, pairs, hops)).collect()
}

/// Markdown-ish table printer for paper-style rows.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n=== {title} ===");
    let widths: Vec<usize> = header
        .iter()
        .enumerate()
        .map(|(i, h)| {
            rows.iter()
                .map(|r| r.get(i).map(|c| c.len()).unwrap_or(0))
                .chain([h.len()])
                .max()
                .unwrap_or(8)
        })
        .collect();
    let line = |cells: Vec<String>| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:>width$}  ", c, width = widths[i]));
        }
        println!("{s}");
    };
    line(header.iter().map(|s| s.to_string()).collect());
    for r in rows {
        line(r.clone());
    }
}
