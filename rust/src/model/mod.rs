//! Model metadata, tokenizer, weight loading, and the catalogue of real
//! model architectures used by the A100 simulator.

pub mod archs;
pub mod meta;
pub mod tokenizer;
pub mod weights;

pub use archs::{arch_by_name, ArchSpec, DEEPSEEK_R1_DISTILL};
pub use meta::{ExecutableSpec, ModelMeta, WeightSpec};
pub use tokenizer::Tokenizer;
pub use weights::Weights;
