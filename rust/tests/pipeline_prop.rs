//! Pipelined-decode equivalence property (artifact-gated): for a
//! covering matrix of policies × KV storage formats × prune cadences ×
//! fault seeds, the pipelined engine (`engine.pipeline_decode = true`,
//! the default) must be **bit-identical** to the fully serial step
//! under greedy decode — the same per-step `(slot, token)` stream, the
//! same generated text, the same `FinishReason`s (injected failures
//! included), the same prune log, and the same final cache bookkeeping.
//!
//! The driver is a deterministic closed loop with rolling admission:
//! finished slots are reaped and refilled mid-run, so the group's
//! composition fingerprint churns and the pipeline's drain/discard
//! paths (finish, composition, policy_due, fault) are all exercised —
//! not just the steady overlapped state. Skips with a notice when AOT
//! artifacts are not built.

use std::path::Path;

use lethe::config::{MixedKvRule, ServingConfig};
use lethe::engine::{Engine, SeqState};
use lethe::kvcache::KvFormat;
use lethe::model::Tokenizer;
use lethe::policy::{make_policy, PolicyKind};
use lethe::runtime::Runtime;
use lethe::util::prng::Rng;
use lethe::workload::make_task;

/// Everything one run produces that the equivalence property compares.
#[derive(Debug, PartialEq)]
struct RunTrace {
    /// Per decode step, the `(slot, token)` pairs `Engine::step`
    /// returned, in order.
    steps: Vec<Vec<(usize, i32)>>,
    /// Per sequence id (sorted): generated tokens, finish reason
    /// (rendered), prune events as (layer, step, before, after).
    done: Vec<(u64, Vec<i32>, String, Vec<(usize, usize, usize, usize)>)>,
    /// Final per-(layer, slot) live lengths.
    lens: Vec<usize>,
    live_bytes: usize,
    f32_equiv_bytes: usize,
    prune_events: u64,
    seq_failures: u64,
    ooms: u64,
    faults_injected: u64,
    decode_steps: u64,
}

struct Scenario {
    name: &'static str,
    policy: PolicyKind,
    format: KvFormat,
    mixed: bool,
    /// (evict_threshold, sparse_ratio) for Lethe; budget for baselines.
    evict_threshold: usize,
    sparse_ratio: f64,
    budget: usize,
    fault_seed: Option<u64>,
    /// -1 ignores EOS (forces Length finishes at staggered max_new).
    eos_mode: bool,
    n_tasks: usize,
    batch: usize,
    max_new_base: usize,
}

fn run_mode(
    dir: &Path,
    sc: &Scenario,
    prompts: &[Vec<i32>],
    eos: i32,
    pipeline: bool,
) -> RunTrace {
    let mut cfg = ServingConfig::default();
    cfg.engine.pipeline_decode = pipeline;
    cfg.kv.format = sc.format;
    if sc.mixed {
        cfg.kv.mixed = Some(MixedKvRule::default());
    }
    cfg.lethe.evict_threshold = sc.evict_threshold;
    cfg.lethe.sparse_ratio = sc.sparse_ratio;
    cfg.baseline.budget = sc.budget;
    if let Some(seed) = sc.fault_seed {
        cfg.faults.seed = seed;
        cfg.faults.rate = 0.08;
        cfg.faults.stall_ms = 1;
    }
    let rt = Runtime::load(dir).expect("runtime loads");
    let mut engine = Engine::new(rt, cfg).unwrap();
    let layers = engine.dims().n_layers;
    let mut group = engine.new_group(sc.batch, sc.policy);

    // Staggered generation lengths so slots finish on different steps:
    // every finish is a drain boundary and every refill a composition
    // change.
    let mut next = 0usize;
    let mut admit = |engine: &mut Engine,
                     group: &mut lethe::engine::DecodeGroup,
                     next: &mut usize| {
        while *next < prompts.len() {
            let Some(slot) = group.free_slot() else { break };
            let max_new = sc.max_new_base + 3 * (*next % 4);
            let seq = SeqState::new(
                *next as u64,
                make_policy(sc.policy, &engine.cfg, layers),
                layers,
                max_new,
                eos,
            );
            engine.prefill(group, slot, seq, &prompts[*next]).unwrap();
            *next += 1;
        }
    };
    admit(&mut engine, &mut group, &mut next);

    let mut steps = Vec::new();
    while group.active() > 0 {
        steps.push(engine.step(&mut group).unwrap());
        group.reap();
        admit(&mut engine, &mut group, &mut next);
    }

    let mut done: Vec<_> = group
        .done
        .iter()
        .map(|s| {
            (
                s.id,
                s.generated.clone(),
                format!("{:?}", s.finished),
                s.prune_log
                    .iter()
                    .map(|e| (e.layer, e.step, e.before, e.after))
                    .collect::<Vec<_>>(),
            )
        })
        .collect();
    done.sort_by_key(|d| d.0);
    let mut lens = Vec::new();
    for l in 0..layers {
        for b in 0..sc.batch {
            lens.push(group.cache.len(l, b));
        }
    }
    let m = &engine.metrics;

    // The drain bookkeeping must balance in both modes: serial runs
    // never overlap; pipelined runs carry one drain reason for every
    // step that fell back to the serial body.
    if pipeline {
        let drains: u64 = m.pipeline_drains.values().sum();
        assert!(
            m.pipeline_overlapped_steps + drains >= m.decode_steps,
            "{}: overlapped {} + drains {:?} < steps {}",
            sc.name,
            m.pipeline_overlapped_steps,
            m.pipeline_drains,
            m.decode_steps,
        );
    } else {
        assert_eq!(
            m.pipeline_overlapped_steps, 0,
            "{}: serial mode must never overlap",
            sc.name
        );
    }

    RunTrace {
        steps,
        done,
        lens,
        live_bytes: group.cache.live_bytes(),
        f32_equiv_bytes: group.cache.f32_equivalent_bytes(),
        prune_events: m.prune_events,
        seq_failures: m.seq_failures,
        ooms: m.ooms,
        faults_injected: m.faults_injected,
        decode_steps: m.decode_steps,
    }
}

#[test]
fn pipelined_decode_is_token_identical_to_serial() {
    let dir = Path::new("artifacts");
    if !dir.join("model_meta.json").exists() {
        eprintln!("[skip] run `make artifacts` first");
        return;
    }
    let rt = Runtime::load(dir).expect("runtime loads");
    let tok = Tokenizer::from_meta(&rt.meta).unwrap();
    drop(rt);

    // Covering matrix: every policy, every storage format (f32 / q8 /
    // q4 / mixed), an aggressive and a default prune cadence, three
    // fault seeds, EOS-respecting and length-forced generations.
    let scenarios = [
        Scenario {
            name: "lethe-f32-aggressive-prune",
            policy: PolicyKind::Lethe,
            format: KvFormat::F32,
            mixed: false,
            evict_threshold: 40,
            sparse_ratio: 10.0,
            budget: 128,
            fault_seed: None,
            eos_mode: false,
            n_tasks: 6,
            batch: 4,
            max_new_base: 56,
        },
        Scenario {
            name: "lethe-q8-faults",
            policy: PolicyKind::Lethe,
            format: KvFormat::QuantI8,
            mixed: false,
            evict_threshold: 128,
            sparse_ratio: 400.0,
            budget: 128,
            fault_seed: Some(1),
            eos_mode: true,
            n_tasks: 6,
            batch: 4,
            max_new_base: 32,
        },
        Scenario {
            name: "h2o-q4-faults",
            policy: PolicyKind::H2o,
            format: KvFormat::QuantI4,
            mixed: false,
            evict_threshold: 128,
            sparse_ratio: 400.0,
            budget: 40,
            fault_seed: Some(2),
            eos_mode: false,
            n_tasks: 5,
            batch: 3,
            max_new_base: 40,
        },
        Scenario {
            name: "streaming-mixed",
            policy: PolicyKind::StreamingLlm,
            format: KvFormat::F32,
            mixed: true,
            evict_threshold: 128,
            sparse_ratio: 400.0,
            budget: 40,
            fault_seed: None,
            eos_mode: true,
            n_tasks: 5,
            batch: 3,
            max_new_base: 36,
        },
        Scenario {
            name: "pyramid-q8-faults",
            policy: PolicyKind::PyramidKv,
            format: KvFormat::QuantI8,
            mixed: false,
            evict_threshold: 128,
            sparse_ratio: 400.0,
            budget: 48,
            fault_seed: Some(3),
            eos_mode: true,
            n_tasks: 4,
            batch: 2,
            max_new_base: 32,
        },
        Scenario {
            name: "fullkv-f32-steady",
            policy: PolicyKind::FullKv,
            format: KvFormat::F32,
            mixed: false,
            evict_threshold: 128,
            sparse_ratio: 400.0,
            budget: 128,
            fault_seed: None,
            eos_mode: false,
            n_tasks: 4,
            batch: 4,
            max_new_base: 28,
        },
    ];

    for (i, sc) in scenarios.iter().enumerate() {
        let mut rng = Rng::new(0xb0a + i as u64);
        let prompts: Vec<Vec<i32>> = (0..sc.n_tasks)
            .map(|j| {
                let t = make_task(&mut rng, 4 + 2 * (j % 4), 1 + j % 3);
                tok.encode_prompt(&t.prompt).unwrap()
            })
            .collect();
        let eos = if sc.eos_mode { tok.eos } else { -1 };

        let serial = run_mode(dir, sc, &prompts, eos, false);
        let pipelined = run_mode(dir, sc, &prompts, eos, true);

        assert_eq!(
            serial.steps, pipelined.steps,
            "{}: per-step token stream diverged",
            sc.name
        );
        assert_eq!(
            serial, pipelined,
            "{}: serial and pipelined runs diverged",
            sc.name
        );
        if let Some(seed) = sc.fault_seed {
            assert!(
                serial.faults_injected > 0,
                "{}: fault seed {seed} never fired — the scenario isn't \
                 exercising the fault drain path",
                sc.name
            );
        }
    }
}
