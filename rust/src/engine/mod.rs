//! The decode engine: drives PJRT executables over a [`crate::kvcache::GroupCache`],
//! applies eviction policies between steps, and exposes the step-level
//! telemetry every bench consumes.
//!
//! One [`Engine`] owns the runtime; one [`DecodeGroup`] is a set of
//! co-batched sequences (continuous batching keeps slots front-packed).
//! Per step the engine:
//!   1. buckets the live batch to the smallest compiled `B` and the live
//!      cache to the smallest compiled capacity `C` (needs one slot of
//!      headroom for the in-graph insert),
//!   2. delta-packs the cache into the bucket's persistent resident
//!      scratch (epoch protocol, see [`crate::kvcache`]) — steady-state
//!      append-only steps copy (or, on the quantized `kv.format = "q8"`
//!      backend, dequantize) one token row per (layer, slot) instead
//!      of the whole C-prefix — then uploads + runs `decode_b{B}_c{C}`,
//!   3. fans the per-slot post-decode work out across the worker pool in
//!      two lanes: the **critical lane** (host-side K/V insert mirror +
//!      NaN-safe greedy sampling — everything the next step's upload
//!      image depends on) and the **deferred policy lane** (RASR score
//!      accumulation Eq. 5, sparsity tracking Eq. 1, multi-round policy
//!      pruning) — each slot's state is disjoint, so slots proceed in
//!      parallel with per-slot scratch buffers.
//!
//! With `engine.pipeline_decode` (the default) the step is software
//! pipelined: right after the critical lane, the next step's image is
//! delta-packed into the *other* scratch buffer and its execute is
//! pre-submitted on the async runtime seam ([`Runtime::decode_submit`]),
//! so the device runs step t+1 while step t's deferred policy lane is
//! still working. The pipeline drains to the serial path at every
//! boundary where deferred work can change layout or control flow — a
//! due prune round (each policy's `may_prune` promise), a finishing
//! sequence, a capacity-bucket or packed-variant flip, any injected or
//! real fault — and every landed result is re-validated against the
//! group's composition and cache-layout fingerprints before being
//! applied, so greedy decode stays token-identical to the serial path.
//!
//! FullKV never prunes, so step 1 eventually finds no capacity bucket —
//! that error is surfaced as an OOM on the sequence, mirroring the
//! paper's Tables 2–3.

pub mod group;

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{anyhow, ensure, Result};

pub use group::{DecodeGroup, FinishReason, PruneEvent, SeqPhase, SeqState};

use crate::attn::score::ProbsView;
use crate::config::ServingConfig;
use crate::error::{EngineError, FailureKind};
use crate::fault::{FaultPlan, FaultSite};
use crate::kvcache::{
    CacheDims, FormatMap, KvFormat, PackScratch, PackStats, PackedScratch,
    SlotViewMut,
};
use crate::metrics::EngineMetrics;
use crate::policy::{LayerState, PolicyKind};
use crate::runtime::registry::{DecodeHandle, DecodeOut, PrefillOut};
use crate::runtime::tensors::HostTensorF32;
use crate::runtime::Runtime;
use crate::util::threadpool::ThreadPool;

/// One resident upload image: either the f32 expansion every backend can
/// produce ([`PackScratch`]) or the packed codes + scales wire form a
/// uniformly quantized group feeds the kernel-side-dequant executables
/// ([`PackedScratch`]).
enum UploadImage {
    F32(PackScratch),
    Packed(PackedScratch),
}

impl UploadImage {
    /// Wire bytes of one full image upload at this variant.
    fn image_bytes(&self) -> usize {
        match self {
            UploadImage::F32(s) => s.image_bytes(),
            UploadImage::Packed(s) => s.image_bytes(),
        }
    }

    /// Does this image already carry the wanted variant (`None` = f32
    /// expansion, `Some(fmt)` = packed at `fmt`)?
    fn matches(&self, want: Option<KvFormat>) -> bool {
        match (self, want) {
            (UploadImage::F32(_), None) => true,
            (UploadImage::Packed(s), Some(f)) => s.format() == f,
            _ => false,
        }
    }
}

/// Double-buffered upload scratch for one (batch, capacity) bucket. Each
/// step rotates to the *other* buffer before delta-packing, so the image
/// being reconciled is never the one the previous step handed to the
/// runtime for upload — the handoff protocol a future async-upload
/// runtime needs, at the cost of each buffer appending two token rows
/// per turn instead of one (still O(1) steady-state work, since each
/// buffer's residency epochs track its own two-step-old image).
struct UploadScratch {
    slots: [Option<UploadImage>; 2],
    cursor: usize,
}

impl UploadScratch {
    fn new() -> UploadScratch {
        UploadScratch { slots: [None, None], cursor: 0 }
    }

    /// Rotate to the other buffer and return it, (re)allocating when it
    /// is cold or carries the wrong variant — e.g. a live format
    /// migration flipped the group between packed and f32 service.
    fn rotate(
        &mut self,
        cd: &CacheDims,
        bb: usize,
        cap: usize,
        want: Option<KvFormat>,
    ) -> &mut UploadImage {
        self.cursor ^= 1;
        let slot = &mut self.slots[self.cursor];
        if !slot.as_ref().is_some_and(|s| s.matches(want)) {
            *slot = Some(match want {
                Some(fmt) => {
                    UploadImage::Packed(PackedScratch::new(cd, bb, cap, fmt))
                }
                None => UploadImage::F32(PackScratch::new(cd, bb, cap)),
            });
        }
        slot.as_mut().unwrap()
    }
}

/// Next step's fault triple, pre-drawn at the end of the current step.
///
/// The pipelined path must decide whether to pre-submit step t+1's
/// execute *before* step t returns, and an injected fault at any seam
/// forces t+1 down the serial path — so every successful step draws the
/// next step's whole triple early, and the serial path consumes the
/// same stash, keeping the seeded RNG stream advancing at identical
/// points in both modes. One seed ⇒ one fault schedule, pipelined or
/// not (the serial-vs-pipelined lockstep property test leans on this).
struct StashedFaults {
    /// Cache generation the triple was drawn against; a stale stash
    /// (the caller swapped groups since) is discarded — identically in
    /// both modes, since the stash protocol is one shared code path.
    cache_id: u64,
    stall: bool,
    /// Raw victim draw for a KV-alloc injection, reduced modulo the
    /// live batch size at consume time ([`FaultPlan::pick_raw`] — one
    /// fixed-width draw keeps the stream batch-size independent).
    kv_raw: Option<u64>,
    exec: bool,
}

/// An execute pre-submitted for the *next* step at the end of this one
/// (`engine.pipeline_decode`). While this exists the runtime and the
/// upload-scratch map are off limits — the executor thread reads the
/// submitted image through raw pointers until [`Engine::sync_runtime`]
/// lands it.
struct PendingDecode {
    handle: DecodeHandle,
    /// Group composition at submit
    /// ([`DecodeGroup::composition_fingerprint`]).
    comp_fp: u64,
    /// Cache layout at submit
    /// ([`crate::kvcache::GroupCache::layout_fingerprint`]). The
    /// deferred policy lane runs *after* the submit, but score
    /// accumulation leaves lens and epochs untouched — so the
    /// fingerprint moves only when something that actually invalidates
    /// the submitted image happened (a prune the `may_prune` gate
    /// missed, a migration, a swap/restore, a prefill install).
    layout_fp: u64,
    cache_id: u64,
    n: usize,
    bb: usize,
    cap: usize,
    want: Option<KvFormat>,
}

/// A landed pre-submitted execute awaiting validation by the next
/// [`Engine::step`] call (same fields as [`PendingDecode`], with the
/// handle resolved into its result).
struct ResolvedDecode {
    out: Result<DecodeOut>,
    comp_fp: u64,
    layout_fp: u64,
    cache_id: u64,
    n: usize,
    bb: usize,
    cap: usize,
    want: Option<KvFormat>,
}

/// Accumulated state of an in-flight incremental (chunked) prefill: the
/// prior-KV window the next `prefill_t{T}_kv` chunk attends over, the
/// running RASR attention mass over the consumed prefix, and the latest
/// chunk's last-position logits. The scheduler holds one per chunked
/// prefill job between ticks and converts it into a window-shaped
/// [`PrefillOut`] for [`Engine::install_prefill`] once the final chunk
/// lands. Compared to the recompute path (each chunk re-prefills the
/// whole prefix from position 0), total work over an n-token prompt
/// drops from O(n²/chunk) to O(n).
pub struct PrefillAcc {
    /// Prior K window `[L, 1, Hkv, cap, D]`; rows `0..consumed` valid.
    k: HostTensorF32,
    /// Prior V window, same shape as `k`.
    v: HostTensorF32,
    /// Accumulated attention mass `[L, 1, Hq, cap]` over the prefix.
    scores: HostTensorF32,
    /// Logits `[1, V]` at the last consumed position.
    logits: HostTensorF32,
    consumed: usize,
    /// Prior-window capacity = the compiled `PREFILL_KV_CAP`
    /// (= the largest prefill bucket).
    cap: usize,
}

impl PrefillAcc {
    /// Prompt tokens consumed so far.
    pub fn consumed(&self) -> usize {
        self.consumed
    }

    /// Convert into the window-shaped [`PrefillOut`] that
    /// [`Engine::install_prefill`] consumes (it reads the first
    /// `consumed` rows/columns of each carrier).
    pub fn into_prefill_out(self) -> PrefillOut {
        PrefillOut {
            logits: self.logits,
            k_all: self.k,
            v_all: self.v,
            scores: self.scores,
        }
    }

    /// Fold a prefill window's K/V rows `0..n` into the prior window at
    /// row offset `off`. `k_all`/`v_all` are `[L, 1, Hkv, T, D]`, rows
    /// contiguous, so each (layer, head) moves one contiguous span.
    fn fold_rows(
        &mut self,
        k_all: &HostTensorF32,
        v_all: &HostTensorF32,
        n: usize,
        off: usize,
    ) {
        let (layers, hkv, dh) =
            (self.k.shape[0], self.k.shape[2], self.k.shape[4]);
        let t = k_all.shape[3];
        for l in 0..layers {
            for h in 0..hkv {
                let src = (l * hkv + h) * t * dh;
                let dst = (l * hkv + h) * self.cap * dh + off * dh;
                self.k.data[dst..dst + n * dh]
                    .copy_from_slice(&k_all.data[src..src + n * dh]);
                self.v.data[dst..dst + n * dh]
                    .copy_from_slice(&v_all.data[src..src + n * dh]);
            }
        }
    }
}

pub struct Engine {
    pub rt: Runtime,
    pub cfg: ServingConfig,
    /// Largest compiled capacity for the active profile (the OOM line).
    pub cmax: usize,
    batch_buckets: Vec<usize>,
    /// Persistent resident upload scratch keyed by (batch, capacity)
    /// bucket — double-buffered ([`UploadScratch`]): two rotating images
    /// so the one being delta-packed for step N+1 never aliases the one
    /// step N handed to the runtime. Each image records per-(layer,
    /// slot) residency epochs so the steady-state step copies only what
    /// changed since *its own* last turn.
    scratch: HashMap<(usize, usize), UploadScratch>,
    /// Per-slot score scratch (index = slot), so the parallel post-decode
    /// pipeline needs no shared mutable buffer.
    slot_score_bufs: Vec<Vec<f32>>,
    /// Engine-level per-layer attention-sparsity EMA (Eq. 1), folded in
    /// from every sequence's tracker after prefill and each decode step.
    /// Feeds the `kv.mixed` sparsity-directed format rule when a new
    /// group's per-layer storage map is resolved; starts at 0.0 (dense)
    /// until real traffic has been observed.
    layer_sparsity: Vec<f64>,
    /// Worker pool for the per-slot post-decode pipeline.
    pool: ThreadPool,
    /// Deterministic fault-injection plan (`faults.*` config); `None`
    /// in production — the hot path then pays one branch per step. All
    /// draws happen on single-threaded control flow *before* the
    /// per-slot fan-out, so a seed fully determines the fault schedule.
    pub faults: Option<FaultPlan>,
    /// `engine.pipeline_decode`: pre-submit the next step's execute at
    /// the end of each step so the device runs concurrently with the
    /// deferred policy lane. Off (`--no-pipeline`) every step runs the
    /// serial pack → execute → policy path.
    pipeline: bool,
    /// In-flight pre-submitted execute for the next step.
    pending: Option<PendingDecode>,
    /// Landed-but-unvalidated pre-submitted result, kept between
    /// [`Engine::sync_runtime`] and the next [`Engine::step`].
    resolved: Option<ResolvedDecode>,
    /// Pre-drawn fault triple for the next step (see
    /// [`Engine::take_step_faults`]).
    fault_stash: Option<StashedFaults>,
    /// The previous step already recorded why this step runs serially
    /// (a pre-submit refusal); suppresses the `"cold"` drain note.
    drain_prenoted: bool,
    pub metrics: EngineMetrics,
    /// When set, [`Engine::step`] keeps a copy of the raw per-head
    /// attention probs `[L, B, Hq, C]` of the last step — the Figures 1
    /// and 5 benches read them for sparsity heatmaps / head similarity.
    pub keep_probs: bool,
    pub last_probs: Option<HostTensorF32>,
}

impl Engine {
    pub fn new(rt: Runtime, cfg: ServingConfig) -> Result<Engine> {
        let caps = rt
            .meta
            .decode_capacities
            .get(&cfg.cache_profile)
            .ok_or_else(|| anyhow!("profile '{}' not compiled",
                                   cfg.cache_profile))?
            .clone();
        let cmax = *caps.iter().max().unwrap();
        let batch_buckets = rt.batch_buckets(&cfg.cache_profile);
        let n_layers = rt.meta.dims.n_layers;
        // Per-layer format overrides are resolved lazily at group
        // construction; reject out-of-range layer indices up front so a
        // config typo fails at boot, not silently.
        if let Some(&bad) = cfg
            .kv
            .layer_formats
            .keys()
            .find(|&&l| l >= n_layers)
        {
            return Err(anyhow!(
                "kv.layer_formats layer {bad} out of range \
                 (model has {n_layers} layers)"
            ));
        }
        let faults = FaultPlan::from_config(&cfg.faults);
        let mut metrics = EngineMetrics::default();
        // Pre-seed the capacity histogram with every compiled bucket so
        // the steady-state step's entry() never allocates a map node;
        // zero-count buckets stay out of the serialized JSON.
        for &c in &caps {
            metrics.capacity_hist.insert(c, 0);
        }
        let pipeline = cfg.engine.pipeline_decode;
        Ok(Engine {
            rt,
            cfg,
            cmax,
            batch_buckets,
            scratch: HashMap::new(),
            slot_score_bufs: Vec::new(),
            layer_sparsity: vec![0.0; n_layers],
            pool: ThreadPool::new(slot_workers()),
            faults,
            pipeline,
            pending: None,
            resolved: None,
            fault_stash: None,
            drain_prenoted: false,
            metrics,
            keep_probs: false,
            last_probs: None,
        })
    }

    /// Land any in-flight pre-submitted execute. Must run before every
    /// runtime entry and before anything moves or mutates the upload
    /// scratch: the executor thread reads the submitted image (and the
    /// runtime's executable registry) through raw pointers until the
    /// wait returns. The landed result is kept for the next
    /// [`Engine::step`] to validate against the live group's
    /// fingerprints and either apply or discard.
    pub fn sync_runtime(&mut self) {
        if let Some(p) = self.pending.take() {
            let (out, exec_seconds) = p.handle.wait();
            // Device time is accounted when the execute lands, whether
            // or not the result survives validation — the hardware was
            // busy either way.
            self.metrics.exec_seconds.push(exec_seconds);
            self.resolved = Some(ResolvedDecode {
                out,
                comp_fp: p.comp_fp,
                layout_fp: p.layout_fp,
                cache_id: p.cache_id,
                n: p.n,
                bb: p.bb,
                cap: p.cap,
                want: p.want,
            });
        }
    }

    pub fn dims(&self) -> &crate::model::meta::ModelDims {
        &self.rt.meta.dims
    }

    /// Cache dims for a new group of `group_size` slots.
    pub fn cache_dims(&self, group_size: usize) -> CacheDims {
        let d = self.dims();
        CacheDims {
            layers: d.n_layers,
            batch: group_size,
            kv_heads: d.n_kv_heads,
            capacity: self.cmax,
            d_head: d.d_head,
        }
    }

    /// New decode group on the configured KV storage backends: the
    /// per-layer format map is resolved from `kv.format` /
    /// `kv.layer_formats` / `kv.mixed` against the engine's current
    /// per-layer sparsity estimates (see [`Engine::layer_sparsity`]), so
    /// a `kv.mixed` rule places high-sparsity layers in the compressed
    /// format once traffic has been observed.
    pub fn new_group(&self, group_size: usize, policy: PolicyKind) -> DecodeGroup {
        DecodeGroup::with_formats(
            self.cache_dims(group_size),
            policy,
            self.current_format_map(),
        )
    }

    /// The per-layer format map a group built right now would get
    /// (`kv.format` / `kv.layer_formats` / `kv.mixed` resolved against
    /// the current sparsity estimates). The scheduler compares this
    /// against its live group's map to know when an idle group should be
    /// rebuilt so the serving path picks up a changed `kv.mixed`
    /// resolution.
    pub fn current_format_map(&self) -> FormatMap {
        FormatMap::new(self.cfg.kv.resolve_formats(
            self.dims().n_layers,
            &self.layer_sparsity,
        ))
    }

    /// Engine-level per-layer attention-sparsity estimates (Eq. 1 EMA
    /// across all served sequences; 0.0 until a layer has been observed).
    pub fn layer_sparsity(&self) -> &[f64] {
        &self.layer_sparsity
    }

    /// Fold the active sequences' per-layer sparsity trackers into the
    /// engine-level EMA that seeds future groups' mixed format maps.
    fn observe_group_sparsity(&mut self, group: &DecodeGroup) {
        let n = group.active();
        if n == 0 {
            return;
        }
        for (l, est) in self.layer_sparsity.iter_mut().enumerate() {
            let mean = (0..n)
                .map(|b| group.seq(b).sparsity.sparsity(l))
                .sum::<f64>()
                / n as f64;
            *est = 0.8 * *est + 0.2 * mean;
        }
    }

    /// Smallest compiled batch bucket >= n.
    fn batch_bucket(&self, n: usize) -> Result<usize> {
        self.batch_buckets
            .iter()
            .copied()
            .find(|&b| b >= n)
            .ok_or_else(|| anyhow!(
                "{n} active sequences exceed largest compiled batch {:?}",
                self.batch_buckets.last()))
    }

    /// The packed decode variant a step over `group` at bucket
    /// (`bb`, `cap`) can be served with: `Some(fmt)` when every layer of
    /// the group stores at the same quantized format *and* the artifact
    /// set carries the matching kernel-side-dequant executable
    /// (`decode_b{bb}_c{cap}_q8` / `_q4`). `None` routes the step down
    /// the f32 expansion path — dense or mixed groups, or artifact sets
    /// built before the packed variants existed.
    fn packed_variant(
        &self,
        group: &DecodeGroup,
        bb: usize,
        cap: usize,
    ) -> Option<KvFormat> {
        let fmt = group.cache.format_map().uniform_format()?;
        let suffix = match fmt {
            KvFormat::QuantI8 => "q8",
            KvFormat::QuantI4 => "q4",
            KvFormat::F32 => return None,
        };
        self.rt
            .has_executable(&format!("decode_b{bb}_c{cap}_{suffix}"))
            .then_some(fmt)
    }

    /// Prefill a prompt into slot `slot` of the group; returns the first
    /// generated token. This is the monolithic path (benches, eval, the
    /// chunked scheduler's final chunk is [`Engine::prefill_window`] +
    /// [`Engine::install_prefill`]).
    pub fn prefill(
        &mut self,
        group: &mut DecodeGroup,
        slot: usize,
        seq: SeqState,
        prompt: &[i32],
    ) -> Result<i32> {
        let out = self.prefill_window(prompt)?;
        self.install_prefill(group, slot, seq, prompt, out, false)
    }

    /// Run the bucketed prefill executable over a prompt *prefix* and
    /// return its raw outputs. This is one chunk of a chunked prefill:
    /// the compiled kernels take no prior KV, so each chunk recomputes
    /// the prefix from position 0 at the smallest bucket that fits —
    /// intermediate chunks bound the per-tick stall (one executable run)
    /// and only the final chunk's outputs are installed.
    pub fn prefill_window(&mut self, prefix: &[i32]) -> Result<PrefillOut> {
        self.sync_runtime();
        let t0 = Instant::now();
        let bucket = self.rt.prefill_bucket(prefix.len())?;
        let out = self.rt.prefill(bucket, prefix)?;
        self.metrics.prefill_seconds.push(t0.elapsed().as_secs_f64());
        self.metrics.prefill_tokens += prefix.len() as u64;
        Ok(out)
    }

    /// Whether the artifact set carries the `prefill_t{T}_kv`
    /// incremental variants for every compiled prefill bucket. Old
    /// artifact sets don't; the scheduler then falls back to the
    /// whole-prefix recompute chunking of [`Engine::prefill_window`].
    pub fn supports_incremental_prefill(&self) -> bool {
        !self.rt.meta.prefill_ts.is_empty()
            && self
                .rt
                .meta
                .prefill_ts
                .iter()
                .all(|t| self.rt.has_executable(&format!("prefill_t{t}_kv")))
    }

    /// Run one chunk of an incremental prefill. `acc = None` starts the
    /// prompt: the chunk runs through the classic bucketed prefill and
    /// seeds a fresh accumulator. With `Some(acc)` the chunk runs
    /// through `prefill_t{T}_kv` against the accumulated prior KV —
    /// O(chunk) work instead of recomputing the whole consumed prefix —
    /// and the chunk's new K/V rows and score mass fold into the
    /// accumulator. Greedy-decode equivalence to the monolithic prefill
    /// is covered by the artifact-gated lifecycle tests and the python
    /// kernel tests.
    pub fn prefill_chunk(
        &mut self,
        acc: Option<PrefillAcc>,
        chunk: &[i32],
    ) -> Result<PrefillAcc> {
        self.sync_runtime();
        let cap = self.max_prefill_tokens();
        let d = self.rt.meta.dims.clone();
        let (hq, hkv) = (d.n_q_heads, d.n_kv_heads);
        let n = chunk.len();
        let Some(mut acc) = acc else {
            // First chunk: no prior KV yet, the plain bucketed prefill
            // is exactly this computation (and meters itself).
            let out = self.prefill_window(chunk)?;
            let mut acc = PrefillAcc {
                k: HostTensorF32::zeros(&[
                    d.n_layers, 1, hkv, cap, d.d_head,
                ]),
                v: HostTensorF32::zeros(&[
                    d.n_layers, 1, hkv, cap, d.d_head,
                ]),
                scores: HostTensorF32::zeros(&[d.n_layers, 1, hq, cap]),
                logits: HostTensorF32::zeros(&[1, d.vocab_size]),
                consumed: 0,
                cap,
            };
            acc.fold_rows(&out.k_all, &out.v_all, n, 0);
            let t = out.scores.shape[3];
            for l in 0..d.n_layers {
                for h in 0..hq {
                    let src = (l * hq + h) * t;
                    let dst = (l * hq + h) * cap;
                    acc.scores.data[dst..dst + n]
                        .copy_from_slice(&out.scores.data[src..src + n]);
                }
            }
            acc.logits = out.logits;
            acc.consumed = n;
            return Ok(acc);
        };
        ensure!(
            acc.consumed + n <= cap,
            "incremental prefill overflow: {} consumed + {n} chunk > \
             prior window {cap}",
            acc.consumed
        );
        let t0 = Instant::now();
        let bucket = self.rt.prefill_bucket(n)?;
        let out = self.rt.prefill_kv(
            bucket,
            &acc.k,
            &acc.v,
            acc.consumed as i32,
            chunk,
        )?;
        self.metrics.prefill_seconds.push(t0.elapsed().as_secs_f64());
        self.metrics.prefill_tokens += n as u64;
        acc.fold_rows(&out.k_all, &out.v_all, n, acc.consumed);
        // scores is [L, 1, Hq, cap + bucket]: mass over the prior keys
        // in [..cap] (only the consumed columns are live), over the
        // chunk's own keys in [cap..cap+n] — fold both at their prefix
        // positions.
        let tw = out.scores.shape[3];
        for l in 0..d.n_layers {
            for h in 0..hq {
                let src = (l * hq + h) * tw;
                let dst = (l * hq + h) * cap;
                for j in 0..acc.consumed {
                    acc.scores.data[dst + j] += out.scores.data[src + j];
                }
                for j in 0..n {
                    acc.scores.data[dst + acc.consumed + j] +=
                        out.scores.data[src + cap + j];
                }
            }
        }
        acc.logits = out.logits;
        acc.consumed += n;
        Ok(acc)
    }

    /// Install a completed prefill into slot `slot`: load the K/V rows,
    /// seed RASR scores (Eq. 2) and sparsity, run the policies, and
    /// record the generated token. `tokens` is exactly what
    /// [`Engine::prefill_window`] consumed. With `resume = false` this
    /// is a fresh prompt (`tokens` = the prompt; the token is the
    /// sequence's first). With `resume = true` the sequence is being
    /// revived after a recompute-preemption: `tokens` is its original
    /// prompt plus everything it had generated, so the recomputed cache
    /// and the produced next token are exactly what an uncontended run
    /// would hold at this point (greedy decode is deterministic).
    pub fn install_prefill(
        &mut self,
        group: &mut DecodeGroup,
        slot: usize,
        mut seq: SeqState,
        tokens: &[i32],
        out: PrefillOut,
        resume: bool,
    ) -> Result<i32> {
        let n = tokens.len();
        group.cache.load_prefill(slot, &out.k_all, &out.v_all, n)?;
        if !resume && seq.prompt.is_empty() {
            // Keep the prompt for a possible future recompute-preemption
            // (the bench path constructs SeqState without one).
            seq.prompt = tokens.to_vec();
        }
        group.install(slot, seq);

        // RASR init (Eq. 2): head-summed prefill attention mass.
        let layers = self.rt.meta.dims.n_layers;
        let sv = ProbsView::new(&out.scores); // [L,1,Hq,T]
        let mut buf = Vec::new();
        for l in 0..layers {
            sv.head_sum_into(l, 0, n, &mut buf);
            group.cache.accumulate_scores(l, slot, 0.0, &buf);
            group.seq_mut(slot).sparsity.observe(l, &buf);
        }
        // Policies may prune immediately (long prompts).
        self.apply_policies(group, slot)?;
        self.observe_group_sparsity(group);

        let tok = argmax(&out.logits.data);
        if resume {
            // The seq already carries prompt_len/abs_pos/generated from
            // before the preemption; the prefill logits at the last
            // position are exactly the next decode step's logits.
            group.seq_mut(slot).note_token(tok);
        } else {
            group.seq_mut(slot).note_prefilled(n, tok);
        }
        Ok(tok)
    }

    /// EOS token id from the artifact manifest's tokenizer specials
    /// (position of `"<eos>"`; falls back to the historical id 2 when
    /// the manifest carries no such special).
    pub fn eos_token(&self) -> i32 {
        self.rt.meta.eos_id().unwrap_or(2)
    }

    /// Largest compiled prefill bucket — the longest prompt (or
    /// recompute-preemption resume prefix) the runtime can process.
    pub fn max_prefill_tokens(&self) -> usize {
        self.rt.meta.prefill_ts.iter().copied().max().unwrap_or(0)
    }

    /// This step's fault triple `(stall, kv_raw, exec)`. Every
    /// successful step pre-draws the *next* step's triple at its end
    /// ([`Engine::draw_fault_triple`]) — before the pipeline decides
    /// whether to pre-submit — and this consumes the stash, falling
    /// back to a fresh draw when none fits (cold start, early-returned
    /// previous step, or a stale stash from a swapped group). Both
    /// decode modes share this exact path, so a seed yields one fault
    /// schedule whether pipelining is on or off.
    fn take_step_faults(&mut self, cache_id: u64) -> (bool, Option<u64>, bool) {
        if self.faults.is_none() {
            return (false, None, false);
        }
        if let Some(s) = self.fault_stash.take() {
            if s.cache_id == cache_id {
                return (s.stall, s.kv_raw, s.exec);
            }
            // Stale: its draws are already consumed — identically in
            // both modes — so just fall through to a fresh triple.
        }
        let fp = self.faults.as_mut().unwrap();
        let stall = fp.trip(FaultSite::TickStall);
        let kv_raw = fp.trip(FaultSite::KvAlloc).then(|| fp.pick_raw());
        let exec = fp.trip(FaultSite::RuntimeExecute);
        self.metrics.faults_injected = fp.injected;
        (stall, kv_raw, exec)
    }

    /// Pre-draw the next step's fault triple into the stash (the
    /// end-of-step half of the protocol above).
    fn draw_fault_triple(&mut self, cache_id: u64) {
        let Some(fp) = self.faults.as_mut() else { return };
        let stall = fp.trip(FaultSite::TickStall);
        let kv_raw = fp.trip(FaultSite::KvAlloc).then(|| fp.pick_raw());
        let exec = fp.trip(FaultSite::RuntimeExecute);
        self.metrics.faults_injected = fp.injected;
        self.fault_stash =
            Some(StashedFaults { cache_id, stall, kv_raw, exec });
    }

    /// One decode step over all active sequences. Returns per-slot newly
    /// generated tokens (empty when the step OOMed).
    ///
    /// Under `engine.pipeline_decode` the fast path applies the execute
    /// pre-submitted by the previous step (validated against the live
    /// group's fingerprints); the serial path below is the drain target
    /// and stays the single source of truth for what a step means.
    pub fn step(&mut self, group: &mut DecodeGroup) -> Result<Vec<(usize, i32)>> {
        let t0 = Instant::now();
        // Land any in-flight pre-submitted execute before touching the
        // runtime or the upload scratch.
        self.sync_runtime();
        let n = group.active();
        if n == 0 {
            self.resolved = None;
            return Ok(Vec::new());
        }
        // Deterministic fault injection: the triple is consumed here on
        // single-threaded control flow before any fan-out, so one seed
        // fixes the whole schedule regardless of worker interleaving —
        // and regardless of pipelining (see `take_step_faults`).
        // `kv_raw` fails exactly one slot's KV insert; `inject_exec`
        // fails the runtime execute call.
        let (stall, kv_raw, inject_exec) =
            self.take_step_faults(group.cache.cache_id());
        let mut stall_secs = 0.0;
        if stall {
            let ms = self.faults.as_ref().map_or(0, FaultPlan::stall_ms);
            stall_secs = ms as f64 / 1e3;
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
        let inject_slot = kv_raw.map(|r| (r % n as u64) as usize);

        // Pipelined fast path: a pre-run execute for exactly this group
        // state, with no fault due this step, is applied directly — the
        // device already ran it while the previous step's policy lane
        // was still working.
        let mut noted = false;
        if let Some(r) = self.resolved.take() {
            let faulted = stall || inject_slot.is_some() || inject_exec;
            if !faulted
                && r.cache_id == group.cache.cache_id()
                && r.n == n
                && r.comp_fp == group.composition_fingerprint()
                && r.layout_fp == group.cache.layout_fingerprint()
            {
                return self.apply_resolved(group, r, t0, stall_secs);
            }
            // Anything the deferred lane or the caller changed that the
            // submitted image can't reflect — or a fault due this step
            // (blast-radius rule: faults always take the serial path) —
            // discards the speculative result; the serial body below
            // re-runs the step and stays token-identical.
            self.metrics
                .note_drain(if faulted { "fault" } else { "composition" });
            noted = true;
        }
        if self.pipeline {
            if !noted && !self.drain_prenoted {
                self.metrics.note_drain("cold");
            }
            self.drain_prenoted = false;
        }

        let bb = self.batch_bucket(n)?;
        // +1 headroom: the in-graph insert writes at slot len.
        let need = group.cache.max_len() + 1;
        let cap = match self.rt.capacity_bucket(&self.cfg.cache_profile, need) {
            Ok(c) => c,
            Err(e) => {
                // OOM: mark the longest sequence failed; caller reaps.
                group.mark_oom();
                self.metrics.ooms += 1;
                crate::log_warn!("OOM at live length {need}: {e}");
                return Ok(Vec::new());
            }
        };

        let cd = group.cache.dims;
        // Raw-speed path selection: a uniformly quantized group whose
        // artifact set carries the matching kernel-side-dequant variant
        // uploads its stored wire bytes; everything else (dense, mixed,
        // old artifacts) takes the f32 expansion.
        let want = self.packed_variant(group, bb, cap);
        let t_pack = Instant::now();
        let image = self
            .scratch
            .entry((bb, cap))
            .or_insert_with(UploadScratch::new)
            .rotate(&cd, bb, cap, want);
        let (pstats, image_bytes) = match image {
            UploadImage::F32(s) => {
                (group.cache.pack_delta(s)?, s.image_bytes())
            }
            UploadImage::Packed(s) => {
                (group.cache.pack_delta_packed(s)?, s.image_bytes())
            }
        };

        let mut tokens = vec![0i32; bb];
        let mut positions = vec![0i32; bb];
        for b in 0..n {
            tokens[b] = group.seq(b).last_token;
            positions[b] = group.seq(b).abs_pos as i32;
        }
        let t_pack = t_pack.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let decode_res = if inject_exec {
            Err(EngineError::RuntimeExecute {
                detail: "injected fault".into(),
            }
            .into())
        } else {
            match &*image {
                UploadImage::F32(s) => self.rt.decode(
                    bb, cap, &s.k, &s.v, &s.lens, &tokens, &positions,
                ),
                UploadImage::Packed(s) => {
                    self.rt.decode_packed(bb, cap, s, &tokens, &positions)
                }
            }
        };
        self.note_pack(pstats, image_bytes, t_pack);
        let out = match decode_res {
            Ok(out) => out,
            Err(e) => {
                // A failed execute fails one sequence — the longest,
                // shedding the most pressure — with a typed finish; the
                // survivors retry next tick instead of the whole tick
                // erroring out.
                group.mark_failed(FailureKind::RuntimeExecute);
                self.metrics.seq_failures += 1;
                crate::log_warn!("decode execute failed: {e:#}");
                return Ok(Vec::new());
            }
        };
        self.metrics.exec_seconds.push(t1.elapsed().as_secs_f64());
        self.post_decode(
            group, out, n, bb, cap, want, inject_slot, false, t0, stall_secs,
        )
    }

    /// Apply a validated pre-run execute as this step's result.
    fn apply_resolved(
        &mut self,
        group: &mut DecodeGroup,
        r: ResolvedDecode,
        t0: Instant,
        stall_secs: f64,
    ) -> Result<Vec<(usize, i32)>> {
        let out = match r.out {
            Ok(out) => out,
            Err(e) => {
                // The pre-run execute itself failed: surface it exactly
                // like a serial execute failure (one sequence fails,
                // survivors retry) and restart the pipeline cold.
                self.metrics.note_drain("exec_err");
                self.drain_prenoted = true;
                group.mark_failed(FailureKind::RuntimeExecute);
                self.metrics.seq_failures += 1;
                crate::log_warn!("decode execute failed: {e:#}");
                return Ok(Vec::new());
            }
        };
        self.post_decode(
            group, out, r.n, r.bb, r.cap, r.want, None, true, t0, stall_secs,
        )
    }

    /// Shared post-execute tail of one decode step: the critical lane
    /// (host K/V mirror insert + NaN-safe greedy sampling), the next
    /// step's fault pre-draw and optional pre-submit, then the deferred
    /// policy lane (Eq. 5 score accumulation, Eq. 1 sparsity,
    /// multi-round pruning) — which, when a pre-submit happened, runs
    /// concurrently with the next step's execute on the device.
    #[allow(clippy::too_many_arguments)]
    fn post_decode(
        &mut self,
        group: &mut DecodeGroup,
        out: DecodeOut,
        n: usize,
        bb: usize,
        cap: usize,
        want: Option<KvFormat>,
        inject_slot: Option<usize>,
        overlapped: bool,
        t0: Instant,
        stall_secs: f64,
    ) -> Result<Vec<(usize, i32)>> {
        let d = self.rt.meta.dims.clone();
        let hkv_d = d.n_kv_heads * d.d_head;
        let vocab = d.vocab_size;
        let n_layers = d.n_layers;
        let cmax = self.cmax;
        // Keep the per-slot scratch high-water bounded by the live
        // group's slot count (a rebuild to a smaller group releases the
        // excess), growing to the active batch as before.
        if self.slot_score_bufs.len() > group.group_size() {
            self.slot_score_bufs.truncate(group.group_size());
        }
        if self.slot_score_bufs.len() < n {
            self.slot_score_bufs.resize_with(n, Vec::new);
        }

        // Critical lane: everything the next step's upload image
        // depends on, fanned out per slot (disjoint state).
        let t_crit = Instant::now();
        let mut crit: Vec<Option<Result<i32>>> =
            std::iter::repeat_with(|| None).take(n).collect();
        {
            let (seqs, cache) = group.seqs_and_cache_mut();
            let views = cache.slot_views_mut(n);
            let out_ref = &out;
            if n == 1 {
                // No point paying thread hand-off for one slot.
                let view = views.into_iter().next().unwrap();
                crit[0] = Some(critical_slot(
                    view, &mut seqs[0], out_ref, 0, bb, n_layers, hkv_d,
                    vocab, inject_slot == Some(0),
                ));
            } else {
                let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                    Vec::with_capacity(n);
                for (b, ((view, seq), res)) in views
                    .into_iter()
                    .zip(seqs.iter_mut())
                    .zip(crit.iter_mut())
                    .enumerate()
                {
                    let inject = inject_slot == Some(b);
                    jobs.push(Box::new(move || {
                        *res = Some(critical_slot(
                            view, seq, out_ref, b, bb, n_layers, hkv_d,
                            vocab, inject,
                        ));
                    }));
                }
                self.pool.scoped(jobs);
            }
        }
        let t_crit = t_crit.elapsed().as_secs_f64();

        // Every successful step pre-draws the next step's fault triple
        // here — the draw point must not depend on pipelining — and
        // then decides whether the next execute can be pre-submitted.
        self.draw_fault_triple(group.cache.cache_id());
        let crit_ok = crit.iter().all(|r| matches!(r, Some(Ok(_))));
        self.maybe_submit_next(group, n, bb, cap, want, crit_ok);

        // Deferred policy lane: nothing the submitted image needs
        // happens here (score accumulation leaves lens and epochs
        // untouched; the submit gate vouched no prune is due), so this
        // overlaps the in-flight execute. Slots whose critical lane
        // failed are skipped — same as the old single-pass behavior,
        // where a failed insert aborted the slot before its policies.
        let t_def = Instant::now();
        let mut defr: Vec<Option<Result<(u64, u64)>>> =
            std::iter::repeat_with(|| None).take(n).collect();
        {
            let (seqs, cache) = group.seqs_and_cache_mut();
            let views = cache.slot_views_mut(n);
            let out_ref = &out;
            if n == 1 {
                if matches!(crit[0], Some(Ok(_))) {
                    let view = views.into_iter().next().unwrap();
                    defr[0] = Some(deferred_slot(
                        view, &mut seqs[0], &mut self.slot_score_bufs[0],
                        out_ref, 0, cmax,
                    ));
                }
            } else {
                let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                    Vec::with_capacity(n);
                for (b, (((view, seq), buf), res)) in views
                    .into_iter()
                    .zip(seqs.iter_mut())
                    .zip(self.slot_score_bufs.iter_mut())
                    .zip(defr.iter_mut())
                    .enumerate()
                {
                    if !matches!(crit[b], Some(Ok(_))) {
                        continue;
                    }
                    jobs.push(Box::new(move || {
                        *res = Some(deferred_slot(
                            view, seq, buf, out_ref, b, cmax,
                        ));
                    }));
                }
                if !jobs.is_empty() {
                    self.pool.scoped(jobs);
                }
            }
        }
        let t_def = t_def.elapsed().as_secs_f64();

        // Per-slot outcomes: a slot that failed in either lane (typed
        // error) or whose worker panicked (the pool caught it; its
        // result cell is still None) finishes *that sequence* with
        // FinishReason::Error — the slot and its KV rows are freed at
        // the next reap and every other sequence proceeds.
        let mut produced = Vec::with_capacity(n);
        for (b, (c, dr)) in
            crit.into_iter().zip(defr.into_iter()).enumerate()
        {
            match (c, dr) {
                (Some(Ok(token)), Some(Ok((events, pruned)))) => {
                    produced.push((b, token));
                    self.metrics.prune_events += events;
                    self.metrics.pruned_tokens += pruned;
                }
                (Some(Err(e)), _) | (Some(Ok(_)), Some(Err(e))) => {
                    let kind = if inject_slot == Some(b) {
                        FailureKind::Injected
                    } else {
                        e.downcast_ref::<EngineError>()
                            .and_then(EngineError::failure_kind)
                            .unwrap_or(FailureKind::KvAlloc)
                    };
                    crate::log_warn!("slot {b} failed ({kind}): {e:#}");
                    group.seq_mut(b).fail(kind);
                    self.metrics.seq_failures += 1;
                }
                _ => {
                    crate::log_warn!(
                        "slot {b} worker panicked; failing its sequence"
                    );
                    group.seq_mut(b).fail(FailureKind::SlotPanic);
                    self.metrics.seq_failures += 1;
                }
            }
        }
        self.observe_group_sparsity(group);
        if self.keep_probs {
            self.last_probs = Some(out.probs.clone());
        }

        self.metrics.decode_steps += 1;
        self.metrics.decode_tokens += n as u64;
        self.metrics.policy_seconds.push(t_crit + t_def);
        self.metrics.live_bytes_last = group.cache.live_bytes();
        self.metrics.f32_equiv_bytes_last = group.cache.f32_equivalent_bytes();
        // Only re-materialize the format snapshot when the served map
        // actually changed (group rebuild); keeps the steady-state step
        // free of per-step String/Vec allocations.
        if self.metrics.kv_layer_formats != group.cache.format_map().as_slice()
        {
            self.metrics.kv_format = group.cache.format_label();
            self.metrics.kv_layer_formats =
                group.cache.format_map().as_slice().to_vec();
        }
        // Pre-seeded at boot, so this never allocates in steady state.
        *self.metrics.capacity_hist.entry(cap).or_insert(0) += 1;
        if overlapped {
            self.metrics.pipeline_overlapped_steps += 1;
        }
        // Honest per-step wall: includes the wait that landed the
        // pre-submitted execute (top of step), excludes injected stall.
        self.metrics
            .step_seconds
            .push((t0.elapsed().as_secs_f64() - stall_secs).max(0.0));
        Ok(produced)
    }

    /// Why the next step cannot be pre-submitted — the drain boundaries
    /// where deferred work can change layout or control flow — or
    /// `None` when the pipeline can keep going.
    fn submit_gate(
        &self,
        group: &DecodeGroup,
        n: usize,
        bb: usize,
        cap: usize,
        want: Option<KvFormat>,
        crit_ok: bool,
    ) -> Option<&'static str> {
        if !crit_ok || (0..n).any(|b| group.seq(b).is_done()) {
            // A finishing (or failing) sequence changes the batch
            // composition before the next step runs.
            return Some("finish");
        }
        if self
            .fault_stash
            .as_ref()
            .is_some_and(|s| s.stall || s.exec || s.kv_raw.is_some())
        {
            // Blast-radius rule: a fault due next step runs serially.
            return Some("fault");
        }
        let need = group.cache.max_len() + 1;
        match self.rt.capacity_bucket(&self.cfg.cache_profile, need) {
            Ok(c) if c == cap => {}
            _ => return Some("capacity_flip"),
        }
        if self.packed_variant(group, bb, cap) != want {
            return Some("variant_flip");
        }
        // The deferred lane below runs the policies at exactly the live
        // lengths visible here; `may_prune` is each policy's promise
        // that `plan` stays a pure no-op under these lengths, so the
        // image about to be packed cannot be invalidated. A missed
        // promise is still caught by the layout fingerprint at wait
        // time — this gate is a perf heuristic, not the safety net.
        let layers = group.cache.dims.layers;
        for b in 0..n {
            let seq = group.seq(b);
            for l in 0..layers {
                let len = group.cache.len(l, b);
                if len > 0 && seq.policy.may_prune(l, len, self.cmax) {
                    return Some("policy_due");
                }
            }
        }
        None
    }

    /// Pack the next step's image into the *other* scratch buffer and
    /// pre-submit its execute on the async runtime seam — unless a
    /// drain boundary is due ([`Engine::submit_gate`]); then record why
    /// and leave the next step to the serial path.
    fn maybe_submit_next(
        &mut self,
        group: &DecodeGroup,
        n: usize,
        bb: usize,
        cap: usize,
        want: Option<KvFormat>,
        crit_ok: bool,
    ) {
        if !self.pipeline {
            return;
        }
        if let Some(reason) =
            self.submit_gate(group, n, bb, cap, want, crit_ok)
        {
            self.metrics.note_drain(reason);
            self.drain_prenoted = true;
            return;
        }
        let cd = group.cache.dims;
        let t_pack = Instant::now();
        let image = self
            .scratch
            .entry((bb, cap))
            .or_insert_with(UploadScratch::new)
            .rotate(&cd, bb, cap, want);
        let packed = match image {
            UploadImage::F32(s) => {
                group.cache.pack_delta(s).map(|p| (p, s.image_bytes()))
            }
            UploadImage::Packed(s) => group
                .cache
                .pack_delta_packed(s)
                .map(|p| (p, s.image_bytes())),
        };
        let (pstats, image_bytes) = match packed {
            Ok(x) => x,
            Err(e) => {
                // Only a scratch/dims mismatch can land here; the
                // serial path will surface it properly next step.
                crate::log_warn!("pipeline pre-pack failed: {e:#}");
                self.metrics.note_drain("cold");
                self.drain_prenoted = true;
                return;
            }
        };
        let mut tokens = vec![0i32; bb];
        let mut positions = vec![0i32; bb];
        for b in 0..n {
            tokens[b] = group.seq(b).last_token;
            positions[b] = group.seq(b).abs_pos as i32;
        }
        let handle = match &*image {
            UploadImage::F32(s) => self.rt.decode_submit(
                bb, cap, &s.k, &s.v, &s.lens, tokens, positions,
            ),
            UploadImage::Packed(s) => {
                self.rt.decode_packed_submit(bb, cap, s, tokens, positions)
            }
        };
        self.note_pack(pstats, image_bytes, t_pack.elapsed().as_secs_f64());
        self.pending = Some(PendingDecode {
            handle,
            comp_fp: group.composition_fingerprint(),
            layout_fp: group.cache.layout_fingerprint(),
            cache_id: group.cache.cache_id(),
            n,
            bb,
            cap,
            want,
        });
        self.drain_prenoted = false;
    }

    /// Fold one delta-pack's stats into the metrics (shared by the
    /// serial path and the pipelined pre-submit — pack work is always
    /// accounted by the step that performed it).
    fn note_pack(&mut self, pstats: PackStats, image_bytes: usize, secs: f64) {
        self.metrics.pack_bytes_copied += pstats.bytes_copied as u64;
        self.metrics.pack_bytes_f32_equiv += pstats.bytes_f32_equiv as u64;
        self.metrics.upload_bytes_last = image_bytes;
        self.metrics.delta_pack_hits +=
            (pstats.pairs_delta + pstats.pairs_skipped) as u64;
        self.metrics.delta_pack_full += pstats.pairs_full as u64;
        self.metrics.pack_seconds.push(secs);
    }

    /// Run each layer's retention plan for one slot (the serial entry
    /// used by prefill; decode steps run [`policy_pass`] inside the
    /// parallel per-slot pipeline).
    fn apply_policies(&mut self, group: &mut DecodeGroup, b: usize) -> Result<()> {
        let cmax = self.cmax;
        let (seqs, cache) = group.seqs_and_cache_mut();
        let mut view = cache.slot_view_mut(b);
        let (events, pruned) = policy_pass(&mut view, &mut seqs[b], cmax)?;
        self.metrics.prune_events += events;
        self.metrics.pruned_tokens += pruned;
        Ok(())
    }

    /// Generate until EOS/limit for every sequence in the group
    /// (the batch inner loop used by benches and the eval harness).
    pub fn run_group(&mut self, group: &mut DecodeGroup) -> Result<()> {
        while group.active() > 0 {
            self.step(group)?;
            group.reap();
        }
        Ok(())
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        // A pre-submitted execute holds raw pointers into the upload
        // scratch; land it before the scratch map is freed.
        if let Some(p) = self.pending.take() {
            let _ = p.handle.wait();
        }
    }
}

/// Worker count for the per-slot post-decode pipeline. Capped: slots are
/// short CPU-bound jobs and the PJRT exec phase owns the machine anyway.
fn slot_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .clamp(1, 8)
}

/// One slot's **critical lane**: mirror the in-graph K/V insert
/// host-side and greedily sample the next token — exactly the state the
/// next step's upload image and token feed depend on, so it runs before
/// the pipelined pre-submit. Runs on a pool worker; touches only
/// slot-local state (`view`, `seq`). `inject` simulates a KV-alloc
/// failure at the insert seam (the fault plan decided this slot before
/// the fan-out).
#[allow(clippy::too_many_arguments)]
fn critical_slot(
    mut view: SlotViewMut<'_>,
    seq: &mut group::SeqState,
    out: &DecodeOut,
    b: usize,
    bb: usize,
    n_layers: usize,
    hkv_d: usize,
    vocab: usize,
    inject: bool,
) -> Result<i32> {
    if inject {
        return Err(EngineError::KvAlloc {
            seq: seq.id,
            detail: "injected fault".into(),
        }
        .into());
    }
    // Mirror the in-graph insert host-side.
    let pos = seq.abs_pos as i32;
    for l in 0..n_layers {
        let off = (l * bb + b) * hkv_d;
        view.insert(
            l,
            &out.k_new.data[off..off + hkv_d],
            &out.v_new.data[off..off + hkv_d],
            pos,
        )?;
    }
    // Sample + bookkeeping.
    let logits = &out.logits.data[b * vocab..(b + 1) * vocab];
    let token = argmax(logits);
    seq.note_token(token);
    Ok(token)
}

/// One slot's **deferred policy lane**: RASR score accumulation (Eq. 5),
/// sparsity tracking (Eq. 1), and multi-round pruning; returns (prune
/// events, pruned tokens). Nothing the next step's upload image needs
/// happens here — score accumulation leaves lens and epochs untouched —
/// so under `engine.pipeline_decode` this lane runs while the
/// pre-submitted next execute is already on the device. Neither lane
/// reads what the other writes (scores/sparsity never look at the
/// sampled token or step count until `policy_pass`, which runs last in
/// both the split and the old fused order), so the lane split is
/// output-identical to the old single-pass slot job.
fn deferred_slot(
    mut view: SlotViewMut<'_>,
    seq: &mut group::SeqState,
    score_buf: &mut Vec<f32>,
    out: &DecodeOut,
    b: usize,
    cmax: usize,
) -> Result<(u64, u64)> {
    let gamma = seq.policy.gamma();
    let pv = ProbsView::new(&out.probs);
    for l in 0..view.layers() {
        let live = view.len(l);
        pv.head_sum_into(l, b, live, score_buf);
        view.accumulate_scores(l, gamma, score_buf);
        seq.sparsity.observe(l, score_buf);
    }
    // Multi-round pruning.
    policy_pass(&mut view, seq, cmax)
}

/// Retention plans for every layer of one slot; returns (prune events,
/// pruned tokens). Shared by the parallel decode pipeline and prefill.
fn policy_pass(
    view: &mut SlotViewMut<'_>,
    seq: &mut group::SeqState,
    cmax: usize,
) -> Result<(u64, u64)> {
    let mut events = 0u64;
    let mut pruned = 0u64;
    for l in 0..view.layers() {
        let len = view.len(l);
        if len == 0 {
            continue;
        }
        let plan = {
            let st = LayerState {
                scores: view.scores(l),
                pos: view.pos(l),
                len,
                step: seq.steps,
                sparsity: seq.sparsity.sparsity(l),
                capacity: cmax,
            };
            seq.policy.plan(l, &st)
        };
        if let Some(keep) = plan {
            let after = view.apply_retention(l, &keep)?;
            seq.note_prune(l, len, after);
            events += 1;
            pruned += (len - after) as u64;
        }
    }
    Ok((events, pruned))
}

/// Greedy sampling, NaN-safe: NaN logits are skipped (a NaN must never
/// win a `>` comparison *or* block a later finite value), ties keep the
/// first maximum, and an all-NaN row falls back to token 0.
pub fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    let mut seen = false;
    for (i, &x) in xs.iter().enumerate() {
        if x.is_nan() {
            continue;
        }
        if !seen || x > bv {
            seen = true;
            bv = x;
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_first_max() {
        assert_eq!(argmax(&[0.1, 0.9, 0.9, 0.2]), 1);
        assert_eq!(argmax(&[-1.0, -2.0]), 0);
    }

    #[test]
    fn argmax_skips_nans() {
        assert_eq!(argmax(&[f32::NAN, 0.5, 0.9]), 2);
        assert_eq!(argmax(&[0.9, f32::NAN, 0.5]), 0);
        // A NaN head must not shadow a later finite -inf.
        assert_eq!(argmax(&[f32::NAN, f32::NEG_INFINITY]), 1);
        // NaN tail keeps the earlier max.
        assert_eq!(argmax(&[0.1, 0.7, f32::NAN]), 1);
    }

    #[test]
    fn argmax_all_nan_falls_back_to_zero() {
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn argmax_ties_break_to_first_even_at_neg_infinity() {
        assert_eq!(
            argmax(&[f32::NEG_INFINITY, f32::NEG_INFINITY]),
            0
        );
    }
}
