//! Host-owned KV cache for a decode group (the serving state).
//!
//! Layout mirrors the executables' expectation: conceptually
//! `[L, B, Hkv, Cmax, D]` row-major, with per-(layer, slot) lengths —
//! per-layer lengths are what make Lethe's layerwise budgets expressible.
//! Alongside K/V we track, per (layer, slot):
//!   * `pos`    — each cached row's original absolute position (recency
//!                signal for RASR / H2O / StreamingLLM),
//!   * `scores` — the policy's accumulated attention score per row
//!                (RASR Eq. 5; γ is policy-owned).
//!
//! Eviction is [`GroupCache::apply_retention`]: an in-place front-packing
//! gather by source index, applied identically to K, V, pos and scores so
//! the four stay aligned. Upload packing ([`GroupCache::pack`]) copies the
//! C-prefix of each (l, b, h) row into a scratch tensor for the chosen
//! capacity bucket — the smaller Lethe keeps the cache, the smaller the
//! bucket and the less is uploaded/attended per step.

pub mod quant;

use anyhow::{ensure, Result};

use crate::runtime::tensors::{HostTensorF32, HostTensorI32};

#[derive(Clone, Debug)]
pub struct CacheDims {
    pub layers: usize,
    pub batch: usize,
    pub kv_heads: usize,
    pub capacity: usize, // Cmax
    pub d_head: usize,
}

#[derive(Clone)]
pub struct GroupCache {
    pub dims: CacheDims,
    /// [L, B, Hkv, Cmax, D]
    k: Vec<f32>,
    v: Vec<f32>,
    /// [L, B]
    lens: Vec<usize>,
    /// [L][B] -> per-slot original absolute position, length = lens[l][b].
    pos: Vec<Vec<i32>>,
    /// [L][B] -> accumulated attention score per slot.
    scores: Vec<Vec<f32>>,
}

impl GroupCache {
    pub fn new(dims: CacheDims) -> Self {
        let CacheDims { layers, batch, kv_heads, capacity, d_head } = dims;
        let n = layers * batch * kv_heads * capacity * d_head;
        GroupCache {
            dims,
            k: vec![0.0; n],
            v: vec![0.0; n],
            lens: vec![0; layers * batch],
            pos: vec![Vec::new(); layers * batch],
            scores: vec![Vec::new(); layers * batch],
        }
    }

    #[inline]
    fn lb(&self, l: usize, b: usize) -> usize {
        l * self.dims.batch + b
    }

    pub fn len(&self, l: usize, b: usize) -> usize {
        self.lens[self.lb(l, b)]
    }

    pub fn is_empty(&self) -> bool {
        self.lens.iter().all(|&l| l == 0)
    }

    /// Longest live row across layers for one slot.
    pub fn max_len_slot(&self, b: usize) -> usize {
        (0..self.dims.layers).map(|l| self.len(l, b)).max().unwrap_or(0)
    }

    /// Longest live row across the whole group (capacity-bucket driver).
    pub fn max_len(&self) -> usize {
        (0..self.dims.batch).map(|b| self.max_len_slot(b)).max().unwrap_or(0)
    }

    /// Total live KV bytes (f32 K+V) — the Table 2 metric.
    pub fn live_bytes(&self) -> usize {
        let row = self.dims.kv_heads * self.dims.d_head * 4 * 2;
        self.lens.iter().map(|&n| n * row).sum()
    }

    pub fn pos(&self, l: usize, b: usize) -> &[i32] {
        &self.pos[self.lb(l, b)]
    }

    pub fn scores(&self, l: usize, b: usize) -> &[f32] {
        &self.scores[self.lb(l, b)]
    }

    fn row_offset(&self, l: usize, b: usize, h: usize, c: usize) -> usize {
        let CacheDims { batch, kv_heads, capacity, d_head, .. } = self.dims;
        (((l * batch + b) * kv_heads + h) * capacity + c) * d_head
    }

    /// Append one token's K/V (layout [Hkv, D]) at the next slot of
    /// (l, b). `abs_pos` is the token's absolute decode position.
    pub fn insert(
        &mut self,
        l: usize,
        b: usize,
        k_row: &[f32],
        v_row: &[f32],
        abs_pos: i32,
    ) -> Result<()> {
        let d = self.dims.d_head;
        let hkv = self.dims.kv_heads;
        ensure!(k_row.len() == hkv * d && v_row.len() == hkv * d,
                "bad row size");
        let idx = self.lb(l, b);
        let c = self.lens[idx];
        ensure!(c < self.dims.capacity,
                "cache overflow at layer {l} slot {b} (len {c})");
        for h in 0..hkv {
            let off = self.row_offset(l, b, h, c);
            self.k[off..off + d].copy_from_slice(&k_row[h * d..(h + 1) * d]);
            self.v[off..off + d].copy_from_slice(&v_row[h * d..(h + 1) * d]);
        }
        self.lens[idx] = c + 1;
        self.pos[idx].push(abs_pos);
        self.scores[idx].push(0.0);
        Ok(())
    }

    /// Bulk-load a prefilled sequence into slot `b` (from prefill k_all
    /// [L, 1, Hkv, T, D] with `len` valid rows). Resets the slot first.
    pub fn load_prefill(
        &mut self,
        b: usize,
        k_all: &HostTensorF32,
        v_all: &HostTensorF32,
        len: usize,
    ) -> Result<()> {
        let CacheDims { layers, kv_heads, d_head, capacity, .. } = self.dims;
        let t = k_all.shape[3];
        ensure!(k_all.shape == vec![layers, 1, kv_heads, t, d_head],
                "bad prefill shape {:?}", k_all.shape);
        ensure!(len <= t && len <= capacity, "prefill len {len} too long");
        self.reset_slot(b);
        for l in 0..layers {
            let idx = self.lb(l, b);
            for h in 0..kv_heads {
                let src = ((l * kv_heads + h) * t) * d_head;
                let dst = self.row_offset(l, b, h, 0);
                let n = len * d_head;
                self.k[dst..dst + n]
                    .copy_from_slice(&k_all.data[src..src + n]);
                self.v[dst..dst + n]
                    .copy_from_slice(&v_all.data[src..src + n]);
            }
            self.lens[idx] = len;
            self.pos[idx] = (0..len as i32).collect();
            self.scores[idx] = vec![0.0; len];
        }
        Ok(())
    }

    pub fn reset_slot(&mut self, b: usize) {
        for l in 0..self.dims.layers {
            let idx = self.lb(l, b);
            self.lens[idx] = 0;
            self.pos[idx].clear();
            self.scores[idx].clear();
        }
        // K/V rows beyond lens are dead; zero lazily only where read.
    }

    /// Swap two slots' contents entirely (scheduler keeps active slots
    /// front-packed; used when a middle sequence finishes).
    pub fn swap_slots(&mut self, a: usize, b: usize) {
        if a == b {
            return;
        }
        let CacheDims { layers, kv_heads, capacity, d_head, .. } = self.dims;
        let row = capacity * d_head;
        for l in 0..layers {
            for h in 0..kv_heads {
                let oa = self.row_offset(l, a, h, 0);
                let ob = self.row_offset(l, b, h, 0);
                for i in 0..row {
                    self.k.swap(oa + i, ob + i);
                    self.v.swap(oa + i, ob + i);
                }
            }
            let (ia, ib) = (self.lb(l, a), self.lb(l, b));
            self.lens.swap(ia, ib);
            self.pos.swap(ia, ib);
            self.scores.swap(ia, ib);
        }
    }

    /// RASR-style score update for (l, b): `scores = gamma * scores + add`
    /// where `add[j]` is the head-summed attention mass on slot j this
    /// step (Eq. 5). `add` may be longer than the live length (bucket
    /// padding) — extra entries are ignored.
    pub fn accumulate_scores(
        &mut self,
        l: usize,
        b: usize,
        gamma: f32,
        add: &[f32],
    ) {
        let idx = self.lb(l, b);
        let n = self.lens[idx];
        let s = &mut self.scores[idx];
        for j in 0..n {
            s[j] = gamma * s[j] + add.get(j).copied().unwrap_or(0.0);
        }
    }

    /// Apply a retention plan to (l, b): keep exactly the rows whose
    /// current indices are in `keep` (any order; deduplicated + sorted
    /// ascending so relative order — and thus recency structure — is
    /// preserved). Returns the new length.
    pub fn apply_retention(
        &mut self,
        l: usize,
        b: usize,
        keep: &[usize],
    ) -> Result<usize> {
        let idx = self.lb(l, b);
        let n = self.lens[idx];
        let mut ks: Vec<usize> = keep.iter().copied().collect();
        ks.sort_unstable();
        ks.dedup();
        ensure!(ks.iter().all(|&i| i < n),
                "retention index out of range (len {n})");
        let d = self.dims.d_head;
        for h in 0..self.dims.kv_heads {
            let base = self.row_offset(l, b, h, 0);
            for (dst, &src) in ks.iter().enumerate() {
                if dst != src {
                    let (do_, so) = (base + dst * d, base + src * d);
                    self.k.copy_within(so..so + d, do_);
                    self.v.copy_within(so..so + d, do_);
                }
            }
        }
        let pos = &mut self.pos[idx];
        let sc = &mut self.scores[idx];
        for (dst, &src) in ks.iter().enumerate() {
            pos[dst] = pos[src];
            sc[dst] = sc[src];
        }
        pos.truncate(ks.len());
        sc.truncate(ks.len());
        self.lens[idx] = ks.len();
        Ok(ks.len())
    }

    /// Pack the C-prefix of the first `bb` slots into upload tensors for
    /// a (batch, capacity) bucket: k/v [L, bb, Hkv, C, D] + lens [L, bb].
    /// Rows longer than C are a caller bug (the engine prunes or picks a
    /// bigger bucket first).
    pub fn pack(
        &self,
        bb: usize,
        c: usize,
        k_out: &mut HostTensorF32,
        v_out: &mut HostTensorF32,
        lens_out: &mut HostTensorI32,
    ) -> Result<()> {
        let CacheDims { layers, batch, kv_heads, d_head, .. } = self.dims;
        ensure!(bb <= batch, "batch bucket {bb} > group size {batch}");
        ensure!(c <= self.dims.capacity, "bucket {c} > Cmax");
        let want = vec![layers, bb, kv_heads, c, d_head];
        ensure!(k_out.shape == want && v_out.shape == want,
                "scratch shape mismatch: {:?} vs {want:?}", k_out.shape);
        let n = c * d_head;
        for l in 0..layers {
            for b in 0..bb {
                ensure!(self.len(l, b) <= c,
                        "live rows exceed bucket {c} at ({l},{b})");
                for h in 0..kv_heads {
                    let src = self.row_offset(l, b, h, 0);
                    let dst = ((l * bb + b) * kv_heads + h) * n;
                    k_out.data[dst..dst + n]
                        .copy_from_slice(&self.k[src..src + n]);
                    v_out.data[dst..dst + n]
                        .copy_from_slice(&self.v[src..src + n]);
                }
                lens_out.data[l * bb + b] = self.lens[self.lb(l, b)] as i32;
            }
        }
        Ok(())
    }

    /// Retained-slot bitmap for one layer/slot against absolute positions
    /// 0..=max_pos (Figure 3 visualisation).
    pub fn retention_bitmap(&self, l: usize, b: usize, max_pos: usize) -> Vec<bool> {
        let mut bm = vec![false; max_pos + 1];
        for &p in self.pos(l, b) {
            if (p as usize) <= max_pos {
                bm[p as usize] = true;
            }
        }
        bm
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> CacheDims {
        CacheDims { layers: 2, batch: 2, kv_heads: 2, capacity: 8, d_head: 4 }
    }

    fn row(val: f32, hkv: usize, d: usize) -> Vec<f32> {
        (0..hkv * d).map(|i| val + i as f32 * 0.01).collect()
    }

    #[test]
    fn insert_then_lengths_and_bytes() {
        let mut c = GroupCache::new(dims());
        for t in 0..3 {
            for l in 0..2 {
                c.insert(l, 0, &row(t as f32, 2, 4), &row(-(t as f32), 2, 4), t)
                    .unwrap();
            }
        }
        assert_eq!(c.len(0, 0), 3);
        assert_eq!(c.len(1, 0), 3);
        assert_eq!(c.len(0, 1), 0);
        assert_eq!(c.max_len(), 3);
        // 2 layers * 3 tokens * (2 heads * 4 dim * 4 bytes * 2 tensors)
        assert_eq!(c.live_bytes(), 2 * 3 * 2 * 4 * 4 * 2);
    }

    #[test]
    fn overflow_is_an_error() {
        let mut c = GroupCache::new(dims());
        for t in 0..8 {
            c.insert(0, 0, &row(0.0, 2, 4), &row(0.0, 2, 4), t).unwrap();
        }
        assert!(c.insert(0, 0, &row(0.0, 2, 4), &row(0.0, 2, 4), 9).is_err());
    }

    #[test]
    fn retention_front_packs_and_keeps_alignment() {
        let mut c = GroupCache::new(dims());
        for t in 0..6 {
            c.insert(0, 0, &row(t as f32, 2, 4), &row(t as f32, 2, 4), t)
                .unwrap();
        }
        c.accumulate_scores(0, 0, 1.0, &[0.1, 0.2, 0.3, 0.4, 0.5, 0.6]);
        let new_len = c.apply_retention(0, 0, &[5, 0, 3]).unwrap();
        assert_eq!(new_len, 3);
        assert_eq!(c.pos(0, 0), &[0, 3, 5]);
        let s = c.scores(0, 0);
        assert!((s[0] - 0.1).abs() < 1e-6);
        assert!((s[1] - 0.4).abs() < 1e-6);
        assert!((s[2] - 0.6).abs() < 1e-6);
        // K row 1 must now hold original token 3's data.
        let off = c.row_offset(0, 0, 0, 1);
        assert!((c.k[off] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn retention_rejects_out_of_range() {
        let mut c = GroupCache::new(dims());
        c.insert(0, 0, &row(0.0, 2, 4), &row(0.0, 2, 4), 0).unwrap();
        assert!(c.apply_retention(0, 0, &[1]).is_err());
    }

    #[test]
    fn pack_respects_bucket_and_lens() {
        let mut c = GroupCache::new(dims());
        for t in 0..4 {
            c.insert(0, 0, &row(t as f32, 2, 4), &row(t as f32, 2, 4), t)
                .unwrap();
        }
        let mut k = HostTensorF32::zeros(&[2, 2, 2, 4, 4]);
        let mut v = HostTensorF32::zeros(&[2, 2, 2, 4, 4]);
        let mut lens = HostTensorI32::zeros(&[2, 2]);
        c.pack(2, 4, &mut k, &mut v, &mut lens).unwrap();
        assert_eq!(lens.data, vec![4, 0, 0, 0]);
        // First token row of (l=0,b=0,h=0) == inserted value 0.0.
        assert!((k.data[0] - 0.0).abs() < 1e-6);
        // Bucket smaller than live rows must fail.
        let mut k2 = HostTensorF32::zeros(&[2, 2, 2, 2, 4]);
        let mut v2 = HostTensorF32::zeros(&[2, 2, 2, 2, 4]);
        let mut l2 = HostTensorI32::zeros(&[2, 2]);
        assert!(c.pack(2, 2, &mut k2, &mut v2, &mut l2).is_err());
        // Packing a single-slot bucket works and only covers slot 0.
        let mut k1 = HostTensorF32::zeros(&[2, 1, 2, 4, 4]);
        let mut v1 = HostTensorF32::zeros(&[2, 1, 2, 4, 4]);
        let mut l1 = HostTensorI32::zeros(&[2, 1]);
        c.pack(1, 4, &mut k1, &mut v1, &mut l1).unwrap();
        assert_eq!(l1.data, vec![4, 0]);
    }

    #[test]
    fn swap_slots_swaps_everything() {
        let mut c = GroupCache::new(dims());
        c.insert(0, 0, &row(1.0, 2, 4), &row(1.0, 2, 4), 0).unwrap();
        c.insert(0, 1, &row(9.0, 2, 4), &row(9.0, 2, 4), 0).unwrap();
        c.insert(0, 1, &row(8.0, 2, 4), &row(8.0, 2, 4), 1).unwrap();
        c.swap_slots(0, 1);
        assert_eq!(c.len(0, 0), 2);
        assert_eq!(c.len(0, 1), 1);
        let off = c.row_offset(0, 0, 0, 0);
        assert!((c.k[off] - 9.0).abs() < 1e-6);
    }

    #[test]
    fn load_prefill_resets_and_fills() {
        let mut c = GroupCache::new(dims());
        c.insert(0, 0, &row(5.0, 2, 4), &row(5.0, 2, 4), 0).unwrap();
        let t = 4;
        let k_all = HostTensorF32::from_vec(
            &[2, 1, 2, t, 4],
            (0..2 * 2 * t * 4).map(|i| i as f32).collect(),
        )
        .unwrap();
        let v_all = k_all.clone();
        c.load_prefill(0, &k_all, &v_all, 3).unwrap();
        assert_eq!(c.len(0, 0), 3);
        assert_eq!(c.len(1, 0), 3);
        assert_eq!(c.pos(0, 0), &[0, 1, 2]);
        assert_eq!(c.scores(1, 0), &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn retention_bitmap_marks_positions() {
        let mut c = GroupCache::new(dims());
        for t in 0..5 {
            c.insert(0, 0, &row(0.0, 2, 4), &row(0.0, 2, 4), t).unwrap();
        }
        c.apply_retention(0, 0, &[0, 4]).unwrap();
        let bm = c.retention_bitmap(0, 0, 4);
        assert_eq!(bm, vec![true, false, false, false, true]);
    }
}
