//! Continuous-batching scheduler (the vLLM-style serving loop, sized for
//! one PJRT CPU device): a bounded waiting queue with admission control,
//! prefill-on-join into free group slots, decode over the co-batched
//! group, and completion reaping.
//!
//! Policy: prefill-priority — whenever a slot is free and work is
//! waiting, prefill before the next decode step (keeps the batch full,
//! maximising decode throughput; the paper's batch-scaling tables depend
//! on exactly this behaviour).

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::Result;

use crate::engine::{DecodeGroup, Engine, SeqState};
use crate::policy::{make_policy, PolicyKind};

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub policy: PolicyKind,
    pub submitted_at: Instant,
}

#[derive(Debug)]
pub struct Completion {
    pub id: u64,
    pub generated: Vec<i32>,
    pub finish: crate::engine::FinishReason,
    pub prompt_len: usize,
    /// Seconds from submission to first token (TTFT).
    pub ttft: f64,
    /// Seconds from submission to completion.
    pub total: f64,
    pub prune_rounds: usize,
}

/// Outcome of one scheduler tick.
#[derive(Debug, Default)]
pub struct TickReport {
    pub prefilled: usize,
    pub decoded_tokens: usize,
    pub completed: Vec<Completion>,
}

pub struct Scheduler {
    pub group: DecodeGroup,
    waiting: VecDeque<Request>,
    max_waiting: usize,
    eos: i32,
    n_layers: usize,
    pub rejected: u64,
}

impl Scheduler {
    pub fn new(engine: &Engine, policy: PolicyKind) -> Scheduler {
        let group_size = engine.cfg.scheduler.max_batch;
        Scheduler {
            group: engine.new_group(group_size, policy),
            waiting: VecDeque::new(),
            max_waiting: engine.cfg.scheduler.max_waiting,
            eos: 2,
            n_layers: engine.dims().n_layers,
            rejected: 0,
        }
    }

    /// Admission control: Err when the waiting queue is full
    /// (backpressure to the caller).
    pub fn submit(&mut self, req: Request) -> Result<()> {
        if self.waiting.len() >= self.max_waiting {
            self.rejected += 1;
            anyhow::bail!("queue full ({} waiting)", self.waiting.len());
        }
        self.waiting.push_back(req);
        Ok(())
    }

    pub fn waiting(&self) -> usize {
        self.waiting.len()
    }

    /// Storage label the group cache serves with ("f32" | "q8" | "q4" |
    /// "mixed" for a per-layer map); surfaced per-completion by the
    /// server.
    pub fn kv_format(&self) -> String {
        self.group.cache.format_label()
    }

    pub fn active(&self) -> usize {
        self.group.active()
    }

    pub fn idle(&self) -> bool {
        self.waiting.is_empty() && self.group.active() == 0
    }

    /// One scheduler tick: fill free slots (prefill-priority), run one
    /// decode step, reap completions.
    pub fn tick(&mut self, engine: &mut Engine) -> Result<TickReport> {
        let mut report = TickReport::default();

        // 0. Per-layer format maps (`kv.mixed`) are resolved from the
        // engine's sparsity estimates at group construction, and those
        // estimates start at zero — so the boot-time group is always
        // all-dense. Whenever the group is idle (holds no live rows),
        // rebuild it if the resolution has changed, so the serving path
        // actually migrates onto the sparsity-directed map once traffic
        // has been observed. A busy group keeps its map (live rows are
        // never re-quantized in place; see ROADMAP follow-ons).
        if self.group.active() == 0
            && *self.group.cache.format_map() != engine.current_format_map()
        {
            self.group = engine
                .new_group(self.group.group_size(), self.group.default_policy);
        }

        // 1. Prefill into free slots.
        while self.group.has_free_slot() {
            let Some(req) = self.waiting.pop_front() else { break };
            let slot = self.group.free_slot().unwrap();
            let mut seq = SeqState::new(
                req.id,
                make_policy(req.policy, &engine.cfg, self.n_layers),
                self.n_layers,
                req.max_new_tokens,
                self.eos,
            );
            seq.submitted_at = Some(req.submitted_at);
            engine.prefill(&mut self.group, slot, seq, &req.prompt)?;
            report.prefilled += 1;
        }

        // 2. One decode step over the co-batched group.
        if self.group.active() > 0 {
            let produced = engine.step(&mut self.group)?;
            report.decoded_tokens = produced.len();
        }

        // 3. Reap completions.
        self.group.reap();
        let now = Instant::now();
        for seq in self.group.done.drain(..) {
            let sub = seq.submitted_at.unwrap_or(now);
            report.completed.push(Completion {
                id: seq.id,
                prompt_len: seq.prompt_len,
                ttft: seq
                    .first_token_at
                    .map(|t| (t - sub).as_secs_f64())
                    .unwrap_or(0.0),
                total: (now - sub).as_secs_f64(),
                prune_rounds: seq.prune_log.len(),
                finish: seq.finished.unwrap(),
                generated: seq.generated,
            });
        }
        Ok(report)
    }

    /// Drive to completion (used by benches and the eval harness).
    pub fn run_to_idle(&mut self, engine: &mut Engine) -> Result<Vec<Completion>> {
        let mut out = Vec::new();
        while !self.idle() {
            let r = self.tick(engine)?;
            out.extend(r.completed);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request {
            id,
            prompt: vec![1, 3, 4],
            max_new_tokens: 4,
            policy: PolicyKind::Lethe,
            submitted_at: Instant::now(),
        }
    }

    #[test]
    fn admission_control_rejects_when_full() {
        // Scheduler without an engine: test the queue paths only.
        let dims = crate::kvcache::CacheDims {
            layers: 1,
            batch: 2,
            kv_heads: 1,
            capacity: 8,
            d_head: 4,
        };
        let mut s = Scheduler {
            group: DecodeGroup::new(dims, PolicyKind::Lethe),
            waiting: VecDeque::new(),
            max_waiting: 2,
            eos: 2,
            n_layers: 1,
            rejected: 0,
        };
        assert!(s.submit(req(1)).is_ok());
        assert!(s.submit(req(2)).is_ok());
        assert!(s.submit(req(3)).is_err());
        assert_eq!(s.rejected, 1);
        assert_eq!(s.waiting(), 2);
        assert!(!s.idle());
    }
}
