//! A100 analytical simulator for the paper's large-model experiments
//! (Tables 2–3, Figures 4 and 6). The paper runs DeepSeek-R1-Distill
//! 7B–70B on A100-80GB GPUs; none are available here, so per DESIGN.md §4
//! the *hardware* is modelled analytically while the *policies* are the
//! real implementations from [`crate::policy`], driven over synthetic
//! attention traces ([`trace`]) to obtain retained-token trajectories.
//!
//! Memory model (per GPU):
//!   weights(arch)/tp + KV(retained tokens × bytes/token) × frag
//!     + workspace(batch)
//! `frag` models the growth/fragmentation overhead of concatenation-style
//! cache allocators (HF-style serving, which the paper's absolute numbers
//! reflect); OOM when the total exceeds 80 GB.
//!
//! Latency model (per decode step, HBM-roofline):
//!   max(bytes_moved / (BW × eff), flops / peak) + per-layer launch
//!     overhead + fixed framework overhead
//! The fixed overhead is calibrated once per model so FullKV batch-1
//! matches the paper's reported tok/s (Table 3 col 1); everything else is
//! predicted, not fitted.

pub mod replay;
pub mod trace;

use crate::model::ArchSpec;
pub use trace::{run_trace, PolicyTrace, TraceConfig};

/// A100-80GB machine constants.
#[derive(Clone, Copy, Debug)]
pub struct Machine {
    pub hbm_bytes: f64,
    pub hbm_bw: f64,
    pub hbm_eff: f64,
    pub peak_flops: f64,
    pub launch_overhead_s: f64,
}

pub const A100: Machine = Machine {
    hbm_bytes: 80e9,
    hbm_bw: 2.039e12,
    hbm_eff: 0.65,
    peak_flops: 312e12,
    launch_overhead_s: 0.25e-3,
};

/// KV fragmentation/growth factor of concatenation-style cache
/// management (see module docs).
pub const KV_FRAG: f64 = 2.0;

#[derive(Clone, Copy, Debug)]
pub struct SimPoint {
    pub batch: usize,
    /// Per-GPU generation memory in MB (KV + workspace, excluding
    /// weights — the paper's "generation memory").
    pub gen_memory_mb: f64,
    pub oom: bool,
    pub tok_per_s: f64,
    pub step_latency_s: f64,
}

pub struct Simulator {
    pub arch: &'static ArchSpec,
    pub machine: Machine,
    /// Fixed framework overhead per step, calibrated via
    /// [`Simulator::calibrate`].
    pub fixed_overhead_s: f64,
}

impl Simulator {
    pub fn new(arch: &'static ArchSpec) -> Simulator {
        Simulator { arch, machine: A100, fixed_overhead_s: 0.0 }
    }

    /// Roofline step latency for `batch` sequences at mean context `ctx`
    /// tokens per sequence (retained, not nominal).
    pub fn step_latency(&self, batch: usize, ctx: f64) -> f64 {
        let a = self.arch;
        let m = self.machine;
        let weight_bytes = a.weight_bytes_per_gpu() as f64;
        let kv_bytes =
            batch as f64 * ctx * a.kv_bytes_per_token_per_gpu() as f64;
        let bytes_t = (weight_bytes + kv_bytes) / (m.hbm_bw * m.hbm_eff);
        let flops_t = batch as f64 * a.flops_per_token(ctx as usize)
            / (a.tp as f64 * m.peak_flops);
        bytes_t.max(flops_t)
            + a.n_layers as f64 * m.launch_overhead_s
            + self.fixed_overhead_s
    }

    /// Calibrate the fixed overhead so FullKV batch-1 at `ctx` tokens
    /// reproduces `paper_tok_s` (Table 3, column 1).
    pub fn calibrate(&mut self, ctx: f64, paper_tok_s: f64) {
        self.fixed_overhead_s = 0.0;
        let model = self.step_latency(1, ctx);
        let target = 1.0 / paper_tok_s;
        self.fixed_overhead_s = (target - model).max(0.0);
    }

    /// Per-GPU generation memory (bytes) for `batch` sequences whose
    /// per-sequence retained KV averages `retained` tokens.
    pub fn gen_memory_bytes(&self, batch: usize, retained: f64) -> f64 {
        let a = self.arch;
        let kv = batch as f64
            * retained
            * a.kv_bytes_per_token_per_gpu() as f64
            * KV_FRAG;
        // Decode workspace: logits fp32 + per-layer activation buffers.
        let workspace = batch as f64
            * (a.vocab_size as f64 * 4.0 * 2.0
                + a.n_layers as f64 * a.d_model as f64 * 16.0);
        kv + workspace
    }

    pub fn is_oom(&self, batch: usize, retained: f64) -> bool {
        self.arch.weight_bytes_per_gpu() as f64
            + self.gen_memory_bytes(batch, retained)
            > self.machine.hbm_bytes
    }

    /// One (model, policy, batch) cell of Tables 2–3.
    ///
    /// `retained_mean` and `retained_final` come from a policy trace:
    /// mean retained tokens over the generation (drives latency) and
    /// retained tokens at the end (drives peak memory). For FullKV both
    /// equal prompt + generated.
    pub fn point(
        &self,
        batch: usize,
        retained_mean: f64,
        retained_final: f64,
    ) -> SimPoint {
        let oom = self.is_oom(batch, retained_final);
        let lat = self.step_latency(batch, retained_mean);
        SimPoint {
            batch,
            gen_memory_mb: self.gen_memory_bytes(batch, retained_final)
                / 1e6,
            oom,
            tok_per_s: if oom { 0.0 } else { batch as f64 / lat },
            step_latency_s: lat,
        }
    }

    /// KV share of total GPU memory at `ctx` tokens, batch 1, FullKV
    /// (Figure 6).
    pub fn kv_fraction(&self, ctx: f64) -> f64 {
        let a = self.arch;
        let kv = ctx * a.kv_bytes_per_token_per_gpu() as f64 * KV_FRAG;
        let total = a.weight_bytes_per_gpu() as f64 + kv;
        kv / total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::arch_by_name;

    #[test]
    fn calibration_reproduces_paper_batch1() {
        // Paper Table 3 FullKV batch-1 numbers.
        for (name, tok_s) in [
            ("Qwen-7B", 33.1),
            ("Qwen-32B", 15.2),
            ("Llama-8B", 30.1),
            ("Llama-70B", 8.3),
        ] {
            let mut sim = Simulator::new(arch_by_name(name).unwrap());
            sim.calibrate(2048.0, tok_s);
            let got = 1.0 / sim.step_latency(1, 2048.0);
            assert!(
                (got - tok_s).abs() / tok_s < 0.01,
                "{name}: {got} vs {tok_s}"
            );
        }
    }

    #[test]
    fn bigger_models_are_slower_before_calibration() {
        let s7 = Simulator::new(arch_by_name("Qwen-7B").unwrap());
        let s70 = Simulator::new(arch_by_name("Llama-70B").unwrap());
        assert!(
            s70.step_latency(1, 4096.0) > s7.step_latency(1, 4096.0),
            "roofline ordering violated"
        );
    }

    #[test]
    fn batching_improves_throughput_until_memory_binds() {
        let mut sim = Simulator::new(arch_by_name("Llama-8B").unwrap());
        sim.calibrate(2048.0, 30.1);
        let t1 = sim.point(1, 2048.0, 2048.0);
        let t8 = sim.point(8, 2048.0, 2048.0);
        assert!(t8.tok_per_s > 2.0 * t1.tok_per_s,
                "batch-8 {} vs batch-1 {}", t8.tok_per_s, t1.tok_per_s);
    }

    #[test]
    fn long_context_fullkv_ooms_but_pruned_does_not() {
        let sim = Simulator::new(arch_by_name("Llama-8B").unwrap());
        // 32 sequences at ~20k tokens: FullKV must OOM (Table 2 batch 32).
        assert!(sim.is_oom(32, 20_500.0));
        // Lethe-style retention (~600 tokens) survives.
        assert!(!sim.is_oom(32, 600.0));
    }

    #[test]
    fn memory_grows_linearly_with_batch_and_retention() {
        let sim = Simulator::new(arch_by_name("Qwen-7B").unwrap());
        let m1 = sim.gen_memory_bytes(1, 1000.0);
        let m2 = sim.gen_memory_bytes(2, 1000.0);
        let m1b = sim.gen_memory_bytes(1, 2000.0);
        assert!((m2 / m1 - 2.0).abs() < 0.05);
        assert!(m1b > 1.8 * m1 && m1b < 2.0 * m1 + 1e9);
    }

    #[test]
    fn kv_fraction_grows_with_context_like_paper_fig6() {
        let sim = Simulator::new(arch_by_name("Llama-8B").unwrap());
        assert!(sim.kv_fraction(2_000.0) < 0.25);
        assert!(sim.kv_fraction(30_000.0) > 0.30);
        // Monotone in context length.
        assert!(sim.kv_fraction(10_000.0) < sim.kv_fraction(20_000.0));
    }
}
