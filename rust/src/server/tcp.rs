//! TCP front-end: a newline-delimited JSON protocol over the in-process
//! [`super::Server`], so external clients can drive the engine:
//!
//!   -> {"prompt": "ab:12;cd:ab?cd>", "max_new_tokens": 32,
//!       "policy": "lethe"}
//!   <- {"ok": true, "text": "ab>12.", "finish": "Eos",
//!       "prompt_tokens": 18, "generated_tokens": 7,
//!       "ttft_s": 0.01, "total_s": 0.05, "prune_rounds": 0,
//!       "preemptions": 0, "kv_format": "f32"}
//!
//! `kv_format` reports the storage the request was served on: "f32",
//! "q8", "q4", or "mixed" when a per-layer format map
//! (`kv.layer_formats` / `kv.mixed`) was active; `preemptions` counts
//! how often the sequence was recompute-preempted under load.
//!
//! A `{"stats": true}` line returns the serving-pressure snapshot
//! instead of a completion:
//!
//!   -> {"stats": true}
//!   <- {"ok": true, "stats": {"queue_depth": 0, "active": 1,
//!       "prefilling": 0, "rejected": 0, "preemptions": 2,
//!       "resumes": 2, "kv_migrations": 4, "kv_format": "mixed",
//!       "metrics": {...}}}
//!
//! One handler thread per connection (threadpool-bounded); requests on
//! one connection are pipelined through the engine like any other
//! client's. Malformed lines get {"ok": false, "error": ...} without
//! dropping the connection.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::policy::PolicyKind;
use crate::util::json::{parse, Json};
use crate::util::threadpool::ThreadPool;

use super::{GenerateRequest, GenerateResponse, Server};

pub struct TcpFrontend {
    pub addr: std::net::SocketAddr,
    listener: TcpListener,
    server: Arc<Server>,
    pool: ThreadPool,
}

impl TcpFrontend {
    /// Bind to `addr` (use "127.0.0.1:0" for an ephemeral test port).
    pub fn bind(server: Arc<Server>, addr: &str, workers: usize)
        -> Result<TcpFrontend>
    {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding {addr}"))?;
        Ok(TcpFrontend {
            addr: listener.local_addr()?,
            listener,
            server,
            pool: ThreadPool::new(workers.max(1)),
        })
    }

    /// Accept loop; returns after serving `max_conns` connections
    /// (None = forever). Each connection is handled on the pool.
    pub fn serve(&self, max_conns: Option<usize>) -> Result<()> {
        let mut served = 0usize;
        for stream in self.listener.incoming() {
            let stream = stream?;
            let server = Arc::clone(&self.server);
            self.pool.spawn(move || {
                if let Err(e) = handle_conn(stream, &server) {
                    crate::log_debug!("connection ended: {e:#}");
                }
            });
            served += 1;
            if let Some(m) = max_conns {
                if served >= m {
                    break;
                }
            }
        }
        self.pool.wait_idle();
        Ok(())
    }
}

fn handle_conn(stream: TcpStream, server: &Server) -> Result<()> {
    let peer = stream.peer_addr()?;
    crate::log_debug!("connection from {peer}");
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match handle_line(&line, server) {
            Ok(resp) => resp,
            Err(e) => Json::obj(vec![
                ("ok", Json::from(false)),
                ("error", Json::str(&format!("{e:#}"))),
            ]),
        };
        writeln!(writer, "{reply}")?;
    }
    Ok(())
}

fn handle_line(line: &str, server: &Server) -> Result<Json> {
    let j = parse(line).context("request is not valid JSON")?;
    // Telemetry query: {"stats": true} (today `Scheduler::rejected` and
    // friends are live counters, not write-only state).
    if let Some(v) = j.opt("stats") {
        if v.as_bool()? {
            return Ok(Json::obj(vec![
                ("ok", Json::from(true)),
                ("stats", server.stats()?),
            ]));
        }
    }
    let prompt = j.get("prompt")?.as_str()?.to_string();
    let max_new_tokens = j
        .opt("max_new_tokens")
        .map(|v| v.as_usize())
        .transpose()?
        .unwrap_or(64);
    let policy = j
        .opt("policy")
        .map(|v| PolicyKind::parse(v.as_str()?))
        .transpose()?;
    let resp =
        server.generate(GenerateRequest { prompt, max_new_tokens, policy })?;
    Ok(response_json(&resp))
}

fn response_json(r: &GenerateResponse) -> Json {
    Json::obj(vec![
        ("ok", Json::from(true)),
        ("id", Json::from(r.id as usize)),
        ("text", Json::str(&r.text)),
        ("finish", Json::str(&r.finish)),
        ("prompt_tokens", Json::from(r.prompt_tokens)),
        ("generated_tokens", Json::from(r.generated_tokens)),
        ("ttft_s", Json::num(r.ttft_s)),
        ("total_s", Json::num(r.total_s)),
        ("prune_rounds", Json::from(r.prune_rounds)),
        ("preemptions", Json::from(r.preemptions as usize)),
        ("kv_format", Json::str(&r.kv_format)),
    ])
}

/// Minimal blocking client for tests/examples.
pub struct TcpClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl TcpClient {
    pub fn connect(addr: std::net::SocketAddr) -> Result<TcpClient> {
        let stream = TcpStream::connect(addr)?;
        Ok(TcpClient { writer: stream.try_clone()?, reader: BufReader::new(stream) })
    }

    pub fn request(&mut self, prompt: &str, max_new: usize,
                   policy: Option<&str>) -> Result<Json> {
        let mut obj = vec![
            ("prompt", Json::str(prompt)),
            ("max_new_tokens", Json::from(max_new)),
        ];
        if let Some(p) = policy {
            obj.push(("policy", Json::str(p)));
        }
        writeln!(self.writer, "{}", Json::obj(obj))?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        parse(&line)
    }

    /// Serving-pressure snapshot (`{"stats": true}` query).
    pub fn stats(&mut self) -> Result<Json> {
        writeln!(
            self.writer,
            "{}",
            Json::obj(vec![("stats", Json::from(true))])
        )?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        parse(&line)
    }
}
