//! Head-collapsed attention scoring (paper Eq. 2): the decode executable
//! returns per-head probabilities `[L, B, Hq, C]`; policies consume the
//! head-summed view per (layer, slot). Head-invariant treatment is
//! justified by the paper's Fig. 5 head-similarity observation and keeps
//! GQA handling trivial (Eq. 3: no key duplication anywhere).

use crate::runtime::tensors::HostTensorF32;

/// Zero-copy view over the decode `probs` output.
pub struct ProbsView<'a> {
    t: &'a HostTensorF32,
}

impl<'a> ProbsView<'a> {
    pub fn new(t: &'a HostTensorF32) -> Self {
        assert_eq!(t.shape.len(), 4, "probs must be [L,B,Hq,C]");
        ProbsView { t }
    }

    pub fn layers(&self) -> usize {
        self.t.shape[0]
    }
    pub fn batch(&self) -> usize {
        self.t.shape[1]
    }
    pub fn heads(&self) -> usize {
        self.t.shape[2]
    }
    pub fn capacity(&self) -> usize {
        self.t.shape[3]
    }

    /// One head's row for (l, b, h).
    pub fn head_row(&self, l: usize, b: usize, h: usize) -> &[f32] {
        let c = self.capacity();
        let off = ((l * self.batch() + b) * self.heads() + h) * c;
        &self.t.data[off..off + c]
    }

    /// Head-summed scores for (l, b), truncated to `n` slots (Eq. 2).
    pub fn head_sum_into(&self, l: usize, b: usize, n: usize, out: &mut Vec<f32>) {
        let n = n.min(self.capacity());
        out.clear();
        out.resize(n, 0.0);
        for h in 0..self.heads() {
            let row = self.head_row(l, b, h);
            for j in 0..n {
                out[j] += row[j];
            }
        }
    }
}

/// Convenience allocating variant.
pub fn head_sum(probs: &HostTensorF32, l: usize, b: usize, n: usize) -> Vec<f32> {
    let mut out = Vec::new();
    ProbsView::new(probs).head_sum_into(l, b, n, &mut out);
    out
}

/// Cosine similarity between two head rows (Figure 5 reproduction).
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let dot: f64 = a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum();
    let na: f64 = a.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    let nb: f64 = b.iter().map(|&y| (y as f64).powi(2)).sum::<f64>().sqrt();
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / (na * nb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn probs() -> HostTensorF32 {
        // [L=1, B=1, Hq=2, C=4]
        HostTensorF32::from_vec(
            &[1, 1, 2, 4],
            vec![0.1, 0.2, 0.3, 0.4, 0.4, 0.3, 0.2, 0.1],
        )
        .unwrap()
    }

    #[test]
    fn head_sum_collapses_heads() {
        let p = probs();
        let s = head_sum(&p, 0, 0, 4);
        for v in &s {
            assert!((v - 0.5).abs() < 1e-6);
        }
        let s2 = head_sum(&p, 0, 0, 2);
        assert_eq!(s2.len(), 2);
    }

    #[test]
    fn head_rows_are_addressed_correctly() {
        let p = probs();
        let v = ProbsView::new(&p);
        assert_eq!(v.head_row(0, 0, 0), &[0.1, 0.2, 0.3, 0.4]);
        assert_eq!(v.head_row(0, 0, 1), &[0.4, 0.3, 0.2, 0.1]);
    }

    #[test]
    fn cosine_properties() {
        let a = [1.0f32, 0.0];
        let b = [0.0f32, 1.0];
        assert!(cosine(&a, &a) > 0.999);
        assert!(cosine(&a, &b).abs() < 1e-9);
        assert_eq!(cosine(&[0.0, 0.0], &a), 0.0);
    }
}
