fn main() { println!("lethe"); }
