//! Typed failure semantics for the serving stack.
//!
//! Errors crossing the engine/scheduler/server boundary are still
//! carried by `anyhow` (so call sites and tests keep their `Result`
//! shapes), but the serving-relevant ones are now a concrete
//! [`EngineError`] placed at the *root* of the chain, recoverable with
//! `err.downcast_ref::<EngineError>()`. Two classifications matter:
//!
//!   * **retryable** — the request never entered (or never corrupted)
//!     the engine: admission backpressure ([`EngineError::Overloaded`])
//!     and shutdown drain ([`EngineError::ShuttingDown`]). Clients may
//!     resubmit verbatim, optionally after
//!     [`EngineError::retry_after_ms`].
//!   * **fatal to the request** — the sequence itself failed
//!     (allocation, runtime execute, migration, deadline). The sequence
//!     finishes with `FinishReason::Error(..)` /
//!     `FinishReason::DeadlineExceeded` and frees its slot and KV rows;
//!     the rest of the tick proceeds.
//!
//! [`FailureKind`] is the compact `Copy` payload embedded in
//! `FinishReason::Error(..)` so per-sequence finishes stay cheap to
//! copy and compare.

use std::fmt;

/// Compact classification of *why* a sequence failed, embedded in
/// `FinishReason::Error(..)`. `Copy` on purpose: finish reasons are
/// copied around the scheduler and completions freely.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailureKind {
    /// A KV-cache row allocation / insert failed (e.g. capacity
    /// overflow in the slot's arena).
    KvAlloc,
    /// The device runtime failed to execute a decode/prefill step.
    RuntimeExecute,
    /// A live per-layer format migration failed under the sequence.
    Migration,
    /// The per-slot post-decode worker panicked; the panic was caught
    /// and converted into a single-sequence failure.
    SlotPanic,
    /// A deterministic fault-injection plan tripped at this seam
    /// (testing only; see [`crate::fault::FaultPlan`]).
    Injected,
    /// The sequence's decode group was quarantined (panic, stall or
    /// sustained errors) and the sequence could not be rescued onto a
    /// healthy group.
    GroupLost,
}

impl FailureKind {
    /// Stable lower-case label (metrics / log lines).
    pub fn label(&self) -> &'static str {
        match self {
            FailureKind::KvAlloc => "kv_alloc",
            FailureKind::RuntimeExecute => "runtime_execute",
            FailureKind::Migration => "migration",
            FailureKind::SlotPanic => "slot_panic",
            FailureKind::Injected => "injected",
            FailureKind::GroupLost => "group_lost",
        }
    }
}

impl fmt::Display for FailureKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

/// The typed error taxonomy for the serving stack. Constructed at the
/// failure seams and carried through `anyhow::Error`, so boundaries
/// that care (TCP protocol, scheduler, tests) can
/// `downcast_ref::<EngineError>()` while everything else keeps plain
/// `Result<_, anyhow::Error>`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EngineError {
    /// KV row allocation / insert failed for sequence `seq`.
    KvAlloc {
        /// Id of the sequence whose allocation failed.
        seq: u64,
        /// Human-readable cause.
        detail: String,
    },
    /// The device runtime failed executing a step.
    RuntimeExecute {
        /// Human-readable cause.
        detail: String,
    },
    /// A live layer-format migration failed.
    Migration {
        /// Layer whose migration failed.
        layer: usize,
        /// Human-readable cause.
        detail: String,
    },
    /// The request's deadline elapsed before it finished.
    DeadlineExceeded {
        /// Id of the deadlined sequence.
        seq: u64,
    },
    /// Admission backpressure: the waiting queue is full. Retryable;
    /// clients should wait `retry_after_ms` before resubmitting.
    Overloaded {
        /// Suggested client backoff before resubmitting.
        retry_after_ms: u64,
        /// Queue depth observed at rejection time.
        waiting: usize,
    },
    /// The prompt exceeds the largest prefill bucket; not retryable
    /// against this deployment (the request itself is too large).
    PromptTooLong {
        /// Prompt length in tokens.
        tokens: usize,
        /// Largest admissible prompt in tokens.
        max: usize,
    },
    /// The server is draining for shutdown and admits no new work.
    /// Retryable — against another replica, or after a restart.
    ShuttingDown,
    /// No decode group is healthy enough to admit new work (all
    /// quarantined or dead). Retryable once a group restarts.
    GroupUnavailable {
        /// Suggested client backoff before resubmitting.
        retry_after_ms: u64,
    },
}

impl EngineError {
    /// True when resubmitting the identical request can succeed
    /// (backpressure and drain); false when the request or the engine
    /// state it touched is the problem.
    pub fn is_retryable(&self) -> bool {
        matches!(
            self,
            EngineError::Overloaded { .. }
                | EngineError::ShuttingDown
                | EngineError::GroupUnavailable { .. }
        )
    }

    /// Suggested client backoff, when the error carries one.
    pub fn retry_after_ms(&self) -> Option<u64> {
        match self {
            EngineError::Overloaded { retry_after_ms, .. }
            | EngineError::GroupUnavailable { retry_after_ms } => {
                Some(*retry_after_ms)
            }
            _ => None,
        }
    }

    /// The per-sequence [`FailureKind`] this error maps to, for the
    /// variants that fail a *running* sequence (admission-time errors
    /// return `None` — no sequence ever existed).
    pub fn failure_kind(&self) -> Option<FailureKind> {
        match self {
            EngineError::KvAlloc { .. } => Some(FailureKind::KvAlloc),
            EngineError::RuntimeExecute { .. } => {
                Some(FailureKind::RuntimeExecute)
            }
            EngineError::Migration { .. } => Some(FailureKind::Migration),
            _ => None,
        }
    }
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EngineError::KvAlloc { seq, detail } => {
                write!(f, "kv allocation failed for seq {seq}: {detail}")
            }
            EngineError::RuntimeExecute { detail } => {
                write!(f, "runtime execute failed: {detail}")
            }
            EngineError::Migration { layer, detail } => {
                write!(f, "format migration failed at layer {layer}: {detail}")
            }
            EngineError::DeadlineExceeded { seq } => {
                write!(f, "seq {seq} exceeded its deadline")
            }
            EngineError::Overloaded { retry_after_ms, waiting } => write!(
                f,
                "overloaded: queue full ({waiting} waiting), retry after \
                 {retry_after_ms} ms"
            ),
            EngineError::PromptTooLong { tokens, max } => write!(
                f,
                "prompt of {tokens} tokens exceeds the largest prefill \
                 bucket {max}"
            ),
            EngineError::ShuttingDown => {
                f.write_str("server is draining for shutdown")
            }
            EngineError::GroupUnavailable { retry_after_ms } => write!(
                f,
                "no healthy decode group available, retry after \
                 {retry_after_ms} ms"
            ),
        }
    }
}

impl std::error::Error for EngineError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retryable_classification() {
        assert!(EngineError::Overloaded { retry_after_ms: 50, waiting: 8 }
            .is_retryable());
        assert!(EngineError::ShuttingDown.is_retryable());
        assert!(!EngineError::PromptTooLong { tokens: 999, max: 192 }
            .is_retryable());
        assert!(!EngineError::KvAlloc { seq: 1, detail: "full".into() }
            .is_retryable());
        assert!(
            !EngineError::RuntimeExecute { detail: "pjrt".into() }
                .is_retryable()
        );
        assert!(!EngineError::DeadlineExceeded { seq: 3 }.is_retryable());
        assert!(EngineError::GroupUnavailable { retry_after_ms: 40 }
            .is_retryable());
    }

    #[test]
    fn retry_after_only_on_overload() {
        let e = EngineError::Overloaded { retry_after_ms: 75, waiting: 2 };
        assert_eq!(e.retry_after_ms(), Some(75));
        assert_eq!(EngineError::ShuttingDown.retry_after_ms(), None);
        let e = EngineError::GroupUnavailable { retry_after_ms: 30 };
        assert_eq!(e.retry_after_ms(), Some(30));
    }

    #[test]
    fn failure_kind_mapping() {
        let e = EngineError::KvAlloc { seq: 0, detail: String::new() };
        assert_eq!(e.failure_kind(), Some(FailureKind::KvAlloc));
        let e = EngineError::Migration { layer: 3, detail: String::new() };
        assert_eq!(e.failure_kind(), Some(FailureKind::Migration));
        assert_eq!(EngineError::ShuttingDown.failure_kind(), None);
    }

    #[test]
    fn survives_an_anyhow_round_trip() {
        let e: anyhow::Error =
            EngineError::Overloaded { retry_after_ms: 10, waiting: 1 }.into();
        let back = e.downcast_ref::<EngineError>().expect("downcasts");
        assert!(back.is_retryable());
        assert_eq!(back.retry_after_ms(), Some(10));
    }

    #[test]
    fn display_is_informative() {
        let e = EngineError::PromptTooLong { tokens: 300, max: 192 };
        let s = e.to_string();
        assert!(s.contains("300") && s.contains("192"), "{s}");
        assert_eq!(FailureKind::SlotPanic.to_string(), "slot_panic");
        assert_eq!(FailureKind::GroupLost.to_string(), "group_lost");
    }
}
