fn main() {}
