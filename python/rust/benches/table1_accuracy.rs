fn main() {}
