//! Sequence-lifecycle integration over the real runtime: chunked
//! prefill interleaving with decode, and recompute-preemption /resume
//! determinism. Skipped (with a notice) when artifacts are not built —
//! the pure-Rust lifecycle paths are unit-tested in
//! `src/scheduler/mod.rs`.

use std::path::Path;
use std::time::{Duration, Instant};

use lethe::bench_support::sum_group_rows;
use lethe::config::ServingConfig;
use lethe::engine::{Engine, FinishReason};
use lethe::model::Tokenizer;
use lethe::policy::PolicyKind;
use lethe::runtime::Runtime;
use lethe::scheduler::{Completion, Request, Scheduler};
use lethe::server::{GenerateRequest, Server};
use lethe::util::prng::Rng;
use lethe::workload::make_task;

fn engine_or_skip(cfg: ServingConfig) -> Option<(Engine, Tokenizer)> {
    let dir = Path::new("artifacts");
    if !dir.join("model_meta.json").exists() {
        eprintln!("[skip] run `make artifacts` first");
        return None;
    }
    let rt = Runtime::load(dir).expect("runtime loads");
    let tok = Tokenizer::from_meta(&rt.meta).unwrap();
    Some((Engine::new(rt, cfg).unwrap(), tok))
}

fn req(id: u64, prompt: Vec<i32>, max_new: usize, policy: PolicyKind) -> Request {
    Request {
        id,
        prompt,
        max_new_tokens: max_new,
        policy,
        submitted_at: Instant::now(),
        deadline_ms: None,
        class: String::new(),
    }
}

/// Run one request alone to completion (no budget pressure).
fn solo_run(
    engine: &mut Engine,
    prompt: Vec<i32>,
    max_new: usize,
    policy: PolicyKind,
) -> Completion {
    let mut sched = Scheduler::new(engine, policy);
    sched.submit(req(0, prompt, max_new, policy)).unwrap();
    let mut done = sched.run_to_idle(engine).unwrap();
    assert_eq!(done.len(), 1);
    done.pop().unwrap()
}

/// (a) A short request keeps decoding — and its TTFT stays bounded —
/// while a long prompt prefills chunk-wise in the same group.
#[test]
fn chunked_prefill_interleaves_decode_with_long_prompt() {
    const CHUNK: usize = 24;
    let mut cfg = ServingConfig::default();
    cfg.scheduler.max_batch = 2;
    cfg.scheduler.prefill_chunk = CHUNK;
    let Some((mut engine, tok)) = engine_or_skip(cfg) else { return };

    // Prompts are 6·n_pairs + 3 chars (+1 BOS token): 2 pairs ≈ 16
    // tokens (one chunk), 24 pairs ≈ 148 tokens (several chunks).
    let short = tok
        .encode_prompt(&make_task(&mut Rng::new(1), 2, 1).prompt)
        .unwrap();
    let long = tok
        .encode_prompt(&make_task(&mut Rng::new(2), 24, 4).prompt)
        .unwrap();
    assert!(short.len() <= CHUNK, "short prompt must fit one chunk");
    assert!(long.len() > 3 * CHUNK, "long prompt must span several chunks");

    let mut sched = Scheduler::new(&engine, PolicyKind::Lethe);
    sched.submit(req(0, short, 24, PolicyKind::Lethe)).unwrap();
    sched.submit(req(1, long.clone(), 8, PolicyKind::Lethe)).unwrap();

    // Tick 1: both enter the prefill lane; the short one (one chunk)
    // installs and takes its first decode step this very tick — its
    // TTFT is one tick, not one-long-prefill.
    let mut all_done = Vec::new();
    let r = sched.tick(&mut engine).unwrap();
    assert_eq!(r.prefilled, 1, "short prompt installs on tick 1");
    assert_eq!(sched.prefilling(), 1, "long prompt still prefilling");
    let short_done_t1 = r.completed.iter().any(|c| c.id == 0);
    all_done.extend(r.completed);

    // While the long prompt chunks through, the short sequence's decode
    // steps keep landing in the same ticks.
    let mut interleaved = 0;
    let mut ticks = 0;
    while sched.prefilling() > 0 && ticks < 64 {
        let r = sched.tick(&mut engine).unwrap();
        if r.prefill_chunks > 0 && r.decoded_tokens > 0 {
            interleaved += 1;
        }
        ticks += 1;
        all_done.extend(r.completed);
    }
    assert!(
        interleaved > 0 || short_done_t1,
        "no decode landed during the long prompt's chunked prefill"
    );
    // The long prefill really was chunked: one bucketed run per tick,
    // so it spans exactly its chunk count after the short one's install.
    let chunks = long.len().div_ceil(CHUNK);
    assert!(
        (2..=chunks + 2).contains(&ticks),
        "long prefill took {ticks} ticks for {chunks} chunks"
    );

    all_done.extend(sched.run_to_idle(&mut engine).unwrap());
    let mut ids: Vec<u64> = all_done.iter().map(|c| c.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![0, 1], "both requests complete");
    for c in &all_done {
        assert_ne!(c.finish, FinishReason::Oom);
    }
}

/// (b) A preempted-then-resumed sequence reproduces exactly the tokens
/// of an uncontended run: the resume prefill recomputes prompt +
/// generated, and greedy decode is deterministic.
#[test]
fn preempted_sequence_resumes_with_identical_tokens() {
    let mut cfg = ServingConfig::default();
    cfg.scheduler.max_batch = 2;
    let Some((mut engine, tok)) = engine_or_skip(cfg) else { return };

    // Pick two tasks whose solo runs are long enough that the pair
    // overlaps for several decode steps (selection is deterministic
    // given the artifacts).
    let mut picked = None;
    for seed in 0..24 {
        let ta = make_task(&mut Rng::new(seed), 8, 2);
        let tb = make_task(&mut Rng::new(seed + 100), 8, 2);
        let pa = tok.encode_prompt(&ta.prompt).unwrap();
        let pb = tok.encode_prompt(&tb.prompt).unwrap();
        if pa.len() > 64 || pb.len() > 64 {
            continue;
        }
        let ca = solo_run(&mut engine, pa.clone(), 40, PolicyKind::FullKv);
        let cb = solo_run(&mut engine, pb.clone(), 16, PolicyKind::FullKv);
        if ca.generated.len() >= 6 && cb.generated.len() >= 4 {
            picked = Some((pa, pb, ca, cb));
            break;
        }
    }
    let Some((pa, pb, solo_a, solo_b)) = picked else {
        eprintln!("[skip] no task pair with long enough solo runs");
        return;
    };

    // Contended run: a KV byte budget that fits both prompts but not
    // their growth, so the younger sequence (B) gets recompute-
    // preempted and later resumed. Swap is explicitly disabled — this
    // test pins the recompute path; (c) below pins the swap path.
    engine.cfg.scheduler.swap_threshold_bytes_per_token = 0;
    engine.cfg.scheduler.kv_budget_bytes =
        (pa.len() + pb.len() + 1) * engine.rt.meta.kv_bytes_per_token();
    let mut sched = Scheduler::new(&engine, PolicyKind::FullKv);
    sched.submit(req(0, pa, 40, PolicyKind::FullKv)).unwrap();
    sched.submit(req(1, pb, 16, PolicyKind::FullKv)).unwrap();
    let done = sched.run_to_idle(&mut engine).unwrap();

    assert!(sched.preemptions >= 1, "budget never forced a preemption");
    assert_eq!(sched.resumes, sched.preemptions);
    assert_eq!(done.len(), 2);
    for c in &done {
        assert_ne!(
            c.finish,
            FinishReason::Oom,
            "co-residency pressure must preempt, not OOM-kill"
        );
    }
    let a = done.iter().find(|c| c.id == 0).unwrap();
    let b = done.iter().find(|c| c.id == 1).unwrap();
    assert!(b.preemptions >= 1, "the younger sequence is the victim");
    assert_eq!(
        b.generated, solo_b.generated,
        "resumed sequence diverged from its uncontended run"
    );
    assert_eq!(a.preemptions, 0, "the older sequence keeps its slot");
    assert_eq!(
        a.generated, solo_a.generated,
        "unpreempted sequence diverged from its uncontended run"
    );
    // Telemetry made it into the engine metrics.
    assert!(engine.metrics.preemptions >= 1);
    assert_eq!(engine.metrics.resumes, engine.metrics.preemptions);
}

/// (c) Swap-to-host preemption is token-identical too: with the swap
/// threshold forced on, the victim's live KV rows are serialized to a
/// host buffer at stored precision and restored verbatim on resume —
/// no recompute — and greedy decode continues exactly as in the
/// uncontended run.
#[test]
fn swap_preempted_sequence_resumes_with_identical_tokens() {
    let mut cfg = ServingConfig::default();
    cfg.scheduler.max_batch = 2;
    // An unbeatable threshold: every preemption takes the swap path.
    cfg.scheduler.swap_threshold_bytes_per_token = usize::MAX;
    let Some((mut engine, tok)) = engine_or_skip(cfg) else { return };

    let mut picked = None;
    for seed in 0..24 {
        let ta = make_task(&mut Rng::new(seed), 8, 2);
        let tb = make_task(&mut Rng::new(seed + 100), 8, 2);
        let pa = tok.encode_prompt(&ta.prompt).unwrap();
        let pb = tok.encode_prompt(&tb.prompt).unwrap();
        if pa.len() > 64 || pb.len() > 64 {
            continue;
        }
        let ca = solo_run(&mut engine, pa.clone(), 40, PolicyKind::FullKv);
        let cb = solo_run(&mut engine, pb.clone(), 16, PolicyKind::FullKv);
        if ca.generated.len() >= 6 && cb.generated.len() >= 4 {
            picked = Some((pa, pb, ca, cb));
            break;
        }
    }
    let Some((pa, pb, solo_a, solo_b)) = picked else {
        eprintln!("[skip] no task pair with long enough solo runs");
        return;
    };

    engine.cfg.scheduler.kv_budget_bytes =
        (pa.len() + pb.len() + 1) * engine.rt.meta.kv_bytes_per_token();
    let mut sched = Scheduler::new(&engine, PolicyKind::FullKv);
    sched.submit(req(0, pa, 40, PolicyKind::FullKv)).unwrap();
    sched.submit(req(1, pb, 16, PolicyKind::FullKv)).unwrap();
    let done = sched.run_to_idle(&mut engine).unwrap();

    // The pressure was handled by the swap path, not recompute.
    assert!(sched.preemptions >= 1, "budget never forced a preemption");
    assert_eq!(
        sched.swap_preemptions, sched.preemptions,
        "the forced threshold must route every preemption through swap"
    );
    assert_eq!(sched.resumes, sched.preemptions);
    assert!(sched.swap_bytes_out > 0, "no KV payload was swapped out");
    assert_eq!(
        sched.swap_bytes_in, sched.swap_bytes_out,
        "restore must bring back exactly the bytes swapped out"
    );

    assert_eq!(done.len(), 2);
    for c in &done {
        assert_ne!(c.finish, FinishReason::Oom);
    }
    let a = done.iter().find(|c| c.id == 0).unwrap();
    let b = done.iter().find(|c| c.id == 1).unwrap();
    assert!(b.preemptions >= 1, "the younger sequence is the victim");
    assert_eq!(
        b.generated, solo_b.generated,
        "swap-resumed sequence diverged from its uncontended run"
    );
    assert_eq!(
        a.generated, solo_a.generated,
        "unpreempted sequence diverged from its uncontended run"
    );
    // Telemetry made it into the engine metrics.
    assert!(engine.metrics.swap_preemptions >= 1);
    assert_eq!(engine.metrics.swap_bytes_in, engine.metrics.swap_bytes_out);
}

/// (d) Kernel-side dequant: decoding straight from a [`PackedScratch`]
/// (codes + scales on the wire, dequantized on-device) must agree with
/// the host-dequant f32 upload path. Both read the same stored codes,
/// so the residual gap is kernel float-order noise — it must land far
/// inside the backend's quantization error bound for the same rows.
#[test]
fn kernel_dequant_decode_matches_host_dequant_path() {
    use lethe::kvcache::quant::dequant_error_bound;
    use lethe::kvcache::{CacheDims, GroupCache, KvFormat, PackedScratch};
    use lethe::runtime::tensors::{HostTensorF32, HostTensorI32};
    use lethe::util::proptest::vec_f32;

    let Some((engine, _tok)) = engine_or_skip(ServingConfig::default())
    else {
        return;
    };
    let rt = &engine.rt;
    let mut found = None;
    'probe: for bb in [1usize, 2, 3, 4, 6, 8] {
        for cap in [32usize, 48, 64, 96, 128, 160, 192, 256, 384, 512] {
            if rt.has_executable(&format!("decode_b{bb}_c{cap}"))
                && rt.has_executable(&format!("decode_b{bb}_c{cap}_q8"))
                && rt.has_executable(&format!("decode_b{bb}_c{cap}_q4"))
            {
                found = Some((bb, cap));
                break 'probe;
            }
        }
    }
    let Some((bb, cap)) = found else {
        eprintln!("[skip] artifact set has no packed decode variants");
        return;
    };

    let d = rt.meta.dims.clone();
    let cd = CacheDims {
        layers: d.n_layers,
        batch: bb,
        kv_heads: d.n_kv_heads,
        capacity: cap,
        d_head: d.d_head,
    };
    let mut rng = Rng::new(7);
    for fmt in [KvFormat::QuantI8, KvFormat::QuantI4] {
        let mut cache = GroupCache::with_format(cd, fmt);
        for b in 0..bb {
            let len = 3 + (b * 5) % 9;
            for t in 0..len {
                for l in 0..d.n_layers {
                    let kr =
                        vec_f32(&mut rng, d.n_kv_heads * d.d_head, -1.0, 1.0);
                    let vr =
                        vec_f32(&mut rng, d.n_kv_heads * d.d_head, -1.0, 1.0);
                    cache.insert(l, b, &kr, &vr, t as i32).unwrap();
                }
            }
        }

        // The fallback path's operands: host-dequantized f32 image.
        let shape = [d.n_layers, bb, d.n_kv_heads, cap, d.d_head];
        let mut k = HostTensorF32::zeros(&shape);
        let mut v = HostTensorF32::zeros(&shape);
        let mut lens = HostTensorI32::zeros(&[d.n_layers, bb]);
        cache.pack(bb, cap, &mut k, &mut v, &mut lens).unwrap();
        // The packed path's operands: the stores' wire bytes.
        let mut ps = PackedScratch::new(&cd, bb, cap, fmt);
        cache.pack_delta_packed(&mut ps).unwrap();
        assert_eq!(ps.lens.data, lens.data, "packed lens diverged");

        let vocab = d.vocab_size as i32;
        let tokens: Vec<i32> = (0..bb as i32).map(|b| (b + 1) % vocab).collect();
        let positions: Vec<i32> =
            (0..bb).map(|b| lens.data[b]).collect();
        let base = rt
            .decode(bb, cap, &k, &v, &lens, &tokens, &positions)
            .unwrap();
        let packed =
            rt.decode_packed(bb, cap, &ps, &tokens, &positions).unwrap();

        // Tolerance: the largest per-row quantization bound across the
        // image — a ceiling orders of magnitude above float noise.
        let bound = k
            .data
            .chunks(d.d_head)
            .chain(v.data.chunks(d.d_head))
            .map(|row| dequant_error_bound(fmt, row))
            .fold(1e-5f32, f32::max);
        let worst = base
            .logits
            .data
            .iter()
            .zip(&packed.logits.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(
            worst <= bound,
            "{}: packed-decode logit gap {worst} exceeds bound {bound}",
            fmt.label()
        );
        // The appended K/V rows feed the cache on the next step: they
        // must match too, or the paths drift over a generation.
        for (out_b, out_p) in [
            (&base.k_new, &packed.k_new),
            (&base.v_new, &packed.v_new),
        ] {
            let w = out_b
                .data
                .iter()
                .zip(&out_p.data)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(
                w <= bound,
                "{}: packed-decode k/v_new gap {w} exceeds bound {bound}",
                fmt.label()
            );
        }
    }
}

/// (e) Incremental chunked prefill is token-identical to the recompute
/// baseline, and pushes O(n) rather than O(n²/chunk) tokens through
/// the prefill executables.
#[test]
fn incremental_prefill_is_token_identical_and_linear() {
    const CHUNK: usize = 24;
    let mut cfg = ServingConfig::default();
    cfg.scheduler.max_batch = 2;
    cfg.scheduler.prefill_chunk = CHUNK;
    cfg.scheduler.incremental_prefill = false;
    let Some((mut engine, tok)) = engine_or_skip(cfg) else { return };
    if !engine.supports_incremental_prefill() {
        eprintln!("[skip] artifact set has no prefill_t*_kv variants");
        return;
    }

    let long = tok
        .encode_prompt(&make_task(&mut Rng::new(2), 24, 4).prompt)
        .unwrap();
    assert!(long.len() > 3 * CHUNK, "prompt must span several chunks");

    // Recompute baseline: each chunk re-prefills the grown prefix.
    engine.metrics.reset();
    let base = solo_run(&mut engine, long.clone(), 16, PolicyKind::Lethe);
    let base_tokens = engine.metrics.prefill_tokens;

    // Incremental path: each chunk feeds the accumulated prior KV.
    engine.cfg.scheduler.incremental_prefill = true;
    engine.metrics.reset();
    let inc = solo_run(&mut engine, long, 16, PolicyKind::Lethe);
    let inc_tokens = engine.metrics.prefill_tokens;

    assert_eq!(
        inc.generated, base.generated,
        "incremental prefill diverged from whole-prefix prefill"
    );
    assert!(
        inc_tokens < base_tokens,
        "incremental path must push fewer tokens through the prefill \
         executables ({inc_tokens} vs {base_tokens})"
    );
}

/// (f) Cross-group rescue is token-identical: a request in flight on a
/// decode group that gets quarantined is rescued onto the healthy peer
/// and finishes with exactly the text of an uncontended run (rescue
/// replays the same tokens; greedy decode is deterministic). The
/// quarantined group then restarts with backoff and returns to
/// `healthy` without disturbing the peer.
#[test]
fn rescued_sequence_continues_token_identically_across_groups() {
    // Uncontended baseline on a plain single engine.
    let Some((mut engine, tok)) = engine_or_skip(ServingConfig::default())
    else {
        return;
    };
    let mut picked = None;
    for seed in 0..24 {
        let t = make_task(&mut Rng::new(seed), 8, 2);
        let p = tok.encode_prompt(&t.prompt).unwrap();
        if p.len() > 64 {
            continue;
        }
        let c = solo_run(&mut engine, p.clone(), 40, PolicyKind::FullKv);
        if c.generated.len() >= 6 {
            picked = Some((t, c));
            break;
        }
    }
    let Some((task, solo)) = picked else {
        eprintln!("[skip] no task with a long enough solo run");
        return;
    };
    let solo_text = tok.decode(&solo.generated);
    drop(engine);

    // Two supervised groups. A small prefill chunk stretches the
    // request across many ticks so the quarantine lands mid-flight
    // (any interleaving is safe: the supervisor shadow-resubmits work
    // its worker could not export).
    let mut cfg = ServingConfig::default();
    cfg.scheduler.prefill_chunk = 8;
    cfg.serving.groups = 2;
    cfg.serving.restart_backoff_ms = 50;
    let server = Server::start(cfg, PolicyKind::FullKv).unwrap();

    // Placement at idle is deterministic: equal headroom and zero
    // assigned requests tie-break to the lowest id, so the request
    // lands on group 0 — which we immediately fence.
    let rx = server
        .submit(GenerateRequest {
            prompt: task.prompt.clone(),
            max_new_tokens: 40,
            policy: None,
            deadline_ms: None,
            class: None,
        })
        .unwrap();
    assert!(
        server.quarantine_group(0).unwrap(),
        "group 0 must be serving when the quarantine lands"
    );

    let resp = rx
        .recv_timeout(Duration::from_secs(180))
        .expect("rescued request never completed")
        .expect("rescued request failed");
    assert_eq!(
        resp.text, solo_text,
        "rescued run diverged from the uncontended run"
    );
    assert_eq!(resp.generated_tokens, solo.generated.len());

    // The rescue is visible in the supervision counters, and the
    // per-group rows balance against them.
    let stats = server.stats().unwrap();
    let m = stats.get("metrics").unwrap();
    let mg = |k: &str| m.get(k).unwrap().as_usize().unwrap() as u64;
    assert!(mg("rescued_seqs") >= 1, "no rescue was counted");
    assert!(mg("group_quarantines") >= 1, "no quarantine was counted");
    let sums = sum_group_rows(&stats).unwrap();
    assert_eq!(sums.rescues, mg("rescued_seqs"));
    assert_eq!(sums.completions, 1, "exactly one completion delivered");
    let rows = stats.get("groups").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 2);
    assert!(
        rows[0].get("rescues").unwrap().as_usize().unwrap() >= 1,
        "the rescue must be charged to the fenced group"
    );

    // The fenced group restarts with backoff and reports healthy again;
    // the peer was never disturbed.
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let s = server.stats().unwrap();
        let row = &s.get("groups").unwrap().as_arr().unwrap()[0];
        let health = row.get("health").unwrap().as_str().unwrap().to_string();
        if health == "healthy"
            && row.get("restarts").unwrap().as_usize().unwrap() >= 1
        {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "group 0 never restarted (health {health})"
        );
        std::thread::sleep(Duration::from_millis(25));
    }
    let resp2 = server
        .generate(GenerateRequest {
            prompt: task.prompt.clone(),
            max_new_tokens: 40,
            policy: None,
            deadline_ms: None,
            class: None,
        })
        .expect("serving continues after the restart");
    assert_eq!(
        resp2.text, solo_text,
        "post-restart serving diverged from the uncontended run"
    );
}
