//! Sequence-lifecycle scheduler (the vLLM-style serving loop, sized for
//! one PJRT CPU device). Every sequence moves through an explicit state
//! machine ([`crate::engine::SeqPhase`]):
//!
//! ```text
//! Waiting ──► Prefilling{consumed} ──► Decoding ──► Finished
//!    ▲                                    │
//!    └────────────── Preempted ◄──────────┘
//! ```
//!
//! * **Chunked prefill** — prompts are consumed `prefill_chunk` tokens
//!   per tick (one bucketed executable run). With an artifact set that
//!   carries the `prefill_t{T}_kv` variants (and
//!   `scheduler.incremental_prefill` on), each chunk attends over the
//!   accumulated prior KV ([`Engine::prefill_chunk`]) — O(prompt)
//!   total work; otherwise each chunk recomputes the growing prefix
//!   from position 0 ([`Engine::prefill_window`]) and only the final
//!   chunk's outputs are installed. Either way a long prompt
//!   interleaves with decode steps instead of stalling every
//!   co-batched decoder, and prefilling sequences round-robin so short
//!   prompts are never stuck behind a long one.
//! * **Recompute-preemption** — when the group's live KV bytes exceed
//!   `scheduler.kv_budget_bytes`, the *youngest* resumable sequence is
//!   evicted back to the waiting queue; on resume its prompt plus
//!   everything it had generated is re-prefilled, which reconstructs
//!   exactly the uncontended decode state (greedy decode is
//!   deterministic). [`crate::engine::FinishReason::Oom`] stays
//!   reserved for sequences whose own cache exceeds the largest
//!   compiled capacity — they would not fit even alone.
//! * **Live format migration** — between ticks the scheduler diffs the
//!   engine's resolved per-layer format map (`kv.format` /
//!   `kv.layer_formats` / `kv.mixed` against the live sparsity EMA)
//!   with the group's and, after `migrate_patience` consecutive
//!   differing ticks, rewrites the changed layers in place via
//!   [`crate::kvcache::GroupCache::migrate_layer_format`] — no idle
//!   window or group rebuild required.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::engine::{
    DecodeGroup, Engine, FinishReason, PrefillAcc, SeqPhase, SeqState,
};
use crate::error::{EngineError, FailureKind};
use crate::fault::FaultSite;
use crate::kvcache::HostSlotImage;
use crate::runtime::registry::PrefillOut;
use crate::policy::{make_policy, PolicyKind};
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub policy: PolicyKind,
    pub submitted_at: Instant,
    /// Wall-clock budget from submission; past it the request finishes
    /// with [`FinishReason::DeadlineExceeded`] at the next tick
    /// boundary, wherever it is in the lifecycle. `None` = no deadline.
    pub deadline_ms: Option<u64>,
    /// Tenant-class label for per-class SLO accounting (latency
    /// percentiles, attainment, preemption fairness in
    /// [`crate::metrics::EngineMetrics`]); empty = unclassified.
    pub class: String,
}

impl Request {
    /// Absolute deadline instant, anchored at submission time.
    fn deadline(&self) -> Option<Instant> {
        self.deadline_ms
            .map(|ms| self.submitted_at + Duration::from_millis(ms))
    }
}

#[derive(Debug)]
pub struct Completion {
    pub id: u64,
    pub generated: Vec<i32>,
    pub finish: crate::engine::FinishReason,
    pub prompt_len: usize,
    /// Seconds from submission to first token (TTFT).
    pub ttft: f64,
    /// Seconds per output token after the first (TPOT):
    /// `(finish − first token) / (generated − 1)`; 0 for fewer than
    /// two tokens. Finish is the terminal-event instant
    /// (`SeqState::finished_at`), not the reaping tick boundary.
    pub tpot: f64,
    /// Seconds from submission to completion (terminal event, token
    /// granularity).
    pub total: f64,
    pub prune_rounds: usize,
    /// How many times the sequence was preempted and resumed.
    pub preemptions: u32,
    /// Tenant-class label carried from the request (empty =
    /// unclassified).
    pub class: String,
}

/// Outcome of one scheduler tick.
#[derive(Debug, Default)]
pub struct TickReport {
    /// Sequences whose prefill completed (entered Decoding) this tick.
    pub prefilled: usize,
    /// Prefill chunks advanced this tick (0 or 1: one bucketed
    /// executable run per tick keeps the stall bounded).
    pub prefill_chunks: usize,
    /// Sequences recompute-preempted back to the waiting queue.
    pub preempted: usize,
    /// Layer formats migrated in place on the live group.
    pub migrated: usize,
    pub decoded_tokens: usize,
    pub completed: Vec<Completion>,
}

/// A queued unit of work: a fresh request, or a preempted sequence
/// waiting to resume (its recompute prefix travels with it).
enum WaitEntry {
    Fresh(Request),
    Resume {
        /// Original prompt + generated-so-far: the resume prefill input.
        tokens: Vec<i32>,
        seq: SeqState,
    },
    /// A swap-preempted sequence: its live KV rows travel with it as a
    /// host-side image (stored precision), so resume restores the cache
    /// instead of re-prefilling. Boxed: the image holds the slot's full
    /// row payload and the queue must stay cheap to rotate.
    Swapped {
        image: Box<HostSlotImage>,
        seq: SeqState,
    },
}

impl WaitEntry {
    /// Rows the entry would install on admission (byte projection).
    fn token_count(&self) -> usize {
        match self {
            WaitEntry::Fresh(r) => r.prompt.len(),
            WaitEntry::Resume { tokens, .. } => tokens.len(),
            WaitEntry::Swapped { image, .. } => image.max_rows(),
        }
    }
}

/// A unit of work exported from a quarantined scheduler for rescue onto
/// a healthy peer ([`Scheduler::export_for_rescue`] →
/// [`Scheduler::admit_rescued`]). Mirrors the internal queue entries:
/// fresh requests transfer verbatim, mid-prefill and recompute-resume
/// work carries its re-prefill prefix, and active decoders travel as
/// host-side KV images so the continuation restores bit-exactly (the
/// receiving group falls back to recompute when its layer formats have
/// since diverged — still token-identical under greedy decode).
pub enum RescueEntry {
    /// A request that had not started prefilling.
    Fresh(Request),
    /// A sequence that resumes by re-prefilling `tokens`
    /// (prompt + generated so far).
    Resume {
        /// The resume prefill input.
        tokens: Vec<i32>,
        /// The sequence's carried state.
        seq: SeqState,
    },
    /// An active decoder exported at stored precision.
    Swapped {
        /// Host-side image of the sequence's live KV rows.
        image: Box<HostSlotImage>,
        /// The sequence's carried state.
        seq: SeqState,
    },
}

impl RescueEntry {
    /// Request id the entry belongs to.
    pub fn id(&self) -> u64 {
        match self {
            RescueEntry::Fresh(r) => r.id,
            RescueEntry::Resume { seq, .. }
            | RescueEntry::Swapped { seq, .. } => seq.id,
        }
    }

    /// Host bytes the entry carries (non-zero only for swapped images);
    /// feeds the supervisor's `rescue_bytes` counter.
    pub fn payload_bytes(&self) -> usize {
        match self {
            RescueEntry::Swapped { image, .. } => image.payload_bytes(),
            _ => 0,
        }
    }
}

/// One chunk-wise prefill in flight. Holds a slot reservation (jobs +
/// active decoders never exceed the group size) but no cache rows until
/// the final chunk installs.
struct PrefillJob {
    tokens: Vec<i32>,
    consumed: usize,
    seq: SeqState,
    resume: bool,
    /// Incremental-prefill accumulator (prior KV + running scores)
    /// carried between ticks; `None` before the first chunk, and always
    /// `None` on the recompute path.
    acc: Option<PrefillAcc>,
}

pub struct Scheduler {
    pub group: DecodeGroup,
    waiting: VecDeque<WaitEntry>,
    prefilling: Vec<PrefillJob>,
    /// Round-robin cursor over `prefilling`.
    rr: usize,
    max_waiting: usize,
    prefill_chunk: usize,
    /// Group-wide live-KV byte budget; 0 = unlimited.
    kv_budget: usize,
    migrate_patience: usize,
    migrate_streak: usize,
    /// Serve chunked prefills through the incremental `prefill_t{T}_kv`
    /// executables (config `scheduler.incremental_prefill` ∧ the
    /// artifact set carries the variants). Off = whole-prefix recompute
    /// per chunk.
    incremental: bool,
    /// Longest admissible prompt (largest compiled prefill bucket).
    max_prompt_tokens: usize,
    /// Longest resumable prefix (prefill bucket ∩ decode capacity).
    max_resume_tokens: usize,
    eos: i32,
    n_layers: usize,
    next_stamp: u64,
    /// Swap-vs-recompute cost knob (`scheduler.
    /// swap_threshold_bytes_per_token`): a victim is swapped to host
    /// when its live bytes ≤ resume-tokens × this threshold, i.e. when
    /// moving its cache costs less than the configured per-token
    /// recompute price. 0 disables swapping (always recompute).
    swap_threshold: usize,
    /// Bounded drain window after [`Scheduler::begin_drain`].
    drain_window_ms: u64,
    /// Shutting down: admit nothing, finish (or deadline-out) in-flight.
    draining: bool,
    /// When the drain window closes; set by [`Scheduler::begin_drain`].
    drain_deadline: Option<Instant>,
    pub rejected: u64,
    pub preemptions: u64,
    pub resumes: u64,
    /// Layer formats migrated in place over the scheduler's lifetime.
    pub migrations: u64,
    /// Preemptions that swapped the victim's KV to host (subset of
    /// `preemptions`; the rest were recompute-preemptions).
    pub swap_preemptions: u64,
    /// Bytes serialized to host by swap-preemptions.
    pub swap_bytes_out: u64,
    /// Bytes restored from host on swap resumes.
    pub swap_bytes_in: u64,
    /// Sequences finished by their own request deadline.
    pub deadline_aborts: u64,
    /// Sequences finished because the shutdown drain window closed.
    pub drain_aborts: u64,
    /// EMA of recent tick wall time (ms); drives the adaptive
    /// [`EngineError::Overloaded`] backoff hint. 0 until the first tick.
    tick_ms_ema: f64,
}

impl Scheduler {
    pub fn new(engine: &Engine, policy: PolicyKind) -> Scheduler {
        let group_size = engine.cfg.scheduler.max_batch;
        let sc = &engine.cfg.scheduler;
        Scheduler {
            group: engine.new_group(group_size, policy),
            waiting: VecDeque::new(),
            prefilling: Vec::new(),
            rr: 0,
            max_waiting: sc.max_waiting,
            prefill_chunk: sc.prefill_chunk.max(1),
            kv_budget: sc.kv_budget_bytes,
            migrate_patience: sc.migrate_patience.max(1),
            migrate_streak: 0,
            incremental: sc.incremental_prefill
                && engine.supports_incremental_prefill(),
            max_prompt_tokens: engine.max_prefill_tokens(),
            max_resume_tokens: engine.max_prefill_tokens().min(engine.cmax),
            eos: engine.eos_token(),
            n_layers: engine.dims().n_layers,
            next_stamp: 1,
            swap_threshold: sc.swap_threshold_bytes_per_token,
            drain_window_ms: sc.drain_window_ms,
            draining: false,
            drain_deadline: None,
            rejected: 0,
            preemptions: 0,
            resumes: 0,
            migrations: 0,
            swap_preemptions: 0,
            swap_bytes_out: 0,
            swap_bytes_in: 0,
            deadline_aborts: 0,
            drain_aborts: 0,
            tick_ms_ema: 0.0,
        }
    }

    /// Adaptive backoff hint for [`EngineError::Overloaded`]: the time
    /// to drain the current queue at the recently observed tick pace
    /// (queue depth × tick-latency EMA, floored at 1 ms/tick before the
    /// first measurement), clamped to a sane client range.
    fn overload_retry_after_ms(&self) -> u64 {
        let est = self.waiting.len() as f64 * self.tick_ms_ema.max(1.0);
        (est as u64).clamp(25, 5000)
    }

    /// Admission control. Every rejection is a typed [`EngineError`]
    /// at the root of the returned chain (downcastable at the TCP
    /// boundary): [`EngineError::ShuttingDown`] while draining,
    /// [`EngineError::PromptTooLong`] past the largest compiled prefill
    /// bucket, [`EngineError::Overloaded`] (with a suggested backoff)
    /// when the waiting queue is full.
    pub fn submit(&mut self, req: Request) -> Result<()> {
        if self.draining {
            self.rejected += 1;
            return Err(EngineError::ShuttingDown.into());
        }
        if req.prompt.len() > self.max_prompt_tokens {
            self.rejected += 1;
            return Err(EngineError::PromptTooLong {
                tokens: req.prompt.len(),
                max: self.max_prompt_tokens,
            }
            .into());
        }
        if self.waiting.len() >= self.max_waiting {
            self.rejected += 1;
            return Err(EngineError::Overloaded {
                retry_after_ms: self.overload_retry_after_ms(),
                waiting: self.waiting.len(),
            }
            .into());
        }
        self.waiting.push_back(WaitEntry::Fresh(req));
        Ok(())
    }

    /// Enter graceful-drain mode: stop admitting new work
    /// ([`EngineError::ShuttingDown`] from [`Scheduler::submit`]) and
    /// give in-flight sequences `scheduler.drain_window_ms` to finish;
    /// whatever is still running past the window is finished with
    /// [`FinishReason::DeadlineExceeded`] (counted in `drain_aborts`).
    /// Idempotent: the window is anchored at the first call.
    pub fn begin_drain(&mut self) {
        if self.draining {
            return;
        }
        self.draining = true;
        self.drain_deadline =
            Some(Instant::now() + Duration::from_millis(self.drain_window_ms));
    }

    /// True once [`Scheduler::begin_drain`] has been called.
    pub fn draining(&self) -> bool {
        self.draining
    }

    pub fn waiting(&self) -> usize {
        self.waiting.len()
    }

    /// Sequences currently in chunk-wise prefill.
    pub fn prefilling(&self) -> usize {
        self.prefilling.len()
    }

    /// Storage label the group cache serves with ("f32" | "q8" | "q4" |
    /// "mixed" for a per-layer map); surfaced per-completion by the
    /// server.
    pub fn kv_format(&self) -> String {
        self.group.cache.format_label()
    }

    pub fn active(&self) -> usize {
        self.group.active()
    }

    pub fn idle(&self) -> bool {
        self.waiting.is_empty()
            && self.prefilling.is_empty()
            && self.group.active() == 0
    }

    /// Serving-pressure snapshot for the `{"stats": true}` front-end
    /// query: queue/lifecycle depths, rejection/preemption/resume/
    /// migration counters, plus the full engine metrics object.
    pub fn stats_json(&self, engine: &Engine) -> Json {
        Json::obj(vec![
            ("queue_depth", Json::from(self.waiting.len())),
            ("prefilling", Json::from(self.prefilling.len())),
            ("active", Json::from(self.group.active())),
            ("rejected", Json::from(self.rejected as usize)),
            ("preemptions", Json::from(self.preemptions as usize)),
            ("resumes", Json::from(self.resumes as usize)),
            ("kv_migrations", Json::from(self.migrations as usize)),
            ("kv_format", Json::str(&self.kv_format())),
            ("draining", Json::from(self.draining)),
            ("metrics", engine.metrics.to_json()),
        ])
    }

    /// One scheduler tick:
    ///   0. migrate live layer formats onto the engine's resolved map,
    ///   1. preempt under KV-byte pressure,
    ///   2. admit waiting work into the chunked-prefill lane,
    ///   3. advance one prefill chunk (installing on the final one),
    ///   4. run one decode step over the co-batched group,
    ///   5. reap completions.
    pub fn tick(&mut self, engine: &mut Engine) -> Result<TickReport> {
        let tick_start = Instant::now();
        let mut report = TickReport::default();

        // Land any decode execute still in flight from the previous
        // tick before touching the runtime or the cache: migration,
        // admission and prefill below all call into the runtime's
        // executable registry (a RefCell) and mutate the cache layout,
        // neither of which may race the executor thread. Pipelining
        // therefore overlaps policy work *within* a step; cross-tick
        // overlap is intentionally drained here.
        engine.sync_runtime();

        // Deadlines first, at the tick boundary: a request past its
        // `deadline_ms` (or caught by a closing drain window) finishes
        // with DeadlineExceeded wherever it is — decoding (reaped
        // below like any completion), mid-prefill, or still queued
        // (completions synthesized here).
        report
            .completed
            .extend(self.enforce_deadlines(Instant::now()));
        self.group.reap();

        // 0. Live per-layer format migration, with hysteresis. This
        // replaces the old idle-only group rebuild: a busy group's
        // layers are rewritten in place through the epoch protocol, so
        // a server under sustained load still picks up the
        // sparsity-directed `kv.mixed` resolution.
        report.migrated = self.drive_migration(engine)?;

        // 1. Co-residency pressure: recompute-preempt the youngest
        // resumable sequence until the group fits its byte budget.
        // Never preempts the last tenant (a single sequence over budget
        // is not an OOM — Oom is reserved for the capacity line).
        if self.kv_budget > 0 {
            while self.group.cache.live_bytes() > self.kv_budget
                && self.group.active() > 1
                && self.preempt_one()
            {
                report.preempted += 1;
            }
        }

        // 2. Admission (slot reservation: jobs + active never exceed
        // the group size; byte budget projected for the rows about to
        // be installed). A swap-preempted entry restores its host image
        // straight into a free slot — no re-prefill; everything else
        // enters the chunked-prefill lane.
        while self.can_admit_front() {
            let entry = self.waiting.pop_front().unwrap();
            match entry {
                WaitEntry::Swapped { image, seq } => {
                    self.restore_swapped(*image, seq);
                }
                entry => {
                    let job = self.start_job(entry, engine);
                    self.prefilling.push(job);
                }
            }
        }

        // 3. Advance one prefill job by one chunk (round-robin so a
        // short prompt never waits out a long one's whole prefill). A
        // runtime failure here fails *that job's sequence* with a typed
        // finish instead of poisoning the tick.
        if !self.prefilling.is_empty() {
            let idx = self.rr % self.prefilling.len();
            let next = {
                let job = &self.prefilling[idx];
                (job.consumed + self.prefill_chunk).min(job.tokens.len())
            };
            // Run the chunk. Incremental: only the new tokens go
            // through `prefill_t{T}_kv` against the job's accumulated
            // prior KV, and the final chunk converts the accumulator
            // into the window-shaped install input. Recompute: the
            // whole grown prefix re-prefills and intermediate chunks'
            // outputs are discarded. `Ok(Some(out))` = final chunk,
            // ready to install; `Ok(None)` = job advanced.
            let step: Result<Option<PrefillOut>> = if self.incremental {
                let job = &mut self.prefilling[idx];
                let acc = job.acc.take();
                engine
                    .prefill_chunk(acc, &job.tokens[job.consumed..next])
                    .map(|acc| {
                        if next == job.tokens.len() {
                            Some(acc.into_prefill_out())
                        } else {
                            job.acc = Some(acc);
                            None
                        }
                    })
            } else {
                engine
                    .prefill_window(&self.prefilling[idx].tokens[..next])
                    .map(|out| {
                        (next == self.prefilling[idx].tokens.len())
                            .then_some(out)
                    })
            };
            match step {
                Err(e) => {
                    let mut job = self.prefilling.remove(idx);
                    let kind = e
                        .downcast_ref::<EngineError>()
                        .and_then(EngineError::failure_kind)
                        .unwrap_or(FailureKind::RuntimeExecute);
                    job.seq.fail(kind);
                    engine.metrics.seq_failures += 1;
                    report
                        .completed
                        .push(Self::completion_of(job.seq, Instant::now()));
                    self.rr = idx;
                }
                Ok(Some(out)) => {
                    report.prefill_chunks += 1;
                    let job = self.prefilling.remove(idx);
                    let slot = self
                        .group
                        .free_slot()
                        .expect("prefill job holds a slot reservation");
                    engine.install_prefill(
                        &mut self.group,
                        slot,
                        job.seq,
                        &job.tokens,
                        out,
                        job.resume,
                    )?;
                    self.group.seq_mut(slot).admit_stamp = self.next_stamp;
                    self.next_stamp += 1;
                    if job.resume {
                        self.resumes += 1;
                    }
                    report.prefilled += 1;
                    // The job that slid into `idx` is next in the
                    // rotation.
                    self.rr = idx;
                }
                Ok(None) => {
                    report.prefill_chunks += 1;
                    let job = &mut self.prefilling[idx];
                    job.consumed = next;
                    job.seq.phase = SeqPhase::Prefilling { consumed: next };
                    self.rr = idx + 1;
                }
            }
        }

        // A sequence can finish on its install token (EOS or max_new of
        // 1); reap it before decoding so the step never advances a
        // finished sequence past its end (keeps a resumed run
        // token-identical to an uncontended one).
        self.group.reap();

        // 4. One decode step over the co-batched group. (Capacity-line
        // overflow inside `step` marks the longest sequence Oom — it
        // would not fit even alone.)
        if self.group.active() > 0 {
            let produced = engine.step(&mut self.group)?;
            report.decoded_tokens = produced.len();
        }

        // 5. Reap completions.
        self.group.reap();
        let now = Instant::now();
        for seq in self.group.done.drain(..) {
            report.completed.push(Self::completion_of(seq, now));
        }

        // Per-class SLO accounting: every completion this tick folds
        // into the streaming per-class latency tracks exactly once.
        for c in &report.completed {
            engine.metrics.record_completion(c);
        }

        // Serving-pressure telemetry travels with the engine metrics.
        engine.metrics.queue_depth_last = self.waiting.len();
        engine.metrics.rejected = self.rejected;
        engine.metrics.preemptions = self.preemptions;
        engine.metrics.resumes = self.resumes;
        engine.metrics.swap_preemptions = self.swap_preemptions;
        engine.metrics.swap_bytes_out = self.swap_bytes_out;
        engine.metrics.swap_bytes_in = self.swap_bytes_in;
        engine.metrics.deadline_aborts = self.deadline_aborts;
        engine.metrics.drain_aborts = self.drain_aborts;
        let ms = tick_start.elapsed().as_secs_f64() * 1e3;
        self.tick_ms_ema = if self.tick_ms_ema == 0.0 {
            ms
        } else {
            0.8 * self.tick_ms_ema + 0.2 * ms
        };
        Ok(report)
    }

    /// Build the caller-facing [`Completion`] record for a finished
    /// sequence (shared by the reap path, deadline enforcement and
    /// typed prefill failures).
    fn completion_of(seq: SeqState, now: Instant) -> Completion {
        let sub = seq.submitted_at.unwrap_or(now);
        // End at the terminal event (EOS/length/failure/deadline mark),
        // not the tick boundary that happens to reap the slot — the
        // difference is a whole tick of slack that would otherwise
        // pollute every TTFT/TPOT/e2e percentile.
        let end = seq.finished_at.unwrap_or(now);
        let tpot = match (seq.first_token_at, seq.generated.len()) {
            (Some(ft), n) if n >= 2 => {
                (end - ft).as_secs_f64() / (n - 1) as f64
            }
            _ => 0.0,
        };
        Completion {
            id: seq.id,
            prompt_len: seq.prompt_len,
            ttft: seq
                .first_token_at
                .map(|t| (t - sub).as_secs_f64())
                .unwrap_or(0.0),
            tpot,
            total: (end - sub).as_secs_f64(),
            prune_rounds: seq.prune_log.len(),
            preemptions: seq.preemptions,
            finish: seq.finished.unwrap_or(FinishReason::DeadlineExceeded),
            generated: seq.generated,
            class: seq.class,
        }
    }

    /// `Some(true)` when the shutdown drain window has closed on `seq`,
    /// `Some(false)` when its own request deadline elapsed, `None`
    /// while it may keep running. Own deadline wins the attribution
    /// when both have passed.
    fn expired(&self, deadline: Option<Instant>, now: Instant) -> Option<bool> {
        if deadline.is_some_and(|d| now >= d) {
            return Some(false);
        }
        if self.draining && self.drain_deadline.is_some_and(|d| now >= d) {
            return Some(true);
        }
        None
    }

    /// Finish everything past its deadline (or past the closed drain
    /// window) with [`FinishReason::DeadlineExceeded`], wherever it is
    /// in the lifecycle. Active decoders are only *marked* — the tick's
    /// next reap frees their slots and reports them through the normal
    /// completion path; mid-prefill jobs and queued entries are removed
    /// here and their completions synthesized and returned.
    fn enforce_deadlines(&mut self, now: Instant) -> Vec<Completion> {
        let mut out = Vec::new();
        for b in 0..self.group.active() {
            if self.group.seq(b).finished.is_some() {
                continue;
            }
            if let Some(is_drain) = self.expired(self.group.seq(b).deadline, now)
            {
                let seq = self.group.seq_mut(b);
                seq.finished = Some(FinishReason::DeadlineExceeded);
                seq.phase = SeqPhase::Finished;
                seq.finished_at = Some(now);
                self.note_abort(is_drain);
            }
        }
        let mut i = 0;
        while i < self.prefilling.len() {
            if let Some(is_drain) =
                self.expired(self.prefilling[i].seq.deadline, now)
            {
                let mut job = self.prefilling.remove(i);
                job.seq.finished = Some(FinishReason::DeadlineExceeded);
                job.seq.phase = SeqPhase::Finished;
                job.seq.finished_at = Some(now);
                self.note_abort(is_drain);
                out.push(Self::completion_of(job.seq, now));
            } else {
                i += 1;
            }
        }
        let entries: Vec<WaitEntry> = self.waiting.drain(..).collect();
        for entry in entries {
            let verdict = match &entry {
                WaitEntry::Fresh(r) => self.expired(r.deadline(), now),
                WaitEntry::Resume { seq, .. }
                | WaitEntry::Swapped { seq, .. } => {
                    self.expired(seq.deadline, now)
                }
            };
            match verdict {
                None => self.waiting.push_back(entry),
                Some(is_drain) => {
                    self.note_abort(is_drain);
                    out.push(match entry {
                        WaitEntry::Fresh(r) => Completion {
                            id: r.id,
                            prompt_len: r.prompt.len(),
                            ttft: 0.0,
                            tpot: 0.0,
                            total: (now - r.submitted_at).as_secs_f64(),
                            prune_rounds: 0,
                            preemptions: 0,
                            finish: FinishReason::DeadlineExceeded,
                            generated: Vec::new(),
                            class: r.class,
                        },
                        WaitEntry::Resume { mut seq, .. }
                        | WaitEntry::Swapped { mut seq, .. } => {
                            seq.finished =
                                Some(FinishReason::DeadlineExceeded);
                            seq.finished_at = Some(now);
                            Self::completion_of(seq, now)
                        }
                    });
                }
            }
        }
        out
    }

    fn note_abort(&mut self, is_drain: bool) {
        if is_drain {
            self.drain_aborts += 1;
        } else {
            self.deadline_aborts += 1;
        }
    }

    /// Drive to completion (used by benches and the eval harness).
    pub fn run_to_idle(&mut self, engine: &mut Engine) -> Result<Vec<Completion>> {
        let mut out = Vec::new();
        while !self.idle() {
            let r = self.tick(engine)?;
            out.extend(r.completed);
        }
        Ok(out)
    }

    /// Diff the engine's resolved format map against the live group's
    /// and migrate changed layers in place once the difference has
    /// persisted `migrate_patience` ticks. Returns layers migrated.
    fn drive_migration(&mut self, engine: &mut Engine) -> Result<usize> {
        let want = engine.current_format_map();
        if *self.group.cache.format_map() == want {
            self.migrate_streak = 0;
            return Ok(0);
        }
        self.migrate_streak += 1;
        if self.migrate_streak < self.migrate_patience {
            return Ok(0);
        }
        if let Some(fp) = engine.faults.as_mut() {
            if fp.trip(FaultSite::Migration) {
                engine.metrics.faults_injected = fp.injected;
                // Injected migration failure: skip this round. The
                // format diff persists, so patience re-arms and the
                // migration retries — exactly the real-failure path.
                self.migrate_streak = 0;
                return Ok(0);
            }
        }
        let mut migrated = 0;
        for l in 0..self.n_layers {
            // A failed layer migration is non-fatal: the layer keeps
            // serving in its old format and the persisting diff retries
            // after another patience window.
            match self.group.cache.migrate_layer_format(l, want.get(l)) {
                Ok(true) => migrated += 1,
                Ok(false) => {}
                Err(e) => eprintln!("layer {l} migration failed: {e:#}"),
            }
        }
        self.migrations += migrated as u64;
        engine.metrics.kv_migrations += migrated as u64;
        self.migrate_streak = 0;
        Ok(migrated)
    }

    /// Whether the front waiting entry can start prefilling now.
    fn can_admit_front(&self) -> bool {
        let Some(entry) = self.waiting.front() else {
            return false;
        };
        if self.prefilling.len() + self.group.active()
            >= self.group.group_size()
        {
            return false;
        }
        if self.kv_budget == 0 {
            return true;
        }
        // An empty core always admits (progress guarantee: a sequence
        // the budget alone would starve still runs solo).
        if self.group.active() == 0 && self.prefilling.is_empty() {
            return true;
        }
        // Project live bytes + the reservations of prefills already in
        // flight (they hold no cache rows yet but will install their
        // full prompt) + the candidate's own footprint, so a burst of
        // admissions cannot over-commit the budget and then thrash
        // through preempt/resume cycles it caused itself.
        let pending: usize = self
            .prefilling
            .iter()
            .map(|j| self.group.cache.bytes_for_rows(j.tokens.len()))
            .sum();
        let projected = self.group.cache.bytes_for_rows(entry.token_count());
        self.group.cache.live_bytes() + pending + projected <= self.kv_budget
    }

    /// Turn a waiting entry into a chunked-prefill job.
    fn start_job(&self, entry: WaitEntry, engine: &Engine) -> PrefillJob {
        match entry {
            WaitEntry::Fresh(req) => {
                let mut seq = SeqState::new(
                    req.id,
                    make_policy(req.policy, &engine.cfg, self.n_layers),
                    self.n_layers,
                    req.max_new_tokens,
                    self.eos,
                );
                seq.submitted_at = Some(req.submitted_at);
                seq.deadline = req.deadline();
                seq.class = req.class.clone();
                seq.prompt = req.prompt.clone();
                seq.phase = SeqPhase::Prefilling { consumed: 0 };
                PrefillJob {
                    tokens: req.prompt,
                    consumed: 0,
                    seq,
                    resume: false,
                    acc: None,
                }
            }
            WaitEntry::Resume { tokens, mut seq } => {
                seq.phase = SeqPhase::Prefilling { consumed: 0 };
                PrefillJob { tokens, consumed: 0, seq, resume: true, acc: None }
            }
            // Swapped entries are restored directly in `tick` (phase 2)
            // and never reach here; if one ever does, degrade to a
            // recompute resume (the image is dropped).
            WaitEntry::Swapped { mut seq, .. } => {
                let mut tokens = seq.prompt.clone();
                tokens.extend_from_slice(&seq.generated);
                seq.phase = SeqPhase::Prefilling { consumed: 0 };
                PrefillJob { tokens, consumed: 0, seq, resume: true, acc: None }
            }
        }
    }

    /// Preempt the youngest resumable decoding sequence back to the
    /// *front* of the waiting queue (it is the oldest admitted work
    /// still unfinished among the queue's entries). Returns false when
    /// no sequence can be preempted (none resumable within the prefill
    /// buckets).
    ///
    /// Per victim, a cost model picks the eviction flavor: when
    /// `swap_threshold_bytes_per_token` is set and the victim's live
    /// bytes ≤ resume-tokens × threshold, its KV rows are serialized to
    /// host at stored precision (swap — resume restores the cache
    /// bit-exactly, no re-prefill); otherwise the rows are dropped and
    /// resume re-prefills prompt + generated (recompute). Both flavors
    /// reconstruct the identical greedy continuation.
    fn preempt_one(&mut self) -> bool {
        let victim = (0..self.group.active())
            .filter(|&b| {
                let s = self.group.seq(b);
                s.prompt.len() + s.generated.len() <= self.max_resume_tokens
            })
            .max_by_key(|&b| self.group.seq(b).admit_stamp);
        let Some(b) = victim else {
            return false;
        };
        let live = self.group.cache.slot_live_bytes(b);
        let resume_tokens = {
            let s = self.group.seq(b);
            s.prompt.len() + s.generated.len()
        };
        // saturating_mul: tests force the swap path with usize::MAX.
        let swap = self.swap_threshold > 0
            && live <= resume_tokens.saturating_mul(self.swap_threshold);
        self.preemptions += 1;
        // Bypasses max_waiting on purpose: the sequence was already
        // admitted once; backpressure applies to new work only.
        if swap {
            let image = self.group.cache.evict_to_host(b);
            self.swap_bytes_out += image.payload_bytes() as u64;
            self.swap_preemptions += 1;
            let mut seq = self.group.remove(b);
            seq.preemptions += 1;
            self.waiting.push_front(WaitEntry::Swapped {
                image: Box::new(image),
                seq,
            });
        } else {
            let mut seq = self.group.remove(b);
            seq.preemptions += 1;
            let mut tokens = seq.prompt.clone();
            tokens.extend_from_slice(&seq.generated);
            self.waiting.push_front(WaitEntry::Resume { tokens, seq });
        }
        true
    }

    /// Export every unit of in-flight work for rescue onto a healthy
    /// peer, draining this scheduler to idle. Resumable active decoders
    /// leave as [`RescueEntry::Swapped`] host images (token-identical
    /// restore), mid-prefill jobs and queued resumes as
    /// [`RescueEntry::Resume`] recompute prefixes, and queued requests
    /// verbatim. Sequences that cannot re-enter any group (prefix past
    /// the resume line) — and finished-but-unreaped ones — come back as
    /// completions: the former typed
    /// [`FinishReason::Error`]`(`[`FailureKind::GroupLost`]`)`, the
    /// latter with their real finish.
    pub fn export_for_rescue(&mut self) -> (Vec<RescueEntry>, Vec<Completion>) {
        let mut entries = Vec::new();
        let mut completed = Vec::new();
        let now = Instant::now();
        self.group.reap();
        for seq in self.group.done.drain(..) {
            completed.push(Self::completion_of(seq, now));
        }
        while self.group.active() > 0 {
            let b = self.group.active() - 1;
            let resumable = {
                let s = self.group.seq(b);
                s.prompt.len() + s.generated.len() <= self.max_resume_tokens
            };
            if resumable {
                let image = self.group.cache.evict_to_host(b);
                self.swap_bytes_out += image.payload_bytes() as u64;
                let mut seq = self.group.remove(b);
                seq.preemptions += 1;
                entries.push(RescueEntry::Swapped {
                    image: Box::new(image),
                    seq,
                });
            } else {
                let mut seq = self.group.remove(b);
                seq.fail(FailureKind::GroupLost);
                completed.push(Self::completion_of(seq, now));
            }
        }
        for job in self.prefilling.drain(..) {
            entries.push(RescueEntry::Resume {
                tokens: job.tokens,
                seq: job.seq,
            });
        }
        for entry in self.waiting.drain(..) {
            entries.push(match entry {
                WaitEntry::Fresh(r) => RescueEntry::Fresh(r),
                WaitEntry::Resume { tokens, seq } => {
                    RescueEntry::Resume { tokens, seq }
                }
                WaitEntry::Swapped { image, seq } => {
                    RescueEntry::Swapped { image, seq }
                }
            });
        }
        (entries, completed)
    }

    /// Admit a rescued unit of work from a quarantined peer. Bypasses
    /// `max_waiting` on purpose — the work was already admitted once;
    /// backpressure applies to new requests only. Swapped images
    /// restore directly on the next tick (or degrade to recompute if
    /// this group's layer formats have diverged).
    pub fn admit_rescued(&mut self, entry: RescueEntry) {
        self.waiting.push_back(match entry {
            RescueEntry::Fresh(r) => WaitEntry::Fresh(r),
            RescueEntry::Resume { tokens, seq } => {
                WaitEntry::Resume { tokens, seq }
            }
            RescueEntry::Swapped { image, seq } => {
                WaitEntry::Swapped { image, seq }
            }
        });
    }

    /// Re-admit a swap-preempted sequence: restore its host image into
    /// the next free slot and rejoin the decode group mid-stream (no
    /// re-prefill). If the restore is rejected — a live format
    /// migration changed a layer while the image was swapped out — fall
    /// back to recompute by re-queuing prompt + generated as a normal
    /// resume entry; the continuation is still token-identical, just
    /// paid for in prefill FLOPs instead of bytes.
    fn restore_swapped(&mut self, image: HostSlotImage, mut seq: SeqState) {
        let slot = self
            .group
            .free_slot()
            .expect("can_admit_front guarantees a free slot");
        match self.group.cache.restore_from_host(slot, &image) {
            Ok(()) => {
                self.swap_bytes_in += image.payload_bytes() as u64;
                seq.phase = SeqPhase::Decoding;
                seq.admit_stamp = self.next_stamp;
                self.next_stamp += 1;
                self.group.install(slot, seq);
                self.resumes += 1;
            }
            Err(e) => {
                eprintln!(
                    "swap restore failed for seq {} (falling back to \
                     recompute): {e:#}",
                    seq.id
                );
                let mut tokens = seq.prompt.clone();
                tokens.extend_from_slice(&seq.generated);
                self.waiting.push_front(WaitEntry::Resume { tokens, seq });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::FullKv;

    fn req(id: u64, prompt_len: usize) -> Request {
        Request {
            id,
            prompt: vec![1; prompt_len],
            max_new_tokens: 4,
            policy: PolicyKind::Lethe,
            submitted_at: Instant::now(),
            deadline_ms: None,
            class: String::new(),
        }
    }

    /// Scheduler without an engine: queue/lifecycle paths only.
    fn bare_sched(batch: usize, max_waiting: usize, kv_budget: usize) -> Scheduler {
        let dims = crate::kvcache::CacheDims {
            layers: 1,
            batch,
            kv_heads: 1,
            capacity: 8,
            d_head: 4,
        };
        Scheduler {
            group: DecodeGroup::new(dims, PolicyKind::Lethe),
            waiting: VecDeque::new(),
            prefilling: Vec::new(),
            rr: 0,
            max_waiting,
            prefill_chunk: 4,
            kv_budget,
            migrate_patience: 1,
            migrate_streak: 0,
            incremental: false,
            max_prompt_tokens: 64,
            max_resume_tokens: 8,
            eos: 2,
            n_layers: 1,
            next_stamp: 1,
            swap_threshold: 0,
            drain_window_ms: 2000,
            draining: false,
            drain_deadline: None,
            rejected: 0,
            preemptions: 0,
            resumes: 0,
            migrations: 0,
            swap_preemptions: 0,
            swap_bytes_out: 0,
            swap_bytes_in: 0,
            deadline_aborts: 0,
            drain_aborts: 0,
            tick_ms_ema: 0.0,
        }
    }

    #[test]
    fn admission_control_rejects_when_full_or_overlong() {
        let mut s = bare_sched(2, 2, 0);
        assert!(s.submit(req(1, 3)).is_ok());
        assert!(s.submit(req(2, 3)).is_ok());
        assert!(s.submit(req(3, 3)).is_err());
        assert_eq!(s.rejected, 1);
        // A prompt beyond the largest prefill bucket is rejected even
        // with queue room.
        let mut s2 = bare_sched(2, 8, 0);
        assert!(s2.submit(req(1, 65)).is_err());
        assert_eq!(s2.rejected, 1);
        assert_eq!(s.waiting(), 2);
        assert!(!s.idle());
    }

    #[test]
    fn preempt_picks_youngest_resumable_and_requeues_front() {
        let mut s = bare_sched(3, 8, 1);
        for i in 0..3 {
            let mut seq =
                SeqState::new(i, Box::new(FullKv), 1, 8, 2);
            seq.prompt = vec![1, 3];
            seq.note_prefilled(2, 10);
            seq.admit_stamp = i + 1;
            let slot = s.group.free_slot().unwrap();
            s.group
                .cache
                .insert(0, slot, &[0.0; 4], &[0.0; 4], 0)
                .unwrap();
            s.group.install(slot, seq);
        }
        // Make the oldest sequence non-resumable (too long a prefix).
        s.group.seqs[0].generated = vec![10; 20];
        assert!(s.preempt_one());
        assert_eq!(s.preemptions, 1);
        assert_eq!(s.group.active(), 2);
        // The youngest (stamp 3, id 2) went back to the queue front
        // with its recompute prefix.
        match s.waiting.front().unwrap() {
            WaitEntry::Resume { tokens, seq } => {
                assert_eq!(seq.id, 2);
                assert_eq!(seq.phase, SeqPhase::Preempted);
                assert_eq!(seq.preemptions, 1);
                // prompt [1, 3] + the first generated token.
                assert_eq!(tokens, &[1, 3, 10]);
            }
            _ => panic!("expected a resume entry at the front"),
        }
        // Preempt again: stamp 2 goes; the non-resumable stamp-1 seq
        // is never a victim.
        assert!(s.preempt_one());
        assert_eq!(s.group.active(), 1);
        assert_eq!(s.group.seqs[0].admit_stamp, 1);
        assert!(!s.preempt_one(), "last tenant is non-resumable here");
    }

    #[test]
    fn admission_projects_byte_budget() {
        // Budget fits one 4-token prompt (1 layer, 1 head, d=4 → 32 B
        // per row) but not two.
        let mut s = bare_sched(3, 8, 6 * 32);
        assert!(s.submit(req(1, 4)).is_ok());
        assert!(s.can_admit_front(), "empty core always admits");
        // Simulate an installed 4-row sequence.
        let mut seq = SeqState::new(1, Box::new(FullKv), 1, 8, 2);
        seq.note_prefilled(4, 10);
        for t in 0..4 {
            s.group.cache.insert(0, 0, &[0.0; 4], &[0.0; 4], t).unwrap();
        }
        s.group.install(0, seq);
        assert!(s.submit(req(2, 4)).is_ok());
        assert!(
            !s.can_admit_front(),
            "4 live + 4 projected rows exceed the 6-row budget"
        );
        let mut s2 = bare_sched(3, 8, 0);
        assert!(s2.submit(req(1, 4)).is_ok());
        assert!(s2.can_admit_front(), "no budget, no gate");
    }

    #[test]
    fn admission_counts_inflight_prefill_reservations() {
        // Budget fits two 4-token prompts but not three; with one
        // sequence decoding and one prompt mid-prefill, the third must
        // wait even though live bytes alone would admit it.
        let mut s = bare_sched(4, 8, 9 * 32);
        let mut seq = SeqState::new(1, Box::new(FullKv), 1, 8, 2);
        seq.note_prefilled(4, 10);
        for t in 0..4 {
            s.group.cache.insert(0, 0, &[0.0; 4], &[0.0; 4], t).unwrap();
        }
        s.group.install(0, seq);
        s.prefilling.push(PrefillJob {
            tokens: vec![1; 4],
            consumed: 0,
            seq: SeqState::new(2, Box::new(FullKv), 1, 8, 2),
            resume: false,
            acc: None,
        });
        assert!(s.submit(req(3, 4)).is_ok());
        assert!(
            !s.can_admit_front(),
            "4 live + 4 in-flight + 4 projected rows exceed 9"
        );
        // Once the in-flight prefill lane drains, the same entry fits.
        s.prefilling.clear();
        assert!(s.can_admit_front());
    }

    #[test]
    fn submit_rejections_are_typed_and_downcastable() {
        let mut s = bare_sched(2, 1, 0);
        assert!(s.submit(req(1, 3)).is_ok());
        let err = s.submit(req(2, 3)).unwrap_err();
        let ee = err.downcast_ref::<EngineError>().expect("typed root");
        assert!(ee.is_retryable(), "queue-full is retryable");
        // No tick has run yet: the EMA floor (1 ms/tick × depth 1)
        // clamps to the 25 ms minimum.
        assert_eq!(ee.retry_after_ms(), Some(25));
        let err = s.submit(req(3, 99)).unwrap_err();
        let ee = err.downcast_ref::<EngineError>().expect("typed root");
        assert!(
            matches!(ee, EngineError::PromptTooLong { tokens: 99, max: 64 }),
            "{ee:?}"
        );
        assert!(!ee.is_retryable(), "an over-long prompt never fits");
        assert_eq!(s.rejected, 2);
    }

    #[test]
    fn overload_backoff_scales_with_queue_and_tick_pace() {
        let mut s = bare_sched(2, 1, 0);
        assert!(s.submit(req(1, 3)).is_ok());
        // Slow ticks (8 ms EMA): one queued entry => 8 ms, floored at 25.
        s.tick_ms_ema = 8.0;
        assert_eq!(s.overload_retry_after_ms(), 25);
        // Deep queue at the same pace scales linearly: 1 × 40 ms = 40.
        s.tick_ms_ema = 40.0;
        let err = s.submit(req(2, 3)).unwrap_err();
        let ee = err.downcast_ref::<EngineError>().unwrap();
        assert_eq!(ee.retry_after_ms(), Some(40));
        // Pathological pace clamps at the 5 s ceiling.
        s.tick_ms_ema = 1e9;
        assert_eq!(s.overload_retry_after_ms(), 5000);
    }

    #[test]
    fn rescue_export_drains_every_lane_and_round_trips() {
        let mut s = bare_sched(4, 8, 0);
        // One active decoder with live KV rows (resumable).
        let mut seq = SeqState::new(1, Box::new(FullKv), 1, 8, 2);
        seq.prompt = vec![1, 3];
        seq.note_prefilled(2, 10);
        s.group.cache.insert(0, 0, &[0.5; 4], &[0.25; 4], 0).unwrap();
        s.group.install(0, seq);
        // One mid-prefill job.
        let mut pseq = SeqState::new(2, Box::new(FullKv), 1, 8, 2);
        pseq.phase = SeqPhase::Prefilling { consumed: 4 };
        s.prefilling.push(PrefillJob {
            tokens: vec![1; 6],
            consumed: 4,
            seq: pseq,
            resume: false,
            acc: None,
        });
        // One queued fresh request.
        assert!(s.submit(req(3, 3)).is_ok());

        let (entries, completed) = s.export_for_rescue();
        assert!(s.idle(), "export drains the scheduler");
        assert!(completed.is_empty());
        assert_eq!(entries.len(), 3);
        let ids: Vec<u64> = entries.iter().map(|e| e.id()).collect();
        assert_eq!(ids, vec![1, 2, 3]);
        assert!(
            matches!(&entries[0], RescueEntry::Swapped { .. }),
            "resumable decoder leaves as a host image"
        );
        assert!(entries[0].payload_bytes() > 0);
        assert!(matches!(
            &entries[1],
            RescueEntry::Resume { tokens, .. } if tokens.len() == 6
        ));
        assert!(matches!(&entries[2], RescueEntry::Fresh(_)));

        // Round-trip onto a healthy peer; the swapped image restores
        // with its KV rows intact on the peer's next admission pass.
        let mut peer = bare_sched(4, 0, 0); // max_waiting 0: rescue bypasses
        for e in entries {
            peer.admit_rescued(e);
        }
        assert_eq!(peer.waiting(), 3);
        assert!(peer.can_admit_front());
        let WaitEntry::Swapped { image, seq } =
            peer.waiting.pop_front().unwrap()
        else {
            panic!("swapped entry survives the transfer");
        };
        assert_eq!(seq.preemptions, 1, "rescue counts as a preemption");
        peer.restore_swapped(*image, seq);
        assert_eq!(peer.group.active(), 1);
        assert_eq!(peer.group.cache.len(0, 0), 1, "KV rows transferred");
    }

    #[test]
    fn rescue_export_fails_over_long_sequences_typed() {
        let mut s = bare_sched(3, 8, 0);
        let mut seq = SeqState::new(7, Box::new(FullKv), 1, 64, 2);
        seq.prompt = vec![1, 3];
        seq.note_prefilled(2, 10);
        // Past max_resume_tokens (8 in bare_sched): unrescuable.
        seq.generated = vec![10; 20];
        s.group.cache.insert(0, 0, &[0.5; 4], &[0.25; 4], 0).unwrap();
        s.group.install(0, seq);
        let (entries, completed) = s.export_for_rescue();
        assert!(entries.is_empty());
        assert_eq!(completed.len(), 1);
        assert_eq!(completed[0].id, 7);
        assert_eq!(
            completed[0].finish,
            FinishReason::Error(FailureKind::GroupLost)
        );
    }

    #[test]
    fn swap_preemption_round_trips_through_host() {
        let mut s = bare_sched(3, 8, 1);
        s.swap_threshold = usize::MAX; // force the swap path
        for i in 0..2 {
            let mut seq = SeqState::new(i, Box::new(FullKv), 1, 8, 2);
            seq.prompt = vec![1, 3];
            seq.note_prefilled(2, 10);
            seq.admit_stamp = i + 1;
            let slot = s.group.free_slot().unwrap();
            s.group
                .cache
                .insert(0, slot, &[0.5; 4], &[0.25; 4], 0)
                .unwrap();
            s.group.install(slot, seq);
        }
        // Manual installs above bypassed the stamp counter.
        s.next_stamp = 3;
        assert!(s.preempt_one());
        assert_eq!(s.preemptions, 1);
        assert_eq!(s.swap_preemptions, 1, "threshold forces swap");
        assert!(s.swap_bytes_out > 0);
        assert_eq!(s.group.active(), 1);
        let WaitEntry::Swapped { image, seq } =
            s.waiting.pop_front().unwrap()
        else {
            panic!("expected a swapped entry at the front");
        };
        assert_eq!(seq.id, 1, "youngest stamp is the victim");
        assert_eq!(seq.preemptions, 1);
        assert_eq!(image.max_rows(), 1);
        s.restore_swapped(*image, seq);
        assert_eq!(s.group.active(), 2);
        assert_eq!(s.resumes, 1, "swap resume counts as a resume");
        assert_eq!(s.swap_bytes_in, s.swap_bytes_out);
        assert_eq!(s.group.seq(1).id, 1);
        assert_eq!(s.group.seq(1).phase, SeqPhase::Decoding);
        assert!(s.group.seq(1).admit_stamp > 2, "re-stamped on re-admit");
        assert_eq!(s.group.cache.len(0, 1), 1, "KV rows restored");
    }

    #[test]
    fn recompute_stays_default_without_threshold() {
        let mut s = bare_sched(3, 8, 1);
        let mut seq = SeqState::new(1, Box::new(FullKv), 1, 8, 2);
        seq.prompt = vec![1, 3];
        seq.note_prefilled(2, 10);
        seq.admit_stamp = 1;
        s.group.cache.insert(0, 0, &[0.5; 4], &[0.25; 4], 0).unwrap();
        s.group.install(0, seq);
        assert!(s.preempt_one());
        assert_eq!(s.swap_preemptions, 0, "threshold 0 never swaps");
        assert!(matches!(
            s.waiting.front(),
            Some(WaitEntry::Resume { .. })
        ));
    }

    #[test]
    fn deadlines_abort_work_in_every_lifecycle_stage() {
        let mut s = bare_sched(2, 8, 0);
        let mut r = req(1, 3);
        r.deadline_ms = Some(0);
        assert!(s.submit(r).is_ok());
        let mut pseq = SeqState::new(2, Box::new(FullKv), 1, 8, 2);
        pseq.deadline = Some(Instant::now());
        s.prefilling.push(PrefillJob {
            tokens: vec![1; 3],
            consumed: 0,
            seq: pseq,
            resume: false,
            acc: None,
        });
        let mut aseq = SeqState::new(3, Box::new(FullKv), 1, 8, 2);
        aseq.note_prefilled(1, 10);
        aseq.deadline = Some(Instant::now());
        s.group.install(0, aseq);
        let done = s.enforce_deadlines(Instant::now());
        // Queued + mid-prefill completions synthesize here; the active
        // decoder is marked and flows through the normal reap.
        assert_eq!(done.len(), 2);
        assert!(done
            .iter()
            .all(|c| c.finish == FinishReason::DeadlineExceeded));
        assert_eq!(s.deadline_aborts, 3);
        assert_eq!(s.drain_aborts, 0);
        assert_eq!(s.waiting(), 0);
        assert_eq!(s.prefilling(), 0);
        assert_eq!(
            s.group.seq(0).finished,
            Some(FinishReason::DeadlineExceeded)
        );
        assert_eq!(s.group.reap(), 1, "marked decoder reaps normally");
        // No deadline, no abort: a fresh entry stays queued.
        assert!(s.submit(req(9, 3)).is_ok());
        assert!(s.enforce_deadlines(Instant::now()).is_empty());
        assert_eq!(s.waiting(), 1);
    }

    #[test]
    fn drain_blocks_admission_and_closes_window() {
        let mut s = bare_sched(2, 8, 0);
        assert!(s.submit(req(1, 3)).is_ok());
        s.drain_window_ms = 0;
        s.begin_drain();
        assert!(s.draining());
        let err = s.submit(req(2, 3)).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<EngineError>(),
            Some(EngineError::ShuttingDown)
        ));
        let first = s.drain_deadline;
        s.begin_drain();
        assert_eq!(s.drain_deadline, first, "drain window is anchored once");
        let done = s.enforce_deadlines(Instant::now());
        assert_eq!(done.len(), 1, "zero-width window aborts queued work");
        assert_eq!(done[0].finish, FinishReason::DeadlineExceeded);
        assert_eq!(s.drain_aborts, 1);
        assert_eq!(s.deadline_aborts, 0);
        assert!(s.idle(), "drained to idle");
    }
}
