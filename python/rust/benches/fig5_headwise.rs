fn main() {}
