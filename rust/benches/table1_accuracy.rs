//! Table 1: accuracy preservation under cache compression.
//!
//! Paper: 5 policies × 4 models × (Math500 + 8 MMLU subjects).
//! Here:  5 policies × lethe-tiny × 8 synthetic subjects (recall-N =
//! MMLU proxies, hopK-N = Math500 proxies; DESIGN.md §4). Expected shape:
//! Lethe ≈ FullKV; StreamingLLM/H2O/PyramidKV degrade on the multihop
//! subjects. Also prints the Table 4 capability matrix.
//!
//! Env knobs: LETHE_BENCH_N (tasks/subject, default 25),
//!            LETHE_BENCH_BUDGET (baseline token budget, default 96).

use lethe::bench_support::{print_table, try_engine, write_csv};
use lethe::config::ServingConfig;
use lethe::eval::eval_policy;
use lethe::policy::{make_policy, PolicyKind};

fn env_usize(k: &str, default: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let n = env_usize("LETHE_BENCH_N", 25);
    // Budget 48 ≈ one third of the longest prompts: the compression
    // regime where Table 1's policy separation appears.
    let budget = env_usize("LETHE_BENCH_BUDGET", 48);
    let mut cfg = ServingConfig::default();
    // Hold every policy to a comparable budget so Table 1 compares like
    // for like (paper: all baselines re-implemented in one framework).
    cfg.baseline.budget = budget;
    cfg.lethe.evict_threshold = budget;
    let n_layers;
    let Some((mut engine, tok)) = try_engine(cfg.clone()) else {
        return Ok(());
    };
    n_layers = engine.dims().n_layers;

    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut csv = Vec::new();
    let subjects: Vec<&str> =
        lethe::workload::SUBJECTS.iter().map(|(s, _, _)| *s).collect();

    for kind in PolicyKind::ALL {
        let t0 = std::time::Instant::now();
        let rep = eval_policy(&mut engine, &tok, kind, n, 4, 64, 0xAAA1)?;
        let mut row = vec![kind.label().to_string()];
        for s in &rep.subjects {
            // chain_acc is the retention-sensitive headline (final-value
            // accuracy alongside in the CSV; see eval::judge_chain docs).
            row.push(format!("{:.1}", 100.0 * s.chain_acc));
            csv.push(format!(
                "{},{},{:.4},{:.4},{:.4},{:.1},{:.1},{}",
                kind.label(),
                s.subject,
                s.chain_acc,
                s.final_acc,
                s.strict_acc,
                s.mean_generated,
                s.prune_rounds,
                s.peak_live_bytes
            ));
        }
        row.push(format!("{:.1}", 100.0 * rep.overall_chain_acc()));
        rows.push(row);
        eprintln!(
            "[table1] {} done in {:.1}s",
            kind.label(),
            t0.elapsed().as_secs_f64()
        );
    }

    let mut header = vec!["Method"];
    header.extend(subjects.iter().copied());
    header.push("overall");
    print_table(
        &format!(
            "Table 1 — chain accuracy (%), lethe-tiny, budget={budget}, \
             n={n}/subject"
        ),
        &header,
        &rows,
    );
    write_csv(
        "table1_accuracy.csv",
        "policy,subject,chain_acc,final_acc,strict_acc,mean_gen,\
         prune_rounds,peak_bytes",
        &csv,
    )?;

    // Table 4: capability matrix straight from the live policy objects.
    let cap_rows: Vec<Vec<String>> = PolicyKind::ALL
        .iter()
        .map(|&k| {
            let p = make_policy(k, &cfg, n_layers);
            let c = p.capabilities();
            let tick = |b: bool| if b { "yes" } else { "-" }.to_string();
            vec![
                k.label().to_string(),
                tick(c.recency_aware),
                tick(c.attention_aware),
                tick(c.layerwise_budget),
                tick(c.adaptive_budget),
                tick(c.multi_step_pruning),
            ]
        })
        .collect();
    print_table(
        "Table 4 — capability matrix",
        &["Method", "recency", "attention", "layerwise", "adaptive",
          "multi-step"],
        &cap_rows,
    );
    Ok(())
}
