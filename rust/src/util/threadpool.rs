//! Fixed-size worker pool (tokio substitute for this workload). The
//! serving stack is CPU-bound through one PJRT device, so the pool's job
//! is request-path concurrency (router/session fan-in, background metric
//! flushes), not data parallelism. Work-queue semantics: FIFO, graceful
//! shutdown on drop, panic isolation per job.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    handles: Vec<thread::JoinHandle<()>>,
    inflight: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let inflight = Arc::new(AtomicUsize::new(0));
        let handles = (0..workers)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let inflight = Arc::clone(&inflight);
                thread::Builder::new()
                    .name(format!("lethe-worker-{i}"))
                    .spawn(move || loop {
                        let msg = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match msg {
                            Ok(Msg::Run(job)) => {
                                // Panic isolation: a single bad request
                                // must not take the worker down.
                                let r = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(job),
                                );
                                inflight.fetch_sub(1, Ordering::SeqCst);
                                if r.is_err() {
                                    crate::log_error!(
                                        "worker {i}: job panicked"
                                    );
                                }
                            }
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx, handles, inflight }
    }

    pub fn spawn<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.inflight.fetch_add(1, Ordering::SeqCst);
        self.tx
            .send(Msg::Run(Box::new(f)))
            .expect("threadpool already shut down");
    }

    /// Jobs submitted but not yet finished.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::SeqCst)
    }

    /// Busy-wait (with yield) until all submitted jobs finished.
    pub fn wait_idle(&self) {
        while self.inflight() > 0 {
            thread::yield_now();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.handles {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn survives_panicking_job() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        pool.spawn(|| panic!("boom"));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn shutdown_joins_workers() {
        let pool = ThreadPool::new(3);
        pool.spawn(|| {});
        pool.wait_idle();
        drop(pool); // must not hang
    }
}
