"""Build-time training of the `lethe-tiny` serving model.

The paper serves DeepSeek-R1-Distill checkpoints; none are available
offline, so we *train* the small GQA transformer that the rust engine
serves (DESIGN.md §4 substitution). Training on the synthetic recall /
multihop CoT tasks (tasks.py) gives the model real attention structure —
induction heads, attention sinks, recency bias — so the eviction-policy
comparisons in Table 1 are earned rather than simulated.

Loss is next-token cross-entropy masked to the answer span. The forward
pass is model.train_forward, whose attention semantics are pytest-pinned
to the Pallas serving kernels.

Usage:  python -m compile.train [--steps N] [--time-budget SECONDS]
Writes: artifacts/weights.npz, artifacts/train_log.csv
"""

from __future__ import annotations

import argparse
import os
import random
import time

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile import tasks

SEQLEN = 192
BATCH = 16
LR = 1e-3
WARMUP = 100
WEIGHT_DECAY = 0.01
CLIP = 1.0


def loss_fn(cfg, ws, toks, mask):
    logits = M.train_forward(cfg, ws, toks)                    # [B,T,V]
    tgt = toks[:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    m = mask[:, :-1]
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)


def adamw_init(ws):
    z = {n: jnp.zeros_like(w) for n, w in ws.items()}
    return {"m": z, "v": {n: jnp.zeros_like(w) for n, w in ws.items()},
            "t": jnp.zeros((), jnp.float32)}


def make_step(cfg):
    @jax.jit
    def step(ws, opt, toks, mask):
        loss, grads = jax.value_and_grad(
            lambda w: loss_fn(cfg, w, toks, mask))(ws)
        gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads.values()))
        scale = jnp.minimum(1.0, CLIP / jnp.maximum(gnorm, 1e-9))
        t = opt["t"] + 1.0
        lr = LR * jnp.minimum(1.0, t / WARMUP)
        b1, b2, eps = 0.9, 0.999, 1e-8
        new_m, new_v, new_w = {}, {}, {}
        for n, w in ws.items():
            g = grads[n] * scale
            m = b1 * opt["m"][n] + (1 - b1) * g
            v = b2 * opt["v"][n] + (1 - b2) * g * g
            mh = m / (1 - b1 ** t)
            vh = v / (1 - b2 ** t)
            upd = mh / (jnp.sqrt(vh) + eps)
            if not n.startswith("ln"):
                upd = upd + WEIGHT_DECAY * w
            new_w[n] = w - lr * upd
            new_m[n], new_v[n] = m, v
        return new_w, {"m": new_m, "v": new_v, "t": t}, loss, gnorm
    return step


def eval_accuracy(cfg, ws, n_tasks: int = 20, seed: int = 777) -> float:
    """Greedy teacher-free accuracy on fresh multihop tasks (FullKV —
    this is the training sanity check, not the Table 1 harness)."""
    rng = random.Random(seed)
    fwd = jax.jit(lambda w, t: M.train_forward(cfg, w, t))
    correct = 0
    for _ in range(n_tasks):
        t = tasks.make_task(rng, n_pairs=10, hops=rng.choice([1, 2, 3]))
        inp, tgt = tasks.task_tokens(t)
        ids = list(inp)
        for _ in range(len(tgt) + 4):
            logits = fwd(ws, jnp.array([ids], jnp.int32))
            nxt = int(jnp.argmax(logits[0, -1]))
            ids.append(nxt)
            if nxt == tasks.EOS:
                break
        gen = tasks.decode_ids(ids[len(inp):])
        correct += int(gen == t.answer)
    return correct / n_tasks


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=3000)
    ap.add_argument("--time-budget", type=float, default=900.0,
                    help="wall-clock cap in seconds")
    ap.add_argument("--out", default="../artifacts/weights.npz")
    ap.add_argument("--log", default="../artifacts/train_log.csv")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    os.makedirs(os.path.dirname(args.out), exist_ok=True)

    cfg = M.ModelConfig()
    if args.resume and os.path.exists(args.out):
        data = np.load(args.out)
        ws = {n: jnp.asarray(data[n]) for n in M.WEIGHT_NAMES}
        print("resumed from", args.out)
    else:
        ws = M.init_weights(cfg, jax.random.PRNGKey(args.seed))
    opt = adamw_init(ws)
    step = make_step(cfg)
    rng = random.Random(args.seed)

    t0 = time.time()
    log = []
    for i in range(args.steps):
        toks, mask = tasks.training_batch_ids(rng, BATCH, SEQLEN)
        ws, opt, loss, gnorm = step(ws, opt, jnp.asarray(toks),
                                    jnp.asarray(mask))
        if i % 25 == 0 or i == args.steps - 1:
            el = time.time() - t0
            log.append((i, float(loss), float(gnorm), el))
            print(f"step {i:5d} loss {float(loss):.4f} "
                  f"gnorm {float(gnorm):.2f} {el:.0f}s", flush=True)
            np.savez(args.out, **{n: np.asarray(w) for n, w in ws.items()})
        if time.time() - t0 > args.time_budget:
            print(f"time budget hit at step {i}")
            break

    np.savez(args.out, **{n: np.asarray(w) for n, w in ws.items()})
    with open(args.log, "w") as f:
        f.write("step,loss,gnorm,elapsed_s\n")
        for r in log:
            f.write(f"{r[0]},{r[1]:.5f},{r[2]:.3f},{r[3]:.1f}\n")
    acc = eval_accuracy(cfg, ws)
    print(f"final multihop sanity accuracy (FullKV, greedy): {acc:.2f}")
    with open(args.log, "a") as f:
        f.write(f"# final_sanity_accuracy,{acc}\n")


if __name__ == "__main__":
    main()
