fn main() {}
