"""L2 correctness: the serving entry points (prefill + decode over the
fixed-capacity cache) must agree with the teacher-forced training forward
— the invariant that lets the rust engine serve the trained weights.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import tasks

CFG = M.ModelConfig()


@pytest.fixture(scope="module")
def ws():
    return M.init_weights(CFG, jax.random.PRNGKey(7))


def random_tokens(rng, n):
    return rng.integers(len(tasks.SPECIALS), CFG.vocab_size, size=n,
                        dtype=np.int32)


def test_prefill_matches_train_forward(ws):
    rng = np.random.default_rng(0)
    toks = random_tokens(rng, 24)
    full = M.train_forward(CFG, ws, jnp.asarray(toks)[None, :])
    T = 32
    padded = np.zeros((1, T), np.int32)
    padded[0, :24] = toks
    logits, k_all, v_all, scores = M.prefill(
        CFG, ws, jnp.asarray(padded), jnp.int32(24))
    np.testing.assert_allclose(
        logits[0], full[0, 23], atol=2e-4, rtol=2e-4)
    assert k_all.shape == (CFG.n_layers, 1, CFG.n_kv_heads, T, CFG.d_head)
    assert scores.shape == (CFG.n_layers, 1, CFG.n_q_heads, T)
    # Pad-query rows contribute nothing to RASR init:
    # total mass == sum over valid queries only (each row sums to 1).
    per_layer = np.asarray(scores).sum(axis=(-1))  # [L,1,Hq]
    np.testing.assert_allclose(per_layer, 24.0, atol=1e-3)


def test_decode_chain_matches_train_forward(ws):
    """prefill(n) + m decode steps == teacher forcing on n+m tokens."""
    rng = np.random.default_rng(1)
    n, m, C = 20, 8, 64
    toks = random_tokens(rng, n + m)
    full = M.train_forward(CFG, ws, jnp.asarray(toks)[None, :])

    padded = np.zeros((1, 32), np.int32)
    padded[0, :n] = toks[:n]
    logits, k_all, v_all, _ = M.prefill(
        CFG, ws, jnp.asarray(padded), jnp.int32(n))
    np.testing.assert_allclose(logits[0], full[0, n - 1], atol=2e-4,
                               rtol=2e-4)

    # Build the capacity-C cache the way the rust engine does.
    L, Hkv, D = CFG.n_layers, CFG.n_kv_heads, CFG.d_head
    kv_k = np.zeros((L, 1, Hkv, C, D), np.float32)
    kv_v = np.zeros((L, 1, Hkv, C, D), np.float32)
    kv_k[:, :, :, :32] = np.asarray(k_all)
    kv_v[:, :, :, :32] = np.asarray(v_all)
    lens = np.full((L, 1), n, np.int32)

    for t in range(m):
        logits, k_new, v_new, probs = M.decode_step(
            CFG, ws, jnp.asarray(kv_k), jnp.asarray(kv_v),
            jnp.asarray(lens), jnp.asarray(toks[n + t : n + t + 1]),
            jnp.asarray([n + t], jnp.int32))
        np.testing.assert_allclose(
            logits[0], full[0, n + t], atol=5e-4, rtol=5e-4,
            err_msg=f"step {t}")
        # Host-side mirror of the in-graph insert.
        kv_k[:, 0, :, n + t] = np.asarray(k_new)[:, 0]
        kv_v[:, 0, :, n + t] = np.asarray(v_new)[:, 0]
        lens += 1
        # probs live on slots [0, n+t]; nothing beyond.
        p = np.asarray(probs)
        assert np.all(p[:, :, :, n + t + 1 :] == 0.0)
        np.testing.assert_allclose(p.sum(-1), 1.0, atol=1e-4)


def test_decode_respects_per_layer_lens(ws):
    """Different lens per layer (post-pruning state) must mask per layer."""
    rng = np.random.default_rng(2)
    L, Hkv, D, C = CFG.n_layers, CFG.n_kv_heads, CFG.d_head, 32
    kv_k = rng.standard_normal((L, 1, Hkv, C, D)).astype(np.float32)
    kv_v = rng.standard_normal((L, 1, Hkv, C, D)).astype(np.float32)
    lens = np.asarray(
        [[4], [8], [12], [16]][: L] if L <= 4 else
        [[4 + 2 * l] for l in range(L)], np.int32)
    tok = jnp.asarray([5], jnp.int32)
    pos = jnp.asarray([20], jnp.int32)
    _, _, _, probs = M.decode_step(
        CFG, ws, jnp.asarray(kv_k), jnp.asarray(kv_v), jnp.asarray(lens),
        tok, pos)
    p = np.asarray(probs)
    for l in range(L):
        live = int(lens[l, 0]) + 1  # incl. the inserted token
        assert np.all(p[l, :, :, live:] == 0.0), f"layer {l}"
        np.testing.assert_allclose(p[l].sum(-1), 1.0, atol=1e-4)


def test_compacted_cache_changes_little_when_dropping_cold_rows(ws):
    """Pruning slots that receive ~no attention must barely change the
    next-token logits (the semantic basis for eviction)."""
    rng = np.random.default_rng(3)
    n, C = 24, 64
    toks = random_tokens(rng, n)
    padded = np.zeros((1, 32), np.int32)
    padded[0, :n] = toks
    _, k_all, v_all, scores = M.prefill(
        CFG, ws, jnp.asarray(padded), jnp.int32(n))

    L, Hkv, D = CFG.n_layers, CFG.n_kv_heads, CFG.d_head
    kv_k = np.zeros((L, 1, Hkv, C, D), np.float32)
    kv_v = np.zeros((L, 1, Hkv, C, D), np.float32)
    kv_k[:, :, :, :32] = np.asarray(k_all)
    kv_v[:, :, :, :32] = np.asarray(v_all)
    lens = np.full((L, 1), n, np.int32)
    tok = jnp.asarray([toks[-1]], jnp.int32)
    pos = jnp.asarray([n], jnp.int32)
    base, _, _, probs = M.decode_step(
        CFG, ws, jnp.asarray(kv_k), jnp.asarray(kv_v), jnp.asarray(lens),
        tok, pos)

    # Evict the 4 least- vs the 4 most-attended slots per layer.
    p = np.asarray(probs)[:, 0].sum(1)  # [L, C]

    def drop(selector):
        kv_k2, kv_v2 = kv_k.copy(), kv_v.copy()
        lens2 = lens.copy()
        for l in range(CFG.n_layers):
            order = np.argsort(p[l, :n])
            keep = np.sort(selector(order))
            kv_k2[l, 0, :, : len(keep)] = kv_k[l, 0][:, keep]
            kv_v2[l, 0, :, : len(keep)] = kv_v[l, 0][:, keep]
            kv_k2[l, 0, :, len(keep) : n] = 0
            kv_v2[l, 0, :, len(keep) : n] = 0
            lens2[l, 0] = len(keep)
        out, _, _, _ = M.decode_step(
            CFG, ws, jnp.asarray(kv_k2), jnp.asarray(kv_v2),
            jnp.asarray(lens2), tok, pos)
        return np.abs(np.asarray(out) - np.asarray(base)).max()

    cold_drift = drop(lambda order: order[4:])   # drop 4 coldest
    hot_drift = drop(lambda order: order[:-4])   # drop 4 hottest
    # The eviction premise: attention mass predicts importance. Even with
    # untrained weights, evicting cold rows must hurt far less than
    # evicting hot rows.
    assert cold_drift < 0.6 * hot_drift, (cold_drift, hot_drift)


def test_weight_specs_order_is_stable():
    names = [n for n, _ in M.weight_specs(CFG)]
    assert names == M.WEIGHT_NAMES
    assert names[0] == "embed" and names[-1] == "lm_head"


def test_tasks_encode_decode_roundtrip():
    import random

    rng = random.Random(0)
    t = tasks.make_task(rng, 8, 3)
    ids = tasks.encode(t.prompt)
    assert tasks.decode_ids(ids) == t.prompt
    inp, tgt = tasks.task_tokens(t)
    assert inp[0] == tasks.BOS and tgt[-1] == tasks.EOS


def test_training_batch_masks_answers_only():
    import random

    rng = random.Random(1)
    toks, mask = tasks.training_batch_ids(rng, 8, 192)
    assert toks.shape == (8, 192) and mask.shape == (8, 192)
    nonempty = 0
    for b in range(8):
        nz = np.nonzero(mask[b])[0]
        if len(nz) == 0:
            continue  # answer fully truncated by seqlen — skipped in loss
        nonempty += 1
        # Mask is one contiguous span (the answer region).
        assert np.all(np.diff(nz) == 1)
        # The last masked position predicts EOS (unless truncated).
        if nz[-1] + 1 < 192:
            assert toks[b, nz[-1] + 1] == tasks.EOS
    assert nonempty >= 6, "most rows should carry answer supervision"
