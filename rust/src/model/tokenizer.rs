//! Char-level tokenizer matching `python/compile/tasks.py` exactly: the
//! vocab (specials + chars) is read from the artifact manifest so the rust
//! request path and the python training path can never drift.

use std::collections::HashMap;

use anyhow::Result;

use super::meta::ModelMeta;

#[derive(Clone, Debug)]
pub struct Tokenizer {
    id_to_char: Vec<Option<char>>,
    char_to_id: HashMap<char, i32>,
    pub pad: i32,
    pub bos: i32,
    pub eos: i32,
}

impl Tokenizer {
    pub fn from_meta(meta: &ModelMeta) -> Result<Tokenizer> {
        Self::new(&meta.specials, &meta.chars)
    }

    pub fn new(specials: &[String], chars: &str) -> Result<Tokenizer> {
        // Special ids follow the manifest's list by *name*, not by a
        // hardcoded position, so non-toy vocabularies (extra specials,
        // reordered lists) terminate and pad correctly.
        let id_of = |name: &str| -> Result<i32> {
            specials
                .iter()
                .position(|s| s == name)
                .map(|i| i as i32)
                .ok_or_else(|| anyhow::anyhow!(
                    "tokenizer specials {specials:?} missing '{name}'"))
        };
        let (pad, bos, eos) = (id_of("<pad>")?, id_of("<bos>")?, id_of("<eos>")?);
        let mut id_to_char: Vec<Option<char>> =
            vec![None; specials.len() + chars.chars().count()];
        let mut char_to_id = HashMap::new();
        for (i, c) in chars.chars().enumerate() {
            let id = (specials.len() + i) as i32;
            id_to_char[id as usize] = Some(c);
            char_to_id.insert(c, id);
        }
        Ok(Tokenizer { id_to_char, char_to_id, pad, bos, eos })
    }

    pub fn vocab_size(&self) -> usize {
        self.id_to_char.len()
    }

    /// Encode text; errors on characters outside the vocab (the server
    /// rejects such requests up front).
    pub fn encode(&self, text: &str) -> Result<Vec<i32>> {
        text.chars()
            .map(|c| {
                self.char_to_id.get(&c).copied().ok_or_else(|| {
                    anyhow::anyhow!("character '{c}' not in model vocab")
                })
            })
            .collect()
    }

    /// BOS + prompt — what prefill consumes.
    pub fn encode_prompt(&self, text: &str) -> Result<Vec<i32>> {
        let mut v = vec![self.bos];
        v.extend(self.encode(text)?);
        Ok(v)
    }

    /// Decode generated ids, stopping at EOS, skipping specials.
    pub fn decode(&self, ids: &[i32]) -> String {
        let mut s = String::new();
        for &id in ids {
            if id == self.eos {
                break;
            }
            if let Some(Some(c)) = self.id_to_char.get(id as usize) {
                s.push(*c);
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok() -> Tokenizer {
        Tokenizer::new(
            &["<pad>".into(), "<bos>".into(), "<eos>".into()],
            "abcdefghijklmnopqrstuvwxyz0123456789:;>?=. ",
        )
        .unwrap()
    }

    #[test]
    fn roundtrip() {
        let t = tok();
        let ids = t.encode("ab:17;cd>99.").unwrap();
        assert_eq!(t.decode(&ids), "ab:17;cd>99.");
    }

    #[test]
    fn ids_match_python_convention() {
        let t = tok();
        // python: CHAR_TO_ID['a'] == 3 (after 3 specials)
        assert_eq!(t.encode("a").unwrap(), vec![3]);
        assert_eq!(t.encode("b").unwrap(), vec![4]);
        assert_eq!(t.vocab_size(), 46);
    }

    #[test]
    fn prompt_has_bos_and_decode_stops_at_eos() {
        let t = tok();
        let p = t.encode_prompt("ab").unwrap();
        assert_eq!(p[0], t.bos);
        let mut ids = t.encode("xy").unwrap();
        ids.push(t.eos);
        ids.extend(t.encode("zz").unwrap());
        assert_eq!(t.decode(&ids), "xy");
    }

    #[test]
    fn rejects_out_of_vocab() {
        assert!(tok().encode("ABC").is_err());
        assert!(tok().encode("日").is_err());
    }

    #[test]
    fn special_ids_follow_names_not_positions() {
        // A non-toy manifest may order or extend the specials list
        // differently; ids must track the names.
        let t = Tokenizer::new(
            &["<unk>".into(), "<eos>".into(), "<pad>".into(), "<bos>".into()],
            "ab",
        )
        .unwrap();
        assert_eq!((t.pad, t.bos, t.eos), (2, 3, 1));
        assert_eq!(t.encode("a").unwrap(), vec![4]);
        // Decode stops at the *named* EOS id.
        assert_eq!(t.decode(&[4, 1, 5]), "a");
        // A vocabulary with a missing special is rejected up front.
        assert!(Tokenizer::new(&["<pad>".into(), "<bos>".into()], "ab").is_err());
    }
}
