pub struct SplitMix64(pub u64);
impl SplitMix64 { pub fn next_u64(&mut self) -> u64 { self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15); let mut z = self.0; z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9); z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB); z ^ (z >> 31) } }
