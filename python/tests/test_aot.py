"""AOT artifact contract tests: the manifest, weight blob and HLO text
must satisfy exactly what rust/src/{model,runtime} assume. Run after
`make artifacts`; skipped cleanly when artifacts are absent.
"""

import json
import os

import numpy as np
import pytest

from compile import aot, model as M, tasks

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
META = os.path.join(ART, "model_meta.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(META), reason="run `make artifacts` first")


@pytest.fixture(scope="module")
def meta():
    with open(META) as f:
        return json.load(f)


def test_manifest_model_dims_match_config(meta):
    cfg = M.ModelConfig()
    m = meta["model"]
    assert m["vocab_size"] == cfg.vocab_size == tasks.VOCAB_SIZE
    assert m["n_layers"] == cfg.n_layers
    assert m["n_kv_heads"] == cfg.n_kv_heads
    assert m["param_count"] == cfg.param_count()


def test_tokenizer_contract(meta):
    t = meta["tokenizer"]
    assert t["specials"] == tasks.SPECIALS
    assert t["chars"] == tasks.CHARS
    assert (t["pad"], t["bos"], t["eos"]) == (tasks.PAD, tasks.BOS, tasks.EOS)


def test_weights_bin_layout(meta):
    path = os.path.join(ART, "weights.bin")
    size = os.path.getsize(path)
    total = sum(w["bytes"] for w in meta["weights"])
    assert size == total
    # Offsets are contiguous and in WEIGHT_NAMES order.
    names = [w["name"] for w in meta["weights"]]
    assert names == M.WEIGHT_NAMES
    off = 0
    for w in meta["weights"]:
        assert w["offset"] == off
        assert w["bytes"] == 4 * int(np.prod(w["shape"]))
        off += w["bytes"]


def test_every_executable_file_exists_and_is_hlo_text(meta):
    for e in meta["executables"]:
        path = os.path.join(ART, e["file"])
        assert os.path.exists(path), e["file"]
        head = open(path).read(200)
        assert "HloModule" in head, f"{e['file']} is not HLO text"


def test_bucket_grid_is_complete(meta):
    names = {e["name"] for e in meta["executables"]}
    for t in meta["prefill_ts"]:
        assert f"prefill_t{t}" in names
        assert f"prefill_t{t}_kv" in names
    for prof, caps in meta["decode_capacities"].items():
        for c in caps:
            for b in meta["decode_batches"][prof]:
                assert f"decode_b{b}_c{c}" in names, (prof, b, c)
                assert f"decode_b{b}_c{c}_q8" in names, (prof, b, c)
                assert f"decode_b{b}_c{c}_q4" in names, (prof, b, c)


def test_decode_param_shapes_match_runtime_expectation(meta):
    cfg = M.ModelConfig()
    nw = len(M.WEIGHT_NAMES)
    by_name = {e["name"]: e for e in meta["executables"]}
    e = by_name["decode_b2_c128"]
    # weights first, then kv_k, kv_v, lens, tokens, positions.
    assert len(e["params"]) == nw + 5
    kv_shape = e["params"][nw]["shape"]
    assert kv_shape == [cfg.n_layers, 2, cfg.n_kv_heads, 128, cfg.d_head]
    assert e["params"][nw + 2]["shape"] == [cfg.n_layers, 2]
    assert e["params"][nw + 2]["dtype"] == "int32"
    assert e["outputs"] == ["logits", "k_new", "v_new", "probs"]


def test_prefill_outputs_contract(meta):
    by_name = {e["name"]: e for e in meta["executables"]}
    e = by_name["prefill_t64"]
    assert e["outputs"] == ["logits", "k_all", "v_all", "scores"]


def test_packed_decode_param_shapes(meta):
    """Kernel-side-dequant variants take the quantized stores' wire layout:
    codes + per-row (q8) / per-group (q4) scales, weights first."""
    cfg = M.ModelConfig()
    L, hkv, dh = cfg.n_layers, cfg.n_kv_heads, cfg.d_head
    nw = len(M.WEIGHT_NAMES)
    by_name = {e["name"]: e for e in meta["executables"]}

    e = by_name["decode_b2_c128_q8"]
    # weights, k_q, k_s, v_q, v_s, lens, tokens, positions.
    assert len(e["params"]) == nw + 7
    assert e["params"][nw]["shape"] == [L, 2, hkv, 128, dh]
    assert e["params"][nw]["dtype"] == "int8"
    assert e["params"][nw + 1]["shape"] == [L, 2, hkv, 128]
    assert e["params"][nw + 1]["dtype"] == "float32"
    assert e["outputs"] == ["logits", "k_new", "v_new", "probs"]

    e = by_name["decode_b2_c128_q4"]
    # weights, k_q, k_s, k_z, v_q, v_s, v_z, lens, tokens, positions.
    assert len(e["params"]) == nw + 9
    assert e["params"][nw]["shape"] == [L, 2, hkv, 128, M.q4_packed(dh)]
    assert e["params"][nw]["dtype"] == "uint8"
    for i in (1, 2):
        assert e["params"][nw + i]["shape"] == [L, 2, hkv, 128,
                                                M.q4_groups(dh)]
        assert e["params"][nw + i]["dtype"] == "float32"


def test_prefill_kv_param_shapes(meta):
    """Incremental prefill takes (prior_k, prior_v, prior_len, tokens,
    length) after the weights, with a PREFILL_KV_CAP-slot prior window."""
    cfg = M.ModelConfig()
    L, hkv, dh = cfg.n_layers, cfg.n_kv_heads, cfg.d_head
    nw = len(M.WEIGHT_NAMES)
    by_name = {e["name"]: e for e in meta["executables"]}
    assert M.PREFILL_KV_CAP == max(meta["prefill_ts"])
    e = by_name["prefill_t64_kv"]
    assert len(e["params"]) == nw + 5
    assert e["params"][nw]["shape"] == [L, 1, hkv, M.PREFILL_KV_CAP, dh]
    assert e["params"][nw + 2]["shape"] == []
    assert e["params"][nw + 2]["dtype"] == "int32"
    assert e["params"][nw + 3]["shape"] == [1, 64]
    assert e["outputs"] == ["logits", "k_new", "v_new", "scores"]


def test_hlo_text_regeneration_is_deterministic():
    """Lowering the same entry point twice yields identical HLO text —
    the property that makes artifact hashes meaningful."""
    cfg = M.ModelConfig()
    entries = aot.build_entry_points(cfg)
    name, fn, specs, _ = next(e for e in entries
                              if e[0] == "decode_b1_c128")
    import jax

    t1 = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    t2 = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert t1 == t2
    assert "HloModule" in t1
