fn main() {}
