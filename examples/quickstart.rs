//! Quickstart: boot the server, submit one reasoning prompt, print the
//! completion. The 60-second tour of the public API.
//!
//!   make artifacts && cargo run --release --example quickstart

use lethe::config::ServingConfig;
use lethe::policy::PolicyKind;
use lethe::server::{GenerateRequest, Server};
use lethe::util::prng::Rng;
use lethe::workload::make_task;

fn main() -> anyhow::Result<()> {
    // 1. Configuration: paper defaults (sparse_ratio=400, recent_ratio=0.3).
    let cfg = ServingConfig::default();

    // 2. Boot: loads AOT artifacts, uploads weights to the PJRT CPU
    //    device, spawns the engine thread. Python is not involved.
    let server = Server::start(cfg, PolicyKind::Lethe)?;

    // 3. A 2-hop chain-of-thought task: "follow ka -> kb -> value".
    let task = make_task(&mut Rng::new(7), 8, 2);
    println!("prompt  : {}", task.prompt);
    println!("expected: {}", task.answer);

    // 4. Generate.
    let resp = server.generate(GenerateRequest {
        prompt: task.prompt.clone(),
        max_new_tokens: 32,
        policy: None, // server default (Lethe)
    })?;
    println!("output  : {}", resp.text);
    println!(
        "{} prompt tokens, {} generated, finish={}, {} prune rounds",
        resp.prompt_tokens, resp.generated_tokens, resp.finish,
        resp.prune_rounds
    );
    Ok(())
}
