"""Synthetic reasoning-task generator (Math500 / MMLU proxies).

The paper evaluates KV-eviction policies on Math500 (multi-step CoT
reasoning) and an 8-subject MMLU slice (factual recall). Neither dataset
nor the DeepSeek-R1-Distill checkpoints are available offline, so we build
task families that stress the *same failure modes* Table 1 measures:

  recall    "k1:v1;k2:v2;...;kN:vN?ki>" -> "vi."          (MMLU proxy)
  multihop  values may themselves be keys; answering "?ka>" requires
            chasing ka -> kb -> ... -> digits, and the model is trained
            to EMIT the chase as chain-of-thought:
            "?ka>" -> "kb>kc>37."                          (Math500 proxy)

Eviction-policy sensitivity: the pair that resolves hop h only becomes
relevant *after* hop h-1 has been generated — exactly the "temporal
inconsistency in token relevance" Lethe targets. A sliding window
(StreamingLLM) loses early pairs; a one-shot heavy-hitter pick (H2O)
keeps pairs that were hot during prefill, not the ones a later hop needs.

The token vocabulary here MUST match rust/src/model/tokenizer.rs; it is
exported into artifacts/model_meta.json by aot.py and loaded by rust.
"""

from __future__ import annotations

import dataclasses
import random
from typing import List, Tuple

# --- vocabulary ---------------------------------------------------------
# Order is load-bearing: ids are positions in this string, specials first.
PAD, BOS, EOS = 0, 1, 2
SPECIALS = ["<pad>", "<bos>", "<eos>"]
CHARS = "abcdefghijklmnopqrstuvwxyz0123456789:;>?=. "
VOCAB = SPECIALS + list(CHARS)
VOCAB_SIZE = len(VOCAB)  # 3 + 43 = 46
CHAR_TO_ID = {c: i + len(SPECIALS) for i, c in enumerate(CHARS)}
ID_TO_CHAR = {i + len(SPECIALS): c for i, c in enumerate(CHARS)}


def encode(text: str) -> List[int]:
    return [CHAR_TO_ID[c] for c in text]


def decode_ids(ids) -> str:
    out = []
    for i in ids:
        i = int(i)
        if i == EOS:
            break
        if i >= len(SPECIALS):
            out.append(ID_TO_CHAR[i])
    return "".join(out)


# --- task generation ----------------------------------------------------

KEY_LETTERS = "abcdefghijklmnopqrstuvwxyz"


@dataclasses.dataclass
class Task:
    prompt: str        # "ab:17;cd:ab;...?cd>"
    answer: str        # full expected generation, e.g. "ab>17."
    final: str         # the 2-digit final value, e.g. "17"
    hops: int
    n_pairs: int


def _fresh_keys(rng: random.Random, n: int) -> List[str]:
    keys = set()
    while len(keys) < n:
        keys.add(rng.choice(KEY_LETTERS) + rng.choice(KEY_LETTERS))
    return list(keys)


def make_task(rng: random.Random, n_pairs: int, hops: int) -> Task:
    """Build one task. `hops`=1 is plain recall; hops>=2 chains keys."""
    assert 1 <= hops <= n_pairs
    keys = _fresh_keys(rng, n_pairs)
    # The chain: keys[0] -> keys[1] -> ... -> keys[hops-1] -> value.
    final_val = f"{rng.randrange(10, 100)}"
    mapping = {}
    for i in range(hops - 1):
        mapping[keys[i]] = keys[i + 1]
    mapping[keys[hops - 1]] = final_val
    # Distractor pairs map to plain values.
    for k in keys[hops:]:
        mapping[k] = f"{rng.randrange(10, 100)}"
    # Shuffle presentation order so chain position is random.
    order = keys[:]
    rng.shuffle(order)
    pairs = ";".join(f"{k}:{mapping[k]}" for k in order)
    prompt = f"{pairs}?{keys[0]}>"
    # CoT answer: emit each intermediate key then the final value.
    steps = [f"{keys[i]}>" for i in range(1, hops)]
    answer = "".join(steps) + final_val + "."
    return Task(prompt=prompt, answer=answer, final=final_val,
                hops=hops, n_pairs=n_pairs)


# (name, n_pairs, hops): 8 "subjects" mirroring the paper's MMLU slice +
# math500. recall-N = MMLU-like; multihop = Math500-like CoT.
SUBJECTS: List[Tuple[str, int, int]] = [
    ("recall-8", 8, 1),
    ("recall-16", 16, 1),
    ("recall-24", 24, 1),
    ("hop2-8", 8, 2),
    ("hop2-16", 16, 2),
    ("hop3-8", 8, 3),
    ("hop3-16", 16, 3),
    ("hop4-16", 16, 4),
]


def training_example(rng: random.Random, max_pairs: int = 24,
                     max_hops: int = 4) -> Task:
    n_pairs = rng.randrange(4, max_pairs + 1)
    hops = rng.randrange(1, min(max_hops, n_pairs) + 1)
    return make_task(rng, n_pairs, hops)


def task_tokens(task: Task) -> Tuple[List[int], List[int]]:
    """(input ids incl BOS+prompt, target ids incl answer+EOS)."""
    return [BOS] + encode(task.prompt), encode(task.answer) + [EOS]


def training_batch_ids(rng: random.Random, batch: int, seqlen: int,
                       max_pairs: int = 24, max_hops: int = 4):
    """Token/loss-mask arrays for LM training: loss only on answer span."""
    import numpy as np

    toks = np.zeros((batch, seqlen), dtype=np.int32)  # PAD = 0
    mask = np.zeros((batch, seqlen), dtype=np.float32)
    for b in range(batch):
        t = training_example(rng, max_pairs, max_hops)
        inp, tgt = task_tokens(t)
        ids = (inp + tgt)[:seqlen]
        toks[b, : len(ids)] = ids
        lo = min(len(inp), seqlen)
        hi = min(len(inp) + len(tgt), seqlen)
        # mask marks positions whose NEXT token is part of the answer
        mask[b, max(lo - 1, 0) : max(hi - 1, 0)] = 1.0
    return toks, mask
