fn main() {}
