//! Trace-driven multi-tenant soak bench: replay the pinned mixed-tenant
//! trace (interactive short-prompt + batch long-reasoning,
//! `workload::trace::pinned`) through the deterministic virtual-time
//! scheduler twin (`sim::replay`) and emit the SLO trail the CI
//! `bench-soak` job gates on:
//!
//!   * `sim_soak_g{1,3}_<class>` — per-class p50/p95/p99 TTFT/TPOT/e2e,
//!     SLO attainment, goodput and preemption-fairness counters for the
//!     1-group and 3-group replays;
//!   * `sim_soak_g{1,3}_aggregate` — makespan, tokens/s, preemption and
//!     swap totals, deadline aborts, plus the trace fingerprint (the CI
//!     gate refuses to compare runs of different traces);
//!   * `swap_sweep_thr<T>` — the `swap_threshold_bytes_per_token` sweep
//!     under a budget that binds, the data behind the tuned 4096
//!     default in `SchedulerConfig`;
//!   * `real_soak_<class>` — the same trace through the real scheduler
//!     (`bench_support::replay_trace`) when AOT artifacts are present;
//!     skipped with a notice otherwise (CI has no artifacts, so the
//!     gate reads only the `sim_*` rows).
//!
//! Everything lands in `bench_results/BENCH_soak.json` via
//! `write_bench_json`; the committed reference lives in
//! `rust/bench_baselines/BENCH_soak.json`.

use lethe::bench_support::{
    replay_trace, try_engine, write_bench_json, BenchJsonRow,
};
use lethe::config::ServingConfig;
use lethe::policy::PolicyKind;
use lethe::sim::replay::{replay, ReplayConfig, ReplayReport};
use lethe::util::json::Json;
use lethe::workload::slo::{summarize, table, ClassSlo};
use lethe::workload::trace::{generate, pinned, trace_fingerprint};

/// Per-class rows + one aggregate row for a replay under `tag`.
fn report_rows(
    tag: &str,
    rep: &ReplayReport,
    fingerprint: u64,
) -> (Vec<ClassSlo>, Vec<BenchJsonRow>) {
    let slos = summarize(&rep.outcomes, rep.makespan_s);
    let mut rows: Vec<BenchJsonRow> = slos
        .iter()
        .map(|s| BenchJsonRow {
            name: format!("{tag}_{}", s.class),
            kv_format: "sim".into(),
            tokens_per_s: rep.tokens_per_s(),
            upload_bytes_per_step: 0,
            extra: s.to_fields(),
        })
        .collect();
    rows.push(BenchJsonRow {
        name: format!("{tag}_aggregate"),
        kv_format: "sim".into(),
        tokens_per_s: rep.tokens_per_s(),
        upload_bytes_per_step: 0,
        extra: vec![
            ("makespan_s".to_string(), Json::num(rep.makespan_s)),
            (
                "generated_tokens".to_string(),
                Json::from(rep.generated_tokens as usize),
            ),
            (
                "prefill_tokens".to_string(),
                Json::from(rep.prefill_tokens as usize),
            ),
            (
                "preemptions".to_string(),
                Json::from(rep.preemptions as usize),
            ),
            (
                "swap_preemptions".to_string(),
                Json::from(rep.swap_preemptions as usize),
            ),
            (
                "swap_bytes_out".to_string(),
                Json::from(rep.swap_bytes_out as usize),
            ),
            (
                "deadline_aborts".to_string(),
                Json::from(rep.deadline_aborts as usize),
            ),
            ("ticks".to_string(), Json::from(rep.ticks as usize)),
            (
                "trace_fingerprint".to_string(),
                Json::str(&format!("{fingerprint:016x}")),
            ),
        ],
    });
    (slos, rows)
}

fn main() -> anyhow::Result<()> {
    let spec = pinned();
    let trace = generate(&spec);
    let fp = trace_fingerprint(&trace);
    println!(
        "=== soak trace: {} requests over {:.0}s, fingerprint {fp:016x} ===",
        trace.len(),
        spec.horizon_s
    );

    let mut rows: Vec<BenchJsonRow> = Vec::new();

    // --- 1-group and 3-group virtual replays ----------------------------
    let rep1 = replay(&trace, &ReplayConfig::default());
    let (slos1, r1) = report_rows("sim_soak_g1", &rep1, fp);
    println!("\n--- 1 group ({:.1} virtual s) ---", rep1.makespan_s);
    print!("{}", table(&slos1));
    rows.extend(r1);

    let rep3 = replay(
        &trace,
        &ReplayConfig { groups: 3, ..ReplayConfig::default() },
    );
    let (slos3, r3) = report_rows("sim_soak_g3", &rep3, fp);
    println!("\n--- 3 groups ({:.1} virtual s) ---", rep3.makespan_s);
    print!("{}", table(&slos3));
    rows.extend(r3);

    // --- swap-threshold sweep (the data behind the 4096 default) --------
    // A budget that binds on this trace, so the swap-vs-recompute split
    // actually matters; threshold 0 is recompute-only, 65536 swaps
    // everything the sim's byte rate can express.
    println!("\n--- swap_threshold_bytes_per_token sweep (budget 192KiB) ---");
    println!(
        "{:>9} {:>8} {:>6} {:>10} {:>10} {:>9} {:>8}",
        "threshold", "preempt", "swap", "prefill_tk", "swap_bytes",
        "inter p95", "tok/s"
    );
    for thr in [0usize, 256, 1024, 4096, 16384, 65536] {
        let cfg = ReplayConfig {
            kv_budget_bytes: 192 * 1024,
            swap_threshold_bytes_per_token: thr,
            ..ReplayConfig::default()
        };
        let rep = replay(&trace, &cfg);
        let slos = summarize(&rep.outcomes, rep.makespan_s);
        let inter_p95 = slos
            .iter()
            .find(|s| s.class == "interactive")
            .map_or(0.0, |s| s.ttft.p95);
        println!(
            "{:>9} {:>8} {:>6} {:>10} {:>10} {:>8.0}ms {:>8.1}",
            thr,
            rep.preemptions,
            rep.swap_preemptions,
            rep.prefill_tokens,
            rep.swap_bytes_out,
            inter_p95 * 1e3,
            rep.tokens_per_s()
        );
        rows.push(BenchJsonRow {
            name: format!("swap_sweep_thr{thr}"),
            kv_format: "sim".into(),
            tokens_per_s: rep.tokens_per_s(),
            upload_bytes_per_step: 0,
            extra: vec![
                ("threshold".to_string(), Json::from(thr)),
                (
                    "preemptions".to_string(),
                    Json::from(rep.preemptions as usize),
                ),
                (
                    "swap_preemptions".to_string(),
                    Json::from(rep.swap_preemptions as usize),
                ),
                (
                    "prefill_tokens".to_string(),
                    Json::from(rep.prefill_tokens as usize),
                ),
                (
                    "swap_bytes_out".to_string(),
                    Json::from(rep.swap_bytes_out as usize),
                ),
                (
                    "interactive_ttft_p95_s".to_string(),
                    Json::num(inter_p95),
                ),
            ],
        });
    }

    // --- real-scheduler replay (artifact-gated) -------------------------
    if let Some((mut engine, tok)) = try_engine(ServingConfig::default()) {
        let (outcomes, makespan_s) = replay_trace(
            &mut engine,
            &tok,
            PolicyKind::Lethe,
            &trace,
            0.1,
        )?;
        let slos = summarize(&outcomes, makespan_s);
        println!("\n--- real scheduler ({makespan_s:.1}s wall, 10x compressed) ---");
        print!("{}", table(&slos));
        let gen_tokens: usize = slos
            .iter()
            .map(|s| (s.goodput_tok_s * makespan_s) as usize)
            .sum();
        for s in &slos {
            rows.push(BenchJsonRow {
                name: format!("real_soak_{}", s.class),
                kv_format: engine.metrics.kv_format.clone(),
                tokens_per_s: gen_tokens as f64 / makespan_s.max(1e-9),
                upload_bytes_per_step: 0,
                extra: s.to_fields(),
            });
        }
    }

    write_bench_json("soak", &rows)?;
    Ok(())
}
