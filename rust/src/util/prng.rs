//! Deterministic PRNGs for workload generation, property tests and
//! tie-breaking. SplitMix64 (seeding) + xoshiro256** (stream); both are
//! tiny, fast, and reproducible across runs — a requirement for the
//! experiment harness (every bench records its seed).

/// SplitMix64 — used to expand a user seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64(pub u64);

impl SplitMix64 {
    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the workhorse generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64(seed);
        Rng { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponential with the given rate (inter-arrival times for the
    /// Poisson request process in `workload`).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -self.f64().max(1e-300).ln() / rate
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element by reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fork a decorrelated child stream (for per-request seeds).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(1);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut r = Rng::new(2);
        let mean: f64 = (0..10_000).map(|_| r.f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / xs.len() as f64;
        assert!(m.abs() < 0.05, "mean={m}");
        assert!((v - 1.0).abs() < 0.05, "var={v}");
    }

    #[test]
    fn exponential_mean_matches_rate() {
        let mut r = Rng::new(4);
        let rate = 4.0;
        let mean: f64 =
            (0..20_000).map(|_| r.exponential(rate)).sum::<f64>() / 20_000.0;
        assert!((mean - 1.0 / rate).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
