//! Pluggable KV row-storage backends: the [`KvStore`] contract, the three
//! uniform stores ([`DenseF32`], [`QuantI8`], [`QuantI4`]) and the
//! per-layer [`KvBackend`] container the engine actually holds.
//!
//! [`super::GroupCache`] owns all *bookkeeping* — per-(layer, slot)
//! lengths, original positions, accumulated scores and the delta-pack
//! epoch protocol — and delegates the *row storage* (the K/V payload
//! bytes) to a [`KvBackend`]. The backend contract is deliberately small:
//!
//!   * [`KvStore::write_row`]    — store one token's `[Hkv, D]` K/V rows,
//!   * [`KvStore::load_rows`]    — bulk prefill load of one (l, b, h) block,
//!   * [`KvStore::gather_rows`]  — the front-packing retention gather,
//!   * [`KvStore::swap_rows`]    — slot swap (scheduler reap path),
//!   * [`KvStore::read_rows`]    — materialize a row range as f32 into the
//!                                 upload scratch (memcpy for dense,
//!                                 dequantize for quantized storage).
//!
//! Because the epoch/rewrite watermarks live in `GroupCache`, the
//! incremental delta-pack protocol is backend-independent: an append-only
//! step copies (or dequantizes) only the newly inserted rows regardless
//! of how the backend holds them. The only backend obligation is that
//! [`KvStore::read_rows`] is *deterministic* for a given stored state —
//! including dead rows past the live length — so a delta-maintained
//! scratch stays bit-identical to a fresh full pack.
//!
//! Three row stores ship today:
//!   * [`DenseF32`] — plain f32 rows, 4 B/elem (the serving default),
//!   * [`QuantI8`]  — per-row symmetric int8, 1 B/elem + one f32 scale
//!     per (head, tensor) row (~3.9× smaller at D = 128),
//!   * [`QuantI4`]  — group-wise asymmetric int4 (KIVI-style: groups of
//!     [`crate::kvcache::quant::Q4_GROUP`] along the head dim, per-group
//!     f32 scale + zero, two codes per byte; ~5.3× smaller at D = 128).
//!
//! [`KvBackend`] is a **per-layer** container over those stores: each
//! model layer owns an independently formatted single-layer store, so a
//! sparsity-directed mixed map (`kv.layer_formats` / `kv.mixed`) can keep
//! dense layers at full fidelity while compressing high-sparsity layers.
//! A uniform `kv.format` is simply the map with every layer equal.
//! Dispatch is by enum rather than `dyn` so the per-token hot path stays
//! devirtualized; future stores (fp8, pinned/device-resident scratch)
//! add a [`LayerKv`] variant and an impl.

use super::quant::{
    dequantize_row_q4, dequantize_span, kv_row_bytes, q4_groups,
    q4_packed_bytes, quantize_row_into, quantize_row_q4_into, KvFormat,
};
use super::CacheDims;

/// The storage contract between [`super::GroupCache`] and a backend.
/// Row coordinates are (layer `l`, slot `b`, head `h`, row `c`); all
/// bounds are validated by the cache before a call, so implementations
/// may assume `l/b/h/c` are in range and slices are correctly sized.
pub trait KvStore {
    /// Dimensions of the cache this store was allocated for.
    fn dims(&self) -> &CacheDims;

    /// Storage format of layer `l` (drives Table 2 byte accounting —
    /// per layer, because a mixed map prices layers differently).
    fn layer_format(&self, l: usize) -> KvFormat;

    /// Bytes to hold one cached token row (K + V, all heads) of layer
    /// `l` as stored.
    fn layer_row_bytes(&self, l: usize) -> usize {
        let d = self.dims();
        kv_row_bytes(d.kv_heads, d.d_head, self.layer_format(l))
    }

    /// Bytes the same row would occupy on the dense f32 store (the
    /// "f32-equivalent" column of Table 2; format- and layer-independent).
    fn f32_row_bytes(&self) -> usize {
        let d = self.dims();
        kv_row_bytes(d.kv_heads, d.d_head, KvFormat::F32)
    }

    /// Store one token's K/V rows (layout `[Hkv, D]` each) at row `c` of
    /// (l, b), for every head.
    fn write_row(&mut self, l: usize, b: usize, c: usize, k_row: &[f32], v_row: &[f32]);

    /// Bulk-load `len` contiguous rows (`[len, D]` each) into rows
    /// `0..len` of (l, b, h) — the prefill path.
    fn load_rows(
        &mut self,
        l: usize,
        b: usize,
        h: usize,
        k_rows: &[f32],
        v_rows: &[f32],
        len: usize,
    );

    /// Front-packing gather by ascending, deduplicated source row index
    /// (the retention eviction), applied to every head of (l, b).
    fn gather_rows(&mut self, l: usize, b: usize, keep: &[usize]);

    /// Swap the first `n` rows of slots `a` and `b` at layer `l`, every
    /// head (the scheduler's reap/front-pack path).
    fn swap_rows(&mut self, l: usize, a: usize, b: usize, n: usize);

    /// Materialize rows `from..to` of (l, b, h) as f32 into `dst`
    /// (`(to - from) * D` values): memcpy for dense storage, dequantize
    /// for quantized. Must be deterministic for a given stored state,
    /// dead rows included (the delta-pack bit-identity invariant).
    #[allow(clippy::too_many_arguments)]
    fn read_rows(
        &self,
        l: usize,
        b: usize,
        h: usize,
        which_v: bool,
        from: usize,
        to: usize,
        dst: &mut [f32],
    );

    /// Copy rows `from..to` of (l, b, h) in **stored packed form** into a
    /// kernel-side-dequant upload image: quantized codes into
    /// `codes_dst`, per-row (q8) / per-group (q4) f32 scales into
    /// `scales_dst`, and — q4 only — zero-points into `zeros_dst` (q8
    /// passes an empty span). Spans are tightly sized by the caller from
    /// [`crate::kvcache::quant::packed_codes_per_row`] /
    /// [`crate::kvcache::quant::packed_scales_per_row`]. Same determinism
    /// obligation as [`KvStore::read_rows`], dead rows included — the
    /// packed delta-pack protocol relies on it. Dense f32 layers have no
    /// packed form: the default implementation panics, and callers must
    /// route them through the f32 image ([`KvStore::read_rows`]).
    #[allow(clippy::too_many_arguments)]
    fn export_packed_rows(
        &self,
        l: usize,
        b: usize,
        h: usize,
        which_v: bool,
        from: usize,
        to: usize,
        codes_dst: &mut [u8],
        scales_dst: &mut [f32],
        zeros_dst: &mut [f32],
    ) {
        let _ = (l, b, h, which_v, from, to, codes_dst, scales_dst, zeros_dst);
        panic!("this layer's storage has no packed (quantized) form");
    }

    /// Serialize the first `len` rows of slot `b` at layer `l` — every
    /// head's K and V payloads plus any quantization side data — into
    /// `out` (appending) **at stored precision**: raw mantissa bytes
    /// and little-endian f32 parameters, no re-encoding. The format is
    /// private to a (dims, layer format) pair and is the exact inverse
    /// of [`KvStore::import_rows`], so an export → import round trip
    /// restores the stored state verbatim and every subsequent
    /// [`KvStore::read_rows`] is bit-identical — which is what makes
    /// swap-to-host preemption resume token-identical under greedy
    /// decode. Appends exactly `len * layer_row_bytes(l)` bytes.
    fn export_rows(&self, l: usize, b: usize, len: usize, out: &mut Vec<u8>);

    /// Inverse of [`KvStore::export_rows`]: load `len` rows into slot
    /// `b` of layer `l` from the front of `bytes`, which must carry an
    /// encoding produced by the same dims and layer format. Returns the
    /// bytes consumed (`len * layer_row_bytes(l)`).
    fn import_rows(&mut self, l: usize, b: usize, len: usize, bytes: &[u8])
        -> usize;
}

/// Append `src` as little-endian f32 bytes (host-swap serialization).
fn push_f32s(out: &mut Vec<u8>, src: &[f32]) {
    for x in src {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Inverse of [`push_f32s`]: fill `dst` from the front of `bytes` and
/// return the bytes consumed.
fn pull_f32s(bytes: &[u8], dst: &mut [f32]) -> usize {
    for (i, x) in dst.iter_mut().enumerate() {
        let o = i * 4;
        *x = f32::from_le_bytes([
            bytes[o],
            bytes[o + 1],
            bytes[o + 2],
            bytes[o + 3],
        ]);
    }
    dst.len() * 4
}

/// Flat element offset of row (l, b, h, c) in a `[L, B, Hkv, Cmax, D]`
/// element buffer.
#[inline]
fn dense_off(dims: &CacheDims, l: usize, b: usize, h: usize, c: usize) -> usize {
    let CacheDims { batch, kv_heads, capacity, d_head, .. } = *dims;
    (((l * batch + b) * kv_heads + h) * capacity + c) * d_head
}

/// Flat *row* index of (l, b, h, c) in a `[L, B, Hkv, Cmax]` side array
/// (per-row scales, per-row group parameters, …).
#[inline]
fn quant_idx(dims: &CacheDims, l: usize, b: usize, h: usize, c: usize) -> usize {
    let CacheDims { batch, kv_heads, capacity, .. } = *dims;
    ((l * batch + b) * kv_heads + h) * capacity + c
}

/// Dense f32 row storage: conceptually `[L, B, Hkv, Cmax, D]` row-major
/// for K and V each. This is exactly the storage the pre-backend
/// `GroupCache` carried inline.
#[derive(Clone)]
pub struct DenseF32 {
    dims: CacheDims,
    k: Vec<f32>,
    v: Vec<f32>,
}

impl DenseF32 {
    /// Allocate zeroed dense storage for `dims`.
    pub fn new(dims: CacheDims) -> DenseF32 {
        let CacheDims { layers, batch, kv_heads, capacity, d_head } = dims;
        let n = layers * batch * kv_heads * capacity * d_head;
        DenseF32 { dims, k: vec![0.0; n], v: vec![0.0; n] }
    }

    pub(super) fn raw(&mut self) -> RawKv {
        RawKv::Dense { k: self.k.as_mut_ptr(), v: self.v.as_mut_ptr() }
    }
}

impl KvStore for DenseF32 {
    fn dims(&self) -> &CacheDims {
        &self.dims
    }

    fn layer_format(&self, _l: usize) -> KvFormat {
        KvFormat::F32
    }

    fn write_row(&mut self, l: usize, b: usize, c: usize, k_row: &[f32], v_row: &[f32]) {
        let dims = self.dims;
        let raw = self.raw();
        // SAFETY: `&mut self` grants exclusive access to every slot.
        unsafe { raw.write_row(&dims, l, b, c, k_row, v_row) }
    }

    fn load_rows(
        &mut self,
        l: usize,
        b: usize,
        h: usize,
        k_rows: &[f32],
        v_rows: &[f32],
        len: usize,
    ) {
        let n = len * self.dims.d_head;
        let off = dense_off(&self.dims, l, b, h, 0);
        self.k[off..off + n].copy_from_slice(&k_rows[..n]);
        self.v[off..off + n].copy_from_slice(&v_rows[..n]);
    }

    fn gather_rows(&mut self, l: usize, b: usize, keep: &[usize]) {
        let dims = self.dims;
        let raw = self.raw();
        // SAFETY: `&mut self` grants exclusive access to every slot.
        unsafe { raw.gather_rows(&dims, l, b, keep) }
    }

    fn swap_rows(&mut self, l: usize, a: usize, b: usize, n: usize) {
        let dims = self.dims;
        let raw = self.raw();
        // SAFETY: `&mut self` grants exclusive access to every slot.
        unsafe { raw.swap_rows(&dims, l, a, b, n) }
    }

    #[allow(clippy::too_many_arguments)]
    fn read_rows(
        &self,
        l: usize,
        b: usize,
        h: usize,
        which_v: bool,
        from: usize,
        to: usize,
        dst: &mut [f32],
    ) {
        let n = (to - from) * self.dims.d_head;
        let off = dense_off(&self.dims, l, b, h, from);
        let src = if which_v { &self.v } else { &self.k };
        dst[..n].copy_from_slice(&src[off..off + n]);
    }

    fn export_rows(&self, l: usize, b: usize, len: usize, out: &mut Vec<u8>) {
        let n = len * self.dims.d_head;
        for h in 0..self.dims.kv_heads {
            let off = dense_off(&self.dims, l, b, h, 0);
            push_f32s(out, &self.k[off..off + n]);
            push_f32s(out, &self.v[off..off + n]);
        }
    }

    fn import_rows(&mut self, l: usize, b: usize, len: usize, bytes: &[u8])
        -> usize
    {
        let n = len * self.dims.d_head;
        let mut used = 0;
        for h in 0..self.dims.kv_heads {
            let off = dense_off(&self.dims, l, b, h, 0);
            used += pull_f32s(&bytes[used..], &mut self.k[off..off + n]);
            used += pull_f32s(&bytes[used..], &mut self.v[off..off + n]);
        }
        used
    }
}

/// Per-row symmetric int8 storage: flat i8 mantissas laid out exactly
/// like the dense backend (`[L, B, Hkv, Cmax, D]`, 1 B/elem) plus one
/// f32 scale per (layer, slot, head, row, tensor) in `[L, B, Hkv, Cmax]`
/// side arrays. Everything is allocated once in [`QuantI8::new`] — the
/// per-token insert quantizes in place with zero heap traffic, and the
/// stored footprint is exactly what [`kv_row_bytes`] reports
/// (`d_head + 4` bytes per head-tensor row), so Table 2's "actual q8
/// bytes" column is honest. Quantization happens at insert/prefill
/// time; [`KvStore::read_rows`] dequantizes into the f32 upload
/// scratch, so the delta-pack protocol pays the dequant cost only for
/// rows that actually changed. Zero-initialized scales make every
/// never-written row dequantize to exact zeros (read determinism).
#[derive(Clone)]
pub struct QuantI8 {
    dims: CacheDims,
    k_q: Vec<i8>,
    v_q: Vec<i8>,
    k_s: Vec<f32>,
    v_s: Vec<f32>,
}

impl QuantI8 {
    /// Allocate zeroed int8 storage for `dims`.
    pub fn new(dims: CacheDims) -> QuantI8 {
        let CacheDims { layers, batch, kv_heads, capacity, d_head } = dims;
        let rows = layers * batch * kv_heads * capacity;
        QuantI8 {
            dims,
            k_q: vec![0; rows * d_head],
            v_q: vec![0; rows * d_head],
            k_s: vec![0.0; rows],
            v_s: vec![0.0; rows],
        }
    }

    pub(super) fn raw(&mut self) -> RawKv {
        RawKv::Quant {
            k_q: self.k_q.as_mut_ptr(),
            v_q: self.v_q.as_mut_ptr(),
            k_s: self.k_s.as_mut_ptr(),
            v_s: self.v_s.as_mut_ptr(),
        }
    }
}

impl KvStore for QuantI8 {
    fn dims(&self) -> &CacheDims {
        &self.dims
    }

    fn layer_format(&self, _l: usize) -> KvFormat {
        KvFormat::QuantI8
    }

    fn write_row(&mut self, l: usize, b: usize, c: usize, k_row: &[f32], v_row: &[f32]) {
        let dims = self.dims;
        let raw = self.raw();
        // SAFETY: `&mut self` grants exclusive access to every slot.
        unsafe { raw.write_row(&dims, l, b, c, k_row, v_row) }
    }

    fn load_rows(
        &mut self,
        l: usize,
        b: usize,
        h: usize,
        k_rows: &[f32],
        v_rows: &[f32],
        len: usize,
    ) {
        let d = self.dims.d_head;
        for c in 0..len {
            let off = dense_off(&self.dims, l, b, h, c);
            let si = quant_idx(&self.dims, l, b, h, c);
            self.k_s[si] = quantize_row_into(
                &k_rows[c * d..(c + 1) * d],
                &mut self.k_q[off..off + d],
            );
            self.v_s[si] = quantize_row_into(
                &v_rows[c * d..(c + 1) * d],
                &mut self.v_q[off..off + d],
            );
        }
    }

    fn gather_rows(&mut self, l: usize, b: usize, keep: &[usize]) {
        let dims = self.dims;
        let raw = self.raw();
        // SAFETY: `&mut self` grants exclusive access to every slot.
        unsafe { raw.gather_rows(&dims, l, b, keep) }
    }

    fn swap_rows(&mut self, l: usize, a: usize, b: usize, n: usize) {
        let dims = self.dims;
        let raw = self.raw();
        // SAFETY: `&mut self` grants exclusive access to every slot.
        unsafe { raw.swap_rows(&dims, l, a, b, n) }
    }

    #[allow(clippy::too_many_arguments)]
    fn read_rows(
        &self,
        l: usize,
        b: usize,
        h: usize,
        which_v: bool,
        from: usize,
        to: usize,
        dst: &mut [f32],
    ) {
        let d = self.dims.d_head;
        let (q, s) = if which_v {
            (&self.v_q, &self.v_s)
        } else {
            (&self.k_q, &self.k_s)
        };
        for c in from..to {
            let off = dense_off(&self.dims, l, b, h, c);
            let si = quant_idx(&self.dims, l, b, h, c);
            // Never-written rows have scale 0 ⇒ exact zeros, so a fresh
            // pack and a delta-maintained scratch agree byte-for-byte.
            dequantize_span(
                &q[off..off + d],
                s[si],
                &mut dst[(c - from) * d..(c - from + 1) * d],
            );
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn export_packed_rows(
        &self,
        l: usize,
        b: usize,
        h: usize,
        which_v: bool,
        from: usize,
        to: usize,
        codes_dst: &mut [u8],
        scales_dst: &mut [f32],
        zeros_dst: &mut [f32],
    ) {
        debug_assert!(zeros_dst.is_empty(), "q8 rows carry no zero-points");
        let _ = zeros_dst;
        let d = self.dims.d_head;
        let n = to - from;
        let off = dense_off(&self.dims, l, b, h, from);
        let si = quant_idx(&self.dims, l, b, h, from);
        let (q, s) = if which_v {
            (&self.v_q, &self.v_s)
        } else {
            (&self.k_q, &self.k_s)
        };
        for (dst, &src) in codes_dst[..n * d].iter_mut().zip(&q[off..off + n * d]) {
            *dst = src as u8;
        }
        scales_dst[..n].copy_from_slice(&s[si..si + n]);
    }

    fn export_rows(&self, l: usize, b: usize, len: usize, out: &mut Vec<u8>) {
        let n = len * self.dims.d_head;
        for h in 0..self.dims.kv_heads {
            let off = dense_off(&self.dims, l, b, h, 0);
            let si = quant_idx(&self.dims, l, b, h, 0);
            out.extend(self.k_q[off..off + n].iter().map(|&x| x as u8));
            out.extend(self.v_q[off..off + n].iter().map(|&x| x as u8));
            push_f32s(out, &self.k_s[si..si + len]);
            push_f32s(out, &self.v_s[si..si + len]);
        }
    }

    fn import_rows(&mut self, l: usize, b: usize, len: usize, bytes: &[u8])
        -> usize
    {
        let n = len * self.dims.d_head;
        let mut used = 0;
        for h in 0..self.dims.kv_heads {
            let off = dense_off(&self.dims, l, b, h, 0);
            let si = quant_idx(&self.dims, l, b, h, 0);
            for (i, q) in self.k_q[off..off + n].iter_mut().enumerate() {
                *q = bytes[used + i] as i8;
            }
            used += n;
            for (i, q) in self.v_q[off..off + n].iter_mut().enumerate() {
                *q = bytes[used + i] as i8;
            }
            used += n;
            used += pull_f32s(&bytes[used..], &mut self.k_s[si..si + len]);
            used += pull_f32s(&bytes[used..], &mut self.v_s[si..si + len]);
        }
        used
    }
}

/// Group-wise asymmetric int4 storage (KIVI-style): each (layer, slot,
/// head, row, tensor) row of D floats is split into
/// [`crate::kvcache::quant::Q4_GROUP`]-element groups along the head
/// dim; codes are packed two nibbles per byte in `[L, B, Hkv, Cmax,
/// ceil(D/2)]` buffers, and each group keeps an f32 (scale, zero) pair
/// in `[L, B, Hkv, Cmax, G]` side arrays (`G = ceil(D/32)`). As with
/// [`QuantI8`], everything is allocated once in [`QuantI4::new`], the
/// per-token insert quantizes in place with zero heap traffic, the
/// stored footprint matches [`kv_row_bytes`] exactly, and
/// zero-initialized buffers make never-written rows dequantize to exact
/// zeros (codes 0 × scale 0 + zero 0), which is what keeps
/// [`KvStore::read_rows`] deterministic over dead rows.
#[derive(Clone)]
pub struct QuantI4 {
    dims: CacheDims,
    k_q: Vec<u8>,
    v_q: Vec<u8>,
    k_s: Vec<f32>,
    v_s: Vec<f32>,
    k_z: Vec<f32>,
    v_z: Vec<f32>,
}

impl QuantI4 {
    /// Allocate zeroed group-wise int4 storage for `dims`.
    pub fn new(dims: CacheDims) -> QuantI4 {
        let CacheDims { layers, batch, kv_heads, capacity, d_head } = dims;
        let rows = layers * batch * kv_heads * capacity;
        let packed = q4_packed_bytes(d_head);
        let groups = q4_groups(d_head);
        QuantI4 {
            dims,
            k_q: vec![0; rows * packed],
            v_q: vec![0; rows * packed],
            k_s: vec![0.0; rows * groups],
            v_s: vec![0.0; rows * groups],
            k_z: vec![0.0; rows * groups],
            v_z: vec![0.0; rows * groups],
        }
    }

    pub(super) fn raw(&mut self) -> RawKv {
        RawKv::Q4 {
            k_q: self.k_q.as_mut_ptr(),
            v_q: self.v_q.as_mut_ptr(),
            k_s: self.k_s.as_mut_ptr(),
            v_s: self.v_s.as_mut_ptr(),
            k_z: self.k_z.as_mut_ptr(),
            v_z: self.v_z.as_mut_ptr(),
        }
    }
}

impl KvStore for QuantI4 {
    fn dims(&self) -> &CacheDims {
        &self.dims
    }

    fn layer_format(&self, _l: usize) -> KvFormat {
        KvFormat::QuantI4
    }

    fn write_row(&mut self, l: usize, b: usize, c: usize, k_row: &[f32], v_row: &[f32]) {
        let dims = self.dims;
        let raw = self.raw();
        // SAFETY: `&mut self` grants exclusive access to every slot.
        unsafe { raw.write_row(&dims, l, b, c, k_row, v_row) }
    }

    fn load_rows(
        &mut self,
        l: usize,
        b: usize,
        h: usize,
        k_rows: &[f32],
        v_rows: &[f32],
        len: usize,
    ) {
        let d = self.dims.d_head;
        let packed = q4_packed_bytes(d);
        let groups = q4_groups(d);
        for c in 0..len {
            let ri = quant_idx(&self.dims, l, b, h, c);
            let (po, go) = (ri * packed, ri * groups);
            quantize_row_q4_into(
                &k_rows[c * d..(c + 1) * d],
                &mut self.k_q[po..po + packed],
                &mut self.k_s[go..go + groups],
                &mut self.k_z[go..go + groups],
            );
            quantize_row_q4_into(
                &v_rows[c * d..(c + 1) * d],
                &mut self.v_q[po..po + packed],
                &mut self.v_s[go..go + groups],
                &mut self.v_z[go..go + groups],
            );
        }
    }

    fn gather_rows(&mut self, l: usize, b: usize, keep: &[usize]) {
        let dims = self.dims;
        let raw = self.raw();
        // SAFETY: `&mut self` grants exclusive access to every slot.
        unsafe { raw.gather_rows(&dims, l, b, keep) }
    }

    fn swap_rows(&mut self, l: usize, a: usize, b: usize, n: usize) {
        let dims = self.dims;
        let raw = self.raw();
        // SAFETY: `&mut self` grants exclusive access to every slot.
        unsafe { raw.swap_rows(&dims, l, a, b, n) }
    }

    #[allow(clippy::too_many_arguments)]
    fn read_rows(
        &self,
        l: usize,
        b: usize,
        h: usize,
        which_v: bool,
        from: usize,
        to: usize,
        dst: &mut [f32],
    ) {
        let d = self.dims.d_head;
        let packed = q4_packed_bytes(d);
        let groups = q4_groups(d);
        let (q, s, z) = if which_v {
            (&self.v_q, &self.v_s, &self.v_z)
        } else {
            (&self.k_q, &self.k_s, &self.k_z)
        };
        for c in from..to {
            let ri = quant_idx(&self.dims, l, b, h, c);
            let (po, go) = (ri * packed, ri * groups);
            // Never-written rows carry (scale, zero) = (0, 0) ⇒ exact
            // zeros — same determinism argument as the int8 store.
            dequantize_row_q4(
                &q[po..po + packed],
                &s[go..go + groups],
                &z[go..go + groups],
                &mut dst[(c - from) * d..(c - from + 1) * d],
            );
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn export_packed_rows(
        &self,
        l: usize,
        b: usize,
        h: usize,
        which_v: bool,
        from: usize,
        to: usize,
        codes_dst: &mut [u8],
        scales_dst: &mut [f32],
        zeros_dst: &mut [f32],
    ) {
        let packed = q4_packed_bytes(self.dims.d_head);
        let groups = q4_groups(self.dims.d_head);
        let n = to - from;
        let ri = quant_idx(&self.dims, l, b, h, from);
        let (po, go) = (ri * packed, ri * groups);
        let (q, s, z) = if which_v {
            (&self.v_q, &self.v_s, &self.v_z)
        } else {
            (&self.k_q, &self.k_s, &self.k_z)
        };
        codes_dst[..n * packed].copy_from_slice(&q[po..po + n * packed]);
        scales_dst[..n * groups].copy_from_slice(&s[go..go + n * groups]);
        zeros_dst[..n * groups].copy_from_slice(&z[go..go + n * groups]);
    }

    fn export_rows(&self, l: usize, b: usize, len: usize, out: &mut Vec<u8>) {
        let d = self.dims.d_head;
        let packed = q4_packed_bytes(d);
        let groups = q4_groups(d);
        for h in 0..self.dims.kv_heads {
            let ri = quant_idx(&self.dims, l, b, h, 0);
            let (po, go) = (ri * packed, ri * groups);
            out.extend_from_slice(&self.k_q[po..po + len * packed]);
            out.extend_from_slice(&self.v_q[po..po + len * packed]);
            push_f32s(out, &self.k_s[go..go + len * groups]);
            push_f32s(out, &self.v_s[go..go + len * groups]);
            push_f32s(out, &self.k_z[go..go + len * groups]);
            push_f32s(out, &self.v_z[go..go + len * groups]);
        }
    }

    fn import_rows(&mut self, l: usize, b: usize, len: usize, bytes: &[u8])
        -> usize
    {
        let d = self.dims.d_head;
        let packed = q4_packed_bytes(d);
        let groups = q4_groups(d);
        let mut used = 0;
        for h in 0..self.dims.kv_heads {
            let ri = quant_idx(&self.dims, l, b, h, 0);
            let (po, go) = (ri * packed, ri * groups);
            let n = len * packed;
            self.k_q[po..po + n].copy_from_slice(&bytes[used..used + n]);
            used += n;
            self.v_q[po..po + n].copy_from_slice(&bytes[used..used + n]);
            used += n;
            let g = len * groups;
            used += pull_f32s(&bytes[used..], &mut self.k_s[go..go + g]);
            used += pull_f32s(&bytes[used..], &mut self.v_s[go..go + g]);
            used += pull_f32s(&bytes[used..], &mut self.k_z[go..go + g]);
            used += pull_f32s(&bytes[used..], &mut self.v_z[go..go + g]);
        }
        used
    }
}

/// One layer's row store inside a [`KvBackend`] (allocated with
/// `dims.layers == 1`; the container translates layer indices).
#[derive(Clone)]
pub enum LayerKv {
    /// Dense f32 rows ([`DenseF32`]).
    Dense(DenseF32),
    /// Per-row symmetric int8 ([`QuantI8`]).
    Q8(QuantI8),
    /// Group-wise asymmetric int4 ([`QuantI4`]).
    Q4(QuantI4),
}

impl LayerKv {
    fn new(dims: CacheDims, fmt: KvFormat) -> LayerKv {
        match fmt {
            KvFormat::F32 => LayerKv::Dense(DenseF32::new(dims)),
            KvFormat::QuantI8 => LayerKv::Q8(QuantI8::new(dims)),
            KvFormat::QuantI4 => LayerKv::Q4(QuantI4::new(dims)),
        }
    }

    fn store(&self) -> &dyn KvStore {
        match self {
            LayerKv::Dense(s) => s,
            LayerKv::Q8(s) => s,
            LayerKv::Q4(s) => s,
        }
    }

    fn store_mut(&mut self) -> &mut dyn KvStore {
        match self {
            LayerKv::Dense(s) => s,
            LayerKv::Q8(s) => s,
            LayerKv::Q4(s) => s,
        }
    }

    fn raw(&mut self) -> RawKv {
        match self {
            LayerKv::Dense(s) => s.raw(),
            LayerKv::Q8(s) => s.raw(),
            LayerKv::Q4(s) => s.raw(),
        }
    }
}

/// The engine-facing backend: one independently formatted single-layer
/// store per model layer, so a mixed per-layer format map is first-class
/// and a uniform `kv.format` is just the degenerate map. The `(l, …)`
/// coordinates of [`KvStore`] are translated to layer-local calls
/// (`l = 0` on the owning store); cross-layer operations never exist in
/// the contract, so layers with different formats cannot interact.
#[derive(Clone)]
pub struct KvBackend {
    dims: CacheDims,
    stores: Vec<LayerKv>,
}

impl KvBackend {
    /// Uniform-format backend (every layer stored as `fmt`).
    pub fn new(dims: CacheDims, fmt: KvFormat) -> KvBackend {
        Self::with_formats(dims, &vec![fmt; dims.layers])
    }

    /// Per-layer backend: `formats[l]` selects layer `l`'s store
    /// (`formats.len()` must equal `dims.layers`).
    pub fn with_formats(dims: CacheDims, formats: &[KvFormat]) -> KvBackend {
        assert_eq!(
            formats.len(),
            dims.layers,
            "format map covers {} layers, cache has {}",
            formats.len(),
            dims.layers
        );
        let layer_dims = CacheDims { layers: 1, ..dims };
        KvBackend {
            dims,
            stores: formats
                .iter()
                .map(|&f| LayerKv::new(layer_dims, f))
                .collect(),
        }
    }

    /// Refresh `out` with one raw pointer set per layer, for the
    /// slot-view path (see [`RawKv`]). The pointers stay valid until the
    /// backend is mutated structurally ([`KvBackend::migrate_layer`]) or
    /// moved; callers re-derive the table on every view handout.
    pub(super) fn raw_table(&mut self, out: &mut Vec<RawKv>) {
        out.clear();
        out.extend(self.stores.iter_mut().map(|s| s.raw()));
    }

    /// Rebuild layer `l`'s store in `fmt`, carrying the live rows over
    /// (`slot_lens[b]` live rows per slot, supplied by the owning
    /// [`super::GroupCache`]). Each live row is materialized as f32
    /// through the old store's [`KvStore::read_rows`] (a dequantization
    /// on quantized storage) and re-encoded through the new store's
    /// [`KvStore::load_rows`] (a requantization). Dead rows are not
    /// copied: the fresh store's zero-initialized buffers keep
    /// [`KvStore::read_rows`] deterministic over them, exactly like a
    /// newly constructed cache — callers must mark the layer rewritten
    /// so resident pack scratches re-read it.
    pub fn migrate_layer(&mut self, l: usize, fmt: KvFormat, slot_lens: &[usize]) {
        debug_assert_eq!(slot_lens.len(), self.dims.batch);
        let layer_dims = CacheDims { layers: 1, ..self.dims };
        let mut fresh = LayerKv::new(layer_dims, fmt);
        let d = self.dims.d_head;
        let mut k_buf = Vec::new();
        let mut v_buf = Vec::new();
        for (b, &len) in slot_lens.iter().enumerate() {
            if len == 0 {
                continue;
            }
            k_buf.resize(len * d, 0.0);
            v_buf.resize(len * d, 0.0);
            for h in 0..self.dims.kv_heads {
                let old = self.stores[l].store();
                old.read_rows(0, b, h, false, 0, len, &mut k_buf);
                old.read_rows(0, b, h, true, 0, len, &mut v_buf);
                fresh.store_mut().load_rows(0, b, h, &k_buf, &v_buf, len);
            }
        }
        self.stores[l] = fresh;
    }
}

impl KvStore for KvBackend {
    fn dims(&self) -> &CacheDims {
        &self.dims
    }

    fn layer_format(&self, l: usize) -> KvFormat {
        self.stores[l].store().layer_format(0)
    }

    fn write_row(&mut self, l: usize, b: usize, c: usize, k_row: &[f32], v_row: &[f32]) {
        self.stores[l].store_mut().write_row(0, b, c, k_row, v_row);
    }

    fn load_rows(
        &mut self,
        l: usize,
        b: usize,
        h: usize,
        k_rows: &[f32],
        v_rows: &[f32],
        len: usize,
    ) {
        self.stores[l].store_mut().load_rows(0, b, h, k_rows, v_rows, len);
    }

    fn gather_rows(&mut self, l: usize, b: usize, keep: &[usize]) {
        self.stores[l].store_mut().gather_rows(0, b, keep);
    }

    fn swap_rows(&mut self, l: usize, a: usize, b: usize, n: usize) {
        self.stores[l].store_mut().swap_rows(0, a, b, n);
    }

    #[allow(clippy::too_many_arguments)]
    fn read_rows(
        &self,
        l: usize,
        b: usize,
        h: usize,
        which_v: bool,
        from: usize,
        to: usize,
        dst: &mut [f32],
    ) {
        self.stores[l].store().read_rows(0, b, h, which_v, from, to, dst);
    }

    #[allow(clippy::too_many_arguments)]
    fn export_packed_rows(
        &self,
        l: usize,
        b: usize,
        h: usize,
        which_v: bool,
        from: usize,
        to: usize,
        codes_dst: &mut [u8],
        scales_dst: &mut [f32],
        zeros_dst: &mut [f32],
    ) {
        self.stores[l].store().export_packed_rows(
            0, b, h, which_v, from, to, codes_dst, scales_dst, zeros_dst,
        );
    }

    fn export_rows(&self, l: usize, b: usize, len: usize, out: &mut Vec<u8>) {
        self.stores[l].store().export_rows(0, b, len, out);
    }

    fn import_rows(&mut self, l: usize, b: usize, len: usize, bytes: &[u8])
        -> usize
    {
        self.stores[l].store_mut().import_rows(0, b, len, bytes)
    }
}

/// Per-layer table of [`RawKv`] pointer sets, `Copy` so every
/// [`super::SlotViewMut`] can carry it. The table itself lives in the
/// owning [`super::GroupCache`] (rebuilt on every view handout) and the
/// views' borrow keeps it alive and unmoved.
#[derive(Clone, Copy)]
pub(super) struct RawKvTable {
    ptr: *const RawKv,
    len: usize,
}

impl RawKvTable {
    pub(super) fn new(table: &[RawKv]) -> RawKvTable {
        RawKvTable { ptr: table.as_ptr(), len: table.len() }
    }

    /// Layer `l`'s raw pointer set. Callers pass `l = 0` to the returned
    /// [`RawKv`]'s operations: each entry points into a single-layer
    /// store.
    ///
    /// SAFETY: the table this was built from must still be alive (the
    /// slot-view borrow on the owning cache guarantees it).
    pub(super) unsafe fn layer(self, l: usize) -> RawKv {
        debug_assert!(l < self.len, "layer {l} out of range ({})", self.len);
        unsafe { *self.ptr.add(l) }
    }
}

/// Raw pointers into one layer store's row buffers, `Copy` so every
/// [`super::SlotViewMut`] can carry the full set. Provenance is the whole
/// K/V allocation; each caller restricts itself to its own slot's
/// disjoint rows (the same discipline as the view's lens/pos/scores
/// pointers), which is what makes a set of slot views usable from
/// multiple threads at once.
#[derive(Clone, Copy)]
pub(super) enum RawKv {
    Dense { k: *mut f32, v: *mut f32 },
    Quant { k_q: *mut i8, v_q: *mut i8, k_s: *mut f32, v_s: *mut f32 },
    Q4 {
        k_q: *mut u8,
        v_q: *mut u8,
        k_s: *mut f32,
        v_s: *mut f32,
        k_z: *mut f32,
        v_z: *mut f32,
    },
}

impl RawKv {
    /// Store one token's K/V rows at row `c` of (l, b); see
    /// [`KvStore::write_row`].
    ///
    /// SAFETY: caller must hold exclusive access to slot `b`'s rows of
    /// the owning backend (one slot view per slot), the backend must
    /// outlive the call, `c < capacity`, and row slices must be
    /// `[Hkv * D]`.
    pub(super) unsafe fn write_row(
        self,
        dims: &CacheDims,
        l: usize,
        b: usize,
        c: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) {
        let d = dims.d_head;
        match self {
            RawKv::Dense { k, v } => {
                for h in 0..dims.kv_heads {
                    let off = dense_off(dims, l, b, h, c);
                    unsafe {
                        std::ptr::copy_nonoverlapping(
                            k_row.as_ptr().add(h * d), k.add(off), d);
                        std::ptr::copy_nonoverlapping(
                            v_row.as_ptr().add(h * d), v.add(off), d);
                    }
                }
            }
            RawKv::Quant { k_q, v_q, k_s, v_s } => {
                for h in 0..dims.kv_heads {
                    let off = dense_off(dims, l, b, h, c);
                    let si = quant_idx(dims, l, b, h, c);
                    unsafe {
                        let kq = std::slice::from_raw_parts_mut(
                            k_q.add(off), d);
                        *k_s.add(si) = quantize_row_into(
                            &k_row[h * d..(h + 1) * d], kq);
                        let vq = std::slice::from_raw_parts_mut(
                            v_q.add(off), d);
                        *v_s.add(si) = quantize_row_into(
                            &v_row[h * d..(h + 1) * d], vq);
                    }
                }
            }
            RawKv::Q4 { k_q, v_q, k_s, v_s, k_z, v_z } => {
                let packed = q4_packed_bytes(d);
                let groups = q4_groups(d);
                for h in 0..dims.kv_heads {
                    let ri = quant_idx(dims, l, b, h, c);
                    let (po, go) = (ri * packed, ri * groups);
                    unsafe {
                        quantize_row_q4_into(
                            &k_row[h * d..(h + 1) * d],
                            std::slice::from_raw_parts_mut(
                                k_q.add(po), packed),
                            std::slice::from_raw_parts_mut(
                                k_s.add(go), groups),
                            std::slice::from_raw_parts_mut(
                                k_z.add(go), groups),
                        );
                        quantize_row_q4_into(
                            &v_row[h * d..(h + 1) * d],
                            std::slice::from_raw_parts_mut(
                                v_q.add(po), packed),
                            std::slice::from_raw_parts_mut(
                                v_s.add(go), groups),
                            std::slice::from_raw_parts_mut(
                                v_z.add(go), groups),
                        );
                    }
                }
            }
        }
    }

    /// Front-packing gather by ascending, deduplicated source index; see
    /// [`KvStore::gather_rows`].
    ///
    /// SAFETY: as [`RawKv::write_row`]; every index in `keep` must be
    /// below the slot's live length.
    pub(super) unsafe fn gather_rows(self, dims: &CacheDims, l: usize, b: usize, keep: &[usize]) {
        let d = dims.d_head;
        for h in 0..dims.kv_heads {
            match self {
                RawKv::Dense { k, v } => {
                    for (dst, &src) in keep.iter().enumerate() {
                        if dst != src {
                            // keep is sorted + deduplicated, so src > dst
                            // and the D-wide rows never overlap.
                            let so = dense_off(dims, l, b, h, src);
                            let doff = dense_off(dims, l, b, h, dst);
                            unsafe {
                                std::ptr::copy_nonoverlapping(
                                    k.add(so) as *const f32, k.add(doff), d);
                                std::ptr::copy_nonoverlapping(
                                    v.add(so) as *const f32, v.add(doff), d);
                            }
                        }
                    }
                }
                RawKv::Quant { k_q, v_q, k_s, v_s } => {
                    for (dst, &src) in keep.iter().enumerate() {
                        if dst != src {
                            // src > dst (sorted + deduplicated keep), so
                            // the mantissa spans never overlap. The tail
                            // keeps stale-but-deterministic rows, same
                            // as the dense gather.
                            let so = dense_off(dims, l, b, h, src);
                            let doff = dense_off(dims, l, b, h, dst);
                            unsafe {
                                std::ptr::copy_nonoverlapping(
                                    k_q.add(so) as *const i8,
                                    k_q.add(doff), d);
                                std::ptr::copy_nonoverlapping(
                                    v_q.add(so) as *const i8,
                                    v_q.add(doff), d);
                                *k_s.add(quant_idx(dims, l, b, h, dst)) =
                                    *k_s.add(quant_idx(dims, l, b, h, src));
                                *v_s.add(quant_idx(dims, l, b, h, dst)) =
                                    *v_s.add(quant_idx(dims, l, b, h, src));
                            }
                        }
                    }
                }
                RawKv::Q4 { k_q, v_q, k_s, v_s, k_z, v_z } => {
                    let packed = q4_packed_bytes(d);
                    let groups = q4_groups(d);
                    for (dst, &src) in keep.iter().enumerate() {
                        if dst != src {
                            // src > dst as above: none of the packed or
                            // group-parameter spans overlap.
                            let rs = quant_idx(dims, l, b, h, src);
                            let rd = quant_idx(dims, l, b, h, dst);
                            unsafe {
                                std::ptr::copy_nonoverlapping(
                                    k_q.add(rs * packed) as *const u8,
                                    k_q.add(rd * packed), packed);
                                std::ptr::copy_nonoverlapping(
                                    v_q.add(rs * packed) as *const u8,
                                    v_q.add(rd * packed), packed);
                                std::ptr::copy_nonoverlapping(
                                    k_s.add(rs * groups) as *const f32,
                                    k_s.add(rd * groups), groups);
                                std::ptr::copy_nonoverlapping(
                                    v_s.add(rs * groups) as *const f32,
                                    v_s.add(rd * groups), groups);
                                std::ptr::copy_nonoverlapping(
                                    k_z.add(rs * groups) as *const f32,
                                    k_z.add(rd * groups), groups);
                                std::ptr::copy_nonoverlapping(
                                    v_z.add(rs * groups) as *const f32,
                                    v_z.add(rd * groups), groups);
                            }
                        }
                    }
                }
            }
        }
    }

    /// Swap the first `n` rows of slots `a` and `b` at layer `l`; see
    /// [`KvStore::swap_rows`].
    ///
    /// SAFETY: caller must hold exclusive access to BOTH slots' rows
    /// (this is the serial reap path, never the parallel slot-view path)
    /// and `a != b`, `n <= capacity`.
    pub(super) unsafe fn swap_rows(self, dims: &CacheDims, l: usize, a: usize, b: usize, n: usize) {
        let d = dims.d_head;
        match self {
            RawKv::Dense { k, v } => {
                for h in 0..dims.kv_heads {
                    let oa = dense_off(dims, l, a, h, 0);
                    let ob = dense_off(dims, l, b, h, 0);
                    // Distinct slots: the two n*D regions never overlap.
                    unsafe {
                        std::ptr::swap_nonoverlapping(
                            k.add(oa), k.add(ob), n * d);
                        std::ptr::swap_nonoverlapping(
                            v.add(oa), v.add(ob), n * d);
                    }
                }
            }
            RawKv::Quant { k_q, v_q, k_s, v_s } => {
                for h in 0..dims.kv_heads {
                    let oa = dense_off(dims, l, a, h, 0);
                    let ob = dense_off(dims, l, b, h, 0);
                    let sa = quant_idx(dims, l, a, h, 0);
                    let sb = quant_idx(dims, l, b, h, 0);
                    // Distinct slots: none of the regions overlap.
                    unsafe {
                        std::ptr::swap_nonoverlapping(
                            k_q.add(oa), k_q.add(ob), n * d);
                        std::ptr::swap_nonoverlapping(
                            v_q.add(oa), v_q.add(ob), n * d);
                        std::ptr::swap_nonoverlapping(
                            k_s.add(sa), k_s.add(sb), n);
                        std::ptr::swap_nonoverlapping(
                            v_s.add(sa), v_s.add(sb), n);
                    }
                }
            }
            RawKv::Q4 { k_q, v_q, k_s, v_s, k_z, v_z } => {
                let packed = q4_packed_bytes(d);
                let groups = q4_groups(d);
                for h in 0..dims.kv_heads {
                    let ra = quant_idx(dims, l, a, h, 0);
                    let rb = quant_idx(dims, l, b, h, 0);
                    // Distinct slots: none of the regions overlap.
                    unsafe {
                        std::ptr::swap_nonoverlapping(
                            k_q.add(ra * packed), k_q.add(rb * packed),
                            n * packed);
                        std::ptr::swap_nonoverlapping(
                            v_q.add(ra * packed), v_q.add(rb * packed),
                            n * packed);
                        std::ptr::swap_nonoverlapping(
                            k_s.add(ra * groups), k_s.add(rb * groups),
                            n * groups);
                        std::ptr::swap_nonoverlapping(
                            v_s.add(ra * groups), v_s.add(rb * groups),
                            n * groups);
                        std::ptr::swap_nonoverlapping(
                            k_z.add(ra * groups), k_z.add(rb * groups),
                            n * groups);
                        std::ptr::swap_nonoverlapping(
                            v_z.add(ra * groups), v_z.add(rb * groups),
                            n * groups);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::proptest::vec_f32;

    const ALL_FORMATS: [KvFormat; 3] =
        [KvFormat::F32, KvFormat::QuantI8, KvFormat::QuantI4];

    fn dims() -> CacheDims {
        CacheDims { layers: 2, batch: 2, kv_heads: 2, capacity: 8, d_head: 4 }
    }

    fn read_row(store: &dyn KvStore, l: usize, b: usize, h: usize, c: usize) -> Vec<f32> {
        let d = store.dims().d_head;
        let mut out = vec![0.0; d];
        store.read_rows(l, b, h, false, c, c + 1, &mut out);
        out
    }

    /// Format error bound plus float fuzz; the bound itself lives in
    /// [`crate::kvcache::quant::dequant_error_bound`].
    fn format_tol(fmt: KvFormat, exact: &[f32]) -> f32 {
        crate::kvcache::quant::dequant_error_bound(fmt, exact) + 1e-6
    }

    #[test]
    fn backends_report_their_format_and_bytes() {
        let dense = KvBackend::new(dims(), KvFormat::F32);
        let quant = KvBackend::new(dims(), KvFormat::QuantI8);
        let q4 = KvBackend::new(dims(), KvFormat::QuantI4);
        for l in 0..2 {
            assert_eq!(dense.layer_format(l), KvFormat::F32);
            assert_eq!(quant.layer_format(l), KvFormat::QuantI8);
            assert_eq!(q4.layer_format(l), KvFormat::QuantI4);
        }
        // 2 heads * 4 elems * 4 B * 2 tensors, vs 2 * (4 + 4) * 2,
        // vs 2 * (2 packed + 8 group bytes) * 2.
        assert_eq!(dense.layer_row_bytes(0), 64);
        assert_eq!(quant.layer_row_bytes(0), 32);
        assert_eq!(q4.layer_row_bytes(1), 40);
        assert_eq!(quant.f32_row_bytes(), dense.layer_row_bytes(0));
        assert_eq!(q4.f32_row_bytes(), dense.layer_row_bytes(0));
    }

    #[test]
    fn mixed_backend_reports_per_layer_formats_and_bytes() {
        let kv = KvBackend::with_formats(
            dims(),
            &[KvFormat::F32, KvFormat::QuantI4],
        );
        assert_eq!(kv.layer_format(0), KvFormat::F32);
        assert_eq!(kv.layer_format(1), KvFormat::QuantI4);
        assert_eq!(kv.layer_row_bytes(0), 64);
        assert_eq!(kv.layer_row_bytes(1), 40);
    }

    #[test]
    #[should_panic(expected = "format map covers")]
    fn mismatched_format_map_panics() {
        KvBackend::with_formats(dims(), &[KvFormat::F32]);
    }

    #[test]
    fn quantized_backends_agree_with_dense_on_written_rows() {
        for fmt in [KvFormat::QuantI8, KvFormat::QuantI4] {
            let mut rng = Rng::new(11);
            let mut dense = KvBackend::new(dims(), KvFormat::F32);
            let mut quant = KvBackend::new(dims(), fmt);
            for c in 0..4 {
                let kr = vec_f32(&mut rng, 2 * 4, -2.0, 2.0);
                let vr = vec_f32(&mut rng, 2 * 4, -2.0, 2.0);
                dense.write_row(0, 1, c, &kr, &vr);
                quant.write_row(0, 1, c, &kr, &vr);
            }
            for c in 0..4 {
                for h in 0..2 {
                    let exact = read_row(&dense, 0, 1, h, c);
                    let approx = read_row(&quant, 0, 1, h, c);
                    let tol = format_tol(fmt, &exact);
                    for (a, b) in exact.iter().zip(&approx) {
                        assert!(
                            (a - b).abs() <= tol,
                            "{fmt:?}: {a} vs {b} (tol {tol})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn quant_dead_rows_read_as_zero() {
        for fmt in [KvFormat::QuantI8, KvFormat::QuantI4] {
            let quant = KvBackend::new(dims(), fmt);
            assert_eq!(read_row(&quant, 1, 0, 1, 7), vec![0.0; 4], "{fmt:?}");
        }
    }

    #[test]
    fn gather_front_packs_every_backend() {
        let mut rng = Rng::new(5);
        let rows: Vec<Vec<f32>> =
            (0..6).map(|_| vec_f32(&mut rng, 8, -1.0, 1.0)).collect();
        for fmt in ALL_FORMATS {
            let mut s = KvBackend::new(dims(), fmt);
            for (c, r) in rows.iter().enumerate() {
                s.write_row(0, 0, c, r, r);
            }
            s.gather_rows(0, 0, &[1, 4]);
            let got0 = read_row(&s, 0, 0, 0, 0);
            let got1 = read_row(&s, 0, 0, 0, 1);
            for (a, b) in got0.iter().zip(&rows[1][..4]) {
                let tol = format_tol(fmt, &rows[1][..4]);
                assert!((a - b).abs() <= tol, "{fmt:?}: {a} vs {b}");
            }
            for (a, b) in got1.iter().zip(&rows[4][..4]) {
                let tol = format_tol(fmt, &rows[4][..4]);
                assert!((a - b).abs() <= tol, "{fmt:?}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn export_import_round_trips_bit_exact_at_stored_precision() {
        let mut rng = Rng::new(13);
        for fmt in ALL_FORMATS {
            let mut src = KvBackend::new(dims(), fmt);
            let len = 5;
            for c in 0..len {
                let kr = vec_f32(&mut rng, 2 * 4, -2.0, 2.0);
                let vr = vec_f32(&mut rng, 2 * 4, -2.0, 2.0);
                src.write_row(1, 0, c, &kr, &vr);
            }
            let mut buf = Vec::new();
            src.export_rows(1, 0, len, &mut buf);
            assert_eq!(buf.len(), len * src.layer_row_bytes(1), "{fmt:?}");
            let mut dst = KvBackend::new(dims(), fmt);
            let used = dst.import_rows(1, 0, len, &buf);
            assert_eq!(used, buf.len(), "{fmt:?}");
            // Stored state restored verbatim: every read — exact f32 or
            // dequantized — is bit-identical to the source store's.
            let mut a = vec![0.0f32; len * 4];
            let mut b = vec![0.0f32; len * 4];
            for h in 0..2 {
                for which_v in [false, true] {
                    src.read_rows(1, 0, h, which_v, 0, len, &mut a);
                    dst.read_rows(1, 0, h, which_v, 0, len, &mut b);
                    assert_eq!(a, b, "{fmt:?} head {h} v={which_v}");
                }
            }
        }
    }

    #[test]
    fn export_import_moves_rows_between_slots() {
        let mut rng = Rng::new(17);
        for fmt in ALL_FORMATS {
            let mut s = KvBackend::new(dims(), fmt);
            let kr = vec_f32(&mut rng, 8, -1.0, 1.0);
            let vr = vec_f32(&mut rng, 8, -1.0, 1.0);
            s.write_row(0, 0, 0, &kr, &vr);
            let mut buf = Vec::new();
            s.export_rows(0, 0, 1, &mut buf);
            assert_eq!(s.import_rows(0, 1, 1, &buf), buf.len());
            assert_eq!(
                read_row(&s, 0, 0, 0, 0),
                read_row(&s, 0, 1, 0, 0),
                "{fmt:?}"
            );
        }
    }

    #[test]
    fn packed_export_dequantizes_to_read_rows() {
        use crate::kvcache::quant::{
            dequantize_row_q4, dequantize_span, packed_codes_per_row,
            packed_scales_per_row,
        };
        let mut rng = Rng::new(23);
        for fmt in [KvFormat::QuantI8, KvFormat::QuantI4] {
            let mut s = KvBackend::new(dims(), fmt);
            for c in 0..5 {
                let kr = vec_f32(&mut rng, 8, -2.0, 2.0);
                let vr = vec_f32(&mut rng, 8, -2.0, 2.0);
                s.write_row(1, 0, c, &kr, &vr);
            }
            let d = dims().d_head;
            let db = packed_codes_per_row(d, fmt).unwrap();
            let g = packed_scales_per_row(d, fmt).unwrap();
            // Cover live rows, a dead tail, and a mid-range window.
            for (from, to) in [(0usize, 5usize), (5, 8), (2, 4)] {
                let n = to - from;
                let mut codes = vec![0u8; n * db];
                let mut scales = vec![0f32; n * g];
                let mut zeros = vec![
                    0f32;
                    if fmt == KvFormat::QuantI4 { n * g } else { 0 }
                ];
                for which_v in [false, true] {
                    s.export_packed_rows(
                        1, 0, 1, which_v, from, to, &mut codes, &mut scales,
                        &mut zeros,
                    );
                    let mut want = vec![0f32; n * d];
                    s.read_rows(1, 0, 1, which_v, from, to, &mut want);
                    // Dequantizing the packed export reproduces read_rows
                    // bit-for-bit — the packed image carries exactly the
                    // rows the f32 image would have.
                    let mut got = vec![0f32; n * d];
                    for r in 0..n {
                        match fmt {
                            KvFormat::QuantI8 => dequantize_span(
                                crate::runtime::tensors::as_i8(
                                    &codes[r * db..(r + 1) * db],
                                ),
                                scales[r],
                                &mut got[r * d..(r + 1) * d],
                            ),
                            KvFormat::QuantI4 => dequantize_row_q4(
                                &codes[r * db..(r + 1) * db],
                                &scales[r * g..(r + 1) * g],
                                &zeros[r * g..(r + 1) * g],
                                &mut got[r * d..(r + 1) * d],
                            ),
                            KvFormat::F32 => unreachable!(),
                        }
                    }
                    assert_eq!(got, want, "{fmt:?} rows {from}..{to}");
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "no packed")]
    fn dense_layer_has_no_packed_export() {
        let s = KvBackend::new(dims(), KvFormat::F32);
        let (mut c, mut sc, mut z) = (vec![0u8; 4], vec![0f32; 1], vec![]);
        s.export_packed_rows(0, 0, 0, false, 0, 1, &mut c, &mut sc, &mut z);
    }

    #[test]
    fn swap_rows_swaps_slot_prefixes() {
        for fmt in ALL_FORMATS {
            let mut s = KvBackend::new(dims(), fmt);
            let ra = vec![1.0f32; 8];
            let rb = vec![-1.0f32; 8];
            s.write_row(1, 0, 0, &ra, &ra);
            s.write_row(1, 1, 0, &rb, &rb);
            s.swap_rows(1, 0, 1, 1);
            assert!((read_row(&s, 1, 0, 0, 0)[0] + 1.0).abs() < 1e-2);
            assert!((read_row(&s, 1, 1, 0, 0)[0] - 1.0).abs() < 1e-2);
        }
    }
}
