//! Seeded multi-tenant request traces for the soak harness.
//!
//! A [`TraceSpec`] describes a set of tenant classes — each with its
//! own arrival process, prompt/decode length distributions and
//! `deadline_ms` — and expands deterministically into a merged,
//! arrival-ordered request list. Every class draws from its own
//! forked PRNG stream, so the trace is a pure function of
//! `(seed, spec)`: the same seed reproduces the trace byte-for-byte
//! (asserted via [`trace_fingerprint`]) and different seeds produce
//! disjoint arrival schedules. That reproducibility is what lets CI
//! replay the [`pinned`] trace and compare SLO numbers against the
//! committed `BENCH_soak.json` baseline.

use crate::util::prng::Rng;
use crate::workload::{make_task, Task};

/// Arrival process of one tenant class.
#[derive(Clone, Debug)]
pub enum ArrivalProcess {
    /// Homogeneous Poisson arrivals at `rate` requests/second.
    Poisson {
        /// Mean arrival intensity (req/s).
        rate: f64,
    },
    /// Two-state on-off (Markov-modulated Poisson) process: bursts of
    /// Poisson arrivals at `rate_on` whose lengths are exponential
    /// with mean `mean_on_s`, separated by silent gaps with mean
    /// `mean_off_s`. This is the "batch long-reasoning tenant wakes
    /// up and floods the queue" shape the KV budget has to survive.
    OnOff {
        /// Arrival intensity during a burst (req/s).
        rate_on: f64,
        /// Mean burst length in seconds.
        mean_on_s: f64,
        /// Mean silent-gap length in seconds.
        mean_off_s: f64,
    },
}

/// One tenant class: who arrives, how often, and with what work.
#[derive(Clone, Debug)]
pub struct TenantClass {
    /// Stable class label carried through scheduling, metrics and the
    /// bench rows (e.g. `"interactive"`).
    pub name: String,
    pub arrival: ArrivalProcess,
    /// Inclusive range of `n_pairs` for [`make_task`] (prompt length).
    pub pairs: (usize, usize),
    /// Inclusive range of reasoning `hops`.
    pub hops: (usize, usize),
    /// Inclusive range of `max_new_tokens` (decode length).
    pub max_new: (usize, usize),
    /// Per-request end-to-end deadline; `None` = best-effort.
    pub deadline_ms: Option<u64>,
}

/// A reproducible multi-tenant trace description.
#[derive(Clone, Debug)]
pub struct TraceSpec {
    /// Root seed; each class forks its own stream from it.
    pub seed: u64,
    /// Arrival horizon in seconds (no arrivals at or past it).
    pub horizon_s: f64,
    pub classes: Vec<TenantClass>,
}

/// One generated request of the trace, arrival-ordered.
#[derive(Clone, Debug)]
pub struct TraceRequest {
    /// 1-based id in merged arrival order.
    pub id: u64,
    /// Index into [`TraceSpec::classes`].
    pub class_idx: usize,
    /// Class label (copy of the class name, for row emission).
    pub class: String,
    pub arrival_s: f64,
    pub task: Task,
    pub max_new_tokens: usize,
    pub deadline_ms: Option<u64>,
}

impl TraceRequest {
    /// Prompt length in tokens under the char-level tokenizer (the
    /// prompt grammar is pure ASCII, so bytes == chars == tokens).
    /// The sim replayer costs prefill with this; the real replay path
    /// re-encodes through the model tokenizer.
    pub fn prompt_tokens(&self) -> usize {
        self.task.prompt.len()
    }

    /// Canonical one-line serialization: every field that can affect a
    /// replay, with the arrival rendered as exact f64 bits. Two traces
    /// are byte-identical iff their canonical lines all match.
    pub fn canonical(&self) -> String {
        format!(
            "{}|{}|{}|{:016x}|{}|{}|{:?}",
            self.id,
            self.class_idx,
            self.class,
            self.arrival_s.to_bits(),
            self.task.prompt,
            self.max_new_tokens,
            self.deadline_ms,
        )
    }
}

/// Per-class arrival schedule: draws from `rng` only, one well-defined
/// draw order (phase length → inter-arrival → task sizes → task), so
/// the stream is reproducible and mirrorable.
fn class_requests(
    rng: &mut Rng,
    class_idx: usize,
    class: &TenantClass,
    horizon_s: f64,
) -> Vec<TraceRequest> {
    let mut out = Vec::new();
    let mut emit = |rng: &mut Rng, t: f64| {
        let pairs = rng.range(class.pairs.0, class.pairs.1);
        let hops = rng.range(class.hops.0, class.hops.1).min(pairs);
        let max_new = rng.range(class.max_new.0, class.max_new.1);
        out.push(TraceRequest {
            id: 0, // assigned after the merge sort
            class_idx,
            class: class.name.clone(),
            arrival_s: t,
            task: make_task(rng, pairs, hops),
            max_new_tokens: max_new,
            deadline_ms: class.deadline_ms,
        });
    };
    match class.arrival {
        ArrivalProcess::Poisson { rate } => {
            let mut t = 0.0;
            loop {
                t += rng.exponential(rate);
                if t >= horizon_s {
                    break;
                }
                emit(rng, t);
            }
        }
        ArrivalProcess::OnOff { rate_on, mean_on_s, mean_off_s } => {
            let mut t = 0.0;
            while t < horizon_s {
                let on_end = t + rng.exponential(1.0 / mean_on_s);
                loop {
                    let dt = rng.exponential(rate_on);
                    if t + dt >= on_end || t + dt >= horizon_s {
                        break;
                    }
                    t += dt;
                    emit(rng, t);
                }
                t = on_end + rng.exponential(1.0 / mean_off_s);
            }
        }
    }
    out
}

/// Expand a spec into the merged, arrival-ordered request list.
///
/// Each class forks its own PRNG stream from the root seed (stream
/// order = class order), so adding draws to one class never perturbs
/// another, and the merge is a stable sort on `(arrival_s, class_idx)`.
pub fn generate(spec: &TraceSpec) -> Vec<TraceRequest> {
    let mut root = Rng::new(spec.seed);
    let mut all: Vec<TraceRequest> = Vec::new();
    for (ci, class) in spec.classes.iter().enumerate() {
        let mut stream = root.fork();
        all.extend(class_requests(&mut stream, ci, class, spec.horizon_s));
    }
    all.sort_by(|a, b| {
        a.arrival_s
            .total_cmp(&b.arrival_s)
            .then(a.class_idx.cmp(&b.class_idx))
    });
    for (i, r) in all.iter_mut().enumerate() {
        r.id = i as u64 + 1;
    }
    all
}

/// FNV-1a 64-bit over the canonical serialization — the trace's
/// identity for reproducibility assertions and the bench rows.
pub fn trace_fingerprint(trace: &[TraceRequest]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for r in trace {
        for b in r.canonical().as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h ^= b'\n' as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Root seed of the pinned CI trace. Changing it (or [`pinned`])
/// invalidates `rust/bench_baselines/BENCH_soak.json` — regenerate the
/// baseline in the same commit.
pub const PINNED_SEED: u64 = 0x1e7e_50a4;

/// The pinned two-tenant trace CI replays for the perf trajectory:
/// an interactive short-prompt class with a tight deadline under
/// steady Poisson arrivals, plus a batch long-reasoning class that
/// arrives in on-off bursts with long decodes and no deadline.
pub fn pinned() -> TraceSpec {
    TraceSpec {
        seed: PINNED_SEED,
        horizon_s: 25.0,
        classes: vec![
            TenantClass {
                name: "interactive".to_string(),
                arrival: ArrivalProcess::Poisson { rate: 6.0 },
                pairs: (3, 5),
                hops: (1, 1),
                max_new: (8, 16),
                deadline_ms: Some(2500),
            },
            TenantClass {
                name: "batch-reasoning".to_string(),
                arrival: ArrivalProcess::OnOff {
                    rate_on: 4.0,
                    mean_on_s: 5.0,
                    mean_off_s: 4.0,
                },
                pairs: (10, 16),
                hops: (3, 4),
                max_new: (48, 96),
                deadline_ms: None,
            },
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_is_byte_identical() {
        let a = generate(&pinned());
        let b = generate(&pinned());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.canonical(), y.canonical());
        }
        assert_eq!(trace_fingerprint(&a), trace_fingerprint(&b));
    }

    #[test]
    fn disjoint_seeds_give_disjoint_arrival_schedules() {
        let mut spec_a = pinned();
        spec_a.seed = 1;
        let mut spec_b = pinned();
        spec_b.seed = 2;
        let a = generate(&spec_a);
        let b = generate(&spec_b);
        assert_ne!(trace_fingerprint(&a), trace_fingerprint(&b));
        // No arrival instant is shared bit-for-bit between the seeds:
        // the exponential draws come from decorrelated streams.
        let set_a: std::collections::HashSet<u64> =
            a.iter().map(|r| r.arrival_s.to_bits()).collect();
        assert!(
            b.iter().all(|r| !set_a.contains(&r.arrival_s.to_bits())),
            "seed-2 trace shares an arrival instant with seed-1"
        );
    }

    #[test]
    fn merged_trace_is_ordered_with_sequential_ids() {
        let tr = generate(&pinned());
        assert!(!tr.is_empty());
        assert!(tr.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
        for (i, r) in tr.iter().enumerate() {
            assert_eq!(r.id, i as u64 + 1);
            assert!(r.arrival_s < 25.0);
            assert!(r.max_new_tokens >= 8);
        }
    }

    #[test]
    fn pinned_trace_mixes_both_tenant_classes() {
        let tr = generate(&pinned());
        let interactive =
            tr.iter().filter(|r| r.class == "interactive").count();
        let batch =
            tr.iter().filter(|r| r.class == "batch-reasoning").count();
        assert!(interactive > 50, "interactive count {interactive}");
        assert!(batch > 10, "batch count {batch}");
        // Deadlines ride with the class.
        assert!(tr
            .iter()
            .filter(|r| r.class == "interactive")
            .all(|r| r.deadline_ms == Some(2500)));
        assert!(tr
            .iter()
            .filter(|r| r.class == "batch-reasoning")
            .all(|r| r.deadline_ms.is_none()));
        // Long-reasoning prompts really are longer.
        let avg = |name: &str| {
            let xs: Vec<usize> = tr
                .iter()
                .filter(|r| r.class == name)
                .map(|r| r.prompt_tokens())
                .collect();
            xs.iter().sum::<usize>() as f64 / xs.len() as f64
        };
        assert!(avg("batch-reasoning") > 2.0 * avg("interactive"));
    }

    #[test]
    fn class_streams_are_independent_of_each_other() {
        // Dropping the second class must not change the first class's
        // schedule: streams are forked up front, not interleaved.
        let full = generate(&pinned());
        let mut solo_spec = pinned();
        solo_spec.classes.truncate(1);
        let solo = generate(&solo_spec);
        let full_interactive: Vec<&TraceRequest> = full
            .iter()
            .filter(|r| r.class == "interactive")
            .collect();
        assert_eq!(full_interactive.len(), solo.len());
        for (a, b) in full_interactive.iter().zip(&solo) {
            assert_eq!(a.arrival_s.to_bits(), b.arrival_s.to_bits());
            assert_eq!(a.task.prompt, b.task.prompt);
            assert_eq!(a.max_new_tokens, b.max_new_tokens);
        }
    }
}
