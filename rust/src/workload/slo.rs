//! SLO accounting over replayed traces: fold per-request latency
//! observations into per-tenant-class percentiles, attainment rates,
//! goodput and preemption-fairness counters — the row schema behind
//! `BENCH_soak.json` and the soak harness's human-readable table.
//!
//! Definitions (also documented in `docs/ARCHITECTURE.md`):
//!
//! * **TTFT** — submit → first generated token, seconds.
//! * **TPOT** — `(finish − first token) / (generated − 1)`; `0` for
//!   single-token completions.
//! * **e2e** — submit → finish, seconds.
//! * Percentiles are **nearest-rank** on the exact sorted sample
//!   ([`crate::util::stats::percentile_sorted`]); the streaming
//!   estimates in [`crate::metrics`] use the P² estimator and converge
//!   to these.
//! * **Attainment** — fraction of a class's requests that finished
//!   (not aborted) with `e2e ≤ deadline`; requests without a deadline
//!   count as attained. Monotone non-decreasing in the deadline
//!   (property-tested).
//! * **Goodput** — generated tokens from *successful* completions per
//!   second of makespan (aborted work contributes nothing).

use crate::util::json::Json;
use crate::util::stats::percentile_sorted;

/// Per-request observation fed into the accounting, backend-agnostic:
/// the sim replayer and the real-`Supervisor` replay both produce it.
#[derive(Clone, Debug)]
pub struct RequestOutcome {
    pub class: String,
    /// Seconds, submit → first token (0 when no token was produced).
    pub ttft_s: f64,
    /// Seconds per output token after the first; 0 for < 2 tokens.
    pub tpot_s: f64,
    /// Seconds, submit → finish (however it finished).
    pub e2e_s: f64,
    /// Generated tokens delivered.
    pub generated: usize,
    /// Finished successfully (EOS / length), as opposed to a deadline
    /// abort, drain abort or failure.
    pub ok: bool,
    pub deadline_ms: Option<u64>,
    /// Times this request was preempted (recompute or swap).
    pub preemptions: u64,
    /// Times this request was swapped to host rather than recomputed.
    pub swaps: u64,
    /// Times this request was rescued across groups.
    pub rescues: u64,
}

impl RequestOutcome {
    /// Did this request meet its SLO? No-deadline requests are
    /// attained by definition (best effort has no bar to miss).
    pub fn attained(&self) -> bool {
        match self.deadline_ms {
            None => self.ok,
            Some(d) => self.ok && self.e2e_s <= d as f64 / 1e3,
        }
    }
}

/// Nearest-rank p50/p95/p99 triple of one latency dimension.
#[derive(Clone, Copy, Debug, Default)]
pub struct Percentiles {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Percentiles {
    /// Exact triple over the (unsorted) sample; zeros when empty.
    pub fn of(xs: &[f64]) -> Percentiles {
        if xs.is_empty() {
            return Percentiles::default();
        }
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.total_cmp(b));
        Percentiles {
            p50: percentile_sorted(&s, 50.0),
            p95: percentile_sorted(&s, 95.0),
            p99: percentile_sorted(&s, 99.0),
        }
    }
}

/// Fraction of observations at or under `deadline_s`. Standalone so
/// the monotonicity property ("a looser deadline never lowers
/// attainment") is testable in isolation.
pub fn attainment(e2e_s: &[f64], deadline_s: f64) -> f64 {
    if e2e_s.is_empty() {
        return 1.0;
    }
    e2e_s.iter().filter(|&&x| x <= deadline_s).count() as f64
        / e2e_s.len() as f64
}

/// Aggregated SLO report for one tenant class.
#[derive(Clone, Debug)]
pub struct ClassSlo {
    pub class: String,
    /// Requests observed (every terminal outcome counts).
    pub n: usize,
    /// Successful completions (EOS / length).
    pub completed: usize,
    /// Aborted or failed requests (`n − completed`).
    pub aborted: usize,
    pub ttft: Percentiles,
    pub tpot: Percentiles,
    pub e2e: Percentiles,
    /// SLO-attainment rate in [0, 1].
    pub attainment: f64,
    /// Generated tokens from successful completions / makespan.
    pub goodput_tok_s: f64,
    /// Preemption-fairness counters: how much disruption this class
    /// absorbed relative to its peers.
    pub preemptions: u64,
    pub swaps: u64,
    pub rescues: u64,
}

impl ClassSlo {
    /// The class's row as `(key, value)` pairs, ready to splice into a
    /// `BenchJsonRow`'s `extra` fields.
    pub fn to_fields(&self) -> Vec<(String, Json)> {
        vec![
            ("class".to_string(), Json::str(&self.class)),
            ("requests".to_string(), Json::from(self.n)),
            ("completed".to_string(), Json::from(self.completed)),
            ("aborted".to_string(), Json::from(self.aborted)),
            ("ttft_p50_s".to_string(), Json::num(self.ttft.p50)),
            ("ttft_p95_s".to_string(), Json::num(self.ttft.p95)),
            ("ttft_p99_s".to_string(), Json::num(self.ttft.p99)),
            ("tpot_p50_s".to_string(), Json::num(self.tpot.p50)),
            ("tpot_p95_s".to_string(), Json::num(self.tpot.p95)),
            ("tpot_p99_s".to_string(), Json::num(self.tpot.p99)),
            ("e2e_p50_s".to_string(), Json::num(self.e2e.p50)),
            ("e2e_p95_s".to_string(), Json::num(self.e2e.p95)),
            ("e2e_p99_s".to_string(), Json::num(self.e2e.p99)),
            ("slo_attainment".to_string(), Json::num(self.attainment)),
            ("goodput_tok_s".to_string(), Json::num(self.goodput_tok_s)),
            ("preemptions".to_string(), Json::from(self.preemptions as usize)),
            ("swaps".to_string(), Json::from(self.swaps as usize)),
            ("rescues".to_string(), Json::from(self.rescues as usize)),
        ]
    }
}

/// Group outcomes by class (first-seen order) and summarize each.
/// `makespan_s` is the wall/virtual span the replay took; it
/// denominates goodput.
pub fn summarize(
    outcomes: &[RequestOutcome],
    makespan_s: f64,
) -> Vec<ClassSlo> {
    let mut order: Vec<String> = Vec::new();
    for o in outcomes {
        if !order.contains(&o.class) {
            order.push(o.class.clone());
        }
    }
    order
        .into_iter()
        .map(|class| {
            let of: Vec<&RequestOutcome> =
                outcomes.iter().filter(|o| o.class == class).collect();
            let completed = of.iter().filter(|o| o.ok).count();
            let ttft: Vec<f64> = of.iter().map(|o| o.ttft_s).collect();
            let tpot: Vec<f64> = of
                .iter()
                .filter(|o| o.generated >= 2)
                .map(|o| o.tpot_s)
                .collect();
            let e2e: Vec<f64> = of.iter().map(|o| o.e2e_s).collect();
            let good_tokens: usize =
                of.iter().filter(|o| o.ok).map(|o| o.generated).sum();
            ClassSlo {
                n: of.len(),
                completed,
                aborted: of.len() - completed,
                ttft: Percentiles::of(&ttft),
                tpot: Percentiles::of(&tpot),
                e2e: Percentiles::of(&e2e),
                attainment: if of.is_empty() {
                    1.0
                } else {
                    of.iter().filter(|o| o.attained()).count() as f64
                        / of.len() as f64
                },
                goodput_tok_s: if makespan_s > 0.0 {
                    good_tokens as f64 / makespan_s
                } else {
                    0.0
                },
                preemptions: of.iter().map(|o| o.preemptions).sum(),
                swaps: of.iter().map(|o| o.swaps).sum(),
                rescues: of.iter().map(|o| o.rescues).sum(),
                class,
            }
        })
        .collect()
}

/// Human-readable per-class table (the soak bench prints this next to
/// the JSON trail).
pub fn table(slos: &[ClassSlo]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<18} {:>5} {:>5} {:>9} {:>9} {:>9} {:>9} {:>7} {:>9} {:>6} {:>5}\n",
        "class", "n", "ok", "ttft p50", "ttft p95", "tpot p50", "e2e p95",
        "attain", "goodput", "preem", "swap"
    ));
    for s in slos {
        out.push_str(&format!(
            "{:<18} {:>5} {:>5} {:>8.0}ms {:>8.0}ms {:>8.1}ms {:>8.2}s \
             {:>6.1}% {:>5.1}t/s {:>6} {:>5}\n",
            s.class,
            s.n,
            s.completed,
            s.ttft.p50 * 1e3,
            s.ttft.p95 * 1e3,
            s.tpot.p50 * 1e3,
            s.e2e.p95,
            s.attainment * 100.0,
            s.goodput_tok_s,
            s.preemptions,
            s.swaps,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn outcome(class: &str, ttft: f64, e2e: f64, gen: usize) -> RequestOutcome {
        RequestOutcome {
            class: class.to_string(),
            ttft_s: ttft,
            tpot_s: if gen >= 2 {
                (e2e - ttft) / (gen - 1) as f64
            } else {
                0.0
            },
            e2e_s: e2e,
            generated: gen,
            ok: true,
            deadline_ms: Some(1000),
            preemptions: 0,
            swaps: 0,
            rescues: 0,
        }
    }

    #[test]
    fn attainment_is_monotone_in_deadline() {
        check("slo-attainment-monotone", 40, |rng, size| {
            let n = 5 + size;
            let e2e: Vec<f64> = (0..n).map(|_| rng.f64() * 10.0).collect();
            let mut d1 = rng.f64() * 10.0;
            let mut d2 = rng.f64() * 10.0;
            if d1 > d2 {
                std::mem::swap(&mut d1, &mut d2);
            }
            let (a1, a2) = (attainment(&e2e, d1), attainment(&e2e, d2));
            if a1 > a2 {
                return Err(format!(
                    "attainment({d1})={a1} > attainment({d2})={a2}"
                ));
            }
            if !(0.0..=1.0).contains(&a1) {
                return Err(format!("attainment {a1} out of [0,1]"));
            }
            Ok(())
        });
    }

    #[test]
    fn summarize_groups_by_class_and_matches_exact_percentiles() {
        let mut outcomes = Vec::new();
        for i in 1..=100 {
            outcomes.push(outcome("a", i as f64 / 1000.0, i as f64 / 100.0, 10));
        }
        outcomes.push(outcome("b", 0.5, 2.0, 1));
        let slos = summarize(&outcomes, 10.0);
        assert_eq!(slos.len(), 2);
        let a = &slos[0];
        assert_eq!(a.class, "a");
        assert_eq!(a.n, 100);
        assert_eq!(a.completed, 100);
        // Nearest-rank over 1..=100 ms.
        assert!((a.ttft.p50 - 0.050).abs() < 1e-12);
        assert!((a.ttft.p95 - 0.095).abs() < 1e-12);
        assert!((a.ttft.p99 - 0.099).abs() < 1e-12);
        // Deadline 1000 ms: e2e runs 0.01..=1.0 s, all attained.
        assert!((a.attainment - 1.0).abs() < 1e-12);
        // 100 ok requests × 10 tokens over 10 s.
        assert!((a.goodput_tok_s - 100.0).abs() < 1e-9);
        let b = &slos[1];
        assert_eq!(b.n, 1);
        // Single-token request contributes no TPOT sample.
        assert_eq!(b.tpot.p50, 0.0);
        // e2e 2.0 s > 1.0 s deadline: missed.
        assert_eq!(b.attainment, 0.0);
    }

    #[test]
    fn aborted_requests_hurt_attainment_and_goodput() {
        let mut o = outcome("a", 0.1, 0.2, 50);
        o.ok = false;
        let slos = summarize(&[o], 1.0);
        assert_eq!(slos[0].completed, 0);
        assert_eq!(slos[0].aborted, 1);
        // Fast but aborted: not attained, no goodput.
        assert_eq!(slos[0].attainment, 0.0);
        assert_eq!(slos[0].goodput_tok_s, 0.0);
    }

    #[test]
    fn no_deadline_counts_as_attained_when_ok() {
        let mut o = outcome("a", 5.0, 50.0, 10);
        o.deadline_ms = None;
        assert!(o.attained());
        o.ok = false;
        assert!(!o.attained());
    }

    #[test]
    fn table_renders_one_line_per_class() {
        let slos = summarize(
            &[outcome("a", 0.1, 0.5, 4), outcome("b", 0.2, 0.9, 8)],
            1.0,
        );
        let t = table(&slos);
        assert_eq!(t.lines().count(), 3);
        assert!(t.contains("attain"));
        assert!(t.contains('a') && t.contains('b'));
    }
}
