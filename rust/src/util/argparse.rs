//! Declarative CLI argument parser (clap substitute). Supports
//! `--flag`, `--key value`, `--key=value`, positionals, per-flag help,
//! and subcommands (handled by the caller via `ArgSpec::positional`).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Clone, Debug)]
struct FlagSpec {
    name: String,
    help: String,
    default: Option<String>,
    takes_value: bool,
}

#[derive(Default)]
pub struct ArgSpec {
    about: String,
    flags: Vec<FlagSpec>,
    positionals: Vec<(String, String)>, // (name, help)
}

#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    present: Vec<String>,
    pub positional: Vec<String>,
}

impl ArgSpec {
    pub fn new(about: &str) -> Self {
        ArgSpec { about: about.to_string(), ..Default::default() }
    }

    /// `--name <value>` with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            takes_value: true,
        });
        self
    }

    /// Boolean `--name`.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            takes_value: false,
        });
        self
    }

    pub fn positional(mut self, name: &str, help: &str) -> Self {
        self.positionals.push((name.to_string(), help.to_string()));
        self
    }

    pub fn usage(&self, prog: &str) -> String {
        let mut s = format!("{}\n\nUsage: {prog}", self.about);
        for (p, _) in &self.positionals {
            s.push_str(&format!(" <{p}>"));
        }
        s.push_str(" [options]\n\nOptions:\n");
        for f in &self.flags {
            let head = if f.takes_value {
                format!("--{} <v>", f.name)
            } else {
                format!("--{}", f.name)
            };
            let def = f
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  {head:<26} {}{def}\n", f.help));
        }
        s
    }

    /// Parse; returns Err with the usage text on `--help` or bad input.
    pub fn parse(&self, argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        for f in &self.flags {
            if let Some(d) = &f.default {
                out.values.insert(f.name.clone(), d.clone());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                bail!("{}", self.usage("<prog>"));
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| {
                        anyhow::anyhow!(
                            "unknown flag --{name}\n\n{}",
                            self.usage("<prog>")
                        )
                    })?;
                out.present.push(name.clone());
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            if i >= argv.len() {
                                bail!("--{name} requires a value");
                            }
                            argv[i].clone()
                        }
                    };
                    out.values.insert(name, v);
                } else if inline.is_some() {
                    bail!("--{name} does not take a value");
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("flag --{name} not declared"))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        let v = self.get(name);
        v.parse().map_err(|_| {
            anyhow::anyhow!("--{name} expects an integer, got '{v}'")
        })
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        let v = self.get(name);
        v.parse()
            .map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{v}'"))
    }

    pub fn has(&self, name: &str) -> bool {
        self.present.iter().any(|p| p == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec::new("test")
            .opt("batch", "8", "batch size")
            .opt("policy", "lethe", "eviction policy")
            .flag("verbose", "chatty")
            .positional("cmd", "subcommand")
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = spec().parse(&sv(&["serve", "--batch", "16"])).unwrap();
        assert_eq!(a.positional, vec!["serve"]);
        assert_eq!(a.get_usize("batch").unwrap(), 16);
        assert_eq!(a.get("policy"), "lethe");
        assert!(!a.has("verbose"));
    }

    #[test]
    fn equals_form_and_flags() {
        let a = spec().parse(&sv(&["--batch=4", "--verbose"])).unwrap();
        assert_eq!(a.get_usize("batch").unwrap(), 4);
        assert!(a.has("verbose"));
    }

    #[test]
    fn rejects_unknown_and_missing_value() {
        assert!(spec().parse(&sv(&["--nope"])).is_err());
        assert!(spec().parse(&sv(&["--batch"])).is_err());
        assert!(spec().parse(&sv(&["--verbose=1"])).is_err());
    }

    #[test]
    fn help_contains_flags() {
        let err = spec().parse(&sv(&["--help"])).unwrap_err().to_string();
        assert!(err.contains("--batch"));
        assert!(err.contains("default: lethe"));
    }
}
