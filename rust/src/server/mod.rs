//! Request router / front door. Clients submit text prompts and receive
//! completions over channels. Since the multi-group refactor the serving
//! core behind this facade is the [`crate::supervisor`]: N fault-isolated
//! decode-group workers (each owning its own PJRT runtime + engine — the
//! engine is not `Sync`) under one supervisor thread that places
//! requests, watches group health, and rescues sequences off quarantined
//! groups. With `serving.groups = 1` (the default) the behaviour is the
//! previous single-engine-thread server, unchanged.

pub mod tcp;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Receiver;

use anyhow::Result;

use crate::config::ServingConfig;
use crate::model::Tokenizer;
use crate::policy::PolicyKind;
use crate::supervisor::Supervisor;

#[derive(Clone, Debug)]
pub struct GenerateRequest {
    pub prompt: String,
    pub max_new_tokens: usize,
    /// None = server default policy.
    pub policy: Option<PolicyKind>,
    /// Wall-clock completion budget in milliseconds; past it the
    /// request finishes with `DeadlineExceeded` at the next tick
    /// boundary. None = no deadline.
    pub deadline_ms: Option<u64>,
    /// Tenant-class label for per-class SLO accounting (e.g.
    /// "interactive" / "batch-reasoning"); folded into the per-class
    /// latency tracks in `{"stats": true}`. None = "default".
    pub class: Option<String>,
}

#[derive(Clone, Debug)]
pub struct GenerateResponse {
    pub id: u64,
    pub text: String,
    pub finish: String,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    pub ttft_s: f64,
    /// Seconds per output token after the first (0 for fewer than two
    /// generated tokens) — the decode-side SLO dimension next to TTFT.
    pub tpot_s: f64,
    pub total_s: f64,
    pub prune_rounds: usize,
    /// How many times the sequence was preempted under load or rescued
    /// across groups (each resume reconstructs the uncontended
    /// continuation).
    pub preemptions: u32,
    /// KV storage the request was served on ("f32" | "q8" | "q4", or
    /// "mixed" when a per-layer format map was active).
    pub kv_format: String,
}

/// Handle to the serving core.
pub struct Server {
    sup: Option<Supervisor>,
    next_id: AtomicU64,
    pub tokenizer: Tokenizer,
    /// Copy of the fault-injection config (the full config moves into
    /// the supervisor); the TCP front-end builds its connection-drop
    /// plan from it.
    pub faults: crate::config::FaultsConfig,
}

impl Server {
    /// Boot the serving core: `serving.groups` decode-group workers
    /// (each loading artifacts and warming the configured profile's
    /// executables) under one supervisor. Returns once every group is
    /// up; fails fast if any worker fails to boot or its shard-manifest
    /// fingerprint disagrees with the probe's.
    pub fn start(cfg: ServingConfig, default_policy: PolicyKind) -> Result<Server> {
        let probe = crate::model::ModelMeta::load(
            std::path::Path::new(&cfg.artifacts_dir),
        )?;
        let tokenizer = Tokenizer::from_meta(&probe)?;
        let faults = cfg.faults.clone();
        let sup = Supervisor::start(cfg, default_policy)?;
        Ok(Server {
            sup: Some(sup),
            next_id: AtomicU64::new(1),
            tokenizer,
            faults,
        })
    }

    fn sup(&self) -> &Supervisor {
        self.sup.as_ref().expect("supervisor lives until drop")
    }

    /// Submit a request; returns a receiver for the completion.
    pub fn submit(
        &self,
        req: GenerateRequest,
    ) -> Result<Receiver<Result<GenerateResponse>>> {
        self.sup().submit(req)
    }

    /// Convenience: synchronous request/response.
    pub fn generate(&self, req: GenerateRequest) -> Result<GenerateResponse> {
        let rx = self.submit(req)?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("serving core dropped the request"))?
    }

    /// Serving-pressure snapshot: aggregate queue/preemption/migration
    /// counters in the original single-scheduler shape, plus per-group
    /// health rows (`groups`), supervision counters and the sharded
    /// model manifest (`model`).
    pub fn stats(&self) -> Result<crate::util::json::Json> {
        self.sup().stats()
    }

    /// Operational control: fence decode group `g` off, rescue its
    /// in-flight sequences onto healthy groups, and let it restart
    /// with backoff. Returns false when `g` is unknown or not serving.
    pub fn quarantine_group(&self, g: usize) -> Result<bool> {
        self.sup().quarantine_group(g)
    }

    pub fn next_request_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    pub fn shutdown(mut self) {
        if let Some(s) = self.sup.take() {
            s.shutdown();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if let Some(s) = self.sup.take() {
            s.shutdown();
        }
    }
}
