//! Serving configuration: model/cache/scheduler/policy knobs, loadable
//! from a JSON file (`--config serve.json`) with CLI overrides. The two
//! paper hyperparameters keep their paper names: `sparse_ratio` (the
//! breakpoint tolerance τ of Eq. 4 / Algorithm 1 — the ablation of
//! Table 6) and `recent_ratio` (fraction of the live cache always kept
//! for recency — Table 5).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::kvcache::KvFormat;
use crate::util::json::Json;

/// Lethe-specific knobs (paper defaults: sparse_ratio=400, recent_ratio=0.3).
#[derive(Clone, Debug, PartialEq)]
pub struct LetheParams {
    /// τ in Eq. 4: max head/cut attention ratio accepted as a breakpoint.
    pub sparse_ratio: f64,
    /// Fraction of live tokens protected as "recent" regardless of score.
    pub recent_ratio: f64,
    /// RASR decay γ in Eq. 5.
    pub gamma: f64,
    /// Number of segments D the sorted score vector is cut into (Alg. 1).
    pub segments: usize,
    /// Attention-sink prefix always retained (StreamingLLM observation).
    pub sink_len: usize,
    /// Initial per-layer eviction threshold L_evict (tokens). Doubles when
    /// Algorithm 1 finds no breakpoint (conservative delay).
    pub evict_threshold: usize,
}

impl Default for LetheParams {
    fn default() -> Self {
        LetheParams {
            sparse_ratio: 400.0,
            recent_ratio: 0.3,
            gamma: 0.95,
            segments: 8,
            sink_len: 4,
            evict_threshold: 128,
        }
    }
}

/// Budget knobs shared by the baseline policies so Table 1 compares like
/// for like: every policy is held to roughly the same token budget.
#[derive(Clone, Debug, PartialEq)]
pub struct BaselineParams {
    /// Token budget per layer for H2O / PyramidKV / StreamingLLM.
    pub budget: usize,
    /// H2O: fraction of the budget given to recent tokens (rest = heavy
    /// hitters).
    pub h2o_recent_frac: f64,
    /// StreamingLLM: sink prefix length.
    pub sink_len: usize,
    /// PyramidKV: budget decay from the bottom layer to the top (the
    /// pyramidal allocation; 1.0 = uniform).
    pub pyramid_beta: f64,
}

impl Default for BaselineParams {
    fn default() -> Self {
        BaselineParams {
            budget: 128,
            h2o_recent_frac: 0.5,
            sink_len: 4,
            pyramid_beta: 2.0,
        }
    }
}

/// Sparsity-directed mixed-precision rule (`kv.mixed`): layers whose
/// estimated attention sparsity (Eq. 1 EMA, engine-aggregated) is at
/// least `threshold` store their cache in the `sparse` format, the rest
/// in the `dense` format. The rationale mirrors the paper's spatial
/// dimension: high-sparsity layers concentrate attention on few tokens
/// and tolerate aggressive compression, while dense layers spread mass
/// and need fidelity.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MixedKvRule {
    /// Format for high-sparsity layers (default `"q4"`).
    pub sparse: KvFormat,
    /// Format for low-sparsity layers (default `"f32"`).
    pub dense: KvFormat,
    /// Sparsity cutoff in [0, 1]; layers start below it (estimates are
    /// zero until observed), so cold groups are all-dense.
    pub threshold: f64,
}

impl Default for MixedKvRule {
    fn default() -> Self {
        MixedKvRule {
            sparse: KvFormat::QuantI4,
            dense: KvFormat::F32,
            threshold: 0.5,
        }
    }
}

/// KV cache storage knobs. `format` selects the uniform engine storage
/// backend (see [`crate::kvcache::backend`]): `"f32"` dense rows (the
/// serving default), `"q8"` per-row symmetric int8 (~3.9× smaller) or
/// `"q4"` group-wise int4 (~5.3× smaller), all dequantized during
/// upload packing. `layer_formats` pins individual layers to an explicit
/// format, and `mixed` derives the remaining layers' formats from the
/// runtime sparsity estimates; resolution order per layer is
/// `layer_formats` > `mixed` > `format` (see
/// [`KvConfig::resolve_formats`]). Table 2 reports both actual and
/// f32-equivalent bytes so every configuration stays comparable.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct KvConfig {
    /// Uniform default storage format.
    pub format: KvFormat,
    /// Explicit per-layer overrides (layer index → format).
    pub layer_formats: BTreeMap<usize, KvFormat>,
    /// Optional sparsity-directed rule for layers without an override.
    pub mixed: Option<MixedKvRule>,
}

impl KvConfig {
    /// Resolve the per-layer storage formats for a model with `layers`
    /// layers, given the engine's current per-layer sparsity estimates
    /// (`sparsity[l]`; missing entries count as 0.0 = dense). Layer
    /// precedence: explicit `layer_formats` entry, then the `mixed`
    /// rule, then the uniform `format`.
    pub fn resolve_formats(&self, layers: usize, sparsity: &[f64]) -> Vec<KvFormat> {
        (0..layers)
            .map(|l| {
                if let Some(&f) = self.layer_formats.get(&l) {
                    f
                } else if let Some(m) = &self.mixed {
                    let s = sparsity.get(l).copied().unwrap_or(0.0);
                    if s >= m.threshold { m.sparse } else { m.dense }
                } else {
                    self.format
                }
            })
            .collect()
    }
}

#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Max sequences decoded together (bucketed to compiled batch sizes).
    pub max_batch: usize,
    /// Queue depth before admission control pushes back.
    pub max_waiting: usize,
    /// Max new tokens any request may generate.
    pub max_new_tokens: usize,
    /// Prefill bucket sizes available (must match compiled artifacts).
    pub prefill_buckets: Vec<usize>,
    /// Chunked-prefill grain: per scheduler tick, one prefilling
    /// sequence advances by up to this many prompt tokens (the compiled
    /// prefill kernels recompute the prefix at the smallest bucket that
    /// fits, so this bounds the per-tick stall to one executable run).
    /// Long prompts therefore interleave with decode steps instead of
    /// blocking the co-batched group.
    pub prefill_chunk: usize,
    /// Group-wide live-KV byte budget (0 = unlimited). When the
    /// co-batched group's `live_bytes` exceeds it, the youngest
    /// sequence is recompute-preempted back to the waiting queue
    /// (prompt + generated re-prefilled on resume) — never OOM-killed;
    /// `FinishReason::Oom` stays reserved for sequences that exceed the
    /// largest compiled capacity even alone.
    pub kv_budget_bytes: usize,
    /// Consecutive ticks the engine's resolved per-layer format map
    /// must differ from the live group's before the scheduler migrates
    /// layer formats in place (hysteresis against a sparsity EMA
    /// hovering at the `kv.mixed` threshold).
    pub migrate_patience: usize,
    /// Swap-vs-recompute cost model for preemption victims: a victim is
    /// swapped to host (stored-precision rows serialized and restored
    /// verbatim) instead of recompute-preempted when its live KV bytes
    /// are at most `resume_tokens * swap_threshold_bytes_per_token`.
    /// 0 disables swapping entirely (recompute only, the PR-5
    /// behaviour). The 4096 default comes from the soak-trace sweep in
    /// `benches/soak_trace.rs` (`swap_sweep_*` rows of
    /// `BENCH_soak.json`): on the pinned mixed-tenant trace it keeps
    /// interactive p95 TTFT at the recompute-path level while cutting
    /// re-prefill work; pushing the threshold to "always swap" buys no
    /// further goodput and inflates swap traffic.
    pub swap_threshold_bytes_per_token: usize,
    /// Graceful-shutdown drain window: after shutdown is requested the
    /// scheduler stops admitting and gives in-flight work this many
    /// milliseconds to finish before deadline-ing it out.
    pub drain_window_ms: u64,
    /// Serve chunked prefills through the incremental `prefill_t{T}_kv`
    /// executables when the artifact set carries them: each chunk
    /// attends over the accumulated prior KV, so a whole prompt costs
    /// O(n) instead of the recompute path's O(n²/chunk). Off (or with
    /// an old artifact set) every chunk re-prefills the grown prefix
    /// from position 0.
    pub incremental_prefill: bool,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_batch: 8,
            max_waiting: 256,
            max_new_tokens: 96,
            prefill_buckets: vec![32, 64, 128, 192],
            prefill_chunk: 64,
            kv_budget_bytes: 0,
            migrate_patience: 4,
            swap_threshold_bytes_per_token: 4096,
            drain_window_ms: 2000,
            incremental_prefill: true,
        }
    }
}

/// Engine execution knobs (`engine.*`).
#[derive(Clone, Debug, PartialEq)]
pub struct EngineConfig {
    /// Software-pipeline the decode loop: after a step's critical lane
    /// (KV mirror insert + sampling), pack and submit the *next* step's
    /// device execute on the async runtime seam, then run the deferred
    /// policy lane (RASR scoring, sparsity EMA, retention planning)
    /// concurrently with it. Fingerprint-validated so output stays
    /// token-identical to serial decode under greedy sampling; the
    /// engine drains to serial at every boundary where deferred work
    /// can change layout or control flow. Disable with `--no-pipeline`
    /// (or `"engine": {"pipeline_decode": false}`) to force the fully
    /// serial step.
    pub pipeline_decode: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { pipeline_decode: true }
    }
}

/// Deterministic fault-injection knobs (`faults.*`). All rates default
/// to zero, which disables injection entirely — the engine then holds
/// no [`crate::fault::FaultPlan`] and the hot path pays one branch per
/// tick.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultsConfig {
    /// Seed for the fault schedule (same seed ⇒ same injected faults).
    pub seed: u64,
    /// Per-draw probability of injecting at the engine seams (KV
    /// insert, runtime execute, migration, tick stall), in [0, 1].
    pub rate: f64,
    /// Milliseconds a `TickStall` injection sleeps before the step.
    pub stall_ms: u64,
    /// Per-connection probability of dropping a TCP connection after
    /// its first request, in [0, 1].
    pub conn_drop_rate: f64,
    /// Per-tick probability of a group-scoped fault (worker panic or
    /// heartbeat stall), in [0, 1]. Drawn from a per-group plan so the
    /// engine-seam schedule above is unaffected.
    pub group_rate: f64,
}

impl FaultsConfig {
    /// True when any injection seam has a non-zero probability.
    pub fn enabled(&self) -> bool {
        self.rate > 0.0 || self.conn_drop_rate > 0.0 || self.group_rate > 0.0
    }
}

/// Multi-group supervision knobs (`serving.*`). The default — one
/// group, no pooled budget, stall detection off — reproduces the
/// single-`Scheduler` behaviour exactly.
#[derive(Clone, Debug, PartialEq)]
pub struct SupervisorConfig {
    /// Number of supervised `Scheduler`+`DecodeGroup` workers.
    pub groups: usize,
    /// Global live-KV byte pool carved evenly into per-group budgets.
    /// 0 keeps each group's budget at `scheduler.kv_budget_bytes`.
    pub kv_pool_bytes: usize,
    /// A group whose tick overruns this many milliseconds (measured by
    /// supervisor heartbeats) is declared stalled and quarantined.
    /// 0 disables stall detection.
    pub tick_timeout_ms: u64,
    /// Tick error-rate EMA at which a group is marked `Degraded`
    /// (deprioritized for placement), in [0, 1].
    pub degraded_error_rate: f64,
    /// Tick error-rate EMA at which a group is quarantined and its
    /// sequences rescued, in [0, 1]. Must be >= `degraded_error_rate`.
    pub quarantine_error_rate: f64,
    /// Restart budget: a group restarted more than this many times is
    /// marked permanently dead.
    pub max_restarts: u32,
    /// Base restart backoff; doubles per consecutive restart.
    pub restart_backoff_ms: u64,
}

impl Default for SupervisorConfig {
    fn default() -> Self {
        SupervisorConfig {
            groups: 1,
            kv_pool_bytes: 0,
            tick_timeout_ms: 0,
            degraded_error_rate: 0.1,
            quarantine_error_rate: 0.5,
            max_restarts: 3,
            restart_backoff_ms: 100,
        }
    }
}

impl SupervisorConfig {
    /// Per-group live-KV budget: an even share of `kv_pool_bytes`, or
    /// the fallback (the scheduler's own budget) when no pool is set.
    pub fn group_budget_bytes(&self, fallback: usize) -> usize {
        if self.kv_pool_bytes == 0 {
            fallback
        } else {
            self.kv_pool_bytes / self.groups.max(1)
        }
    }
}

#[derive(Clone, Debug)]
pub struct ServingConfig {
    /// Directory with HLO artifacts + weights + manifest.
    pub artifacts_dir: String,
    /// Cache profile to serve with ("std" C=512 or "long" C=2048).
    pub cache_profile: String,
    pub lethe: LetheParams,
    pub baseline: BaselineParams,
    pub scheduler: SchedulerConfig,
    pub kv: KvConfig,
    pub engine: EngineConfig,
    pub faults: FaultsConfig,
    pub serving: SupervisorConfig,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            artifacts_dir: "artifacts".to_string(),
            cache_profile: "std".to_string(),
            lethe: LetheParams::default(),
            baseline: BaselineParams::default(),
            scheduler: SchedulerConfig::default(),
            kv: KvConfig::default(),
            engine: EngineConfig::default(),
            faults: FaultsConfig::default(),
            serving: SupervisorConfig::default(),
        }
    }
}

fn get_f64(obj: &Json, key: &str, dst: &mut f64) -> Result<()> {
    if let Some(v) = obj.opt(key) {
        *dst = v.as_f64().with_context(|| format!("config key '{key}'"))?;
    }
    Ok(())
}

fn get_usize(obj: &Json, key: &str, dst: &mut usize) -> Result<()> {
    if let Some(v) = obj.opt(key) {
        *dst = v.as_usize().with_context(|| format!("config key '{key}'"))?;
    }
    Ok(())
}

impl ServingConfig {
    /// Load from JSON, overlaying onto defaults. Unknown keys are
    /// rejected at the section level to catch typos.
    pub fn from_json(j: &Json) -> Result<ServingConfig> {
        let mut c = ServingConfig::default();
        for (k, _) in j.as_obj()? {
            if !["artifacts_dir", "cache_profile", "lethe", "baseline",
                 "scheduler", "kv", "engine", "faults", "serving"]
                .contains(&k.as_str())
            {
                anyhow::bail!("unknown config section '{k}'");
            }
        }
        if let Some(v) = j.opt("artifacts_dir") {
            c.artifacts_dir = v.as_str()?.to_string();
        }
        if let Some(v) = j.opt("cache_profile") {
            c.cache_profile = v.as_str()?.to_string();
        }
        if let Some(l) = j.opt("lethe") {
            get_f64(l, "sparse_ratio", &mut c.lethe.sparse_ratio)?;
            get_f64(l, "recent_ratio", &mut c.lethe.recent_ratio)?;
            get_f64(l, "gamma", &mut c.lethe.gamma)?;
            get_usize(l, "segments", &mut c.lethe.segments)?;
            get_usize(l, "sink_len", &mut c.lethe.sink_len)?;
            get_usize(l, "evict_threshold", &mut c.lethe.evict_threshold)?;
        }
        if let Some(b) = j.opt("baseline") {
            get_usize(b, "budget", &mut c.baseline.budget)?;
            get_f64(b, "h2o_recent_frac", &mut c.baseline.h2o_recent_frac)?;
            get_usize(b, "sink_len", &mut c.baseline.sink_len)?;
            get_f64(b, "pyramid_beta", &mut c.baseline.pyramid_beta)?;
        }
        if let Some(s) = j.opt("scheduler") {
            get_usize(s, "max_batch", &mut c.scheduler.max_batch)?;
            get_usize(s, "max_waiting", &mut c.scheduler.max_waiting)?;
            get_usize(s, "max_new_tokens", &mut c.scheduler.max_new_tokens)?;
            get_usize(s, "prefill_chunk", &mut c.scheduler.prefill_chunk)?;
            get_usize(s, "kv_budget_bytes", &mut c.scheduler.kv_budget_bytes)?;
            get_usize(s, "migrate_patience", &mut c.scheduler.migrate_patience)?;
            get_usize(
                s,
                "swap_threshold_bytes_per_token",
                &mut c.scheduler.swap_threshold_bytes_per_token,
            )?;
            if let Some(v) = s.opt("drain_window_ms") {
                c.scheduler.drain_window_ms = v
                    .as_usize()
                    .context("config key 'drain_window_ms'")?
                    as u64;
            }
            if let Some(v) = s.opt("incremental_prefill") {
                c.scheduler.incremental_prefill = v
                    .as_bool()
                    .context("config key 'incremental_prefill'")?;
            }
            if let Some(v) = s.opt("prefill_buckets") {
                c.scheduler.prefill_buckets = v
                    .as_arr()?
                    .iter()
                    .map(|x| x.as_usize())
                    .collect::<Result<_>>()?;
            }
        }
        if let Some(kv) = j.opt("kv") {
            for (k, _) in kv.as_obj()? {
                if !["format", "layer_formats", "mixed"]
                    .contains(&k.as_str())
                {
                    anyhow::bail!("unknown kv key '{k}'");
                }
            }
            if let Some(v) = kv.opt("format") {
                c.kv.format = KvFormat::parse(v.as_str()?)
                    .context("config key 'kv.format'")?;
            }
            if let Some(v) = kv.opt("layer_formats") {
                for (k, val) in v.as_obj()? {
                    let l: usize = k.parse().map_err(|_| {
                        anyhow::anyhow!(
                            "kv.layer_formats key '{k}' is not a layer index"
                        )
                    })?;
                    let f = KvFormat::parse(val.as_str()?)
                        .with_context(|| format!("kv.layer_formats['{k}']"))?;
                    c.kv.layer_formats.insert(l, f);
                }
            }
            if let Some(m) = kv.opt("mixed") {
                for (k, _) in m.as_obj()? {
                    if !["sparse", "dense", "threshold"]
                        .contains(&k.as_str())
                    {
                        anyhow::bail!("unknown kv.mixed key '{k}'");
                    }
                }
                let mut rule = MixedKvRule::default();
                if let Some(v) = m.opt("sparse") {
                    rule.sparse = KvFormat::parse(v.as_str()?)
                        .context("config key 'kv.mixed.sparse'")?;
                }
                if let Some(v) = m.opt("dense") {
                    rule.dense = KvFormat::parse(v.as_str()?)
                        .context("config key 'kv.mixed.dense'")?;
                }
                get_f64(m, "threshold", &mut rule.threshold)?;
                c.kv.mixed = Some(rule);
            }
        }
        if let Some(e) = j.opt("engine") {
            for (k, _) in e.as_obj()? {
                if !["pipeline_decode"].contains(&k.as_str()) {
                    anyhow::bail!("unknown engine key '{k}'");
                }
            }
            if let Some(v) = e.opt("pipeline_decode") {
                c.engine.pipeline_decode = v
                    .as_bool()
                    .context("config key 'engine.pipeline_decode'")?;
            }
        }
        if let Some(f) = j.opt("faults") {
            for (k, _) in f.as_obj()? {
                if !["seed", "rate", "stall_ms", "conn_drop_rate",
                     "group_rate"]
                    .contains(&k.as_str())
                {
                    anyhow::bail!("unknown faults key '{k}'");
                }
            }
            if let Some(v) = f.opt("seed") {
                c.faults.seed =
                    v.as_usize().context("config key 'faults.seed'")? as u64;
            }
            get_f64(f, "rate", &mut c.faults.rate)?;
            if let Some(v) = f.opt("stall_ms") {
                c.faults.stall_ms = v
                    .as_usize()
                    .context("config key 'faults.stall_ms'")?
                    as u64;
            }
            get_f64(f, "conn_drop_rate", &mut c.faults.conn_drop_rate)?;
            get_f64(f, "group_rate", &mut c.faults.group_rate)?;
        }
        if let Some(s) = j.opt("serving") {
            for (k, _) in s.as_obj()? {
                if !["groups", "kv_pool_bytes", "tick_timeout_ms",
                     "degraded_error_rate", "quarantine_error_rate",
                     "max_restarts", "restart_backoff_ms"]
                    .contains(&k.as_str())
                {
                    anyhow::bail!("unknown serving key '{k}'");
                }
            }
            get_usize(s, "groups", &mut c.serving.groups)?;
            get_usize(s, "kv_pool_bytes", &mut c.serving.kv_pool_bytes)?;
            if let Some(v) = s.opt("tick_timeout_ms") {
                c.serving.tick_timeout_ms = v
                    .as_usize()
                    .context("config key 'serving.tick_timeout_ms'")?
                    as u64;
            }
            get_f64(s, "degraded_error_rate",
                    &mut c.serving.degraded_error_rate)?;
            get_f64(s, "quarantine_error_rate",
                    &mut c.serving.quarantine_error_rate)?;
            if let Some(v) = s.opt("max_restarts") {
                c.serving.max_restarts = v
                    .as_usize()
                    .context("config key 'serving.max_restarts'")?
                    as u32;
            }
            if let Some(v) = s.opt("restart_backoff_ms") {
                c.serving.restart_backoff_ms = v
                    .as_usize()
                    .context("config key 'serving.restart_backoff_ms'")?
                    as u64;
            }
        }
        c.validate()?;
        Ok(c)
    }

    pub fn load(path: &Path) -> Result<ServingConfig> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path:?}"))?;
        Self::from_json(&crate::util::json::parse(&src)?)
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.lethe.sparse_ratio >= 1.0,
                        "sparse_ratio (τ) must be >= 1");
        anyhow::ensure!(
            (0.0..1.0).contains(&self.lethe.recent_ratio),
            "recent_ratio must be in [0, 1)"
        );
        anyhow::ensure!(
            self.lethe.gamma > 0.0 && self.lethe.gamma < 1.0,
            "gamma must be in (0, 1)"
        );
        anyhow::ensure!(self.lethe.segments >= 2, "segments must be >= 2");
        anyhow::ensure!(self.scheduler.max_batch >= 1, "max_batch >= 1");
        anyhow::ensure!(!self.scheduler.prefill_buckets.is_empty(),
                        "need at least one prefill bucket");
        anyhow::ensure!(self.scheduler.prefill_chunk >= 1,
                        "prefill_chunk must be >= 1");
        anyhow::ensure!(self.scheduler.migrate_patience >= 1,
                        "migrate_patience must be >= 1");
        if let Some(m) = &self.kv.mixed {
            anyhow::ensure!(
                (0.0..=1.0).contains(&m.threshold),
                "kv.mixed.threshold must be in [0, 1]"
            );
        }
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.faults.rate),
            "faults.rate must be in [0, 1]"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.faults.conn_drop_rate),
            "faults.conn_drop_rate must be in [0, 1]"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.faults.group_rate),
            "faults.group_rate must be in [0, 1]"
        );
        anyhow::ensure!(self.serving.groups >= 1, "serving.groups >= 1");
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.serving.degraded_error_rate),
            "serving.degraded_error_rate must be in [0, 1]"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.serving.quarantine_error_rate),
            "serving.quarantine_error_rate must be in [0, 1]"
        );
        anyhow::ensure!(
            self.serving.quarantine_error_rate
                >= self.serving.degraded_error_rate,
            "serving.quarantine_error_rate must be >= degraded_error_rate"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn defaults_are_paper_defaults() {
        let p = LetheParams::default();
        assert_eq!(p.sparse_ratio, 400.0);
        assert_eq!(p.recent_ratio, 0.3);
    }

    #[test]
    fn json_overlay() {
        let j = parse(
            r#"{"cache_profile": "long",
                "lethe": {"sparse_ratio": 100, "recent_ratio": 0.2},
                "scheduler": {"max_batch": 4}}"#,
        )
        .unwrap();
        let c = ServingConfig::from_json(&j).unwrap();
        assert_eq!(c.cache_profile, "long");
        assert_eq!(c.lethe.sparse_ratio, 100.0);
        assert_eq!(c.lethe.recent_ratio, 0.2);
        assert_eq!(c.lethe.gamma, 0.95); // untouched default
        assert_eq!(c.scheduler.max_batch, 4);
    }

    #[test]
    fn scheduler_lifecycle_knobs_parse_and_validate() {
        let c = ServingConfig::from_json(&parse("{}").unwrap()).unwrap();
        assert_eq!(c.scheduler.prefill_chunk, 64);
        assert_eq!(c.scheduler.kv_budget_bytes, 0);
        assert_eq!(c.scheduler.migrate_patience, 4);
        assert!(c.scheduler.incremental_prefill, "incremental by default");
        let c = ServingConfig::from_json(
            &parse(
                r#"{"scheduler": {"prefill_chunk": 16,
                                  "kv_budget_bytes": 65536,
                                  "migrate_patience": 2,
                                  "incremental_prefill": false}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(c.scheduler.prefill_chunk, 16);
        assert_eq!(c.scheduler.kv_budget_bytes, 65536);
        assert_eq!(c.scheduler.migrate_patience, 2);
        assert!(!c.scheduler.incremental_prefill);
        assert!(ServingConfig::from_json(
            &parse(r#"{"scheduler": {"incremental_prefill": 3}}"#).unwrap()
        )
        .is_err());
        assert!(ServingConfig::from_json(
            &parse(r#"{"scheduler": {"prefill_chunk": 0}}"#).unwrap()
        )
        .is_err());
        assert!(ServingConfig::from_json(
            &parse(r#"{"scheduler": {"migrate_patience": 0}}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn faults_and_resilience_knobs_parse_and_validate() {
        // Defaults: injection off, swap at the sweep-tuned 4096 B/token
        // threshold (see `SchedulerConfig` docs), 2 s drain window.
        let c = ServingConfig::from_json(&parse("{}").unwrap()).unwrap();
        assert!(!c.faults.enabled());
        assert_eq!(c.scheduler.swap_threshold_bytes_per_token, 4096);
        assert_eq!(c.scheduler.drain_window_ms, 2000);

        let c = ServingConfig::from_json(
            &parse(
                r#"{"faults": {"seed": 9, "rate": 0.05, "stall_ms": 3,
                               "conn_drop_rate": 0.1},
                    "scheduler": {"swap_threshold_bytes_per_token": 0,
                                  "drain_window_ms": 500}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(c.faults.seed, 9);
        assert_eq!(c.faults.rate, 0.05);
        assert_eq!(c.faults.stall_ms, 3);
        assert_eq!(c.faults.conn_drop_rate, 0.1);
        assert!(c.faults.enabled());
        assert_eq!(
            c.scheduler.swap_threshold_bytes_per_token, 0,
            "swap stays explicitly disableable"
        );
        assert_eq!(c.scheduler.drain_window_ms, 500);

        // Out-of-range rates and unknown keys are rejected.
        assert!(ServingConfig::from_json(
            &parse(r#"{"faults": {"rate": 1.5}}"#).unwrap()
        )
        .is_err());
        assert!(ServingConfig::from_json(
            &parse(r#"{"faults": {"conn_drop_rate": -0.1}}"#).unwrap()
        )
        .is_err());
        assert!(ServingConfig::from_json(
            &parse(r#"{"faults": {"probability": 0.5}}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn serving_knobs_parse_validate_and_default_to_one_group() {
        let c = ServingConfig::from_json(&parse("{}").unwrap()).unwrap();
        assert_eq!(c.serving, SupervisorConfig::default());
        assert_eq!(c.serving.groups, 1);
        assert_eq!(c.serving.group_budget_bytes(4096), 4096,
                   "no pool: fall through to the scheduler budget");

        let c = ServingConfig::from_json(
            &parse(
                r#"{"serving": {"groups": 3, "kv_pool_bytes": 300000,
                                "tick_timeout_ms": 250,
                                "degraded_error_rate": 0.2,
                                "quarantine_error_rate": 0.6,
                                "max_restarts": 5,
                                "restart_backoff_ms": 50},
                    "faults": {"group_rate": 0.02}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(c.serving.groups, 3);
        assert_eq!(c.serving.kv_pool_bytes, 300000);
        assert_eq!(c.serving.group_budget_bytes(4096), 100000,
                   "pool is carved evenly across groups");
        assert_eq!(c.serving.tick_timeout_ms, 250);
        assert_eq!(c.serving.degraded_error_rate, 0.2);
        assert_eq!(c.serving.quarantine_error_rate, 0.6);
        assert_eq!(c.serving.max_restarts, 5);
        assert_eq!(c.serving.restart_backoff_ms, 50);
        assert_eq!(c.faults.group_rate, 0.02);
        assert!(c.faults.enabled(), "group_rate alone enables injection");

        for bad in [
            r#"{"serving": {"groups": 0}}"#,
            r#"{"serving": {"degraded_error_rate": 1.5}}"#,
            r#"{"serving": {"degraded_error_rate": 0.6,
                            "quarantine_error_rate": 0.2}}"#,
            r#"{"serving": {"workers": 2}}"#,
            r#"{"faults": {"group_rate": -0.5}}"#,
        ] {
            assert!(ServingConfig::from_json(&parse(bad).unwrap()).is_err(),
                    "should reject {bad}");
        }
    }

    #[test]
    fn engine_pipeline_knob_parses_and_defaults_on() {
        let c = ServingConfig::from_json(&parse("{}").unwrap()).unwrap();
        assert!(c.engine.pipeline_decode, "pipelining is on by default");
        let c = ServingConfig::from_json(
            &parse(r#"{"engine": {"pipeline_decode": false}}"#).unwrap(),
        )
        .unwrap();
        assert!(!c.engine.pipeline_decode);
        assert!(ServingConfig::from_json(
            &parse(r#"{"engine": {"pipeline_decode": 1}}"#).unwrap()
        )
        .is_err());
        assert!(ServingConfig::from_json(
            &parse(r#"{"engine": {"pipelined": true}}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn kv_format_defaults_to_f32_and_parses_q8() {
        // Absent section and absent key both leave the default.
        let c = ServingConfig::from_json(&parse("{}").unwrap()).unwrap();
        assert_eq!(c.kv.format, KvFormat::F32);
        let c = ServingConfig::from_json(&parse(r#"{"kv": {}}"#).unwrap())
            .unwrap();
        assert_eq!(c.kv.format, KvFormat::F32);
        let c = ServingConfig::from_json(
            &parse(r#"{"kv": {"format": "q8"}}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(c.kv.format, KvFormat::QuantI8);
        let c = ServingConfig::from_json(
            &parse(r#"{"kv": {"format": "f32"}}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(c.kv.format, KvFormat::F32);
        let c = ServingConfig::from_json(
            &parse(r#"{"kv": {"format": "q4"}}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(c.kv.format, KvFormat::QuantI4);
    }

    #[test]
    fn kv_layer_formats_and_mixed_parse() {
        let c = ServingConfig::from_json(
            &parse(
                r#"{"kv": {"format": "q8",
                           "layer_formats": {"0": "f32", "3": "q4"},
                           "mixed": {"sparse": "q4", "dense": "f32",
                                     "threshold": 0.6}}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(c.kv.format, KvFormat::QuantI8);
        assert_eq!(c.kv.layer_formats.get(&0), Some(&KvFormat::F32));
        assert_eq!(c.kv.layer_formats.get(&3), Some(&KvFormat::QuantI4));
        let m = c.kv.mixed.unwrap();
        assert_eq!(m.sparse, KvFormat::QuantI4);
        assert_eq!(m.dense, KvFormat::F32);
        assert_eq!(m.threshold, 0.6);

        // Partial mixed spec keeps rule defaults.
        let c = ServingConfig::from_json(
            &parse(r#"{"kv": {"mixed": {}}}"#).unwrap(),
        )
        .unwrap();
        let m = c.kv.mixed.unwrap();
        assert_eq!(m, MixedKvRule::default());

        // Bad layer key / format / threshold are rejected.
        assert!(ServingConfig::from_json(
            &parse(r#"{"kv": {"layer_formats": {"x": "q4"}}}"#).unwrap()
        )
        .is_err());
        assert!(ServingConfig::from_json(
            &parse(r#"{"kv": {"layer_formats": {"1": "fp8"}}}"#).unwrap()
        )
        .is_err());
        assert!(ServingConfig::from_json(
            &parse(r#"{"kv": {"mixed": {"threshold": 1.5}}}"#).unwrap()
        )
        .is_err());
        assert!(ServingConfig::from_json(
            &parse(r#"{"kv": {"mixed": {"cutoff": 0.5}}}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn resolve_formats_precedence_and_sparsity_rule() {
        let mut kv = KvConfig {
            format: KvFormat::QuantI8,
            ..KvConfig::default()
        };
        // Uniform only.
        assert_eq!(
            kv.resolve_formats(3, &[]),
            vec![KvFormat::QuantI8; 3]
        );
        // Mixed rule splits by threshold; missing estimates are dense.
        kv.mixed = Some(MixedKvRule {
            sparse: KvFormat::QuantI4,
            dense: KvFormat::F32,
            threshold: 0.5,
        });
        assert_eq!(
            kv.resolve_formats(4, &[0.9, 0.1, 0.5]),
            vec![
                KvFormat::QuantI4, // 0.9 >= 0.5
                KvFormat::F32,     // 0.1 < 0.5
                KvFormat::QuantI4, // 0.5 >= 0.5
                KvFormat::F32,     // no estimate yet
            ]
        );
        // Explicit per-layer override beats the rule.
        kv.layer_formats.insert(0, KvFormat::F32);
        assert_eq!(kv.resolve_formats(2, &[0.9, 0.9])[0], KvFormat::F32);
        assert_eq!(kv.resolve_formats(2, &[0.9, 0.9])[1], KvFormat::QuantI4);
    }

    #[test]
    fn kv_format_rejects_unknown_values_and_keys() {
        let err = ServingConfig::from_json(
            &parse(r#"{"kv": {"format": "fp8"}}"#).unwrap(),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("unknown kv format 'fp8'"),
                "unhelpful error: {err:#}");
        assert!(ServingConfig::from_json(
            &parse(r#"{"kv": {"fmt": "q8"}}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn rejects_unknown_section_and_bad_values() {
        assert!(ServingConfig::from_json(
            &parse(r#"{"letthe": {}}"#).unwrap()
        )
        .is_err());
        assert!(ServingConfig::from_json(
            &parse(r#"{"lethe": {"recent_ratio": 1.5}}"#).unwrap()
        )
        .is_err());
        assert!(ServingConfig::from_json(
            &parse(r#"{"lethe": {"sparse_ratio": 0.5}}"#).unwrap()
        )
        .is_err());
    }
}
