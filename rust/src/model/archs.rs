//! Architecture catalogue for the A100 simulator (`sim/`): the real
//! configs of the four DeepSeek-R1-Distill models the paper evaluates
//! (Tables 2–3, Figures 4 and 6). Dims are the published Qwen2/LLaMA
//! configs the distills inherit.

/// Static architecture description of a served model.
#[derive(Clone, Copy, Debug)]
pub struct ArchSpec {
    pub name: &'static str,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_q_heads: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub vocab_size: usize,
    /// Bytes per weight element as served (fp16/bf16 = 2).
    pub weight_bytes: usize,
    /// Bytes per KV-cache element (fp16 = 2).
    pub kv_bytes: usize,
    /// Tensor-parallel GPU count used in the paper's setup.
    pub tp: usize,
}

impl ArchSpec {
    /// Total parameter count (embeddings + blocks + head), exact enough
    /// for memory accounting (±1%).
    pub fn param_count(&self) -> usize {
        let d = self.d_model;
        let attn = d * self.n_q_heads * self.d_head      // wq
            + 2 * d * self.n_kv_heads * self.d_head      // wk, wv
            + self.n_q_heads * self.d_head * d;          // wo
        let mlp = 3 * d * self.d_ff;                     // gate, up, down
        let norms = 2 * d;
        let blocks = self.n_layers * (attn + mlp + norms);
        let embed = 2 * self.vocab_size * d;             // embed + lm_head
        blocks + embed + d
    }

    /// Model weight bytes per GPU under tensor parallelism.
    pub fn weight_bytes_per_gpu(&self) -> usize {
        self.param_count() * self.weight_bytes / self.tp
    }

    /// KV-cache bytes per cached token per sequence, per GPU.
    pub fn kv_bytes_per_token_per_gpu(&self) -> usize {
        self.n_layers * 2 * self.n_kv_heads * self.d_head * self.kv_bytes
            / self.tp
    }

    /// FLOPs per generated token (dense decode, 2*params approximation
    /// plus attention over `ctx` cached tokens).
    pub fn flops_per_token(&self, ctx: usize) -> f64 {
        let dense = 2.0 * self.param_count() as f64;
        let attn = 4.0
            * self.n_layers as f64
            * self.n_q_heads as f64
            * self.d_head as f64
            * ctx as f64;
        dense + attn
    }

    /// HBM bytes read per generated token (weights once + KV over ctx).
    pub fn hbm_bytes_per_token(&self, ctx: usize, batch: usize) -> f64 {
        // Weights are read once per step regardless of batch; KV is per
        // sequence.
        self.weight_bytes_per_gpu() as f64 / batch as f64
            + self.kv_bytes_per_token_per_gpu() as f64 * ctx as f64
    }
}

/// Qwen-7B, Qwen-32B, LLaMA-8B, LLaMA-70B — the paper's four models.
pub const DEEPSEEK_R1_DISTILL: [ArchSpec; 4] = [
    ArchSpec {
        name: "DeepSeek-R1-Distill-Qwen-7B",
        n_layers: 28,
        d_model: 3584,
        n_q_heads: 28,
        n_kv_heads: 4,
        d_head: 128,
        d_ff: 18944,
        vocab_size: 152064,
        weight_bytes: 2,
        kv_bytes: 2,
        tp: 1,
    },
    ArchSpec {
        name: "DeepSeek-R1-Distill-Qwen-32B",
        n_layers: 64,
        d_model: 5120,
        n_q_heads: 40,
        n_kv_heads: 8,
        d_head: 128,
        d_ff: 27648,
        vocab_size: 152064,
        weight_bytes: 2,
        kv_bytes: 2,
        tp: 1,
    },
    ArchSpec {
        name: "DeepSeek-R1-Distill-Llama-8B",
        n_layers: 32,
        d_model: 4096,
        n_q_heads: 32,
        n_kv_heads: 8,
        d_head: 128,
        d_ff: 14336,
        vocab_size: 128256,
        weight_bytes: 2,
        kv_bytes: 2,
        tp: 1,
    },
    ArchSpec {
        name: "DeepSeek-R1-Distill-Llama-70B",
        n_layers: 80,
        d_model: 8192,
        n_q_heads: 64,
        n_kv_heads: 8,
        d_head: 128,
        d_ff: 28672,
        vocab_size: 128256,
        weight_bytes: 2,
        kv_bytes: 2,
        tp: 3, // paper: 3-way model parallelism for the 70B
    },
];

pub fn arch_by_name(name: &str) -> Option<&'static ArchSpec> {
    DEEPSEEK_R1_DISTILL.iter().find(|a| a.name.contains(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_are_plausible() {
        // Within 15% of the nominal 7B/32B/8B/70B.
        let nominal = [7.6e9, 32.8e9, 8.0e9, 70.6e9];
        for (a, n) in DEEPSEEK_R1_DISTILL.iter().zip(nominal) {
            let p = a.param_count() as f64;
            assert!(
                (p / n - 1.0).abs() < 0.15,
                "{}: {p:.2e} vs nominal {n:.2e}",
                a.name
            );
        }
    }

    #[test]
    fn kv_bytes_match_hand_calc() {
        // LLaMA-8B: 32 layers * 2 * 8 heads * 128 dim * 2 bytes = 131072.
        let a = arch_by_name("Llama-8B").unwrap();
        assert_eq!(a.kv_bytes_per_token_per_gpu(), 131072);
    }

    #[test]
    fn gqa_reduces_kv_vs_mha() {
        let a = arch_by_name("Qwen-7B").unwrap();
        let mha = a.n_layers * 2 * a.n_q_heads * a.d_head * a.kv_bytes;
        assert!(a.kv_bytes_per_token_per_gpu() * 7 == mha,
                "Qwen-7B GQA ratio is 7x");
    }

    #[test]
    fn flops_grow_with_context() {
        let a = arch_by_name("Llama-70B").unwrap();
        assert!(a.flops_per_token(10_000) > a.flops_per_token(100));
    }
}
