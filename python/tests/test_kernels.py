"""L1 correctness: Pallas kernels vs the pure-jnp oracles in ref.py.

hypothesis sweeps shapes/dtypes; assert_allclose against ref — this is the
core correctness signal for everything the rust engine executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.decode_attention import decode_attention, vmem_bytes
from compile.kernels.prefill_attention import prefill_attention
from compile.kernels.ref import decode_attention_ref, prefill_attention_ref

SETTINGS = dict(max_examples=12, deadline=None)


def rand(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


@settings(**SETTINGS)
@given(
    b=st.sampled_from([1, 2, 4]),
    hq=st.sampled_from([2, 4, 8]),
    group=st.sampled_from([1, 2, 4]),
    c=st.sampled_from([64, 128, 256]),
    d=st.sampled_from([16, 32]),
    data=st.data(),
)
def test_decode_attention_matches_ref(b, hq, group, c, d, data):
    if hq % group:
        group = 1
    hkv = hq // group
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    q = rand(rng, (b, hq, d), jnp.float32)
    k = rand(rng, (b, hkv, c, d), jnp.float32)
    v = rand(rng, (b, hkv, c, d), jnp.float32)
    lens = jnp.asarray(rng.integers(0, c + 1, size=(b,)), jnp.int32)
    o, p = decode_attention(q, k, v, lens)
    o_ref, p_ref = decode_attention_ref(q, k, v, lens, 1.0 / d**0.5)
    np.testing.assert_allclose(o, o_ref, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(p, p_ref, atol=2e-6)


@settings(**SETTINGS)
@given(
    b=st.sampled_from([1, 2]),
    hq=st.sampled_from([2, 4]),
    group=st.sampled_from([1, 2]),
    t=st.sampled_from([32, 64, 128]),
    d=st.sampled_from([16, 32]),
    data=st.data(),
)
def test_prefill_attention_matches_ref(b, hq, group, t, d, data):
    if hq % group:
        group = 1
    hkv = hq // group
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    q = rand(rng, (b, hq, t, d), jnp.float32)
    k = rand(rng, (b, hkv, t, d), jnp.float32)
    v = rand(rng, (b, hkv, t, d), jnp.float32)
    o, p = prefill_attention(q, k, v)
    o_ref, p_ref = prefill_attention_ref(q, k, v, 1.0 / d**0.5)
    np.testing.assert_allclose(o, o_ref, atol=3e-5, rtol=3e-5)
    np.testing.assert_allclose(p, p_ref, atol=2e-6)


def test_decode_probs_are_a_distribution():
    rng = np.random.default_rng(0)
    b, hq, hkv, c, d = 2, 4, 2, 128, 32
    q = rand(rng, (b, hq, d), jnp.float32)
    k = rand(rng, (b, hkv, c, d), jnp.float32)
    v = rand(rng, (b, hkv, c, d), jnp.float32)
    lens = jnp.asarray([60, 128], jnp.int32)
    _, p = decode_attention(q, k, v, lens)
    p = np.asarray(p)
    # Sum to 1 over valid slots; exactly 0 beyond lens.
    np.testing.assert_allclose(p.sum(-1), 1.0, atol=1e-5)
    assert np.all(p[0, :, 60:] == 0.0)
    assert np.all(p >= 0.0)


def test_decode_zero_len_is_safe():
    rng = np.random.default_rng(1)
    q = rand(rng, (1, 2, 16), jnp.float32)
    k = rand(rng, (1, 2, 64, 16), jnp.float32)
    v = rand(rng, (1, 2, 64, 16), jnp.float32)
    lens = jnp.asarray([0], jnp.int32)
    o, p = decode_attention(q, k, v, lens)
    assert np.all(np.isfinite(np.asarray(o)))
    assert np.all(np.asarray(p) == 0.0)


def test_decode_bf16_storage_path():
    """bf16 K/V storage with f32 scores — the quantized-cache variant."""
    rng = np.random.default_rng(2)
    b, hq, hkv, c, d = 1, 4, 2, 128, 32
    q = rand(rng, (b, hq, d), jnp.bfloat16)
    k = rand(rng, (b, hkv, c, d), jnp.bfloat16)
    v = rand(rng, (b, hkv, c, d), jnp.bfloat16)
    lens = jnp.asarray([100], jnp.int32)
    o, p = decode_attention(q, k, v, lens)
    assert o.dtype == jnp.bfloat16
    o_ref, _ = decode_attention_ref(
        q.astype(jnp.float32), k.astype(jnp.float32),
        v.astype(jnp.float32), lens, 1.0 / d**0.5)
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(o_ref), atol=3e-2, rtol=3e-2)


def test_block_size_invariance():
    """The HBM->VMEM tile size must not change the numerics."""
    rng = np.random.default_rng(3)
    b, hq, hkv, c, d = 1, 2, 1, 256, 32
    q = rand(rng, (b, hq, d), jnp.float32)
    k = rand(rng, (b, hkv, c, d), jnp.float32)
    v = rand(rng, (b, hkv, c, d), jnp.float32)
    lens = jnp.asarray([200], jnp.int32)
    o64, p64 = decode_attention(q, k, v, lens, block_k=64)
    o256, p256 = decode_attention(q, k, v, lens, block_k=256)
    np.testing.assert_allclose(o64, o256, atol=1e-6)
    np.testing.assert_allclose(p64, p256, atol=1e-7)


def test_vmem_estimate_within_tpu_budget():
    """Structural check (interpret=True gives no TPU timing): the decode
    block must fit VMEM (~16 MiB/core) with generous margin."""
    assert vmem_bytes(c=2048, d=32, block_k=128) < 4 * 2**20
    assert vmem_bytes(c=512, d=128, block_k=128) < 4 * 2**20


@pytest.mark.parametrize("c,block_k", [(128, 128), (256, 64), (512, 128)])
def test_decode_various_buckets(c, block_k):
    rng = np.random.default_rng(c)
    b, hq, hkv, d = 2, 4, 2, 32
    q = rand(rng, (b, hq, d), jnp.float32)
    k = rand(rng, (b, hkv, c, d), jnp.float32)
    v = rand(rng, (b, hkv, c, d), jnp.float32)
    lens = jnp.asarray([c // 3, c], jnp.int32)
    o, p = decode_attention(q, k, v, lens, block_k=block_k)
    o_ref, p_ref = decode_attention_ref(q, k, v, lens, 1.0 / d**0.5)
    np.testing.assert_allclose(o, o_ref, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(p, p_ref, atol=2e-6)
