//! Backend equivalence property: the same PRNG interleaving of inserts,
//! retentions, prefill loads, slot swaps, slot resets and score updates
//! applied to a DenseF32-backed [`GroupCache`] and to each quantized /
//! mixed variant (uniform q8, uniform q4, and a per-layer map with a
//! dense f32 layer and a q4 layer in one group) must keep the caches in
//! lockstep:
//!
//!   * identical per-(layer, slot) `len`, `pos`, `scores` and
//!     epoch/rewrite bookkeeping (the delta-pack protocol lives above
//!     the backend, so it must not be able to tell backends apart),
//!   * identical [`PackStats`] pair classification on every reconcile,
//!   * the packed output within the owning **layer format's** error
//!     bound of the dense packed output on every *live* row — exact for
//!     f32 layers, per-row symmetric int8 bound for q8 layers, per-group
//!     zero-widened int4 bound for q4 layers (dense rows are exact, so
//!     they double as the reference), and
//!   * `f32_equivalent_bytes` of every variant equal to the dense
//!     cache's actual `live_bytes` (Table 2's comparability invariant),
//!     with the variant's own `live_bytes` never exceeding it.

use lethe::kvcache::{
    CacheDims, FormatMap, GroupCache, KvFormat, PackScratch, PackStats,
};
use lethe::runtime::tensors::HostTensorF32;
use lethe::util::proptest::{check, vec_f32};

const LAYERS: usize = 2;
const BATCH: usize = 3;
const HKV: usize = 2;
const CAP: usize = 24;
const D: usize = 4;

fn dims() -> CacheDims {
    CacheDims {
        layers: LAYERS,
        batch: BATCH,
        kv_heads: HKV,
        capacity: CAP,
        d_head: D,
    }
}

/// The variants run against the dense reference.
fn variants() -> Vec<(&'static str, FormatMap)> {
    vec![
        ("q8", FormatMap::uniform(LAYERS, KvFormat::QuantI8)),
        ("q4", FormatMap::uniform(LAYERS, KvFormat::QuantI4)),
        (
            "mixed",
            FormatMap::new(vec![KvFormat::F32, KvFormat::QuantI4]),
        ),
    ]
}

/// Worst-case absolute dequantization error for a row stored in `fmt`
/// whose exact values are `exact`. The bound itself is the shared
/// [`lethe::kvcache::quant::dequant_error_bound`] contract; f32 layers
/// get no fuzz (dense packed rows must match bit-for-bit), quantized
/// layers get float fuzz on top.
fn format_tol(fmt: KvFormat, exact: &[f32]) -> f32 {
    match fmt {
        KvFormat::F32 => 0.0,
        _ => lethe::kvcache::quant::dequant_error_bound(fmt, exact) + 1e-6,
    }
}

/// Bookkeeping that must be bit-identical across backends.
fn check_lockstep(dense: &GroupCache, quant: &GroupCache) -> Result<(), String> {
    for l in 0..LAYERS {
        for b in 0..BATCH {
            if dense.len(l, b) != quant.len(l, b) {
                return Err(format!(
                    "len diverged at ({l},{b}): {} vs {}",
                    dense.len(l, b),
                    quant.len(l, b)
                ));
            }
            if dense.slot_epoch(l, b) != quant.slot_epoch(l, b) {
                return Err(format!(
                    "epoch diverged at ({l},{b}): {:?} vs {:?}",
                    dense.slot_epoch(l, b),
                    quant.slot_epoch(l, b)
                ));
            }
            if dense.pos(l, b) != quant.pos(l, b) {
                return Err(format!("pos diverged at ({l},{b})"));
            }
            if dense.scores(l, b) != quant.scores(l, b) {
                return Err(format!("scores diverged at ({l},{b})"));
            }
        }
    }
    if dense.live_bytes() != quant.f32_equivalent_bytes() {
        return Err(format!(
            "f32-equivalent accounting diverged: {} vs {}",
            dense.live_bytes(),
            quant.f32_equivalent_bytes()
        ));
    }
    // Every variant stores at most as much as dense (strictly less for
    // any quantized layer holding rows; a mixed map's f32 layer prices
    // at the dense rate, so "≤" is the cross-variant invariant).
    if quant.live_bytes() > dense.live_bytes() {
        return Err(format!(
            "quantized storage larger than dense: {} vs {}",
            quant.live_bytes(),
            dense.live_bytes()
        ));
    }
    Ok(())
}

/// Reconcile both scratches and compare: identical pair classification,
/// identical lens, and the per-layer format's dequantization bound on
/// every live row.
fn check_packed(
    dense: &GroupCache,
    quant: &GroupCache,
    ds: &mut PackScratch,
    qs: &mut PackScratch,
) -> Result<(), String> {
    let dstats: PackStats = dense.pack_delta(ds).map_err(|e| e.to_string())?;
    let qstats: PackStats = quant.pack_delta(qs).map_err(|e| e.to_string())?;
    let d3 = (dstats.pairs_full, dstats.pairs_delta, dstats.pairs_skipped);
    let q3 = (qstats.pairs_full, qstats.pairs_delta, qstats.pairs_skipped);
    if d3 != q3 {
        return Err(format!("pack stats diverged: {d3:?} vs {q3:?}"));
    }
    if ds.lens.data != qs.lens.data {
        return Err(format!(
            "packed lens diverged: {:?} vs {:?}",
            ds.lens.data, qs.lens.data
        ));
    }
    let (bb, c) = ds.bucket();
    for l in 0..LAYERS {
        let fmt = quant.format_map().get(l);
        for b in 0..bb {
            let live = dense.len(l, b);
            for h in 0..HKV {
                for r in 0..live {
                    let off = (((l * bb + b) * HKV + h) * c + r) * D;
                    row_close(
                        fmt,
                        &ds.k.data[off..off + D],
                        &qs.k.data[off..off + D],
                    )
                    .map_err(|m| format!("K ({l},{b},{h},{r}): {m}"))?;
                    row_close(
                        fmt,
                        &ds.v.data[off..off + D],
                        &qs.v.data[off..off + D],
                    )
                    .map_err(|m| format!("V ({l},{b},{h},{r}): {m}"))?;
                }
            }
        }
    }
    Ok(())
}

/// The dense row stores the original values exactly, so its range is the
/// range the quantizer saw.
fn row_close(fmt: KvFormat, exact: &[f32], approx: &[f32]) -> Result<(), String> {
    let tol = format_tol(fmt, exact);
    for (a, b) in exact.iter().zip(approx) {
        if (a - b).abs() > tol {
            return Err(format!("{a} vs {b} (tol {tol}, {fmt:?})"));
        }
    }
    Ok(())
}

#[test]
fn quantized_and_mixed_backends_stay_in_lockstep_with_dense() {
    for (name, formats) in variants() {
        check(&format!("backend-equivalence-{name}"), 30, |rng, size| {
            let mut dense = GroupCache::with_format(dims(), KvFormat::F32);
            let mut quant = GroupCache::with_formats(dims(), formats.clone());
            let mut ds = PackScratch::new(&dims(), BATCH, CAP);
            let mut qs = PackScratch::new(&dims(), BATCH, CAP);

            let steps = 4 + size;
            let mut abs = 0i32;
            for step in 0..steps {
                match rng.range(0, 6) {
                    0 | 1 => {
                        // Append one token to a random (layer, slot), same
                        // values into both backends.
                        let l = rng.range(0, LAYERS - 1);
                        let b = rng.range(0, BATCH - 1);
                        if dense.len(l, b) < CAP {
                            let kr = vec_f32(rng, HKV * D, -2.0, 2.0);
                            let vr = vec_f32(rng, HKV * D, -2.0, 2.0);
                            dense
                                .insert(l, b, &kr, &vr, abs)
                                .map_err(|e| e.to_string())?;
                            quant
                                .insert(l, b, &kr, &vr, abs)
                                .map_err(|e| e.to_string())?;
                            abs += 1;
                        }
                    }
                    2 => {
                        // Retention: same keep subset on both.
                        let l = rng.range(0, LAYERS - 1);
                        let b = rng.range(0, BATCH - 1);
                        let n = dense.len(l, b);
                        if n > 0 {
                            let keep: Vec<usize> =
                                (0..n).filter(|_| rng.bool(0.6)).collect();
                            dense
                                .apply_retention(l, b, &keep)
                                .map_err(|e| e.to_string())?;
                            quant
                                .apply_retention(l, b, &keep)
                                .map_err(|e| e.to_string())?;
                        }
                    }
                    3 => {
                        // Prefill-load a random slot from the same tensors.
                        let b = rng.range(0, BATCH - 1);
                        let t = rng.range(1, CAP);
                        let len = rng.range(1, t);
                        let k_all = HostTensorF32::from_vec(
                            &[LAYERS, 1, HKV, t, D],
                            vec_f32(rng, LAYERS * HKV * t * D, -1.0, 1.0),
                        )
                        .map_err(|e| e.to_string())?;
                        let v_all = HostTensorF32::from_vec(
                            &[LAYERS, 1, HKV, t, D],
                            vec_f32(rng, LAYERS * HKV * t * D, -1.0, 1.0),
                        )
                        .map_err(|e| e.to_string())?;
                        dense
                            .load_prefill(b, &k_all, &v_all, len)
                            .map_err(|e| e.to_string())?;
                        quant
                            .load_prefill(b, &k_all, &v_all, len)
                            .map_err(|e| e.to_string())?;
                    }
                    4 => {
                        // Swap two random slots (reap path).
                        let a = rng.range(0, BATCH - 1);
                        let b = rng.range(0, BATCH - 1);
                        dense.swap_slots(a, b);
                        quant.swap_slots(a, b);
                    }
                    5 => {
                        // RASR score update — identical float math both sides.
                        let l = rng.range(0, LAYERS - 1);
                        let b = rng.range(0, BATCH - 1);
                        let n = dense.len(l, b);
                        if n > 0 {
                            let add = vec_f32(rng, n, 0.0, 1.0);
                            dense.accumulate_scores(l, b, 0.9, &add);
                            quant.accumulate_scores(l, b, 0.9, &add);
                        }
                    }
                    _ => {
                        let b = rng.range(0, BATCH - 1);
                        dense.reset_slot(b);
                        quant.reset_slot(b);
                    }
                }

                check_lockstep(&dense, &quant)
                    .map_err(|m| format!("[{name}] step {step}: {m}"))?;
                check_packed(&dense, &quant, &mut ds, &mut qs)
                    .map_err(|m| format!("[{name}] step {step}: {m}"))?;
            }
            Ok(())
        });
    }
}

#[test]
fn live_migration_preserves_bookkeeping_and_delta_pack_identity() {
    // Random op stream on a busy cache, interleaved with random
    // `migrate_layer_format` calls and delta-pack reconciles against a
    // *resident* scratch. After every step:
    //   * lens/pos/scores are untouched by migration, the migrated
    //     layer's epochs are bumped to the rewrite watermark, and other
    //     layers' epochs are untouched,
    //   * the migrated rows match their pre-migration f32 reads within
    //     the NEW format's dequantization bound (the requantizer's
    //     input is exactly the pre-migration read),
    //   * the next pack_delta output is bit-identical to a fresh pack
    //     of the migrated cache — the one backend obligation.
    let all = [KvFormat::F32, KvFormat::QuantI8, KvFormat::QuantI4];
    check("live-migration", 30, |rng, size| {
        let mut cache = GroupCache::with_format(dims(), KvFormat::F32);
        let mut scratch = PackScratch::new(&dims(), BATCH, CAP);
        let mut abs = 0i32;
        let fresh_pack = |c: &GroupCache| {
            let shape = [LAYERS, BATCH, HKV, CAP, D];
            let mut k = HostTensorF32::zeros(&shape);
            let mut v = HostTensorF32::zeros(&shape);
            let mut lens =
                lethe::runtime::tensors::HostTensorI32::zeros(&[LAYERS, BATCH]);
            c.pack(BATCH, CAP, &mut k, &mut v, &mut lens).unwrap();
            (k, v, lens)
        };
        for step in 0..(4 + size) {
            match rng.range(0, 4) {
                0 | 1 => {
                    let l = rng.range(0, LAYERS - 1);
                    let b = rng.range(0, BATCH - 1);
                    if cache.len(l, b) < CAP {
                        let kr = vec_f32(rng, HKV * D, -2.0, 2.0);
                        let vr = vec_f32(rng, HKV * D, -2.0, 2.0);
                        cache
                            .insert(l, b, &kr, &vr, abs)
                            .map_err(|e| e.to_string())?;
                        abs += 1;
                    }
                }
                2 => {
                    let l = rng.range(0, LAYERS - 1);
                    let b = rng.range(0, BATCH - 1);
                    let n = cache.len(l, b);
                    if n > 0 {
                        let keep: Vec<usize> =
                            (0..n).filter(|_| rng.bool(0.7)).collect();
                        cache
                            .apply_retention(l, b, &keep)
                            .map_err(|e| e.to_string())?;
                    }
                }
                _ => {
                    // Live migration of a random layer to a random
                    // (possibly identical) format.
                    let l = rng.range(0, LAYERS - 1);
                    let fmt = all[rng.range(0, all.len() - 1)];
                    let was = cache.format_map().get(l);
                    let (pre_k, pre_v, _) = fresh_pack(&cache);
                    let lens_before: Vec<usize> =
                        (0..BATCH).map(|b| cache.len(l, b)).collect();
                    let pos_before: Vec<Vec<i32>> =
                        (0..BATCH).map(|b| cache.pos(l, b).to_vec()).collect();
                    let epochs_before: Vec<_> = (0..LAYERS)
                        .flat_map(|ll| {
                            (0..BATCH).map(move |b| (ll, b))
                        })
                        .map(|(ll, b)| cache.slot_epoch(ll, b))
                        .collect();
                    let changed = cache
                        .migrate_layer_format(l, fmt)
                        .map_err(|e| e.to_string())?;
                    if changed != (was != fmt) {
                        return Err("migration no-op detection wrong".into());
                    }
                    for b in 0..BATCH {
                        if cache.len(l, b) != lens_before[b]
                            || cache.pos(l, b) != &pos_before[b][..]
                        {
                            return Err(format!(
                                "step {step}: migration disturbed \
                                 lens/pos at ({l},{b})"
                            ));
                        }
                    }
                    for (i, (ll, b)) in (0..LAYERS)
                        .flat_map(|ll| (0..BATCH).map(move |b| (ll, b)))
                        .enumerate()
                    {
                        let e = cache.slot_epoch(ll, b);
                        if ll == l && changed {
                            if e.epoch <= epochs_before[i].epoch
                                || e.rewrite != e.epoch
                            {
                                return Err(format!(
                                    "step {step}: migrated layer not \
                                     marked rewritten at ({ll},{b})"
                                ));
                            }
                        } else if e != epochs_before[i] {
                            return Err(format!(
                                "step {step}: unmigrated pair ({ll},{b}) \
                                 epoch moved"
                            ));
                        }
                    }
                    // Value accuracy: live rows within the NEW format's
                    // bound of their pre-migration reads.
                    if changed {
                        let (post_k, post_v, _) = fresh_pack(&cache);
                        for b in 0..BATCH {
                            for h in 0..HKV {
                                for r in 0..lens_before[b] {
                                    let off = (((l * BATCH + b) * HKV + h)
                                        * CAP
                                        + r)
                                        * D;
                                    for (t, (pk, po)) in [
                                        (&pre_k, &post_k),
                                        (&pre_v, &post_v),
                                    ]
                                    .iter()
                                    .enumerate()
                                    {
                                        let exact = &pk.data[off..off + D];
                                        let got = &po.data[off..off + D];
                                        let tol = format_tol(fmt, exact);
                                        for (a, g) in exact.iter().zip(got) {
                                            if (a - g).abs() > tol {
                                                return Err(format!(
                                                    "step {step}: tensor {t} \
                                                     row ({l},{b},{h},{r}) \
                                                     moved {a} -> {g} \
                                                     (tol {tol})"
                                                ));
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
            // Delta-maintained scratch must stay bit-identical to a
            // fresh pack of the (possibly just-migrated) cache.
            cache.pack_delta(&mut scratch).map_err(|e| e.to_string())?;
            let (k, v, lens) = fresh_pack(&cache);
            if k.data != scratch.k.data
                || v.data != scratch.v.data
                || lens.data != scratch.lens.data
            {
                return Err(format!(
                    "step {step}: scratch diverged from fresh pack after \
                     migration"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn mixed_map_stores_strictly_less_once_the_quant_layer_fills() {
    // The mixed variant's "≤ dense" invariant becomes strict as soon as
    // its quantized layer holds rows — the f32 layer alone must price
    // identically to dense.
    let mut mixed = GroupCache::with_formats(
        dims(),
        FormatMap::new(vec![KvFormat::F32, KvFormat::QuantI4]),
    );
    let mut dense = GroupCache::with_format(dims(), KvFormat::F32);
    let row = vec![0.5f32; HKV * D];
    mixed.insert(0, 0, &row, &row, 0).unwrap();
    dense.insert(0, 0, &row, &row, 0).unwrap();
    assert_eq!(mixed.live_bytes(), dense.live_bytes());
    mixed.insert(1, 0, &row, &row, 0).unwrap();
    dense.insert(1, 0, &row, &row, 0).unwrap();
    assert!(mixed.live_bytes() < dense.live_bytes());
    assert_eq!(mixed.f32_equivalent_bytes(), dense.live_bytes());
}

#[test]
fn quant_scratch_residency_survives_cache_swap_between_groups() {
    // Same engine scenario as the dense variant in delta_pack_prop.rs:
    // one scratch alternating between two quantized caches must force a
    // cold re-sync on every owner change (unique cache ids), and the
    // delta-maintained image must stay bit-identical to a fresh pack.
    // Runs on every quantized/mixed variant.
    for (name, formats) in variants() {
        let mut a = GroupCache::with_formats(dims(), formats.clone());
        let mut b = GroupCache::with_formats(dims(), formats);
        let row_a = vec![1.0f32; HKV * D];
        let row_b = vec![2.0f32; HKV * D];
        for l in 0..LAYERS {
            a.insert(l, 0, &row_a, &row_a, 0).unwrap();
            b.insert(l, 0, &row_b, &row_b, 0).unwrap();
            b.insert(l, 0, &row_b, &row_b, 1).unwrap();
        }
        let mut scratch = PackScratch::new(&dims(), 2, 16);
        for _ in 0..3 {
            for cache in [&a, &b] {
                let st = cache.pack_delta(&mut scratch).unwrap();
                assert_eq!(st.pairs_full, LAYERS * 2,
                           "[{name}] owner change must cold-sync every pair");
                // Reference: fresh pack at the same bucket.
                let shape = [LAYERS, 2, HKV, 16, D];
                let mut k = HostTensorF32::zeros(&shape);
                let mut v = HostTensorF32::zeros(&shape);
                let mut lens =
                    lethe::runtime::tensors::HostTensorI32::zeros(&[LAYERS, 2]);
                cache.pack(2, 16, &mut k, &mut v, &mut lens).unwrap();
                assert_eq!(k.data, scratch.k.data, "[{name}]");
                assert_eq!(v.data, scratch.v.data, "[{name}]");
                assert_eq!(lens.data, scratch.lens.data, "[{name}]");
            }
        }
    }
}
