//! Compare all five eviction policies on one reasoning subject: accuracy,
//! peak KV, throughput, prune rounds — a quick interactive version of the
//! Table 1 / Table 3 story.
//!
//!   cargo run --release --example policy_compare [-- <subject> [n]]
//!   subjects: recall-8|recall-16|recall-24|hop2-8|hop2-16|hop3-8|
//!             hop3-16|hop4-16

use lethe::bench_support::{print_table, run_tasks, try_engine};
use lethe::config::ServingConfig;
use lethe::policy::PolicyKind;
use lethe::util::prng::Rng;
use lethe::workload::subject_batch;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let subject = args.first().map(|s| s.as_str()).unwrap_or("hop3-16");
    let n: usize = args.get(1).and_then(|v| v.parse().ok()).unwrap_or(16);

    let mut cfg = ServingConfig::default();
    cfg.baseline.budget = 48;
    cfg.lethe.evict_threshold = 48;
    let Some((mut engine, tok)) = try_engine(cfg) else { return Ok(()) };

    let tasks = subject_batch(&mut Rng::new(0xC0DE), subject, n);
    let mut rows = Vec::new();
    for kind in PolicyKind::ALL {
        engine.metrics.reset();
        let st = run_tasks(&mut engine, &tok, kind, &tasks, 4, 64)?;
        rows.push(vec![
            kind.label().to_string(),
            format!("{:.1}", 100.0 * st.chain_acc),
            format!("{:.1}", 100.0 * st.final_acc),
            format!("{:.0}", st.peak_live_bytes as f64 / 1e3),
            format!("{:.0}", engine.metrics.decode_tput()),
            format!("{}", st.prune_events),
            format!("{}", st.ooms),
        ]);
    }
    print_table(
        &format!("policy comparison — subject {subject}, n={n}"),
        &["policy", "chain%", "final%", "peakKB", "tok/s", "prunes", "ooms"],
        &rows,
    );
    println!(
        "\nexpected shape (paper Table 1): Lethe tracks FullKV; \
         StreamingLLM/H2O lose the chain on multihop subjects; \
         PyramidKV's static pyramid misallocates."
    );
    Ok(())
}
