//! Parses `artifacts/model_meta.json` — the wire contract emitted by
//! `python/compile/aot.py`: model dims, tokenizer vocab, weight layout,
//! and the manifest of compiled HLO executables with their bucket shapes.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::{parse, Json};

#[derive(Clone, Debug)]
pub struct ModelDims {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_q_heads: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub param_count: usize,
    pub weights_source: String,
}

#[derive(Clone, Debug)]
pub struct WeightSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub bytes: usize,
}

#[derive(Clone, Debug)]
pub struct ExecutableSpec {
    pub name: String,
    pub file: String,
    /// (shape, dtype) per parameter, in lowered order (weights first).
    pub params: Vec<(Vec<usize>, String)>,
    pub outputs: Vec<String>,
}

#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub dir: PathBuf,
    pub dims: ModelDims,
    pub specials: Vec<String>,
    pub chars: String,
    pub weights: Vec<WeightSpec>,
    pub executables: BTreeMap<String, ExecutableSpec>,
    pub cache_profiles: BTreeMap<String, usize>,
    /// Per profile: compiled decode cache-capacity buckets (ascending).
    pub decode_capacities: BTreeMap<String, Vec<usize>>,
    pub decode_batches: BTreeMap<String, Vec<usize>>,
    pub prefill_ts: Vec<usize>,
}

fn usize_arr(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()?.iter().map(|x| x.as_usize()).collect()
}

fn usize_map(j: &Json) -> Result<BTreeMap<String, usize>> {
    j.as_obj()?
        .iter()
        .map(|(k, v)| Ok((k.clone(), v.as_usize()?)))
        .collect()
}

fn usize_arr_map(j: &Json) -> Result<BTreeMap<String, Vec<usize>>> {
    j.as_obj()?
        .iter()
        .map(|(k, v)| Ok((k.clone(), usize_arr(v)?)))
        .collect()
}

impl ModelMeta {
    pub fn load(artifacts_dir: &Path) -> Result<ModelMeta> {
        let path = artifacts_dir.join("model_meta.json");
        let src = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {path:?} — run `make artifacts` first"
            )
        })?;
        let j = parse(&src).context("parsing model_meta.json")?;

        let m = j.get("model")?;
        let dims = ModelDims {
            vocab_size: m.get("vocab_size")?.as_usize()?,
            d_model: m.get("d_model")?.as_usize()?,
            n_layers: m.get("n_layers")?.as_usize()?,
            n_q_heads: m.get("n_q_heads")?.as_usize()?,
            n_kv_heads: m.get("n_kv_heads")?.as_usize()?,
            d_head: m.get("d_head")?.as_usize()?,
            d_ff: m.get("d_ff")?.as_usize()?,
            param_count: m.get("param_count")?.as_usize()?,
            weights_source: m.get("weights_source")?.as_str()?.to_string(),
        };

        let tok = j.get("tokenizer")?;
        let specials = tok
            .get("specials")?
            .as_arr()?
            .iter()
            .map(|s| Ok(s.as_str()?.to_string()))
            .collect::<Result<Vec<_>>>()?;
        let chars = tok.get("chars")?.as_str()?.to_string();

        let weights = j
            .get("weights")?
            .as_arr()?
            .iter()
            .map(|w| {
                Ok(WeightSpec {
                    name: w.get("name")?.as_str()?.to_string(),
                    shape: usize_arr(w.get("shape")?)?,
                    offset: w.get("offset")?.as_usize()?,
                    bytes: w.get("bytes")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let executables = j
            .get("executables")?
            .as_arr()?
            .iter()
            .map(|e| {
                let spec = ExecutableSpec {
                    name: e.get("name")?.as_str()?.to_string(),
                    file: e.get("file")?.as_str()?.to_string(),
                    params: e
                        .get("params")?
                        .as_arr()?
                        .iter()
                        .map(|p| {
                            Ok((
                                usize_arr(p.get("shape")?)?,
                                p.get("dtype")?.as_str()?.to_string(),
                            ))
                        })
                        .collect::<Result<Vec<_>>>()?,
                    outputs: e
                        .get("outputs")?
                        .as_arr()?
                        .iter()
                        .map(|o| Ok(o.as_str()?.to_string()))
                        .collect::<Result<Vec<_>>>()?,
                };
                Ok((spec.name.clone(), spec))
            })
            .collect::<Result<BTreeMap<_, _>>>()?;

        Ok(ModelMeta {
            dir: artifacts_dir.to_path_buf(),
            dims,
            specials,
            chars,
            weights,
            executables,
            cache_profiles: usize_map(j.get("cache_profiles")?)?,
            decode_capacities: usize_arr_map(j.get("decode_capacities")?)?,
            decode_batches: usize_arr_map(j.get("decode_batches")?)?,
            prefill_ts: usize_arr(j.get("prefill_ts")?)?,
        })
    }

    /// Cache capacity C for a profile name.
    pub fn capacity(&self, profile: &str) -> Result<usize> {
        self.cache_profiles
            .get(profile)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unknown cache profile '{profile}'"))
    }

    /// KV bytes per cached token per sequence (all layers, K+V, f32).
    pub fn kv_bytes_per_token(&self) -> usize {
        self.dims.n_layers * 2 * self.dims.n_kv_heads * self.dims.d_head * 4
    }

    /// Token id of a named special (its position in the manifest's
    /// `tokenizer.specials` list), e.g. `special_id("<eos>")`.
    pub fn special_id(&self, name: &str) -> Option<i32> {
        self.specials.iter().position(|s| s == name).map(|i| i as i32)
    }

    /// EOS token id from the manifest (None when the vocabulary carries
    /// no `"<eos>"` special — callers decide their fallback).
    pub fn eos_id(&self) -> Option<i32> {
        self.special_id("<eos>")
    }

    /// Derive the sharded deployment manifest from the flat weight
    /// layout. `aot.py` stacks every per-layer tensor on axis 0 (shape
    /// `[n_layers, ...]`), so the partition rule is structural: the
    /// embedding table forms the `embed` shard, each stacked tensor
    /// contributes `bytes / n_layers` to every `layer` shard, and the
    /// unstacked tail (`ln_f`, `lm_head`) forms the `lm_head` shard.
    pub fn shard_manifest(&self) -> ShardManifest {
        let l = self.dims.n_layers.max(1);
        let mut shards = Vec::with_capacity(l + 2);
        let mut embed = Vec::new();
        let mut layer = Vec::new();
        let mut head = Vec::new();
        for w in &self.weights {
            if w.name == "embed" {
                embed.push(w);
            } else if w.shape.first() == Some(&self.dims.n_layers) {
                layer.push(w);
            } else {
                head.push(w);
            }
        }
        shards.push(ShardSpec::new("embed", ShardKind::Embed, &embed, None));
        for i in 0..l {
            shards.push(ShardSpec::layer_slice(i, &layer, l));
        }
        shards.push(ShardSpec::new(
            "lm_head",
            ShardKind::LmHead,
            &head,
            None,
        ));
        ShardManifest {
            model_id: format!(
                "lethe-{}l-d{}", self.dims.n_layers, self.dims.d_model
            ),
            total_layers: self.dims.n_layers,
            shards,
        }
    }
}

/// Role of a shard in the sharded model manifest.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardKind {
    /// Token-embedding table.
    Embed,
    /// One transformer layer's slice of the stacked layer tensors.
    Layer,
    /// Final norm + output projection.
    LmHead,
}

impl ShardKind {
    /// Stable lower-case label used in the manifest JSON.
    pub fn label(&self) -> &'static str {
        match self {
            ShardKind::Embed => "embed",
            ShardKind::Layer => "layer",
            ShardKind::LmHead => "lm_head",
        }
    }
}

/// One shard of the model: a unit a future multi-process deployment
/// loads independently (`id/kind/bytes/hash/layer_range`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    /// Stable shard id (`embed`, `layer_3`, `lm_head`).
    pub id: String,
    /// Shard role.
    pub kind: ShardKind,
    /// Bytes of weight data attributed to this shard.
    pub bytes: usize,
    /// Content fingerprint over the contributing weight specs
    /// (`fnv1a:<16 hex>`). A layout hash, not a payload hash: it pins
    /// names/shapes/offsets/sizes so mismatched shards are rejected
    /// before any weight bytes move.
    pub hash: String,
    /// Half-open `[start, end)` layer range for `layer` shards.
    pub layer_range: Option<(usize, usize)>,
}

/// 64-bit FNV-1a over a byte stream; the manifest fingerprint.
fn fnv1a(acc: u64, data: &[u8]) -> u64 {
    let mut h = acc;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

fn hash_specs(specs: &[&WeightSpec], salt: usize) -> String {
    let mut h = fnv1a(FNV_OFFSET, &salt.to_le_bytes());
    for w in specs {
        h = fnv1a(h, w.name.as_bytes());
        for d in &w.shape {
            h = fnv1a(h, &d.to_le_bytes());
        }
        h = fnv1a(h, &w.offset.to_le_bytes());
        h = fnv1a(h, &w.bytes.to_le_bytes());
    }
    format!("fnv1a:{h:016x}")
}

impl ShardSpec {
    fn new(
        id: &str,
        kind: ShardKind,
        specs: &[&WeightSpec],
        layer_range: Option<(usize, usize)>,
    ) -> ShardSpec {
        ShardSpec {
            id: id.to_string(),
            kind,
            bytes: specs.iter().map(|w| w.bytes).sum(),
            hash: hash_specs(specs, usize::MAX),
            layer_range,
        }
    }

    /// The per-layer shard: layer `i`'s axis-0 slice of every stacked
    /// tensor (each contributes `bytes / total` — tensors are stacked
    /// uniformly, so the slice size is exact).
    fn layer_slice(i: usize, stacked: &[&WeightSpec], total: usize) -> ShardSpec {
        ShardSpec {
            id: format!("layer_{i}"),
            kind: ShardKind::Layer,
            bytes: stacked.iter().map(|w| w.bytes / total).sum(),
            hash: hash_specs(stacked, i),
            layer_range: Some((i, i + 1)),
        }
    }

    /// Manifest-row JSON (`id/kind/bytes/hash/layer_range`).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("id", Json::str(&self.id)),
            ("kind", Json::str(self.kind.label())),
            ("bytes", Json::num(self.bytes as f64)),
            ("hash", Json::str(&self.hash)),
        ];
        if let Some((s, e)) = self.layer_range {
            fields.push((
                "layer_range",
                Json::Arr(vec![Json::num(s as f64), Json::num(e as f64)]),
            ));
        }
        Json::obj(fields)
    }
}

/// The sharded model manifest: what each worker (or, later, each
/// process) needs to load exactly its slice of the model.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardManifest {
    /// Deployment-stable model identifier derived from the dims.
    pub model_id: String,
    /// Total transformer layers across the `layer` shards.
    pub total_layers: usize,
    /// Shards in load order: embed, layer_0..layer_{L-1}, lm_head.
    pub shards: Vec<ShardSpec>,
}

impl ShardManifest {
    /// Total bytes across all shards.
    pub fn total_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.bytes).sum()
    }

    /// Order-sensitive digest over the shard hashes. Every worker
    /// reports its manifest fingerprint at boot; the supervisor rejects
    /// a worker whose layout disagrees with the probe's (a torn or
    /// mismatched artifact directory).
    pub fn fingerprint(&self) -> String {
        let mut h = fnv1a(FNV_OFFSET, self.model_id.as_bytes());
        h = fnv1a(h, &self.total_layers.to_le_bytes());
        for s in &self.shards {
            h = fnv1a(h, s.hash.as_bytes());
        }
        format!("fnv1a:{h:016x}")
    }

    /// Full manifest JSON (stats endpoint / future deployment tooling).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model_id", Json::str(&self.model_id)),
            ("total_layers", Json::num(self.total_layers as f64)),
            ("total_bytes", Json::num(self.total_bytes() as f64)),
            (
                "shards",
                Json::Arr(self.shards.iter().map(|s| s.to_json()).collect()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic meta mirroring aot.py's layout: embed, stacked layer
    /// tensors (axis-0 length L), then ln_f / lm_head.
    fn synthetic_meta(n_layers: usize) -> ModelMeta {
        let d = 8usize;
        let vocab = 16usize;
        let mut weights = Vec::new();
        let mut off = 0usize;
        let mut push = |name: &str, shape: Vec<usize>| {
            let bytes = shape.iter().product::<usize>() * 4;
            weights.push(WeightSpec {
                name: name.to_string(),
                shape,
                offset: off,
                bytes,
            });
            off += bytes;
        };
        push("embed", vec![vocab, d]);
        push("ln1", vec![n_layers, d]);
        push("wq", vec![n_layers, d, d]);
        push("ln_f", vec![d]);
        push("lm_head", vec![d, vocab]);
        ModelMeta {
            dir: PathBuf::from("unused"),
            dims: ModelDims {
                vocab_size: vocab,
                d_model: d,
                n_layers,
                n_q_heads: 2,
                n_kv_heads: 1,
                d_head: 4,
                d_ff: 16,
                param_count: 0,
                weights_source: "synthetic".to_string(),
            },
            specials: vec![],
            chars: String::new(),
            weights,
            executables: BTreeMap::new(),
            cache_profiles: BTreeMap::new(),
            decode_capacities: BTreeMap::new(),
            decode_batches: BTreeMap::new(),
            prefill_ts: vec![],
        }
    }

    #[test]
    fn shard_manifest_partitions_embed_layers_and_head() {
        let meta = synthetic_meta(4);
        let m = meta.shard_manifest();
        assert_eq!(m.total_layers, 4);
        assert_eq!(m.shards.len(), 1 + 4 + 1);
        assert_eq!(m.shards[0].id, "embed");
        assert_eq!(m.shards[0].kind, ShardKind::Embed);
        assert_eq!(m.shards[0].bytes, 16 * 8 * 4);
        assert_eq!(m.shards[0].layer_range, None);
        for (i, s) in m.shards[1..5].iter().enumerate() {
            assert_eq!(s.id, format!("layer_{i}"));
            assert_eq!(s.kind, ShardKind::Layer);
            // Per-layer slice of ln1 [4,8] + wq [4,8,8], f32.
            assert_eq!(s.bytes, (8 + 8 * 8) * 4);
            assert_eq!(s.layer_range, Some((i, i + 1)));
        }
        let head = &m.shards[5];
        assert_eq!(head.id, "lm_head");
        assert_eq!(head.kind, ShardKind::LmHead);
        assert_eq!(head.bytes, (8 + 8 * 16) * 4);
        // No weight byte is lost or double-counted by the partition.
        assert_eq!(
            m.total_bytes(),
            meta.weights.iter().map(|w| w.bytes).sum::<usize>()
        );
    }

    #[test]
    fn shard_hashes_are_deterministic_and_distinct() {
        let a = synthetic_meta(4).shard_manifest();
        let b = synthetic_meta(4).shard_manifest();
        assert_eq!(a, b, "same layout => identical manifest");
        for s in &a.shards {
            assert!(s.hash.starts_with("fnv1a:") && s.hash.len() == 22,
                    "bad hash {}", s.hash);
        }
        // Each layer slice hashes distinctly (salted by layer index),
        // and a different layout changes every layer hash.
        assert_ne!(a.shards[1].hash, a.shards[2].hash);
        let c = synthetic_meta(5).shard_manifest();
        assert_ne!(a.shards[1].hash, c.shards[1].hash);
        assert_ne!(a.model_id, c.model_id);
        // The whole-manifest fingerprint follows the same rules.
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert_ne!(a.fingerprint(), c.fingerprint());
        assert!(a.fingerprint().starts_with("fnv1a:"));
    }

    #[test]
    fn shard_manifest_json_shape() {
        let m = synthetic_meta(2).shard_manifest();
        let j = m.to_json();
        assert_eq!(j.get("model_id").unwrap().as_str().unwrap(), "lethe-2l-d8");
        assert_eq!(j.get("total_layers").unwrap().as_usize().unwrap(), 2);
        let shards = j.get("shards").unwrap().as_arr().unwrap();
        assert_eq!(shards.len(), 4);
        assert_eq!(shards[1].get("kind").unwrap().as_str().unwrap(), "layer");
        let r = shards[1].get("layer_range").unwrap().as_arr().unwrap();
        assert_eq!(r[0].as_usize().unwrap(), 0);
        assert_eq!(r[1].as_usize().unwrap(), 1);
        assert!(shards[0].opt("layer_range").is_none());
    }

    /// Integration-style: parses the real artifact manifest if present
    /// (`make artifacts`), otherwise skipped.
    #[test]
    fn loads_real_manifest_when_present() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("model_meta.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let meta = ModelMeta::load(&dir).unwrap();
        assert!(meta.dims.n_layers >= 1);
        assert_eq!(
            meta.dims.vocab_size,
            meta.specials.len() + meta.chars.chars().count()
        );
        assert!(meta.kv_bytes_per_token() > 0);
        for spec in meta.executables.values() {
            assert!(dir.join(&spec.file).exists(), "missing {}", spec.file);
        }
    }
}
