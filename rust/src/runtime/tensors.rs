//! Minimal host tensors (row-major, owned Vec) used on the boundary
//! between the rust coordinator and PJRT. Deliberately tiny: the engine
//! only needs shaped f32/i32 carriers with upload/download helpers.

use anyhow::{ensure, Result};
use xla::{ElementType, Literal, PjRtBuffer, PjRtClient};

#[derive(Clone, Debug, PartialEq)]
pub struct HostTensorF32 {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

#[derive(Clone, Debug, PartialEq)]
pub struct HostTensorI32 {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl HostTensorF32 {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        HostTensorF32 { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        ensure!(
            shape.iter().product::<usize>() == data.len(),
            "shape {shape:?} != {} elements",
            data.len()
        );
        Ok(HostTensorF32 { shape: shape.to_vec(), data })
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Payload size in bytes (f32 = 4 bytes/element). Used by the
    /// delta-pack telemetry to report resident-scratch footprints.
    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }

    pub fn upload(&self, client: &PjRtClient) -> Result<PjRtBuffer> {
        Ok(client.buffer_from_host_buffer(&self.data, &self.shape, None)?)
    }

    pub fn from_literal(lit: &Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        Self::from_vec(&dims, data)
    }
}

impl HostTensorI32 {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        HostTensorI32 { shape: shape.to_vec(), data: vec![0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> Result<Self> {
        ensure!(
            shape.iter().product::<usize>() == data.len(),
            "shape {shape:?} != {} elements",
            data.len()
        );
        Ok(HostTensorI32 { shape: shape.to_vec(), data })
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Payload size in bytes (i32 = 4 bytes/element).
    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }

    pub fn upload(&self, client: &PjRtClient) -> Result<PjRtBuffer> {
        Ok(client.buffer_from_host_buffer(&self.data, &self.shape, None)?)
    }

    pub fn from_literal(lit: &Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<i32>()?;
        Self::from_vec(&dims, data)
    }
}

/// Raw byte carrier for packed (quantized) KV uploads. The same buffer
/// serves u8 (q4 nibble-packed codes) and i8 (q8 codes, via
/// [`HostTensorU8::upload_i8`]) operands — the bit pattern is the wire
/// format, the element type is picked at upload time.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensorU8 {
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl HostTensorU8 {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        HostTensorU8 { shape: shape.to_vec(), data: vec![0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<u8>) -> Result<Self> {
        ensure!(
            shape.iter().product::<usize>() == data.len(),
            "shape {shape:?} != {} elements",
            data.len()
        );
        Ok(HostTensorU8 { shape: shape.to_vec(), data })
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Payload size in bytes (1 byte/element) — the wire bytes the packed
    /// upload path actually moves.
    pub fn bytes(&self) -> usize {
        self.data.len()
    }

    /// Upload as a u8 operand (q4 packed codes).
    pub fn upload(&self, client: &PjRtClient) -> Result<PjRtBuffer> {
        Ok(client.buffer_from_host_buffer(&self.data, &self.shape, None)?)
    }

    /// Upload the same bytes as an i8 operand (q8 codes are stored as u8
    /// bit patterns of two's-complement i8).
    pub fn upload_i8(&self, client: &PjRtClient) -> Result<PjRtBuffer> {
        Ok(client.buffer_from_host_buffer(as_i8(&self.data), &self.shape, None)?)
    }
}

/// Reinterpret unsigned bytes as signed. u8 and i8 have identical size
/// and alignment; the two's-complement bit pattern IS the q8 wire format.
pub fn as_i8(bytes: &[u8]) -> &[i8] {
    // SAFETY: same layout, same length, read-only view.
    unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const i8, bytes.len()) }
}

/// Scalar i32 upload helper.
pub fn scalar_i32(client: &PjRtClient, v: i32) -> Result<PjRtBuffer> {
    Ok(client.buffer_from_host_buffer(&[v], &[], None)?)
}

/// Row-major strides for a shape.
pub fn strides(shape: &[usize]) -> Vec<usize> {
    let mut s = vec![1; shape.len()];
    for i in (0..shape.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * shape[i + 1];
    }
    s
}

#[allow(unused)]
fn element_type_size(t: ElementType) -> usize {
    t.element_size_in_bytes()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_row_major() {
        assert_eq!(strides(&[2, 3, 4]), vec![12, 4, 1]);
        assert_eq!(strides(&[5]), vec![1]);
        assert_eq!(strides(&[]), Vec::<usize>::new());
    }

    #[test]
    fn from_vec_validates() {
        assert!(HostTensorF32::from_vec(&[2, 2], vec![0.0; 3]).is_err());
        assert!(HostTensorI32::from_vec(&[2, 2], vec![0; 4]).is_ok());
    }

    #[test]
    fn byte_sizes() {
        assert_eq!(HostTensorF32::zeros(&[2, 3]).bytes(), 24);
        let i = HostTensorI32::zeros(&[4]);
        assert_eq!(i.numel(), 4);
        assert_eq!(i.bytes(), 16);
        let u = HostTensorU8::zeros(&[2, 5]);
        assert_eq!(u.numel(), 10);
        assert_eq!(u.bytes(), 10);
        assert!(HostTensorU8::from_vec(&[3], vec![1, 2]).is_err());
    }

    #[test]
    fn u8_as_i8_reinterprets_bit_patterns() {
        let bytes = [0u8, 1, 127, 128, 255];
        assert_eq!(as_i8(&bytes), &[0i8, 1, 127, -128, -1]);
    }
}
