"""L1 Pallas kernel: blocked causal GQA prefill attention.

Grid cell = (batch, q-head, q-block). Each cell owns a `block_q`-row slab
of queries and streams keys/values through VMEM in `block_k` tiles with an
online (flash-style) softmax; the probability matrix is written as a side
output so the L2 graph can fold it into the RASR initial score vector
(paper Eq. 2 summed over queries) without a second attention pass.

VMEM per cell: block_q*D + 2*block_k*D + block_q*block_k (f32) — see
vmem_bytes(). interpret=True for CPU-PJRT execution (see decode_attention).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _prefill_kernel(q_ref, k_ref, v_ref, o_ref, p_ref, *,
                    block_q: int, block_k: int, scale: float):
    """Refs: q [1,1,block_q,D], k/v [1,T,D], o [1,1,block_q,D],
    p [1,1,block_q,T]."""
    t = k_ref.shape[1]
    d = q_ref.shape[3]
    qi = pl.program_id(2)
    q = q_ref[0, 0, :, :].astype(jnp.float32)                  # [bq, D]
    row = qi * block_q + jax.lax.iota(jnp.int32, block_q)      # abs q rows
    nblk = t // block_k

    def score_blk(i, m):
        ks = k_ref[0, pl.dslice(i * block_k, block_k), :].astype(jnp.float32)
        s = (q @ ks.T) * scale                                  # [bq, bk]
        col = i * block_k + jax.lax.iota(jnp.int32, block_k)
        s = jnp.where(col[None, :] <= row[:, None], s, NEG_INF)
        p_ref[0, 0, :, pl.dslice(i * block_k, block_k)] = s
        return jnp.maximum(m, jnp.max(s, axis=1))

    m = jax.lax.fori_loop(0, nblk, score_blk,
                          jnp.full((block_q,), NEG_INF, jnp.float32))

    def pv_blk(i, carry):
        acc, denom = carry
        sl = pl.dslice(i * block_k, block_k)
        s = p_ref[0, 0, :, sl]
        col = i * block_k + jax.lax.iota(jnp.int32, block_k)
        e = jnp.where(col[None, :] <= row[:, None],
                      jnp.exp(s - m[:, None]), 0.0)
        p_ref[0, 0, :, sl] = e
        vs = v_ref[0, sl, :].astype(jnp.float32)
        return acc + e @ vs, denom + jnp.sum(e, axis=1)

    acc, denom = jax.lax.fori_loop(
        0, nblk, pv_blk,
        (jnp.zeros((block_q, d), jnp.float32),
         jnp.zeros((block_q,), jnp.float32)))
    inv = 1.0 / jnp.maximum(denom, 1e-30)                      # [bq]
    o_ref[0, 0, :, :] = (acc * inv[:, None]).astype(o_ref.dtype)

    def norm_blk(i, _):
        sl = pl.dslice(i * block_k, block_k)
        p_ref[0, 0, :, sl] = (p_ref[0, 0, :, sl] * inv[:, None]
                              ).astype(p_ref.dtype)
        return 0

    jax.lax.fori_loop(0, nblk, norm_blk, 0)


def prefill_attention(q, k, v, *, scale=None, block_q: int = 64,
                      block_k: int = 64, interpret: bool = True):
    """Pallas causal GQA prefill attention.

    q: [B, Hq, T, D]; k, v: [B, Hkv, T, D].
    returns (out [B, Hq, T, D], probs [B, Hq, T, T] f32)
    """
    b, hq, t, d = q.shape
    _, hkv, _, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    block_q = min(block_q, t)
    block_k = min(block_k, t)
    assert t % block_q == 0 and t % block_k == 0

    kernel = functools.partial(_prefill_kernel, block_q=block_q,
                               block_k=block_k, scale=float(scale))
    return pl.pallas_call(
        kernel,
        grid=(b, hq, t // block_q),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda i, j, l: (i, j, l, 0)),
            pl.BlockSpec((1, None, t, d), lambda i, j, l: (i, j // group, 0, 0)),
            pl.BlockSpec((1, None, t, d), lambda i, j, l: (i, j // group, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda i, j, l: (i, j, l, 0)),
            pl.BlockSpec((1, 1, block_q, t), lambda i, j, l: (i, j, l, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, t, d), q.dtype),
            jax.ShapeDtypeStruct((b, hq, t, t), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def vmem_bytes(t: int, d: int, block_q: int = 64, block_k: int = 64) -> int:
    """Static per-cell VMEM estimate (f32), for the §Perf audit."""
    block_q, block_k = min(block_q, t), min(block_k, t)
    return 4 * (block_q * d + 2 * block_k * d + block_q * t + block_q * d)
