fn main() {}
