//! Supervised multi-group serving core.
//!
//! One supervisor thread owns `serving.groups` decode-group workers.
//! Each worker is a thread that boots its own PJRT [`Runtime`] +
//! [`Engine`] (the engine is not `Sync`) and runs a private
//! [`Scheduler`] loop, so a fault in one group — a panicked tick, a
//! hung runtime call, a burst of tick errors — never touches its
//! peers. The supervisor is the only router: it encodes prompts,
//! places each request on the group with the most KV headroom, and
//! fans completions back to the per-request reply channels.
//!
//! # Health machine
//!
//! ```text
//! Healthy ──ema ≥ degraded──► Degraded ──ema ≥ quarantine──► Quarantined
//!    ▲                           │ ema decays                    │
//!    └────── Booted(ok) ◄── restart (backoff, capped) ◄──────────┘
//!                                                             │ budget
//!                                                             ▼ spent
//!                                                            Dead
//! ```
//!
//! Three signals drive a group into `Quarantined`:
//!
//!   * **Error EMA** — every tick updates an exponential moving
//!     average of the group's tick-error rate; past
//!     `serving.degraded_error_rate` the group is deprioritized for
//!     placement, past `serving.quarantine_error_rate` it is
//!     quarantined.
//!   * **Panic** — a worker catches its own tick panic
//!     (`catch_unwind`), exports what it can for rescue, and reports
//!     [`Event::Panicked`].
//!   * **Stall** — each worker stamps a shared [`Heartbeat`] around
//!     its tick; the supervisor's watchdog quarantines a group whose
//!     tick has overrun `serving.tick_timeout_ms`.
//!
//! # Rescue
//!
//! Quarantining a group invalidates its *lease* (a shared epoch
//! counter) so the worker exits at the next checkpoint, then rescues
//! its in-flight sequences onto healthy groups:
//!
//!   1. sequences the worker exported travel as
//!      [`RescueEntry`] units — active decoders as `HostSlotImage`s
//!      (bit-exact restore), queued/mid-prefill work as recompute
//!      prefixes — and re-enter a healthy peer token-identically
//!      (greedy decode is deterministic);
//!   2. pending requests the worker could *not* export (it was hung or
//!      mid-panic) are shadow-resubmitted from the supervisor's own
//!      copy of the request — same tokens from scratch, still
//!      token-identical;
//!   3. only when no healthy-or-degraded group exists does a sequence
//!      finish with `FinishReason::Error(FailureKind::GroupLost)`.
//!
//! The quarantined group then restarts with exponential backoff
//! (`serving.restart_backoff_ms` doubling per consecutive restart) up
//! to `serving.max_restarts`, after which it is permanently `Dead`.
//! At boot every worker loads the sharded model manifest
//! ([`crate::model::ShardManifest`]) and reports its fingerprint; a
//! worker whose layout disagrees with the supervisor's probe is
//! rejected before serving anything.
//!
//! With the default config (one group, no pool, stall detection off)
//! the behaviour — admission, scheduling, fault semantics, stats —
//! reproduces the previous single-`Scheduler` server exactly.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::ServingConfig;
use crate::engine::{Engine, FinishReason};
use crate::error::{EngineError, FailureKind};
use crate::fault::{FaultPlan, FaultSite};
use crate::metrics::EngineMetrics;
use crate::model::{ModelMeta, Tokenizer};
use crate::policy::PolicyKind;
use crate::runtime::Runtime;
use crate::scheduler::{Completion, Request, RescueEntry, Scheduler};
use crate::server::{GenerateRequest, GenerateResponse};
use crate::util::json::Json;

/// Lifecycle state of one supervised decode group.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GroupHealth {
    /// Serving normally; preferred placement target.
    Healthy,
    /// Tick-error EMA past `serving.degraded_error_rate`: still
    /// serving, deprioritized for placement.
    Degraded,
    /// Fenced off (panic, stall, or sustained errors); sequences
    /// rescued; restart pending.
    Quarantined,
    /// Restart budget exhausted; never restarted again.
    Dead,
}

impl GroupHealth {
    /// Stable lower-case label (stats rows / log lines).
    pub fn label(&self) -> &'static str {
        match self {
            GroupHealth::Healthy => "healthy",
            GroupHealth::Degraded => "degraded",
            GroupHealth::Quarantined => "quarantined",
            GroupHealth::Dead => "dead",
        }
    }
}

/// Classify a tick-error EMA against the configured thresholds.
fn classify(ema: f64, degraded: f64, quarantine: f64) -> GroupHealth {
    if ema >= quarantine {
        GroupHealth::Quarantined
    } else if ema >= degraded {
        GroupHealth::Degraded
    } else {
        GroupHealth::Healthy
    }
}

/// Exponential restart backoff: `base << restarts`, shift-capped so a
/// long-dying group cannot overflow.
fn backoff_ms(base_ms: u64, restarts: u32) -> u64 {
    base_ms.max(1).saturating_mul(1u64 << restarts.min(16))
}

/// Placement: pick the group with the most KV headroom among the
/// healthy ones, falling back to degraded ones; quarantined and dead
/// groups are never targets. `budget` 0 means "unlimited", in which
/// case the groups tie on headroom and the fewest-assigned-requests /
/// lowest-id tiebreaks decide. Candidates: `(health, budget,
/// live_bytes, assigned_requests)` per group, indexed by group id.
fn pick_target(groups: &[(GroupHealth, usize, usize, usize)]) -> Option<usize> {
    for want in [GroupHealth::Healthy, GroupHealth::Degraded] {
        let best = groups
            .iter()
            .enumerate()
            .filter(|(_, (h, ..))| *h == want)
            // max_by_key takes the *last* max; reverse the id so ties
            // land on the lowest group id.
            .max_by_key(|(g, (_, budget, live, assigned))| {
                let headroom = budget.saturating_sub(*live);
                (headroom, usize::MAX - assigned, usize::MAX - g)
            })
            .map(|(g, _)| g);
        if best.is_some() {
            return best;
        }
    }
    None
}

/// Shared per-group heartbeat: the worker stamps it around every tick;
/// the supervisor's watchdog reads it to detect a hung tick without
/// touching the worker thread.
struct Heartbeat {
    /// Time origin; both sides measure against it.
    epoch: Instant,
    /// Milliseconds-since-epoch at the last `enter`.
    ms: AtomicU64,
    /// True while the worker is inside a tick.
    in_tick: AtomicBool,
}

impl Heartbeat {
    fn new() -> Heartbeat {
        Heartbeat {
            epoch: Instant::now(),
            ms: AtomicU64::new(0),
            in_tick: AtomicBool::new(false),
        }
    }

    fn now_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64
    }

    fn enter(&self) {
        self.ms.store(self.now_ms(), Ordering::Release);
        self.in_tick.store(true, Ordering::Release);
    }

    fn exit(&self) {
        self.in_tick.store(false, Ordering::Release);
    }

    /// True when the worker has been inside one tick for longer than
    /// `timeout_ms`.
    fn stalled(&self, timeout_ms: u64) -> bool {
        self.in_tick.load(Ordering::Acquire)
            && self.now_ms().saturating_sub(self.ms.load(Ordering::Acquire))
                > timeout_ms
    }
}

/// Cumulative counters a worker snapshots after every tick; the
/// supervisor applies per-tick *deltas* to its aggregate
/// [`EngineMetrics`], so totals survive group restarts (each fresh
/// engine restarts its own counters from zero).
macro_rules! counters {
    ($($name:ident),* $(,)?) => {
        #[derive(Clone, Copy, Debug, Default)]
        struct CounterSnap {
            $($name: u64,)*
        }

        impl CounterSnap {
            /// Field-wise `self − prev`, saturating (a restarted
            /// engine's counters legitimately go backwards).
            fn delta(self, prev: CounterSnap) -> CounterSnap {
                CounterSnap {
                    $($name: self.$name.saturating_sub(prev.$name),)*
                }
            }

            /// Add this delta into the aggregate metrics.
            fn apply(self, m: &mut EngineMetrics) {
                $(m.$name = m.$name.saturating_add(self.$name);)*
            }
        }
    };
}

counters!(
    decode_steps,
    decode_tokens,
    prefill_tokens,
    prune_events,
    pruned_tokens,
    ooms,
    kv_migrations,
    faults_injected,
    seq_failures,
    rejected,
    preemptions,
    resumes,
    swap_preemptions,
    swap_bytes_out,
    swap_bytes_in,
    deadline_aborts,
    drain_aborts,
);

impl CounterSnap {
    fn capture(sched: &Scheduler, engine: &Engine) -> CounterSnap {
        let m = &engine.metrics;
        CounterSnap {
            decode_steps: m.decode_steps,
            decode_tokens: m.decode_tokens,
            prefill_tokens: m.prefill_tokens,
            prune_events: m.prune_events,
            pruned_tokens: m.pruned_tokens,
            ooms: m.ooms,
            kv_migrations: m.kv_migrations,
            faults_injected: m.faults_injected,
            seq_failures: m.seq_failures,
            rejected: sched.rejected,
            preemptions: sched.preemptions,
            resumes: sched.resumes,
            swap_preemptions: sched.swap_preemptions,
            swap_bytes_out: sched.swap_bytes_out,
            swap_bytes_in: sched.swap_bytes_in,
            deadline_aborts: sched.deadline_aborts,
            drain_aborts: sched.drain_aborts,
        }
    }
}

/// Client-side messages into the supervisor.
enum SupMsg {
    Generate(GenerateRequest, Sender<Result<GenerateResponse>>),
    Stats(Sender<Json>),
    /// Operational control: fence group `g` off and rescue its work
    /// (drain-for-maintenance; also the lifecycle tests' fault lever).
    Quarantine(usize, Sender<bool>),
    Shutdown,
}

/// Per-tick report from a worker.
struct TickUpdate {
    /// This tick returned an error (the scheduler was rebuilt and its
    /// work exported in `rescued`).
    errored: bool,
    completions: Vec<Completion>,
    kv_format: String,
    delta: CounterSnap,
    live_bytes: usize,
    queue_depth: usize,
    active: usize,
    prefilling: usize,
    /// Work exported for rescue by an errored tick.
    rescued: Vec<RescueEntry>,
}

/// Worker → supervisor events. Every event is tagged with the worker's
/// lease epoch; events from a superseded incarnation are dropped.
enum Event {
    /// Boot finished; `Ok` carries the worker's manifest fingerprint.
    Booted(Result<String>),
    Ticked(Box<TickUpdate>),
    /// `Scheduler::submit` rejected request `id` (typed error).
    Rejected { id: u64, err: anyhow::Error },
    /// The tick panicked; the worker exported what it could and exited.
    Panicked {
        rescued: Vec<RescueEntry>,
        completions: Vec<Completion>,
    },
    /// Clean exit after a drain.
    Exited,
}

/// Envelope on the supervisor's single input channel (std `mpsc` has
/// no `select`, so client messages and worker events share one queue).
enum SupIn {
    Client(SupMsg),
    Event { group: usize, epoch: u64, ev: Event },
}

/// Supervisor → worker commands.
enum WorkerCmd {
    Submit(Request),
    Rescue(RescueEntry),
    Drain,
}

/// Per-group cumulative counters kept on the supervisor side (they
/// survive worker restarts; the stats endpoint reports them per row).
#[derive(Clone, Copy, Debug, Default)]
struct GroupStats {
    seq_failures: u64,
    rescues: u64,
    completions: u64,
    preemptions: u64,
    resumes: u64,
    swap_preemptions: u64,
}

/// Supervisor-side state for one decode group.
struct GroupSlot {
    tx: Option<Sender<WorkerCmd>>,
    /// Currently valid lease epoch; bumping it fences the live worker.
    lease: Arc<AtomicU64>,
    /// Epoch of the worker incarnation the supervisor considers
    /// current (== `lease` except transiently during quarantine).
    epoch: u64,
    hb: Arc<Heartbeat>,
    health: GroupHealth,
    /// Worker thread believed to be running.
    live: bool,
    /// Tick-error EMA (the health signal).
    err_ema: f64,
    restarts: u32,
    /// When the pending restart fires; `None` = no restart scheduled.
    restart_at: Option<Instant>,
    /// Per-group live-KV byte budget (0 = unlimited).
    budget: usize,
    // Gauges from the last accepted Ticked event.
    live_bytes: usize,
    queue_depth: usize,
    active: usize,
    prefilling: usize,
    kv_format: String,
    stats: GroupStats,
}

impl GroupSlot {
    fn row_json(&self, id: usize, assigned: usize) -> Json {
        Json::obj(vec![
            ("id", Json::from(id)),
            ("health", Json::str(self.health.label())),
            ("live_bytes", Json::from(self.live_bytes)),
            ("queue_depth", Json::from(self.queue_depth)),
            ("active", Json::from(self.active)),
            ("prefilling", Json::from(self.prefilling)),
            ("assigned", Json::from(assigned)),
            ("kv_format", Json::str(&self.kv_format)),
            ("seq_failures", Json::from(self.stats.seq_failures as usize)),
            ("rescues", Json::from(self.stats.rescues as usize)),
            ("restarts", Json::from(self.restarts as usize)),
            ("completions", Json::from(self.stats.completions as usize)),
            ("preemptions", Json::from(self.stats.preemptions as usize)),
            ("resumes", Json::from(self.stats.resumes as usize)),
            (
                "swap_preemptions",
                Json::from(self.stats.swap_preemptions as usize),
            ),
        ])
    }
}

/// A submitted request the supervisor is still waiting on.
struct Pending {
    reply: Sender<Result<GenerateResponse>>,
    prompt_tokens: usize,
    /// Supervisor-side copy for shadow re-submission when the owning
    /// group dies without exporting the sequence (same tokens, same
    /// greedy continuation).
    shadow: Request,
    /// Group currently serving the request.
    group: usize,
}

/// Handle to the supervisor thread (the server's serving core).
pub struct Supervisor {
    tx: Sender<SupIn>,
    handle: Option<JoinHandle<()>>,
}

impl Supervisor {
    /// Boot `serving.groups` workers (each loading runtime + engine +
    /// manifest) and the supervisor loop; returns once every group is
    /// up or the first one fails.
    pub fn start(
        cfg: ServingConfig,
        default_policy: PolicyKind,
    ) -> Result<Supervisor> {
        let (tx, rx) = mpsc::channel::<SupIn>();
        let (boot_tx, boot_rx) = mpsc::channel::<Result<()>>();
        let events = tx.clone();
        let handle = std::thread::Builder::new()
            .name("lethe-supervisor".into())
            .spawn(move || {
                supervisor_thread(cfg, default_policy, rx, events, boot_tx);
            })
            .context("spawning supervisor thread")?;
        boot_rx
            .recv()
            .context("supervisor thread died during boot")??;
        Ok(Supervisor { tx, handle: Some(handle) })
    }

    /// Submit a request; returns a receiver for the completion.
    pub fn submit(
        &self,
        req: GenerateRequest,
    ) -> Result<Receiver<Result<GenerateResponse>>> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(SupIn::Client(SupMsg::Generate(req, tx)))
            .map_err(|_| anyhow::anyhow!("server is shut down"))?;
        Ok(rx)
    }

    /// Aggregate + per-group serving-pressure snapshot.
    pub fn stats(&self) -> Result<Json> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(SupIn::Client(SupMsg::Stats(tx)))
            .map_err(|_| anyhow::anyhow!("server is shut down"))?;
        rx.recv().context("supervisor dropped the stats query")
    }

    /// Fence group `g` off and rescue its in-flight work onto healthy
    /// peers (it restarts with backoff like any quarantined group).
    /// Returns false when `g` is unknown or not currently serving.
    pub fn quarantine_group(&self, g: usize) -> Result<bool> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(SupIn::Client(SupMsg::Quarantine(g, tx)))
            .map_err(|_| anyhow::anyhow!("server is shut down"))?;
        rx.recv().context("supervisor dropped the quarantine request")
    }

    /// Drain every group and stop the supervisor.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(SupIn::Client(SupMsg::Shutdown));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Supervisor {
    fn drop(&mut self) {
        let _ = self.tx.send(SupIn::Client(SupMsg::Shutdown));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------
// Worker side
// ---------------------------------------------------------------------

/// Downgrade swapped rescue images to recompute prefixes. Used after an
/// errored tick: the cache may hold rows the failed step half-wrote, so
/// the safe export is the token prefix (still token-identical under
/// greedy decode, paid in prefill FLOPs).
fn downgrade_swapped(entries: Vec<RescueEntry>) -> Vec<RescueEntry> {
    entries
        .into_iter()
        .map(|e| match e {
            RescueEntry::Swapped { seq, .. } => {
                let mut tokens = seq.prompt.clone();
                tokens.extend_from_slice(&seq.generated);
                RescueEntry::Resume { tokens, seq }
            }
            e => e,
        })
        .collect()
}

struct Worker {
    group: usize,
    epoch: u64,
    cfg: ServingConfig,
    default_policy: PolicyKind,
    rx: Receiver<WorkerCmd>,
    out: Sender<SupIn>,
    lease: Arc<AtomicU64>,
    hb: Arc<Heartbeat>,
}

impl Worker {
    fn send(&self, ev: Event) {
        let _ = self.out.send(SupIn::Event {
            group: self.group,
            epoch: self.epoch,
            ev,
        });
    }

    fn leased(&self) -> bool {
        self.lease.load(Ordering::Acquire) == self.epoch
    }

    /// Thread body: boot, then the scheduler loop until drain, lease
    /// loss, or panic.
    fn run(self) {
        let boot = (|| -> Result<(Engine, String)> {
            let rt =
                Runtime::load(std::path::Path::new(&self.cfg.artifacts_dir))?;
            let fp = rt.meta.shard_manifest().fingerprint();
            Ok((Engine::new(rt, self.cfg.clone())?, fp))
        })();
        let mut engine = match boot {
            Ok((engine, fp)) => {
                self.send(Event::Booted(Ok(fp)));
                engine
            }
            Err(e) => {
                self.send(Event::Booted(Err(e)));
                return;
            }
        };

        let mut sched = Scheduler::new(&engine, self.default_policy);
        // Group-scoped fault plan (panic/stall seams); independent of
        // the engine-seam plan the engine itself owns.
        let mut gplan = FaultPlan::for_group(&self.cfg.faults, self.group);
        let stall_sleep_ms =
            (self.cfg.serving.tick_timeout_ms.saturating_mul(3)).max(50);
        let mut last_snap = CounterSnap::default();
        let mut shutdown = false;

        loop {
            // Command pump; blocks in short slices when idle so a lease
            // loss is noticed promptly.
            loop {
                if !self.leased() {
                    return;
                }
                let cmd = if sched.idle() && !shutdown {
                    match self.rx.recv_timeout(Duration::from_millis(50)) {
                        Ok(c) => c,
                        Err(RecvTimeoutError::Timeout) => continue,
                        Err(RecvTimeoutError::Disconnected) => {
                            shutdown = true;
                            break;
                        }
                    }
                } else {
                    match self.rx.try_recv() {
                        Ok(c) => c,
                        Err(_) => break,
                    }
                };
                match cmd {
                    WorkerCmd::Submit(r) => {
                        let id = r.id;
                        if let Err(err) = sched.submit(r) {
                            self.send(Event::Rejected { id, err });
                        }
                    }
                    WorkerCmd::Rescue(e) => sched.admit_rescued(e),
                    WorkerCmd::Drain => {
                        shutdown = true;
                        break;
                    }
                }
            }

            if shutdown && !sched.draining() {
                sched.begin_drain();
            }
            if sched.idle() {
                if shutdown {
                    self.send(Event::Exited);
                    return;
                }
                continue;
            }

            // Injected stall: hold the heartbeat inside a fake tick
            // long enough for the watchdog to fire, then honour the
            // lease it will have revoked.
            if let Some(p) = gplan.as_mut() {
                if p.trip(FaultSite::GroupStall) {
                    engine.metrics.faults_injected =
                        engine.metrics.faults_injected.saturating_add(1);
                    self.hb.enter();
                    std::thread::sleep(Duration::from_millis(stall_sleep_ms));
                    self.hb.exit();
                    if !self.leased() {
                        return;
                    }
                }
            }
            let panic_now = gplan
                .as_mut()
                .is_some_and(|p| p.trip(FaultSite::GroupPanic));

            self.hb.enter();
            let ticked = catch_unwind(AssertUnwindSafe(|| {
                if panic_now {
                    panic!("injected: group panic");
                }
                sched.tick(&mut engine)
            }));
            self.hb.exit();

            match ticked {
                Ok(Ok(report)) => {
                    let snap = CounterSnap::capture(&sched, &engine);
                    let delta = snap.delta(last_snap);
                    last_snap = snap;
                    self.send(Event::Ticked(Box::new(TickUpdate {
                        errored: false,
                        completions: report.completed,
                        kv_format: sched.kv_format(),
                        delta,
                        live_bytes: sched.group.cache.live_bytes(),
                        queue_depth: sched.waiting(),
                        active: sched.active(),
                        prefilling: sched.prefilling(),
                        rescued: Vec::new(),
                    })));
                }
                Ok(Err(e)) => {
                    // The tick failed wholesale: scheduler/cache state
                    // is suspect. Export everything as recompute
                    // prefixes, hand it to the supervisor (which may
                    // rescue it right back here if this group stays
                    // below the quarantine line), and keep serving on a
                    // rebuilt scheduler.
                    crate::log_error!(
                        "group {}: tick failed: {e:#}",
                        self.group
                    );
                    let (entries, completions) = sched.export_for_rescue();
                    let rescued = downgrade_swapped(entries);
                    let snap = CounterSnap::capture(&sched, &engine);
                    let delta = snap.delta(last_snap);
                    last_snap = snap;
                    let draining = sched.draining();
                    sched = Scheduler::new(&engine, self.default_policy);
                    if draining {
                        sched.begin_drain();
                    }
                    self.send(Event::Ticked(Box::new(TickUpdate {
                        errored: true,
                        completions,
                        kv_format: sched.kv_format(),
                        delta,
                        live_bytes: 0,
                        queue_depth: 0,
                        active: 0,
                        prefilling: 0,
                        rescued,
                    })));
                }
                Err(_panic) => {
                    // Export under a guard: the panic may have torn the
                    // very state the export walks.
                    let (rescued, completions) =
                        catch_unwind(AssertUnwindSafe(|| {
                            sched.export_for_rescue()
                        }))
                        .unwrap_or_default();
                    self.send(Event::Panicked { rescued, completions });
                    return;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Supervisor side
// ---------------------------------------------------------------------

struct SupState {
    cfg: ServingConfig,
    default_policy: PolicyKind,
    events: Sender<SupIn>,
    tok: Tokenizer,
    /// Fingerprint every worker must match (from the probe's manifest).
    expected_fp: String,
    /// Probe manifest (the stats endpoint's `model` object).
    manifest: Json,
    slots: Vec<GroupSlot>,
    pending: HashMap<u64, Pending>,
    /// Aggregate metrics across groups and restarts (delta-applied).
    metrics: EngineMetrics,
    next_id: u64,
    shutdown: bool,
    shutdown_deadline: Option<Instant>,
}

fn supervisor_thread(
    cfg: ServingConfig,
    default_policy: PolicyKind,
    rx: Receiver<SupIn>,
    events: Sender<SupIn>,
    boot_tx: Sender<Result<()>>,
) {
    let probe = (|| -> Result<SupState> {
        let meta =
            ModelMeta::load(std::path::Path::new(&cfg.artifacts_dir))?;
        let tok = Tokenizer::from_meta(&meta)?;
        let manifest = meta.shard_manifest();
        Ok(SupState {
            expected_fp: manifest.fingerprint(),
            manifest: manifest.to_json(),
            tok,
            slots: Vec::new(),
            pending: HashMap::new(),
            metrics: EngineMetrics::default(),
            next_id: 1,
            shutdown: false,
            shutdown_deadline: None,
            cfg,
            default_policy,
            events,
        })
    })();
    let mut st = match probe {
        Ok(st) => st,
        Err(e) => {
            let _ = boot_tx.send(Err(e));
            return;
        }
    };

    // Spawn every group, then hold the boot barrier: all workers up,
    // fingerprints agreeing, before the server opens for business.
    let n = st.cfg.serving.groups.max(1);
    for g in 0..n {
        let mut slot = GroupSlot {
            tx: None,
            lease: Arc::new(AtomicU64::new(1)),
            epoch: 1,
            hb: Arc::new(Heartbeat::new()),
            health: GroupHealth::Quarantined,
            live: false,
            err_ema: 0.0,
            restarts: 0,
            restart_at: None,
            budget: st
                .cfg
                .serving
                .group_budget_bytes(st.cfg.scheduler.kv_budget_bytes),
            live_bytes: 0,
            queue_depth: 0,
            active: 0,
            prefilling: 0,
            kv_format: String::new(),
            stats: GroupStats::default(),
        };
        if let Err(e) = st.spawn_worker(g, &mut slot) {
            let _ = boot_tx.send(Err(e));
            return;
        }
        st.slots.push(slot);
    }
    let mut booted = 0usize;
    while booted < n {
        let Ok(msg) = rx.recv() else {
            let _ = boot_tx
                .send(Err(anyhow::anyhow!("supervisor channel closed at boot")));
            return;
        };
        match msg {
            SupIn::Event { group, epoch, ev } => {
                if st.slots[group].epoch != epoch {
                    continue;
                }
                match ev {
                    Event::Booted(Ok(fp)) if fp == st.expected_fp => {
                        st.slots[group].health = GroupHealth::Healthy;
                        booted += 1;
                    }
                    Event::Booted(Ok(fp)) => {
                        let _ = boot_tx.send(Err(anyhow::anyhow!(
                            "group {group}: manifest fingerprint {fp} \
                             disagrees with probe {}",
                            st.expected_fp
                        )));
                        st.fence_all();
                        return;
                    }
                    Event::Booted(Err(e)) => {
                        let _ = boot_tx.send(
                            Err(e).context(format!("group {group} boot")),
                        );
                        st.fence_all();
                        return;
                    }
                    // Nothing else can arrive before the first submit.
                    _ => {}
                }
            }
            // Clients cannot reach us before boot_tx resolves; drop.
            SupIn::Client(_) => {}
        }
    }
    let _ = boot_tx.send(Ok(()));

    // Main loop: pump one message (bounded wait so the watchdog and
    // restart timers run even when idle), then supervise.
    loop {
        match rx.recv_timeout(Duration::from_millis(20)) {
            Ok(msg) => {
                st.handle(msg);
                while let Ok(m) = rx.try_recv() {
                    st.handle(m);
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => st.begin_shutdown(),
        }
        st.supervise();
        if st.shutdown {
            let groups_done = st.slots.iter().all(|s| !s.live);
            let expired = st
                .shutdown_deadline
                .is_some_and(|d| Instant::now() >= d);
            if groups_done || expired {
                break;
            }
        }
    }

    // Fail whatever is still pending, typed, and fence any straggler.
    for (_, p) in st.pending.drain() {
        let _ = p.reply.send(Err(EngineError::ShuttingDown.into()));
    }
    st.fence_all();
    // Worker handles are detached on purpose: a truly hung worker
    // would otherwise wedge shutdown; the lease fence guarantees it
    // can never touch shared state again.
}

impl SupState {
    /// Spawn (or respawn) group `g`'s worker into `slot`.
    fn spawn_worker(&self, g: usize, slot: &mut GroupSlot) -> Result<()> {
        let (tx, rx) = mpsc::channel::<WorkerCmd>();
        let mut wcfg = self.cfg.clone();
        wcfg.scheduler.kv_budget_bytes = slot.budget;
        // Decorrelate the engine-seam fault schedule per group (group
        // 0 keeps the configured seed, preserving single-group runs).
        wcfg.faults.seed = wcfg.faults.seed.wrapping_add(g as u64);
        let worker = Worker {
            group: g,
            epoch: slot.epoch,
            cfg: wcfg,
            default_policy: self.default_policy,
            rx,
            out: self.events.clone(),
            lease: Arc::clone(&slot.lease),
            hb: Arc::clone(&slot.hb),
        };
        std::thread::Builder::new()
            .name(format!("lethe-group-{g}"))
            .spawn(move || worker.run())
            .with_context(|| format!("spawning group {g} worker"))?;
        slot.tx = Some(tx);
        slot.live = true;
        Ok(())
    }

    /// Revoke every group's lease (shutdown / aborted boot).
    fn fence_all(&mut self) {
        for s in &mut self.slots {
            s.epoch += 1;
            s.lease.store(s.epoch, Ordering::Release);
            s.tx = None;
        }
    }

    fn begin_shutdown(&mut self) {
        if self.shutdown {
            return;
        }
        self.shutdown = true;
        for s in &mut self.slots {
            s.restart_at = None;
            if let Some(tx) = &s.tx {
                let _ = tx.send(WorkerCmd::Drain);
            }
        }
        self.shutdown_deadline = Some(
            Instant::now()
                + Duration::from_millis(
                    self.cfg.scheduler.drain_window_ms + 3000,
                ),
        );
    }

    fn handle(&mut self, msg: SupIn) {
        match msg {
            SupIn::Client(SupMsg::Shutdown) => self.begin_shutdown(),
            SupIn::Client(SupMsg::Stats(reply)) => {
                let _ = reply.send(self.stats_json());
            }
            SupIn::Client(SupMsg::Quarantine(g, reply)) => {
                let ok = g < self.slots.len()
                    && self.slots[g].live
                    && matches!(
                        self.slots[g].health,
                        GroupHealth::Healthy | GroupHealth::Degraded
                    );
                if ok {
                    crate::log_error!("group {g}: operator quarantine");
                    self.quarantine(g, Vec::new());
                }
                let _ = reply.send(ok);
            }
            SupIn::Client(SupMsg::Generate(req, reply)) => {
                self.place(req, reply);
            }
            SupIn::Event { group, epoch, ev } => {
                if self.slots[group].epoch == epoch {
                    self.on_event(group, ev);
                }
            }
        }
    }

    /// Requests currently assigned to group `g`.
    fn assigned(&self, g: usize) -> usize {
        self.pending.values().filter(|p| p.group == g).count()
    }

    fn placement_view(&self) -> Vec<(GroupHealth, usize, usize, usize)> {
        (0..self.slots.len())
            .map(|g| {
                let s = &self.slots[g];
                (s.health, s.budget, s.live_bytes, self.assigned(g))
            })
            .collect()
    }

    /// Backoff hint for `GroupUnavailable`: time until the nearest
    /// scheduled restart, or one base backoff when none is scheduled.
    fn unavailable_retry_ms(&self) -> u64 {
        let now = Instant::now();
        self.slots
            .iter()
            .filter_map(|s| s.restart_at)
            .map(|at| at.saturating_duration_since(now).as_millis() as u64)
            .min()
            .unwrap_or(self.cfg.serving.restart_backoff_ms)
            .clamp(25, 5000)
    }

    /// Admission: encode, clamp, place on the group with the most KV
    /// headroom, and remember the shadow copy for rescue.
    fn place(
        &mut self,
        req: GenerateRequest,
        reply: Sender<Result<GenerateResponse>>,
    ) {
        if self.shutdown {
            let _ = reply.send(Err(EngineError::ShuttingDown.into()));
            return;
        }
        let prompt = match self.tok.encode_prompt(&req.prompt) {
            Ok(p) => p,
            Err(e) => {
                let _ = reply.send(Err(e));
                return;
            }
        };
        let Some(g) = pick_target(&self.placement_view()) else {
            let _ = reply.send(Err(EngineError::GroupUnavailable {
                retry_after_ms: self.unavailable_retry_ms(),
            }
            .into()));
            return;
        };
        let id = self.next_id;
        self.next_id += 1;
        let r = Request {
            id,
            prompt,
            max_new_tokens: req
                .max_new_tokens
                .min(self.cfg.scheduler.max_new_tokens),
            policy: req.policy.unwrap_or(self.default_policy),
            submitted_at: Instant::now(),
            deadline_ms: req.deadline_ms,
            class: req.class.clone().unwrap_or_default(),
        };
        let pending = Pending {
            reply,
            prompt_tokens: r.prompt.len(),
            shadow: r.clone(),
            group: g,
        };
        let sent = self.slots[g]
            .tx
            .as_ref()
            .is_some_and(|tx| tx.send(WorkerCmd::Submit(r)).is_ok());
        if sent {
            self.pending.insert(id, pending);
        } else {
            let _ = pending.reply.send(Err(EngineError::GroupUnavailable {
                retry_after_ms: self.unavailable_retry_ms(),
            }
            .into()));
        }
    }

    fn on_event(&mut self, g: usize, ev: Event) {
        match ev {
            Event::Booted(Ok(fp)) if fp == self.expected_fp => {
                let s = &mut self.slots[g];
                s.health = GroupHealth::Healthy;
                s.err_ema = 0.0;
                crate::log_error!(
                    "group {g}: restarted (attempt {})",
                    s.restarts
                );
            }
            Event::Booted(Ok(_)) | Event::Booted(Err(_)) => {
                if let Event::Booted(Err(e)) = ev {
                    crate::log_error!("group {g}: reboot failed: {e:#}");
                } else {
                    crate::log_error!(
                        "group {g}: reboot rejected: manifest mismatch"
                    );
                }
                self.slots[g].live = false;
                self.schedule_restart(g);
            }
            Event::Exited => {
                self.slots[g].live = false;
            }
            Event::Rejected { id, err } => {
                if let Some(p) = self.pending.remove(&id) {
                    let _ = p.reply.send(Err(err));
                }
            }
            Event::Panicked { rescued, completions } => {
                crate::log_error!("group {g}: worker panicked mid-tick");
                self.deliver(g, completions);
                self.slots[g].live = false;
                self.quarantine(g, rescued);
            }
            Event::Ticked(t) => {
                let t = *t;
                let s = &mut self.slots[g];
                s.live_bytes = t.live_bytes;
                s.queue_depth = t.queue_depth;
                s.active = t.active;
                s.prefilling = t.prefilling;
                s.kv_format = t.kv_format;
                s.stats.completions += t.completions.len() as u64;
                s.stats.seq_failures += t.delta.seq_failures;
                s.stats.preemptions += t.delta.preemptions;
                s.stats.resumes += t.delta.resumes;
                s.stats.swap_preemptions += t.delta.swap_preemptions;
                t.delta.apply(&mut self.metrics);
                // EMA update; quarantine only from a serving state (a
                // group already being fenced reports no valid events).
                s.err_ema = if t.errored {
                    0.7 * s.err_ema + 0.3
                } else {
                    0.7 * s.err_ema
                };
                let health = classify(
                    s.err_ema,
                    self.cfg.serving.degraded_error_rate,
                    self.cfg.serving.quarantine_error_rate,
                );
                self.deliver(g, t.completions);
                if health == GroupHealth::Quarantined {
                    crate::log_error!(
                        "group {g}: tick-error EMA {:.2} past the \
                         quarantine line",
                        self.slots[g].err_ema
                    );
                    self.quarantine(g, t.rescued);
                } else {
                    self.slots[g].health = health;
                    for e in t.rescued {
                        self.rescue_entry(e, g);
                    }
                }
            }
        }
    }

    /// Route a finished batch to its reply channels.
    fn deliver(&mut self, g: usize, completions: Vec<Completion>) {
        let kv_format = self.slots[g].kv_format.clone();
        for c in completions {
            // Aggregate per-class SLO tracks live on the supervisor's
            // metrics (worker-side tracks are per group); every
            // delivered completion folds in exactly once, whether or
            // not a reply channel is still waiting for it.
            self.metrics.record_completion(&c);
            let Some(p) = self.pending.remove(&c.id) else {
                continue;
            };
            let resp = GenerateResponse {
                id: c.id,
                text: self.tok.decode(&c.generated),
                finish: format!("{:?}", c.finish),
                prompt_tokens: p.prompt_tokens,
                generated_tokens: c.generated.len(),
                ttft_s: c.ttft,
                tpot_s: c.tpot,
                total_s: c.total,
                prune_rounds: c.prune_rounds,
                preemptions: c.preemptions,
                kv_format: kv_format.clone(),
            };
            let _ = p.reply.send(Ok(resp));
        }
    }

    /// Fence group `g`, rescue everything it was serving, and schedule
    /// its restart (or declare it dead past the restart budget).
    /// `exported` is whatever the worker managed to hand over; pending
    /// requests not covered by it are shadow-resubmitted from the
    /// supervisor's own request copies.
    fn quarantine(&mut self, g: usize, exported: Vec<RescueEntry>) {
        {
            let s = &mut self.slots[g];
            if matches!(
                s.health,
                GroupHealth::Quarantined | GroupHealth::Dead
            ) && s.tx.is_none()
            {
                return; // already fenced
            }
            self.metrics.group_quarantines += 1;
            s.health = GroupHealth::Quarantined;
            s.err_ema = 0.0;
            s.epoch += 1;
            s.lease.store(s.epoch, Ordering::Release);
            s.tx = None;
            s.live = false;
            s.live_bytes = 0;
            s.queue_depth = 0;
            s.active = 0;
            s.prefilling = 0;
        }
        let mut covered = Vec::new();
        for e in exported {
            covered.push(e.id());
            self.rescue_entry(e, g);
        }
        let orphans: Vec<u64> = self
            .pending
            .iter()
            .filter(|&(id, p)| p.group == g && !covered.contains(id))
            .map(|(id, _)| *id)
            .collect();
        for id in orphans {
            let shadow = self.pending[&id].shadow.clone();
            self.rescue_entry(RescueEntry::Fresh(shadow), g);
        }
        self.schedule_restart(g);
    }

    fn schedule_restart(&mut self, g: usize) {
        let s = &mut self.slots[g];
        if self.shutdown {
            return;
        }
        if s.restarts >= self.cfg.serving.max_restarts {
            crate::log_error!(
                "group {g}: restart budget spent ({}); marking dead",
                s.restarts
            );
            s.health = GroupHealth::Dead;
            s.restart_at = None;
            return;
        }
        let delay =
            backoff_ms(self.cfg.serving.restart_backoff_ms, s.restarts);
        s.restart_at = Some(Instant::now() + Duration::from_millis(delay));
    }

    /// Move one rescued unit onto the best healthy group (which may be
    /// `from` itself after a below-threshold errored tick). When no
    /// group can take it, the request finishes typed:
    /// `Error(GroupLost)` with whatever text it had produced.
    fn rescue_entry(&mut self, e: RescueEntry, from: usize) {
        let id = e.id();
        let bytes = e.payload_bytes() as u64;
        if !self.pending.contains_key(&id) {
            return; // completed or failed while the rescue was in flight
        }
        let target = pick_target(&self.placement_view());
        let sent = target.is_some_and(|t| {
            self.slots[t]
                .tx
                .as_ref()
                .is_some_and(|tx| tx.send(WorkerCmd::Rescue(e)).is_ok())
        });
        // `e` moved into the channel on success; on failure the typed
        // finish below reconstructs its text from the shadow copy.
        if sent {
            let t = target.unwrap();
            let p = self.pending.get_mut(&id).unwrap();
            p.group = t;
            self.metrics.rescued_seqs += 1;
            self.metrics.rescue_bytes += bytes;
            self.slots[from].stats.rescues += 1;
            return;
        }
        let p = self.pending.remove(&id).unwrap();
        let resp = GenerateResponse {
            id,
            text: String::new(),
            finish: format!(
                "{:?}",
                FinishReason::Error(FailureKind::GroupLost)
            ),
            prompt_tokens: p.prompt_tokens,
            generated_tokens: 0,
            ttft_s: 0.0,
            tpot_s: 0.0,
            total_s: p.shadow.submitted_at.elapsed().as_secs_f64(),
            prune_rounds: 0,
            preemptions: 0,
            kv_format: String::new(),
        };
        let _ = p.reply.send(Ok(resp));
    }

    /// Watchdog + restart timers; runs every loop iteration.
    fn supervise(&mut self) {
        let timeout = self.cfg.serving.tick_timeout_ms;
        for g in 0..self.slots.len() {
            let stalled = timeout > 0
                && self.slots[g].live
                && matches!(
                    self.slots[g].health,
                    GroupHealth::Healthy | GroupHealth::Degraded
                )
                && self.slots[g].hb.stalled(timeout);
            if stalled {
                crate::log_error!(
                    "group {g}: tick overran {timeout} ms; quarantining"
                );
                self.quarantine(g, Vec::new());
            }
        }
        for g in 0..self.slots.len() {
            let due = self.slots[g]
                .restart_at
                .is_some_and(|at| Instant::now() >= at);
            if !due || self.shutdown {
                continue;
            }
            self.slots[g].restart_at = None;
            self.slots[g].restarts += 1;
            self.metrics.group_restarts += 1;
            let mut slot = std::mem::replace(
                &mut self.slots[g],
                GroupSlot {
                    tx: None,
                    lease: Arc::new(AtomicU64::new(0)),
                    epoch: 0,
                    hb: Arc::new(Heartbeat::new()),
                    health: GroupHealth::Dead,
                    live: false,
                    err_ema: 0.0,
                    restarts: 0,
                    restart_at: None,
                    budget: 0,
                    live_bytes: 0,
                    queue_depth: 0,
                    active: 0,
                    prefilling: 0,
                    kv_format: String::new(),
                    stats: GroupStats::default(),
                },
            );
            // Fresh heartbeat so a stall from the dead incarnation
            // cannot re-trip the watchdog.
            slot.hb = Arc::new(Heartbeat::new());
            let spawned = self.spawn_worker(g, &mut slot);
            self.slots[g] = slot;
            if let Err(e) = spawned {
                crate::log_error!("group {g}: respawn failed: {e:#}");
                self.slots[g].live = false;
                self.schedule_restart(g);
            }
            // Health stays Quarantined until Booted(Ok) flips it.
        }
    }

    /// The `{"stats": true}` document: the single-scheduler shape
    /// (aggregated), plus per-group rows, supervision counters and the
    /// shard manifest.
    fn stats_json(&mut self) -> Json {
        let queue: usize = self.slots.iter().map(|s| s.queue_depth).sum();
        let prefilling: usize =
            self.slots.iter().map(|s| s.prefilling).sum();
        let active: usize = self.slots.iter().map(|s| s.active).sum();
        let live: usize = self.slots.iter().map(|s| s.live_bytes).sum();
        let fmt = {
            let mut fmts: Vec<&str> = self
                .slots
                .iter()
                .filter(|s| !s.kv_format.is_empty())
                .map(|s| s.kv_format.as_str())
                .collect();
            fmts.sort_unstable();
            fmts.dedup();
            match fmts.as_slice() {
                [] => self.cfg.kv.format.label().to_string(),
                [one] => one.to_string(),
                _ => "mixed".to_string(),
            }
        };
        self.metrics.queue_depth_last = queue;
        self.metrics.live_bytes_last = live;
        let rows: Vec<Json> = (0..self.slots.len())
            .map(|g| self.slots[g].row_json(g, self.assigned(g)))
            .collect();
        Json::obj(vec![
            ("queue_depth", Json::from(queue)),
            ("prefilling", Json::from(prefilling)),
            ("active", Json::from(active)),
            ("rejected", Json::from(self.metrics.rejected as usize)),
            ("preemptions", Json::from(self.metrics.preemptions as usize)),
            ("resumes", Json::from(self.metrics.resumes as usize)),
            (
                "kv_migrations",
                Json::from(self.metrics.kv_migrations as usize),
            ),
            ("kv_format", Json::str(&fmt)),
            ("draining", Json::from(self.shutdown)),
            ("groups", Json::Arr(rows)),
            ("model", self.manifest.clone()),
            ("metrics", self.metrics.to_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_doubles_and_saturates() {
        assert_eq!(backoff_ms(100, 0), 100);
        assert_eq!(backoff_ms(100, 1), 200);
        assert_eq!(backoff_ms(100, 3), 800);
        assert_eq!(backoff_ms(0, 0), 1, "zero base still waits");
        // Shift-capped: huge restart counts neither overflow nor wrap.
        assert_eq!(backoff_ms(100, 64), 100 * (1 << 16));
        assert!(backoff_ms(u64::MAX, 16) == u64::MAX, "saturates");
    }

    #[test]
    fn health_classification_thresholds() {
        assert_eq!(classify(0.0, 0.1, 0.5), GroupHealth::Healthy);
        assert_eq!(classify(0.09, 0.1, 0.5), GroupHealth::Healthy);
        assert_eq!(classify(0.1, 0.1, 0.5), GroupHealth::Degraded);
        assert_eq!(classify(0.49, 0.1, 0.5), GroupHealth::Degraded);
        assert_eq!(classify(0.5, 0.1, 0.5), GroupHealth::Quarantined);
        assert_eq!(GroupHealth::Dead.label(), "dead");
    }

    #[test]
    fn ema_reaches_quarantine_under_sustained_errors() {
        // The worker-side update: errored → 0.7e + 0.3, ok → 0.7e.
        let mut ema: f64 = 0.0;
        let mut ticks = 0;
        while ema < 0.5 {
            ema = 0.7 * ema + 0.3;
            ticks += 1;
            assert!(ticks < 10, "sustained errors must cross the line");
        }
        assert_eq!(ticks, 3, "three consecutive errored ticks quarantine");
        // One error among many healthy ticks only degrades transiently.
        let mut ema = 0.3f64;
        for _ in 0..8 {
            ema *= 0.7;
        }
        assert!(ema < 0.1, "healthy ticks decay back below degraded");
    }

    #[test]
    fn placement_prefers_healthy_max_headroom() {
        use GroupHealth::*;
        // (health, budget, live_bytes, assigned)
        let groups = vec![
            (Healthy, 1000, 800, 0), // headroom 200
            (Healthy, 1000, 100, 5), // headroom 900 ← winner
            (Degraded, 1000, 0, 0),  // more headroom but degraded
            (Quarantined, 1000, 0, 0),
        ];
        assert_eq!(pick_target(&groups), Some(1));
        // No healthy group: degraded beats nothing; dead/quarantined
        // are never picked.
        let groups = vec![
            (Quarantined, 1000, 0, 0),
            (Degraded, 1000, 500, 0),
            (Dead, 1000, 0, 0),
        ];
        assert_eq!(pick_target(&groups), Some(1));
        assert_eq!(
            pick_target(&[(Dead, 0, 0, 0), (Quarantined, 0, 0, 0)]),
            None
        );
        // Unlimited budget: fewest assigned requests wins, then the
        // lowest group id.
        let groups =
            vec![(Healthy, 0, 0, 2), (Healthy, 0, 0, 1), (Healthy, 0, 0, 1)];
        assert_eq!(pick_target(&groups), Some(1));
    }

    #[test]
    fn counter_deltas_saturate_across_restarts() {
        let a = CounterSnap { decode_steps: 10, resumes: 2, ..Default::default() };
        let b = CounterSnap { decode_steps: 14, resumes: 2, ..Default::default() };
        let d = b.delta(a);
        assert_eq!(d.decode_steps, 4);
        assert_eq!(d.resumes, 0);
        // A fresh engine's counters restart from zero: the delta
        // saturates instead of wrapping.
        let fresh = CounterSnap { decode_steps: 1, ..Default::default() };
        assert_eq!(fresh.delta(b).decode_steps, 0);
        let mut m = EngineMetrics::default();
        d.apply(&mut m);
        d.apply(&mut m);
        assert_eq!(m.decode_steps, 8, "deltas accumulate");
    }

    #[test]
    fn heartbeat_stall_detection() {
        let hb = Heartbeat::new();
        assert!(!hb.stalled(0), "not in a tick, never stalled");
        hb.enter();
        std::thread::sleep(Duration::from_millis(5));
        assert!(hb.stalled(1), "tick older than the timeout");
        assert!(!hb.stalled(10_000), "young tick is fine");
        hb.exit();
        assert!(!hb.stalled(1), "exit clears the stall");
    }

    #[test]
    fn downgrade_turns_images_into_recompute_prefixes() {
        use crate::policy::FullKv;
        let mut seq =
            crate::engine::SeqState::new(9, Box::new(FullKv), 1, 8, 2);
        seq.prompt = vec![1, 3];
        seq.generated = vec![7];
        let entries = vec![RescueEntry::Resume {
            tokens: vec![1, 3],
            seq,
        }];
        let out = downgrade_swapped(entries);
        assert!(
            matches!(&out[0], RescueEntry::Resume { tokens, .. }
                     if tokens == &vec![1, 3]),
            "non-swapped entries pass through untouched"
        );
    }
}
