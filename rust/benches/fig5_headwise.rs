//! Figure 5: head-wise attention similarity. The paper shows four heads
//! at one layer focusing on the same key positions, justifying Lethe's
//! head-invariant (Eq. 2) scoring against FastGen-style per-head budgets.
//!
//! We decode a prompt, capture the raw per-head attention rows at a
//! chosen layer/step, and report the pairwise cosine-similarity matrix
//! across query heads for every layer (paper: layer 6, step 1000; here
//! scaled to the tiny model).

use lethe::attn::score::{cosine, ProbsView};
use lethe::bench_support::{print_table, try_engine, write_csv};
use lethe::config::ServingConfig;
use lethe::engine::SeqState;
use lethe::policy::{make_policy, PolicyKind};
use lethe::util::prng::Rng;
use lethe::workload::make_task;

fn main() -> anyhow::Result<()> {
    let cfg = ServingConfig::default();
    let Some((mut engine, tok)) = try_engine(cfg) else { return Ok(()) };
    engine.keep_probs = true;
    let layers = engine.dims().n_layers;
    let heads = engine.dims().n_q_heads;

    let mut rng = Rng::new(0xF165);
    let task = make_task(&mut rng, 24, 3);
    let prompt = tok.encode_prompt(&task.prompt)?;
    let mut group = engine.new_group(1, PolicyKind::FullKv);
    let seq = SeqState::new(
        0,
        make_policy(PolicyKind::FullKv, &engine.cfg, layers),
        layers,
        64,
        tok.eos,
    );
    engine.prefill(&mut group, 0, seq, &prompt)?;

    // Capture mid-generation (hop-4 answers run ~13 tokens).
    let capture_step = 8;
    let mut captured: Option<(Vec<Vec<f32>>, usize)> = None; // per layer rows
    let mut step = 0;
    while group.active() > 0 {
        engine.step(&mut group)?;
        step += 1;
        if step == capture_step {
            if let Some(p) = engine.last_probs.take() {
                let pv = ProbsView::new(&p);
                let live = group.cache.len(0, 0);
                let mut rows = Vec::new();
                for l in 0..layers {
                    for h in 0..heads {
                        rows.push(pv.head_row(l, 0, h)[..live].to_vec());
                    }
                }
                captured = Some((rows, live));
            }
        }
        group.reap();
    }
    let Some((rows, live)) = captured else {
        eprintln!("[skip] generation too short to reach capture step");
        return Ok(());
    };

    let mut csv = Vec::new();
    let mut mean_off_diag = Vec::new();
    for l in 0..layers {
        let mut table = Vec::new();
        let mut sum = 0.0;
        let mut cnt = 0;
        for h1 in 0..heads {
            let mut row = vec![format!("h{h1}")];
            for h2 in 0..heads {
                let c = cosine(
                    &rows[l * heads + h1],
                    &rows[l * heads + h2],
                );
                row.push(format!("{c:.3}"));
                csv.push(format!("{l},{h1},{h2},{c:.4}"));
                if h1 != h2 {
                    sum += c;
                    cnt += 1;
                }
            }
            table.push(row);
        }
        mean_off_diag.push(sum / cnt as f64);
        let mut header = vec!["".to_string()];
        header.extend((0..heads).map(|h| format!("h{h}")));
        let header_refs: Vec<&str> =
            header.iter().map(|s| s.as_str()).collect();
        print_table(
            &format!(
                "Fig 5 — head-similarity (cosine), layer {l}, step \
                 {capture_step}, {live} cached tokens"
            ),
            &header_refs,
            &table,
        );
    }
    println!("\nmean off-diagonal similarity per layer:");
    for (l, m) in mean_off_diag.iter().enumerate() {
        println!("  layer {l}: {m:.3}");
    }
    println!(
        "(high similarity justifies Eq. 2's head-collapsed scoring; \
         FastGen-style per-head budgets buy little here)"
    );
    write_csv("fig5_headwise.csv", "layer,head_i,head_j,cosine", &csv)?;
    Ok(())
}
