//! Executable registry: one compiled PJRT executable per AOT shape bucket
//! (`prefill_t{T}`, `decode_b{B}_c{C}`), loaded lazily from HLO text and
//! cached. Also owns the typed call wrappers that marshal host tensors to
//! buffers, run `execute_b` with the persistent weight buffers, and
//! decompose the output tuple.

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};
use xla::{HloModuleProto, PjRtBuffer, PjRtClient, PjRtLoadedExecutable,
          XlaComputation};

use crate::kvcache::{KvFormat, PackedScratch};
use crate::model::{ModelMeta, Weights};
use crate::runtime::tensors::{scalar_i32, HostTensorF32, HostTensorI32};

pub struct Runtime {
    pub client: PjRtClient,
    pub meta: ModelMeta,
    pub weights: Weights,
    exes: RefCell<HashMap<String, PjRtLoadedExecutable>>,
    /// (name, compile seconds) log for EXPERIMENTS.md.
    compile_log: RefCell<Vec<(String, f64)>>,
    /// Lazily-spawned executor thread for the async decode seam
    /// ([`Runtime::decode_submit`] / [`Runtime::decode_packed_submit`]).
    executor: RefCell<Option<DecodeExecutor>>,
    /// True between a `*_submit` and its [`DecodeHandle::wait`]. Every
    /// synchronous entry point asserts this is clear: the executor job
    /// touches `self` (the PJRT client and the executable cache are not
    /// thread-safe) and holds raw pointers into the caller's scratch
    /// tensors, so overlapping runtime use is undefined behaviour, not
    /// merely a race.
    inflight: Arc<AtomicBool>,
}

type ExecJob = Box<dyn FnOnce() + Send>;

struct DecodeExecutor {
    tx: Option<Sender<ExecJob>>,
    join: Option<JoinHandle<()>>,
}

/// Raw-pointer wrapper that lets an executor job carry references across
/// the thread boundary. Safety rests entirely on the `inflight` protocol:
/// while the flag is set, the submitting thread must neither use the
/// runtime nor move/mutate the pointed-at tensors (the engine's
/// `sync_runtime` discipline — see `engine/mod.rs`).
struct SendPtr<T>(*const T);
unsafe impl<T> Send for SendPtr<T> {}

/// In-flight async decode step. `wait` joins the result; dropping the
/// handle without waiting leaves the runtime poisoned (the inflight
/// assertion will abort the next call), which is deliberate — a lost
/// execute means lost exclusivity guarantees.
pub struct DecodeHandle {
    rx: Receiver<(Result<DecodeOut>, f64)>,
    inflight: Arc<AtomicBool>,
}

impl DecodeHandle {
    /// Block until the submitted step finishes; returns the decode
    /// result and the executor-side execute seconds. An executor-thread
    /// death surfaces as a normal runtime-execute error so the engine's
    /// typed failure path handles it like any other execute fault.
    pub fn wait(self) -> (Result<DecodeOut>, f64) {
        let out = self.rx.recv().unwrap_or_else(|_| {
            (Err(anyhow!("decode executor thread died mid-step")), 0.0)
        });
        self.inflight.store(false, Ordering::Release);
        out
    }
}

/// Decode-step outputs (host side).
#[derive(Clone, Debug)]
pub struct DecodeOut {
    pub logits: HostTensorF32,  // [B, V]
    pub k_new: HostTensorF32,   // [L, B, Hkv, D]
    pub v_new: HostTensorF32,   // [L, B, Hkv, D]
    pub probs: HostTensorF32,   // [L, B, Hq, C]
}

/// Prefill outputs (host side).
#[derive(Clone, Debug)]
pub struct PrefillOut {
    pub logits: HostTensorF32,  // [1, V]
    pub k_all: HostTensorF32,   // [L, 1, Hkv, T, D]
    pub v_all: HostTensorF32,   // [L, 1, Hkv, T, D]
    pub scores: HostTensorF32,  // [L, 1, Hq, T]
}

impl Runtime {
    /// Create the PJRT CPU client, parse the manifest, upload weights.
    pub fn load(artifacts_dir: &std::path::Path) -> Result<Runtime> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        let meta = ModelMeta::load(artifacts_dir)?;
        let weights = Weights::load(&client, &meta)?;
        crate::log_info!(
            "runtime up: platform={} model={} params ({})",
            client.platform_name(),
            weights.param_count(),
            meta.dims.weights_source
        );
        Ok(Runtime {
            client,
            meta,
            weights,
            exes: RefCell::new(HashMap::new()),
            compile_log: RefCell::new(Vec::new()),
            executor: RefCell::new(None),
            inflight: Arc::new(AtomicBool::new(false)),
        })
    }

    /// Abort if an async decode is still in flight: using the runtime
    /// (or the tensors the job points into) concurrently is UB. The
    /// engine's `sync_runtime` guarantees this never fires in practice.
    fn assert_idle(&self) {
        assert!(
            !self.inflight.load(Ordering::Acquire),
            "runtime entered while an async decode is in flight — \
             DecodeHandle::wait() must run first"
        );
    }

    /// Sender to the (lazily spawned) executor thread.
    fn executor_tx(&self) -> Sender<ExecJob> {
        let mut slot = self.executor.borrow_mut();
        if slot.is_none() {
            let (tx, rx) = channel::<ExecJob>();
            let join = std::thread::Builder::new()
                .name("lethe-decode-exec".into())
                .spawn(move || {
                    while let Ok(job) = rx.recv() {
                        job();
                    }
                })
                .expect("spawning decode executor thread");
            *slot = Some(DecodeExecutor { tx: Some(tx), join: Some(join) });
        }
        slot.as_ref().unwrap().tx.as_ref().unwrap().clone()
    }

    /// Compile (or fetch cached) an executable by manifest name.
    fn exe_for(&self, name: &str) -> Result<()> {
        if self.exes.borrow().contains_key(name) {
            return Ok(());
        }
        let spec = self
            .meta
            .executables
            .get(name)
            .ok_or_else(|| anyhow!(
                "executable '{name}' not in manifest — rebuild artifacts"))?;
        let path = self.meta.dir.join(&spec.file);
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let dt = t0.elapsed().as_secs_f64();
        crate::log_info!("compiled {name} in {dt:.2}s");
        self.compile_log.borrow_mut().push((name.to_string(), dt));
        self.exes.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Eagerly compile every executable needed for a profile (avoids
    /// first-request latency spikes; called by the server at startup).
    pub fn warmup(&self, names: &[String]) -> Result<()> {
        for n in names {
            self.exe_for(n)?;
        }
        Ok(())
    }

    pub fn compile_log(&self) -> Vec<(String, f64)> {
        self.compile_log.borrow().clone()
    }

    fn run(&self, name: &str, extra: &[PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        self.exe_for(name)?;
        let exes = self.exes.borrow();
        let exe = exes.get(name).unwrap();
        let mut args: Vec<&PjRtBuffer> =
            self.weights.buffers.iter().collect();
        args.extend(extra.iter());
        let out = exe
            .execute_b(&args)
            .with_context(|| format!("executing {name}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching {name} outputs"))?;
        Ok(lit.to_tuple()?)
    }

    /// Run `decode_b{B}_c{C}`.
    ///
    /// kv_k/kv_v [L,B,Hkv,C,D], lens [L,B], tokens [B], positions [B].
    pub fn decode(
        &self,
        batch: usize,
        capacity: usize,
        kv_k: &HostTensorF32,
        kv_v: &HostTensorF32,
        lens: &HostTensorI32,
        tokens: &[i32],
        positions: &[i32],
    ) -> Result<DecodeOut> {
        self.assert_idle();
        self.decode_inner(batch, capacity, kv_k, kv_v, lens, tokens, positions)
    }

    fn decode_inner(
        &self,
        batch: usize,
        capacity: usize,
        kv_k: &HostTensorF32,
        kv_v: &HostTensorF32,
        lens: &HostTensorI32,
        tokens: &[i32],
        positions: &[i32],
    ) -> Result<DecodeOut> {
        let name = format!("decode_b{batch}_c{capacity}");
        let extra = vec![
            kv_k.upload(&self.client)?,
            kv_v.upload(&self.client)?,
            lens.upload(&self.client)?,
            self.client
                .buffer_from_host_buffer(tokens, &[batch], None)?,
            self.client
                .buffer_from_host_buffer(positions, &[batch], None)?,
        ];
        let mut outs = self.run(&name, &extra)?;
        anyhow::ensure!(outs.len() == 4, "decode returned {}", outs.len());
        let probs = HostTensorF32::from_literal(&outs.pop().unwrap())?;
        let v_new = HostTensorF32::from_literal(&outs.pop().unwrap())?;
        let k_new = HostTensorF32::from_literal(&outs.pop().unwrap())?;
        let logits = HostTensorF32::from_literal(&outs.pop().unwrap())?;
        Ok(DecodeOut { logits, k_new, v_new, probs })
    }

    /// Whether the manifest carries an executable named `name`. The
    /// engine probes this before routing a step down the packed or
    /// incremental path, so old artifact sets (without the `_q8` /
    /// `_q4` / `_kv` variants) degrade to the f32 / whole-prefix paths
    /// instead of erroring.
    pub fn has_executable(&self, name: &str) -> bool {
        self.meta.executables.contains_key(name)
    }

    /// Run `decode_b{B}_c{C}_q8` / `_q4` — kernel-side dequant. The KV
    /// operands are the quantized stores' wire bytes straight from a
    /// [`PackedScratch`] (codes + scales, + zeros for q4); the
    /// executable dequantizes on-device, so the host never materializes
    /// the 4·D f32 image.
    pub fn decode_packed(
        &self,
        batch: usize,
        capacity: usize,
        scratch: &PackedScratch,
        tokens: &[i32],
        positions: &[i32],
    ) -> Result<DecodeOut> {
        self.assert_idle();
        self.decode_packed_inner(batch, capacity, scratch, tokens, positions)
    }

    fn decode_packed_inner(
        &self,
        batch: usize,
        capacity: usize,
        scratch: &PackedScratch,
        tokens: &[i32],
        positions: &[i32],
    ) -> Result<DecodeOut> {
        let fmt = scratch.format();
        let name = match fmt {
            KvFormat::QuantI8 => format!("decode_b{batch}_c{capacity}_q8"),
            KvFormat::QuantI4 => format!("decode_b{batch}_c{capacity}_q4"),
            KvFormat::F32 => anyhow::bail!(
                "decode_packed needs a quantized scratch, got f32"),
        };
        let mut extra = Vec::with_capacity(9);
        match fmt {
            // q8 codes are i8 on the wire (two's-complement bit
            // patterns of the stored u8 bytes).
            KvFormat::QuantI8 => {
                extra.push(scratch.k_codes.upload_i8(&self.client)?);
                extra.push(scratch.k_scales.upload(&self.client)?);
                extra.push(scratch.v_codes.upload_i8(&self.client)?);
                extra.push(scratch.v_scales.upload(&self.client)?);
            }
            KvFormat::QuantI4 => {
                extra.push(scratch.k_codes.upload(&self.client)?);
                extra.push(scratch.k_scales.upload(&self.client)?);
                extra.push(scratch.k_zeros.upload(&self.client)?);
                extra.push(scratch.v_codes.upload(&self.client)?);
                extra.push(scratch.v_scales.upload(&self.client)?);
                extra.push(scratch.v_zeros.upload(&self.client)?);
            }
            KvFormat::F32 => unreachable!(),
        }
        extra.push(scratch.lens.upload(&self.client)?);
        extra.push(self.client
            .buffer_from_host_buffer(tokens, &[batch], None)?);
        extra.push(self.client
            .buffer_from_host_buffer(positions, &[batch], None)?);
        let mut outs = self.run(&name, &extra)?;
        anyhow::ensure!(outs.len() == 4, "decode returned {}", outs.len());
        let probs = HostTensorF32::from_literal(&outs.pop().unwrap())?;
        let v_new = HostTensorF32::from_literal(&outs.pop().unwrap())?;
        let k_new = HostTensorF32::from_literal(&outs.pop().unwrap())?;
        let logits = HostTensorF32::from_literal(&outs.pop().unwrap())?;
        Ok(DecodeOut { logits, k_new, v_new, probs })
    }

    /// Submit a `decode_b{B}_c{C}` step to the executor thread and
    /// return immediately. The caller owns the handoff protocol: until
    /// [`DecodeHandle::wait`] returns, the runtime must not be entered
    /// again and `kv_k`/`kv_v`/`lens` must not move or change (in the
    /// engine they live in the upload-scratch double buffer whose other
    /// half the next pack writes — that is the whole point).
    #[allow(clippy::too_many_arguments)]
    pub fn decode_submit(
        &self,
        batch: usize,
        capacity: usize,
        kv_k: &HostTensorF32,
        kv_v: &HostTensorF32,
        lens: &HostTensorI32,
        tokens: Vec<i32>,
        positions: Vec<i32>,
    ) -> DecodeHandle {
        self.assert_idle();
        self.inflight.store(true, Ordering::Release);
        let tx = self.executor_tx();
        let (res_tx, res_rx) = channel();
        let rt = SendPtr(self as *const Runtime);
        let k = SendPtr(kv_k as *const HostTensorF32);
        let v = SendPtr(kv_v as *const HostTensorF32);
        let l = SendPtr(lens as *const HostTensorI32);
        tx.send(Box::new(move || {
            let t0 = Instant::now();
            // SAFETY: the inflight flag serializes every runtime entry
            // point against this job, and the engine pins the pointed-at
            // tensors (no scratch-map mutation) until wait() returns.
            let out = unsafe {
                (*rt.0).decode_inner(
                    batch, capacity, &*k.0, &*v.0, &*l.0, &tokens, &positions,
                )
            };
            let _ = res_tx.send((out, t0.elapsed().as_secs_f64()));
        }))
        .expect("decode executor channel closed");
        DecodeHandle { rx: res_rx, inflight: self.inflight.clone() }
    }

    /// Quantized-path twin of [`Runtime::decode_submit`], wrapping
    /// [`Runtime::decode_packed`]. Same handoff protocol, with the
    /// pinned operand being the whole [`PackedScratch`].
    pub fn decode_packed_submit(
        &self,
        batch: usize,
        capacity: usize,
        scratch: &PackedScratch,
        tokens: Vec<i32>,
        positions: Vec<i32>,
    ) -> DecodeHandle {
        self.assert_idle();
        self.inflight.store(true, Ordering::Release);
        let tx = self.executor_tx();
        let (res_tx, res_rx) = channel();
        let rt = SendPtr(self as *const Runtime);
        let s = SendPtr(scratch as *const PackedScratch);
        tx.send(Box::new(move || {
            let t0 = Instant::now();
            // SAFETY: see decode_submit.
            let out = unsafe {
                (*rt.0).decode_packed_inner(
                    batch, capacity, &*s.0, &tokens, &positions,
                )
            };
            let _ = res_tx.send((out, t0.elapsed().as_secs_f64()));
        }))
        .expect("decode executor channel closed");
        DecodeHandle { rx: res_rx, inflight: self.inflight.clone() }
    }

    /// Run `prefill_t{T}_kv` — incremental prefill over a prior prefix.
    ///
    /// `prior_k`/`prior_v` are `[L, 1, Hkv, PREFILL_KV_CAP, D]` windows
    /// holding `prior_len` valid rows; `tokens` is this chunk (padded to
    /// the bucket). Outputs: `k_all`/`v_all` carry only the **chunk's**
    /// new rows `[L, 1, Hkv, T, D]`, and `scores` is the concatenated
    /// `[L, 1, Hq, PREFILL_KV_CAP + T]` attention mass over
    /// [prior | chunk] keys for RASR accumulation.
    pub fn prefill_kv(
        &self,
        bucket: usize,
        prior_k: &HostTensorF32,
        prior_v: &HostTensorF32,
        prior_len: i32,
        tokens: &[i32],
    ) -> Result<PrefillOut> {
        self.assert_idle();
        anyhow::ensure!(
            tokens.len() <= bucket,
            "chunk of {} tokens exceeds bucket {bucket}",
            tokens.len()
        );
        let name = format!("prefill_t{bucket}_kv");
        let mut padded = tokens.to_vec();
        padded.resize(bucket, 0); // PAD id = 0
        let extra = vec![
            prior_k.upload(&self.client)?,
            prior_v.upload(&self.client)?,
            scalar_i32(&self.client, prior_len)?,
            self.client
                .buffer_from_host_buffer(&padded, &[1, bucket], None)?,
            scalar_i32(&self.client, tokens.len() as i32)?,
        ];
        let mut outs = self.run(&name, &extra)?;
        anyhow::ensure!(outs.len() == 4, "prefill_kv returned {}", outs.len());
        let scores = HostTensorF32::from_literal(&outs.pop().unwrap())?;
        let v_all = HostTensorF32::from_literal(&outs.pop().unwrap())?;
        let k_all = HostTensorF32::from_literal(&outs.pop().unwrap())?;
        let logits = HostTensorF32::from_literal(&outs.pop().unwrap())?;
        Ok(PrefillOut { logits, k_all, v_all, scores })
    }

    /// Run `prefill_t{T}`; tokens are padded to the bucket size.
    pub fn prefill(&self, bucket: usize, tokens: &[i32]) -> Result<PrefillOut> {
        self.assert_idle();
        anyhow::ensure!(
            tokens.len() <= bucket,
            "prompt of {} tokens exceeds bucket {bucket}",
            tokens.len()
        );
        let name = format!("prefill_t{bucket}");
        let mut padded = tokens.to_vec();
        padded.resize(bucket, 0); // PAD id = 0
        let extra = vec![
            self.client
                .buffer_from_host_buffer(&padded, &[1, bucket], None)?,
            scalar_i32(&self.client, tokens.len() as i32)?,
        ];
        let mut outs = self.run(&name, &extra)?;
        anyhow::ensure!(outs.len() == 4, "prefill returned {}", outs.len());
        let scores = HostTensorF32::from_literal(&outs.pop().unwrap())?;
        let v_all = HostTensorF32::from_literal(&outs.pop().unwrap())?;
        let k_all = HostTensorF32::from_literal(&outs.pop().unwrap())?;
        let logits = HostTensorF32::from_literal(&outs.pop().unwrap())?;
        Ok(PrefillOut { logits, k_all, v_all, scores })
    }

    /// Smallest compiled prefill bucket that fits `n` tokens.
    pub fn prefill_bucket(&self, n: usize) -> Result<usize> {
        self.meta
            .prefill_ts
            .iter()
            .copied()
            .filter(|&t| t >= n)
            .min()
            .ok_or_else(|| anyhow!(
                "prompt of {n} tokens exceeds largest prefill bucket {:?}",
                self.meta.prefill_ts.iter().max()))
    }

    /// Smallest compiled decode capacity >= `need` for a profile.
    pub fn capacity_bucket(&self, profile: &str, need: usize) -> Result<usize> {
        let caps = self
            .meta
            .decode_capacities
            .get(profile)
            .ok_or_else(|| anyhow!("unknown profile '{profile}'"))?;
        caps.iter()
            .copied()
            .filter(|&c| c >= need)
            .min()
            .ok_or_else(|| anyhow!(
                "cache length {need} exceeds max capacity {:?} — OOM",
                caps.iter().max()))
    }

    /// Compiled decode batch sizes for a profile (ascending).
    pub fn batch_buckets(&self, profile: &str) -> Vec<usize> {
        let mut b = self
            .meta
            .decode_batches
            .get(profile)
            .cloned()
            .unwrap_or_default();
        b.sort_unstable();
        b
    }
}

impl Drop for Runtime {
    fn drop(&mut self) {
        // Close the job channel and join the executor. A still-running
        // job is safe here: `self`'s fields outlive this body, and the
        // job's result send into a dropped handle is simply discarded.
        if let Some(mut ex) = self.executor.borrow_mut().take() {
            drop(ex.tx.take());
            if let Some(j) = ex.join.take() {
                let _ = j.join();
            }
        }
    }
}
