//! Deterministic fault injection for the serving stack.
//!
//! A [`FaultPlan`] is a seeded coin sequence consulted at the real
//! failure seams — KV insert, runtime execute, layer migration, tick
//! pacing, connection handling — so every recovery path in the engine
//! is drivable from a test, reproducibly. Two properties are load
//! bearing:
//!
//!   * **Determinism.** All draws come from one seeded
//!     [`Rng`](crate::util::prng::Rng), and every [`FaultPlan::trip`]
//!     call happens on single-threaded control flow (the engine decides
//!     *before* fanning out to slot workers which slot, if any, this
//!     step fails; the TCP accept loop decides per connection). Same
//!     seed + same request sequence ⇒ same injected faults.
//!   * **Zero cost when off.** The engine holds `Option<FaultPlan>`;
//!     with `faults.rate == 0` in the config the plan is `None` and the
//!     hot path pays one branch per tick.
//!
//! Configured through `faults.*` ([`crate::config::FaultsConfig`]) or
//! the `--fault-seed` / `--fault-rate` CLI flags.

use crate::config::FaultsConfig;
use crate::util::prng::Rng;

/// A seam where the plan can inject a failure.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSite {
    /// Fail one slot's KV insert during the post-decode pipeline.
    KvAlloc,
    /// Fail the whole runtime execute call for one step.
    RuntimeExecute,
    /// Fail a pending layer-format migration (it retries later).
    Migration,
    /// Stall the tick by `stall_ms` before executing (latency fault).
    TickStall,
    /// Drop a TCP connection after its first request (peer fault).
    ConnDrop,
    /// Panic a supervised decode-group worker mid-tick (the supervisor
    /// quarantines the group and rescues its sequences).
    GroupPanic,
    /// Stall a worker past `serving.tick_timeout_ms` so the supervisor's
    /// heartbeat watchdog quarantines it as hung.
    GroupStall,
}

/// Seeded fault plan: one PRNG, one probability per class of seam.
/// Construct with [`FaultPlan::from_config`]; `None` means injection is
/// disabled and costs nothing.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    rng: Rng,
    rate: f64,
    conn_drop_rate: f64,
    group_rate: f64,
    stall_ms: u64,
    /// Faults injected so far (mirrored into `EngineMetrics`).
    pub injected: u64,
}

/// Seed-spacing constant for [`FaultPlan::for_group`]: a large odd
/// multiplier keeps per-group schedules decorrelated while staying
/// deterministic in the base seed.
const GROUP_SEED_STRIDE: u64 = 0x9e37_79b9_7f4a_7c15;

impl FaultPlan {
    /// Build a plan from the config, or `None` when every rate is zero
    /// (the common production case).
    pub fn from_config(cfg: &FaultsConfig) -> Option<FaultPlan> {
        if !cfg.enabled() {
            return None;
        }
        Some(FaultPlan {
            rng: Rng::new(cfg.seed),
            rate: cfg.rate,
            conn_drop_rate: cfg.conn_drop_rate,
            group_rate: cfg.group_rate,
            stall_ms: cfg.stall_ms,
            injected: 0,
        })
    }

    /// Build the group-scoped plan for worker `group`, or `None` when
    /// `faults.group_rate` is zero. Each worker draws from its own
    /// seeded stream (base seed offset by the group id) so the engine
    /// seams' schedule is untouched and groups fail independently yet
    /// reproducibly.
    pub fn for_group(cfg: &FaultsConfig, group: usize) -> Option<FaultPlan> {
        if cfg.group_rate <= 0.0 {
            return None;
        }
        let seed = cfg
            .seed
            .wrapping_add(GROUP_SEED_STRIDE.wrapping_mul(group as u64 + 1));
        Some(FaultPlan {
            rng: Rng::new(seed),
            rate: cfg.rate,
            conn_drop_rate: cfg.conn_drop_rate,
            group_rate: cfg.group_rate,
            stall_ms: cfg.stall_ms,
            injected: 0,
        })
    }

    /// Draw the next coin for `site`; true means "inject here". Must
    /// only be called from single-threaded control flow so the draw
    /// sequence — and therefore the whole fault schedule — is
    /// reproducible for a given seed.
    pub fn trip(&mut self, site: FaultSite) -> bool {
        let p = match site {
            FaultSite::ConnDrop => self.conn_drop_rate,
            FaultSite::GroupPanic | FaultSite::GroupStall => self.group_rate,
            _ => self.rate,
        };
        // Always consume a draw so enabling one site does not reshuffle
        // the schedule of the others.
        let hit = self.rng.bool(p);
        if hit {
            self.injected += 1;
        }
        hit
    }

    /// Deterministically pick a victim in `[0, n)` (e.g. which active
    /// slot receives an injected KV-alloc failure).
    pub fn pick(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        self.rng.below(n as u64) as usize
    }

    /// Raw (unreduced) victim draw for a stashed fault. The engine's
    /// pre-draw protocol stamps next step's whole fault triple at the
    /// end of the current step, when the next step's batch size is not
    /// known yet; the consumer reduces this modulo the then-live batch
    /// size. One fixed-width draw regardless of `n` keeps the RNG
    /// stream identical between the serial and pipelined decode paths.
    pub fn pick_raw(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Stall duration for [`FaultSite::TickStall`] injections.
    pub fn stall_ms(&self) -> u64 {
        self.stall_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(seed: u64, rate: f64) -> FaultsConfig {
        FaultsConfig {
            seed,
            rate,
            stall_ms: 0,
            conn_drop_rate: 0.0,
            group_rate: 0.0,
        }
    }

    #[test]
    fn disabled_config_yields_no_plan() {
        assert!(FaultPlan::from_config(&cfg(1, 0.0)).is_none());
        let c = FaultsConfig {
            conn_drop_rate: 0.5,
            ..cfg(1, 0.0)
        };
        assert!(FaultPlan::from_config(&c).is_some());
    }

    #[test]
    fn same_seed_same_schedule() {
        let mut a = FaultPlan::from_config(&cfg(42, 0.3)).unwrap();
        let mut b = FaultPlan::from_config(&cfg(42, 0.3)).unwrap();
        for i in 0..200 {
            let site = match i % 4 {
                0 => FaultSite::KvAlloc,
                1 => FaultSite::RuntimeExecute,
                2 => FaultSite::Migration,
                _ => FaultSite::TickStall,
            };
            assert_eq!(a.trip(site), b.trip(site));
        }
        assert_eq!(a.injected, b.injected);
    }

    #[test]
    fn rate_one_always_trips_and_counts() {
        let mut p = FaultPlan::from_config(&cfg(7, 1.0)).unwrap();
        for _ in 0..32 {
            assert!(p.trip(FaultSite::KvAlloc));
        }
        assert_eq!(p.injected, 32);
        // conn_drop_rate is 0: that seam never fires, but still draws.
        assert!(!p.trip(FaultSite::ConnDrop));
    }

    #[test]
    fn group_sites_draw_from_group_rate_only() {
        // Engine plan with group_rate 0: group sites never fire but
        // still consume a draw, so enabling them elsewhere does not
        // reshuffle this schedule.
        let mut p = FaultPlan::from_config(&cfg(3, 1.0)).unwrap();
        assert!(!p.trip(FaultSite::GroupPanic));
        assert!(!p.trip(FaultSite::GroupStall));
        assert!(p.trip(FaultSite::KvAlloc));

        // group_rate 1 trips every group draw.
        let c = FaultsConfig { group_rate: 1.0, ..cfg(3, 0.0) };
        let mut g = FaultPlan::for_group(&c, 0).unwrap();
        assert!(g.trip(FaultSite::GroupPanic));
        assert!(g.trip(FaultSite::GroupStall));
        assert!(!g.trip(FaultSite::KvAlloc), "rate stays 0 on engine seams");
    }

    #[test]
    fn group_plans_are_seeded_per_group_and_deterministic() {
        let c = FaultsConfig { group_rate: 0.4, ..cfg(11, 0.0) };
        assert!(FaultPlan::for_group(&cfg(11, 0.5), 0).is_none(),
                "no group plan when group_rate is 0");
        let draws = |g: usize| {
            let mut p = FaultPlan::for_group(&c, g).unwrap();
            (0..64).map(|_| p.trip(FaultSite::GroupPanic)).collect::<Vec<_>>()
        };
        assert_eq!(draws(0), draws(0), "same seed+group => same schedule");
        assert_ne!(draws(0), draws(1), "groups draw decorrelated streams");
    }

    #[test]
    fn pick_is_in_range() {
        let mut p = FaultPlan::from_config(&cfg(9, 1.0)).unwrap();
        for n in 1..16 {
            for _ in 0..8 {
                assert!(p.pick(n) < n);
            }
        }
    }
}
