//! Engine/serving telemetry: step-phase timings, token counters, prune
//! accounting, capacity-bucket usage. Everything the benches print comes
//! from here, serialisable to JSON for the experiment logs.

use std::collections::BTreeMap;

use crate::kvcache::KvFormat;
use crate::util::json::Json;
use crate::util::stats::{P2Quantile, StreamStat, Summary};

/// Streaming per-tenant-class SLO accounting. One track per distinct
/// [`crate::scheduler::Completion::class`] label (empty labels fold
/// into `"default"`). Latency percentiles are P² streaming estimates
/// ([`P2Quantile`]): O(1) memory per (class, metric, quantile)
/// regardless of how many requests the soak replays.
pub struct ClassTrack {
    pub class: String,
    /// Terminal outcomes folded in (completed + aborted).
    pub requests: u64,
    /// Finished with `Eos` or `Length`.
    pub completed: u64,
    /// Finished with `Oom`, `DeadlineExceeded`, or `Error(..)`.
    pub aborted: u64,
    /// Output tokens across completed-or-aborted requests.
    pub generated_tokens: u64,
    /// Preempt-and-resume round trips summed over requests.
    pub preemptions: u64,
    ttft: [P2Quantile; 3],
    tpot: [P2Quantile; 3],
    e2e: [P2Quantile; 3],
}

/// The three quantiles every latency track estimates.
const TRACK_QS: [f64; 3] = [0.50, 0.95, 0.99];

fn track_quantiles() -> [P2Quantile; 3] {
    [
        P2Quantile::new(TRACK_QS[0]),
        P2Quantile::new(TRACK_QS[1]),
        P2Quantile::new(TRACK_QS[2]),
    ]
}

impl ClassTrack {
    pub fn new(class: &str) -> ClassTrack {
        ClassTrack {
            class: class.to_string(),
            requests: 0,
            completed: 0,
            aborted: 0,
            generated_tokens: 0,
            preemptions: 0,
            ttft: track_quantiles(),
            tpot: track_quantiles(),
            e2e: track_quantiles(),
        }
    }

    fn record(&mut self, c: &crate::scheduler::Completion) {
        use crate::engine::FinishReason;
        self.requests += 1;
        match c.finish {
            FinishReason::Eos | FinishReason::Length => self.completed += 1,
            _ => self.aborted += 1,
        }
        self.generated_tokens += c.generated.len() as u64;
        self.preemptions += c.preemptions as u64;
        // TTFT only once a first token exists; TPOT only once the
        // inter-token gap is defined (≥ 2 tokens). E2E always.
        if !c.generated.is_empty() {
            for q in &mut self.ttft {
                q.push(c.ttft);
            }
        }
        if c.generated.len() >= 2 {
            for q in &mut self.tpot {
                q.push(c.tpot);
            }
        }
        for q in &mut self.e2e {
            q.push(c.total);
        }
    }

    pub fn ttft_p(&self, i: usize) -> f64 {
        self.ttft[i].value()
    }
    pub fn tpot_p(&self, i: usize) -> f64 {
        self.tpot[i].value()
    }
    pub fn e2e_p(&self, i: usize) -> f64 {
        self.e2e[i].value()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("class", Json::str(&self.class)),
            ("requests", Json::from(self.requests as usize)),
            ("completed", Json::from(self.completed as usize)),
            ("aborted", Json::from(self.aborted as usize)),
            (
                "generated_tokens",
                Json::from(self.generated_tokens as usize),
            ),
            ("preemptions", Json::from(self.preemptions as usize)),
            ("ttft_p50_s", Json::num(self.ttft[0].value())),
            ("ttft_p95_s", Json::num(self.ttft[1].value())),
            ("ttft_p99_s", Json::num(self.ttft[2].value())),
            ("tpot_p50_s", Json::num(self.tpot[0].value())),
            ("tpot_p95_s", Json::num(self.tpot[1].value())),
            ("tpot_p99_s", Json::num(self.tpot[2].value())),
            ("e2e_p50_s", Json::num(self.e2e[0].value())),
            ("e2e_p95_s", Json::num(self.e2e[1].value())),
            ("e2e_p99_s", Json::num(self.e2e[2].value())),
        ])
    }
}

#[derive(Default)]
pub struct EngineMetrics {
    /// Per-phase step timings as bounded streaming accumulators
    /// (count/sum/moments + P² percentiles). These used to be
    /// `Vec<f64>` pushed every step forever — an unbounded-memory leak
    /// on any long soak; the [`StreamStat`] replacements keep the same
    /// derived `stats` shape in O(1) memory.
    pub prefill_seconds: StreamStat,
    pub pack_seconds: StreamStat,
    pub exec_seconds: StreamStat,
    pub policy_seconds: StreamStat,
    /// Wall-clock of each whole decode step (result wait + critical
    /// lane + next-step pack/submit + deferred policy lane). Under
    /// pipelining this is the honest per-step cost: the exec of step
    /// t+1 overlaps the policy lane of step t, so `Σ step_seconds` can
    /// be well below `Σ pack + Σ exec + Σ policy`.
    pub step_seconds: StreamStat,
    /// Decode steps whose execute was pre-submitted at the end of the
    /// previous step and applied — i.e. the device ran concurrently
    /// with the previous step's deferred policy lane.
    pub pipeline_overlapped_steps: u64,
    /// Pipeline drains by reason: decode steps that fell back to the
    /// serial pack→execute→policy path, keyed by the boundary that
    /// forced it (`"policy_due"`, `"finish"`, `"fault"`,
    /// `"capacity_flip"`, `"variant_flip"`, `"composition"`,
    /// `"exec_err"`, `"cold"`).
    pub pipeline_drains: BTreeMap<&'static str, u64>,
    pub prefill_tokens: u64,
    pub decode_tokens: u64,
    pub decode_steps: u64,
    pub prune_events: u64,
    pub pruned_tokens: u64,
    pub ooms: u64,
    /// Recompute-preemptions: sequences evicted back to the waiting
    /// queue under co-residency pressure (scheduler lifecycle).
    pub preemptions: u64,
    /// Preempted sequences re-prefilled and returned to decoding.
    pub resumes: u64,
    /// Requests rejected by admission control (queue full / prompt
    /// beyond the largest prefill bucket).
    pub rejected: u64,
    /// Waiting-queue depth after the last scheduler tick.
    pub queue_depth_last: usize,
    /// Layer formats migrated in place on a live group
    /// (`GroupCache::migrate_layer_format`, driven by the scheduler
    /// from `kv.mixed` / `kv.layer_formats` resolution changes).
    pub kv_migrations: u64,
    /// Host bytes actually copied into upload scratch by delta-pack
    /// (K + V); a full per-step repack would be L·B·Hkv·C·D·8 every step.
    pub pack_bytes_copied: u64,
    /// What the same delta-packed rows would have cost at dense f32
    /// (`rows · Hkv · D · 4 · 2`). Equals `pack_bytes_copied` on the f32
    /// expansion path; on the packed (kernel-side dequant) path the
    /// `pack_bytes_f32_equiv / pack_bytes_copied` ratio is the measured
    /// upload-byte reduction.
    pub pack_bytes_f32_equiv: u64,
    /// Wire bytes of the full upload image the last decode step handed
    /// to the runtime (K + V [+ scales/zeros] + lens at the step's
    /// (batch, capacity) bucket) — the per-step upload cost the
    /// bench-smoke CI gate compares across KV formats.
    pub upload_bytes_last: usize,
    /// (layer, slot) pairs served by the delta path (append-only copy or
    /// pure residency skip) instead of a full re-copy.
    pub delta_pack_hits: u64,
    /// (layer, slot) pairs that needed a full re-copy (cold scratch,
    /// retention, swap, prefill or reset since last sync).
    pub delta_pack_full: u64,
    /// Faults deliberately injected by the seeded [`crate::fault`] plan
    /// (mirror of `FaultPlan::injected`; 0 when injection is off).
    pub faults_injected: u64,
    /// Sequences that finished with `FinishReason::Error(..)` — a
    /// per-slot failure (real or injected) isolated to that sequence
    /// instead of poisoning the whole tick.
    pub seq_failures: u64,
    /// Preemptions served by swap-to-host (KV serialized at stored
    /// precision) instead of drop-and-recompute.
    pub swap_preemptions: u64,
    /// Bytes of KV payload swapped out to host buffers.
    pub swap_bytes_out: u64,
    /// Bytes of KV payload restored from host buffers on resume.
    pub swap_bytes_in: u64,
    /// Requests aborted because their own `deadline_ms` expired.
    pub deadline_aborts: u64,
    /// Requests aborted because the shutdown drain window closed.
    pub drain_aborts: u64,
    /// Quarantined decode groups restarted by the supervisor.
    pub group_restarts: u64,
    /// Decode groups moved to `Quarantined` (panic, stall, or sustained
    /// tick errors).
    pub group_quarantines: u64,
    /// Sequences rescued off a quarantined group onto a healthy peer.
    pub rescued_seqs: u64,
    /// Host bytes of KV images carried by rescued sequences (subset of
    /// swap traffic attributable to cross-group rescue).
    pub rescue_bytes: u64,
    pub live_bytes_last: usize,
    /// What `live_bytes_last` would cost at f32 (Table 2's
    /// "f32-equivalent" column; equals `live_bytes_last` on the dense
    /// backend).
    pub f32_equiv_bytes_last: usize,
    /// KV storage label the last decode step served with ("f32" | "q8" |
    /// "q4" | "mixed"; empty before the first step).
    pub kv_format: String,
    /// Per-layer storage formats of the last-served group (index =
    /// layer) — the full picture behind a "mixed" label, and what makes
    /// the varying per-layer byte rates of Table 2 auditable.
    pub kv_layer_formats: Vec<KvFormat>,
    /// decode capacity bucket -> steps run at that bucket.
    pub capacity_hist: BTreeMap<usize, u64>,
    /// Per-tenant-class SLO tracks, first-seen order. Fed by
    /// [`EngineMetrics::record_completion`] — the scheduler folds every
    /// tick's completions in once, so the tracks cover terminal
    /// outcomes exactly (including deadline aborts).
    pub classes: Vec<ClassTrack>,
}

impl EngineMetrics {
    pub fn reset(&mut self) {
        *self = EngineMetrics::default();
    }

    /// Seconds the engine actually spent per decode step: measured
    /// step wall time when available, the per-phase sum otherwise
    /// (the two agree on the serial path; under pipelining the phase
    /// sum double-counts the overlapped exec/policy window).
    fn step_total_seconds(&self) -> f64 {
        if self.step_seconds.count() > 0 {
            self.step_seconds.sum()
        } else {
            self.pack_seconds.sum()
                + self.exec_seconds.sum()
                + self.policy_seconds.sum()
        }
    }

    pub fn step_seconds_mean(&self) -> f64 {
        if self.step_seconds.count() > 0 {
            return self.step_seconds.sum() / self.step_seconds.count() as f64;
        }
        if self.exec_seconds.count() == 0 {
            return 0.0;
        }
        self.step_total_seconds() / self.exec_seconds.count() as f64
    }

    /// Decode throughput over the measured window (tokens / second of
    /// engine step time).
    pub fn decode_tput(&self) -> f64 {
        let secs = self.step_total_seconds();
        if secs == 0.0 {
            0.0
        } else {
            self.decode_tokens as f64 / secs
        }
    }

    /// Count one pipeline drain under `reason` (a drain boundary label
    /// from the [`EngineMetrics::pipeline_drains`] key set).
    pub fn note_drain(&mut self, reason: &'static str) {
        *self.pipeline_drains.entry(reason).or_insert(0) += 1;
    }

    /// Fold one terminal outcome into its tenant class's streaming SLO
    /// track (empty class labels fold into `"default"`).
    pub fn record_completion(&mut self, c: &crate::scheduler::Completion) {
        let label = if c.class.is_empty() { "default" } else { &c.class };
        let track = match
            self.classes.iter_mut().find(|t| t.class == label)
        {
            Some(t) => t,
            None => {
                self.classes.push(ClassTrack::new(label));
                self.classes.last_mut().unwrap()
            }
        };
        track.record(c);
    }

    /// Per-phase (pack, exec, policy) timing snapshots in the batch
    /// [`Summary`] shape; `None` before the first decode step. The
    /// percentiles are P² streaming estimates (exact below five steps).
    pub fn phase_summaries(&self) -> Option<(Summary, Summary, Summary)> {
        if self.exec_seconds.count() == 0 {
            return None;
        }
        Some((
            self.pack_seconds.summary(),
            self.exec_seconds.summary(),
            self.policy_seconds.summary(),
        ))
    }

    pub fn to_json(&self) -> Json {
        let mut caps = Vec::new();
        // The histogram is pre-seeded with every compiled capacity
        // bucket (so the hot path never allocates a map entry); only
        // buckets that actually served a step are reported.
        for (c, n) in &self.capacity_hist {
            if *n == 0 {
                continue;
            }
            caps.push(Json::obj(vec![
                ("capacity", Json::from(*c)),
                ("steps", Json::from(*n as usize)),
            ]));
        }
        let drains = Json::obj(
            self.pipeline_drains
                .iter()
                .map(|(k, v)| (*k, Json::from(*v as usize)))
                .collect(),
        );
        Json::obj(vec![
            ("decode_steps", Json::from(self.decode_steps as usize)),
            ("decode_tokens", Json::from(self.decode_tokens as usize)),
            ("prefill_tokens", Json::from(self.prefill_tokens as usize)),
            ("prune_events", Json::from(self.prune_events as usize)),
            ("pruned_tokens", Json::from(self.pruned_tokens as usize)),
            ("ooms", Json::from(self.ooms as usize)),
            ("preemptions", Json::from(self.preemptions as usize)),
            ("resumes", Json::from(self.resumes as usize)),
            ("rejected", Json::from(self.rejected as usize)),
            ("queue_depth", Json::from(self.queue_depth_last)),
            ("kv_migrations", Json::from(self.kv_migrations as usize)),
            ("pack_bytes_copied", Json::from(self.pack_bytes_copied as usize)),
            (
                "pack_bytes_f32_equiv",
                Json::from(self.pack_bytes_f32_equiv as usize),
            ),
            ("upload_bytes_last", Json::from(self.upload_bytes_last)),
            ("delta_pack_hits", Json::from(self.delta_pack_hits as usize)),
            ("delta_pack_full", Json::from(self.delta_pack_full as usize)),
            ("faults_injected", Json::from(self.faults_injected as usize)),
            ("seq_failures", Json::from(self.seq_failures as usize)),
            ("swap_preemptions", Json::from(self.swap_preemptions as usize)),
            ("swap_bytes_out", Json::from(self.swap_bytes_out as usize)),
            ("swap_bytes_in", Json::from(self.swap_bytes_in as usize)),
            ("deadline_aborts", Json::from(self.deadline_aborts as usize)),
            ("drain_aborts", Json::from(self.drain_aborts as usize)),
            ("group_restarts", Json::from(self.group_restarts as usize)),
            (
                "group_quarantines",
                Json::from(self.group_quarantines as usize),
            ),
            ("rescued_seqs", Json::from(self.rescued_seqs as usize)),
            ("rescue_bytes", Json::from(self.rescue_bytes as usize)),
            ("live_bytes_last", Json::from(self.live_bytes_last)),
            ("f32_equivalent_bytes", Json::from(self.f32_equiv_bytes_last)),
            ("kv_format", Json::str(&self.kv_format)),
            (
                "kv_layer_formats",
                Json::Arr(
                    self.kv_layer_formats
                        .iter()
                        .map(|f| Json::str(f.label()))
                        .collect(),
                ),
            ),
            ("decode_tput_tok_s", Json::num(self.decode_tput())),
            ("step_seconds_mean", Json::num(self.step_seconds_mean())),
            (
                "pipeline_overlapped_steps",
                Json::from(self.pipeline_overlapped_steps as usize),
            ),
            ("pipeline_drains", drains),
            ("capacity_hist", Json::Arr(caps)),
            (
                "classes",
                Json::Arr(
                    self.classes.iter().map(|t| t.to_json()).collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::FinishReason;
    use crate::scheduler::Completion;

    fn done(class: &str, n_tok: usize, ttft: f64, total: f64,
            finish: FinishReason) -> Completion {
        let tpot = if n_tok >= 2 {
            (total - ttft) / (n_tok - 1) as f64
        } else {
            0.0
        };
        Completion {
            id: 1,
            generated: vec![7; n_tok],
            finish,
            prompt_len: 4,
            ttft,
            tpot,
            total,
            prune_rounds: 0,
            preemptions: 1,
            class: class.to_string(),
        }
    }

    #[test]
    fn class_tracks_split_by_label_and_classify_outcomes() {
        let mut m = EngineMetrics::default();
        m.record_completion(&done("interactive", 4, 0.1, 0.5,
                                  FinishReason::Eos));
        m.record_completion(&done("interactive", 0, 0.0, 2.5,
                                  FinishReason::DeadlineExceeded));
        m.record_completion(&done("batch", 8, 0.4, 2.0,
                                  FinishReason::Length));
        m.record_completion(&done("", 2, 0.2, 0.4, FinishReason::Eos));
        assert_eq!(m.classes.len(), 3);
        let inter = &m.classes[0];
        assert_eq!(inter.class, "interactive");
        assert_eq!((inter.requests, inter.completed, inter.aborted),
                   (2, 1, 1));
        assert_eq!(inter.generated_tokens, 4);
        assert_eq!(inter.preemptions, 2);
        // The aborted-before-first-token request must not drag TTFT to
        // zero: only the one real first token feeds the track.
        assert!((inter.ttft_p(0) - 0.1).abs() < 1e-9);
        // Both e2e samples feed in; p99 of {0.5, 2.5} is the max.
        assert!((inter.e2e_p(2) - 2.5).abs() < 1e-9);
        assert_eq!(m.classes[1].class, "batch");
        assert!((m.classes[1].tpot_p(0) - (2.0 - 0.4) / 7.0).abs() < 1e-9);
        assert_eq!(m.classes[2].class, "default",
                   "empty labels fold into a default track");
    }

    #[test]
    fn class_tracks_serialize_into_metrics_json() {
        let mut m = EngineMetrics::default();
        m.record_completion(&done("interactive", 3, 0.2, 0.8,
                                  FinishReason::Eos));
        let parsed =
            crate::util::json::parse(&m.to_json().to_string()).unwrap();
        let classes = parsed.get("classes").unwrap().as_arr().unwrap();
        assert_eq!(classes.len(), 1);
        let c = &classes[0];
        assert_eq!(c.get("class").unwrap().as_str().unwrap(),
                   "interactive");
        assert_eq!(c.get("requests").unwrap().as_usize().unwrap(), 1);
        assert_eq!(c.get("completed").unwrap().as_usize().unwrap(), 1);
        for key in ["ttft_p50_s", "ttft_p95_s", "ttft_p99_s",
                    "tpot_p50_s", "tpot_p95_s", "tpot_p99_s",
                    "e2e_p50_s", "e2e_p95_s", "e2e_p99_s"] {
            assert!(c.get(key).is_some(), "missing {key}");
        }
        assert!((c.get("e2e_p50_s").unwrap().as_f64().unwrap() - 0.8)
            .abs() < 1e-9);
    }

    #[test]
    fn throughput_accounts_all_phases() {
        let mut m = EngineMetrics::default();
        m.decode_tokens = 100;
        m.pack_seconds.push(0.5);
        m.exec_seconds.push(1.0);
        m.policy_seconds.push(0.5);
        // Serial fallback (no step wall time recorded): phase sums.
        assert!((m.decode_tput() - 50.0).abs() < 1e-9);
        assert!((m.step_seconds_mean() - 2.0).abs() < 1e-9);
        // With measured step wall time, throughput reflects the
        // overlap: exec hidden under policy makes the step cheaper
        // than the phase sum.
        m.step_seconds.push(1.0);
        assert!((m.decode_tput() - 100.0).abs() < 1e-9);
        assert!((m.step_seconds_mean() - 1.0).abs() < 1e-9);
        // Accumulators are bounded but keep exact counts and sums.
        for _ in 0..10_000 {
            m.exec_seconds.push(0.001);
        }
        assert_eq!(m.exec_seconds.count(), 10_001);
        let (_, exec, _) = m.phase_summaries().unwrap();
        assert_eq!(exec.n, 10_001);
    }

    #[test]
    fn pipeline_counters_serialize() {
        let mut m = EngineMetrics::default();
        m.pipeline_overlapped_steps = 42;
        m.note_drain("composition");
        m.note_drain("composition");
        m.note_drain("policy_due");
        let parsed =
            crate::util::json::parse(&m.to_json().to_string()).unwrap();
        assert_eq!(
            parsed
                .get("pipeline_overlapped_steps")
                .unwrap()
                .as_usize()
                .unwrap(),
            42
        );
        let d = parsed.get("pipeline_drains").unwrap();
        assert_eq!(d.get("composition").unwrap().as_usize().unwrap(), 2);
        assert_eq!(d.get("policy_due").unwrap().as_usize().unwrap(), 1);
        // Pre-seeded zero-count capacity buckets stay out of the JSON.
        m.capacity_hist.insert(128, 0);
        m.capacity_hist.insert(256, 3);
        let parsed =
            crate::util::json::parse(&m.to_json().to_string()).unwrap();
        let caps = parsed.get("capacity_hist").unwrap().as_arr().unwrap();
        assert_eq!(caps.len(), 1);
        assert_eq!(
            caps[0].get("capacity").unwrap().as_usize().unwrap(),
            256
        );
    }

    #[test]
    fn json_roundtrips() {
        let mut m = EngineMetrics::default();
        m.decode_steps = 3;
        m.pack_bytes_copied = 4096;
        m.pack_bytes_f32_equiv = 16384;
        m.upload_bytes_last = 9216;
        m.delta_pack_hits = 12;
        m.preemptions = 2;
        m.resumes = 2;
        m.rejected = 1;
        m.queue_depth_last = 5;
        m.kv_migrations = 3;
        m.faults_injected = 7;
        m.seq_failures = 2;
        m.swap_preemptions = 4;
        m.swap_bytes_out = 1024;
        m.swap_bytes_in = 1024;
        m.deadline_aborts = 1;
        m.drain_aborts = 1;
        m.group_restarts = 2;
        m.group_quarantines = 1;
        m.rescued_seqs = 3;
        m.rescue_bytes = 512;
        m.kv_format = "mixed".to_string();
        m.kv_layer_formats = vec![KvFormat::F32, KvFormat::QuantI4];
        m.f32_equiv_bytes_last = 2048;
        m.capacity_hist.insert(128, 2);
        m.capacity_hist.insert(256, 1);
        let j = m.to_json().to_string();
        let parsed = crate::util::json::parse(&j).unwrap();
        assert_eq!(parsed.get("decode_steps").unwrap().as_usize().unwrap(), 3);
        assert_eq!(
            parsed.get("pack_bytes_copied").unwrap().as_usize().unwrap(),
            4096
        );
        assert_eq!(
            parsed
                .get("pack_bytes_f32_equiv")
                .unwrap()
                .as_usize()
                .unwrap(),
            16384
        );
        assert_eq!(
            parsed.get("upload_bytes_last").unwrap().as_usize().unwrap(),
            9216
        );
        assert_eq!(
            parsed.get("delta_pack_hits").unwrap().as_usize().unwrap(),
            12
        );
        assert_eq!(parsed.get("preemptions").unwrap().as_usize().unwrap(), 2);
        assert_eq!(parsed.get("resumes").unwrap().as_usize().unwrap(), 2);
        assert_eq!(parsed.get("rejected").unwrap().as_usize().unwrap(), 1);
        assert_eq!(parsed.get("queue_depth").unwrap().as_usize().unwrap(), 5);
        assert_eq!(
            parsed.get("kv_migrations").unwrap().as_usize().unwrap(),
            3
        );
        assert_eq!(
            parsed.get("faults_injected").unwrap().as_usize().unwrap(),
            7
        );
        assert_eq!(parsed.get("seq_failures").unwrap().as_usize().unwrap(), 2);
        assert_eq!(
            parsed.get("swap_preemptions").unwrap().as_usize().unwrap(),
            4
        );
        assert_eq!(
            parsed.get("swap_bytes_out").unwrap().as_usize().unwrap(),
            1024
        );
        assert_eq!(
            parsed.get("swap_bytes_in").unwrap().as_usize().unwrap(),
            1024
        );
        assert_eq!(
            parsed.get("deadline_aborts").unwrap().as_usize().unwrap(),
            1
        );
        assert_eq!(parsed.get("drain_aborts").unwrap().as_usize().unwrap(), 1);
        assert_eq!(
            parsed.get("group_restarts").unwrap().as_usize().unwrap(),
            2
        );
        assert_eq!(
            parsed.get("group_quarantines").unwrap().as_usize().unwrap(),
            1
        );
        assert_eq!(parsed.get("rescued_seqs").unwrap().as_usize().unwrap(), 3);
        assert_eq!(parsed.get("rescue_bytes").unwrap().as_usize().unwrap(), 512);
        assert_eq!(
            parsed.get("capacity_hist").unwrap().as_arr().unwrap().len(),
            2
        );
        assert_eq!(
            parsed.get("kv_format").unwrap().as_str().unwrap(),
            "mixed"
        );
        let lf = parsed.get("kv_layer_formats").unwrap().as_arr().unwrap();
        assert_eq!(lf.len(), 2);
        assert_eq!(lf[0].as_str().unwrap(), "f32");
        assert_eq!(lf[1].as_str().unwrap(), "q4");
        assert_eq!(
            parsed
                .get("f32_equivalent_bytes")
                .unwrap()
                .as_usize()
                .unwrap(),
            2048
        );
    }
}
