//! Watch Lethe work on a single long reasoning trace: per-layer cache
//! lengths, adaptive thresholds, sparsity estimates and prune events,
//! printed live as the model decodes (the Figure 2/3 mechanics,
//! narrated).
//!
//!   cargo run --release --example reasoning_trace

use lethe::config::ServingConfig;
use lethe::engine::SeqState;
use lethe::policy::{make_policy, PolicyKind};
use lethe::util::prng::Rng;
use lethe::workload::make_task;

fn main() -> anyhow::Result<()> {
    let mut cfg = ServingConfig::default();
    cfg.lethe.evict_threshold = 64;
    let Some((mut engine, tok)) =
        lethe::bench_support::try_engine(cfg) else { return Ok(()) };
    let layers = engine.dims().n_layers;

    let task = make_task(&mut Rng::new(0x7ACE), 24, 4);
    println!("prompt  : {}", task.prompt);
    println!("expected: {}\n", task.answer);

    let prompt = tok.encode_prompt(&task.prompt)?;
    let mut group = engine.new_group(1, PolicyKind::Lethe);
    let seq = SeqState::new(
        0,
        make_policy(PolicyKind::Lethe, &engine.cfg, layers),
        layers,
        96,
        tok.eos,
    );
    engine.prefill(&mut group, 0, seq, &prompt)?;
    println!(
        "after prefill ({} tokens): per-layer cache lens = {:?}",
        prompt.len(),
        (0..layers).map(|l| group.cache.len(l, 0)).collect::<Vec<_>>()
    );

    let mut step = 0;
    let mut peak_len = 0usize;
    while group.active() > 0 {
        let before: Vec<usize> =
            (0..layers).map(|l| group.cache.len(l, 0)).collect();
        engine.step(&mut group)?;
        peak_len = peak_len.max(group.cache.max_len());
        step += 1;
        if group.active() > 0 {
            let after: Vec<usize> =
                (0..layers).map(|l| group.cache.len(l, 0)).collect();
            let pruned = before
                .iter()
                .zip(&after)
                .any(|(b, a)| a < &(b + 1));
            if pruned || step % 16 == 0 {
                let spars: Vec<String> = (0..layers)
                    .map(|l| format!("{:.2}", group.seq(0).sparsity.sparsity(l)))
                    .collect();
                println!(
                    "step {step:3}: lens={after:?} sparsity={spars:?}{}",
                    if pruned { "  <- PRUNED" } else { "" }
                );
            }
        }
        group.reap();
    }

    let done = &group.done[0];
    let text = tok.decode(&done.generated);
    println!("\noutput  : {text}");
    println!("finish  : {:?}", done.finished.unwrap());
    println!("\nprune log ({} rounds):", done.prune_log.len());
    for ev in &done.prune_log {
        println!(
            "  step {:3} layer {}: {} -> {} tokens",
            ev.step, ev.layer, ev.before, ev.after
        );
    }
    let (ok, strict) = lethe::eval::judge(&task, &text);
    println!("\ncorrect(final)={ok} correct(strict)={strict}");
    println!(
        "peak live KV would have been {} tokens/layer under FullKV; \
         Lethe's peak across layers was {peak_len}",
        done.abs_pos,
    );
    Ok(())
}
