//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): serve a batched
//! chain-of-thought workload through the full production path —
//! router → admission → continuous-batching scheduler → PJRT decode
//! engine → Lethe pruning — and report accuracy, latency percentiles and
//! throughput, comparing Lethe against FullKV on the same trace.
//!
//!   make artifacts && cargo run --release --example serve_cot
//!
//! Env: SERVE_COT_N (requests, default 24), SERVE_COT_RATE (req/s, 8),
//!      SERVE_COT_BATCH (max batch, 8).

use std::time::Instant;

use lethe::config::ServingConfig;
use lethe::eval::judge;
use lethe::policy::PolicyKind;
use lethe::server::{GenerateRequest, Server};
use lethe::util::prng::Rng;
use lethe::util::stats::Summary;
use lethe::workload::poisson_trace;

fn env_usize(k: &str, d: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn run(policy: PolicyKind, n: usize, rate: f64, batch: usize)
    -> anyhow::Result<()>
{
    let mut cfg = ServingConfig::default();
    cfg.scheduler.max_batch = batch;
    cfg.lethe.evict_threshold = 48;
    cfg.baseline.budget = 48;
    let server = Server::start(cfg, policy)?;

    // Identical trace across policies (same seed).
    let mut rng = Rng::new(0xC07);
    let trace = poisson_trace(&mut rng, rate, n);

    let t0 = Instant::now();
    let mut inflight = Vec::new();
    for item in &trace {
        let wait = item.arrival_s - t0.elapsed().as_secs_f64();
        if wait > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(wait));
        }
        inflight.push((
            item.task.clone(),
            server.submit(GenerateRequest {
                prompt: item.task.prompt.clone(),
                max_new_tokens: 64,
                policy: None,
            })?,
        ));
    }
    let mut correct = 0usize;
    let mut chain_ok = 0usize;
    let mut gen_tokens = 0usize;
    let mut ttft = Vec::new();
    let mut e2e = Vec::new();
    let mut prune_rounds = 0usize;
    for (task, rx) in inflight {
        let r = rx.recv()??;
        let (ok, _) = judge(&task, &r.text);
        correct += ok as usize;
        chain_ok += lethe::eval::judge_chain(&task, &r.text) as usize;
        gen_tokens += r.generated_tokens;
        ttft.push(r.ttft_s);
        e2e.push(r.total_s);
        prune_rounds += r.prune_rounds;
    }
    let wall = t0.elapsed().as_secs_f64();
    let ts = Summary::of(&ttft);
    let te = Summary::of(&e2e);
    println!("--- {} ---", policy.label());
    println!(
        "  {n} reqs in {wall:.2}s -> {:.1} tok/s generated, {:.2} req/s",
        gen_tokens as f64 / wall,
        n as f64 / wall
    );
    println!(
        "  accuracy: chain {:.3}  final {:.3}",
        chain_ok as f64 / n as f64,
        correct as f64 / n as f64
    );
    println!(
        "  TTFT p50 {:.0}ms p99 {:.0}ms | E2E p50 {:.0}ms p99 {:.0}ms",
        ts.p50 * 1e3, ts.p99 * 1e3, te.p50 * 1e3, te.p99 * 1e3
    );
    println!("  prune rounds: {prune_rounds}");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let n = env_usize("SERVE_COT_N", 24);
    let rate = env_usize("SERVE_COT_RATE", 8) as f64;
    let batch = env_usize("SERVE_COT_BATCH", 8);
    println!(
        "serve_cot: {n} CoT requests, Poisson {rate} req/s, max batch {batch}"
    );
    run(PolicyKind::Lethe, n, rate, batch)?;
    run(PolicyKind::FullKv, n, rate, batch)?;
    Ok(())
}
