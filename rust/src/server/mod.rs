//! Request router / front door. Clients submit text prompts and receive
//! completions over channels; a dedicated engine thread owns the PJRT
//! runtime (it is not Sync) and runs the scheduler loop. This is the L3
//! "serving system" shell: validation, routing, per-request policy
//! override, graceful shutdown, latency accounting.

pub mod tcp;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::ServingConfig;
use crate::engine::Engine;
use crate::model::Tokenizer;
use crate::policy::PolicyKind;
use crate::runtime::Runtime;
use crate::scheduler::{Request, Scheduler};

#[derive(Clone, Debug)]
pub struct GenerateRequest {
    pub prompt: String,
    pub max_new_tokens: usize,
    /// None = server default policy.
    pub policy: Option<PolicyKind>,
    /// Wall-clock completion budget in milliseconds; past it the
    /// request finishes with `DeadlineExceeded` at the next tick
    /// boundary. None = no deadline.
    pub deadline_ms: Option<u64>,
}

#[derive(Clone, Debug)]
pub struct GenerateResponse {
    pub id: u64,
    pub text: String,
    pub finish: String,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    pub ttft_s: f64,
    pub total_s: f64,
    pub prune_rounds: usize,
    /// How many times the sequence was recompute-preempted under load
    /// (each resume re-prefilled prompt + generated; the continuation is
    /// the uncontended one).
    pub preemptions: u32,
    /// KV storage the request was served on ("f32" | "q8" | "q4", or
    /// "mixed" when a per-layer format map was active).
    pub kv_format: String,
}

enum Msg {
    Generate(GenerateRequest, Sender<Result<GenerateResponse>>),
    /// Serving-pressure snapshot (queue depth, preempt/resume counters,
    /// live migrations, engine metrics) — the `{"stats": true}` query.
    Stats(Sender<crate::util::json::Json>),
    Shutdown,
}

/// Handle to the serving thread.
pub struct Server {
    tx: Sender<Msg>,
    handle: Option<JoinHandle<()>>,
    next_id: AtomicU64,
    pub tokenizer: Tokenizer,
    /// Copy of the fault-injection config (the full config moves into
    /// the engine thread); the TCP front-end builds its connection-drop
    /// plan from it.
    pub faults: crate::config::FaultsConfig,
}

impl Server {
    /// Boot the engine thread: loads artifacts, warms the executables for
    /// the configured profile, then serves until shutdown.
    pub fn start(cfg: ServingConfig, default_policy: PolicyKind) -> Result<Server> {
        let rt_probe = crate::model::ModelMeta::load(
            std::path::Path::new(&cfg.artifacts_dir),
        )?;
        let tokenizer = Tokenizer::from_meta(&rt_probe)?;
        let (tx, rx) = mpsc::channel::<Msg>();
        let cfg2 = cfg.clone();
        let (boot_tx, boot_rx) = mpsc::channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("lethe-engine".into())
            .spawn(move || {
                engine_thread(cfg2, default_policy, rx, boot_tx);
            })
            .context("spawning engine thread")?;
        boot_rx
            .recv()
            .context("engine thread died during boot")??;
        Ok(Server {
            tx,
            handle: Some(handle),
            next_id: AtomicU64::new(1),
            tokenizer,
            faults: cfg.faults.clone(),
        })
    }

    /// Submit a request; returns a receiver for the completion.
    pub fn submit(
        &self,
        req: GenerateRequest,
    ) -> Result<Receiver<Result<GenerateResponse>>> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Generate(req, tx))
            .map_err(|_| anyhow::anyhow!("server is shut down"))?;
        Ok(rx)
    }

    /// Convenience: synchronous request/response.
    pub fn generate(&self, req: GenerateRequest) -> Result<GenerateResponse> {
        let rx = self.submit(req)?;
        rx.recv().context("engine thread dropped the request")?
    }

    /// Serving-pressure snapshot from the engine thread: queue depth,
    /// rejected/preemption/resume counts, live KV migrations, and the
    /// full engine metrics object.
    pub fn stats(&self) -> Result<crate::util::json::Json> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Stats(tx))
            .map_err(|_| anyhow::anyhow!("server is shut down"))?;
        rx.recv().context("engine thread dropped the stats query")
    }

    pub fn next_request_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

struct Pending {
    reply: Sender<Result<GenerateResponse>>,
    prompt_tokens: usize,
}

/// Poison-safe lock: a panic in some other thread while holding the map
/// must not wedge the serving loop — the plain `HashMap` inside is valid
/// regardless of where the panicking thread stopped, so recover the guard.
fn lock_pending(
    m: &Mutex<std::collections::HashMap<u64, Pending>>,
) -> std::sync::MutexGuard<'_, std::collections::HashMap<u64, Pending>> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn engine_thread(
    cfg: ServingConfig,
    default_policy: PolicyKind,
    rx: Receiver<Msg>,
    boot_tx: Sender<Result<()>>,
) {
    let boot = (|| -> Result<(Engine, Tokenizer)> {
        let rt = Runtime::load(std::path::Path::new(&cfg.artifacts_dir))?;
        let tok = Tokenizer::from_meta(&rt.meta)?;
        Ok((Engine::new(rt, cfg.clone())?, tok))
    })();
    let (mut engine, tok) = match boot {
        Ok(v) => {
            let _ = boot_tx.send(Ok(()));
            v
        }
        Err(e) => {
            let _ = boot_tx.send(Err(e));
            return;
        }
    };

    let mut sched = Scheduler::new(&engine, default_policy);
    let pending: Arc<Mutex<std::collections::HashMap<u64, Pending>>> =
        Arc::new(Mutex::new(std::collections::HashMap::new()));
    let mut next_id = 1u64;
    let mut shutdown = false;

    while !(shutdown && sched.idle()) {
        // Drain incoming messages; block only when fully idle.
        loop {
            let msg = if sched.idle() && !shutdown {
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => {
                        shutdown = true;
                        break;
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(_) => break,
                }
            };
            match msg {
                Msg::Shutdown => {
                    shutdown = true;
                    break;
                }
                Msg::Stats(reply) => {
                    let _ = reply.send(sched.stats_json(&engine));
                }
                Msg::Generate(req, reply) => {
                    let id = next_id;
                    next_id += 1;
                    match tok.encode_prompt(&req.prompt) {
                        Ok(prompt) => {
                            let r = Request {
                                id,
                                prompt,
                                max_new_tokens: req
                                    .max_new_tokens
                                    .min(engine.cfg.scheduler.max_new_tokens),
                                policy: req.policy.unwrap_or(default_policy),
                                submitted_at: Instant::now(),
                                deadline_ms: req.deadline_ms,
                            };
                            let ptoks = r.prompt.len();
                            if let Err(e) = sched.submit(r) {
                                let _ = reply.send(Err(e));
                            } else {
                                lock_pending(&pending).insert(
                                    id,
                                    Pending { reply, prompt_tokens: ptoks },
                                );
                            }
                        }
                        Err(e) => {
                            let _ = reply.send(Err(e));
                        }
                    }
                }
            }
        }

        // Entering shutdown with work in flight: stop admitting and give
        // running sequences a bounded drain window to finish.
        if shutdown && !sched.draining() {
            sched.begin_drain();
        }

        if sched.idle() {
            continue;
        }
        match sched.tick(&mut engine) {
            Ok(report) => {
                let kv_format = sched.kv_format();
                let mut p = lock_pending(&pending);
                for c in report.completed {
                    if let Some(entry) = p.remove(&c.id) {
                        let resp = GenerateResponse {
                            id: c.id,
                            text: tok.decode(&c.generated),
                            finish: format!("{:?}", c.finish),
                            prompt_tokens: entry.prompt_tokens,
                            generated_tokens: c.generated.len(),
                            ttft_s: c.ttft,
                            total_s: c.total,
                            prune_rounds: c.prune_rounds,
                            preemptions: c.preemptions,
                            kv_format: kv_format.clone(),
                        };
                        let _ = entry.reply.send(Ok(resp));
                    }
                }
            }
            Err(e) => {
                // A tick error means scheduler/cache state may be
                // inconsistent. Fail everything in flight, rebuild the
                // scheduler from scratch, and keep serving — the engine
                // (weights, executables) is still sound.
                crate::log_error!("scheduler tick failed: {e:#}");
                let mut p = lock_pending(&pending);
                for (_, entry) in p.drain() {
                    let _ = entry
                        .reply
                        .send(Err(anyhow::anyhow!("engine error: {e}")));
                }
                drop(p);
                let draining = sched.draining();
                sched = Scheduler::new(&engine, default_policy);
                if draining {
                    sched.begin_drain();
                }
            }
        }
    }
}
