pub mod util;
