//! Lethe: layer- and time-adaptive KV cache pruning for
//! reasoning-intensive LLM serving (AAAI 2026 reproduction).
//!
//! Three-layer architecture (see DESIGN.md):
//! - L3 (this crate): the serving coordinator — request router, continuous
//!   batching scheduler, per-layer KV-cache manager, and the paper's
//!   eviction policies (Lethe + FullKV/H2O/StreamingLLM/PyramidKV).
//! - L2/L1 (python/, build-time only): JAX GQA transformer + Pallas
//!   attention kernels, AOT-lowered to the HLO-text artifacts this crate
//!   loads via PJRT ([`runtime`]).
//!
//! Python never runs on the request path: after `make artifacts`, the
//! `lethe` binary and every example/bench is self-contained.

pub mod attn;
pub mod bench_support;
pub mod config;
pub mod engine;
pub mod error;
pub mod eval;
pub mod fault;
pub mod kvcache;
pub mod metrics;
pub mod model;
pub mod policy;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod sim;
pub mod supervisor;
pub mod util;
pub mod workload;

pub use config::LetheParams;
pub use policy::PolicyKind;
