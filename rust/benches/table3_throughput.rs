//! Table 3: decode throughput (tokens/s) across models and batch sizes,
//! FullKV vs Lethe, with OOM cells.
//!
//!   (a) A100 simulator, calibrated per model so FullKV batch-1 matches
//!       the paper's own column-1 number; everything else (batch scaling,
//!       the Lethe advantage, the OOM cells) is predicted from the real
//!       policy traces + roofline — not fitted.
//!   (b) Real measured decode throughput on the lethe-tiny engine: the
//!       mechanism (smaller retained cache → smaller capacity bucket →
//!       less upload + attention per step) measured for real.

use lethe::bench_support::{gen_tasks, kv_configs, print_table, run_churn,
                           run_tasks, try_engine, write_bench_json,
                           write_csv, BenchJsonRow};
use lethe::config::ServingConfig;
use lethe::model::DEEPSEEK_R1_DISTILL;
use lethe::policy::PolicyKind;
use lethe::sim::{run_trace, Simulator, TraceConfig};

const BATCHES: [usize; 5] = [1, 4, 8, 16, 32];
const GEN_LEN: usize = 20_000;
/// Paper Table 3 FullKV batch-1 tok/s (calibration anchors), matched to
/// DEEPSEEK_R1_DISTILL order: Qwen-7B, Qwen-32B, Llama-8B, Llama-70B.
const PAPER_B1: [f64; 4] = [33.1, 15.2, 30.1, 8.3];

fn main() -> anyhow::Result<()> {
    let mut cfg = ServingConfig::default();
    cfg.baseline.budget = 768;
    cfg.lethe.evict_threshold = 512;
    cfg.lethe.sink_len = 16;

    // ---- (a) simulated A100 section -----------------------------------
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for (arch, paper_b1) in DEEPSEEK_R1_DISTILL.iter().zip(PAPER_B1) {
        // FullKV context over the generation: prompt + t/2 on average.
        let full_mean = 512.0 + GEN_LEN as f64 / 2.0;
        let full_final = 512.0 + GEN_LEN as f64;
        let mut sim = Simulator::new(arch);
        sim.calibrate(full_mean, paper_b1);

        let tc = TraceConfig {
            n_layers: arch.n_layers,
            prompt_len: 512,
            gen_len: GEN_LEN,
            ..TraceConfig::default()
        };
        let lethe_tr = run_trace(PolicyKind::Lethe, &cfg, &tc);

        for (kind, mean, fin) in [
            (PolicyKind::FullKv, full_mean, full_final),
            (
                PolicyKind::Lethe,
                lethe_tr.mean_retained(),
                lethe_tr.final_retained(),
            ),
        ] {
            let mut row =
                vec![format!("{}/{}", short(arch.name), kind.label())];
            for b in BATCHES {
                let p = sim.point(b, mean, fin);
                row.push(if p.oom {
                    "OOM".into()
                } else {
                    format!("{:.1}", p.tok_per_s)
                });
                csv.push(format!(
                    "{},{},{},{:.2},{}",
                    arch.name,
                    kind.label(),
                    b,
                    p.tok_per_s,
                    p.oom
                ));
            }
            rows.push(row);
        }
    }
    print_table(
        &format!(
            "Table 3(a) — simulated throughput (tok/s), A100, \
             {GEN_LEN}-token CoT decode (batch-1 FullKV calibrated to paper)"
        ),
        &["model/policy", "b=1", "b=4", "b=8", "b=16", "b=32"],
        &rows,
    );
    write_csv("table3_tput_sim.csv", "model,policy,batch,tok_s,oom", &csv)?;

    // ---- (b) real engine section ---------------------------------------
    // Tiny-model-calibrated τ (see Table 6) so the capacity-bucket
    // mechanism engages within short generations. All four storage
    // configurations (f32, q8, q4, sparsity-directed mixed) run the
    // full serving path end-to-end (prefill → multi-round pruning →
    // delta-pack upload → completion); the quantized rows measure the
    // quantize-on-insert / dequantize-on-pack overhead in situ, and the
    // mixed rows exercise per-layer format maps resolved from the
    // engine's live sparsity estimates.
    cfg.baseline.budget = 48;
    cfg.lethe.evict_threshold = 48;
    cfg.lethe.sparse_ratio = 25.0;
    let Some((mut engine, tok)) = try_engine(cfg) else { return Ok(()) };
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    let mut jrows: Vec<BenchJsonRow> = Vec::new();
    for (label, kv) in kv_configs() {
        engine.cfg.kv = kv;
        for kind in [PolicyKind::FullKv, PolicyKind::Lethe] {
            let mut row = vec![format!("{}/{}", kind.label(), label)];
            for b in [1usize, 2, 4, 8] {
                // Long-ish multihop generations so pruning matters. First
                // a warmup pass (compiles the (B, C) executables — and,
                // for "mixed", seeds the engine's sparsity EMA so the
                // measured pass serves on the resolved map), then the
                // measured pass.
                let tasks = gen_tasks(100 + b as u64, 2 * b, 24, 4);
                let _ = run_tasks(&mut engine, &tok, kind, &tasks, b, 80)?;
                engine.metrics.reset();
                let st = run_tasks(&mut engine, &tok, kind, &tasks, b, 80)?;
                let tput = engine.metrics.decode_tput();
                let pairs = engine.metrics.delta_pack_hits
                    + engine.metrics.delta_pack_full;
                let hit_pct = if pairs == 0 {
                    0.0
                } else {
                    100.0 * engine.metrics.delta_pack_hits as f64
                        / pairs as f64
                };
                eprintln!(
                    "[delta-pack] {}/{} b={}: {:.0}% pair hit rate, \
                     {:.2}MB copied over the run (kv={})",
                    kind.label(),
                    label,
                    b,
                    hit_pct,
                    st.pack_bytes_copied as f64 / 1e6,
                    engine.metrics.kv_format
                );
                row.push(format!("{tput:.0}"));
                jrows.push(BenchJsonRow {
                    name: format!("decode_tput_{}_b{}", kind.label(), b),
                    kv_format: label.to_string(),
                    tokens_per_s: tput,
                    upload_bytes_per_step: engine
                        .metrics
                        .upload_bytes_last,
                    extra: Vec::new(),
                });
                csv.push(format!(
                    "{},{},{},{:.1},{:.1},{}",
                    kind.label(),
                    label,
                    b,
                    tput,
                    hit_pct,
                    st.pack_bytes_copied
                ));
            }
            rows.push(row);
        }
    }
    print_table(
        "Table 3(b) — measured decode throughput (tok/s), lethe-tiny engine",
        &["policy/kv", "b=1", "b=2", "b=4", "b=8"],
        &rows,
    );
    write_csv(
        "table3_tput_real.csv",
        "policy,kv_format,batch,tok_s,delta_hit_pct,pack_bytes",
        &csv,
    )?;
    write_bench_json("table3", &jrows)?;

    // ---- (c) sustained-load serving section ----------------------------
    // The lifecycle path the tables above bypass: the real scheduler
    // under over-subscription with a tight KV budget and the mixed
    // format rule — chunked prefill interleaving with decode,
    // recompute-preemption instead of OOM-kills, and live per-layer
    // format migration on the busy group.
    engine.cfg.kv = kv_configs()
        .into_iter()
        .find(|(name, _)| *name == "mixed")
        .expect("kv_configs always carries the mixed rule")
        .1;
    engine.cfg.scheduler.max_batch = 4;
    engine.cfg.scheduler.prefill_chunk = 24;
    engine.cfg.scheduler.migrate_patience = 8;
    let tasks = gen_tasks(42, 16, 16, 3);
    let lens: usize = {
        // Budget ≈ 2.5 average prompts at dense boot-time rates.
        let tok_counts: Vec<usize> = tasks
            .iter()
            .map(|t| t.prompt.len() + 1)
            .collect();
        tok_counts.iter().sum::<usize>() * 5 / (2 * tok_counts.len())
    };
    engine.cfg.scheduler.kv_budget_bytes =
        lens * engine.rt.meta.kv_bytes_per_token();
    engine.metrics.reset();
    let (churn, completions) = lethe::bench_support::run_churn(
        &mut engine,
        &tok,
        PolicyKind::Lethe,
        &tasks,
        48,
    )?;
    println!(
        "\n=== Table 3(c) — sustained-load serving (scheduler path) ===\n\
         {} requests in {:.2}s | peak queue {} | preempt {} / resume {} | \
         live migrations {} ({} busy) | interleaved ticks {} | OOM kills {}",
        completions.len(),
        churn.wall_s,
        churn.peak_queue_depth,
        churn.preemptions,
        churn.resumes,
        churn.kv_migrations,
        churn.busy_migrations,
        churn.interleaved_ticks,
        churn.oom_finishes,
    );
    write_csv(
        "table3_churn.csv",
        "requests,wall_s,peak_queue,preemptions,resumes,kv_migrations,\
         busy_migrations,interleaved_ticks,oom_finishes",
        &[format!(
            "{},{:.3},{},{},{},{},{},{},{}",
            completions.len(),
            churn.wall_s,
            churn.peak_queue_depth,
            churn.preemptions,
            churn.resumes,
            churn.kv_migrations,
            churn.busy_migrations,
            churn.interleaved_ticks,
            churn.oom_finishes
        )],
    )?;

    // ---- (d) incremental vs recompute chunked prefill ------------------
    // Same scheduler path and chunk grain; the only difference is
    // `scheduler.incremental_prefill`. The recompute path re-prefills
    // the grown prefix from position 0 every chunk, so a prompt of n
    // tokens pushes O(n²/chunk) tokens through the prefill executables;
    // the incremental path feeds each chunk the accumulated prior KV
    // and pushes exactly n. `prefill_tokens` makes the asymptotic
    // difference directly visible; prefill seconds show the win.
    engine.cfg.scheduler.kv_budget_bytes = 0; // isolate the prefill path
    engine.cfg.scheduler.prefill_chunk = 16;
    let supported = engine.supports_incremental_prefill();
    if !supported {
        eprintln!(
            "[note] artifact set has no prefill_t*_kv variants — both \
             rows below run the recompute path"
        );
    }
    let mut prefill_rows = Vec::new();
    for (label, incremental) in [("recompute", false), ("incremental", true)]
    {
        engine.cfg.scheduler.incremental_prefill = incremental;
        engine.metrics.reset();
        let tasks = gen_tasks(7, 8, 24, 4);
        let (churn, completions) =
            run_churn(&mut engine, &tok, PolicyKind::Lethe, &tasks, 16)?;
        let prefill_s: f64 = engine.metrics.prefill_seconds.sum();
        println!(
            "prefill[{label}]: {} tokens through prefill executables in \
             {:.3}s ({} requests, wall {:.2}s)",
            engine.metrics.prefill_tokens,
            prefill_s,
            completions.len(),
            churn.wall_s
        );
        prefill_rows.push(format!(
            "{label},{},{:.4},{:.3},{}",
            engine.metrics.prefill_tokens,
            prefill_s,
            churn.wall_s,
            supported && incremental
        ));
    }
    write_csv(
        "table3_prefill_path.csv",
        "path,prefill_tokens,prefill_s,wall_s,incremental_active",
        &prefill_rows,
    )?;
    Ok(())
}

fn short(name: &str) -> &str {
    name.trim_start_matches("DeepSeek-R1-Distill-")
}
