fn main() {}
