//! Figure 4: latency, generation memory and throughput versus generated
//! tokens, FullKV vs Lethe.
//!
//!   (a) Real engine, long profile (C up to 2048): a single sequence is
//!       decoded to ~1.8k tokens; per-step latency and live KV bytes are
//!       sampled along the way. FullKV grows linearly and eventually
//!       OOMs at the largest bucket; Lethe plateaus — the paper's
//!       memory-plateau curve, measured.
//!   (b) Simulator to 20k tokens on the four A100 archs.

use lethe::bench_support::{print_table, try_engine, write_csv};
use lethe::config::ServingConfig;
use lethe::engine::SeqState;
use lethe::model::DEEPSEEK_R1_DISTILL;
use lethe::policy::{make_policy, PolicyKind};
use lethe::sim::{run_trace, Simulator, TraceConfig};
use lethe::util::prng::Rng;
use lethe::workload::make_task;

fn env_usize(k: &str, default: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    // 2400 > the long profile's 2048-slot ceiling: FullKV must OOM on the
    // way (the paper's Fig. 4 cliff) while Lethe completes.
    let gen_target = env_usize("LETHE_FIG4_TOKENS", 2400);

    // ---- (a) real engine, long profile --------------------------------
    let mut cfg = ServingConfig::default();
    cfg.cache_profile = "long".to_string();
    cfg.lethe.evict_threshold = 256;
    // τ calibrated to the tiny model's score scale (see Table 6 sweep /
    // EXPERIMENTS.md): makes multi-round pruning engage so the memory
    // plateau is visible.
    cfg.lethe.sparse_ratio = 25.0;
    let mut csv = Vec::new();
    if let Some((mut engine, tok)) = try_engine(cfg) {
        let layers = engine.dims().n_layers;
        for kind in [PolicyKind::FullKv, PolicyKind::Lethe] {
            let mut rng = Rng::new(0xF164);
            let task = make_task(&mut rng, 24, 4);
            let prompt = tok.encode_prompt(&task.prompt)?;
            let mut group = engine.new_group(1, kind);
            // max_new > gen target; EOS is ignored by regenerating: use a
            // huge max and stop manually at the target.
            let mut seq = SeqState::new(
                0,
                make_policy(kind, &engine.cfg, layers),
                layers,
                usize::MAX / 2,
                -1, // never matches => length-capped manually
            );
            seq.max_new = gen_target;
            engine.prefill(&mut group, 0, seq, &prompt)?;
            let mut t_last = std::time::Instant::now();
            let mut steps = 0usize;
            while group.active() > 0 {
                if engine.step(&mut group)?.is_empty() {
                    // OOM: record the wall and stop this policy's curve.
                    csv.push(format!(
                        "{},{},OOM,OOM",
                        kind.label(),
                        group.seqs.first().map(|s| s.steps).unwrap_or(steps)
                    ));
                    eprintln!(
                        "[fig4] {} OOM at ~{} generated tokens",
                        kind.label(),
                        steps
                    );
                    break;
                }
                steps += 1;
                if steps % 100 == 0 {
                    let dt = t_last.elapsed().as_secs_f64() / 100.0;
                    t_last = std::time::Instant::now();
                    csv.push(format!(
                        "{},{},{:.5},{}",
                        kind.label(),
                        steps,
                        dt,
                        group.cache.live_bytes()
                    ));
                    eprintln!(
                        "[fig4] {} step {steps}: {:.2} ms/step, {} live KB",
                        kind.label(),
                        dt * 1e3,
                        group.cache.live_bytes() / 1000
                    );
                }
                group.reap();
            }
        }
        write_csv(
            "fig4_token_scaling_real.csv",
            "policy,generated_tokens,step_latency_s,live_kv_bytes",
            &csv,
        )?;
    }

    // ---- (b) simulator to 20k -----------------------------------------
    let mut cfg = ServingConfig::default();
    cfg.baseline.budget = 768;
    cfg.lethe.evict_threshold = 512;
    cfg.lethe.sink_len = 16;
    let mut sim_csv = Vec::new();
    let mut rows = Vec::new();
    for arch in &DEEPSEEK_R1_DISTILL {
        let mut sim = Simulator::new(arch);
        sim.calibrate(2048.0, 30.0);
        let tc = TraceConfig {
            n_layers: arch.n_layers,
            prompt_len: 512,
            gen_len: 20_000,
            ..TraceConfig::default()
        };
        let lethe = run_trace(PolicyKind::Lethe, &cfg, &tc);
        for t in (1000..=20_000).step_by(1000) {
            let full_ctx = 512.0 + t as f64;
            let lethe_ctx = lethe.retained[t - 1];
            for (kind, ctx) in
                [("FullKV", full_ctx), ("Lethe(ours)", lethe_ctx)]
            {
                let lat = sim.step_latency(1, ctx);
                let mem =
                    sim.gen_memory_bytes(1, ctx) / 1e6;
                sim_csv.push(format!(
                    "{},{},{},{:.5},{:.0},{:.2}",
                    arch.name, kind, t, lat, mem, 1.0 / lat
                ));
            }
            if t % 5000 == 0 && arch.name.contains("70B") {
                rows.push(vec![
                    format!("{t}"),
                    format!("{:.0}", (512.0 + t as f64)
                            * arch.kv_bytes_per_token_per_gpu() as f64
                            * lethe::sim::KV_FRAG / 1e6),
                    format!("{:.0}", lethe.retained[t - 1]
                            * arch.kv_bytes_per_token_per_gpu() as f64
                            * lethe::sim::KV_FRAG / 1e6),
                ]);
            }
        }
    }
    print_table(
        "Fig 4 (sim, Llama-70B) — KV memory (MB) vs generated tokens",
        &["tokens", "FullKV", "Lethe"],
        &rows,
    );
    write_csv(
        "fig4_token_scaling_sim.csv",
        "model,policy,generated_tokens,step_latency_s,gen_memory_mb,tok_s",
        &sim_csv,
    )?;
    Ok(())
}
