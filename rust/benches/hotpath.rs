//! Hot-path microbenchmarks (§Perf): the pieces of a decode step, each
//! measured in isolation so the optimization loop can attribute time.
//!
//!   decode exec   — PJRT execute per (B, C) bucket (upload + run + fetch)
//!   cache pack    — GroupCache::pack into upload scratch
//!   delta pack    — epoch-tracked incremental pack (f32/q8/q4 backends)
//!   q8 insert     — per-token insert incl. int8 quantization
//!   q4 insert     — per-token insert incl. group-wise int4 quantization
//!   score accum   — RASR Eq. 5 update over a full group
//!   hoyer         — Eq. 1 sparsity over a C-vector
//!   lethe plan    — Algorithm 1 on a worst-case layer
//!   apply retain  — the eviction gather
//!   json parse    — manifest-sized document (startup path)
//!
//! Every pure-rust row is also written to `bench_results/hotpath.csv`
//! via `bench_support::hotpath_csv`.

use lethe::bench_support::{gen_tasks, hotpath_csv, run_tasks, try_engine,
                           write_bench_json, BenchJsonRow};
use lethe::config::{LetheParams, ServingConfig};
use lethe::kvcache::{CacheDims, GroupCache, KvFormat, PackScratch,
                     PackedScratch};
use lethe::policy::{EvictionPolicy, LayerState, LethePolicy, PolicyKind};
use lethe::runtime::tensors::{HostTensorF32, HostTensorI32};
use lethe::util::json::Json;
use lethe::util::prng::Rng;
use lethe::util::stats::{bench, bench_row, Summary};

fn main() -> anyhow::Result<()> {
    println!("=== hotpath microbenches (warmup 3, n=20) ===");
    let mut rng = Rng::new(0x407);
    let mut csv: Vec<(String, Summary)> = Vec::new();
    let emit = |name: &str, s: &Summary, csv: &mut Vec<(String, Summary)>| {
        println!("{}", bench_row(name, s));
        csv.push((name.to_string(), s.clone()));
    };

    // --- pure-rust paths -------------------------------------------------
    let dims = CacheDims {
        layers: 4,
        batch: 8,
        kv_heads: 2,
        capacity: 512,
        d_head: 32,
    };
    let mut cache = GroupCache::new(dims);
    let row: Vec<f32> = (0..64).map(|i| i as f32).collect();
    for b in 0..8 {
        for t in 0..400 {
            for l in 0..4 {
                cache.insert(l, b, &row, &row, t as i32).unwrap();
            }
        }
    }
    let mut k_s = HostTensorF32::zeros(&[4, 8, 2, 512, 32]);
    let mut v_s = HostTensorF32::zeros(&[4, 8, 2, 512, 32]);
    let mut l_s = HostTensorI32::zeros(&[4, 8]);
    let s = bench(3, 20, || {
        cache.pack(8, 512, &mut k_s, &mut v_s, &mut l_s).unwrap();
    });
    emit("cache pack b8 c512 (16.8MB)", &s, &mut csv);

    // Steady-state decode step: one appended token per (l, b), then an
    // incremental pack — the Engine::step path. A separate clone keeps
    // the benches below at exactly 400 live rows. Acceptance bar: >= 5x
    // faster than the full "cache pack" row above.
    let mut dcache = cache.clone();
    let mut scratch = PackScratch::new(&dims, 8, 512);
    dcache.pack_delta(&mut scratch).unwrap(); // cold full sync
    let mut t = 400i32;
    let s = bench(3, 20, || {
        for b in 0..8 {
            for l in 0..4 {
                dcache.insert(l, b, &row, &row, t).unwrap();
            }
        }
        t += 1;
        dcache.pack_delta(&mut scratch).unwrap();
    });
    emit(
        &format!(
            "delta pack (append-only step, {:.1}MB resident)",
            scratch.k.bytes() as f64 / 1e6
        ),
        &s,
        &mut csv,
    );
    let s_f32_delta = s.clone();

    // Quantized (kv.format = "q8") backend: the same per-token paths on
    // int8 storage. Insert pays the per-row quantization; the append-only
    // delta pack pays the dequantization of exactly the new rows into the
    // f32 upload scratch.
    let mut q_ins = GroupCache::with_format(dims, KvFormat::QuantI8);
    for b in 0..8 {
        for tq in 0..400 {
            for l in 0..4 {
                q_ins.insert(l, b, &row, &row, tq as i32).unwrap();
            }
        }
    }
    let mut tq = 400i32;
    let s = bench(3, 20, || {
        for b in 0..8 {
            for l in 0..4 {
                q_ins.insert(l, b, &row, &row, tq).unwrap();
            }
        }
        tq += 1;
    });
    emit("q8 insert+quantize (32 rows/step)", &s, &mut csv);

    let mut q_d = q_ins.clone();
    let mut q_scratch = PackScratch::new(&dims, 8, 512);
    q_d.pack_delta(&mut q_scratch).unwrap(); // cold full sync
    let s = bench(3, 20, || {
        for b in 0..8 {
            for l in 0..4 {
                q_d.insert(l, b, &row, &row, tq).unwrap();
            }
        }
        tq += 1;
        q_d.pack_delta(&mut q_scratch).unwrap();
    });
    emit("q8 dequant pack (append-only step)", &s, &mut csv);

    // Group-wise int4 (kv.format = "q4") backend: insert pays the
    // per-group min/max + nibble packing, the append-only delta pack
    // pays the group-wise dequantization of exactly the new rows.
    let mut q4_ins = GroupCache::with_format(dims, KvFormat::QuantI4);
    for b in 0..8 {
        for t4 in 0..400 {
            for l in 0..4 {
                q4_ins.insert(l, b, &row, &row, t4 as i32).unwrap();
            }
        }
    }
    let mut t4 = 400i32;
    let s = bench(3, 20, || {
        for b in 0..8 {
            for l in 0..4 {
                q4_ins.insert(l, b, &row, &row, t4).unwrap();
            }
        }
        t4 += 1;
    });
    emit("q4 insert+quantize (32 rows/step)", &s, &mut csv);

    let mut q4_d = q4_ins.clone();
    let mut q4_scratch = PackScratch::new(&dims, 8, 512);
    q4_d.pack_delta(&mut q4_scratch).unwrap(); // cold full sync
    let s = bench(3, 20, || {
        for b in 0..8 {
            for l in 0..4 {
                q4_d.insert(l, b, &row, &row, t4).unwrap();
            }
        }
        t4 += 1;
        q4_d.pack_delta(&mut q4_scratch).unwrap();
    });
    emit("q4 dequant pack (append-only step)", &s, &mut csv);

    // Packed delta pack — the raw-speed upload path: the same
    // append-only step reconciled into the PackedScratch wire image
    // (stored codes + scales, + zeros for q4) the kernel-side-dequant
    // `decode_*_q8`/`_q4` executables consume directly, so the host
    // never materializes the 4·D f32 expansion.
    let mut q8_p = q_ins.clone();
    let mut p8 = PackedScratch::new(&dims, 8, 512, KvFormat::QuantI8);
    q8_p.pack_delta_packed(&mut p8).unwrap(); // cold full sync
    let s = bench(3, 20, || {
        for b in 0..8 {
            for l in 0..4 {
                q8_p.insert(l, b, &row, &row, tq).unwrap();
            }
        }
        tq += 1;
        q8_p.pack_delta_packed(&mut p8).unwrap();
    });
    emit("q8 packed pack (append-only, wire bytes)", &s, &mut csv);
    let s_q8_packed = s.clone();

    let mut q4_p = q4_ins.clone();
    let mut p4 = PackedScratch::new(&dims, 8, 512, KvFormat::QuantI4);
    q4_p.pack_delta_packed(&mut p4).unwrap(); // cold full sync
    let s = bench(3, 20, || {
        for b in 0..8 {
            for l in 0..4 {
                q4_p.insert(l, b, &row, &row, t4).unwrap();
            }
        }
        t4 += 1;
        q4_p.pack_delta_packed(&mut p4).unwrap();
    });
    emit("q4 packed pack (append-only, wire bytes)", &s, &mut csv);
    let s_q4_packed = s.clone();

    // Measured upload bytes per steady-state step (one instrumented
    // append step per format) → BENCH_hotpath.json. Codes-only
    // asymptotics are 4x (q8) / 8x (q4); the measured wire ratios at
    // d_head=32 include the f32 scales (and q4 zero points), landing
    // near 3.6x / 5.3x.
    let mut json_rows: Vec<BenchJsonRow> = Vec::new();
    {
        for b in 0..8 {
            for l in 0..4 {
                dcache.insert(l, b, &row, &row, t).unwrap();
            }
        }
        t += 1;
        let st_f = dcache.pack_delta(&mut scratch).unwrap();
        for b in 0..8 {
            for l in 0..4 {
                q8_p.insert(l, b, &row, &row, tq).unwrap();
            }
        }
        tq += 1;
        let st_8 = q8_p.pack_delta_packed(&mut p8).unwrap();
        for b in 0..8 {
            for l in 0..4 {
                q4_p.insert(l, b, &row, &row, t4).unwrap();
            }
        }
        t4 += 1;
        let st_4 = q4_p.pack_delta_packed(&mut p4).unwrap();
        assert_eq!(
            st_f.bytes_copied, st_8.bytes_f32_equiv,
            "f32-equivalent pricing must match the dense step"
        );
        println!(
            "upload bytes/step (32 appended rows): f32 {} | q8 {} \
             ({:.2}x) | q4 {} ({:.2}x)",
            st_f.bytes_copied,
            st_8.bytes_copied,
            st_f.bytes_copied as f64 / st_8.bytes_copied as f64,
            st_4.bytes_copied,
            st_f.bytes_copied as f64 / st_4.bytes_copied as f64,
        );
        json_rows.push(BenchJsonRow {
            name: "delta_pack_step".into(),
            kv_format: "f32".into(),
            tokens_per_s: 8.0 / s_f32_delta.mean,
            upload_bytes_per_step: st_f.bytes_copied,
            extra: Vec::new(),
        });
        json_rows.push(BenchJsonRow {
            name: "delta_pack_step".into(),
            kv_format: "q8".into(),
            tokens_per_s: 8.0 / s_q8_packed.mean,
            upload_bytes_per_step: st_8.bytes_copied,
            extra: Vec::new(),
        });
        json_rows.push(BenchJsonRow {
            name: "delta_pack_step".into(),
            kv_format: "q4".into(),
            tokens_per_s: 8.0 / s_q4_packed.mean,
            upload_bytes_per_step: st_4.bytes_copied,
            extra: Vec::new(),
        });
    }

    let add: Vec<f32> = (0..400).map(|_| rng.f32()).collect();
    let s = bench(3, 20, || {
        for b in 0..8 {
            for l in 0..4 {
                cache.accumulate_scores(l, b, 0.95, &add);
            }
        }
    });
    emit("score accum (32 rows x 400)", &s, &mut csv);

    let scores: Vec<f32> = (0..400).map(|_| rng.f32() * rng.f32()).collect();
    let s = bench(3, 20, || {
        std::hint::black_box(lethe::attn::sparsity::hoyer_sparsity(&scores));
    });
    emit("hoyer sparsity (400)", &s, &mut csv);

    let pos: Vec<i32> = (0..400).collect();
    let params = LetheParams {
        evict_threshold: 64,
        sparse_ratio: 40.0,
        ..LetheParams::default()
    };
    let s = bench(3, 20, || {
        // Fresh policy per iteration so the adaptive threshold doesn't
        // absorb the trigger after the first plan.
        let mut p2 = LethePolicy::new(params.clone(), 4);
        let st = LayerState {
            scores: &scores,
            pos: &pos,
            len: 400,
            step: 100,
            sparsity: 0.8,
            capacity: 512,
        };
        std::hint::black_box(p2.plan(0, &st));
    });
    emit("lethe plan (400 slots, incl alloc)", &s, &mut csv);

    let keep: Vec<usize> = (0..400).filter(|i| i % 3 != 0).collect();
    let s = bench(3, 20, || {
        let mut c2 = cache.clone();
        c2.apply_retention(0, 0, &keep).unwrap();
    });
    emit("apply retention (400→267, incl clone)", &s, &mut csv);

    let manifest = std::fs::read_to_string("artifacts/model_meta.json")
        .unwrap_or_else(|_| "{}".into());
    let s = bench(3, 20, || {
        std::hint::black_box(lethe::util::json::parse(&manifest).unwrap());
    });
    emit("json parse (manifest)", &s, &mut csv);

    hotpath_csv(&csv)?;

    // --- PJRT decode per bucket -------------------------------------------
    if let Some((engine, _tok)) = try_engine(ServingConfig::default()) {
        let meta = &engine.rt.meta;
        let d = meta.dims.clone();
        for &(bb, cap) in &[(1usize, 128usize), (1, 512), (4, 128), (8, 128),
                            (8, 512)] {
            if !meta
                .executables
                .contains_key(&format!("decode_b{bb}_c{cap}"))
            {
                continue;
            }
            let kv = HostTensorF32::zeros(&[d.n_layers, bb, d.n_kv_heads, cap,
                                            d.d_head]);
            let mut lens = HostTensorI32::zeros(&[d.n_layers, bb]);
            for x in lens.data.iter_mut() {
                *x = (cap / 2) as i32;
            }
            let tokens = vec![5i32; bb];
            let positions = vec![(cap / 2) as i32; bb];
            let s = bench(3, 20, || {
                std::hint::black_box(
                    engine
                        .rt
                        .decode(bb, cap, &kv, &kv, &lens, &tokens, &positions)
                        .unwrap(),
                );
            });
            println!("{}", bench_row(&format!("decode exec b{bb} c{cap}"), &s));
        }
    }

    // --- pipelined decode step --------------------------------------------
    // End-to-end Engine::step walls, serial vs pipelined, on the same
    // closed-loop workload. The serial-equivalent cost of a pipelined
    // step is its own measured components (pack + exec + policy — each
    // overlapped step still performs all three); overlap efficiency is
    // the fraction of the theoretically hideable time — min(exec,
    // policy) — the pipeline actually hid. CI gates this row at >= 0.5.
    {
        let tasks = gen_tasks(0x9a7, 8, 6, 2);
        let mut serial_tps = 0.0;
        let mut serial_step = 0.0;
        let mut scfg = ServingConfig::default();
        scfg.engine.pipeline_decode = false;
        if let Some((mut e, tok)) = try_engine(scfg) {
            let r = run_tasks(&mut e, &tok, PolicyKind::Lethe, &tasks, 4, 48)?;
            serial_tps = r.gen_tokens as f64 / r.wall_s;
            serial_step = e.metrics.step_seconds.mean();
        }
        if let Some((mut e, tok)) =
            try_engine(ServingConfig::default())
        {
            let r = run_tasks(&mut e, &tok, PolicyKind::Lethe, &tasks, 4, 48)?;
            let m = &e.metrics;
            let (pack, exec, policy, step) = (
                m.pack_seconds.mean(),
                m.exec_seconds.mean(),
                m.policy_seconds.mean(),
                m.step_seconds.mean(),
            );
            let serial_equiv = pack + exec + policy;
            let hideable = exec.min(policy);
            let eff = if hideable > 0.0 {
                ((serial_equiv - step) / hideable).max(0.0)
            } else {
                0.0
            };
            let tps = r.gen_tokens as f64 / r.wall_s;
            println!(
                "pipeline overlap: step {:.3}ms (serial {:.3}ms, \
                 components {:.3}ms = pack {:.3} + exec {:.3} + policy \
                 {:.3}) | efficiency {:.2} | overlapped {}/{} steps | \
                 {:.1} tok/s vs {:.1} serial",
                step * 1e3, serial_step * 1e3, serial_equiv * 1e3,
                pack * 1e3, exec * 1e3, policy * 1e3, eff,
                m.pipeline_overlapped_steps, m.decode_steps, tps, serial_tps,
            );
            json_rows.push(BenchJsonRow {
                name: "pipeline_overlap".into(),
                kv_format: "f32".into(),
                tokens_per_s: tps,
                upload_bytes_per_step: 0,
                extra: vec![
                    ("step_s_mean".into(), Json::num(step)),
                    ("serial_step_s_mean".into(), Json::num(serial_step)),
                    ("serial_equiv_s_mean".into(), Json::num(serial_equiv)),
                    ("pack_s_mean".into(), Json::num(pack)),
                    ("exec_s_mean".into(), Json::num(exec)),
                    ("policy_s_mean".into(), Json::num(policy)),
                    ("overlap_efficiency".into(), Json::num(eff)),
                    (
                        "overlapped_steps".into(),
                        Json::from(m.pipeline_overlapped_steps as usize),
                    ),
                    ("decode_steps".into(), Json::from(m.decode_steps as usize)),
                    ("serial_tokens_per_s".into(), Json::num(serial_tps)),
                ],
            });
        }
    }

    write_bench_json("hotpath", &json_rows)?;
    Ok(())
}
