//! Delta-pack equivalence property: after ANY interleaving of inserts,
//! retentions, prefill loads, slot swaps, slot resets and bucket changes,
//! a [`GroupCache::pack_delta`]-maintained resident scratch is
//! bit-identical to a fresh [`GroupCache::pack`] at the same bucket.
//! This is the invariant that lets `Engine::step` skip the O(L·B·Hkv·C·D)
//! per-step repack.

use lethe::kvcache::{CacheDims, GroupCache, PackScratch};
use lethe::runtime::tensors::{HostTensorF32, HostTensorI32};
use lethe::util::proptest::{check, vec_f32};

const LAYERS: usize = 2;
const BATCH: usize = 3;
const HKV: usize = 2;
const CAP: usize = 32;
const D: usize = 4;

fn dims() -> CacheDims {
    CacheDims {
        layers: LAYERS,
        batch: BATCH,
        kv_heads: HKV,
        capacity: CAP,
        d_head: D,
    }
}

/// Compare one scratch against a fresh pack; Err(msg) on divergence.
fn check_bucket(
    cache: &GroupCache,
    scratch: &PackScratch,
) -> Result<(), String> {
    let (bb, c) = scratch.bucket();
    let shape = [LAYERS, bb, HKV, c, D];
    let mut k = HostTensorF32::zeros(&shape);
    let mut v = HostTensorF32::zeros(&shape);
    let mut lens = HostTensorI32::zeros(&[LAYERS, bb]);
    cache
        .pack(bb, c, &mut k, &mut v, &mut lens)
        .map_err(|e| format!("reference pack failed: {e}"))?;
    if scratch.lens.data != lens.data {
        return Err(format!(
            "lens diverged at bucket ({bb},{c}): {:?} vs {:?}",
            scratch.lens.data, lens.data
        ));
    }
    if scratch.k.data != k.data {
        return Err(format!("K scratch diverged at bucket ({bb},{c})"));
    }
    if scratch.v.data != v.data {
        return Err(format!("V scratch diverged at bucket ({bb},{c})"));
    }
    Ok(())
}

#[test]
fn delta_pack_equals_fresh_pack_under_random_ops() {
    check("delta-pack-equivalence", 40, |rng, size| {
        let mut cache = GroupCache::new(dims());
        // Several buckets, engine-style: residency is per bucket, and
        // revisiting a bucket after steps at another exercises the
        // bucket-change reseed path.
        let buckets: [(usize, usize); 4] =
            [(1, 16), (2, 32), (3, 16), (3, 32)];
        let mut scratches: Vec<PackScratch> = buckets
            .iter()
            .map(|&(bb, c)| PackScratch::new(&dims(), bb, c))
            .collect();

        let steps = 4 + size;
        let mut abs = 0i32;
        for step in 0..steps {
            match rng.range(0, 4) {
                0 => {
                    // Append one token to a random (layer, slot).
                    let l = rng.range(0, LAYERS - 1);
                    let b = rng.range(0, BATCH - 1);
                    if cache.len(l, b) < CAP {
                        let kr = vec_f32(rng, HKV * D, -1.0, 1.0);
                        let vr = vec_f32(rng, HKV * D, -1.0, 1.0);
                        cache
                            .insert(l, b, &kr, &vr, abs)
                            .map_err(|e| e.to_string())?;
                        abs += 1;
                    }
                }
                1 => {
                    // Retention: keep a random subset of a random pair.
                    let l = rng.range(0, LAYERS - 1);
                    let b = rng.range(0, BATCH - 1);
                    let n = cache.len(l, b);
                    if n > 0 {
                        let keep: Vec<usize> = (0..n)
                            .filter(|_| rng.bool(0.6))
                            .collect();
                        cache
                            .apply_retention(l, b, &keep)
                            .map_err(|e| e.to_string())?;
                    }
                }
                2 => {
                    // Prefill-load a random slot (resets it first).
                    let b = rng.range(0, BATCH - 1);
                    let t = rng.range(1, CAP);
                    let len = rng.range(1, t);
                    let k_all = HostTensorF32::from_vec(
                        &[LAYERS, 1, HKV, t, D],
                        vec_f32(rng, LAYERS * HKV * t * D, -1.0, 1.0),
                    )
                    .map_err(|e| e.to_string())?;
                    let v_all = HostTensorF32::from_vec(
                        &[LAYERS, 1, HKV, t, D],
                        vec_f32(rng, LAYERS * HKV * t * D, -1.0, 1.0),
                    )
                    .map_err(|e| e.to_string())?;
                    cache
                        .load_prefill(b, &k_all, &v_all, len)
                        .map_err(|e| e.to_string())?;
                }
                3 => {
                    // Swap two random slots (reap path).
                    let a = rng.range(0, BATCH - 1);
                    let b = rng.range(0, BATCH - 1);
                    cache.swap_slots(a, b);
                }
                _ => {
                    cache.reset_slot(rng.range(0, BATCH - 1));
                }
            }

            // Reconcile + verify every bucket the live lengths fit.
            for (i, &(bb, c)) in buckets.iter().enumerate() {
                let fits = (0..bb).all(|b| {
                    (0..LAYERS).all(|l| cache.len(l, b) <= c)
                });
                if !fits {
                    continue;
                }
                cache
                    .pack_delta(&mut scratches[i])
                    .map_err(|e| format!("step {step}: {e}"))?;
                check_bucket(&cache, &scratches[i])
                    .map_err(|m| format!("step {step}: {m}"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn delta_pack_residency_survives_cache_swap_between_groups() {
    // Engine scratch is keyed by bucket, not by group: simulate two
    // groups alternating on one scratch. The unique cache id must force
    // a cold re-sync on every owner change.
    let mut a = GroupCache::new(dims());
    let mut b = GroupCache::new(dims());
    let row_a = vec![1.0f32; HKV * D];
    let row_b = vec![2.0f32; HKV * D];
    for l in 0..LAYERS {
        a.insert(l, 0, &row_a, &row_a, 0).unwrap();
        b.insert(l, 0, &row_b, &row_b, 0).unwrap();
        b.insert(l, 0, &row_b, &row_b, 1).unwrap();
    }
    let mut scratch = PackScratch::new(&dims(), 2, 16);
    for _ in 0..3 {
        a.pack_delta(&mut scratch).unwrap();
        check_bucket(&a, &scratch).unwrap();
        b.pack_delta(&mut scratch).unwrap();
        check_bucket(&b, &scratch).unwrap();
    }
}

// ---------------------------------------------------------------------
// Packed-scratch equivalence: the same invariant for the raw-speed
// upload path. After ANY interleaving of cache ops, a
// [`GroupCache::pack_delta_packed`]-maintained [`PackedScratch`]
// (stored codes + scales, the kernel-side-dequant operand image)
// dequantizes bit-identically to a fresh f32 pack of the same cache —
// both sides decode the same stored codes, so equality is exact, not
// bounded.

use lethe::kvcache::quant::{
    dequantize_row_q4, dequantize_span, packed_codes_per_row,
    packed_scales_per_row,
};
use lethe::kvcache::{KvFormat, PackedScratch};
use lethe::runtime::tensors::as_i8;

/// Compare one packed scratch against a fresh f32 pack by dequantizing
/// every live row; Err(msg) on any divergence.
fn check_bucket_packed(
    cache: &GroupCache,
    scratch: &PackedScratch,
    fmt: KvFormat,
) -> Result<(), String> {
    let (bb, c) = scratch.bucket();
    let shape = [LAYERS, bb, HKV, c, D];
    let mut k = HostTensorF32::zeros(&shape);
    let mut v = HostTensorF32::zeros(&shape);
    let mut lens = HostTensorI32::zeros(&[LAYERS, bb]);
    cache
        .pack(bb, c, &mut k, &mut v, &mut lens)
        .map_err(|e| format!("reference pack failed: {e}"))?;
    if scratch.lens.data != lens.data {
        return Err(format!(
            "lens diverged at bucket ({bb},{c}): {:?} vs {:?}",
            scratch.lens.data, lens.data
        ));
    }
    let db = packed_codes_per_row(D, fmt).unwrap();
    let sg = packed_scales_per_row(D, fmt).unwrap();
    let mut out = vec![0.0f32; D];
    for l in 0..LAYERS {
        for b in 0..bb {
            let live = lens.data[l * bb + b] as usize;
            for h in 0..HKV {
                for t in 0..live {
                    let ri = ((l * bb + b) * HKV + h) * c + t;
                    for (which, codes, scales, zeros, reference) in [
                        (
                            "K",
                            &scratch.k_codes,
                            &scratch.k_scales,
                            &scratch.k_zeros,
                            &k,
                        ),
                        (
                            "V",
                            &scratch.v_codes,
                            &scratch.v_scales,
                            &scratch.v_zeros,
                            &v,
                        ),
                    ] {
                        match fmt {
                            KvFormat::QuantI8 => dequantize_span(
                                as_i8(&codes.data[ri * db..ri * db + db]),
                                scales.data[ri],
                                &mut out,
                            ),
                            KvFormat::QuantI4 => dequantize_row_q4(
                                &codes.data[ri * db..ri * db + db],
                                &scales.data[ri * sg..ri * sg + sg],
                                &zeros.data[ri * sg..ri * sg + sg],
                                &mut out,
                            ),
                            KvFormat::F32 => unreachable!(),
                        }
                        let off = ri * D;
                        if out[..] != reference.data[off..off + D] {
                            return Err(format!(
                                "{which} row diverged at bucket \
                                 ({bb},{c}) l={l} b={b} h={h} t={t}"
                            ));
                        }
                    }
                }
            }
        }
    }
    Ok(())
}

#[test]
fn packed_delta_pack_equals_fresh_pack_under_random_ops() {
    for fmt in [KvFormat::QuantI8, KvFormat::QuantI4] {
        check(&format!("packed-delta-pack-{}", fmt.label()), 30, |rng, size| {
            let mut cache = GroupCache::with_format(dims(), fmt);
            let buckets: [(usize, usize); 4] =
                [(1, 16), (2, 32), (3, 16), (3, 32)];
            let mut scratches: Vec<PackedScratch> = buckets
                .iter()
                .map(|&(bb, c)| PackedScratch::new(&dims(), bb, c, fmt))
                .collect();

            let steps = 4 + size;
            let mut abs = 0i32;
            for step in 0..steps {
                match rng.range(0, 4) {
                    0 => {
                        let l = rng.range(0, LAYERS - 1);
                        let b = rng.range(0, BATCH - 1);
                        if cache.len(l, b) < CAP {
                            let kr = vec_f32(rng, HKV * D, -1.0, 1.0);
                            let vr = vec_f32(rng, HKV * D, -1.0, 1.0);
                            cache
                                .insert(l, b, &kr, &vr, abs)
                                .map_err(|e| e.to_string())?;
                            abs += 1;
                        }
                    }
                    1 => {
                        let l = rng.range(0, LAYERS - 1);
                        let b = rng.range(0, BATCH - 1);
                        let n = cache.len(l, b);
                        if n > 0 {
                            let keep: Vec<usize> = (0..n)
                                .filter(|_| rng.bool(0.6))
                                .collect();
                            cache
                                .apply_retention(l, b, &keep)
                                .map_err(|e| e.to_string())?;
                        }
                    }
                    2 => {
                        let b = rng.range(0, BATCH - 1);
                        let t = rng.range(1, CAP);
                        let len = rng.range(1, t);
                        let k_all = HostTensorF32::from_vec(
                            &[LAYERS, 1, HKV, t, D],
                            vec_f32(rng, LAYERS * HKV * t * D, -1.0, 1.0),
                        )
                        .map_err(|e| e.to_string())?;
                        let v_all = HostTensorF32::from_vec(
                            &[LAYERS, 1, HKV, t, D],
                            vec_f32(rng, LAYERS * HKV * t * D, -1.0, 1.0),
                        )
                        .map_err(|e| e.to_string())?;
                        cache
                            .load_prefill(b, &k_all, &v_all, len)
                            .map_err(|e| e.to_string())?;
                    }
                    3 => {
                        let a = rng.range(0, BATCH - 1);
                        let b = rng.range(0, BATCH - 1);
                        cache.swap_slots(a, b);
                    }
                    _ => {
                        cache.reset_slot(rng.range(0, BATCH - 1));
                    }
                }

                for (i, &(bb, c)) in buckets.iter().enumerate() {
                    let fits = (0..bb).all(|b| {
                        (0..LAYERS).all(|l| cache.len(l, b) <= c)
                    });
                    if !fits {
                        continue;
                    }
                    cache
                        .pack_delta_packed(&mut scratches[i])
                        .map_err(|e| format!("step {step}: {e}"))?;
                    check_bucket_packed(&cache, &scratches[i], fmt)
                        .map_err(|m| format!("step {step}: {m}"))?;
                }
            }
            Ok(())
        });
    }
}

#[test]
fn packed_residency_survives_cache_swap_between_groups() {
    // Same owner-change invariant as the f32 scratch: the unique cache
    // id forces a cold re-sync whenever a different group reconciles
    // into a shared bucket scratch.
    let fmt = KvFormat::QuantI8;
    let mut a = GroupCache::with_format(dims(), fmt);
    let mut b = GroupCache::with_format(dims(), fmt);
    let row_a = vec![1.0f32; HKV * D];
    let row_b = vec![2.0f32; HKV * D];
    for l in 0..LAYERS {
        a.insert(l, 0, &row_a, &row_a, 0).unwrap();
        b.insert(l, 0, &row_b, &row_b, 0).unwrap();
        b.insert(l, 0, &row_b, &row_b, 1).unwrap();
    }
    let mut scratch = PackedScratch::new(&dims(), 2, 16, fmt);
    for _ in 0..3 {
        a.pack_delta_packed(&mut scratch).unwrap();
        check_bucket_packed(&a, &scratch, fmt).unwrap();
        b.pack_delta_packed(&mut scratch).unwrap();
        check_bucket_packed(&b, &scratch, fmt).unwrap();
    }
}
