"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness signal: every Pallas kernel in this package
is pytest/hypothesis-compared against the function of the same name here.
They are also used directly by the training loop (train.py), so the model
the rust engine serves was trained against exactly this semantics.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k, v, lens, scale):
    """Single-step masked GQA decode attention with score side-output.

    q:    [B, Hq, D]       (one query per sequence — decode step)
    k, v: [B, Hkv, C, D]   (cache, rotary already applied to k)
    lens: [B] int32        (valid slots are the prefix 0..lens[b])
    returns (out [B, Hq, D], probs [B, Hq, C])
    """
    b, hq, d = q.shape
    _, hkv, c, _ = k.shape
    group = hq // hkv
    valid = jnp.arange(c)[None, :] < lens[:, None]          # [B, C]
    # Map q head h -> kv head h // group without materialising repeats
    # (paper Eq. 3: GQA handled head-invariantly, no key duplication).
    qg = q.reshape(b, hkv, group, d)
    scores = jnp.einsum("bkgd,bkcd->bkgc", qg, k) * scale
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m) * valid[:, None, None, :]
    p = e / jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bkgc,bkcd->bkgd", p, v).reshape(b, hq, d)
    return out, p.reshape(b, hq, c)


def prefill_attention_ref(q, k, v, scale):
    """Causal GQA attention over a full prompt, probs side-output.

    q:    [B, Hq, T, D]
    k, v: [B, Hkv, T, D]
    returns (out [B, Hq, T, D], probs [B, Hq, T, T])
    """
    b, hq, t, d = q.shape
    _, hkv, _, _ = k.shape
    group = hq // hkv
    qg = q.reshape(b, hkv, group, t, d)
    scores = jnp.einsum("bkgtd,bksd->bkgts", qg, k) * scale
    causal = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(causal[None, None, None], scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m) * causal[None, None, None]
    p = e / jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bkgts,bksd->bkgtd", p, v)
    return out.reshape(b, hq, t, d), p.reshape(b, hq, t, t)
