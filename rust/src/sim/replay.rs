//! Deterministic virtual-time replay of a multi-tenant trace against a
//! faithful model of the serving scheduler.
//!
//! The real soak replays traces against the live [`crate::supervisor`]
//! — but that needs AOT artifacts, which CI does not have. This module
//! mirrors the scheduler's *control-plane* semantics (FIFO admission
//! with byte-budget projection, chunked prefill one chunk per tick,
//! one decode token per active sequence per tick, youngest-victim
//! preemption with the swap-vs-recompute cost split, per-request
//! deadlines, KV-headroom placement across groups) on a virtual clock,
//! so the pinned-trace SLO numbers in `BENCH_soak.json` are a pure
//! function of `(trace, ReplayConfig)` and reproduce bit-for-bit on
//! every machine. Divergences from the real engine are intentional and
//! documented inline: decode runs to `max_new_tokens` (no EOS — the
//! reasoning-heavy decode length *is* the workload), groups tick in
//! lockstep (the slowest group sets the tick length), and admission
//! projects `resume_tokens × bytes_per_token` just like the real
//! scheduler's projection.
//!
//! The virtual tick cost model is linear:
//!
//! ```text
//! dt = t_tick_base + prefill_tokens·t_prefill_token
//!                  + decoded_seqs·t_decode_token
//!                  + swapped_bytes·t_swap_byte
//! ```
//!
//! calibrated loosely against the A100 model in [`crate::sim`]; the CI
//! gate compares runs of *this* model against each other, so only
//! relative regressions matter, not absolute fidelity.

use std::collections::VecDeque;

use crate::workload::slo::RequestOutcome;
use crate::workload::trace::TraceRequest;

/// Knobs of the virtual replay (mirror of the scheduler knobs that
/// matter for SLO shape, plus the tick cost model).
#[derive(Clone, Debug)]
pub struct ReplayConfig {
    /// Decode groups ticking in lockstep.
    pub groups: usize,
    /// Max co-resident sequences per group.
    pub max_batch: usize,
    /// Prefill chunk tokens (one chunk per group per tick).
    pub prefill_chunk: usize,
    /// Per-group live-KV byte budget; 0 = unlimited.
    pub kv_budget_bytes: usize,
    /// Resident KV bytes per token (all layers, stored precision).
    pub bytes_per_token: usize,
    /// Swap-vs-recompute threshold, same meaning as
    /// `scheduler.swap_threshold_bytes_per_token`: a victim whose live
    /// bytes are at most `resume_tokens × threshold` swaps to host,
    /// everything else drops and recomputes. 0 disables swapping.
    pub swap_threshold_bytes_per_token: usize,
    /// Fixed per-tick overhead, seconds.
    pub t_tick_base: f64,
    /// Seconds per prefill token.
    pub t_prefill_token: f64,
    /// Seconds per decoding sequence per tick.
    pub t_decode_token: f64,
    /// Seconds per swapped byte (out or in).
    pub t_swap_byte: f64,
}

impl Default for ReplayConfig {
    fn default() -> ReplayConfig {
        ReplayConfig {
            groups: 1,
            max_batch: 8,
            prefill_chunk: 64,
            kv_budget_bytes: 512 * 1024,
            bytes_per_token: 1024,
            swap_threshold_bytes_per_token:
                crate::config::SchedulerConfig::default()
                    .swap_threshold_bytes_per_token,
            t_tick_base: 2e-3,
            t_prefill_token: 40e-6,
            t_decode_token: 1.2e-3,
            t_swap_byte: 2e-9,
        }
    }
}

/// Aggregate result of one replay.
#[derive(Clone, Debug)]
pub struct ReplayReport {
    /// Terminal outcome per trace request, in trace order.
    pub outcomes: Vec<RequestOutcome>,
    /// Virtual seconds from t=0 to the last terminal event.
    pub makespan_s: f64,
    /// Total generated tokens (successful or not).
    pub generated_tokens: u64,
    /// Total prefill tokens processed (recomputation included).
    pub prefill_tokens: u64,
    pub preemptions: u64,
    pub swap_preemptions: u64,
    pub swap_bytes_out: u64,
    pub deadline_aborts: u64,
    pub ticks: u64,
}

impl ReplayReport {
    /// Aggregate decode throughput over the replay.
    pub fn tokens_per_s(&self) -> f64 {
        if self.makespan_s <= 0.0 {
            return 0.0;
        }
        self.generated_tokens as f64 / self.makespan_s
    }
}

/// Lifecycle shadow of one sequence.
struct SimSeq {
    prompt: usize,
    max_new: usize,
    arrival: f64,
    /// Absolute deadline in virtual seconds.
    deadline: Option<f64>,
    /// Current prefill target: `prompt`, or `prompt + generated` after
    /// a recompute preemption.
    target: usize,
    /// Prefilled tokens toward `target`.
    consumed: usize,
    /// Resident tokens generated since the last prefill completion.
    fresh: usize,
    /// Total generated tokens (survives preemption).
    generated: usize,
    /// Parked on host via swap (bytes off-device, resume without
    /// recompute).
    swapped: bool,
    admit_stamp: u64,
    first_token: Option<f64>,
    /// `(virtual finish instant, finished ok)`.
    done: Option<(f64, bool)>,
    preemptions: u64,
    swaps: u64,
}

impl SimSeq {
    fn resident_tokens(&self) -> usize {
        self.consumed + self.fresh
    }
    fn resume_tokens(&self) -> usize {
        self.prompt + self.generated
    }
}

struct SimGroup {
    waiting: VecDeque<usize>,
    active: Vec<usize>,
    next_stamp: u64,
}

impl SimGroup {
    fn live_bytes(&self, seqs: &[SimSeq], bpt: usize) -> usize {
        self.active
            .iter()
            .map(|&i| seqs[i].resident_tokens() * bpt)
            .sum()
    }

    /// Admission-time projection: every active sequence at the larger
    /// of its resident footprint and its prefill target (mirrors the
    /// scheduler projecting `resume_tokens` bytes for admitted work
    /// that has not materialized yet).
    fn projected_bytes(&self, seqs: &[SimSeq], bpt: usize) -> usize {
        self.active
            .iter()
            .map(|&i| seqs[i].resident_tokens().max(seqs[i].target) * bpt)
            .sum()
    }

    fn in_flight(&self) -> usize {
        self.waiting.len() + self.active.len()
    }
}

/// Replay `trace` through the virtual scheduler; pure and
/// deterministic — same `(trace, cfg)` ⇒ identical report.
pub fn replay(trace: &[TraceRequest], cfg: &ReplayConfig) -> ReplayReport {
    let bpt = cfg.bytes_per_token;
    let budget = cfg.kv_budget_bytes;
    let thr = cfg.swap_threshold_bytes_per_token;
    let mut seqs: Vec<SimSeq> = trace
        .iter()
        .map(|r| SimSeq {
            prompt: r.prompt_tokens(),
            max_new: r.max_new_tokens.max(1),
            arrival: r.arrival_s,
            deadline: r.deadline_ms.map(|d| r.arrival_s + d as f64 / 1e3),
            target: r.prompt_tokens(),
            consumed: 0,
            fresh: 0,
            generated: 0,
            swapped: false,
            admit_stamp: 0,
            first_token: None,
            done: None,
            preemptions: 0,
            swaps: 0,
        })
        .collect();
    let mut groups: Vec<SimGroup> = (0..cfg.groups.max(1))
        .map(|_| SimGroup {
            waiting: VecDeque::new(),
            active: Vec::new(),
            next_stamp: 0,
        })
        .collect();

    let mut report = ReplayReport {
        outcomes: Vec::new(),
        makespan_s: 0.0,
        generated_tokens: 0,
        prefill_tokens: 0,
        preemptions: 0,
        swap_preemptions: 0,
        swap_bytes_out: 0,
        deadline_aborts: 0,
        ticks: 0,
    };

    let mut t = 0.0f64;
    let mut next_arrival = 0usize;
    loop {
        // Drain arrivals due by now onto the group with the most KV
        // headroom (ties: fewest in-flight, then lowest group id) —
        // the supervisor's placement rule.
        while next_arrival < trace.len()
            && trace[next_arrival].arrival_s <= t
        {
            let mut best = 0usize;
            let mut best_key = (0usize, usize::MAX, usize::MAX);
            for (g, grp) in groups.iter().enumerate() {
                let headroom =
                    budget.saturating_sub(grp.live_bytes(&seqs, bpt));
                let key = (
                    headroom,
                    usize::MAX - grp.in_flight(),
                    usize::MAX - g,
                );
                if g == 0 || key > best_key {
                    best = g;
                    best_key = key;
                }
            }
            groups[best].waiting.push_back(next_arrival);
            next_arrival += 1;
        }

        let busy = groups.iter().any(|g| g.in_flight() > 0);
        if !busy {
            if next_arrival >= trace.len() {
                break;
            }
            // Idle: jump the virtual clock to the next arrival.
            t = trace[next_arrival].arrival_s;
            continue;
        }

        // One lockstep tick across groups; the slowest group's cost
        // sets the global tick length.
        report.ticks += 1;
        let mut max_dt = 0.0f64;
        let mut first_tokens: Vec<usize> = Vec::new();
        let mut finished: Vec<usize> = Vec::new();
        for grp in groups.iter_mut() {
            let mut pf_tokens = 0usize;
            let mut decoded = 0usize;
            let mut swap_bytes = 0usize;

            // Deadline sweep (tick start, all lifecycle stages).
            let expired = |s: &SimSeq| s.deadline.is_some_and(|d| t >= d);
            for &i in grp.waiting.iter().chain(grp.active.iter()) {
                if expired(&seqs[i]) {
                    seqs[i].done = Some((t, false));
                    report.deadline_aborts += 1;
                }
            }
            grp.waiting.retain(|&i| seqs[i].done.is_none());
            grp.active.retain(|&i| seqs[i].done.is_none());

            // Youngest-victim preemption while over budget (never down
            // to an empty group).
            while budget > 0
                && grp.live_bytes(&seqs, bpt) > budget
                && grp.active.len() > 1
            {
                let (pos, &victim) = grp
                    .active
                    .iter()
                    .enumerate()
                    .max_by_key(|(_, &i)| seqs[i].admit_stamp)
                    .unwrap();
                grp.active.remove(pos);
                let s = &mut seqs[victim];
                let resident_bytes = s.resident_tokens() * bpt;
                if thr > 0 && resident_bytes <= s.resume_tokens() * thr {
                    s.swapped = true;
                    report.swap_preemptions += 1;
                    report.swap_bytes_out += resident_bytes as u64;
                    swap_bytes += resident_bytes;
                    s.swaps += 1;
                } else {
                    s.target = s.resume_tokens();
                    s.consumed = 0;
                    s.fresh = 0;
                    s.swapped = false;
                }
                s.preemptions += 1;
                report.preemptions += 1;
                grp.waiting.push_front(victim);
            }

            // FIFO admission under the byte projection; a sequence
            // that fits nowhere still runs alone (the real scheduler
            // reserves OOM for can't-fit-alone).
            while let Some(&front) = grp.waiting.front() {
                if grp.active.len() >= cfg.max_batch {
                    break;
                }
                let need = if seqs[front].swapped {
                    seqs[front].resident_tokens() * bpt
                } else {
                    seqs[front].target * bpt
                };
                let fits = budget == 0
                    || grp.active.is_empty()
                    || grp.projected_bytes(&seqs, bpt) + need <= budget;
                if !fits {
                    break;
                }
                grp.waiting.pop_front();
                let s = &mut seqs[front];
                s.admit_stamp = grp.next_stamp;
                grp.next_stamp += 1;
                if s.swapped {
                    // Restore from host: bytes come back, decoding
                    // resumes without recompute.
                    swap_bytes += s.resident_tokens() * bpt;
                    s.swapped = false;
                }
                grp.active.push(front);
            }

            // One prefill chunk: least-progressed job first (the
            // scheduler's round-robin serves the most starved job).
            let job = grp
                .active
                .iter()
                .copied()
                .filter(|&i| seqs[i].consumed < seqs[i].target)
                .min_by_key(|&i| (seqs[i].consumed, seqs[i].admit_stamp));
            let mut completed_prefill = None;
            if let Some(i) = job {
                let s = &mut seqs[i];
                let chunk =
                    cfg.prefill_chunk.max(1).min(s.target - s.consumed);
                s.consumed += chunk;
                pf_tokens += chunk;
                if s.consumed == s.target {
                    // Prefill yields the first new token
                    // (`note_prefilled` in the real engine).
                    s.fresh += 1;
                    s.generated += 1;
                    report.generated_tokens += 1;
                    completed_prefill = Some(i);
                    first_tokens.push(i);
                    if s.generated >= s.max_new {
                        finished.push(i);
                    }
                }
            }

            // Decode: one token per fully-prefilled active sequence
            // (the one that just finished prefill already got its
            // token from the prefill logits).
            for &i in &grp.active {
                let s = &mut seqs[i];
                if s.consumed < s.target
                    || Some(i) == completed_prefill
                    || s.generated >= s.max_new
                {
                    continue;
                }
                s.fresh += 1;
                s.generated += 1;
                report.generated_tokens += 1;
                decoded += 1;
                if s.generated >= s.max_new {
                    finished.push(i);
                }
            }
            report.prefill_tokens += pf_tokens as u64;

            let dt = cfg.t_tick_base
                + pf_tokens as f64 * cfg.t_prefill_token
                + decoded as f64 * cfg.t_decode_token
                + swap_bytes as f64 * cfg.t_swap_byte;
            if dt > max_dt {
                max_dt = dt;
            }
        }

        let t_end = t + max_dt;
        for i in first_tokens {
            if seqs[i].first_token.is_none() {
                seqs[i].first_token = Some(t_end);
            }
        }
        for i in finished {
            if seqs[i].done.is_none() {
                seqs[i].done = Some((t_end, true));
            }
        }
        for grp in groups.iter_mut() {
            grp.active.retain(|&i| seqs[i].done.is_none());
        }
        t = t_end;
    }

    // Fold terminal states into per-request outcomes (trace order).
    let mut makespan = 0.0f64;
    for (r, s) in trace.iter().zip(&seqs) {
        let (end, ok) = s.done.unwrap_or((t, false));
        if end > makespan {
            makespan = end;
        }
        let ttft = s.first_token.map_or(0.0, |ft| ft - s.arrival);
        let e2e = end - s.arrival;
        let tpot = if s.generated >= 2 {
            (end - s.first_token.unwrap_or(end)) / (s.generated - 1) as f64
        } else {
            0.0
        };
        report.outcomes.push(RequestOutcome {
            class: r.class.clone(),
            ttft_s: ttft,
            tpot_s: tpot,
            e2e_s: e2e,
            generated: s.generated,
            ok,
            deadline_ms: r.deadline_ms,
            preemptions: s.preemptions,
            swaps: s.swaps,
            rescues: 0,
        });
    }
    report.makespan_s = makespan;
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::slo::summarize;
    use crate::workload::trace::{
        generate, pinned, ArrivalProcess, TenantClass, TraceSpec,
    };

    fn outcome_key(o: &RequestOutcome) -> (u64, u64, u64, usize, bool) {
        (
            o.ttft_s.to_bits(),
            o.e2e_s.to_bits(),
            o.tpot_s.to_bits(),
            o.generated,
            o.ok,
        )
    }

    #[test]
    fn replay_is_deterministic_bit_for_bit() {
        let trace = generate(&pinned());
        let cfg = ReplayConfig::default();
        let a = replay(&trace, &cfg);
        let b = replay(&trace, &cfg);
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
        assert_eq!(a.generated_tokens, b.generated_tokens);
        assert_eq!(a.preemptions, b.preemptions);
        assert_eq!(a.ticks, b.ticks);
        for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
            assert_eq!(outcome_key(x), outcome_key(y));
        }
    }

    #[test]
    fn every_request_reaches_exactly_one_terminal_outcome() {
        let trace = generate(&pinned());
        let rep = replay(&trace, &ReplayConfig::default());
        assert_eq!(rep.outcomes.len(), trace.len());
        for o in &rep.outcomes {
            assert!(o.e2e_s >= 0.0);
            assert!(o.ok || o.generated < 200, "{o:?}");
            if o.ok {
                assert!(o.generated >= 1);
                assert!(o.ttft_s > 0.0);
                assert!(o.e2e_s >= o.ttft_s);
            }
        }
        assert!(rep.tokens_per_s() > 0.0);
    }

    /// Satellite coverage: an interactive class keeps its TTFT SLO
    /// while a long-reasoning burst saturates the KV budget — asserted
    /// through the per-class SLO stats, with the batch class absorbing
    /// the preemptions.
    #[test]
    fn interactive_ttft_slo_survives_batch_burst() {
        let spec = TraceSpec {
            seed: 77,
            horizon_s: 20.0,
            classes: vec![
                TenantClass {
                    name: "interactive".to_string(),
                    arrival: ArrivalProcess::Poisson { rate: 4.0 },
                    pairs: (3, 4),
                    hops: (1, 1),
                    max_new: (8, 12),
                    deadline_ms: Some(2500),
                },
                TenantClass {
                    name: "batch-reasoning".to_string(),
                    arrival: ArrivalProcess::OnOff {
                        rate_on: 5.0,
                        mean_on_s: 3.0,
                        mean_off_s: 4.0,
                    },
                    pairs: (12, 16),
                    hops: (3, 4),
                    max_new: (64, 96),
                    deadline_ms: None,
                },
            ],
        };
        let trace = generate(&spec);
        let cfg = ReplayConfig {
            kv_budget_bytes: 256 * 1024,
            swap_threshold_bytes_per_token: 4096,
            ..ReplayConfig::default()
        };
        let rep = replay(&trace, &cfg);
        // The burst really saturates the budget: preemptions happened.
        assert!(rep.preemptions > 0, "burst never hit the KV budget");
        let slos = summarize(&rep.outcomes, rep.makespan_s);
        let find = |name: &str| {
            slos.iter().find(|s| s.class == name).unwrap_or_else(|| {
                panic!("missing class {name} in {slos:?}")
            })
        };
        let inter = find("interactive");
        let batch = find("batch-reasoning");
        // Interactive keeps its SLO through the burst...
        assert!(
            inter.ttft.p95 < 2.5,
            "interactive p95 TTFT {}s blows the 2.5s deadline",
            inter.ttft.p95
        );
        assert!(
            inter.attainment > 0.9,
            "interactive attainment {}",
            inter.attainment
        );
        // ...while the burst class absorbs the disruption: preemption
        // lands on the youngest big sequences, not the short ones.
        assert!(
            batch.preemptions >= inter.preemptions,
            "batch {} vs interactive {} preemptions",
            batch.preemptions,
            inter.preemptions
        );
        assert!(batch.e2e.p95 > inter.e2e.p95);
    }

    #[test]
    fn swap_threshold_zero_recomputes_instead_of_swapping() {
        let trace = generate(&pinned());
        let mut cfg = ReplayConfig {
            kv_budget_bytes: 192 * 1024,
            swap_threshold_bytes_per_token: 0,
            ..ReplayConfig::default()
        };
        let rec = replay(&trace, &cfg);
        assert!(rec.preemptions > 0, "budget never binds");
        assert_eq!(rec.swap_preemptions, 0);
        cfg.swap_threshold_bytes_per_token = usize::MAX;
        let swp = replay(&trace, &cfg);
        assert!(swp.swap_preemptions > 0);
        // Swapping spares the prefill recomputation the recompute run
        // pays for.
        assert!(swp.prefill_tokens < rec.prefill_tokens);
    }

    #[test]
    fn multi_group_spreads_load_and_finishes_everything() {
        let trace = generate(&pinned());
        let one = replay(&trace, &ReplayConfig::default());
        let three = replay(
            &trace,
            &ReplayConfig { groups: 3, ..ReplayConfig::default() },
        );
        assert_eq!(three.outcomes.len(), trace.len());
        // More groups never slow the virtual makespan.
        assert!(three.makespan_s <= one.makespan_s + 1e-9);
        let slos = summarize(&three.outcomes, three.makespan_s);
        for s in &slos {
            assert!(s.n > 0);
        }
    }
}
