fn main() {}
