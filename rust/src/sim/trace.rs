//! Policy traces over synthetic attention: runs the *real* eviction
//! policies (the same objects the live engine uses) over a synthetic
//! decode-long attention stream with the statistical structure the paper
//! observes in reasoning models — a few persistent heavy hitters, strong
//! recency bias, layer-dependent sharpness, and slow drift of which
//! tokens matter (the "temporal inconsistency" motivating RASR).
//!
//! Output: retained-token trajectories per layer, which the [`super`]
//! simulator turns into memory/latency numbers for the big models.

use crate::config::ServingConfig;
use crate::policy::{make_policy, LayerState, PolicyKind};
use crate::util::prng::Rng;

#[derive(Clone, Debug)]
pub struct TraceConfig {
    pub n_layers: usize,
    pub prompt_len: usize,
    pub gen_len: usize,
    /// Fraction of tokens that are heavy hitters.
    pub hitter_frac: f64,
    /// Recency decay scale (tokens).
    pub recency_scale: f64,
    /// Hard capacity (the simulator's OOM line is separate; this only
    /// bounds adaptive thresholds).
    pub capacity: usize,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            n_layers: 32,
            prompt_len: 512,
            gen_len: 4096,
            hitter_frac: 0.03,
            recency_scale: 64.0,
            capacity: 1 << 20,
            seed: 0xA100,
        }
    }
}

#[derive(Clone, Debug)]
pub struct PolicyTrace {
    /// retained[t] = mean retained tokens per layer after step t.
    pub retained: Vec<f64>,
    /// Per-layer retained counts at the final step.
    pub final_per_layer: Vec<usize>,
    pub prune_events: usize,
}

impl PolicyTrace {
    pub fn mean_retained(&self) -> f64 {
        if self.retained.is_empty() {
            return 0.0;
        }
        self.retained.iter().sum::<f64>() / self.retained.len() as f64
    }

    pub fn final_retained(&self) -> f64 {
        *self.retained.last().unwrap_or(&0.0)
    }
}

/// Per-layer synthetic stream state.
struct LayerStream {
    /// Per-slot: base weight (heavy hitters get large weights).
    weight: Vec<f32>,
    /// Per-slot: original position.
    pos: Vec<i32>,
    /// Accumulated (gamma-decayed) scores, aligned with slots.
    scores: Vec<f32>,
    /// Layer-specific attention sharpness in [0.5, 2.0]; non-monotone
    /// across depth (paper Fig. 1).
    sharpness: f32,
}

impl LayerStream {
    fn step_scores(&mut self, t: usize, recency: f64, rng: &mut Rng,
                   buf: &mut Vec<f32>) {
        // Raw attention logits: base weight ^ sharpness + recency bias +
        // cheap uniform jitter; softmax-normalised like real attention
        // rows. (Box–Muller noise was the hot spot at 20k-step traces —
        // uniform jitter preserves the distributional shape that matters
        // here: heavy-hitter separation + recency mass.)
        let n = self.weight.len();
        buf.clear();
        buf.resize(n, 0.0);
        let inv_rec = -(1.0 / recency) as f32;
        let mut m = f32::MIN;
        for j in 0..n {
            let age = (t as i64 - self.pos[j] as i64).max(0) as f32;
            let rec = (age * inv_rec).exp();
            let jitter = 0.6 * (rng.f32() - 0.5);
            let v = self.weight[j] * self.sharpness + 2.5 * rec + jitter;
            buf[j] = v;
            m = m.max(v);
        }
        let mut s = 0f32;
        for x in buf.iter_mut() {
            *x = (*x - m).exp();
            s += *x;
        }
        let inv = 1.0 / s.max(1e-20);
        for x in buf.iter_mut() {
            *x *= inv;
        }
    }
}

/// Run one policy over a synthetic generation; returns its retained
/// trajectory. All layers share a token stream but have independent
/// sharpness/weights, so layerwise policies differentiate.
pub fn run_trace(
    kind: PolicyKind,
    cfg: &ServingConfig,
    tc: &TraceConfig,
) -> PolicyTrace {
    // FullKV needs no simulation: retained == prompt + generated.
    if matches!(kind, PolicyKind::FullKv) {
        let retained: Vec<f64> = (1..=tc.gen_len)
            .map(|t| (tc.prompt_len + t) as f64)
            .collect();
        return PolicyTrace {
            final_per_layer: vec![tc.prompt_len + tc.gen_len; tc.n_layers],
            retained,
            prune_events: 0,
        };
    }
    // Layer subsampling: per-layer streams are statistically independent,
    // so simulating min(n_layers, 8) representative layers and reporting
    // per-layer means preserves the retained-token statistics while
    // keeping 20k-step × 80-layer traces tractable.
    let tc = TraceConfig { n_layers: tc.n_layers.min(8), ..tc.clone() };
    let tc = &tc;
    let mut rng = Rng::new(tc.seed);
    let mut policy = make_policy(kind, cfg, tc.n_layers);
    let gamma = policy.gamma();

    let mut layers: Vec<LayerStream> = (0..tc.n_layers)
        .map(|l| {
            // Non-monotone sharpness profile: mid layers denser
            // (paper Fig. 1a), plus jitter. The absolute scale is set so
            // heavy-hitter/tail score ratios span the paper's regime
            // (sparse layers >> τ=400, dense layers < τ) — see the
            // DESIGN.md §4 note on trace calibration.
            let x = l as f32 / tc.n_layers.max(2) as f32;
            let sharpness = 2.4
                - 1.4 * (std::f32::consts::PI * x).sin().abs()
                + 0.3 * rng.f32();
            LayerStream {
                weight: Vec::new(),
                pos: Vec::new(),
                scores: Vec::new(),
                sharpness,
            }
        })
        .collect();

    // Helper to append a token to every layer.
    let push_token = |layers: &mut Vec<LayerStream>, t: usize, rng: &mut Rng| {
        for ls in layers.iter_mut() {
            let heavy = rng.bool(tc.hitter_frac);
            let w = if heavy { 4.0 + 2.0 * rng.f32() } else { rng.f32() * 0.5 };
            ls.weight.push(w);
            ls.pos.push(t as i32);
            ls.scores.push(0.0);
        }
    };

    for t in 0..tc.prompt_len {
        push_token(&mut layers, t, &mut rng);
    }

    let mut retained = Vec::with_capacity(tc.gen_len);
    let mut prune_events = 0usize;
    let mut probs: Vec<f32> = Vec::new();
    for step in 0..tc.gen_len {
        let t = tc.prompt_len + step;
        push_token(&mut layers, t, &mut rng);
        let mut live_sum = 0usize;
        for (l, ls) in layers.iter_mut().enumerate() {
            ls.step_scores(t, tc.recency_scale, &mut rng, &mut probs);
            for (s, &p) in ls.scores.iter_mut().zip(&probs) {
                *s = gamma * *s + p;
            }
            let sparsity = crate::attn::sparsity::hoyer_sparsity(&probs);
            let st = LayerState {
                scores: &ls.scores,
                pos: &ls.pos,
                len: ls.scores.len(),
                step,
                sparsity,
                capacity: tc.capacity,
            };
            if let Some(keep) = policy.plan(l, &st) {
                let mut ks = keep;
                ks.sort_unstable();
                ks.dedup();
                ls.weight = ks.iter().map(|&i| ls.weight[i]).collect();
                ls.pos = ks.iter().map(|&i| ls.pos[i]).collect();
                ls.scores = ks.iter().map(|&i| ls.scores[i]).collect();
                prune_events += 1;
            }
            live_sum += ls.scores.len();
        }
        retained.push(live_sum as f64 / tc.n_layers as f64);
    }

    PolicyTrace {
        retained,
        final_per_layer: layers.iter().map(|l| l.scores.len()).collect(),
        prune_events,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ServingConfig {
        let mut c = ServingConfig::default();
        c.baseline.budget = 512;
        c.lethe.evict_threshold = 256;
        c
    }

    fn tc() -> TraceConfig {
        TraceConfig {
            n_layers: 8,
            prompt_len: 128,
            gen_len: 600,
            ..TraceConfig::default()
        }
    }

    #[test]
    fn fullkv_retains_everything() {
        let tr = run_trace(PolicyKind::FullKv, &cfg(), &tc());
        assert_eq!(tr.prune_events, 0);
        assert!((tr.final_retained() - (128.0 + 600.0)).abs() < 1e-9);
    }

    #[test]
    fn streaming_plateaus_at_budget() {
        let tr = run_trace(PolicyKind::StreamingLlm, &cfg(), &tc());
        assert!(tr.final_retained() <= 512.0 + 1.0);
        assert!(tr.prune_events > 0);
    }

    #[test]
    fn lethe_prunes_and_stays_bounded() {
        let tr = run_trace(PolicyKind::Lethe, &cfg(), &tc());
        assert!(tr.prune_events > 0, "lethe never pruned");
        // Multi-round pruning keeps the cache well under FullKV.
        assert!(
            tr.final_retained() < 0.8 * 728.0,
            "final {}",
            tr.final_retained()
        );
        // And the trajectory plateaus: the last quarter grows much slower
        // than FullKV's linear growth.
        let q = tr.retained.len() / 4;
        let tail_growth =
            tr.retained.last().unwrap() - tr.retained[tr.retained.len() - q];
        assert!(tail_growth < 0.8 * q as f64, "tail growth {tail_growth}");
    }

    #[test]
    fn h2o_respects_budget_eventually() {
        let tr = run_trace(PolicyKind::H2o, &cfg(), &tc());
        assert!(tr.final_retained() <= 513.0);
    }

    #[test]
    #[ignore] // diagnostic probe: cargo test probe_20k -- --ignored --nocapture
    fn probe_20k_retention() {
        let mut cfg = crate::config::ServingConfig::default();
        cfg.baseline.budget = 768;
        cfg.lethe.evict_threshold = 512;
        cfg.lethe.sink_len = 16;
        let tcfg = TraceConfig {
            n_layers: 80,
            prompt_len: 512,
            gen_len: 20_000,
            ..TraceConfig::default()
        };
        let tr = run_trace(crate::policy::PolicyKind::Lethe, &cfg, &tcfg);
        println!(
            "lethe: mean {:.0} final {:.0} events {}",
            tr.mean_retained(),
            tr.final_retained(),
            tr.prune_events
        );
        for (i, r) in tr.retained.iter().enumerate() {
            if i % 4000 == 0 {
                println!("  t={i} retained={r:.0}");
            }
        }
    }

    #[test]
    fn per_layer_retention_differs_for_lethe_not_for_streaming() {
        let lethe = run_trace(PolicyKind::Lethe, &cfg(), &tc());
        let min = *lethe.final_per_layer.iter().min().unwrap();
        let max = *lethe.final_per_layer.iter().max().unwrap();
        assert!(max > min, "lethe should allocate per layer");
        let s = run_trace(PolicyKind::StreamingLlm, &cfg(), &tc());
        let smin = *s.final_per_layer.iter().min().unwrap();
        let smax = *s.final_per_layer.iter().max().unwrap();
        assert_eq!(smin, smax, "streaming is layer-agnostic");
    }
}
