//! Release-mode soak smoke: a churn workload of mixed-length prompts
//! over-subscribing the decode group under a tight KV byte budget and a
//! sparsity-directed `kv.mixed` format rule. Asserts the acceptance
//! criteria of the sequence-lifecycle serving core in one sustained
//! run with no idle window:
//!
//!   * over-subscription produces preempt/resume events and **zero**
//!     OOM-kills (`FinishReason::Oom` stays reserved for sequences
//!     that cannot fit even alone),
//!   * the `kv.mixed` map migrates layer formats **on a busy group** —
//!     `metrics.kv_layer_formats` changes while the same `GroupCache`
//!     (no rebuild) keeps serving,
//!   * decode steps keep landing during a long prompt's chunked
//!     prefill.
//!
//! Skipped (with a notice) when artifacts are not built; CI runs the
//! suite in release mode so this exercises the optimized scheduler.

use std::path::Path;
use std::time::{Duration, Instant};

use lethe::bench_support::{
    replay_trace, run_churn, sum_group_rows, write_bench_json,
    BenchJsonRow,
};
use lethe::config::{MixedKvRule, ServingConfig};
use lethe::engine::FinishReason;
use lethe::kvcache::KvFormat;
use lethe::policy::PolicyKind;
use lethe::server::{GenerateRequest, Server};
use lethe::sim::replay::{replay, ReplayConfig};
use lethe::util::prng::Rng;
use lethe::workload::make_task;
use lethe::workload::slo::summarize;
use lethe::workload::trace::{generate, pinned, trace_fingerprint};

#[test]
fn churn_soak_preempts_resumes_and_migrates_without_oom() {
    let dir = Path::new("artifacts");
    if !dir.join("model_meta.json").exists() {
        eprintln!("[skip] run `make artifacts` first");
        return;
    }
    let mut cfg = ServingConfig::default();
    cfg.scheduler.max_batch = 4;
    cfg.scheduler.prefill_chunk = 24;
    // Hysteresis long enough that the first co-residency preemption
    // (priced at the boot-time all-dense rates) lands before the mixed
    // map compresses the cache.
    cfg.scheduler.migrate_patience = 30;
    cfg.kv.mixed = Some(MixedKvRule {
        sparse: KvFormat::QuantI4,
        dense: KvFormat::F32,
        threshold: 0.1,
    });
    let rt = lethe::runtime::Runtime::load(dir).expect("runtime loads");
    let tok = lethe::model::Tokenizer::from_meta(&rt.meta).unwrap();
    let mut engine = lethe::engine::Engine::new(rt, cfg).unwrap();

    // Mixed-length churn: two long multi-hop prompts up front (the
    // pressure pair), then alternating short and long.
    let mut rng = Rng::new(7);
    let tasks: Vec<_> = (0..12)
        .map(|i| {
            if i < 2 || i % 2 == 1 {
                make_task(&mut rng, 12, 3)
            } else {
                make_task(&mut rng, 4, 1)
            }
        })
        .collect();
    // Budget: the first two prompts at boot-time (all-dense) rates plus
    // one decode row. Admission (which projects live + in-flight +
    // candidate bytes) legitimately accepts both, and their combined
    // decode growth crosses the budget within a few steps — forcing a
    // recompute-preemption instead of an OOM-kill.
    let lens: Vec<usize> = tasks
        .iter()
        .map(|t| tok.encode_prompt(&t.prompt).unwrap().len())
        .collect();
    let row = engine.rt.meta.kv_bytes_per_token();
    engine.cfg.scheduler.kv_budget_bytes = (lens[0] + lens[1] + 1) * row;
    // This soak pins the recompute-preemption path (the chaos soak
    // below exercises swap); keep it pinned regardless of the swap
    // threshold's tuned default.
    engine.cfg.scheduler.swap_threshold_bytes_per_token = 0;

    let boot_formats = engine.metrics.kv_layer_formats.clone();
    let (stats, completions) =
        run_churn(&mut engine, &tok, PolicyKind::Lethe, &tasks, 16).unwrap();

    // Every request completes; none is OOM-killed.
    assert_eq!(completions.len(), tasks.len());
    let mut ids: Vec<u64> = completions.iter().map(|c| c.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..tasks.len() as u64).collect::<Vec<_>>());
    assert_eq!(stats.oom_finishes, 0, "preemption must replace OOM-kills");
    assert_eq!(engine.metrics.ooms, 0);

    // Over-subscription really happened, and pressure was handled by
    // preempt/resume.
    assert!(stats.peak_queue_depth >= 1, "group was never over-subscribed");
    assert!(stats.preemptions >= 1, "budget never forced a preemption");
    assert!(stats.resumes >= 1, "no preempted sequence resumed");
    assert_eq!(stats.resumes, stats.preemptions);

    // The mixed map migrated on the busy group: per-layer formats
    // changed without a group rebuild (run_churn keeps one Scheduler —
    // and thus one GroupCache — for the whole run), while the core was
    // under load.
    assert!(stats.kv_migrations >= 1, "kv.mixed never migrated a layer");
    assert!(
        stats.busy_migrations >= 1,
        "no migration landed while the core was serving load"
    );
    assert_ne!(
        engine.metrics.kv_layer_formats, boot_formats,
        "metrics never observed a changed per-layer format map"
    );
    assert!(
        engine
            .metrics
            .kv_layer_formats
            .iter()
            .any(|&f| f == KvFormat::QuantI4),
        "no layer ended up in the sparse format"
    );
    assert_eq!(engine.metrics.kv_migrations, stats.kv_migrations);

    // Chunked prefill interleaved with decode in the same ticks.
    assert!(
        stats.interleaved_ticks >= 1,
        "no decode step landed during a chunked prefill"
    );

    // Group-aware accounting: the single-scheduler run fills exactly
    // one lane, and the lane sums reproduce the aggregates (the same
    // invariant the multi-group soak asserts over supervisor rows).
    assert_eq!(stats.lanes.len(), 1);
    let completions_sum: u64 =
        stats.lanes.iter().map(|l| l.completions).sum();
    let preemptions_sum: u64 =
        stats.lanes.iter().map(|l| l.preemptions).sum();
    let resumes_sum: u64 = stats.lanes.iter().map(|l| l.resumes).sum();
    let oom_sum: u64 = stats.lanes.iter().map(|l| l.oom_finishes).sum();
    assert_eq!(completions_sum, completions.len() as u64);
    assert_eq!(preemptions_sum, stats.preemptions);
    assert_eq!(resumes_sum, stats.resumes);
    assert_eq!(oom_sum, stats.oom_finishes as u64);
}

/// Chaos soak: the same churn shape with seeded fault injection live at
/// every engine seam (KV-insert alloc, runtime execute, tick stalls)
/// and swap-to-host preemption forced on. Every request must still
/// reach exactly one typed completion — an injected failure finishes
/// its own sequence with `FinishReason::Error(..)` and frees the slot
/// instead of poisoning the tick or hanging the run.
///
/// The fault seed comes from `LETHE_FAULT_SEED` (CI runs a small seed
/// matrix in release mode), defaulting to 1; the same seed replays the
/// same fault schedule.
#[test]
fn chaos_soak_fault_injection_yields_typed_completions() {
    let dir = Path::new("artifacts");
    if !dir.join("model_meta.json").exists() {
        eprintln!("[skip] run `make artifacts` first");
        return;
    }
    let seed: u64 = std::env::var("LETHE_FAULT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    let mut cfg = ServingConfig::default();
    cfg.scheduler.max_batch = 4;
    cfg.scheduler.prefill_chunk = 24;
    // Make every preemption take the swap-to-host path (no per-token
    // cost can beat an unbeatable threshold), so serialization/restore
    // runs under injection too.
    cfg.scheduler.swap_threshold_bytes_per_token = usize::MAX;
    cfg.faults.seed = seed;
    cfg.faults.rate = 0.05;
    cfg.faults.stall_ms = 1;
    // CI runs this soak in two flavors: pipelined decode (the default)
    // and LETHE_PIPELINE=0, which pins the fully serial step. The fault
    // schedule is mode-independent (uniform end-of-step pre-draw), so
    // both flavors replay the same injected faults per seed.
    if std::env::var("LETHE_PIPELINE").as_deref() == Ok("0") {
        cfg.engine.pipeline_decode = false;
    }
    let rt = lethe::runtime::Runtime::load(dir).expect("runtime loads");
    let tok = lethe::model::Tokenizer::from_meta(&rt.meta).unwrap();
    let mut engine = lethe::engine::Engine::new(rt, cfg).unwrap();

    // Mixed-length churn: long multi-hop prompts interleaved with short
    // ones, over-subscribing the group.
    let mut rng = Rng::new(11);
    let tasks: Vec<_> = (0..12)
        .map(|i| {
            if i < 2 || i % 2 == 1 {
                make_task(&mut rng, 12, 3)
            } else {
                make_task(&mut rng, 4, 1)
            }
        })
        .collect();
    // Tight budget (pressure pair + one decode row) so preemption — and
    // with the threshold above, swap-out/restore — happens under fire.
    let lens: Vec<usize> = tasks
        .iter()
        .map(|t| tok.encode_prompt(&t.prompt).unwrap().len())
        .collect();
    let row = engine.rt.meta.kv_bytes_per_token();
    engine.cfg.scheduler.kv_budget_bytes = (lens[0] + lens[1] + 1) * row;

    let (stats, completions) =
        run_churn(&mut engine, &tok, PolicyKind::Lethe, &tasks, 16).unwrap();

    // No request is lost: every submitted id reaches exactly one
    // completion, failed or not.
    assert_eq!(completions.len(), tasks.len());
    let mut ids: Vec<u64> = completions.iter().map(|c| c.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..tasks.len() as u64).collect::<Vec<_>>());

    // The plan actually fired (rate 0.05 over hundreds of draws).
    assert!(
        engine.metrics.faults_injected > 0,
        "no fault was injected (seed {seed})"
    );

    // Failure accounting is exact: every Error finish is counted as a
    // sequence failure and nothing else is.
    let failed = completions
        .iter()
        .filter(|c| matches!(c.finish, FinishReason::Error(_)))
        .count() as u64;
    assert_eq!(
        failed, engine.metrics.seq_failures,
        "seq_failures must equal Error-finished completions (seed {seed})"
    );

    // Lifecycle invariants survive the chaos: every preemption swapped
    // (the threshold forces it), every swapped sequence came back, and
    // the bytes restored match the bytes swapped out.
    assert_eq!(stats.resumes, stats.preemptions);
    assert_eq!(engine.metrics.swap_preemptions, stats.preemptions);
    assert_eq!(engine.metrics.swap_bytes_in, engine.metrics.swap_bytes_out);

    // Injected faults surface as typed Error finishes, never as
    // OOM-kills or hangs.
    assert_eq!(stats.oom_finishes, 0, "faults must surface as Error, not Oom");
}

/// Multi-group chaos soak: three supervised decode groups under seeded
/// group-level fault injection (`faults.group_rate` arms the GroupPanic
/// and GroupStall seams) with stall detection on. Asserts the
/// supervision acceptance criteria in one sustained run:
///
///   * every submitted request reaches **exactly one** typed completion
///     — rescued across groups, typed-failed, or typed-rejected, never
///     lost, hung, or OOM-killed;
///   * the per-group stats rows sum to the aggregate supervision
///     counters (the bookkeeping balances across groups and restarts);
///   * a quarantined group restarts with backoff and returns to
///     `healthy` while its peers keep serving (forced deterministically
///     via the operator-quarantine lever, independent of the seed's
///     fault schedule).
///
/// The fault seed comes from `LETHE_FAULT_SEED` (CI runs a seed matrix
/// in release mode). Emits `bench_results/BENCH_table3.json` with the
/// run's throughput + rescue counters for the robustness trail.
#[test]
fn multi_group_chaos_soak_rescues_and_restarts() {
    let dir = Path::new("artifacts");
    if !dir.join("model_meta.json").exists() {
        eprintln!("[skip] run `make artifacts` first");
        return;
    }
    let seed: u64 = std::env::var("LETHE_FAULT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    let mut cfg = ServingConfig::default();
    cfg.scheduler.max_batch = 4;
    cfg.scheduler.prefill_chunk = 24;
    cfg.serving.groups = 3;
    cfg.serving.tick_timeout_ms = 250;
    // The soak is about recovery, not permanent death: a generous
    // restart budget with a short base backoff keeps every group
    // cycling through quarantine → restart → healthy under fire.
    cfg.serving.max_restarts = 100;
    cfg.serving.restart_backoff_ms = 50;
    cfg.faults.seed = seed;
    cfg.faults.group_rate = 0.02;
    let server = Server::start(cfg, PolicyKind::Lethe).unwrap();

    // Mixed-length churn across the groups.
    let mut rng = Rng::new(13);
    let tasks: Vec<_> = (0..18)
        .map(|i| {
            if i % 3 == 0 {
                make_task(&mut rng, 12, 3)
            } else {
                make_task(&mut rng, 4, 1)
            }
        })
        .collect();
    let t0 = Instant::now();
    let rxs: Vec<_> = tasks
        .iter()
        .map(|t| {
            server
                .submit(GenerateRequest {
                    prompt: t.prompt.clone(),
                    max_new_tokens: 16,
                    policy: None,
                    deadline_ms: None,
                    class: None,
                })
                .unwrap()
        })
        .collect();

    // Every request reaches exactly one typed completion: the reply
    // channel yields one result and then disconnects (the supervisor
    // dropped its sender).
    let mut ok_responses = Vec::new();
    for (i, rx) in rxs.into_iter().enumerate() {
        let res = rx
            .recv_timeout(Duration::from_secs(180))
            .unwrap_or_else(|e| panic!("request {i} never completed: {e}"));
        match res {
            Ok(resp) => {
                assert_ne!(
                    resp.finish, "Oom",
                    "request {i}: chaos must never surface as an OOM-kill"
                );
                ok_responses.push(resp);
            }
            Err(e) => {
                // Typed rejection (queue pressure / no serving group).
                let typed = e.downcast_ref::<lethe::error::EngineError>();
                assert!(
                    typed.is_some(),
                    "request {i}: untyped error {e:#}"
                );
            }
        }
        assert!(
            rx.recv_timeout(Duration::from_secs(1)).is_err(),
            "request {i} completed more than once"
        );
    }
    let wall_s = t0.elapsed().as_secs_f64();
    assert!(
        !ok_responses.is_empty(),
        "no request survived the chaos run (seed {seed})"
    );

    // Per-group rows sum to the aggregate counters — the supervision
    // bookkeeping balances across groups, rescues and restarts.
    let stats = server.stats().unwrap();
    let sums = sum_group_rows(&stats).unwrap();
    let m = stats.get("metrics").unwrap();
    let mg = |k: &str| m.get(k).unwrap().as_usize().unwrap() as u64;
    assert_eq!(sums.preemptions, mg("preemptions"));
    assert_eq!(sums.resumes, mg("resumes"));
    assert_eq!(sums.seq_failures, mg("seq_failures"));
    assert_eq!(sums.rescues, mg("rescued_seqs"));
    assert_eq!(sums.restarts, mg("group_restarts"));
    assert_eq!(
        sums.queue_depth,
        stats.get("queue_depth").unwrap().as_usize().unwrap()
    );
    assert_eq!(
        stats.get("groups").unwrap().as_arr().unwrap().len(),
        3,
        "stats must report one row per configured group"
    );

    // Deterministic quarantine → restart-with-backoff → healthy cycle,
    // independent of the seed's fault schedule: fence a serving group
    // via the operator lever and watch it come back. Groups fenced by
    // the chaos schedule may still be mid-restart, so poll for one
    // that is currently healthy.
    let deadline = Instant::now() + Duration::from_secs(60);
    let serving = loop {
        let s = server.stats().unwrap();
        let found = s
            .get("groups")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .position(|r| {
                r.get("health").unwrap().as_str().unwrap() == "healthy"
            });
        if let Some(g) = found {
            break g;
        }
        assert!(
            Instant::now() < deadline,
            "no group returned to healthy after the run"
        );
        std::thread::sleep(Duration::from_millis(25));
    };
    let quarantines_before = mg("group_quarantines");
    assert!(
        server.quarantine_group(serving).unwrap(),
        "operator quarantine of a healthy group must be accepted"
    );
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let s = server.stats().unwrap();
        let row = &s.get("groups").unwrap().as_arr().unwrap()[serving];
        let health = row.get("health").unwrap().as_str().unwrap().to_string();
        let restarts = row.get("restarts").unwrap().as_usize().unwrap();
        if health == "healthy" && restarts >= 1 {
            let q = s
                .get("metrics")
                .unwrap()
                .get("group_quarantines")
                .unwrap()
                .as_usize()
                .unwrap() as u64;
            assert!(q > quarantines_before, "quarantine was not counted");
            break;
        }
        assert!(
            Instant::now() < deadline,
            "group {serving} never restarted (health {health}, \
             {restarts} restarts)"
        );
        std::thread::sleep(Duration::from_millis(25));
    }

    // Peers kept serving through the cycle: a fresh request completes
    // (retrying the typed retryable rejections the chaos schedule can
    // still produce).
    let mut attempts = 0;
    let resp = loop {
        match server.generate(GenerateRequest {
            prompt: tasks[0].prompt.clone(),
            max_new_tokens: 8,
            policy: None,
            deadline_ms: None,
            class: None,
        }) {
            Ok(r) => break r,
            Err(e) => {
                attempts += 1;
                assert!(
                    attempts < 10,
                    "serving never resumed after the cycle: {e:#}"
                );
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    };
    assert_ne!(resp.finish, "Oom");

    // Robustness trail: BENCH_table3.json with the run's throughput and
    // rescue traffic.
    let gen_tokens: usize =
        ok_responses.iter().map(|r| r.generated_tokens).sum();
    let kv_format = stats
        .get("kv_format")
        .unwrap()
        .as_str()
        .unwrap()
        .to_string();
    write_bench_json(
        "table3",
        &[BenchJsonRow {
            name: format!("multi_group_chaos_seed{seed}"),
            kv_format,
            tokens_per_s: gen_tokens as f64 / wall_s.max(1e-9),
            upload_bytes_per_step: mg("rescue_bytes") as usize,
            extra: Vec::new(),
        }],
    )
    .unwrap();

    drop(server); // graceful drain
}

/// Trace-driven soak, sim backend (always runs — no artifacts needed):
/// the pinned multi-tenant trace replays through the virtual-time
/// scheduler twin bit-for-bit reproducibly, the per-class SLO summary
/// covers both tenant classes, and the rows round-trip through the
/// `BENCH_soak.json` writer schema the CI gate validates.
#[test]
fn pinned_trace_sim_soak_slo_rows_round_trip() {
    let trace = generate(&pinned());
    // The trace itself is stable (same fingerprint on regeneration) —
    // the CI gate depends on replaying the identical arrival schedule.
    assert_eq!(
        trace_fingerprint(&trace),
        trace_fingerprint(&generate(&pinned()))
    );

    let rep = replay(&trace, &ReplayConfig::default());
    let rep2 = replay(&trace, &ReplayConfig::default());
    assert_eq!(rep.makespan_s.to_bits(), rep2.makespan_s.to_bits());
    assert_eq!(rep.generated_tokens, rep2.generated_tokens);

    let slos = summarize(&rep.outcomes, rep.makespan_s);
    assert_eq!(slos.len(), 2, "both tenant classes must be represented");
    for s in &slos {
        assert_eq!(s.n, s.completed + s.aborted);
        assert!((0.0..=1.0).contains(&s.attainment), "{}", s.attainment);
        assert!(s.e2e.p50 <= s.e2e.p95 && s.e2e.p95 <= s.e2e.p99);
        assert!(s.goodput_tok_s > 0.0, "class {} made no progress", s.class);
    }

    // Per-class SLO fields ride a bench row's `extra` and come back out
    // of the written JSON intact — the exact schema the CI job gates.
    let rows: Vec<BenchJsonRow> = slos
        .iter()
        .map(|s| BenchJsonRow {
            name: format!("sim_soak_g1_{}", s.class),
            kv_format: "f32".into(),
            tokens_per_s: rep.tokens_per_s(),
            upload_bytes_per_step: 0,
            extra: s.to_fields(),
        })
        .collect();
    write_bench_json("soak_smoke", &rows).unwrap();
    let doc = lethe::util::json::parse(
        &std::fs::read_to_string("bench_results/BENCH_soak_smoke.json")
            .unwrap(),
    )
    .unwrap();
    let out = doc.get("rows").unwrap().as_arr().unwrap();
    assert_eq!(out.len(), slos.len());
    for (row, s) in out.iter().zip(&slos) {
        assert_eq!(row.get("class").unwrap().as_str().unwrap(), s.class);
        assert_eq!(
            row.get("requests").unwrap().as_usize().unwrap(),
            s.n
        );
        let p95 = row.get("ttft_p95_s").unwrap().as_f64().unwrap();
        assert!((p95 - s.ttft.p95).abs() < 1e-12);
        assert!(row.get("slo_attainment").is_ok());
        assert!(row.get("goodput_tok_s").is_ok());
    }
}

/// Trace-driven soak, real backend (artifact-gated): the pinned trace
/// replays open-loop through the real scheduler with tenant classes
/// and scaled deadlines attached; every request reaches a terminal
/// outcome and the per-class streaming tracks in `EngineMetrics` agree
/// with the exact per-class outcome counts.
#[test]
fn pinned_trace_replays_through_real_scheduler_with_class_stats() {
    let dir = Path::new("artifacts");
    if !dir.join("model_meta.json").exists() {
        eprintln!("[skip] run `make artifacts` first");
        return;
    }
    let cfg = ServingConfig::default();
    let rt = lethe::runtime::Runtime::load(dir).expect("runtime loads");
    let tok = lethe::model::Tokenizer::from_meta(&rt.meta).unwrap();
    let mut engine = lethe::engine::Engine::new(rt, cfg).unwrap();

    // Compress the 25 s trace ~10×; deadlines scale with it inside
    // replay_trace, so SLO semantics survive the compression.
    let trace = generate(&pinned());
    let (outcomes, makespan_s) = replay_trace(
        &mut engine,
        &tok,
        PolicyKind::Lethe,
        &trace,
        0.1,
    )
    .unwrap();
    assert_eq!(outcomes.len(), trace.len());
    assert!(makespan_s > 0.0);

    let slos = summarize(&outcomes, makespan_s);
    assert_eq!(slos.len(), 2);
    let done: usize = slos.iter().map(|s| s.completed).sum();
    assert!(done > 0, "nothing completed on the real path");
    for s in &slos {
        assert_eq!(s.n, s.completed + s.aborted);
    }

    // The scheduler folded every terminal event into the per-class
    // streaming tracks exactly once (satellite surface of
    // `{"stats": true}` → metrics.classes).
    for s in &slos {
        let track = engine
            .metrics
            .classes
            .iter()
            .find(|t| t.class == s.class)
            .unwrap_or_else(|| panic!("no metrics track for {}", s.class));
        // Admission-rejected requests never reach the scheduler, so the
        // track can only undercount relative to the trace-side view —
        // and only by the aborted (rejected) remainder.
        assert!(track.requests as usize <= s.n);
        assert!(track.requests as usize >= s.completed);
        assert_eq!(
            track.completed as usize, s.completed,
            "class {}: completions disagree", s.class
        );
    }

    // Pipelined decode (on by default) must actually overlap on the
    // pinned trace: steady-state decode dominates, so the drains at
    // prune rounds, finishes and composition changes leave well over
    // 80% of steps on the pre-submitted fast path. The two counters are
    // the satellite surface of `{"stats": true}`.
    let m = &engine.metrics;
    assert!(m.decode_steps > 0);
    let drains: u64 = m.pipeline_drains.values().sum();
    let frac = m.pipeline_overlapped_steps as f64 / m.decode_steps as f64;
    assert!(
        frac > 0.8,
        "only {:.1}% of {} decode steps overlapped (drains: {:?})",
        frac * 100.0,
        m.decode_steps,
        m.pipeline_drains,
    );
    assert!(
        m.pipeline_overlapped_steps + drains >= m.decode_steps,
        "every non-overlapped step must carry a drain reason \
         (overlapped {} + drains {} < steps {})",
        m.pipeline_overlapped_steps,
        drains,
        m.decode_steps,
    );
}
