//! Decode groups and per-sequence state. A group co-batches up to
//! `group_size` sequences over one [`GroupCache`]; active sequences are
//! kept front-packed (slot swap on completion) so the engine can run the
//! smallest compiled batch bucket.

use crate::attn::sparsity::SparsityTracker;
use crate::error::FailureKind;
use crate::kvcache::{CacheDims, FormatMap, GroupCache, KvFormat};
use crate::policy::{EvictionPolicy, PolicyKind};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    Eos,
    Length,
    /// Reserved for sequences that cannot fit even alone: the live
    /// cache of this single sequence exceeds the largest compiled
    /// capacity. Co-residency pressure is handled by recompute-
    /// preemption in the scheduler, never by an OOM kill.
    Oom,
    /// The request's `deadline_ms` elapsed (or the shutdown drain
    /// window closed) before the sequence finished; enforced at tick
    /// boundaries by the scheduler.
    DeadlineExceeded,
    /// The sequence failed (KV alloc, runtime execute, migration, slot
    /// panic, or an injected fault — see [`FailureKind`]) and was
    /// finished in place of poisoning the tick: its slot and KV rows
    /// are freed and every other sequence proceeds.
    Error(FailureKind),
}

/// Lifecycle of one sequence through the serving core. Owned by the
/// scheduler's state machine:
///
/// ```text
/// Waiting ──► Prefilling{consumed} ──► Decoding ──► Finished
///    ▲                                    │
///    └────────────── Preempted ◄──────────┘   (recompute on resume)
/// ```
///
/// `Prefilling` consumes the prompt chunk-wise (`scheduler.prefill_chunk`
/// tokens per tick) so long prompts interleave with decode steps; a
/// `Preempted` sequence re-enters `Waiting` carrying its generated
/// tokens, and its resume prefill recomputes prompt + generated so the
/// continuation is exactly the uncontended one (greedy decode).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeqPhase {
    /// In the waiting queue; no work done yet (or re-queued after a
    /// preemption).
    Waiting,
    /// Chunk-wise prompt processing: `consumed` prompt tokens done.
    Prefilling {
        /// Prompt tokens processed so far.
        consumed: usize,
    },
    /// Co-batched in the decode group, generating.
    Decoding,
    /// Evicted under co-residency pressure; waiting to resume.
    Preempted,
    /// Completed (`FinishReason` set) and reported.
    Finished,
}

/// One pruning round's record (Figure 3 / diagnostics).
#[derive(Clone, Copy, Debug)]
pub struct PruneEvent {
    pub layer: usize,
    pub step: usize,
    pub before: usize,
    pub after: usize,
}

pub struct SeqState {
    pub id: u64,
    pub policy: Box<dyn EvictionPolicy>,
    pub sparsity: SparsityTracker,
    /// Generated token ids (not including the prompt).
    pub generated: Vec<i32>,
    /// Next absolute position (prompt length + generated count).
    pub abs_pos: usize,
    pub last_token: i32,
    pub prompt_len: usize,
    pub steps: usize,
    pub max_new: usize,
    pub eos: i32,
    pub finished: Option<FinishReason>,
    pub prune_log: Vec<PruneEvent>,
    /// Lifecycle position (see [`SeqPhase`]); advanced by the scheduler
    /// and, on completion, by the token-accept bookkeeping.
    pub phase: SeqPhase,
    /// Original prompt token ids, kept so a recompute-preemption can
    /// re-prefill prompt + generated on resume.
    pub prompt: Vec<i32>,
    /// Monotonic admission stamp (set by the scheduler at install);
    /// the *youngest* sequence — largest stamp — is the preemption
    /// victim, minimizing recomputed work.
    pub admit_stamp: u64,
    /// How many times this sequence has been preempted and resumed.
    pub preemptions: u32,
    /// Wall-clock bookkeeping for latency metrics (set by the server).
    pub submitted_at: Option<std::time::Instant>,
    pub first_token_at: Option<std::time::Instant>,
    /// The instant the sequence actually finished (EOS / length cap /
    /// failure / deadline mark) — stamped where the terminal event
    /// happens, not at the tick boundary that reaps it, so TTFT/TPOT
    /// and e2e latency are measured at token granularity.
    pub finished_at: Option<std::time::Instant>,
    /// Absolute completion deadline (from the request's `deadline_ms`);
    /// the scheduler finishes the sequence with
    /// [`FinishReason::DeadlineExceeded`] at the first tick boundary
    /// past it. `None` = no deadline.
    pub deadline: Option<std::time::Instant>,
    /// Tenant-class label carried from the request for per-class SLO
    /// accounting; empty = unclassified.
    pub class: String,
}

impl SeqState {
    pub fn new(
        id: u64,
        policy: Box<dyn EvictionPolicy>,
        n_layers: usize,
        max_new: usize,
        eos: i32,
    ) -> SeqState {
        SeqState {
            id,
            policy,
            sparsity: SparsityTracker::new(n_layers, 0.25),
            generated: Vec::new(),
            abs_pos: 0,
            last_token: 0,
            prompt_len: 0,
            steps: 0,
            max_new,
            eos,
            finished: None,
            prune_log: Vec::new(),
            phase: SeqPhase::Waiting,
            prompt: Vec::new(),
            admit_stamp: 0,
            preemptions: 0,
            submitted_at: None,
            first_token_at: None,
            finished_at: None,
            deadline: None,
            class: String::new(),
        }
    }

    /// Finish this sequence with a typed failure; the scheduler reaps
    /// it like any other completion (slot and KV rows are freed).
    pub fn fail(&mut self, kind: FailureKind) {
        self.finished = Some(FinishReason::Error(kind));
        self.phase = SeqPhase::Finished;
        if self.finished_at.is_none() {
            self.finished_at = Some(std::time::Instant::now());
        }
    }

    /// Record prefill completion + the first generated token.
    pub fn note_prefilled(&mut self, prompt_len: usize, first_token: i32) {
        self.prompt_len = prompt_len;
        self.abs_pos = prompt_len;
        self.accept(first_token);
        if self.first_token_at.is_none() {
            self.first_token_at = Some(std::time::Instant::now());
        }
    }

    /// Record a decode-step token.
    pub fn note_token(&mut self, token: i32) {
        self.steps += 1;
        self.abs_pos += 1;
        self.accept(token);
    }

    fn accept(&mut self, token: i32) {
        self.generated.push(token);
        self.last_token = token;
        self.phase = SeqPhase::Decoding;
        if token == self.eos {
            self.finished = Some(FinishReason::Eos);
        } else if self.generated.len() >= self.max_new {
            self.finished = Some(FinishReason::Length);
        }
        if self.finished.is_some() {
            self.phase = SeqPhase::Finished;
            if self.finished_at.is_none() {
                self.finished_at = Some(std::time::Instant::now());
            }
        }
    }

    pub fn note_prune(&mut self, layer: usize, before: usize, after: usize) {
        self.prune_log.push(PruneEvent {
            layer,
            step: self.steps,
            before,
            after,
        });
    }

    pub fn is_done(&self) -> bool {
        self.finished.is_some()
    }
}

pub struct DecodeGroup {
    pub cache: GroupCache,
    pub seqs: Vec<SeqState>,
    /// Finished sequences reaped out of the active set.
    pub done: Vec<SeqState>,
    pub default_policy: PolicyKind,
}

impl DecodeGroup {
    /// Group over the dense f32 storage backend.
    pub fn new(dims: CacheDims, default_policy: PolicyKind) -> DecodeGroup {
        Self::with_format(dims, default_policy, KvFormat::F32)
    }

    /// Group with one uniform KV storage backend (`kv.format`).
    pub fn with_format(
        dims: CacheDims,
        default_policy: PolicyKind,
        fmt: KvFormat,
    ) -> DecodeGroup {
        Self::with_formats(dims, default_policy, FormatMap::uniform(dims.layers, fmt))
    }

    /// Group with a per-layer KV format map (`kv.layer_formats` /
    /// `kv.mixed` resolved by the engine against its sparsity estimates).
    pub fn with_formats(
        dims: CacheDims,
        default_policy: PolicyKind,
        formats: FormatMap,
    ) -> DecodeGroup {
        let cap = dims.batch;
        DecodeGroup {
            cache: GroupCache::with_formats(dims, formats),
            seqs: Vec::with_capacity(cap),
            done: Vec::new(),
            default_policy,
        }
    }

    pub fn group_size(&self) -> usize {
        self.cache.dims.batch
    }

    pub fn active(&self) -> usize {
        self.seqs.len()
    }

    pub fn has_free_slot(&self) -> bool {
        self.seqs.len() < self.group_size()
    }

    /// Next free slot index (sequences are front-packed).
    pub fn free_slot(&self) -> Option<usize> {
        self.has_free_slot().then_some(self.seqs.len())
    }

    /// Install a prefilled sequence at `slot` (must be the next free one).
    pub fn install(&mut self, slot: usize, seq: SeqState) {
        assert_eq!(slot, self.seqs.len(), "slots must stay front-packed");
        self.seqs.push(seq);
    }

    pub fn seq(&self, b: usize) -> &SeqState {
        &self.seqs[b]
    }

    pub fn seq_mut(&mut self, b: usize) -> &mut SeqState {
        &mut self.seqs[b]
    }

    /// Split borrow helper for the policy step.
    pub fn split_mut(&mut self) -> (&mut [SeqState], &GroupCache) {
        (&mut self.seqs, &self.cache)
    }

    /// Disjoint mutable borrows of the sequences and the cache, for the
    /// engine's parallel per-slot post-decode pipeline (each worker gets
    /// one `&mut SeqState` plus one cache slot view).
    pub fn seqs_and_cache_mut(&mut self) -> (&mut [SeqState], &mut GroupCache) {
        (&mut self.seqs, &mut self.cache)
    }

    /// Mark the sequence with the longest cache as OOM-failed. The
    /// longest sequence is the one whose live rows exceed the largest
    /// compiled capacity — it would not fit even alone, which is exactly
    /// what [`FinishReason::Oom`] is reserved for (co-residency pressure
    /// is the scheduler's recompute-preemption, not an OOM).
    pub fn mark_oom(&mut self) {
        if let Some((b, _)) = (0..self.seqs.len())
            .map(|b| (b, self.cache.max_len_slot(b)))
            .max_by_key(|&(_, l)| l)
        {
            self.seqs[b].finished = Some(FinishReason::Oom);
            self.seqs[b].phase = SeqPhase::Finished;
            if self.seqs[b].finished_at.is_none() {
                self.seqs[b].finished_at = Some(std::time::Instant::now());
            }
        }
    }

    /// Mark the sequence with the longest cache as failed with a typed
    /// reason — the group-wide analogue of [`DecodeGroup::mark_oom`]
    /// for failures (e.g. a runtime execute error) that cannot be
    /// attributed to one slot. Failing the longest sequence sheds the
    /// most pressure; the survivors retry next tick.
    pub fn mark_failed(&mut self, kind: FailureKind) {
        if let Some((b, _)) = (0..self.seqs.len())
            .map(|b| (b, self.cache.max_len_slot(b)))
            .max_by_key(|&(_, l)| l)
        {
            self.seqs[b].fail(kind);
        }
    }

    /// Take the sequence at `slot` out of the group (recompute-
    /// preemption): its cache rows are recycled exactly like a reap —
    /// swap-with-last keeps the survivors front-packed — but the
    /// [`SeqState`] is returned to the caller instead of being reported
    /// done, so the scheduler can re-queue it for a later resume.
    pub fn remove(&mut self, slot: usize) -> SeqState {
        assert!(slot < self.seqs.len(), "slot {slot} not active");
        let last = self.seqs.len() - 1;
        self.cache.swap_slots(slot, last);
        self.seqs.swap(slot, last);
        let mut seq = self.seqs.pop().unwrap();
        self.cache.reset_slot(last);
        seq.phase = SeqPhase::Preempted;
        seq
    }

    /// FNV-1a digest of the group's batch composition: which sequences
    /// sit in which slots and how far each has decoded. The pipelined
    /// engine stamps this at decode-submit time and compares at wait
    /// time — any reap/install/remove/preemption (or an accepted token
    /// the submit did not see) between the two changes the digest, and
    /// a mismatch discards the in-flight result and reruns the step
    /// serially. Combined with [`crate::kvcache::GroupCache`]'s layout
    /// fingerprint this is the safety net that makes pre-submission
    /// heuristics (`may_prune` etc.) allowed to be wrong.
    pub fn composition_fingerprint(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = FNV_OFFSET;
        let mut eat = |h: &mut u64, bytes: &[u8]| {
            for &b in bytes {
                *h ^= b as u64;
                *h = h.wrapping_mul(FNV_PRIME);
            }
        };
        eat(&mut h, &(self.seqs.len() as u64).to_le_bytes());
        for s in &self.seqs {
            eat(&mut h, &s.id.to_le_bytes());
            eat(&mut h, &(s.abs_pos as u64).to_le_bytes());
            eat(&mut h, &s.last_token.to_le_bytes());
            eat(&mut h, &(s.steps as u64).to_le_bytes());
        }
        h
    }

    /// Remove finished sequences, keeping slots front-packed; returns how
    /// many were reaped. Cache rows for removed slots are recycled via
    /// swap-with-last.
    pub fn reap(&mut self) -> usize {
        let mut reaped = 0;
        let mut b = 0;
        while b < self.seqs.len() {
            if self.seqs[b].is_done() {
                let last = self.seqs.len() - 1;
                self.cache.swap_slots(b, last);
                self.seqs.swap(b, last);
                let seq = self.seqs.pop().unwrap();
                self.cache.reset_slot(last);
                self.done.push(seq);
                reaped += 1;
            } else {
                b += 1;
            }
        }
        reaped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::FullKv;

    fn dims(batch: usize) -> CacheDims {
        CacheDims { layers: 2, batch, kv_heads: 1, capacity: 16, d_head: 4 }
    }

    fn seq(id: u64) -> SeqState {
        SeqState::new(id, Box::new(FullKv), 2, 8, 2)
    }

    #[test]
    fn eos_and_length_finish() {
        let mut s = seq(1);
        s.note_prefilled(4, 10);
        assert!(!s.is_done());
        s.note_token(2); // EOS id
        assert_eq!(s.finished, Some(FinishReason::Eos));

        let mut s2 = seq(2);
        s2.note_prefilled(4, 10);
        for t in 0..8 {
            if s2.is_done() {
                break;
            }
            s2.note_token(20 + t);
        }
        assert_eq!(s2.finished, Some(FinishReason::Length));
        assert_eq!(s2.generated.len(), 8);
    }

    #[test]
    fn reap_front_packs_and_recycles_cache() {
        let mut g = DecodeGroup::new(dims(3), PolicyKind::FullKv);
        for i in 0..3 {
            let slot = g.free_slot().unwrap();
            g.cache
                .insert(0, slot, &[i as f32; 4], &[0.0; 4], 0)
                .unwrap();
            let mut s = seq(i as u64);
            s.note_prefilled(1, 10);
            g.install(slot, s);
        }
        assert!(!g.has_free_slot());
        g.seqs[0].finished = Some(FinishReason::Eos);
        let n = g.reap();
        assert_eq!(n, 1);
        assert_eq!(g.active(), 2);
        // Old slot 2 (id 2) moved into slot 0; its cache row came along.
        assert_eq!(g.seqs[0].id, 2);
        assert_eq!(g.cache.len(0, 0), 1);
        // Slot 2 was recycled.
        assert_eq!(g.cache.len(0, 2), 0);
        assert_eq!(g.done.len(), 1);
        assert!(g.has_free_slot());
    }

    #[test]
    fn remove_returns_seq_and_recycles_slot() {
        let mut g = DecodeGroup::new(dims(3), PolicyKind::FullKv);
        for i in 0..3 {
            let slot = g.free_slot().unwrap();
            g.cache
                .insert(0, slot, &[i as f32; 4], &[0.0; 4], 0)
                .unwrap();
            let mut s = seq(i as u64);
            s.note_prefilled(1, 10);
            g.install(slot, s);
        }
        let victim = g.remove(1);
        assert_eq!(victim.id, 1);
        assert_eq!(victim.phase, SeqPhase::Preempted);
        assert_eq!(g.active(), 2);
        // Old slot 2 (id 2) front-packed into slot 1, its rows along.
        assert_eq!(g.seqs[1].id, 2);
        assert_eq!(g.cache.len(0, 1), 1);
        assert_eq!(g.cache.len(0, 2), 0, "victim's rows recycled");
        assert!(g.done.is_empty(), "a preemption is not a completion");
    }

    #[test]
    fn phase_tracks_lifecycle_on_completion() {
        let mut s = seq(1);
        assert_eq!(s.phase, SeqPhase::Waiting);
        s.note_prefilled(4, 10);
        assert_eq!(s.phase, SeqPhase::Decoding);
        s.note_token(2); // EOS
        assert_eq!(s.phase, SeqPhase::Finished);
    }

    #[test]
    fn fail_and_mark_failed_finish_with_typed_error() {
        let mut s = seq(1);
        s.note_prefilled(2, 10);
        s.fail(FailureKind::SlotPanic);
        assert_eq!(
            s.finished,
            Some(FinishReason::Error(FailureKind::SlotPanic))
        );
        assert_eq!(s.phase, SeqPhase::Finished);

        // mark_failed hits the longest slot, like mark_oom, and the
        // reap frees its slot for the survivors.
        let mut g = DecodeGroup::new(dims(2), PolicyKind::FullKv);
        for i in 0..2 {
            let slot = g.free_slot().unwrap();
            let mut s = seq(i as u64);
            s.note_prefilled(1, 10);
            g.install(slot, s);
        }
        g.cache.insert(0, 1, &[0.0; 4], &[0.0; 4], 0).unwrap();
        g.cache.insert(0, 1, &[0.0; 4], &[0.0; 4], 1).unwrap();
        g.mark_failed(FailureKind::RuntimeExecute);
        assert_eq!(
            g.seqs[1].finished,
            Some(FinishReason::Error(FailureKind::RuntimeExecute))
        );
        assert!(g.seqs[0].finished.is_none());
        assert_eq!(g.reap(), 1);
        assert_eq!(g.active(), 1);
        assert_eq!(g.cache.len(0, 1), 0, "failed slot's rows recycled");
    }

    #[test]
    fn mark_oom_hits_longest() {
        let mut g = DecodeGroup::new(dims(2), PolicyKind::FullKv);
        for i in 0..2 {
            let slot = g.free_slot().unwrap();
            let mut s = seq(i as u64);
            s.note_prefilled(1, 10);
            g.install(slot, s);
        }
        g.cache.insert(0, 1, &[0.0; 4], &[0.0; 4], 0).unwrap();
        g.cache.insert(0, 1, &[0.0; 4], &[0.0; 4], 1).unwrap();
        g.mark_oom();
        assert_eq!(g.seqs[1].finished, Some(FinishReason::Oom));
        assert!(g.seqs[0].finished.is_none());
    }
}
