//! Attention-signal analysis: Hoyer sparsity (paper Eq. 1) and the
//! head-collapsed score utilities (Eq. 2) that feed RASR and the
//! layerwise budget estimator.

pub mod score;
pub mod sparsity;

pub use score::{head_sum, ProbsView};
pub use sparsity::{hoyer_sparsity, SparsityTracker};
