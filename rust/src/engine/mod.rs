//! The decode engine: drives PJRT executables over a [`crate::kvcache::GroupCache`],
//! applies eviction policies between steps, and exposes the step-level
//! telemetry every bench consumes.
//!
//! One [`Engine`] owns the runtime; one [`DecodeGroup`] is a set of
//! co-batched sequences (continuous batching keeps slots front-packed).
//! Per step the engine:
//!   1. buckets the live batch to the smallest compiled `B` and the live
//!      cache to the smallest compiled capacity `C` (needs one slot of
//!      headroom for the in-graph insert),
//!   2. packs + uploads the cache, runs `decode_b{B}_c{C}`,
//!   3. mirrors the in-graph K/V insert host-side, greedily samples,
//!   4. feeds attention probs into the RASR score accumulator (Eq. 5)
//!      and the layerwise sparsity tracker (Eq. 1),
//!   5. asks the per-sequence policy for retention plans per layer and
//!      applies them (multi-round pruning during decoding).
//!
//! FullKV never prunes, so step 1 eventually finds no capacity bucket —
//! that error is surfaced as an OOM on the sequence, mirroring the
//! paper's Tables 2–3.

pub mod group;

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{anyhow, Result};

pub use group::{DecodeGroup, FinishReason, PruneEvent, SeqState};

use crate::attn::score::ProbsView;
use crate::config::ServingConfig;
use crate::kvcache::CacheDims;
use crate::metrics::EngineMetrics;
use crate::policy::{LayerState, PolicyKind};
use crate::runtime::tensors::{HostTensorF32, HostTensorI32};
use crate::runtime::Runtime;

pub struct Engine {
    pub rt: Runtime,
    pub cfg: ServingConfig,
    /// Largest compiled capacity for the active profile (the OOM line).
    pub cmax: usize,
    batch_buckets: Vec<usize>,
    /// Scratch upload tensors keyed by (batch, capacity) bucket, reused
    /// across steps to keep the hot loop allocation-free.
    scratch: HashMap<(usize, usize), (HostTensorF32, HostTensorF32, HostTensorI32)>,
    score_buf: Vec<f32>,
    pub metrics: EngineMetrics,
    /// When set, [`Engine::step`] keeps a copy of the raw per-head
    /// attention probs `[L, B, Hq, C]` of the last step — the Figures 1
    /// and 5 benches read them for sparsity heatmaps / head similarity.
    pub keep_probs: bool,
    pub last_probs: Option<HostTensorF32>,
}

impl Engine {
    pub fn new(rt: Runtime, cfg: ServingConfig) -> Result<Engine> {
        let caps = rt
            .meta
            .decode_capacities
            .get(&cfg.cache_profile)
            .ok_or_else(|| anyhow!("profile '{}' not compiled",
                                   cfg.cache_profile))?;
        let cmax = *caps.iter().max().unwrap();
        let batch_buckets = rt.batch_buckets(&cfg.cache_profile);
        Ok(Engine {
            rt,
            cfg,
            cmax,
            batch_buckets,
            scratch: HashMap::new(),
            score_buf: Vec::new(),
            metrics: EngineMetrics::default(),
            keep_probs: false,
            last_probs: None,
        })
    }

    pub fn dims(&self) -> &crate::model::meta::ModelDims {
        &self.rt.meta.dims
    }

    /// Cache dims for a new group of `group_size` slots.
    pub fn cache_dims(&self, group_size: usize) -> CacheDims {
        let d = self.dims();
        CacheDims {
            layers: d.n_layers,
            batch: group_size,
            kv_heads: d.n_kv_heads,
            capacity: self.cmax,
            d_head: d.d_head,
        }
    }

    pub fn new_group(&self, group_size: usize, policy: PolicyKind) -> DecodeGroup {
        DecodeGroup::new(self.cache_dims(group_size), policy)
    }

    /// Smallest compiled batch bucket >= n.
    fn batch_bucket(&self, n: usize) -> Result<usize> {
        self.batch_buckets
            .iter()
            .copied()
            .find(|&b| b >= n)
            .ok_or_else(|| anyhow!(
                "{n} active sequences exceed largest compiled batch {:?}",
                self.batch_buckets.last()))
    }

    /// Prefill a prompt into slot `slot` of the group; returns the first
    /// generated token.
    pub fn prefill(
        &mut self,
        group: &mut DecodeGroup,
        slot: usize,
        seq: SeqState,
        prompt: &[i32],
    ) -> Result<i32> {
        let t0 = Instant::now();
        let bucket = self.rt.prefill_bucket(prompt.len())?;
        let out = self.rt.prefill(bucket, prompt)?;
        let n = prompt.len();
        group.cache.load_prefill(slot, &out.k_all, &out.v_all, n)?;
        group.install(slot, seq);

        // RASR init (Eq. 2): head-summed prefill attention mass.
        let layers = self.rt.meta.dims.n_layers;
        let sv = ProbsView::new(&out.scores); // [L,1,Hq,T]
        let mut buf = Vec::new();
        for l in 0..layers {
            sv.head_sum_into(l, 0, n, &mut buf);
            group.cache.accumulate_scores(l, slot, 0.0, &buf);
            group.seq_mut(slot).sparsity.observe(l, &buf);
        }
        // Policies may prune immediately (long prompts).
        self.apply_policies(group, slot)?;

        let tok = argmax(&out.logits.data);
        group.seq_mut(slot).note_prefilled(n, tok);
        self.metrics.prefill_seconds.push(t0.elapsed().as_secs_f64());
        self.metrics.prefill_tokens += n as u64;
        Ok(tok)
    }

    /// One decode step over all active sequences. Returns per-slot newly
    /// generated tokens (empty when the step OOMed).
    pub fn step(&mut self, group: &mut DecodeGroup) -> Result<Vec<(usize, i32)>> {
        let n = group.active();
        if n == 0 {
            return Ok(Vec::new());
        }
        let t0 = Instant::now();
        let bb = self.batch_bucket(n)?;
        // +1 headroom: the in-graph insert writes at slot len.
        let need = group.cache.max_len() + 1;
        let cap = match self.rt.capacity_bucket(&self.cfg.cache_profile, need) {
            Ok(c) => c,
            Err(e) => {
                // OOM: mark the longest sequence failed; caller reaps.
                group.mark_oom();
                self.metrics.ooms += 1;
                crate::log_warn!("OOM at live length {need}: {e}");
                return Ok(Vec::new());
            }
        };

        let d = self.rt.meta.dims.clone();
        let (k_s, v_s, l_s) = self.scratch.entry((bb, cap)).or_insert_with(|| {
            (
                HostTensorF32::zeros(&[d.n_layers, bb, d.n_kv_heads, cap, d.d_head]),
                HostTensorF32::zeros(&[d.n_layers, bb, d.n_kv_heads, cap, d.d_head]),
                HostTensorI32::zeros(&[d.n_layers, bb]),
            )
        });
        group.cache.pack(bb, cap, k_s, v_s, l_s)?;

        let mut tokens = vec![0i32; bb];
        let mut positions = vec![0i32; bb];
        for b in 0..n {
            tokens[b] = group.seq(b).last_token;
            positions[b] = group.seq(b).abs_pos as i32;
        }
        let t_pack = t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let out = self.rt.decode(bb, cap, k_s, v_s, l_s, &tokens, &positions)?;
        let t_exec = t1.elapsed().as_secs_f64();

        let t2 = Instant::now();
        let mut produced = Vec::with_capacity(n);
        let hkv_d = d.n_kv_heads * d.d_head;
        let pv = ProbsView::new(&out.probs);
        for b in 0..n {
            // Mirror the in-graph insert host-side.
            let pos = group.seq(b).abs_pos as i32;
            for l in 0..d.n_layers {
                let off = (l * bb + b) * hkv_d;
                group.cache.insert(
                    l,
                    b,
                    &out.k_new.data[off..off + hkv_d],
                    &out.v_new.data[off..off + hkv_d],
                    pos,
                )?;
            }
            // Score accumulation (Eq. 5) + sparsity tracking (Eq. 1).
            let gamma = group.seq(b).policy.gamma();
            for l in 0..d.n_layers {
                let live = group.cache.len(l, b);
                pv.head_sum_into(l, b, live, &mut self.score_buf);
                group.cache.accumulate_scores(l, b, gamma, &self.score_buf);
                group.seq_mut(b).sparsity.observe(l, &self.score_buf);
            }
            // Sample + bookkeeping.
            let logits = &out.logits.data[b * d.vocab_size..(b + 1) * d.vocab_size];
            let tok = argmax(logits);
            group.seq_mut(b).note_token(tok);
            produced.push((b, tok));
            // Multi-round pruning.
            self.apply_policies(group, b)?;
        }
        let t_policy = t2.elapsed().as_secs_f64();
        if self.keep_probs {
            self.last_probs = Some(out.probs.clone());
        }

        self.metrics.decode_steps += 1;
        self.metrics.decode_tokens += n as u64;
        self.metrics.pack_seconds.push(t_pack);
        self.metrics.exec_seconds.push(t_exec);
        self.metrics.policy_seconds.push(t_policy);
        self.metrics.live_bytes_last = group.cache.live_bytes();
        *self.metrics.capacity_hist.entry(cap).or_insert(0) += 1;
        Ok(produced)
    }

    /// Run each layer's retention plan for one slot.
    fn apply_policies(&mut self, group: &mut DecodeGroup, b: usize) -> Result<()> {
        let layers = group.cache.dims.layers;
        for l in 0..layers {
            let len = group.cache.len(l, b);
            if len == 0 {
                continue;
            }
            // Split borrows: the policy lives in seqs[b], the score/pos
            // views in the cache.
            let (seqs, cache) = group.split_mut();
            let seq = &mut seqs[b];
            let st = LayerState {
                scores: cache.scores(l, b),
                pos: cache.pos(l, b),
                len,
                step: seq.steps,
                sparsity: seq.sparsity.sparsity(l),
                capacity: self.cmax,
            };
            let plan = seq.policy.plan(l, &st);
            if let Some(keep) = plan {
                let before = len;
                let after = group.cache.apply_retention(l, b, &keep)?;
                group.seq_mut(b).note_prune(l, before, after);
                self.metrics.prune_events += 1;
                self.metrics.pruned_tokens += (before - after) as u64;
            }
        }
        Ok(())
    }

    /// Generate until EOS/limit for every sequence in the group
    /// (the batch inner loop used by benches and the eval harness).
    pub fn run_group(&mut self, group: &mut DecodeGroup) -> Result<()> {
        while group.active() > 0 {
            self.step(group)?;
            group.reap();
        }
        Ok(())
    }
}

/// Greedy sampling.
pub fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_first_max() {
        assert_eq!(argmax(&[0.1, 0.9, 0.9, 0.2]), 1);
        assert_eq!(argmax(&[-1.0, -2.0]), 0);
    }
}
