//! H2O (Heavy-Hitter Oracle, Zhang et al. 2023): keep the top-scoring
//! "heavy hitter" tokens by *cumulative* attention mass (γ = 1, no decay)
//! plus a recent window, under a fixed per-layer budget. The paper's
//! Table 1 shows where this fails on reasoning traces: hitters that were
//! hot during prefill stay pinned while the tokens a later reasoning hop
//! needs are evicted.

use crate::config::BaselineParams;

use super::{top_k_indices, Capabilities, EvictionPolicy, LayerState};

pub struct H2o {
    params: BaselineParams,
}

impl H2o {
    pub fn new(params: BaselineParams) -> Self {
        H2o { params }
    }

    fn recent_budget(&self) -> usize {
        ((self.params.budget as f64 * self.params.h2o_recent_frac) as usize)
            .max(1)
    }
}

impl EvictionPolicy for H2o {
    fn name(&self) -> &'static str {
        "H2O"
    }

    fn gamma(&self) -> f32 {
        1.0 // cumulative attention, the H2O saliency statistic
    }

    fn plan(&mut self, _layer: usize, st: &LayerState<'_>) -> Option<Vec<usize>> {
        if st.len <= self.params.budget {
            return None;
        }
        let recent = self.recent_budget();
        let heavy = self.params.budget - recent;
        let mut keep: Vec<usize> =
            (st.len - recent..st.len).collect();
        // Heavy hitters among the non-recent prefix.
        let prefix = &st.scores[..st.len - recent];
        keep.extend(top_k_indices(prefix, heavy));
        Some(keep)
    }

    /// Stateless policy: `plan` is a pure no-op exactly while the live
    /// length stays within the fixed budget.
    fn may_prune(&self, _layer: usize, len: usize, _capacity: usize) -> bool {
        len > self.params.budget
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            recency_aware: true,
            attention_aware: true,
            layerwise_budget: false,
            adaptive_budget: false,
            multi_step_pruning: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::proptest::check;

    fn st<'a>(scores: &'a [f32], pos: &'a [i32]) -> LayerState<'a> {
        LayerState {
            scores,
            pos,
            len: scores.len(),
            step: 10,
            sparsity: 0.5,
            capacity: 1024,
        }
    }

    fn params(budget: usize) -> BaselineParams {
        BaselineParams { budget, h2o_recent_frac: 0.5, ..Default::default() }
    }

    #[test]
    fn under_budget_keeps_all() {
        let mut p = H2o::new(params(16));
        let s = vec![0.1f32; 10];
        let pos: Vec<i32> = (0..10).collect();
        assert!(p.plan(0, &st(&s, &pos)).is_none());
    }

    #[test]
    fn over_budget_keeps_hitters_and_recents() {
        let mut p = H2o::new(params(8));
        let mut s = vec![0.01f32; 32];
        s[3] = 5.0; // heavy hitter in the prefix
        let pos: Vec<i32> = (0..32).collect();
        let keep = p.plan(0, &st(&s, &pos)).unwrap();
        assert!(keep.contains(&3), "heavy hitter evicted");
        for i in 28..32 {
            assert!(keep.contains(&i), "recent {i} evicted");
        }
        let mut k = keep.clone();
        k.sort_unstable();
        k.dedup();
        assert_eq!(k.len(), 8);
    }

    #[test]
    fn property_budget_respected() {
        check("h2o-budget", 50, |rng: &mut Rng, size| {
            let n = 4 + size * 3;
            let budget = 2 + rng.range(1, 16.min(n.max(2)));
            let scores: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
            let pos: Vec<i32> = (0..n as i32).collect();
            let mut p = H2o::new(params(budget));
            match p.plan(0, &st(&scores, &pos)) {
                Some(keep) => {
                    let mut k = keep;
                    k.sort_unstable();
                    k.dedup();
                    if k.len() > budget {
                        return Err(format!(
                            "kept {} > budget {budget}",
                            k.len()
                        ));
                    }
                    if k.iter().any(|&i| i >= n) {
                        return Err("oob index".into());
                    }
                }
                None => {
                    if n > budget {
                        return Err(format!(
                            "no plan although len {n} > budget {budget}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }
}
