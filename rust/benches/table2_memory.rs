//! Table 2: per-GPU generation memory (MB) across models and batch
//! sizes, FullKV vs Lethe, with OOM cells.
//!
//! Two sections:
//!   (a) A100 simulator over the paper's four DeepSeek-R1-Distill archs
//!       (DESIGN.md §4 substitution): real policy code over synthetic
//!       attention traces → retained tokens → analytical memory.
//!   (b) Real measured KV bytes from the live lethe-tiny engine across
//!       compiled batch sizes (ground truth for the mechanism).

use lethe::bench_support::{gen_tasks, kv_configs, print_table, run_tasks,
                           try_engine, write_csv};
use lethe::config::ServingConfig;
use lethe::model::DEEPSEEK_R1_DISTILL;
use lethe::policy::PolicyKind;
use lethe::sim::{run_trace, Simulator, TraceConfig};

const BATCHES: [usize; 5] = [1, 4, 8, 16, 32];
/// The paper's generation regime for the batch tables: long CoT decode.
const GEN_LEN: usize = 20_000;
const PROMPT: usize = 512;

fn main() -> anyhow::Result<()> {
    let mut cfg = ServingConfig::default();
    // Budgets at large-model scale (tokens).
    cfg.baseline.budget = 768;
    cfg.lethe.evict_threshold = 512;
    cfg.lethe.sink_len = 16;

    // ---- (a) simulated A100 section -----------------------------------
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for arch in &DEEPSEEK_R1_DISTILL {
        for kind in [PolicyKind::FullKv, PolicyKind::Lethe] {
            let tc = TraceConfig {
                n_layers: arch.n_layers,
                prompt_len: PROMPT,
                gen_len: GEN_LEN,
                ..TraceConfig::default()
            };
            let tr = run_trace(kind, &cfg, &tc);
            let sim = Simulator::new(arch);
            let mut row = vec![
                format!("{}/{}", short(arch.name), kind.label()),
            ];
            for b in BATCHES {
                let p = sim.point(b, tr.mean_retained(), tr.final_retained());
                let cell = if p.oom {
                    "OOM".to_string()
                } else {
                    format!("{:.0}", p.gen_memory_mb)
                };
                csv.push(format!(
                    "{},{},{},{:.0},{}",
                    arch.name,
                    kind.label(),
                    b,
                    p.gen_memory_mb,
                    p.oom
                ));
                row.push(cell);
            }
            rows.push(row);
        }
    }
    print_table(
        &format!(
            "Table 2(a) — simulated per-GPU generation memory (MB), \
             A100-80GB, {GEN_LEN}-token CoT decode"
        ),
        &["model/policy", "b=1", "b=4", "b=8", "b=16", "b=32"],
        &rows,
    );
    write_csv(
        "table2_memory_sim.csv",
        "model,policy,batch,gen_memory_mb,oom",
        &csv,
    )?;

    // ---- (b) real engine section ---------------------------------------
    // Tight budgets + tiny-model-calibrated τ (Table 6 sweep) so pruning
    // actually engages on ~150-token prompts + 64-token generations.
    // All four storage configurations run (f32, q8, q4, and the
    // sparsity-directed mixed map): "actual" is bytes as stored,
    // "f32-eq" prices the same retained rows at f32, so the token
    // reduction (policy) and the storage compression (backend) stay
    // separable — their product is the paper's compounded saving. For
    // "mixed", per-layer byte rates vary: live_bytes sums each layer at
    // its own format's rate.
    cfg.baseline.budget = 48;
    cfg.lethe.evict_threshold = 48;
    cfg.lethe.sparse_ratio = 25.0;
    let Some((mut engine, tok)) = try_engine(cfg) else { return Ok(()) };
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for (label, kv) in kv_configs() {
        engine.cfg.kv = kv;
        for kind in [PolicyKind::FullKv, PolicyKind::Lethe] {
            let mut row = vec![format!("{}/{}", kind.label(), label)];
            for b in [1usize, 2, 4, 8] {
                let tasks = gen_tasks(7 + b as u64, 2 * b, 24, 4);
                if label == "mixed" {
                    // Seed the engine's sparsity EMA (cold estimates
                    // resolve all-dense) so the measured pass serves on
                    // the resolved per-layer map, as Table 3 does.
                    let _ = run_tasks(&mut engine, &tok, kind, &tasks, b, 64)?;
                }
                engine.metrics.reset();
                let st = run_tasks(&mut engine, &tok, kind, &tasks, b, 64)?;
                row.push(format!(
                    "{:.0}KB ({:.0}KB f32-eq)",
                    st.peak_live_bytes as f64 / 1e3,
                    st.peak_f32_equiv_bytes as f64 / 1e3
                ));
                csv.push(format!(
                    "{},{},{},{},{},{}",
                    kind.label(),
                    label,
                    b,
                    st.peak_live_bytes,
                    st.peak_f32_equiv_bytes,
                    st.ooms
                ));
            }
            if label == "mixed" {
                // Surface what the sparsity rule actually resolved to on
                // the last-served group.
                let fmts: Vec<&str> = engine
                    .metrics
                    .kv_layer_formats
                    .iter()
                    .map(|f| f.label())
                    .collect();
                eprintln!(
                    "[mixed] {} realized per-layer formats: [{}] \
                     (layer sparsity: {:?})",
                    kind.label(),
                    fmts.join(","),
                    engine.layer_sparsity()
                );
            }
            rows.push(row);
        }
    }
    print_table(
        "Table 2(b) — measured peak live KV bytes (actual / f32-equivalent), \
         lethe-tiny engine",
        &["policy/kv", "b=1", "b=2", "b=4", "b=8"],
        &rows,
    );
    write_csv(
        "table2_memory_real.csv",
        "policy,kv_format,batch,peak_live_kv_bytes,peak_f32_equiv_bytes,ooms",
        &csv,
    )?;
    Ok(())
}

fn short(name: &str) -> &str {
    name.trim_start_matches("DeepSeek-R1-Distill-")
}
