//! Fixed-size worker pool (tokio substitute for this workload). The
//! serving stack is CPU-bound through one PJRT device, so the pool's job
//! is request-path concurrency (router/session fan-in, background metric
//! flushes), not data parallelism. Work-queue semantics: FIFO, graceful
//! shutdown on drop, panic isolation per job.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    handles: Vec<thread::JoinHandle<()>>,
    inflight: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let inflight = Arc::new(AtomicUsize::new(0));
        let handles = (0..workers)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let inflight = Arc::clone(&inflight);
                thread::Builder::new()
                    .name(format!("lethe-worker-{i}"))
                    .spawn(move || loop {
                        let msg = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match msg {
                            Ok(Msg::Run(job)) => {
                                // Panic isolation: a single bad request
                                // must not take the worker down.
                                let r = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(job),
                                );
                                inflight.fetch_sub(1, Ordering::SeqCst);
                                if r.is_err() {
                                    crate::log_error!(
                                        "worker {i}: job panicked"
                                    );
                                }
                            }
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx, handles, inflight }
    }

    pub fn spawn<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.inflight.fetch_add(1, Ordering::SeqCst);
        self.tx
            .send(Msg::Run(Box::new(f)))
            .expect("threadpool already shut down");
    }

    /// Run a batch of *borrowing* jobs to completion (scoped fork-join).
    /// Unlike [`ThreadPool::spawn`], jobs may borrow from the caller's
    /// stack: the call blocks until every job in the batch has finished
    /// (panicked jobs count as finished), which restores the borrow
    /// contract before returning — the same argument `std::thread::scope`
    /// makes. Used by the engine's parallel per-slot decode pipeline.
    pub fn scoped<'a>(&self, mut jobs: Vec<Box<dyn FnOnce() + Send + 'a>>) {
        struct Latch {
            done: Mutex<usize>,
            cv: Condvar,
        }
        struct DoneGuard(Arc<Latch>);
        impl Drop for DoneGuard {
            fn drop(&mut self) {
                // Fires on normal return AND during unwind, so the join
                // below never hangs on a panicked job (the worker loop
                // catches the unwind).
                *self.0.done.lock().unwrap() += 1;
                self.0.cv.notify_one();
            }
        }
        // The caller is a perfectly good worker for one job: keep the
        // last one to run inline instead of parking immediately.
        let Some(inline) = jobs.pop() else { return };
        let total = jobs.len();
        let latch = Arc::new(Latch { done: Mutex::new(0), cv: Condvar::new() });
        for job in jobs {
            let guard = DoneGuard(Arc::clone(&latch));
            let wrapped: Box<dyn FnOnce() + Send + 'a> = Box::new(move || {
                let _completes_on_any_exit = guard;
                job();
            });
            // SAFETY: `wrapped` only borrows data that outlives 'a, and
            // this function does not return (even by unwind — see the
            // catch below) until every enqueued job has run to
            // completion, so no borrow is used past its real lifetime.
            // The transmute only erases the lifetime; layout is
            // identical.
            let wrapped: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'a>, Job>(
                    wrapped,
                )
            };
            self.inflight.fetch_add(1, Ordering::SeqCst);
            self.tx
                .send(Msg::Run(wrapped))
                .expect("threadpool already shut down");
        }
        // A panic in the inline job must not skip the join (the workers
        // would still hold borrows): defer the unwind past the wait.
        let inline_result =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(inline));
        let mut done = latch.done.lock().unwrap();
        while *done < total {
            done = latch.cv.wait(done).unwrap();
        }
        drop(done);
        if let Err(p) = inline_result {
            std::panic::resume_unwind(p);
        }
    }

    /// Jobs submitted but not yet finished.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::SeqCst)
    }

    /// Busy-wait (with yield) until all submitted jobs finished.
    pub fn wait_idle(&self) {
        while self.inflight() > 0 {
            thread::yield_now();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.handles {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn survives_panicking_job() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        pool.spawn(|| panic!("boom"));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn scoped_jobs_may_borrow_the_stack() {
        let pool = ThreadPool::new(4);
        let mut data = vec![0u64; 32];
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = data
            .iter_mut()
            .enumerate()
            .map(|(i, slot)| {
                Box::new(move || *slot = i as u64 * 2)
                    as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.scoped(jobs);
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i as u64 * 2);
        }
    }

    #[test]
    fn scoped_joins_even_when_a_job_panics() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        jobs.push(Box::new(|| panic!("boom")));
        for _ in 0..8 {
            let c = Arc::clone(&counter);
            jobs.push(Box::new(move || {
                c.fetch_add(1, Ordering::SeqCst);
            }));
        }
        pool.scoped(jobs); // must not hang
        assert_eq!(counter.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn shutdown_joins_workers() {
        let pool = ThreadPool::new(3);
        pool.spawn(|| {});
        pool.wait_idle();
        drop(pool); // must not hang
    }
}
