//! Leveled stderr logger (log-crate substitute). Level comes from
//! `LETHE_LOG` (error|warn|info|debug|trace), default `info`.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);
static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l != u8::MAX {
        return l;
    }
    let parsed = match std::env::var("LETHE_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    } as u8;
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

pub fn log(l: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{t:9.3}s {} {module}] {msg}", l.tag());
}

#[macro_export]
macro_rules! log_error {
    ($($t:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Error,
            module_path!(), format_args!($($t)*))
    };
}
#[macro_export]
macro_rules! log_warn {
    ($($t:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn,
            module_path!(), format_args!($($t)*))
    };
}
#[macro_export]
macro_rules! log_info {
    ($($t:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info,
            module_path!(), format_args!($($t)*))
    };
}
#[macro_export]
macro_rules! log_debug {
    ($($t:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug,
            module_path!(), format_args!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
