//! Host-owned KV cache for a decode group (the serving state), built on
//! pluggable row-storage backends.
//!
//! # Architecture: bookkeeping vs storage
//!
//! [`GroupCache`] owns the *bookkeeping* of the conceptual
//! `[L, B, Hkv, Cmax, D]` cache: per-(layer, slot) lengths (what makes
//! Lethe's layerwise budgets expressible), each row's original absolute
//! position `pos` (recency signal for RASR / H2O / StreamingLLM), the
//! policy's accumulated attention score per row (RASR Eq. 5; γ is
//! policy-owned), and the delta-pack epoch protocol below. The K/V
//! payload itself lives behind the [`KvStore`] trait ([`backend`]
//! module), with **one independently formatted store per layer**
//! ([`FormatMap`]):
//!
//!   * [`DenseF32`] (`"f32"`, default) — plain f32 rows,
//!   * [`QuantI8`]  (`"q8"`) — per-row symmetric int8, ~3.9× smaller,
//!     quantized at insert and dequantized during packing,
//!   * [`QuantI4`]  (`"q4"`) — group-wise asymmetric int4 (groups of 32
//!     along the head dim, per-group scale + zero, two codes per byte),
//!     ~5.3× smaller.
//!
//! A uniform `kv.format` makes every layer the same; `kv.layer_formats`
//! or the sparsity-fed `kv.mixed` rule place each layer in its own
//! format (the paper's "compose with quantized caches" claim, extended
//! to precision-per-layer: high-sparsity layers tolerate aggressive
//! compression while dense layers keep full fidelity). A layer's format
//! can change **while the group is live** via
//! [`GroupCache::migrate_layer_format`]: the rows are dequantized and
//! re-encoded into a fresh store and the layer is marked rewritten, so
//! resident pack scratches repack exactly that layer on the next
//! [`GroupCache::pack_delta`].
//!
//! Eviction is [`GroupCache::apply_retention`]: an in-place
//! front-packing gather by source index, applied identically to the
//! backend rows, pos and scores so they stay aligned. Upload packing
//! ([`GroupCache::pack`]) materializes the C-prefix of each (l, b, h)
//! row as f32 in a scratch tensor for the chosen capacity bucket — a
//! memcpy on the dense backend, a dequantization on the quantized one.
//!
//! # Epoch / dirty protocol (incremental delta-pack)
//!
//! Every (layer, slot) pair carries a [`SlotEpoch`]: `epoch` advances on
//! *every* mutation of that pair, and `rewrite` records the epoch of the
//! last **non-append** mutation (retention gather, prefill load, slot
//! swap, slot reset, live format migration). Appends ([`GroupCache::insert`]) bump only `epoch`,
//! so `rewrite < e <= epoch` certifies that everything between epoch `e`
//! and now was append-only: rows `0..len(e)` are unchanged and only rows
//! `len(e)..len` are new. Because the watermarks live here — not in the
//! backend — the protocol is identical for every backend; the only
//! backend obligation is that [`KvStore::read_rows`] is deterministic
//! for a given stored state (dead rows included), which keeps a
//! delta-maintained scratch bit-identical to a fresh pack.
//!
//! [`PackScratch`] is the consumer: a persistent f32 upload image for
//! one (batch, capacity) bucket that records, per (l, b), the epoch +
//! row count it holds, tagged with the owning cache's unique id.
//! [`GroupCache::pack_delta`] then reconciles per pair:
//!   * epoch unchanged          → skip (zero bytes copied),
//!   * append-only since sync   → copy only the new token rows,
//!   * rewritten / unknown cache→ full C-prefix re-copy of that pair.
//! The invariant (enforced by `tests/delta_pack_prop.rs` and, across
//! backends, `tests/backend_prop.rs`) is that the resident scratch is
//! bit-identical to a fresh [`GroupCache::pack`] after every reconcile.
//! Cache ids are never reused and a [`Clone`] of a cache takes a fresh
//! id, so residency can never confuse two diverging copies.
//!
//! # Byte accounting (Table 2)
//!
//! [`GroupCache::live_bytes`] is live rows × the owning **layer's**
//! per-row cost ([`quant::kv_row_bytes`] at that layer's format, summed
//! per (layer, slot) — a mixed map prices every layer at its own rate);
//! [`GroupCache::f32_equivalent_bytes`] prices the same rows at f32.
//! Table 2 reports both, so the memory numbers show token-count
//! reduction (Lethe) and storage compression (backend) separately — and
//! their product, the compounded saving.

#![deny(missing_docs)]

pub mod backend;
pub mod quant;

use std::marker::PhantomData;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{ensure, Result};

use crate::runtime::tensors::{HostTensorF32, HostTensorI32, HostTensorU8};

pub use backend::{DenseF32, KvBackend, KvStore, QuantI4, QuantI8};
pub use quant::KvFormat;

use backend::{RawKv, RawKvTable};

/// Shape of one group's conceptual `[L, B, Hkv, Cmax, D]` cache.
#[derive(Clone, Copy, Debug)]
pub struct CacheDims {
    /// Model layers L.
    pub layers: usize,
    /// Co-batched slots B (the group size).
    pub batch: usize,
    /// KV heads Hkv (GQA: ≤ query heads).
    pub kv_heads: usize,
    /// Row capacity Cmax (largest compiled decode bucket).
    pub capacity: usize,
    /// Head dimension D.
    pub d_head: usize,
}

/// Per-layer KV storage formats for one group cache: which
/// [`KvFormat`] each layer's rows are stored in. Built by the engine
/// from `kv.format` / `kv.layer_formats` / `kv.mixed` and handed to
/// [`GroupCache::with_formats`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FormatMap {
    per_layer: Vec<KvFormat>,
}

impl FormatMap {
    /// Map with every layer stored as `fmt`.
    pub fn uniform(layers: usize, fmt: KvFormat) -> FormatMap {
        FormatMap { per_layer: vec![fmt; layers] }
    }

    /// Map from an explicit per-layer vector (index = layer).
    pub fn new(per_layer: Vec<KvFormat>) -> FormatMap {
        FormatMap { per_layer }
    }

    /// Number of layers the map covers.
    pub fn layers(&self) -> usize {
        self.per_layer.len()
    }

    /// Layer `l`'s storage format.
    pub fn get(&self, l: usize) -> KvFormat {
        self.per_layer[l]
    }

    /// Re-point layer `l` at `fmt` (live-migration bookkeeping; the row
    /// payload itself moves in [`GroupCache::migrate_layer_format`]).
    pub fn set(&mut self, l: usize, fmt: KvFormat) {
        self.per_layer[l] = fmt;
    }

    /// The formats as a slice (index = layer).
    pub fn as_slice(&self) -> &[KvFormat] {
        &self.per_layer
    }

    /// `Some(fmt)` when every layer shares one format, `None` for a
    /// genuinely mixed map.
    pub fn uniform_format(&self) -> Option<KvFormat> {
        let first = *self.per_layer.first()?;
        self.per_layer.iter().all(|&f| f == first).then_some(first)
    }

    /// Short serving label: the format name when uniform ("f32" | "q8" |
    /// "q4"), `"mixed"` otherwise (the per-layer vector is surfaced
    /// separately in metrics).
    pub fn label(&self) -> String {
        match self.uniform_format() {
            Some(f) => f.label().to_string(),
            None => "mixed".to_string(),
        }
    }
}

/// Change-tracking state for one (layer, slot) pair. `epoch` advances on
/// every mutation; `rewrite` is the epoch of the last non-append mutation
/// (see the module-level protocol docs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SlotEpoch {
    /// Monotonic mutation counter for the pair.
    pub epoch: u64,
    /// Epoch of the last non-append mutation (rewrite watermark).
    pub rewrite: u64,
}

static NEXT_CACHE_ID: AtomicU64 = AtomicU64::new(1);

fn next_cache_id() -> u64 {
    NEXT_CACHE_ID.fetch_add(1, Ordering::Relaxed)
}

/// Host-owned KV cache for one decode group: bookkeeping (lens, pos,
/// scores, epochs) plus per-layer row storage behind [`KvStore`]. See
/// the module docs for the architecture and the delta-pack protocol.
pub struct GroupCache {
    /// Shape of the cache (layers, slots, heads, capacity, head dim).
    pub dims: CacheDims,
    /// Process-unique identity; fresh per `new` AND per `clone` so
    /// [`PackScratch`] residency never matches a different cache.
    id: u64,
    /// Row storage (K/V payload) behind the backend contract.
    kv: KvBackend,
    /// Per-layer storage formats of `kv` (cached for cheap reads).
    formats: FormatMap,
    /// Scratch table of per-layer raw pointer sets; refreshed on every
    /// view handout, valid only while that view borrow lives.
    raw_kv: Vec<RawKv>,
    /// [L, B]
    lens: Vec<usize>,
    /// [L][B] -> per-slot original absolute position, length = lens[l][b].
    pos: Vec<Vec<i32>>,
    /// [L][B] -> accumulated attention score per slot.
    scores: Vec<Vec<f32>>,
    /// [L, B] change-tracking epochs (delta-pack protocol).
    epochs: Vec<SlotEpoch>,
}

impl Clone for GroupCache {
    /// A clone is a logically distinct cache: it takes a fresh id so a
    /// scratch synced against the original can never false-hit on the
    /// (independently mutated) copy.
    fn clone(&self) -> Self {
        GroupCache {
            dims: self.dims,
            id: next_cache_id(),
            kv: self.kv.clone(),
            formats: self.formats.clone(),
            // Stale raw pointers must never travel with a clone; the
            // table is rebuilt on the next view handout.
            raw_kv: Vec::new(),
            lens: self.lens.clone(),
            pos: self.pos.clone(),
            scores: self.scores.clone(),
            epochs: self.epochs.clone(),
        }
    }
}

impl GroupCache {
    /// Dense f32 cache (the serving default).
    pub fn new(dims: CacheDims) -> Self {
        Self::with_format(dims, KvFormat::F32)
    }

    /// Cache with one uniform storage format across layers
    /// (`kv.format` in [`crate::config::ServingConfig`]).
    pub fn with_format(dims: CacheDims, fmt: KvFormat) -> Self {
        Self::with_formats(dims, FormatMap::uniform(dims.layers, fmt))
    }

    /// Cache with an explicit per-layer format map (`kv.layer_formats` /
    /// `kv.mixed`); `formats.layers()` must equal `dims.layers`.
    pub fn with_formats(dims: CacheDims, formats: FormatMap) -> Self {
        let CacheDims { layers, batch, .. } = dims;
        GroupCache {
            dims,
            id: next_cache_id(),
            kv: KvBackend::with_formats(dims, formats.as_slice()),
            formats,
            raw_kv: Vec::new(),
            lens: vec![0; layers * batch],
            pos: vec![Vec::new(); layers * batch],
            scores: vec![Vec::new(); layers * batch],
            epochs: vec![SlotEpoch::default(); layers * batch],
        }
    }

    /// Process-unique cache identity (delta-pack residency key).
    pub fn cache_id(&self) -> u64 {
        self.id
    }

    /// Per-layer storage formats of the active backend.
    pub fn format_map(&self) -> &FormatMap {
        &self.formats
    }

    /// Serving label of the storage configuration: the format name when
    /// uniform ("f32" | "q8" | "q4"), `"mixed"` otherwise.
    pub fn format_label(&self) -> String {
        self.formats.label()
    }

    /// Change-tracking epoch state of (layer `l`, slot `b`).
    pub fn slot_epoch(&self, l: usize, b: usize) -> SlotEpoch {
        self.epochs[self.lb(l, b)]
    }

    #[inline]
    fn lb(&self, l: usize, b: usize) -> usize {
        l * self.dims.batch + b
    }

    /// Live rows of (layer `l`, slot `b`).
    pub fn len(&self, l: usize, b: usize) -> usize {
        self.lens[self.lb(l, b)]
    }

    /// True when no (layer, slot) holds any live rows.
    pub fn is_empty(&self) -> bool {
        self.lens.iter().all(|&l| l == 0)
    }

    /// Longest live row across layers for one slot.
    pub fn max_len_slot(&self, b: usize) -> usize {
        (0..self.dims.layers).map(|l| self.len(l, b)).max().unwrap_or(0)
    }

    /// Longest live row across the whole group (capacity-bucket driver).
    pub fn max_len(&self) -> usize {
        (0..self.dims.batch).map(|b| self.max_len_slot(b)).max().unwrap_or(0)
    }

    /// FNV-1a digest of the physical cache layout: per-(layer, slot)
    /// epoch state + live length, the per-layer formats, and the cache
    /// identity. Any mutation the delta-pack protocol would care about —
    /// append, retention, swap, reset, migration, import — changes the
    /// digest (every such path bumps the pair's epoch). The pipelined
    /// engine stamps this at decode-submit time and compares at wait
    /// time; a mismatch means the uploaded image no longer matches the
    /// live cache, so the in-flight result is discarded and the step
    /// reruns serially.
    pub fn layout_fingerprint(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = FNV_OFFSET;
        let mut eat = |h: &mut u64, bytes: &[u8]| {
            for &b in bytes {
                *h ^= b as u64;
                *h = h.wrapping_mul(FNV_PRIME);
            }
        };
        eat(&mut h, &self.id.to_le_bytes());
        for l in 0..self.dims.layers {
            eat(&mut h, &[self.formats.get(l) as u8]);
        }
        for (e, len) in self.epochs.iter().zip(&self.lens) {
            eat(&mut h, &e.epoch.to_le_bytes());
            eat(&mut h, &e.rewrite.to_le_bytes());
            eat(&mut h, &(*len as u64).to_le_bytes());
        }
        h
    }

    /// Total live KV bytes as actually stored — the Table 2 metric.
    /// Summed per (layer, slot) at the **owning layer's** per-row cost
    /// ([`KvStore::layer_row_bytes`]), so mixed per-layer maps report
    /// every layer at its own rate rather than assuming one group-wide
    /// format.
    pub fn live_bytes(&self) -> usize {
        // lens is [L, B] row-major: one chunk per layer. Allocation-free
        // (this runs per decode step for the metrics snapshot).
        self.lens
            .chunks(self.dims.batch)
            .enumerate()
            .map(|(l, slots)| {
                self.kv.layer_row_bytes(l) * slots.iter().sum::<usize>()
            })
            .sum()
    }

    /// What the same live rows would occupy on the dense f32 backend
    /// (Table 2's "f32-equivalent" column; equals [`Self::live_bytes`]
    /// when every layer is dense).
    pub fn f32_equivalent_bytes(&self) -> usize {
        let row = self.kv.f32_row_bytes();
        self.lens.iter().map(|&n| n * row).sum()
    }

    /// Bytes `rows` cached token rows would occupy across all layers at
    /// the group's current per-layer formats — the scheduler's admission
    /// and preemption-budget projection for a prompt of `rows` tokens.
    pub fn bytes_for_rows(&self, rows: usize) -> usize {
        (0..self.dims.layers)
            .map(|l| self.kv.layer_row_bytes(l) * rows)
            .sum()
    }

    /// Rewrite layer `l`'s rows into a freshly constructed `fmt` store
    /// **while the group stays live**: lens/pos/scores are untouched,
    /// the K/V payload is materialized as f32 row-wise from the old
    /// store (a dequantization on quantized storage) and re-encoded into
    /// the new one (a requantization), and every (l, b) pair's rewrite
    /// watermark is bumped so the next [`GroupCache::pack_delta`]
    /// re-copies exactly that layer — the scratch then reads the
    /// migrated store, staying bit-identical to a fresh pack. Lossy when
    /// either side is quantized, bounded by the formats' dequantization
    /// error bounds ([`quant::dequant_error_bound`]). Returns `false`
    /// (and touches nothing) when the layer already stores `fmt`.
    pub fn migrate_layer_format(&mut self, l: usize, fmt: KvFormat) -> Result<bool> {
        ensure!(l < self.dims.layers, "layer {l} out of range");
        if self.formats.get(l) == fmt {
            return Ok(false);
        }
        let lens: Vec<usize> =
            (0..self.dims.batch).map(|b| self.len(l, b)).collect();
        self.kv.migrate_layer(l, fmt, &lens);
        self.formats.set(l, fmt);
        for b in 0..self.dims.batch {
            let idx = self.lb(l, b);
            self.touch_rewrite(idx);
        }
        Ok(true)
    }

    /// Original absolute position of each live row of (l, b).
    pub fn pos(&self, l: usize, b: usize) -> &[i32] {
        &self.pos[self.lb(l, b)]
    }

    /// Accumulated attention score of each live row of (l, b).
    pub fn scores(&self, l: usize, b: usize) -> &[f32] {
        &self.scores[self.lb(l, b)]
    }

    /// Append one token's K/V (layout [Hkv, D]) at the next slot of
    /// (l, b). `abs_pos` is the token's absolute decode position.
    pub fn insert(
        &mut self,
        l: usize,
        b: usize,
        k_row: &[f32],
        v_row: &[f32],
        abs_pos: i32,
    ) -> Result<()> {
        self.slot_view_mut(b).insert(l, k_row, v_row, abs_pos)
    }

    /// Bulk-load a prefilled sequence into slot `b` (from prefill k_all
    /// [L, 1, Hkv, T, D] with `len` valid rows). Resets the slot first.
    pub fn load_prefill(
        &mut self,
        b: usize,
        k_all: &HostTensorF32,
        v_all: &HostTensorF32,
        len: usize,
    ) -> Result<()> {
        let CacheDims { layers, kv_heads, d_head, capacity, .. } = self.dims;
        let t = k_all.shape[3];
        ensure!(k_all.shape == vec![layers, 1, kv_heads, t, d_head],
                "bad prefill shape {:?}", k_all.shape);
        ensure!(len <= t && len <= capacity, "prefill len {len} too long");
        self.reset_slot(b);
        for l in 0..layers {
            let idx = self.lb(l, b);
            for h in 0..kv_heads {
                let src = ((l * kv_heads + h) * t) * d_head;
                let n = len * d_head;
                self.kv.load_rows(
                    l,
                    b,
                    h,
                    &k_all.data[src..src + n],
                    &v_all.data[src..src + n],
                    len,
                );
            }
            self.lens[idx] = len;
            self.pos[idx] = (0..len as i32).collect();
            self.scores[idx] = vec![0.0; len];
            self.touch_rewrite(idx);
        }
        Ok(())
    }

    /// Clear slot `b` across all layers (lens/pos/scores; rows beyond
    /// the live length are dead and overwritten lazily).
    pub fn reset_slot(&mut self, b: usize) {
        for l in 0..self.dims.layers {
            let idx = self.lb(l, b);
            self.lens[idx] = 0;
            self.pos[idx].clear();
            self.scores[idx].clear();
            self.touch_rewrite(idx);
        }
        // K/V rows beyond lens are dead; backends overwrite lazily.
    }

    /// Mark (layer, slot) `idx` rewritten: bump the epoch and move the
    /// rewrite watermark to it.
    fn touch_rewrite(&mut self, idx: usize) {
        let e = &mut self.epochs[idx];
        e.epoch += 1;
        e.rewrite = e.epoch;
    }

    /// Swap two slots' contents (scheduler keeps active slots
    /// front-packed; used when a middle sequence finishes). Only the live
    /// rows — `max(len_a, len_b)` per layer — are moved: dead rows beyond
    /// the live length are never read (the decode kernel masks by lens),
    /// so moving the full Cmax extent would be wasted bandwidth.
    pub fn swap_slots(&mut self, a: usize, b: usize) {
        if a == b {
            return;
        }
        for l in 0..self.dims.layers {
            let (ia, ib) = (self.lb(l, a), self.lb(l, b));
            let n = self.lens[ia].max(self.lens[ib]);
            self.kv.swap_rows(l, a, b, n);
            self.lens.swap(ia, ib);
            self.pos.swap(ia, ib);
            self.scores.swap(ia, ib);
            // Both sides count as rewritten; keep each pair's epoch
            // strictly increasing past both old values.
            let next = self.epochs[ia].epoch.max(self.epochs[ib].epoch) + 1;
            self.epochs[ia] = SlotEpoch { epoch: next, rewrite: next };
            self.epochs[ib] = SlotEpoch { epoch: next, rewrite: next };
        }
    }

    /// RASR-style score update for (l, b): `scores = gamma * scores + add`
    /// where `add[j]` is the head-summed attention mass on slot j this
    /// step (Eq. 5). `add` may be longer than the live length (bucket
    /// padding) — extra entries are ignored.
    pub fn accumulate_scores(
        &mut self,
        l: usize,
        b: usize,
        gamma: f32,
        add: &[f32],
    ) {
        self.slot_view_mut(b).accumulate_scores(l, gamma, add);
    }

    /// Apply a retention plan to (l, b): keep exactly the rows whose
    /// current indices are in `keep` (any order; deduplicated + sorted
    /// ascending so relative order — and thus recency structure — is
    /// preserved). Returns the new length.
    pub fn apply_retention(
        &mut self,
        l: usize,
        b: usize,
        keep: &[usize],
    ) -> Result<usize> {
        self.slot_view_mut(b).apply_retention(l, keep)
    }

    /// Pack the C-prefix of the first `bb` slots into f32 upload tensors
    /// for a (batch, capacity) bucket: k/v [L, bb, Hkv, C, D] +
    /// lens [L, bb]. Rows longer than C are a caller bug (the engine
    /// prunes or picks a bigger bucket first).
    pub fn pack(
        &self,
        bb: usize,
        c: usize,
        k_out: &mut HostTensorF32,
        v_out: &mut HostTensorF32,
        lens_out: &mut HostTensorI32,
    ) -> Result<()> {
        let CacheDims { layers, batch, kv_heads, d_head, .. } = self.dims;
        ensure!(bb <= batch, "batch bucket {bb} > group size {batch}");
        ensure!(c <= self.dims.capacity, "bucket {c} > Cmax");
        let want = vec![layers, bb, kv_heads, c, d_head];
        ensure!(k_out.shape == want && v_out.shape == want,
                "scratch shape mismatch: {:?} vs {want:?}", k_out.shape);
        let n = c * d_head;
        for l in 0..layers {
            for b in 0..bb {
                ensure!(self.len(l, b) <= c,
                        "live rows exceed bucket {c} at ({l},{b})");
                for h in 0..kv_heads {
                    let dst = ((l * bb + b) * kv_heads + h) * n;
                    self.kv.read_rows(l, b, h, false, 0, c,
                                      &mut k_out.data[dst..dst + n]);
                    self.kv.read_rows(l, b, h, true, 0, c,
                                      &mut v_out.data[dst..dst + n]);
                }
                lens_out.data[l * bb + b] = self.lens[self.lb(l, b)] as i32;
            }
        }
        Ok(())
    }

    /// Reconcile a persistent [`PackScratch`] with the current cache
    /// state, copying (dense) or dequantizing (quantized) only what
    /// changed since the scratch was last synced (see the module-level
    /// epoch protocol). The scratch ends up bit-identical to a fresh
    /// [`GroupCache::pack`] at the same bucket.
    pub fn pack_delta(&self, scratch: &mut PackScratch) -> Result<PackStats> {
        let CacheDims { layers, batch, kv_heads, d_head, .. } = self.dims;
        let (bb, cap) = (scratch.bb, scratch.cap);
        ensure!(bb <= batch, "batch bucket {bb} > group size {batch}");
        ensure!(cap <= self.dims.capacity, "bucket {cap} > Cmax");
        let want = vec![layers, bb, kv_heads, cap, d_head];
        ensure!(scratch.k.shape == want && scratch.v.shape == want,
                "scratch shape mismatch: {:?} vs {want:?}", scratch.k.shape);
        // Residency from another cache (or none) says nothing about this
        // one — every pair gets a full re-copy below.
        let cold = scratch.cache_id != Some(self.id);
        // Mark cold until the reconcile fully succeeds: an error below
        // (e.g. a mid-loop bucket overflow) leaves `res` partially
        // rewritten, and residency claiming the *previous* cache over
        // mixed contents could silently skip pairs on the next pack.
        scratch.cache_id = None;
        let mut stats = PackStats::default();
        let n_block = cap * d_head;
        for l in 0..layers {
            for b in 0..bb {
                let idx = self.lb(l, b);
                let len = self.lens[idx];
                ensure!(len <= cap,
                        "live rows exceed bucket {cap} at ({l},{b})");
                let st = self.epochs[idx];
                let ridx = l * bb + b;
                let (re, rlen) = scratch.res[ridx];
                let (from, to) = if !cold && re == st.epoch {
                    stats.pairs_skipped += 1;
                    (0, 0)
                } else if !cold && re >= st.rewrite {
                    // Append-only since last sync: rows 0..rlen are
                    // unchanged, only the newly inserted rows move.
                    stats.pairs_delta += 1;
                    (rlen, len)
                } else {
                    // Rewritten (or cold): re-copy the full C-prefix so
                    // dead rows match a fresh pack too.
                    stats.pairs_full += 1;
                    (0, cap)
                };
                if to > from {
                    let count = (to - from) * d_head;
                    for h in 0..kv_heads {
                        let dst = ((l * bb + b) * kv_heads + h) * n_block
                            + from * d_head;
                        self.kv.read_rows(l, b, h, false, from, to,
                                          &mut scratch.k.data[dst..dst + count]);
                        self.kv.read_rows(l, b, h, true, from, to,
                                          &mut scratch.v.data[dst..dst + count]);
                    }
                    // f32 bytes written into the upload scratch (K + V);
                    // format-independent because the scratch is f32, so
                    // wire == f32-equivalent on this path.
                    stats.bytes_copied += count * kv_heads * 4 * 2;
                    stats.bytes_f32_equiv += count * kv_heads * 4 * 2;
                }
                scratch.res[ridx] = (st.epoch, len);
                scratch.lens.data[ridx] = len as i32;
            }
        }
        scratch.cache_id = Some(self.id);
        Ok(stats)
    }

    /// Reconcile a persistent [`PackedScratch`] — the quantized layers'
    /// stored codes + scales (+ zeros for q4), **not** an f32 expansion
    /// — under exactly the epoch protocol of
    /// [`GroupCache::pack_delta`]: skip resident pairs, copy only newly
    /// appended rows after append-only mutation, full C-prefix re-copy
    /// after a rewrite or on a cold scratch. This is the raw-speed
    /// upload path for the kernel-side-dequant decode executables
    /// (`decode_b{B}_c{C}_q8` / `_q4`): the bytes moved per head-row
    /// are the stored wire bytes (`D + 4` for q8,
    /// `ceil(D/2) + 8·groups` for q4) instead of the `4·D` f32 image.
    /// Every layer must store exactly the scratch's format — the
    /// engine falls back to [`GroupCache::pack_delta`] for dense or
    /// mixed maps. Errors before mutating anything on a format or
    /// shape mismatch.
    pub fn pack_delta_packed(
        &self,
        scratch: &mut PackedScratch,
    ) -> Result<PackStats> {
        let CacheDims { layers, batch, kv_heads, d_head, .. } = self.dims;
        let fmt = scratch.fmt;
        ensure!(self.formats.uniform_format() == Some(fmt),
                "packed scratch is {} but the cache stores {}",
                fmt.label(), self.format_label());
        let (bb, cap) = (scratch.bb, scratch.cap);
        ensure!(bb <= batch, "batch bucket {bb} > group size {batch}");
        ensure!(cap <= self.dims.capacity, "bucket {cap} > Cmax");
        let db = quant::packed_codes_per_row(d_head, fmt)
            .expect("packed scratch format is quantized");
        let sg = quant::packed_scales_per_row(d_head, fmt)
            .expect("packed scratch format is quantized");
        let zg = if fmt == KvFormat::QuantI4 { sg } else { 0 };
        let want = vec![layers, bb, kv_heads, cap, db];
        ensure!(scratch.k_codes.shape == want
                    && scratch.v_codes.shape == want,
                "packed scratch shape mismatch: {:?} vs {want:?}",
                scratch.k_codes.shape);
        // Residency semantics are identical to pack_delta: unknown (or
        // mid-error) scratches are cold and fully re-copied.
        let cold = scratch.cache_id != Some(self.id);
        scratch.cache_id = None;
        let mut stats = PackStats::default();
        for l in 0..layers {
            for b in 0..bb {
                let idx = self.lb(l, b);
                let len = self.lens[idx];
                ensure!(len <= cap,
                        "live rows exceed bucket {cap} at ({l},{b})");
                let st = self.epochs[idx];
                let ridx = l * bb + b;
                let (re, rlen) = scratch.res[ridx];
                let (from, to) = if !cold && re == st.epoch {
                    stats.pairs_skipped += 1;
                    (0, 0)
                } else if !cold && re >= st.rewrite {
                    stats.pairs_delta += 1;
                    (rlen, len)
                } else {
                    stats.pairs_full += 1;
                    (0, cap)
                };
                if to > from {
                    let rows = to - from;
                    for h in 0..kv_heads {
                        let base = ((l * bb + b) * kv_heads + h) * cap;
                        let co = (base + from) * db;
                        let so = (base + from) * sg;
                        let zo = (base + from) * zg;
                        let (cn, sn, zn) = (rows * db, rows * sg, rows * zg);
                        self.kv.export_packed_rows(
                            l, b, h, false, from, to,
                            &mut scratch.k_codes.data[co..co + cn],
                            &mut scratch.k_scales.data[so..so + sn],
                            &mut scratch.k_zeros.data[zo..zo + zn],
                        );
                        self.kv.export_packed_rows(
                            l, b, h, true, from, to,
                            &mut scratch.v_codes.data[co..co + cn],
                            &mut scratch.v_scales.data[so..so + sn],
                            &mut scratch.v_zeros.data[zo..zo + zn],
                        );
                    }
                    // Wire bytes actually staged (codes + f32 scales and
                    // zeros, K + V), plus the f32 pricing of the same
                    // rows for the compression-ratio telemetry.
                    let wire = db + 4 * (sg + zg);
                    stats.bytes_copied += rows * kv_heads * wire * 2;
                    stats.bytes_f32_equiv += rows * kv_heads * d_head * 4 * 2;
                }
                scratch.res[ridx] = (st.epoch, len);
                scratch.lens.data[ridx] = len as i32;
            }
        }
        scratch.cache_id = Some(self.id);
        Ok(stats)
    }

    /// Raw component pointers shared by the view constructors. Refreshes
    /// the per-layer [`RawKv`] table in `self.raw_kv`; the returned
    /// parts point into it, so they are only valid while the view borrow
    /// on `self` lives.
    fn raw_parts(&mut self) -> RawParts {
        self.kv.raw_table(&mut self.raw_kv);
        RawParts {
            kv: RawKvTable::new(&self.raw_kv),
            lens: self.lens.as_mut_ptr(),
            pos: self.pos.as_mut_ptr(),
            scores: self.scores.as_mut_ptr(),
            epochs: self.epochs.as_mut_ptr(),
        }
    }

    /// Exclusive mutable view over one slot's state across all layers.
    pub fn slot_view_mut(&mut self, b: usize) -> SlotViewMut<'_> {
        assert!(b < self.dims.batch, "slot {b} out of range");
        let parts = self.raw_parts();
        SlotViewMut {
            b,
            dims: self.dims,
            parts,
            _borrow: PhantomData,
        }
    }

    /// Disjoint mutable views over slots `0..n`, for parallel per-slot
    /// post-decode work. Each view only ever touches its own slot's
    /// backend rows, lens, pos, scores and epochs, so the views can be
    /// sent to different worker threads simultaneously.
    pub fn slot_views_mut(&mut self, n: usize) -> Vec<SlotViewMut<'_>> {
        assert!(n <= self.dims.batch,
                "view count {n} > group size {}", self.dims.batch);
        let parts = self.raw_parts();
        let dims = self.dims;
        (0..n)
            .map(|b| SlotViewMut {
                b,
                dims,
                parts,
                _borrow: PhantomData,
            })
            .collect()
    }

    /// Live KV bytes of slot `b` alone, priced like
    /// [`Self::live_bytes`] (each layer at its own stored-format rate).
    /// The scheduler's swap-vs-recompute cost model compares this — the
    /// bytes a swap must move — against the tokens a recompute must
    /// re-prefill.
    pub fn slot_live_bytes(&self, b: usize) -> usize {
        (0..self.dims.layers)
            .map(|l| self.kv.layer_row_bytes(l) * self.len(l, b))
            .sum()
    }

    /// Serialize slot `b`'s live state — rows at **stored precision**
    /// via [`KvStore::export_rows`], plus lens/pos/scores and the
    /// per-layer formats in force — into a host-side [`HostSlotImage`].
    /// Read-only: the slot stays resident until the caller clears it.
    /// Because the row bytes round-trip exactly and
    /// [`KvStore::read_rows`] is deterministic for a given stored state,
    /// a later [`Self::restore_from_host`] reproduces the slot's packed
    /// K/V bit-identically — swap-preempted sequences resume
    /// token-identical under greedy decode.
    pub fn evict_to_host(&self, b: usize) -> HostSlotImage {
        let layers = self.dims.layers;
        let mut bytes = Vec::with_capacity(layers);
        let mut lens = Vec::with_capacity(layers);
        let mut pos = Vec::with_capacity(layers);
        let mut scores = Vec::with_capacity(layers);
        for l in 0..layers {
            let idx = self.lb(l, b);
            let len = self.lens[idx];
            let mut buf = Vec::with_capacity(len * self.kv.layer_row_bytes(l));
            self.kv.export_rows(l, b, len, &mut buf);
            bytes.push(buf);
            lens.push(len);
            pos.push(self.pos[idx].clone());
            scores.push(self.scores[idx].clone());
        }
        HostSlotImage {
            bytes,
            lens,
            pos,
            scores,
            formats: self.formats.as_slice().to_vec(),
        }
    }

    /// Load a [`HostSlotImage`] back into slot `b`: the inverse of
    /// [`Self::evict_to_host`]. Validates **before mutating anything**
    /// that the image matches this cache — same layer count, every
    /// layer still in the format it was exported at (a live
    /// [`Self::migrate_layer_format`] while the image was swapped out
    /// makes the raw bytes unreadable), rows within capacity, payload
    /// sizes exact — so a failed restore leaves the slot untouched and
    /// the caller can fall back to recompute. Marks every (layer, slot)
    /// pair rewritten (delta-pack full re-copy on next pack).
    pub fn restore_from_host(&mut self, b: usize, img: &HostSlotImage) -> Result<()> {
        let layers = self.dims.layers;
        ensure!(b < self.dims.batch, "slot {b} out of range");
        ensure!(img.formats.len() == layers,
                "image covers {} layers, cache has {layers}", img.formats.len());
        for l in 0..layers {
            ensure!(self.formats.get(l) == img.formats[l],
                    "layer {l} format changed while swapped out ({} -> {})",
                    img.formats[l].label(), self.formats.get(l).label());
            ensure!(img.lens[l] <= self.dims.capacity,
                    "image rows {} exceed capacity {} at layer {l}",
                    img.lens[l], self.dims.capacity);
            let want = img.lens[l] * self.kv.layer_row_bytes(l);
            ensure!(img.bytes[l].len() == want,
                    "image payload at layer {l} is {} bytes, expected {want}",
                    img.bytes[l].len());
        }
        for l in 0..layers {
            let idx = self.lb(l, b);
            let used = self.kv.import_rows(l, b, img.lens[l], &img.bytes[l]);
            debug_assert_eq!(used, img.bytes[l].len());
            self.lens[idx] = img.lens[l];
            self.pos[idx] = img.pos[l].clone();
            self.scores[idx] = img.scores[l].clone();
            self.touch_rewrite(idx);
        }
        Ok(())
    }

    /// Retained-slot bitmap for one layer/slot against absolute positions
    /// 0..=max_pos (Figure 3 visualisation).
    pub fn retention_bitmap(&self, l: usize, b: usize, max_pos: usize) -> Vec<bool> {
        let mut bm = vec![false; max_pos + 1];
        for &p in self.pos(l, b) {
            if (p as usize) <= max_pos {
                bm[p as usize] = true;
            }
        }
        bm
    }
}

/// Host-side image of one slot's live KV state across all layers:
/// row payload at stored precision (f32/q8/q4 byte streams from
/// [`KvStore::export_rows`]), the bookkeeping that makes the rows
/// meaningful (lens, pos, scores) and the per-layer formats the bytes
/// were encoded at. Produced by [`GroupCache::evict_to_host`] when the
/// scheduler swap-preempts a sequence instead of discarding its cache;
/// consumed by [`GroupCache::restore_from_host`] on resume.
#[derive(Clone, Debug)]
pub struct HostSlotImage {
    /// Per-layer row payload at stored precision.
    bytes: Vec<Vec<u8>>,
    /// Per-layer live-row counts.
    lens: Vec<usize>,
    /// Per-layer original absolute positions (length = lens[l]).
    pos: Vec<Vec<i32>>,
    /// Per-layer accumulated attention scores (length = lens[l]).
    scores: Vec<Vec<f32>>,
    /// Format each layer's bytes were encoded at (restore must match).
    formats: Vec<KvFormat>,
}

impl HostSlotImage {
    /// Total row-payload bytes held — what a swap actually moved
    /// (the `swap_bytes_out` / `swap_bytes_in` metrics).
    pub fn payload_bytes(&self) -> usize {
        self.bytes.iter().map(Vec::len).sum()
    }

    /// Longest live row across layers (the KV footprint in tokens the
    /// admission projection uses when re-admitting a swapped sequence).
    pub fn max_rows(&self) -> usize {
        self.lens.iter().copied().max().unwrap_or(0)
    }
}

/// Raw pointers to the cache's component buffers (Copy so every view can
/// carry the full set; provenance is the whole allocation, each view
/// restricts itself to its slot's disjoint sub-ranges).
#[derive(Clone, Copy)]
struct RawParts {
    kv: RawKvTable,
    lens: *mut usize,
    pos: *mut Vec<i32>,
    scores: *mut Vec<f32>,
    epochs: *mut SlotEpoch,
}

/// Exclusive mutable access to one slot `b` of a [`GroupCache`], across
/// all layers. Obtained via [`GroupCache::slot_views_mut`]; the borrow on
/// the cache lives as long as any view, and distinct views touch disjoint
/// (layer, slot) state, so a set of views is safe to use from multiple
/// threads at once (the engine's parallel post-decode pipeline).
pub struct SlotViewMut<'a> {
    b: usize,
    dims: CacheDims,
    parts: RawParts,
    _borrow: PhantomData<&'a mut GroupCache>,
}

// SAFETY: all pointed-to data is plain owned memory (f32/i8 row buffers,
// `usize`/`Vec`s of Send types), and the constructor hands out at most
// one view per slot, so no two threads ever alias the same (layer, slot)
// state.
unsafe impl Send for SlotViewMut<'_> {}

impl SlotViewMut<'_> {
    /// The slot index this view owns.
    pub fn slot(&self) -> usize {
        self.b
    }

    /// Model layers covered by the view (== the cache's layer count).
    pub fn layers(&self) -> usize {
        self.dims.layers
    }

    #[inline]
    fn lb(&self, l: usize) -> usize {
        l * self.dims.batch + self.b
    }

    /// Live rows of this slot at layer `l`.
    pub fn len(&self, l: usize) -> usize {
        unsafe { *self.parts.lens.add(self.lb(l)) }
    }

    /// True when no layer of this slot holds live rows.
    pub fn is_empty(&self) -> bool {
        (0..self.dims.layers).all(|l| self.len(l) == 0)
    }

    /// Original absolute positions of this slot's rows at layer `l`.
    pub fn pos(&self, l: usize) -> &[i32] {
        unsafe { &*self.parts.pos.add(self.lb(l)) }
    }

    /// Accumulated attention scores of this slot's rows at layer `l`.
    pub fn scores(&self, l: usize) -> &[f32] {
        unsafe { &*self.parts.scores.add(self.lb(l)) }
    }

    /// Append one token's K/V (layout [Hkv, D]); see
    /// [`GroupCache::insert`]. Bumps the pair's epoch (append).
    pub fn insert(
        &mut self,
        l: usize,
        k_row: &[f32],
        v_row: &[f32],
        abs_pos: i32,
    ) -> Result<()> {
        let d = self.dims.d_head;
        let hkv = self.dims.kv_heads;
        ensure!(k_row.len() == hkv * d && v_row.len() == hkv * d,
                "bad row size");
        let idx = self.lb(l);
        let c = self.len(l);
        ensure!(c < self.dims.capacity,
                "cache overflow at layer {l} slot {} (len {c})", self.b);
        // SAFETY: this view is the sole owner of slot `b`'s rows and
        // bookkeeping entries; the PhantomData borrow keeps the cache
        // (and its raw table) alive and unmoved. Layer `l`'s entry is a
        // single-layer store, so the row write passes l = 0.
        unsafe {
            self.parts
                .kv
                .layer(l)
                .write_row(&self.dims, 0, self.b, c, k_row, v_row);
            *self.parts.lens.add(idx) = c + 1;
            (*self.parts.pos.add(idx)).push(abs_pos);
            (*self.parts.scores.add(idx)).push(0.0);
            (*self.parts.epochs.add(idx)).epoch += 1;
        }
        Ok(())
    }

    /// RASR score update; see [`GroupCache::accumulate_scores`].
    pub fn accumulate_scores(&mut self, l: usize, gamma: f32, add: &[f32]) {
        let idx = self.lb(l);
        let n = self.len(l);
        let s = unsafe { &mut *self.parts.scores.add(idx) };
        for j in 0..n {
            s[j] = gamma * s[j] + add.get(j).copied().unwrap_or(0.0);
        }
    }

    /// Retention gather; see [`GroupCache::apply_retention`]. Marks the
    /// pair rewritten (delta-pack full re-copy on next pack).
    pub fn apply_retention(&mut self, l: usize, keep: &[usize]) -> Result<usize> {
        let idx = self.lb(l);
        let n = self.len(l);
        let mut ks: Vec<usize> = keep.to_vec();
        ks.sort_unstable();
        ks.dedup();
        ensure!(ks.iter().all(|&i| i < n),
                "retention index out of range (len {n})");
        // SAFETY: as in `insert` — exclusive slot ownership; layer-local
        // gather on layer `l`'s single-layer store.
        unsafe {
            self.parts.kv.layer(l).gather_rows(&self.dims, 0, self.b, &ks);
            let pos = &mut *self.parts.pos.add(idx);
            let sc = &mut *self.parts.scores.add(idx);
            for (dst, &src) in ks.iter().enumerate() {
                pos[dst] = pos[src];
                sc[dst] = sc[src];
            }
            pos.truncate(ks.len());
            sc.truncate(ks.len());
            *self.parts.lens.add(idx) = ks.len();
            let e = &mut *self.parts.epochs.add(idx);
            e.epoch += 1;
            e.rewrite = e.epoch;
        }
        Ok(ks.len())
    }
}

/// What one [`GroupCache::pack_delta`] /
/// [`GroupCache::pack_delta_packed`] call actually moved.
#[derive(Clone, Copy, Debug, Default)]
pub struct PackStats {
    /// Host bytes copied into the scratch (K + V) **at the scratch's
    /// wire width**: f32 expansion for [`PackScratch`], stored
    /// codes + scales for [`PackedScratch`].
    pub bytes_copied: usize,
    /// The same moved rows priced at dense f32
    /// (`rows × Hkv × D × 4 × 2`). Equal to `bytes_copied` on the f32
    /// path; the `bytes_f32_equiv / bytes_copied` ratio is the packed
    /// path's upload-byte reduction.
    pub bytes_f32_equiv: usize,
    /// (layer, slot) pairs re-copied in full (rewritten or cold).
    pub pairs_full: usize,
    /// Pairs where only newly appended rows were copied.
    pub pairs_delta: usize,
    /// Pairs already resident at the current epoch (zero copy).
    pub pairs_skipped: usize,
}

/// Persistent f32 upload image for one (batch, capacity) bucket, plus
/// the per-(layer, slot) residency record [`GroupCache::pack_delta`]
/// uses to decide how little it can copy. The image is f32 for every
/// backend: quantized storage dequantizes during reconcile.
pub struct PackScratch {
    /// Packed K image `[L, bb, Hkv, C, D]` (always f32).
    pub k: HostTensorF32,
    /// Packed V image `[L, bb, Hkv, C, D]` (always f32).
    pub v: HostTensorF32,
    /// Live-row counts `[L, bb]`.
    pub lens: HostTensorI32,
    bb: usize,
    cap: usize,
    /// Which cache (by unique id) the residency describes; None = cold.
    cache_id: Option<u64>,
    /// [L * bb] -> (epoch held, rows valid at that epoch).
    res: Vec<(u64, usize)>,
}

impl PackScratch {
    /// `dims` supplies layers/kv_heads/d_head; `bb`/`cap` are the bucket.
    pub fn new(dims: &CacheDims, bb: usize, cap: usize) -> PackScratch {
        let shape = [dims.layers, bb, dims.kv_heads, cap, dims.d_head];
        PackScratch {
            k: HostTensorF32::zeros(&shape),
            v: HostTensorF32::zeros(&shape),
            lens: HostTensorI32::zeros(&[dims.layers, bb]),
            bb,
            cap,
            cache_id: None,
            res: vec![(0, 0); dims.layers * bb],
        }
    }

    /// The (batch, capacity) bucket this scratch was sized for.
    pub fn bucket(&self) -> (usize, usize) {
        (self.bb, self.cap)
    }

    /// Total wire bytes of one full upload image (K + V + lens) — the
    /// per-step f32 upload cost the benches compare the packed path
    /// against.
    pub fn image_bytes(&self) -> usize {
        self.k.bytes() + self.v.bytes() + self.lens.bytes()
    }

    /// Drop residency; the next pack_delta re-copies everything.
    pub fn invalidate(&mut self) {
        self.cache_id = None;
    }
}

/// Persistent **packed** upload image for one (batch, capacity) bucket:
/// the quantized stores' codes + scales (+ zeros for q4), in exactly
/// the operand layout the kernel-side-dequant decode executables
/// (`decode_b{B}_c{C}_q8` / `_q4`) take — so a uniformly quantized
/// group uploads its stored bytes instead of a 4·D f32 expansion.
/// Maintained by [`GroupCache::pack_delta_packed`] under the same
/// epoch/residency protocol as [`PackScratch`].
pub struct PackedScratch {
    /// Packed K codes: `[L, bb, Hkv, C, D]` u8 holding i8 bit patterns
    /// for q8; `[L, bb, Hkv, C, ceil(D/2)]` two-nibbles-per-byte for q4.
    pub k_codes: HostTensorU8,
    /// K scales: per-row `[L, bb, Hkv, C]` for q8, per-group
    /// `[L, bb, Hkv, C, G]` for q4.
    pub k_scales: HostTensorF32,
    /// K zero points, per-group `[L, bb, Hkv, C, G]` (q4 only; empty
    /// for q8, whose codec is symmetric).
    pub k_zeros: HostTensorF32,
    /// Packed V codes (same layout as `k_codes`).
    pub v_codes: HostTensorU8,
    /// V scales (same layout as `k_scales`).
    pub v_scales: HostTensorF32,
    /// V zero points (same layout as `k_zeros`).
    pub v_zeros: HostTensorF32,
    /// Live-row counts `[L, bb]`.
    pub lens: HostTensorI32,
    fmt: KvFormat,
    bb: usize,
    cap: usize,
    /// Which cache (by unique id) the residency describes; None = cold.
    cache_id: Option<u64>,
    /// [L * bb] -> (epoch held, rows valid at that epoch).
    res: Vec<(u64, usize)>,
}

impl PackedScratch {
    /// Scratch for a (bb, cap) bucket at packed format `fmt`. Panics on
    /// [`KvFormat::F32`], which has no packed wire form (use
    /// [`PackScratch`]).
    pub fn new(
        dims: &CacheDims,
        bb: usize,
        cap: usize,
        fmt: KvFormat,
    ) -> PackedScratch {
        let db = quant::packed_codes_per_row(dims.d_head, fmt)
            .expect("PackedScratch requires a quantized format");
        let sg = quant::packed_scales_per_row(dims.d_head, fmt)
            .expect("PackedScratch requires a quantized format");
        let codes = [dims.layers, bb, dims.kv_heads, cap, db];
        // q8 carries one scale per row: shaped [L, bb, Hkv, C] — the
        // 4-D operand the q8 executables expect — not a trailing
        // singleton dim.
        let scales: Vec<usize> = if fmt == KvFormat::QuantI8 {
            vec![dims.layers, bb, dims.kv_heads, cap]
        } else {
            vec![dims.layers, bb, dims.kv_heads, cap, sg]
        };
        let zeros: Vec<usize> = if fmt == KvFormat::QuantI4 {
            scales.clone()
        } else {
            vec![0]
        };
        PackedScratch {
            k_codes: HostTensorU8::zeros(&codes),
            k_scales: HostTensorF32::zeros(&scales),
            k_zeros: HostTensorF32::zeros(&zeros),
            v_codes: HostTensorU8::zeros(&codes),
            v_scales: HostTensorF32::zeros(&scales),
            v_zeros: HostTensorF32::zeros(&zeros),
            lens: HostTensorI32::zeros(&[dims.layers, bb]),
            fmt,
            bb,
            cap,
            cache_id: None,
            res: vec![(0, 0); dims.layers * bb],
        }
    }

    /// The (batch, capacity) bucket this scratch was sized for.
    pub fn bucket(&self) -> (usize, usize) {
        (self.bb, self.cap)
    }

    /// The packed format the images are encoded at.
    pub fn format(&self) -> KvFormat {
        self.fmt
    }

    /// Total wire bytes of one full upload image (codes + scales +
    /// zeros + lens, K and V) — the per-step upload cost of the packed
    /// path the benches report against [`PackScratch::image_bytes`].
    pub fn image_bytes(&self) -> usize {
        self.k_codes.bytes() + self.k_scales.bytes() + self.k_zeros.bytes()
            + self.v_codes.bytes() + self.v_scales.bytes()
            + self.v_zeros.bytes() + self.lens.bytes()
    }

    /// Drop residency; the next pack_delta_packed re-copies everything.
    pub fn invalidate(&mut self) {
        self.cache_id = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> CacheDims {
        CacheDims { layers: 2, batch: 2, kv_heads: 2, capacity: 8, d_head: 4 }
    }

    fn row(val: f32, hkv: usize, d: usize) -> Vec<f32> {
        (0..hkv * d).map(|i| val + i as f32 * 0.01).collect()
    }

    /// First element of the stored (l, b, h, row) K row, read through the
    /// backend (replaces the old direct `c.k[off]` peeks).
    fn k_at(c: &GroupCache, l: usize, b: usize, h: usize, row_idx: usize) -> f32 {
        let d = c.dims.d_head;
        let mut buf = vec![0.0; d];
        c.kv.read_rows(l, b, h, false, row_idx, row_idx + 1, &mut buf);
        buf[0]
    }

    #[test]
    fn insert_then_lengths_and_bytes() {
        let mut c = GroupCache::new(dims());
        for t in 0..3 {
            for l in 0..2 {
                c.insert(l, 0, &row(t as f32, 2, 4), &row(-(t as f32), 2, 4), t)
                    .unwrap();
            }
        }
        assert_eq!(c.len(0, 0), 3);
        assert_eq!(c.len(1, 0), 3);
        assert_eq!(c.len(0, 1), 0);
        assert_eq!(c.max_len(), 3);
        // 2 layers * 3 tokens * (2 heads * 4 dim * 4 bytes * 2 tensors)
        assert_eq!(c.live_bytes(), 2 * 3 * 2 * 4 * 4 * 2);
        // Dense backend: f32-equivalent == actual.
        assert_eq!(c.f32_equivalent_bytes(), c.live_bytes());
        assert_eq!(c.format_map().uniform_format(), Some(KvFormat::F32));
        assert_eq!(c.format_label(), "f32");
    }

    #[test]
    fn overflow_is_an_error() {
        let mut c = GroupCache::new(dims());
        for t in 0..8 {
            c.insert(0, 0, &row(0.0, 2, 4), &row(0.0, 2, 4), t).unwrap();
        }
        assert!(c.insert(0, 0, &row(0.0, 2, 4), &row(0.0, 2, 4), 9).is_err());
    }

    #[test]
    fn retention_front_packs_and_keeps_alignment() {
        let mut c = GroupCache::new(dims());
        for t in 0..6 {
            c.insert(0, 0, &row(t as f32, 2, 4), &row(t as f32, 2, 4), t)
                .unwrap();
        }
        c.accumulate_scores(0, 0, 1.0, &[0.1, 0.2, 0.3, 0.4, 0.5, 0.6]);
        let new_len = c.apply_retention(0, 0, &[5, 0, 3]).unwrap();
        assert_eq!(new_len, 3);
        assert_eq!(c.pos(0, 0), &[0, 3, 5]);
        let s = c.scores(0, 0);
        assert!((s[0] - 0.1).abs() < 1e-6);
        assert!((s[1] - 0.4).abs() < 1e-6);
        assert!((s[2] - 0.6).abs() < 1e-6);
        // K row 1 must now hold original token 3's data.
        assert!((k_at(&c, 0, 0, 0, 1) - 3.0).abs() < 1e-6);
    }

    #[test]
    fn retention_rejects_out_of_range() {
        let mut c = GroupCache::new(dims());
        c.insert(0, 0, &row(0.0, 2, 4), &row(0.0, 2, 4), 0).unwrap();
        assert!(c.apply_retention(0, 0, &[1]).is_err());
    }

    #[test]
    fn pack_respects_bucket_and_lens() {
        let mut c = GroupCache::new(dims());
        for t in 0..4 {
            c.insert(0, 0, &row(t as f32, 2, 4), &row(t as f32, 2, 4), t)
                .unwrap();
        }
        let mut k = HostTensorF32::zeros(&[2, 2, 2, 4, 4]);
        let mut v = HostTensorF32::zeros(&[2, 2, 2, 4, 4]);
        let mut lens = HostTensorI32::zeros(&[2, 2]);
        c.pack(2, 4, &mut k, &mut v, &mut lens).unwrap();
        assert_eq!(lens.data, vec![4, 0, 0, 0]);
        // First token row of (l=0,b=0,h=0) == inserted value 0.0.
        assert!((k.data[0] - 0.0).abs() < 1e-6);
        // Bucket smaller than live rows must fail.
        let mut k2 = HostTensorF32::zeros(&[2, 2, 2, 2, 4]);
        let mut v2 = HostTensorF32::zeros(&[2, 2, 2, 2, 4]);
        let mut l2 = HostTensorI32::zeros(&[2, 2]);
        assert!(c.pack(2, 2, &mut k2, &mut v2, &mut l2).is_err());
        // Packing a single-slot bucket works and only covers slot 0.
        let mut k1 = HostTensorF32::zeros(&[2, 1, 2, 4, 4]);
        let mut v1 = HostTensorF32::zeros(&[2, 1, 2, 4, 4]);
        let mut l1 = HostTensorI32::zeros(&[2, 1]);
        c.pack(1, 4, &mut k1, &mut v1, &mut l1).unwrap();
        assert_eq!(l1.data, vec![4, 0]);
    }

    #[test]
    fn swap_slots_swaps_everything() {
        let mut c = GroupCache::new(dims());
        c.insert(0, 0, &row(1.0, 2, 4), &row(1.0, 2, 4), 0).unwrap();
        c.insert(0, 1, &row(9.0, 2, 4), &row(9.0, 2, 4), 0).unwrap();
        c.insert(0, 1, &row(8.0, 2, 4), &row(8.0, 2, 4), 1).unwrap();
        c.swap_slots(0, 1);
        assert_eq!(c.len(0, 0), 2);
        assert_eq!(c.len(0, 1), 1);
        assert!((k_at(&c, 0, 0, 0, 0) - 9.0).abs() < 1e-6);
    }

    #[test]
    fn load_prefill_resets_and_fills() {
        let mut c = GroupCache::new(dims());
        c.insert(0, 0, &row(5.0, 2, 4), &row(5.0, 2, 4), 0).unwrap();
        let t = 4;
        let k_all = HostTensorF32::from_vec(
            &[2, 1, 2, t, 4],
            (0..2 * 2 * t * 4).map(|i| i as f32).collect(),
        )
        .unwrap();
        let v_all = k_all.clone();
        c.load_prefill(0, &k_all, &v_all, 3).unwrap();
        assert_eq!(c.len(0, 0), 3);
        assert_eq!(c.len(1, 0), 3);
        assert_eq!(c.pos(0, 0), &[0, 1, 2]);
        assert_eq!(c.scores(1, 0), &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn retention_bitmap_marks_positions() {
        let mut c = GroupCache::new(dims());
        for t in 0..5 {
            c.insert(0, 0, &row(0.0, 2, 4), &row(0.0, 2, 4), t).unwrap();
        }
        c.apply_retention(0, 0, &[0, 4]).unwrap();
        let bm = c.retention_bitmap(0, 0, 4);
        assert_eq!(bm, vec![true, false, false, false, true]);
    }

    fn assert_matches_fresh_pack(c: &GroupCache, s: &PackScratch) {
        let (bb, cap) = s.bucket();
        let shape = [c.dims.layers, bb, c.dims.kv_heads, cap, c.dims.d_head];
        let mut k = HostTensorF32::zeros(&shape);
        let mut v = HostTensorF32::zeros(&shape);
        let mut lens = HostTensorI32::zeros(&[c.dims.layers, bb]);
        c.pack(bb, cap, &mut k, &mut v, &mut lens).unwrap();
        assert_eq!(k.data, s.k.data, "K scratch diverged from fresh pack");
        assert_eq!(v.data, s.v.data, "V scratch diverged from fresh pack");
        assert_eq!(lens.data, s.lens.data, "lens diverged from fresh pack");
    }

    #[test]
    fn epochs_distinguish_appends_from_rewrites() {
        let mut c = GroupCache::new(dims());
        let e0 = c.slot_epoch(0, 0);
        c.insert(0, 0, &row(1.0, 2, 4), &row(1.0, 2, 4), 0).unwrap();
        let e1 = c.slot_epoch(0, 0);
        assert_eq!(e1.epoch, e0.epoch + 1);
        assert_eq!(e1.rewrite, e0.rewrite, "append must not move rewrite");
        c.apply_retention(0, 0, &[0]).unwrap();
        let e2 = c.slot_epoch(0, 0);
        assert!(e2.epoch > e1.epoch);
        assert_eq!(e2.rewrite, e2.epoch, "retention is a rewrite");
    }

    #[test]
    fn delta_pack_append_only_copies_only_new_rows() {
        let mut c = GroupCache::new(dims());
        for t in 0..3 {
            for l in 0..2 {
                c.insert(l, 0, &row(t as f32, 2, 4), &row(t as f32, 2, 4), t)
                    .unwrap();
            }
        }
        let mut s = PackScratch::new(&c.dims, 2, 8);
        let st = c.pack_delta(&mut s).unwrap();
        assert_eq!(st.pairs_full, 4, "cold sync re-copies every pair");
        assert_matches_fresh_pack(&c, &s);

        // One append on (0, 0): exactly one delta pair, rest skipped,
        // bytes == 1 row * Hkv * D * 4 bytes * 2 tensors.
        c.insert(0, 0, &row(9.0, 2, 4), &row(9.0, 2, 4), 3).unwrap();
        let st = c.pack_delta(&mut s).unwrap();
        assert_eq!(st.pairs_delta, 1);
        assert_eq!(st.pairs_skipped, 3);
        assert_eq!(st.pairs_full, 0);
        assert_eq!(st.bytes_copied, 2 * 4 * 4 * 2);
        assert_eq!(st.bytes_f32_equiv, st.bytes_copied,
                   "f32 path: wire bytes == f32-equivalent bytes");
        assert_matches_fresh_pack(&c, &s);

        // No change at all: pure skip.
        let st = c.pack_delta(&mut s).unwrap();
        assert_eq!(st.pairs_skipped, 4);
        assert_eq!(st.bytes_copied, 0);
        assert_matches_fresh_pack(&c, &s);
    }

    #[test]
    fn delta_pack_repacks_rewritten_pairs() {
        let mut c = GroupCache::new(dims());
        for t in 0..5 {
            for l in 0..2 {
                c.insert(l, 0, &row(t as f32, 2, 4), &row(t as f32, 2, 4), t)
                    .unwrap();
            }
        }
        let mut s = PackScratch::new(&c.dims, 2, 8);
        c.pack_delta(&mut s).unwrap();
        c.apply_retention(0, 0, &[0, 2, 4]).unwrap();
        let st = c.pack_delta(&mut s).unwrap();
        assert_eq!(st.pairs_full, 1, "only the retained pair re-copies");
        assert_eq!(st.pairs_skipped, 3);
        assert_matches_fresh_pack(&c, &s);

        c.swap_slots(0, 1);
        let st = c.pack_delta(&mut s).unwrap();
        assert_eq!(st.pairs_full, 4, "swap rewrites both slots, all layers");
        assert_matches_fresh_pack(&c, &s);
    }

    #[test]
    fn delta_pack_never_trusts_a_different_cache() {
        let mut c = GroupCache::new(dims());
        c.insert(0, 0, &row(1.0, 2, 4), &row(1.0, 2, 4), 0).unwrap();
        let mut s = PackScratch::new(&c.dims, 2, 8);
        c.pack_delta(&mut s).unwrap();

        // A clone has a fresh id: same epochs, divergent future.
        let mut c2 = c.clone();
        assert_ne!(c.cache_id(), c2.cache_id());
        c2.insert(0, 0, &row(7.0, 2, 4), &row(7.0, 2, 4), 1).unwrap();
        let st = c2.pack_delta(&mut s).unwrap();
        assert_eq!(st.pairs_full, 4, "unknown cache forces a cold sync");
        assert_matches_fresh_pack(&c2, &s);

        s.invalidate();
        let st = c2.pack_delta(&mut s).unwrap();
        assert_eq!(st.pairs_full, 4);
    }

    #[test]
    fn delta_pack_rejects_overfull_bucket() {
        let mut c = GroupCache::new(dims());
        for t in 0..5 {
            c.insert(0, 0, &row(0.0, 2, 4), &row(0.0, 2, 4), t).unwrap();
        }
        let mut s = PackScratch::new(&c.dims, 2, 4);
        assert!(c.pack_delta(&mut s).is_err());
    }

    #[test]
    fn quant_backend_end_to_end_retention_and_pack() {
        let mut c = GroupCache::with_format(dims(), KvFormat::QuantI8);
        assert_eq!(c.format_map().uniform_format(), Some(KvFormat::QuantI8));
        assert_eq!(c.format_label(), "q8");
        for t in 0..6 {
            c.insert(0, 0, &row(t as f32, 2, 4), &row(t as f32, 2, 4), t)
                .unwrap();
        }
        // Quantized storage is smaller than its f32 equivalent:
        // (4 + 4) vs 4 * 4 bytes per head-row.
        assert_eq!(c.live_bytes() * 2, c.f32_equivalent_bytes());
        c.apply_retention(0, 0, &[0, 3, 5]).unwrap();
        assert_eq!(c.pos(0, 0), &[0, 3, 5]);
        // Row 1 after retention == original token 3, within quant error
        // (amax ≈ 3.07 ⇒ tolerance ≈ 0.0121 + fuzz).
        let got = k_at(&c, 0, 0, 0, 1);
        assert!((got - 3.0).abs() < 0.02, "{got}");
    }

    #[test]
    fn quant_backend_delta_pack_matches_fresh_pack() {
        let mut c = GroupCache::with_format(dims(), KvFormat::QuantI8);
        for t in 0..4 {
            for l in 0..2 {
                c.insert(l, 0, &row(t as f32, 2, 4), &row(t as f32, 2, 4), t)
                    .unwrap();
            }
        }
        let mut s = PackScratch::new(&c.dims, 2, 8);
        let st = c.pack_delta(&mut s).unwrap();
        assert_eq!(st.pairs_full, 4);
        assert_matches_fresh_pack(&c, &s);

        // Append-only step: the dequantized delta lands bit-identical.
        c.insert(0, 0, &row(9.0, 2, 4), &row(9.0, 2, 4), 4).unwrap();
        let st = c.pack_delta(&mut s).unwrap();
        assert_eq!(st.pairs_delta, 1);
        assert_matches_fresh_pack(&c, &s);

        // Rewrite (retention) then reconcile: still bit-identical.
        c.apply_retention(0, 0, &[1, 4]).unwrap();
        c.pack_delta(&mut s).unwrap();
        assert_matches_fresh_pack(&c, &s);

        // Reap path: swap + reset, both backends share the epoch logic.
        c.swap_slots(0, 1);
        c.reset_slot(1);
        c.pack_delta(&mut s).unwrap();
        assert_matches_fresh_pack(&c, &s);
    }

    #[test]
    fn format_map_uniform_and_mixed_labels() {
        let u = FormatMap::uniform(3, KvFormat::QuantI4);
        assert_eq!(u.layers(), 3);
        assert_eq!(u.uniform_format(), Some(KvFormat::QuantI4));
        assert_eq!(u.label(), "q4");
        let m = FormatMap::new(vec![KvFormat::F32, KvFormat::QuantI4]);
        assert_eq!(m.uniform_format(), None);
        assert_eq!(m.label(), "mixed");
        assert_eq!(m.get(0), KvFormat::F32);
        assert_eq!(m.get(1), KvFormat::QuantI4);
        assert_eq!(m.as_slice(), &[KvFormat::F32, KvFormat::QuantI4]);
    }

    #[test]
    fn mixed_map_prices_each_layer_at_its_own_rate() {
        // Layer 0 dense (f32), layer 1 group-wise int4, in one group.
        let mut c = GroupCache::with_formats(
            dims(),
            FormatMap::new(vec![KvFormat::F32, KvFormat::QuantI4]),
        );
        assert_eq!(c.format_label(), "mixed");
        for t in 0..3 {
            for l in 0..2 {
                c.insert(l, 0, &row(t as f32, 2, 4), &row(t as f32, 2, 4), t)
                    .unwrap();
            }
        }
        // Per-layer sums: 3 rows * 64 B (f32) + 3 rows * 40 B (q4),
        // not 6 rows at either single-format rate.
        use super::quant::kv_row_bytes;
        let f32_row = kv_row_bytes(2, 4, KvFormat::F32);
        let q4_row = kv_row_bytes(2, 4, KvFormat::QuantI4);
        assert_eq!(c.live_bytes(), 3 * f32_row + 3 * q4_row);
        assert_eq!(c.f32_equivalent_bytes(), 6 * f32_row);
    }

    #[test]
    fn mixed_map_delta_pack_matches_fresh_pack() {
        let mut c = GroupCache::with_formats(
            dims(),
            FormatMap::new(vec![KvFormat::F32, KvFormat::QuantI4]),
        );
        for t in 0..4 {
            for l in 0..2 {
                c.insert(l, 0, &row(t as f32, 2, 4), &row(t as f32, 2, 4), t)
                    .unwrap();
            }
        }
        let mut s = PackScratch::new(&c.dims, 2, 8);
        let st = c.pack_delta(&mut s).unwrap();
        assert_eq!(st.pairs_full, 4);
        assert_matches_fresh_pack(&c, &s);
        // The dense layer's packed rows are exact; the q4 layer's are
        // close (range [0, 3.07] ⇒ tolerance ≈ 0.11).
        assert!((s.k.data[0] - 0.0).abs() < 1e-6);
        c.insert(0, 0, &row(9.0, 2, 4), &row(9.0, 2, 4), 4).unwrap();
        c.insert(1, 0, &row(9.0, 2, 4), &row(9.0, 2, 4), 4).unwrap();
        let st = c.pack_delta(&mut s).unwrap();
        assert_eq!(st.pairs_delta, 2);
        assert_matches_fresh_pack(&c, &s);
        c.apply_retention(1, 0, &[0, 2]).unwrap();
        c.swap_slots(0, 1);
        c.pack_delta(&mut s).unwrap();
        assert_matches_fresh_pack(&c, &s);
    }

    #[test]
    fn migrate_layer_format_keeps_bookkeeping_and_values() {
        let mut c = GroupCache::new(dims());
        for t in 0..5 {
            c.insert(0, 0, &row(t as f32, 2, 4), &row(-(t as f32), 2, 4), t)
                .unwrap();
        }
        c.accumulate_scores(0, 0, 1.0, &[0.1, 0.2, 0.3, 0.4, 0.5]);
        let pos0 = c.pos(0, 0).to_vec();
        let sc0 = c.scores(0, 0).to_vec();
        let bytes_dense = c.live_bytes();
        let e_before = c.slot_epoch(0, 0);
        let other_layer = c.slot_epoch(1, 0);
        assert!(c.migrate_layer_format(0, KvFormat::QuantI8).unwrap());
        assert_eq!(c.format_map().get(0), KvFormat::QuantI8);
        assert_eq!(c.format_label(), "mixed");
        // Bookkeeping untouched, bytes repriced at the new rate.
        assert_eq!(c.len(0, 0), 5);
        assert_eq!(c.pos(0, 0), &pos0[..]);
        assert_eq!(c.scores(0, 0), &sc0[..]);
        assert!(c.live_bytes() < bytes_dense);
        assert_eq!(c.f32_equivalent_bytes(), bytes_dense);
        // Migration is a rewrite of exactly that layer.
        let e_after = c.slot_epoch(0, 0);
        assert!(e_after.epoch > e_before.epoch);
        assert_eq!(e_after.rewrite, e_after.epoch, "migration is a rewrite");
        assert_eq!(c.slot_epoch(1, 0), other_layer, "other layers untouched");
        // Values survive the dequant → requant round trip (q8 bound).
        let got = k_at(&c, 0, 0, 0, 3);
        assert!((got - 3.0).abs() < 0.03, "{got}");
        // No-op migration reports false and bumps nothing.
        assert!(!c.migrate_layer_format(0, KvFormat::QuantI8).unwrap());
        assert_eq!(c.slot_epoch(0, 0), e_after);
        // Out-of-range layer is an error.
        assert!(c.migrate_layer_format(7, KvFormat::F32).is_err());
    }

    #[test]
    fn migrate_layer_format_keeps_delta_pack_bit_identical() {
        let mut c = GroupCache::with_format(dims(), KvFormat::QuantI8);
        for t in 0..4 {
            for l in 0..2 {
                c.insert(l, 0, &row(t as f32, 2, 4), &row(t as f32, 2, 4), t)
                    .unwrap();
            }
        }
        // Retention first, so the old store carries stale dead rows the
        // migrated store must NOT inherit.
        c.apply_retention(1, 0, &[0, 2, 3]).unwrap();
        let mut s = PackScratch::new(&c.dims, 2, 8);
        c.pack_delta(&mut s).unwrap();
        c.migrate_layer_format(1, KvFormat::F32).unwrap();
        let st = c.pack_delta(&mut s).unwrap();
        assert_eq!(st.pairs_full, 2, "exactly the migrated layer repacks");
        assert_eq!(st.pairs_skipped, 2);
        assert_matches_fresh_pack(&c, &s);
        // An append after migration lands on the new store via the
        // normal delta path.
        c.insert(1, 0, &row(9.0, 2, 4), &row(9.0, 2, 4), 4).unwrap();
        let st = c.pack_delta(&mut s).unwrap();
        assert_eq!(st.pairs_delta, 1);
        assert_matches_fresh_pack(&c, &s);
    }

    #[test]
    fn q4_backend_end_to_end_retention_and_pack() {
        let mut c = GroupCache::with_format(dims(), KvFormat::QuantI4);
        assert_eq!(c.format_label(), "q4");
        for t in 0..6 {
            c.insert(0, 0, &row(t as f32, 2, 4), &row(t as f32, 2, 4), t)
                .unwrap();
        }
        c.apply_retention(0, 0, &[0, 3, 5]).unwrap();
        assert_eq!(c.pos(0, 0), &[0, 3, 5]);
        // Row 1 after retention == original token 3, within the group
        // quant error (range [0, 3.03] ⇒ tolerance ≈ 0.101 + fuzz).
        let got = k_at(&c, 0, 0, 0, 1);
        assert!((got - 3.0).abs() < 0.11, "{got}");
        let mut s = PackScratch::new(&c.dims, 2, 8);
        c.pack_delta(&mut s).unwrap();
        assert_matches_fresh_pack(&c, &s);
    }

    /// Dequantizing the packed image must reproduce the f32 upload
    /// image bit-exactly: `read_rows` on a quantized store IS
    /// "dequantize the stored codes", and the packed export carries
    /// those same codes and scales.
    fn assert_matches_fresh_pack_packed(c: &GroupCache, s: &PackedScratch) {
        let (bb, cap) = s.bucket();
        let d = c.dims.d_head;
        let shape = [c.dims.layers, bb, c.dims.kv_heads, cap, d];
        let mut k = HostTensorF32::zeros(&shape);
        let mut v = HostTensorF32::zeros(&shape);
        let mut lens = HostTensorI32::zeros(&[c.dims.layers, bb]);
        c.pack(bb, cap, &mut k, &mut v, &mut lens).unwrap();
        assert_eq!(lens.data, s.lens.data, "lens diverged from fresh pack");
        let db = quant::packed_codes_per_row(d, s.format()).unwrap();
        let sg = quant::packed_scales_per_row(d, s.format()).unwrap();
        let rows = c.dims.layers * bb * c.dims.kv_heads * cap;
        let mut out = vec![0.0f32; d];
        for (codes, scales, zeros, img) in [
            (&s.k_codes, &s.k_scales, &s.k_zeros, &k),
            (&s.v_codes, &s.v_scales, &s.v_zeros, &v),
        ] {
            for r in 0..rows {
                match s.format() {
                    KvFormat::QuantI8 => quant::dequantize_span(
                        crate::runtime::tensors::as_i8(
                            &codes.data[r * db..(r + 1) * db]),
                        scales.data[r],
                        &mut out,
                    ),
                    KvFormat::QuantI4 => quant::dequantize_row_q4(
                        &codes.data[r * db..(r + 1) * db],
                        &scales.data[r * sg..(r + 1) * sg],
                        &zeros.data[r * sg..(r + 1) * sg],
                        &mut out,
                    ),
                    KvFormat::F32 => unreachable!(),
                }
                assert_eq!(out, img.data[r * d..(r + 1) * d],
                           "packed row {r} diverged from fresh pack");
            }
        }
    }

    #[test]
    fn packed_delta_pack_tracks_epochs_and_prices_wire_bytes() {
        let mut c = GroupCache::with_format(dims(), KvFormat::QuantI8);
        for t in 0..3 {
            for l in 0..2 {
                c.insert(l, 0, &row(t as f32, 2, 4), &row(t as f32, 2, 4), t)
                    .unwrap();
            }
        }
        let mut s = PackedScratch::new(&c.dims, 2, 8, KvFormat::QuantI8);
        let st = c.pack_delta_packed(&mut s).unwrap();
        assert_eq!(st.pairs_full, 4, "cold sync re-copies every pair");
        assert_matches_fresh_pack_packed(&c, &s);

        // One append: 1 row * 2 heads * (4 code bytes + 1 f32 scale) * 2
        // tensors on the wire; the f32-equivalent prices the same rows
        // at 4 bytes per element.
        c.insert(0, 0, &row(9.0, 2, 4), &row(9.0, 2, 4), 3).unwrap();
        let st = c.pack_delta_packed(&mut s).unwrap();
        assert_eq!(st.pairs_delta, 1);
        assert_eq!(st.pairs_skipped, 3);
        assert_eq!(st.pairs_full, 0);
        assert_eq!(st.bytes_copied, 2 * (4 + 4) * 2);
        assert_eq!(st.bytes_f32_equiv, 2 * 4 * 4 * 2);
        assert_matches_fresh_pack_packed(&c, &s);

        // No change at all: pure skip, zero bytes.
        let st = c.pack_delta_packed(&mut s).unwrap();
        assert_eq!(st.pairs_skipped, 4);
        assert_eq!(st.bytes_copied, 0);

        // Retention rewrites exactly the touched pair.
        c.apply_retention(0, 0, &[0, 2]).unwrap();
        let st = c.pack_delta_packed(&mut s).unwrap();
        assert_eq!(st.pairs_full, 1);
        assert_eq!(st.pairs_skipped, 3);
        assert_matches_fresh_pack_packed(&c, &s);
    }

    #[test]
    fn packed_delta_pack_q4_round_trips_and_survives_rewrites() {
        let mut c = GroupCache::with_format(dims(), KvFormat::QuantI4);
        for t in 0..5 {
            for l in 0..2 {
                c.insert(l, 0, &row(t as f32, 2, 4), &row(-(t as f32), 2, 4),
                         t)
                    .unwrap();
            }
        }
        let mut s = PackedScratch::new(&c.dims, 2, 8, KvFormat::QuantI4);
        c.pack_delta_packed(&mut s).unwrap();
        assert_matches_fresh_pack_packed(&c, &s);
        c.insert(0, 0, &row(9.0, 2, 4), &row(9.0, 2, 4), 5).unwrap();
        let st = c.pack_delta_packed(&mut s).unwrap();
        assert_eq!(st.pairs_delta, 1);
        // 1 row * 2 heads * (2 packed bytes + 8 scale/zero bytes) * 2.
        assert_eq!(st.bytes_copied, 2 * (2 + 8) * 2);
        assert_matches_fresh_pack_packed(&c, &s);
        c.apply_retention(1, 0, &[0, 3]).unwrap();
        c.swap_slots(0, 1);
        let st = c.pack_delta_packed(&mut s).unwrap();
        assert_eq!(st.pairs_full, 4, "swap rewrites both slots, all layers");
        assert_matches_fresh_pack_packed(&c, &s);
    }

    #[test]
    fn packed_delta_pack_rejects_non_uniform_or_wrong_format() {
        let mut s = PackedScratch::new(&dims(), 2, 8, KvFormat::QuantI8);
        assert_eq!(s.format(), KvFormat::QuantI8);
        // Dense cache has no packed wire form.
        let dense = GroupCache::new(dims());
        assert!(dense.pack_delta_packed(&mut s).is_err());
        // Mixed maps fall back to the f32 image too.
        let mixed = GroupCache::with_formats(
            dims(),
            FormatMap::new(vec![KvFormat::QuantI8, KvFormat::QuantI4]),
        );
        assert!(mixed.pack_delta_packed(&mut s).is_err());
        // Uniform-but-different format is rejected as well.
        let q4 = GroupCache::with_format(dims(), KvFormat::QuantI4);
        assert!(q4.pack_delta_packed(&mut s).is_err());
        // The q8 scratch still works against a matching cache.
        let mut c = GroupCache::with_format(dims(), KvFormat::QuantI8);
        c.insert(0, 0, &row(1.0, 2, 4), &row(1.0, 2, 4), 0).unwrap();
        c.pack_delta_packed(&mut s).unwrap();
        assert_matches_fresh_pack_packed(&c, &s);
        // image_bytes: codes + scales (+ empty zeros) + lens, K and V.
        let rows = 2 * 2 * 2 * 8; // L * bb * Hkv * C
        assert_eq!(s.image_bytes(), rows * (4 + 4) * 2 + 2 * 2 * 4);
    }

    #[test]
    fn slot_views_are_disjoint_and_usable_in_parallel() {
        let mut c = GroupCache::new(dims());
        let views = c.slot_views_mut(2);
        std::thread::scope(|sc| {
            for (i, mut view) in views.into_iter().enumerate() {
                sc.spawn(move || {
                    for t in 0..4 {
                        for l in 0..view.layers() {
                            view.insert(l, &row(i as f32, 2, 4),
                                        &row(i as f32, 2, 4), t)
                                .unwrap();
                        }
                    }
                    view.accumulate_scores(0, 1.0, &[0.5; 4]);
                    view.apply_retention(0, &[1, 3]).unwrap();
                });
            }
        });
        assert_eq!(c.len(0, 0), 2);
        assert_eq!(c.len(0, 1), 2);
        assert_eq!(c.len(1, 0), 4);
        assert_eq!(c.pos(0, 1), &[1, 3]);
        assert!((c.scores(0, 0)[0] - 0.5).abs() < 1e-6);
        // Slot 1's K data must be the value its own thread wrote.
        assert!((k_at(&c, 0, 1, 0, 0) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn evict_restore_round_trips_all_formats() {
        for fmt in [KvFormat::F32, KvFormat::QuantI8, KvFormat::QuantI4] {
            let mut c = GroupCache::with_format(dims(), fmt);
            for t in 0..5 {
                for l in 0..2 {
                    c.insert(l, 0, &row(t as f32, 2, 4),
                             &row(-(t as f32), 2, 4), t)
                        .unwrap();
                }
            }
            c.accumulate_scores(0, 0, 1.0, &[0.1, 0.2, 0.3, 0.4, 0.5]);
            // Stored state snapshot through the deterministic read path.
            let d = c.dims.d_head;
            let mut before = vec![0.0; 5 * d];
            c.kv.read_rows(0, 0, 0, false, 0, 5, &mut before);
            let img = c.evict_to_host(0);
            assert_eq!(img.payload_bytes(), c.slot_live_bytes(0),
                       "image carries exactly the slot's stored bytes");
            assert_eq!(img.max_rows(), 5);
            c.reset_slot(0);
            assert_eq!(c.len(0, 0), 0);
            let e0 = c.slot_epoch(0, 0);
            c.restore_from_host(0, &img).unwrap();
            assert_eq!(c.len(0, 0), 5);
            assert_eq!(c.len(1, 0), 5);
            assert_eq!(c.pos(0, 0), &[0, 1, 2, 3, 4]);
            assert!((c.scores(0, 0)[4] - 0.5).abs() < 1e-6);
            let mut after = vec![0.0; 5 * d];
            c.kv.read_rows(0, 0, 0, false, 0, 5, &mut after);
            assert_eq!(before, after,
                       "restore must be bit-exact at stored precision ({fmt:?})");
            // Restore is a rewrite: the next delta-pack re-copies it.
            let e1 = c.slot_epoch(0, 0);
            assert!(e1.epoch > e0.epoch);
            assert_eq!(e1.rewrite, e1.epoch, "restore is a rewrite");
        }
    }

    #[test]
    fn evict_restore_can_target_a_different_slot() {
        let mut c = GroupCache::with_format(dims(), KvFormat::QuantI8);
        for t in 0..3 {
            c.insert(0, 0, &row(t as f32, 2, 4), &row(t as f32, 2, 4), t)
                .unwrap();
        }
        let img = c.evict_to_host(0);
        c.reset_slot(0);
        c.restore_from_host(1, &img).unwrap();
        assert_eq!(c.len(0, 1), 3);
        assert_eq!(c.pos(0, 1), &[0, 1, 2]);
        // Delta-pack after restore matches a fresh pack (the rewrite
        // watermark forces a full re-copy of the restored pairs).
        let mut s = PackScratch::new(&c.dims, 2, 8);
        c.pack_delta(&mut s).unwrap();
        assert_matches_fresh_pack(&c, &s);
    }

    #[test]
    fn restore_rejects_changed_layer_format() {
        let mut c = GroupCache::new(dims());
        c.insert(0, 0, &row(1.0, 2, 4), &row(1.0, 2, 4), 0).unwrap();
        let img = c.evict_to_host(0);
        c.migrate_layer_format(0, KvFormat::QuantI8).unwrap();
        let err = c.restore_from_host(0, &img).unwrap_err();
        assert!(err.to_string().contains("format changed"), "{err}");
        // Validation failed before any mutation: the slot still holds
        // the (migrated) pre-restore row.
        assert_eq!(c.len(0, 0), 1);
    }

    #[test]
    fn slot_live_bytes_sums_to_live_bytes() {
        let mut c = GroupCache::with_formats(
            dims(),
            FormatMap::new(vec![KvFormat::F32, KvFormat::QuantI4]),
        );
        for t in 0..3 {
            for l in 0..2 {
                c.insert(l, 0, &row(t as f32, 2, 4), &row(t as f32, 2, 4), t)
                    .unwrap();
            }
        }
        c.insert(0, 1, &row(7.0, 2, 4), &row(7.0, 2, 4), 0).unwrap();
        assert_eq!(c.slot_live_bytes(0) + c.slot_live_bytes(1),
                   c.live_bytes());
        assert!(c.slot_live_bytes(0) > c.slot_live_bytes(1));
    }

    #[test]
    fn quant_slot_views_parallel_insert_and_retain() {
        let mut c = GroupCache::with_format(dims(), KvFormat::QuantI8);
        let views = c.slot_views_mut(2);
        std::thread::scope(|sc| {
            for (i, mut view) in views.into_iter().enumerate() {
                sc.spawn(move || {
                    for t in 0..4 {
                        for l in 0..view.layers() {
                            view.insert(l, &row(i as f32 + 1.0, 2, 4),
                                        &row(i as f32 + 1.0, 2, 4), t)
                                .unwrap();
                        }
                    }
                    view.apply_retention(0, &[0, 2]).unwrap();
                });
            }
        });
        assert_eq!(c.len(0, 0), 2);
        assert_eq!(c.len(0, 1), 2);
        assert_eq!(c.pos(0, 1), &[0, 2]);
        assert!((k_at(&c, 0, 1, 0, 0) - 2.0).abs() < 0.02);
    }
}
