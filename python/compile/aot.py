"""AOT lowering: JAX entry points -> HLO *text* artifacts for the rust
runtime, plus the weights blob and the model/tokenizer manifest.

HLO text (not `.serialize()`d protos) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1
(what the published `xla` 0.1.6 crate links) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Artifacts (all under artifacts/):
    model_meta.json   — config, vocab, weight specs, executable manifest
    weights.bin       — raw LE f32, WEIGHT_NAMES order (trained if
                        weights.npz exists from train.py, else seeded init)
    <name>.hlo.txt    — one per (entry point, shape bucket)

Run via `make artifacts`; python never runs again after this.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M
from compile import tasks

# Shape buckets — the wire contract with rust/src/runtime/registry.rs.
# Decode executables exist per (batch, capacity) pair: the engine picks the
# smallest compiled C >= the group's max live cache length, so Lethe's
# pruning translates directly into smaller uploads + shorter attention.
CACHE_PROFILES = {"std": 512, "long": 2048}
DECODE_CAPACITIES = {"std": [128, 256, 512], "long": [1024, 2048]}
DECODE_BATCHES = {"std": [1, 2, 4, 8], "long": [1]}
PREFILL_TS = [32, 64, 128, 192]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_entry_points(cfg: M.ModelConfig):
    """(name, fn, example_args, outputs) for every bucket. Argument order
    convention: weights tuple first (WEIGHT_NAMES order), then state, then
    step inputs — mirrored in rust/src/runtime/registry.rs."""
    L, hkv, dh = cfg.n_layers, cfg.n_kv_heads, cfg.d_head
    hq, V = cfg.n_q_heads, cfg.vocab_size
    w_specs = [_spec(s) for _, s in M.weight_specs(cfg)]
    nw = len(w_specs)

    def wdict(args):
        return dict(zip(M.WEIGHT_NAMES, args[:nw]))

    entries = []
    for T in PREFILL_TS:
        def prefill_fn(*args):
            return M.prefill(cfg, wdict(args), args[nw], args[nw + 1])
        entries.append((
            f"prefill_t{T}", prefill_fn,
            w_specs + [_spec((1, T), jnp.int32), _spec((), jnp.int32)],
            ["logits", "k_all", "v_all", "scores"]))

        # Incremental prefill: the chunk attends over a prior KV window
        # instead of the engine recomputing the whole consumed prefix.
        P = M.PREFILL_KV_CAP
        kvp = _spec((L, 1, hkv, P, dh))

        def prefill_kv_fn(*args):
            return M.prefill_kv(cfg, wdict(args), args[nw], args[nw + 1],
                                args[nw + 2], args[nw + 3], args[nw + 4])
        entries.append((
            f"prefill_t{T}_kv", prefill_kv_fn,
            w_specs + [kvp, kvp, _spec((), jnp.int32),
                       _spec((1, T), jnp.int32), _spec((), jnp.int32)],
            ["logits", "k_new", "v_new", "scores"]))

    for prof in CACHE_PROFILES:
        for C in DECODE_CAPACITIES[prof]:
            for B in DECODE_BATCHES[prof]:
                kvb = _spec((L, B, hkv, C, dh))
                lensb = _spec((L, B), jnp.int32)

                def decode_fn(*args):
                    return M.decode_step(cfg, wdict(args), args[nw],
                                         args[nw + 1], args[nw + 2],
                                         args[nw + 3], args[nw + 4])
                entries.append((
                    f"decode_b{B}_c{C}", decode_fn,
                    w_specs + [kvb, kvb, lensb, _spec((B,), jnp.int32),
                               _spec((B,), jnp.int32)],
                    ["logits", "k_new", "v_new", "probs"]))

                # Kernel-side dequant variants: the KV operands are the
                # quantized stores' bytes (codes + scales[/zeros]) exactly
                # as rust/src/kvcache/backend.rs lays them out, so packed
                # layers upload wire bytes instead of an f32 image.
                q8c = _spec((L, B, hkv, C, dh), jnp.int8)
                q8s = _spec((L, B, hkv, C), jnp.float32)

                def decode_q8_fn(*args):
                    return M.decode_step_q8(
                        cfg, wdict(args), args[nw], args[nw + 1],
                        args[nw + 2], args[nw + 3], args[nw + 4],
                        args[nw + 5], args[nw + 6])
                entries.append((
                    f"decode_b{B}_c{C}_q8", decode_q8_fn,
                    w_specs + [q8c, q8s, q8c, q8s, lensb,
                               _spec((B,), jnp.int32),
                               _spec((B,), jnp.int32)],
                    ["logits", "k_new", "v_new", "probs"]))

                q4c = _spec((L, B, hkv, C, M.q4_packed(dh)), jnp.uint8)
                q4g = _spec((L, B, hkv, C, M.q4_groups(dh)), jnp.float32)

                def decode_q4_fn(*args):
                    return M.decode_step_q4(
                        cfg, wdict(args), args[nw], args[nw + 1],
                        args[nw + 2], args[nw + 3], args[nw + 4],
                        args[nw + 5], args[nw + 6], args[nw + 7],
                        args[nw + 8])
                entries.append((
                    f"decode_b{B}_c{C}_q4", decode_q4_fn,
                    w_specs + [q4c, q4g, q4g, q4c, q4g, q4g, lensb,
                               _spec((B,), jnp.int32),
                               _spec((B,), jnp.int32)],
                    ["logits", "k_new", "v_new", "probs"]))
    return entries


def load_or_init_weights(cfg: M.ModelConfig, weights_npz: str):
    if os.path.exists(weights_npz):
        data = np.load(weights_npz)
        ws = {n: jnp.asarray(data[n]) for n in M.WEIGHT_NAMES}
        src = f"trained ({weights_npz})"
    else:
        ws = M.init_weights(cfg, jax.random.PRNGKey(42))
        src = "seeded-init (run python -m compile.train for a trained model)"
    return ws, src


def write_weights_bin(ws: Dict[str, jax.Array], path: str) -> List[dict]:
    layout, off = [], 0
    with open(path, "wb") as f:
        for n in M.WEIGHT_NAMES:
            a = np.asarray(ws[n], dtype=np.float32)
            f.write(a.tobytes())
            layout.append({"name": n, "shape": list(a.shape),
                           "offset": off, "bytes": a.nbytes})
            off += a.nbytes
    return layout


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--weights", default="../artifacts/weights.npz")
    ap.add_argument("--only", default="",
                    help="comma-separated artifact-name prefixes to emit")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cfg = M.ModelConfig()
    ws, wsrc = load_or_init_weights(cfg, args.weights)
    layout = write_weights_bin(ws, os.path.join(args.out_dir, "weights.bin"))
    print(f"weights.bin: {sum(e['bytes'] for e in layout)} bytes [{wsrc}]")

    manifest = []
    only = [p for p in args.only.split(",") if p]
    for name, fn, specs, outs in build_entry_points(cfg):
        if only and not any(name.startswith(p) for p in only):
            continue
        text = to_hlo_text(jax.jit(fn).lower(*specs))
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.append({
            "name": name,
            "file": f"{name}.hlo.txt",
            "params": [{"shape": list(s.shape), "dtype": s.dtype.name}
                       for s in specs],
            "outputs": outs,
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        })
        print(f"  {name}: {len(text)} chars")

    meta = {
        "model": {
            "vocab_size": cfg.vocab_size, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_q_heads": cfg.n_q_heads,
            "n_kv_heads": cfg.n_kv_heads, "d_head": cfg.d_head,
            "d_ff": cfg.d_ff, "rope_theta": cfg.rope_theta,
            "norm_eps": cfg.norm_eps,
            "param_count": cfg.param_count(),
            "weights_source": wsrc,
        },
        "tokenizer": {"specials": tasks.SPECIALS, "chars": tasks.CHARS,
                      "pad": tasks.PAD, "bos": tasks.BOS, "eos": tasks.EOS},
        "weights": layout,
        "cache_profiles": CACHE_PROFILES,
        "decode_capacities": DECODE_CAPACITIES,
        "decode_batches": DECODE_BATCHES,
        "prefill_ts": PREFILL_TS,
        "executables": manifest,
    }
    with open(os.path.join(args.out_dir, "model_meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print(f"model_meta.json: {len(manifest)} executables")


if __name__ == "__main__":
    main()
