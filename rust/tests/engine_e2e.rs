//! End-to-end integration over the REAL runtime: loads the AOT
//! artifacts, runs prefill + decode through PJRT, exercises policies and
//! continuous batching, and checks cross-layer invariants. These tests
//! are skipped (with a notice) when artifacts are not built.

use std::path::Path;

use lethe::config::ServingConfig;
use lethe::engine::{Engine, FinishReason, SeqState};
use lethe::model::Tokenizer;
use lethe::policy::{make_policy, PolicyKind};
use lethe::runtime::Runtime;
use lethe::scheduler::{Request, Scheduler};
use lethe::util::prng::Rng;
use lethe::workload::make_task;

fn engine_or_skip() -> Option<(Engine, Tokenizer)> {
    let dir = Path::new("artifacts");
    if !dir.join("model_meta.json").exists() {
        eprintln!("[skip] run `make artifacts` first");
        return None;
    }
    let rt = Runtime::load(dir).expect("runtime loads");
    let tok = Tokenizer::from_meta(&rt.meta).unwrap();
    let mut cfg = ServingConfig::default();
    cfg.lethe.evict_threshold = 48;
    cfg.baseline.budget = 48;
    Some((Engine::new(rt, cfg).unwrap(), tok))
}

/// The serving path agrees with itself: prefill+decode is deterministic.
#[test]
fn generation_is_deterministic() {
    let Some((mut engine, tok)) = engine_or_skip() else { return };
    let layers = engine.dims().n_layers;
    let task = make_task(&mut Rng::new(1), 8, 2);
    let prompt = tok.encode_prompt(&task.prompt).unwrap();
    let mut outs = Vec::new();
    for _ in 0..2 {
        let mut group = engine.new_group(1, PolicyKind::Lethe);
        let seq = SeqState::new(
            0,
            make_policy(PolicyKind::Lethe, &engine.cfg, layers),
            layers,
            32,
            tok.eos,
        );
        engine.prefill(&mut group, 0, seq, &prompt).unwrap();
        engine.run_group(&mut group).unwrap();
        outs.push(tok.decode(&group.done[0].generated));
    }
    assert_eq!(outs[0], outs[1], "greedy decode must be deterministic");
}

/// Pruning under pressure: Lethe generates a long sequence without the
/// per-layer cache ever exceeding the compiled capacity, with multiple
/// pruning rounds, and the capacity bucket the engine runs at stays low.
#[test]
fn lethe_prunes_under_long_generation() {
    let Some((mut engine, tok)) = engine_or_skip() else { return };
    // Aggressive pruning pressure so multiple rounds fire within a
    // 220-token generation (τ=400 on a 4-layer tiny model can
    // legitimately delay for hundreds of tokens).
    engine.cfg.lethe.sparse_ratio = 10.0;
    engine.cfg.lethe.evict_threshold = 40;
    let layers = engine.dims().n_layers;
    let task = make_task(&mut Rng::new(2), 24, 4);
    let prompt = tok.encode_prompt(&task.prompt).unwrap();
    let mut group = engine.new_group(1, PolicyKind::Lethe);
    let mut seq = SeqState::new(
        0,
        make_policy(PolicyKind::Lethe, &engine.cfg, layers),
        layers,
        220,
        -1, // ignore EOS: force a long generation
    );
    seq.max_new = 220;
    engine.prefill(&mut group, 0, seq, &prompt).unwrap();
    while group.active() > 0 {
        engine.step(&mut group).unwrap();
        assert!(group.cache.max_len() <= engine.cmax);
        group.reap();
    }
    let done = &group.done[0];
    assert_eq!(done.finished, Some(FinishReason::Length));
    assert!(
        done.prune_log.len() >= 2,
        "expected multi-round pruning, got {} events",
        done.prune_log.len()
    );
    let _ = layers;
    // 220 generated + ~150 prompt >> retained: memory actually shrank.
    let max_retained = done
        .prune_log
        .iter()
        .map(|e| e.after)
        .max()
        .unwrap_or(usize::MAX);
    assert!(max_retained < 220, "retained {max_retained}");
    // Small capacity buckets were actually used (the throughput lever).
    // The histogram is pre-seeded with every compiled bucket at zero,
    // so only buckets that served steps count.
    assert!(
        engine
            .metrics
            .capacity_hist
            .iter()
            .filter(|(_, n)| **n > 0)
            .map(|(c, _)| *c)
            .min()
            .unwrap()
            <= 256,
        "never ran at a small bucket: {:?}",
        engine.metrics.capacity_hist
    );
}

/// FullKV on the std profile must hit the OOM path on a long generation
/// (paper Tables 2–3 behaviour), and the sequence is failed cleanly.
#[test]
fn fullkv_ooms_cleanly_at_capacity() {
    let Some((mut engine, tok)) = engine_or_skip() else { return };
    let layers = engine.dims().n_layers;
    let task = make_task(&mut Rng::new(3), 8, 2);
    let prompt = tok.encode_prompt(&task.prompt).unwrap();
    let mut group = engine.new_group(1, PolicyKind::FullKv);
    let mut seq = SeqState::new(
        0,
        make_policy(PolicyKind::FullKv, &engine.cfg, layers),
        layers,
        4096,
        -1,
    );
    seq.max_new = 4096;
    engine.prefill(&mut group, 0, seq, &prompt).unwrap();
    while group.active() > 0 {
        engine.step(&mut group).unwrap();
        group.reap();
    }
    assert_eq!(group.done[0].finished, Some(FinishReason::Oom));
    assert!(engine.metrics.ooms >= 1);
}

/// Continuous batching: more requests than slots, mixed policies, all
/// complete, slots recycle, and per-request isolation holds (each
/// completion decodes to vocabulary text).
#[test]
fn scheduler_continuous_batching_completes_all() {
    let Some((mut engine, tok)) = engine_or_skip() else { return };
    engine.cfg.scheduler.max_batch = 2;
    let mut sched = Scheduler::new(&engine, PolicyKind::Lethe);
    let mut rng = Rng::new(4);
    let n = 5;
    for id in 0..n {
        let task = make_task(&mut rng, 8, 1 + (id as usize % 3));
        sched
            .submit(Request {
                id,
                prompt: tok.encode_prompt(&task.prompt).unwrap(),
                max_new_tokens: 24,
                policy: if id % 2 == 0 {
                    PolicyKind::Lethe
                } else {
                    PolicyKind::H2o
                },
                submitted_at: std::time::Instant::now(),
                deadline_ms: None,
                class: String::new(),
            })
            .unwrap();
    }
    let completions = sched.run_to_idle(&mut engine).unwrap();
    assert_eq!(completions.len(), n as usize);
    let mut ids: Vec<u64> = completions.iter().map(|c| c.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..n).collect::<Vec<_>>());
    for c in &completions {
        assert!(c.generated.len() <= 24);
        assert!(c.total >= c.ttft);
    }
}

/// TCP front-end round trip: JSON-line request over a real socket,
/// through the router + engine, JSON response back; malformed input is
/// answered with an error object, not a dropped connection.
#[test]
fn tcp_frontend_serves_json_lines() {
    use std::io::{BufRead, Write};

    if !Path::new("artifacts/model_meta.json").exists() {
        eprintln!("[skip] run `make artifacts` first");
        return;
    }
    let mut cfg = ServingConfig::default();
    cfg.lethe.evict_threshold = 48;
    let server = std::sync::Arc::new(
        lethe::server::Server::start(cfg, PolicyKind::Lethe).unwrap(),
    );
    let fe = lethe::server::tcp::TcpFrontend::bind(
        std::sync::Arc::clone(&server),
        "127.0.0.1:0",
        2,
    )
    .unwrap();
    let addr = fe.addr;
    let accept = std::thread::spawn(move || fe.serve(Some(1)).unwrap());

    let task = make_task(&mut Rng::new(77), 8, 2);
    let mut client =
        lethe::server::tcp::TcpClient::connect(addr).unwrap();
    // Malformed line first: must get ok=false, connection stays up.
    {
        let stream = std::net::TcpStream::connect(addr);
        drop(stream); // unrelated: ensure extra connects don't wedge
    }
    let bad = client.request("ÜNKNOWN", 8, None);
    let bad = bad.unwrap();
    assert!(!bad.get("ok").unwrap().as_bool().unwrap());
    // Real request.
    let resp = client
        .request(&task.prompt, 24, Some("lethe"))
        .unwrap();
    assert!(resp.get("ok").unwrap().as_bool().unwrap(), "{resp}");
    let text = resp.get("text").unwrap().as_str().unwrap().to_string();
    assert!(!text.is_empty());
    assert!(resp.get("generated_tokens").unwrap().as_usize().unwrap() <= 24);
    assert_eq!(resp.get("preemptions").unwrap().as_usize().unwrap(), 0);
    // Serving-pressure telemetry: {"stats": true} returns the
    // queue/preemption/migration counters plus the engine metrics.
    let stats = client.stats().unwrap();
    assert!(stats.get("ok").unwrap().as_bool().unwrap(), "{stats}");
    let s = stats.get("stats").unwrap();
    assert_eq!(s.get("queue_depth").unwrap().as_usize().unwrap(), 0);
    assert_eq!(s.get("rejected").unwrap().as_usize().unwrap(), 0);
    assert_eq!(s.get("preemptions").unwrap().as_usize().unwrap(), 0);
    assert_eq!(s.get("resumes").unwrap().as_usize().unwrap(), 0);
    assert!(s.get("kv_migrations").unwrap().as_usize().is_ok());
    let m = s.get("metrics").unwrap();
    assert!(m.get("decode_steps").unwrap().as_usize().unwrap() >= 1);
    drop(client);
    accept.join().unwrap();
}

/// The decode executable's probs output is a true distribution over the
/// live cache — checked through the engine's own bookkeeping.
#[test]
fn attention_scores_are_normalised_through_the_stack() {
    let Some((mut engine, tok)) = engine_or_skip() else { return };
    engine.keep_probs = true;
    let layers = engine.dims().n_layers;
    let task = make_task(&mut Rng::new(5), 8, 2);
    let prompt = tok.encode_prompt(&task.prompt).unwrap();
    let mut group = engine.new_group(1, PolicyKind::FullKv);
    let seq = SeqState::new(
        0,
        make_policy(PolicyKind::FullKv, &engine.cfg, layers),
        layers,
        8,
        tok.eos,
    );
    engine.prefill(&mut group, 0, seq, &prompt).unwrap();
    for _ in 0..4 {
        if group.active() == 0 {
            break;
        }
        engine.step(&mut group).unwrap();
        let p = engine.last_probs.as_ref().unwrap();
        let pv = lethe::attn::score::ProbsView::new(p);
        for l in 0..layers {
            let live = group.cache.len(l, 0);
            let s = lethe::attn::score::head_sum(p, l, 0, pv.capacity());
            let total: f32 = s.iter().sum();
            let heads = pv.heads() as f32;
            assert!(
                (total - heads).abs() < 1e-2,
                "layer {l}: head-summed mass {total} != {heads}"
            );
            // No mass beyond the live region.
            assert!(s[live..].iter().all(|&x| x == 0.0));
        }
        group.reap();
    }
}
