"""Upload-path correctness for the new raw-speed entry points (no AOT
artifacts required — pure JAX):

- decode_step_q8 / decode_step_q4 (kernel-side dequant) must agree with
  decode_step over the host-dequantized f32 image, within the codec's
  round-trip error. The rust engine relies on this to swap the f32 upload
  image for stored codes+scales without changing served tokens.
- prefill_kv (incremental prefill) chunked over a prompt must agree with
  whole-prefix prefill: same last-token logits, same K/V rows, and the
  per-chunk RASR increments must sum to the whole-prefix RASR init.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import tasks

CFG = M.ModelConfig()
L, HKV, HQ, D = CFG.n_layers, CFG.n_kv_heads, CFG.n_q_heads, CFG.d_head


@pytest.fixture(scope="module")
def ws():
    return M.init_weights(CFG, jax.random.PRNGKey(7))


def random_tokens(rng, n):
    return rng.integers(len(tasks.SPECIALS), CFG.vocab_size, size=n,
                        dtype=np.int32)


# --- numpy mirrors of rust/src/kvcache/quant.rs ---------------------------

def quantize_q8(rows):
    """rows [..., D] -> (codes int8, scales [...]) per-row symmetric."""
    amax = np.abs(rows).max(axis=-1)
    scale = amax / 127.0
    inv = np.where(scale > 0, 1.0 / np.where(scale > 0, scale, 1.0), 0.0)
    codes = np.clip(np.rint(rows * inv[..., None]), -127, 127).astype(np.int8)
    return codes, scale.astype(np.float32)


def quantize_q4(rows):
    """rows [..., D] -> (packed uint8 [..., D/2], scales, zeros [..., G])
    group-wise over a zero-widened range, even element in the low nibble."""
    G = M.q4_groups(D)
    g = rows.reshape(*rows.shape[:-1], G, M.Q4_GROUP)
    lo = np.minimum(g.min(axis=-1), 0.0)
    hi = np.maximum(g.max(axis=-1), 0.0)
    scale = ((hi - lo) / 15.0).astype(np.float32)
    safe = np.where(scale > 0, scale, 1.0)
    codes = np.clip(np.rint((g - lo[..., None]) / safe[..., None]), 0, 15)
    codes = codes.astype(np.uint8).reshape(*rows.shape)
    packed = (codes[..., 0::2] | (codes[..., 1::2] << 4)).astype(np.uint8)
    return packed, scale, lo.astype(np.float32)


def build_cache(rng, C, n):
    kv = rng.standard_normal((L, 1, HKV, C, D)).astype(np.float32)
    kv[:, :, :, n:] = 0.0
    return kv


def test_dequant_kv_q4_matches_scalar_reference():
    rng = np.random.default_rng(0)
    rows = rng.standard_normal((3, 5, D)).astype(np.float32)
    packed, scale, zero = quantize_q4(rows)
    out = np.asarray(M.dequant_kv_q4(
        jnp.asarray(packed), jnp.asarray(scale), jnp.asarray(zero), D))
    # Scalar reference, nibble by nibble (even index = low nibble).
    for idx in np.ndindex(3, 5):
        for i in range(D):
            byte = packed[idx][i // 2]
            code = (byte & 0x0F) if i % 2 == 0 else (byte >> 4)
            g = i // M.Q4_GROUP
            want = float(code) * float(scale[idx][g]) + float(zero[idx][g])
            np.testing.assert_allclose(out[idx][i], want, atol=1e-6)
    # Round-trip error respects the codec bound: scale/2 per group.
    err = np.abs(out - rows).reshape(3, 5, M.q4_groups(D), M.Q4_GROUP)
    bound = scale[..., None] * 0.5 + 1e-6
    assert np.all(err <= bound)


def test_decode_q8_matches_host_dequant_decode(ws):
    rng = np.random.default_rng(1)
    C, n = 32, 20
    kv_k, kv_v = build_cache(rng, C, n), build_cache(rng, C, n)
    k_q, k_s = quantize_q8(kv_k)
    v_q, v_s = quantize_q8(kv_v)
    # The f32 path sees the host-dequantized image — exactly what
    # PackScratch uploads for a q8 layer today.
    host_k = k_q.astype(np.float32) * k_s[..., None]
    host_v = v_q.astype(np.float32) * v_s[..., None]
    lens = np.full((L, 1), n, np.int32)
    tok = jnp.asarray([5], jnp.int32)
    pos = jnp.asarray([n], jnp.int32)
    ref = M.decode_step(CFG, ws, jnp.asarray(host_k), jnp.asarray(host_v),
                        jnp.asarray(lens), tok, pos)
    got = M.decode_step_q8(CFG, ws, jnp.asarray(k_q), jnp.asarray(k_s),
                           jnp.asarray(v_q), jnp.asarray(v_s),
                           jnp.asarray(lens), tok, pos)
    for r, g in zip(ref, got):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   atol=1e-6, rtol=1e-6)


def test_decode_q4_close_to_exact_f32_decode(ws):
    rng = np.random.default_rng(2)
    C, n = 32, 20
    kv_k, kv_v = build_cache(rng, C, n), build_cache(rng, C, n)
    k_q, k_s, k_z = quantize_q4(kv_k)
    v_q, v_s, v_z = quantize_q4(kv_v)
    lens = np.full((L, 1), n, np.int32)
    tok = jnp.asarray([5], jnp.int32)
    pos = jnp.asarray([n], jnp.int32)
    exact, _, _, _ = M.decode_step(
        CFG, ws, jnp.asarray(kv_k), jnp.asarray(kv_v), jnp.asarray(lens),
        tok, pos)
    logits, _, _, probs = M.decode_step_q4(
        CFG, ws, jnp.asarray(k_q), jnp.asarray(k_s), jnp.asarray(k_z),
        jnp.asarray(v_q), jnp.asarray(v_s), jnp.asarray(v_z),
        jnp.asarray(lens), tok, pos)
    # q4 is lossy; the decode output drifts by O(codec error), not more.
    assert np.abs(np.asarray(logits) - np.asarray(exact)).max() < 0.5
    p = np.asarray(probs)
    assert np.all(p[:, :, :, n + 1:] == 0.0)
    np.testing.assert_allclose(p.sum(-1), 1.0, atol=1e-4)


def test_prefill_kv_chunks_match_whole_prefix_prefill(ws):
    rng = np.random.default_rng(3)
    n, chunk = 56, 32
    toks = random_tokens(rng, n)
    P = M.PREFILL_KV_CAP

    whole = np.zeros((1, 64), np.int32)
    whole[0, :n] = toks
    ref_logits, ref_k, ref_v, ref_scores = M.prefill(
        CFG, ws, jnp.asarray(whole), jnp.int32(n))

    # Chunk 1 through the classic path (what the engine does for the first
    # chunk), chunk 2 through prefill_kv over the accumulated prior.
    c1 = np.zeros((1, chunk), np.int32)
    c1[0] = toks[:chunk]
    _, k1, v1, s1 = M.prefill(CFG, ws, jnp.asarray(c1), jnp.int32(chunk))

    prior_k = np.zeros((L, 1, HKV, P, D), np.float32)
    prior_v = np.zeros((L, 1, HKV, P, D), np.float32)
    prior_k[:, :, :, :chunk] = np.asarray(k1)
    prior_v[:, :, :, :chunk] = np.asarray(v1)
    acc_scores = np.zeros((L, 1, HQ, P), np.float32)
    acc_scores[..., :chunk] = np.asarray(s1)

    n2 = n - chunk
    c2 = np.zeros((1, chunk), np.int32)
    c2[0, :n2] = toks[chunk:]
    logits, k2, v2, s2 = M.prefill_kv(
        CFG, ws, jnp.asarray(prior_k), jnp.asarray(prior_v),
        jnp.int32(chunk), jnp.asarray(c2), jnp.int32(n2))
    s2 = np.asarray(s2)
    acc_scores[..., :P] += s2[..., :P]
    acc_scores[..., chunk:chunk + n2] += s2[..., P:P + n2]

    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                               atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(k2)[:, :, :, :n2],
                               np.asarray(ref_k)[:, :, :, chunk:n],
                               atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(np.asarray(v2)[:, :, :, :n2],
                               np.asarray(ref_v)[:, :, :, chunk:n],
                               atol=5e-5, rtol=5e-5)
    # Chunk keys past this chunk's real tokens receive no mass, and the
    # RASR increments accumulate to the whole-prefix init.
    assert np.all(s2[..., P + n2:] == 0.0)
    np.testing.assert_allclose(acc_scores[..., :n],
                               np.asarray(ref_scores)[..., :n],
                               atol=2e-3, rtol=2e-3)
    assert np.all(acc_scores[..., n:] == 0.0)


def test_prefill_kv_with_empty_prior_matches_prefill(ws):
    rng = np.random.default_rng(4)
    n = 24
    toks = random_tokens(rng, n)
    padded = np.zeros((1, 32), np.int32)
    padded[0, :n] = toks
    ref_logits, ref_k, _, ref_scores = M.prefill(
        CFG, ws, jnp.asarray(padded), jnp.int32(n))
    P = M.PREFILL_KV_CAP
    zk = jnp.zeros((L, 1, HKV, P, D), jnp.float32)
    logits, k_new, _, scores = M.prefill_kv(
        CFG, ws, zk, zk, jnp.int32(0), jnp.asarray(padded), jnp.int32(n))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                               atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(k_new)[:, :, :, :n],
                               np.asarray(ref_k)[:, :, :, :n],
                               atol=5e-5, rtol=5e-5)
    s = np.asarray(scores)
    assert np.all(s[..., :P] == 0.0)  # no prior rows -> no prior mass
    np.testing.assert_allclose(s[..., P:P + n],
                               np.asarray(ref_scores)[..., :n],
                               atol=2e-3, rtol=2e-3)


def test_aot_grid_contains_upload_path_variants():
    """build_entry_points exposes the packed + incremental entry points
    with the documented operand shapes (pure metadata — no lowering)."""
    from compile import aot

    entries = {name: specs for name, _, specs, _ in
               aot.build_entry_points(CFG)}
    nw = len(M.WEIGHT_NAMES)
    for prof in aot.CACHE_PROFILES:
        for C in aot.DECODE_CAPACITIES[prof]:
            for B in aot.DECODE_BATCHES[prof]:
                q8 = entries[f"decode_b{B}_c{C}_q8"][nw:]
                assert [tuple(s.shape) for s in q8[:2]] == [
                    (L, B, HKV, C, D), (L, B, HKV, C)]
                assert q8[0].dtype == jnp.int8
                q4 = entries[f"decode_b{B}_c{C}_q4"][nw:]
                assert tuple(q4[0].shape) == (L, B, HKV, C, M.q4_packed(D))
                assert q4[0].dtype == jnp.uint8
                assert tuple(q4[1].shape) == (L, B, HKV, C, M.q4_groups(D))
    for T in aot.PREFILL_TS:
        kv = entries[f"prefill_t{T}_kv"][nw:]
        assert tuple(kv[0].shape) == (L, 1, HKV, M.PREFILL_KV_CAP, D)
        assert tuple(kv[3].shape) == (1, T)
