//! FullKV: the no-eviction baseline. Retains every token; OOMs (fails the
//! request) when a sequence outgrows the largest compiled capacity —
//! which is precisely the behaviour Tables 2–3 report at batch 32.

use super::{Capabilities, EvictionPolicy, LayerState};

pub struct FullKv;

impl EvictionPolicy for FullKv {
    fn name(&self) -> &'static str {
        "FullKV"
    }

    fn plan(&mut self, _layer: usize, _st: &LayerState<'_>) -> Option<Vec<usize>> {
        None
    }

    /// `plan` is unconditionally a stateless no-op — FullKV steps never
    /// drain the decode pipeline.
    fn may_prune(&self, _layer: usize, _len: usize, _capacity: usize) -> bool {
        false
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            recency_aware: false,
            attention_aware: false,
            layerwise_budget: false,
            adaptive_budget: false,
            multi_step_pruning: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn never_prunes() {
        let mut p = FullKv;
        let s = vec![0.5f32; 4096];
        let pos: Vec<i32> = (0..4096).collect();
        let st = LayerState {
            scores: &s,
            pos: &pos,
            len: 4096,
            step: 4096,
            sparsity: 1.0,
            capacity: 512,
        };
        assert!(p.plan(0, &st).is_none());
    }
}
