//! StreamingLLM (Xiao et al. 2023): attention sinks + a fixed sliding
//! window, no attention statistics at all. The paper's Table 1 shows the
//! failure mode this repo reproduces: as soon as the token a reasoning
//! hop needs slides out of the window, the chain breaks.

use crate::config::BaselineParams;

use super::{Capabilities, EvictionPolicy, LayerState};

pub struct StreamingLlm {
    params: BaselineParams,
}

impl StreamingLlm {
    pub fn new(params: BaselineParams) -> Self {
        StreamingLlm { params }
    }
}

impl EvictionPolicy for StreamingLlm {
    fn name(&self) -> &'static str {
        "StreamingLLM"
    }

    fn plan(&mut self, _layer: usize, st: &LayerState<'_>) -> Option<Vec<usize>> {
        if st.len <= self.params.budget {
            return None;
        }
        let sink = self.params.sink_len.min(st.len);
        let window = self.params.budget.saturating_sub(sink).max(1);
        let mut keep: Vec<usize> = (0..sink).collect();
        keep.extend(st.len - window..st.len);
        Some(keep)
    }

    /// Stateless policy: `plan` is a pure no-op exactly while the live
    /// length stays within the fixed budget.
    fn may_prune(&self, _layer: usize, len: usize, _capacity: usize) -> bool {
        len > self.params.budget
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            recency_aware: true,
            attention_aware: false,
            layerwise_budget: false,
            adaptive_budget: false,
            multi_step_pruning: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn st<'a>(scores: &'a [f32], pos: &'a [i32]) -> LayerState<'a> {
        LayerState {
            scores,
            pos,
            len: scores.len(),
            step: 5,
            sparsity: 0.5,
            capacity: 1024,
        }
    }

    #[test]
    fn window_is_exact() {
        let params = BaselineParams { budget: 8, sink_len: 2, ..Default::default() };
        let mut p = StreamingLlm::new(params);
        let s = vec![9.0f32; 20]; // scores must be ignored
        let pos: Vec<i32> = (0..20).collect();
        let keep = p.plan(0, &st(&s, &pos)).unwrap();
        let mut k = keep;
        k.sort_unstable();
        assert_eq!(k, vec![0, 1, 14, 15, 16, 17, 18, 19]);
    }

    #[test]
    fn under_budget_noop() {
        let params = BaselineParams { budget: 32, ..Default::default() };
        let mut p = StreamingLlm::new(params);
        let s = vec![0.0f32; 8];
        let pos: Vec<i32> = (0..8).collect();
        assert!(p.plan(0, &st(&s, &pos)).is_none());
    }
}
