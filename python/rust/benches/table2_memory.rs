fn main() {}
