//! Minimal JSON parser/writer (serde_json substitute). Parses the
//! artifact manifest (`model_meta.json`), serving configs, and serializes
//! metrics/bench reports. Supports the full JSON grammar; numbers are f64.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // --- typed accessors (error messages carry the path context the
    // config loader needs) ------------------------------------------------
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => {
                m.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
            }
            _ => bail!("expected object while looking up '{key}'"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("expected non-negative integer, got {x}");
        }
        Ok(x as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object"),
        }
    }

    // --- builders ---------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

// --- parsing ---------------------------------------------------------------

pub fn parse(src: &str) -> Result<Json> {
    let mut p = Parser { b: src.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        bail!("trailing characters at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'",
                  c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4],
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // Surrogate pairs.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                let hex2 = std::str::from_utf8(
                                    &self.b[self.i..self.i + 4],
                                )?;
                                let lo = u32::from_str_radix(hex2, 16)?;
                                self.i += 4;
                                char::from_u32(
                                    0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00),
                                )
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| {
                                anyhow!("invalid unicode escape")
                            })?);
                        }
                        _ => bail!("invalid escape '\\{}'", e as char),
                    }
                }
                _ => {
                    // Collect the full UTF-8 sequence.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                        b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|_| {
            anyhow!("invalid number '{s}' at byte {start}")
        })?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// --- writing ---------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "c"
        );
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(
            parse(r#""😀""#).unwrap(),
            Json::Str("😀".into())
        );
        assert_eq!(parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"nested":{"t":true,"n":null}}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn typed_accessors_error_politely() {
        let v = parse(r#"{"n": 1.5}"#).unwrap();
        assert!(v.get("missing").is_err());
        assert!(v.get("n").unwrap().as_usize().is_err());
        assert_eq!(v.get("n").unwrap().as_f64().unwrap(), 1.5);
    }
}
